"""Shared benchmark harness: the paper's dynamic-workload protocol (§5.2).

Runs the four insert/delete-ratio workloads over the three systems
(LSM-VEC, DiskANN-like, SPFresh-like), recording per batch: Recall 10@10,
modeled update / search I/O cost (Eq. 7-8 with the paper's disk constants),
wall times, and resident-memory bytes.  Results cache to JSON so fig5
(recall/latency) and fig6 (memory) read one run.

Scale note: the paper uses a 100M-vector SIFT subset; this container is a
single CPU core, so the harness defaults to a few thousand vectors with
the same *protocol* (1% batches, same ratios) and validates the paper's
relative claims.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hnsw, iostats
from repro.core.baselines import DiskANNIndex, SPFreshIndex
from repro.core.index import LSMVecIndex, brute_force_knn, recall_at_k
from repro.data.synth import make_clustered_vectors

WORKLOADS = {
    "insert_only": (1.0, 0.0),
    "insert_heavy": (0.7, 0.3),
    "balanced": (0.5, 0.5),
    "delete_heavy": (0.3, 0.7),
}

DISK = iostats.DISK


def default_cfg(dim: int, cap: int) -> hnsw.HNSWConfig:
    return hnsw.HNSWConfig(
        cap=cap, dim=dim, M=12, M_up=6, num_upper=2, ef_search=48,
        ef_construction=48, k=10, m_bits=64, rho=0.8, eps=0.1,
        use_filter=True, lsm_mem_cap=256, lsm_levels=3, lsm_fanout=8)


def _mem_mb(idx) -> float:
    return idx.memory_bytes() / 1e6


def _update_cost_ms(stats_delta, n_ops: int) -> float:
    if n_ops == 0:
        return 0.0
    return float(iostats.search_cost(stats_delta, DISK)) * 1e3 / n_ops


def run_workloads(*, n_base: int = 4096, dim: int = 64, n_batches: int = 8,
                  batch_pct: float = 0.01, n_queries: int = 64,
                  seed: int = 0, out_path: str = "results/workloads.json",
                  use_cache: bool = True) -> List[Dict]:
    if use_cache and os.path.exists(out_path):
        with open(out_path) as f:
            return json.load(f)

    rows: List[Dict] = []
    batch_n = max(8, int(n_base * batch_pct))
    queries = make_clustered_vectors(n_queries, dim=dim, seed=777)

    for wl, (p_ins, p_del) in WORKLOADS.items():
        base = make_clustered_vectors(n_base, dim=dim, seed=seed)
        fresh = make_clustered_vectors(
            batch_n * n_batches + 16, dim=dim, seed=seed + 1)
        cap = n_base + len(fresh) + 16

        systems = {
            "lsmvec": LSMVecIndex.build(default_cfg(dim, cap), base),
            "diskann": DiskANNIndex.build(base, M=12, ef=48),
            "spfresh": SPFreshIndex.build(base, posting_cap=64, n_probe=3),
        }
        # live-set model for ground truth
        vectors = {name: [base.copy()] for name in systems}
        live = {name: np.ones(n_base, bool) for name in systems}
        fresh_cursor = {name: 0 for name in systems}
        rng = np.random.default_rng(seed + 2)

        for b in range(n_batches):
            for name, idx in systems.items():
                t0 = time.monotonic()
                idx.reset_stats() if hasattr(idx, "reset_stats") else None
                stats_before = idx.io_stats
                n_ins = int(round(batch_n * p_ins))
                n_del = batch_n - n_ins
                # inserts — batched systems (LSM-VEC) take the whole batch
                # in one device call; baselines fall back to the loop
                c = fresh_cursor[name]
                fresh_cursor[name] += n_ins
                batch_xs = fresh[c:c + n_ins]
                if n_ins:
                    if hasattr(idx, "insert_batch"):
                        new_ids = idx.insert_batch(batch_xs)
                    else:
                        new_ids = [idx.insert(x) for x in batch_xs]
                    allv = np.concatenate(vectors[name] + [batch_xs])
                    vectors[name] = [allv]
                    live[name] = np.append(live[name],
                                           np.ones(n_ins, bool))
                    assert list(new_ids) == list(
                        range(len(live[name]) - n_ins, len(live[name])))
                # deletes (uniform over live ids)
                live_ids = np.flatnonzero(live[name])
                victims = rng.choice(live_ids, min(n_del, len(live_ids)),
                                     replace=False)
                if len(victims):
                    if hasattr(idx, "delete_batch"):
                        idx.delete_batch(victims)
                    else:
                        for v in victims:
                            idx.delete(int(v))
                    live[name][victims] = False
                upd_wall = time.monotonic() - t0
                stats_delta = jax.tree.map(
                    lambda a, b: a - b, idx.io_stats, stats_before)
                upd_cost = _update_cost_ms(stats_delta, batch_n)

                # search phase
                idx.reset_stats()
                t1 = time.monotonic()
                # LSMVecIndex returns a SearchResult, baselines a plain
                # (ids, dists) tuple
                res = idx.search(queries, k=10)
                ids = res.ids if hasattr(res, "ids") else res[0]
                search_wall = time.monotonic() - t1
                search_cost = float(iostats.search_cost(idx.io_stats, DISK)) \
                    * 1e3 / len(queries)
                allv = vectors[name][0]
                truth = brute_force_knn(
                    jnp.asarray(allv), jnp.asarray(queries), 10,
                    live=jnp.asarray(live[name]))
                rec = recall_at_k(np.asarray(ids), truth)
                rows.append({
                    "workload": wl, "batch": b, "system": name,
                    "recall": round(rec, 4),
                    "update_cost_ms": round(upd_cost, 4),
                    "search_cost_ms": round(search_cost, 4),
                    "update_wall_s": round(upd_wall, 3),
                    "search_wall_s": round(search_wall, 3),
                    "memory_mb": round(_mem_mb(idx), 4),
                    "n_live": int(live[name].sum()),
                })
                print(f"[{wl}] b{b} {name}: recall={rec:.3f} "
                      f"upd={upd_cost:.2f}ms srch={search_cost:.2f}ms "
                      f"mem={_mem_mb(idx):.2f}MB", flush=True)

    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    return rows
