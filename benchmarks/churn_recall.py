"""Churn-tolerance benchmark: lazy deletion + consolidation vs eager.

ROADMAP flags that recall under heavy insert/delete churn degrades on
every path because eager `delete`/`delete_batch` relink-and-tombstone
nodes immediately, severing routes through deleted regions.  The lazy
two-phase protocol (DESIGN.md §9) keeps deleted nodes *routable but not
returnable* until `consolidate` splices them out.  This benchmark sweeps
churn ratios on the `serve_load` instance shape and records, per ratio:

  - **recall_eager**    — recall 10@10 after churn through the eager
    Algorithm-2 delete path (`lazy_delete=False`), the paper baseline;
  - **recall_lazy**     — same churn through tombstone-only deletes,
    queried *before* consolidation (tombstones still routable);
  - **recall_consolidated** — after `consolidate()` reclaims the slots;
  - **qps_pre / qps_lazy / qps_consolidated** — fixed-batch query
    throughput before churn, with tombstones resident, and after
    consolidation (consolidation must restore QPS: the clean graph
    should serve within 10% of the pre-churn index).

Results go to ``BENCH_churn.json``.  ``--smoke`` runs a tiny instance
and validates the schema (the CI mode); ``--check`` additionally
compares the measured smoke recalls against the committed floors in
``BENCH_churn.json`` and exits non-zero on regression — the CI
recall-regression gate (no other job measures recall at all).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from _util import write_bench_json
from repro.core import hnsw
from repro.core.backend import SearchParams
from repro.core.index import LSMVecIndex, brute_force_knn, recall_at_k
from repro.data.synth import make_clustered_vectors

SCHEMA = {
    "meta": ("mode", "backend", "n_base", "dim", "batch", "n_eval",
             "churn_ratios", "config"),
    "sweep": (),          # list of per-ratio dicts, validated separately
    "criteria": ("lazy_beats_eager_by_0p05_at_30pct",
                 "consolidation_restores_qps_within_10pct",
                 "consolidated_tombstone_free"),
    "floors": ("smoke_recall_lazy", "smoke_recall_consolidated",
               "smoke_churn"),
}

SWEEP_FIELDS = ("churn", "n_deleted", "n_inserted", "tombstone_ratio",
                "recall_eager", "recall_lazy", "recall_consolidated",
                "qps_pre", "qps_lazy", "qps_consolidated",
                "slots_reclaimed")

#: margin subtracted from the measured smoke recall to form the committed
#: CI floor — wide enough to absorb cross-platform jax numeric drift,
#: tight enough that a returnable-mask or consolidation regression
#: (which costs far more recall than this) still trips the gate
FLOOR_MARGIN = 0.08

TRIALS = 2   # best-of-N per timed section (container jitter)


def validate_schema(doc: dict) -> None:
    """Raise ValueError unless `doc` matches the BENCH_churn schema."""
    for section, fields in SCHEMA.items():
        if section not in doc:
            raise ValueError(f"missing section {section!r}")
        for f in fields:
            if f not in doc[section]:
                raise ValueError(f"missing field {section}.{f}")
    if not isinstance(doc["sweep"], list) or not doc["sweep"]:
        raise ValueError("sweep must be a non-empty list")
    for row in doc["sweep"]:
        for f in SWEEP_FIELDS:
            if f not in row:
                raise ValueError(f"missing sweep field {f!r}")
            v = row[f]
            if not isinstance(v, (int, float)) or not np.isfinite(v):
                raise ValueError(f"non-finite sweep.{f}: {v!r}")
    for f, v in doc["criteria"].items():
        if not isinstance(v, bool):
            raise ValueError(f"criteria.{f} must be bool, got {v!r}")


def _cfg(dim: int, cap: int, *, lazy: bool) -> hnsw.HNSWConfig:
    # the BENCH_serve instance shape, so the numbers are comparable
    return hnsw.HNSWConfig(
        cap=cap, dim=dim, M=12, M_up=6, num_upper=2, ef_search=48,
        ef_construction=48, k=10, m_bits=64, rho=1.0, eps=0.1,
        use_filter=False, lsm_mem_cap=256, lsm_levels=2, lsm_fanout=8,
        n_expand=1, batch_expand=4, lazy_delete=lazy)


def _fixed_batch_qps(idx: LSMVecIndex, pool: np.ndarray, batch: int,
                     k: int) -> float:
    """Best-of-TRIALS fixed-shape search throughput (the PR-1 path)."""
    nb = len(pool) // batch
    idx.search(pool[:batch], k=k,
               params=SearchParams(record_heat=False))      # compile
    dt = float("inf")
    for _ in range(TRIALS):
        t0 = time.monotonic()
        for b in range(nb):
            idx.search(pool[b * batch:(b + 1) * batch], k=k,
                       params=SearchParams(record_heat=False))
        jax.block_until_ready(idx.state.count)
        dt = min(dt, time.monotonic() - t0)
    return nb * batch / dt


def _apply_churn(idx: LSMVecIndex, victims: np.ndarray, fresh: np.ndarray,
                 batch: int) -> None:
    """Interleaved delete/insert batches — the serving write pattern."""
    for s in range(0, max(len(victims), len(fresh)), batch):
        dv = victims[s:s + batch]
        if len(dv):
            idx.delete_batch(dv, pad_to=batch)
        fv = fresh[s:s + batch]
        if len(fv):
            idx.insert_batch(fv, pad_to=batch)


def run(*, n_base: int, batch: int, dim: int, seed: int,
        churn_ratios: list, n_eval: int, mode: str) -> dict:
    rng = np.random.default_rng(seed)
    max_churn = max(churn_ratios)
    n_fresh_max = int(n_base * max_churn)
    cap = n_base + n_fresh_max + 4 * batch + 64
    base = make_clustered_vectors(n_base, dim=dim, seed=seed)
    eval_q = make_clustered_vectors(n_eval, dim=dim, seed=seed + 3)
    qpool = base[rng.integers(0, n_base, size=max(8, 512 // batch) * batch)]

    cfg_lazy = _cfg(dim, cap, lazy=True)
    cfg_eager = _cfg(dim, cap, lazy=False)
    k = cfg_lazy.k

    # one bulk build; every arm starts from a copy (the lazy_delete flag
    # is config-static, the state arrays are identical) — donated jits
    # consume their input state, hence the copies
    state0 = LSMVecIndex.build(cfg_lazy, base).state

    def fork(cfg):
        return LSMVecIndex(cfg, state=jax.tree.map(jnp.copy, state0))

    # pre-churn reference QPS, measured once on a clean index
    qps_pre = _fixed_batch_qps(fork(cfg_lazy), qpool, batch, k)

    sweep = []
    tombstone_free = True
    for churn in churn_ratios:
        n_churn = int(n_base * churn)
        victims = rng.choice(n_base, n_churn, replace=False).astype(np.int32)
        fresh = make_clustered_vectors(max(n_churn, 1), dim=dim,
                                       seed=seed + 17)[:n_churn]
        live = np.ones(n_base + n_churn, bool)
        live[victims] = False
        allv = np.concatenate([base, fresh]) if n_churn else base
        truth = brute_force_knn(jnp.asarray(allv), jnp.asarray(eval_q), k,
                                live=jnp.asarray(live))
        deleted = set(victims.tolist())

        # ---- eager baseline (the paper's Algorithm-2 delete) -------------
        idx_e = fork(cfg_eager)
        _apply_churn(idx_e, victims, fresh, batch)
        ids_e = idx_e.search(eval_q, k=k).ids
        recall_eager = recall_at_k(ids_e, truth)
        del idx_e

        # ---- lazy: tombstones routable, then consolidated ----------------
        idx_l = fork(cfg_lazy)
        _apply_churn(idx_l, victims, fresh, batch)
        nt = idx_l.n_tombstones
        tomb_ratio = nt / max(idx_l.size + nt, 1)
        ids_l = idx_l.search(eval_q, k=k).ids
        recall_lazy = recall_at_k(ids_l, truth)
        if set(ids_l.flatten().tolist()) & deleted:
            raise AssertionError("tombstoned id returned pre-consolidation")
        qps_lazy = _fixed_batch_qps(idx_l, qpool, batch, k)

        reclaimed = idx_l.consolidate()
        ids_c = idx_l.search(eval_q, k=k).ids
        recall_cons = recall_at_k(ids_c, truth)
        if (set(ids_c.flatten().tolist()) & deleted) \
                or idx_l.n_tombstones != 0:
            tombstone_free = False
        qps_cons = _fixed_batch_qps(idx_l, qpool, batch, k)
        del idx_l

        sweep.append({
            "churn": churn,
            "n_deleted": n_churn,
            "n_inserted": n_churn,
            "tombstone_ratio": round(tomb_ratio, 4),
            "recall_eager": round(recall_eager, 4),
            "recall_lazy": round(recall_lazy, 4),
            "recall_consolidated": round(recall_cons, 4),
            "qps_pre": round(qps_pre, 1),
            "qps_lazy": round(qps_lazy, 1),
            "qps_consolidated": round(qps_cons, 1),
            "slots_reclaimed": reclaimed,
        })

    heavy = [r for r in sweep if r["churn"] >= 0.3] or sweep
    lazy_wins = all(r["recall_lazy"] >= r["recall_eager"] + 0.05
                    for r in heavy)
    qps_restored = all(r["qps_consolidated"] >= 0.9 * r["qps_pre"]
                       for r in sweep)

    # floors for the CI recall-regression gate: committed from a full run,
    # compared against fresh smoke numbers by `--check`
    smoke_row = sweep[-1] if mode == "smoke" else None
    doc = {
        "meta": {
            "mode": mode, "backend": jax.default_backend(),
            "n_base": n_base, "dim": dim, "batch": batch, "n_eval": n_eval,
            "churn_ratios": churn_ratios,
            "config": {kk: vv for kk, vv in cfg_lazy._asdict().items()},
        },
        "sweep": sweep,
        "criteria": {
            "lazy_beats_eager_by_0p05_at_30pct": bool(lazy_wins),
            "consolidation_restores_qps_within_10pct": bool(qps_restored),
            "consolidated_tombstone_free": bool(tombstone_free),
        },
        "floors": {
            "smoke_churn": smoke_row["churn"] if smoke_row else 0.0,
            "smoke_recall_lazy": round(
                max(smoke_row["recall_lazy"] - FLOOR_MARGIN, 0.0), 4)
            if smoke_row else 0.0,
            "smoke_recall_consolidated": round(
                max(smoke_row["recall_consolidated"] - FLOOR_MARGIN, 0.0), 4)
            if smoke_row else 0.0,
        },
    }
    return doc


def smoke_args(seed: int) -> dict:
    return dict(n_base=384, batch=16, dim=16, seed=seed,
                churn_ratios=[0.3], n_eval=32, mode="smoke")


def check_floors(doc: dict, committed_path: str) -> int:
    """CI recall-regression gate: fresh smoke recalls vs committed floors."""
    if not os.path.exists(committed_path):
        print(f"check: no committed {committed_path}; nothing to gate "
              "against (write one with a full run first)")
        return 1
    with open(committed_path) as f:
        committed = json.load(f)
    floors = committed.get("floors", {})
    row = doc["sweep"][-1]
    failures = []
    for field, floor_key in (("recall_lazy", "smoke_recall_lazy"),
                             ("recall_consolidated",
                              "smoke_recall_consolidated")):
        floor = floors.get(floor_key)
        if floor is None:
            failures.append(f"committed floors missing {floor_key}")
            continue
        got = row[field]
        status = "PASS" if got >= floor else "FAIL"
        print(f"  {status} {field}: {got:.4f} >= floor {floor:.4f}")
        if got < floor:
            failures.append(
                f"{field} {got:.4f} regressed below floor {floor:.4f}")
    if failures:
        print("recall-regression gate FAILED:")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print("recall-regression gate passed")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run; validate the JSON schema only")
    ap.add_argument("--check", action="store_true",
                    help="compare smoke recall against the committed "
                         "floors in BENCH_churn.json; non-zero exit on "
                         "regression (the CI gate)")
    ap.add_argument("--out", default=None,
                    help="output path (default: <repo>/BENCH_churn.json)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = args.out or os.path.join(root, "BENCH_churn.json")

    if args.check and not args.smoke:
        # a full run regenerates the committed floors from *current*
        # code; gating against floors it just rewrote would pass any
        # regression, so the combination is refused outright
        ap.error("--check requires --smoke (the gate compares a fresh "
                 "smoke replay against the committed floors)")

    if args.smoke:
        doc = run(**smoke_args(args.seed))
    else:
        doc = run(n_base=4096, batch=64, dim=64, seed=args.seed,
                  churn_ratios=[0.1, 0.3, 0.5], n_eval=64, mode="full")
        # the committed floors come from the smoke instance so the CI
        # gate replays the exact configuration it compares against
        smoke_doc = run(**smoke_args(args.seed))
        doc["floors"] = smoke_doc["floors"]

    validate_schema(doc)
    print(json.dumps(doc, indent=1))
    if args.smoke:
        print("smoke: schema OK (perf criteria not enforced)")
        if args.out:
            # CI uploads the smoke measurement it actually produced; the
            # committed BENCH_churn.json (floors) is never overwritten
            # in smoke mode, so gate comparisons stay against main
            write_bench_json(args.out, doc)
        rc = 0
        if args.check:
            rc = check_floors(doc, os.path.join(root, "BENCH_churn.json"))
        return rc

    write_bench_json(out, doc)
    rc = 0
    for name, ok in doc["criteria"].items():
        print(f"  {'PASS' if ok else 'FAIL'} {name}")
        rc = rc if ok else 1
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
