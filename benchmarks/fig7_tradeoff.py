"""Fig. 7 reproduction: recall-vs-latency frontier (search and update).

Sweeps the per-system quality knob (LSM-VEC/DiskANN: ef; SPFresh: n_probe)
on a static index and reports Recall 10@10 against modeled per-query I/O
cost.  Paper claim validated: at matched recall, LSM-VEC's search cost is
below DiskANN's (the sampling filter skips fetches), and SPFresh's recall
ceiling sits below the graph systems'.
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import DISK, default_cfg
from repro.core import iostats
from repro.core.backend import SearchParams
from repro.core.baselines import DiskANNIndex, SPFreshIndex
from repro.core.index import LSMVecIndex, brute_force_knn, recall_at_k
from repro.data.synth import make_clustered_vectors


def main(n_base: int = 4096, dim: int = 64, n_queries: int = 64):
    base = make_clustered_vectors(n_base, dim=dim, seed=0)
    queries = make_clustered_vectors(n_queries, dim=dim, seed=777)
    truth = brute_force_knn(jnp.asarray(base), jnp.asarray(queries), 10)

    print("\nfig7,system,knob,recall,query_cost_ms")
    frontier = {}
    lv = LSMVecIndex.build(default_cfg(dim, n_base + 16), base)
    for ef in (16, 32, 48, 96):
        lv.reset_stats()
        ids = lv.search(queries, k=10, params=SearchParams(ef=ef)).ids
        cost = float(iostats.search_cost(lv.io_stats, DISK)) * 1e3 / n_queries
        rec = recall_at_k(ids, truth)
        frontier.setdefault("lsmvec", []).append((rec, cost))
        print(f"fig7,lsmvec,ef={ef},{rec:.3f},{cost:.3f}")

    for ef in (16, 32, 48, 96):
        dk = DiskANNIndex.build(base, M=12, ef=ef)
        dk.reset_stats()
        ids, _ = dk.search(queries, k=10)
        cost = float(iostats.search_cost(dk.io_stats, DISK)) * 1e3 / n_queries
        rec = recall_at_k(ids, truth)
        frontier.setdefault("diskann", []).append((rec, cost))
        print(f"fig7,diskann,ef={ef},{rec:.3f},{cost:.3f}")

    sp = SPFreshIndex.build(base, posting_cap=64, n_probe=3)
    for probe in (2, 4, 8, 16):
        sp.n_probe = probe
        sp.reset_stats()
        ids, _ = sp.search(queries, k=10)
        cost = float(iostats.search_cost(sp.io_stats, DISK)) * 1e3 / n_queries
        rec = recall_at_k(ids, truth)
        frontier.setdefault("spfresh", []).append((rec, cost))
        print(f"fig7,spfresh,probe={probe},{rec:.3f},{cost:.3f}")

    # claim: at its best recall point, lsmvec's cost < diskann's cost at
    # comparable-or-lower recall; if diskann never reaches lsmvec's
    # recall, lsmvec dominates the frontier outright
    best_lv = max(frontier["lsmvec"])
    dk_at_least = [c for r, c in frontier["diskann"] if r >= best_lv[0]-0.02]
    if dk_at_least:
        ok = best_lv[1] < min(dk_at_least)
    else:
        ok = best_lv[1] < max(c for _, c in frontier["diskann"])
    print(f"check,lsmvec cheaper than diskann at matched recall,"
          f"{'PASS' if ok else 'FAIL'}")
    ceiling_ok = max(r for r, _ in frontier["spfresh"]) <= \
        max(r for r, _ in frontier["lsmvec"]) + 0.02
    print(f"check,spfresh recall ceiling below graph systems,"
          f"{'PASS' if ceiling_ok else 'FAIL'}")
    return frontier, ok and ceiling_ok


if __name__ == "__main__":
    main()
