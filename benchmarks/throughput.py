"""Hot-path throughput benchmark: batched updates + multi-expansion search.

Records the repo's update/query performance trajectory (the first entry in
it).  Three comparisons, all on CPU-sized data with the paper's protocol:

  - inserts/sec — jit-scanned ``LSMVecIndex.insert_batch`` (one donated
    device call per batch) vs the seed's per-vector loop: one jit dispatch
    per vector with a host sync (``int(state.count)``) before each call.
  - deletes/sec — ``delete_batch`` (one ``lax.scan`` call) vs the per-id
    dispatch loop.
  - batched search QPS — multi-expansion beam search (``n_expand=4``) vs
    the seed-exact one-node-per-hop path (``n_expand=1``), with a
    Recall 10@10 guardrail between the two.

Results are written to ``BENCH_throughput.json`` (repo root by default) so
every future PR has a baseline to beat.  ``--smoke`` runs a tiny instance
and only validates the JSON schema — that is what CI executes.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from _util import write_bench_json
from repro.core import hnsw
from repro.core.backend import SearchParams
from repro.core.index import LSMVecIndex, brute_force_knn, recall_at_k
from repro.data.synth import make_clustered_vectors

SCHEMA = {
    "meta": ("mode", "backend", "n_base", "batch", "n_queries", "dim",
             "config"),
    "insert": ("per_item_ips", "batch_ips", "speedup"),
    "delete": ("per_item_dps", "batch_dps", "speedup"),
    "search": ("qps_b1", "qps_b4", "qps_ratio", "recall_b1", "recall_b4",
               "recall_delta"),
    "criteria": ("insert_speedup_ge_5x", "qps_b4_gt_b1",
                 "recall_within_0p01"),
}


def validate_schema(doc: dict) -> None:
    """Raise ValueError unless `doc` matches the BENCH_throughput schema."""
    for section, fields in SCHEMA.items():
        if section not in doc:
            raise ValueError(f"missing section {section!r}")
        for f in fields:
            if f not in doc[section]:
                raise ValueError(f"missing field {section}.{f}")
    for section in ("insert", "delete", "search"):
        for f, v in doc[section].items():
            if not isinstance(v, (int, float)) or not np.isfinite(v):
                raise ValueError(f"non-finite {section}.{f}: {v!r}")
    for f, v in doc["criteria"].items():
        if not isinstance(v, bool):
            raise ValueError(f"criteria.{f} must be bool, got {v!r}")


def _cfg(dim: int, cap: int) -> hnsw.HNSWConfig:
    return hnsw.HNSWConfig(
        cap=cap, dim=dim, M=12, M_up=6, num_upper=2, ef_search=48,
        ef_construction=48, k=10, m_bits=64, rho=1.0, eps=0.1,
        use_filter=False, lsm_mem_cap=256, lsm_levels=2, lsm_fanout=8,
        n_expand=1, batch_expand=4)


TRIALS = 3   # best-of-N per timed section: shared-CPU containers jitter
             # 30-50% under transient load, and the best trial is the
             # closest observation of what the code path actually costs


def run(*, n_base: int, batch: int, n_queries: int, dim: int, seed: int,
        search_reps: int, mode: str) -> dict:
    cap = n_base + (TRIALS + 4) * batch + 64
    cfg = _cfg(dim, cap)
    base = make_clustered_vectors(n_base, dim=dim, seed=seed)
    idx = LSMVecIndex.build(cfg, base)
    inserted = [base]

    def fresh(s):
        v = make_clustered_vectors(batch, dim=dim, seed=s)
        return v

    # ---- warm both insert paths (compile outside the timed region).
    # The batch warm-up must use the same batch length as the timed call:
    # the jit specializes on it.
    warm_item = make_clustered_vectors(1, dim=dim, seed=seed + 11)
    idx.insert(warm_item[0])
    inserted.append(warm_item)
    warm = fresh(seed + 1)
    idx.insert_batch(warm)
    inserted.append(warm)
    jax.block_until_ready(idx.state.count)

    # ---- inserts/sec (best-of-TRIALS per path) ----------------------------
    xs_item = fresh(seed + 2)
    t0 = time.monotonic()
    for x in xs_item:
        _ = int(idx.state.count)   # the seed's per-call host sync
        idx.insert(x)
    jax.block_until_ready(idx.state.count)
    dt_item = time.monotonic() - t0
    inserted.append(xs_item)

    dt_batch = float("inf")
    for t in range(TRIALS):
        xs_batch = fresh(seed + 3 + t)
        t0 = time.monotonic()
        idx.insert_batch(xs_batch)
        jax.block_until_ready(idx.state.count)
        dt_batch = min(dt_batch, time.monotonic() - t0)
        inserted.append(xs_batch)

    ins = {
        "per_item_ips": round(len(xs_item) / dt_item, 1),
        "batch_ips": round(batch / dt_batch, 1),
        "speedup": round(dt_item / len(xs_item) / (dt_batch / batch), 3),
    }

    # ---- batched search QPS + recall guardrail ----------------------------
    queries = make_clustered_vectors(n_queries, dim=dim, seed=seed + 777)
    allv = np.concatenate(inserted)
    truth = brute_force_knn(jnp.asarray(allv), jnp.asarray(queries), cfg.k)
    search = {}
    for b in (1, 4):
        ids = idx.search(queries, k=cfg.k,
                         params=SearchParams(n_expand=b)).ids  # warm/compile
        dt = float("inf")
        for _ in range(TRIALS):
            t0 = time.monotonic()
            for _ in range(search_reps):
                ids = idx.search(queries, k=cfg.k, params=SearchParams(
                    n_expand=b, record_heat=False)).ids
            jax.block_until_ready(idx.state.count)
            dt = min(dt, (time.monotonic() - t0) / search_reps)
        search[f"qps_b{b}"] = round(n_queries / dt, 1)
        search[f"recall_b{b}"] = round(recall_at_k(ids, truth), 4)
    search["qps_ratio"] = round(search["qps_b4"] / search["qps_b1"], 3)
    search["recall_delta"] = round(
        search["recall_b4"] - search["recall_b1"], 4)

    # ---- deletes/sec ------------------------------------------------------
    n_del = min(batch, idx.size // 5)
    rng = np.random.default_rng(seed + 9)
    victims = rng.choice(idx.size, 3 * n_del + 1, replace=False)
    idx.delete(int(victims[0]))                     # warm per-item
    idx.delete_batch(victims[1:1 + n_del])          # warm batch (same length)
    jax.block_until_ready(idx.state.count)
    t0 = time.monotonic()
    for v in victims[1 + n_del:1 + 2 * n_del]:
        _ = int(idx.state.count)   # the seed's per-call host sync
        idx.delete(int(v))
    jax.block_until_ready(idx.state.count)
    dt_item_d = time.monotonic() - t0
    t0 = time.monotonic()
    idx.delete_batch(victims[1 + 2 * n_del:1 + 3 * n_del])
    jax.block_until_ready(idx.state.count)
    dt_batch_d = time.monotonic() - t0

    dele = {
        "per_item_dps": round(n_del / dt_item_d, 1),
        "batch_dps": round(n_del / dt_batch_d, 1),
        "speedup": round(dt_item_d / dt_batch_d, 3),
    }

    doc = {
        "meta": {
            "mode": mode,
            "backend": jax.default_backend(),
            "n_base": n_base, "batch": batch, "n_queries": n_queries,
            "dim": dim,
            "config": {k: v for k, v in cfg._asdict().items()},
        },
        "insert": ins,
        "delete": dele,
        "search": search,
        "criteria": {
            "insert_speedup_ge_5x": bool(ins["speedup"] >= 5.0),
            "qps_b4_gt_b1": bool(search["qps_ratio"] > 1.0),
            "recall_within_0p01": bool(abs(search["recall_delta"]) <= 0.01),
        },
    }
    return doc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run; validate the JSON schema only")
    ap.add_argument("--out", default=None,
                    help="output path (default: <repo>/BENCH_throughput.json)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = args.out or os.path.join(root, "BENCH_throughput.json")

    if args.smoke:
        doc = run(n_base=256, batch=32, n_queries=16, dim=16,
                  seed=args.seed, search_reps=2, mode="smoke")
    else:
        # SIFT-shaped instance (clustered, dim 64) — large enough that the
        # graph, not fixed overheads, dominates both update paths
        doc = run(n_base=4096, batch=256, n_queries=64, dim=64,
                  seed=args.seed, search_reps=8, mode="full")

    validate_schema(doc)
    print(json.dumps(doc, indent=1))
    if args.smoke:
        print("smoke: schema OK (perf criteria not enforced)")
        if args.out:
            # an explicit --out in smoke mode gets the smoke doc (CI
            # uploads the measurement it produced); the committed full-
            # run JSON is only written by full runs
            write_bench_json(args.out, doc)
        return 0

    write_bench_json(out, doc)
    for name, ok in doc["criteria"].items():
        print(f"  {'PASS' if ok else 'FAIL'} {name}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
