"""Fig. 8 reproduction: sampling-ratio sweep (rho = 1.0 -> 0.7).

Paper claims validated: modeled query cost drops monotonically with rho
(Eq. 8) while recall degrades only modestly; at the paper's rho=0.8
operating point the cost saving is large relative to the recall loss.
Paper numbers at 100M scale: 6.81ms -> 4.72ms (-30%) and 89.2% -> 82.4%
recall across the sweep; we assert the same ordering at bench scale.
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import DISK, default_cfg
from repro.core import iostats
from repro.core.backend import SearchParams
from repro.core.index import LSMVecIndex, brute_force_knn, recall_at_k
from repro.data.synth import make_clustered_vectors

RHOS = (1.0, 0.9, 0.8, 0.7)


def main(n_base: int = 4096, dim: int = 64, n_queries: int = 64):
    base = make_clustered_vectors(n_base, dim=dim, seed=0)
    queries = make_clustered_vectors(n_queries, dim=dim, seed=777)
    truth = brute_force_knn(jnp.asarray(base), jnp.asarray(queries), 10)
    idx = LSMVecIndex.build(default_cfg(dim, n_base + 16), base)

    print("\nfig8,rho,recall,query_cost_ms,vec_fetches,filtered")
    curve = []
    for rho in RHOS:
        idx.reset_stats()
        # rho = 1.0 is the paper's "no sampling applied" baseline (Eq. 7)
        ids = idx.search(queries, k=10, params=SearchParams(
            rho=rho, use_filter=(rho < 1.0))).ids
        cost = float(iostats.search_cost(idx.io_stats, DISK)) * 1e3 / n_queries
        rec = recall_at_k(ids, truth)
        curve.append((rho, rec, cost))
        print(f"fig8,{rho},{rec:.3f},{cost:.3f},"
              f"{int(idx.io_stats.n_vec)},{int(idx.io_stats.n_filtered)}")

    r10, c10 = curve[0][1], curve[0][2]
    r07, c07 = curve[-1][1], curve[-1][2]
    saving = 100 * (1 - c07 / c10)
    drop = 100 * (r10 - r07)
    print(f"fig8,summary,cost_saving_pct={saving:.1f},"
          f"recall_drop_pts={drop:.1f},,")
    ok = (c07 < c10) and (r07 >= r10 - 0.15)
    # the paper's sweet spot: meaningful saving, modest recall loss
    print(f"check,cost drops while recall holds (rho sweep),"
          f"{'PASS' if ok else 'FAIL'}")
    return curve, ok


if __name__ == "__main__":
    main()
