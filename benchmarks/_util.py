"""Small shared helpers for the benchmark CLIs (no heavy imports)."""

from __future__ import annotations

import json
import os


def write_bench_json(path: str, doc: dict) -> None:
    """Write a BENCH_*.json document (creating parent dirs)."""
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(f"wrote {path}")
