"""Benchmark entrypoint: one function per paper figure/table.

Prints CSV (`name,value,detail` lines) for Fig. 5/6/7/8 reproductions, the
kernel microbenchmarks, and — when results/dryrun.json exists — the
roofline table.  `PYTHONPATH=src python -m benchmarks.run`
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def bench_kernels():
    """Kernel wrapper micro-timings (CPU oracle path; TPU is the target)."""
    from repro.kernels import (collision_count, gather_l2, l2_distance,
                               simhash_encode)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (32, 128)), jnp.float32)
    tbl = jnp.asarray(rng.normal(0, 1, (4096, 128)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 4096, (32, 16)), jnp.int32)
    proj = jnp.asarray(rng.normal(0, 1, (64, 128)), jnp.float32)

    def t(fn, *a, **kw):
        jax.block_until_ready(fn(*a, **kw))   # compile
        t0 = time.perf_counter_ns()
        for _ in range(10):
            jax.block_until_ready(fn(*a, **kw))
        return (time.perf_counter_ns() - t0) / 10 / 1e3

    print(f"kernel,l2_distance_32x4096_us,{t(l2_distance, q, tbl):.1f}")
    print(f"kernel,gather_l2_32x16_us,{t(gather_l2, q, tbl, ids):.1f}")
    codes = simhash_encode(tbl, proj)
    cq = simhash_encode(q, proj)
    print(f"kernel,simhash_encode_4096_us,"
          f"{t(simhash_encode, tbl, proj):.1f}")
    print(f"kernel,collision_count_32x4096_us,"
          f"{t(collision_count, cq, codes, 64):.1f}")


def main() -> None:
    t_start = time.monotonic()
    print("name,value,detail")

    bench_kernels()

    from benchmarks import (fig5_workloads, fig6_memory, fig7_tradeoff,
                            fig8_sampling)
    # protocol-faithful sizes that complete on one CPU core; pass larger
    # n_base/n_batches for the paper-scale sweep on real hardware
    wl = dict(n_base=2048, n_batches=5)
    _, ok5 = fig5_workloads.main(**wl)
    # fig6 is now the tier sweep (BENCH_memory.json); smoke instance here,
    # `python benchmarks/fig6_memory.py` for the committed full run
    doc6 = fig6_memory.run(**fig6_memory.smoke_args(0))
    ok6 = all(doc6["criteria"].values())
    _, ok7 = fig7_tradeoff.main()
    _, ok8 = fig8_sampling.main()

    if os.path.exists("results/dryrun.json"):
        from benchmarks import roofline
        roofline.main()

    status = all([ok5, ok6, ok7, ok8])
    print(f"\nsummary,paper_claims,"
          f"{'ALL-PASS' if status else 'SOME-FAIL'}")
    print(f"summary,total_wall_s,{time.monotonic() - t_start:.1f}")


if __name__ == "__main__":
    main()
