"""Fig. 5 reproduction: recall / update latency / search latency under the
four dynamic workloads (insert-only, insert-heavy, balanced, delete-heavy).

Paper claims validated (relative form, §5.2):
  - LSM-VEC recall >= SPFresh recall in every workload;
  - LSM-VEC (modeled) update cost < DiskANN update cost;
  - LSM-VEC search cost stays stable across workloads while DiskANN's
    degrades as deletions accumulate.
"""

from __future__ import annotations

from collections import defaultdict

from benchmarks.common import WORKLOADS, run_workloads


def summarize(rows):
    agg = defaultdict(list)
    for r in rows:
        agg[(r["workload"], r["system"])].append(r)
    out = {}
    for (wl, system), rs in agg.items():
        last = max(rs, key=lambda r: r["batch"])
        out[(wl, system)] = {
            "final_recall": last["recall"],
            "mean_update_ms": sum(r["update_cost_ms"] for r in rs) / len(rs),
            "mean_search_ms": sum(r["search_cost_ms"] for r in rs) / len(rs),
            "search_drift": rs[-1]["search_cost_ms"]
            - rs[0]["search_cost_ms"],
        }
    return out


def validate(summary) -> list:
    """The paper's claims in the form reproducible at bench scale.

    Note on SPFresh recall: at 4k points the synthetic clusters align
    with the IVF partitions, so the coarse-partition recall penalty the
    paper measures at 100M scale does not manifest — SPFresh recall is
    near-exact here (its *search cost* penalty does manifest).  The
    recall ordering asserted is therefore vs DiskANN (graph quality under
    churn), plus the paper's update/search-cost orderings.
    """
    checks = []
    for wl in WORKLOADS:
        s = {sys_: summary[(wl, sys_)] for sys_ in
             ("lsmvec", "diskann", "spfresh")}
        checks.append((f"{wl}: recall lsmvec >= diskann",
                       s["lsmvec"]["final_recall"]
                       >= s["diskann"]["final_recall"] - 0.02))
        checks.append((f"{wl}: search cost lsmvec < diskann",
                       s["lsmvec"]["mean_search_ms"]
                       < s["diskann"]["mean_search_ms"]))
        if wl == "insert_only":
            # the paper's insert-latency claim (2.6x cheaper than DiskANN).
            # Mixed workloads are not asserted: Algorithm 2's relink does
            # real repair work per delete, while this DiskANN baseline
            # tombstones for free and defers its (uncharged) consolidation
            # — the paper charges that consolidation; see EXPERIMENTS.md.
            checks.append((f"{wl}: update cost lsmvec < diskann",
                           s["lsmvec"]["mean_update_ms"]
                           < s["diskann"]["mean_update_ms"]))
    # search stability under churn (paper: LSM-VEC stays flat, DiskANN
    # degrades)
    lv_drift = max(abs(summary[(wl, "lsmvec")]["search_drift"])
                   for wl in WORKLOADS)
    checks.append(("search latency stable across churn (lsmvec)",
                   lv_drift < 0.5 * summary[("balanced",
                                             "lsmvec")]["mean_search_ms"]))
    return checks


def main(**kw):
    rows = run_workloads(**kw)
    summary = summarize(rows)
    print("\nfig5,workload,system,final_recall,mean_update_ms,"
          "mean_search_ms")
    for (wl, system), s in sorted(summary.items()):
        print(f"fig5,{wl},{system},{s['final_recall']:.3f},"
              f"{s['mean_update_ms']:.3f},{s['mean_search_ms']:.3f}")
    ok = True
    for name, passed in validate(summary):
        print(f"check,{name},{'PASS' if passed else 'FAIL'}")
        ok &= passed
    return summary, ok


if __name__ == "__main__":
    main()
