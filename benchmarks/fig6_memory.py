"""Fig. 6 reproduction: resident memory vs recall across the tier sweep.

The paper's headline systems claim is a 66.2% smaller resident footprint
than DiskANN at scale.  This benchmark makes the claim first-class for
our reproduction (DESIGN.md §12): build one index over a clustered
corpus, serve a head-skewed query workload to accumulate traversal
heat, then sweep the tier policy's hot-fraction budget.  For each
budget the benchmark demotes the cold tail into the int8 lane and
measures

  - resident bytes (the full per-component `MemoryBreakdown`: vector
    lanes, upper graph + cache, simhash codes, memtable, tombstone
    lane, insert overlay, id maps), and
  - recall 10@10 on the *same* query workload against the dense
    baseline (the pre-demotion index, every routable node in the f32
    lane).

Criteria (the `tier-smoke` CI gate):
  - at hot_frac=0.25 the tiered resident bytes are <= 50% of dense;
  - at hot_frac=0.25 recall is >= 0.95x the dense baseline.

Results go to ``BENCH_memory.json``.  ``--smoke`` runs the small CI
instance; ``--check`` exits non-zero unless both criteria hold.
"""

from __future__ import annotations

import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from _util import write_bench_json
from repro.core import hnsw
from repro.core.backend import SearchParams
from repro.core.index import LSMVecIndex, brute_force_knn, recall_at_k
from repro.data.synth import make_clustered_vectors
from repro.tier import TierPolicy

SCHEMA = {
    "meta": ("mode", "backend", "n", "dim", "n_queries", "head_frac",
             "hot_fracs", "config"),
    "dense": ("recall", "bytes", "breakdown"),
    "sweep": (),          # per-hot_frac dicts, validated separately
    "criteria": ("tiered_bytes_le_50pct_dense_at_hot25",
                 "recall_ge_95pct_dense_at_hot25"),
}

SWEEP_FIELDS = ("hot_frac", "recall", "bytes", "bytes_vs_dense",
                "recall_vs_dense", "n_hot", "n_cold", "demoted",
                "promoted", "rerank_fetches_per_query")

GATE_HOT_FRAC = 0.25


def validate_schema(doc: dict) -> None:
    """Raise ValueError unless `doc` matches the BENCH_memory schema."""
    for section, fields in SCHEMA.items():
        if section not in doc:
            raise ValueError(f"missing section {section!r}")
        for f in fields:
            if f not in doc[section]:
                raise ValueError(f"missing field {section}.{f}")
    if not isinstance(doc["sweep"], list) or not doc["sweep"]:
        raise ValueError("sweep must be a non-empty list")
    for row in doc["sweep"]:
        for f in SWEEP_FIELDS:
            if f not in row:
                raise ValueError(f"missing sweep field {f!r}")
            v = row[f]
            if not isinstance(v, (int, float)) or not np.isfinite(v):
                raise ValueError(f"non-finite sweep.{f}: {v!r}")
    for f, v in doc["criteria"].items():
        if not isinstance(v, bool):
            raise ValueError(f"criteria.{f} must be bool, got {v!r}")


def _cfg(dim: int, cap: int) -> hnsw.HNSWConfig:
    # dim is deliberately large (vectors dominate a real deployment's
    # footprint, and the fixed serving overheads — insert overlay, id
    # maps, memtable — weigh the ratio toward 1 at toy sizes) and
    # level_scale puts <1% of nodes in the upper layers — the paper's
    # regime — so the resident upper-layer vector cache doesn't swamp
    # the lane accounting it routes for.
    return hnsw.HNSWConfig(
        cap=cap, dim=dim, M=12, M_up=6, num_upper=2, ef_search=48,
        ef_construction=48, k=10, m_bits=64, rho=1.0, eps=0.1,
        use_filter=False, lsm_mem_cap=256, lsm_levels=2, lsm_fanout=8,
        tier=True, rerank=32, level_scale=0.2)


def _skewed_queries(base: np.ndarray, n_queries: int, head_frac: float,
                    seed: int) -> np.ndarray:
    """Head-skewed query workload: 80% of queries perturb vectors from
    the `head_frac` head of the corpus, 20% from the tail — the traffic
    shape that makes a hot/cold split pay (percolate-node's premise)."""
    rng = np.random.default_rng(seed)
    n = len(base)
    n_head = max(1, int(n * head_frac))
    n_hot_q = int(n_queries * 0.8)
    head_ids = rng.integers(0, n_head, n_hot_q)
    tail_ids = rng.integers(0, n, n_queries - n_hot_q)
    picks = base[np.concatenate([head_ids, tail_ids])]
    noise = rng.normal(0.0, 0.1, picks.shape).astype(np.float32)
    return (picks + noise).astype(np.float32)


def run(*, n: int, dim: int, n_queries: int, head_frac: float,
        hot_fracs: list, warm_rounds: int, seed: int, mode: str) -> dict:
    cfg = _cfg(dim, cap=n + 64)
    base = make_clustered_vectors(n, dim=dim, seed=seed)
    queries = _skewed_queries(base, n_queries, head_frac, seed + 1)
    truth = brute_force_knn(jnp.asarray(base), jnp.asarray(queries), cfg.k)

    idx0 = LSMVecIndex.build(cfg, base)

    # dense baseline: every routable node in the f32 lane (pre-demotion
    # state of the very same index, so graph and level draws are shared
    # with every tiered arm).  The searches double as heat warmup.
    for _ in range(warm_rounds):
        ids_d = idx0.search(queries, k=cfg.k,
                            params=SearchParams(record_heat=True)).ids
    recall_dense = recall_at_k(np.asarray(ids_d), truth)
    mem_dense = idx0.memory_breakdown()
    print(f"fig6,dense,recall={recall_dense:.4f},"
          f"bytes={mem_dense.total}", flush=True)

    sweep = []
    for hf in hot_fracs:
        idx = idx0.clone()
        pol = TierPolicy(hot_frac=hf, ewma=0.5, hysteresis=0.05,
                         max_demote=cfg.cap, max_promote=cfg.cap)
        moved = idx.tier_maintain(pol)
        moved2 = idx.tier_maintain(pol)   # EWMA settles, hysteresis holds
        idx.reset_stats()
        ids_t = idx.search(queries, k=cfg.k,
                           params=SearchParams(record_heat=False)).ids
        rerank_fetches = int(idx.io_stats.n_vec) / n_queries
        recall_t = recall_at_k(np.asarray(ids_t), truth)
        mem_t = idx.memory_breakdown()
        row = {
            "hot_frac": hf,
            "recall": round(recall_t, 4),
            "bytes": int(mem_t.total),
            "bytes_vs_dense": round(mem_t.total / max(mem_dense.total, 1), 4),
            "recall_vs_dense": round(recall_t / max(recall_dense, 1e-9), 4),
            "n_hot": mem_t.n_hot,
            "n_cold": mem_t.n_cold,
            "demoted": moved["demoted"] + moved2["demoted"],
            "promoted": moved["promoted"] + moved2["promoted"],
            "rerank_fetches_per_query": round(rerank_fetches, 2),
            "breakdown": mem_t.as_dict(),
        }
        sweep.append(row)
        print(f"fig6,hot_frac={hf},recall={recall_t:.4f},"
              f"bytes={mem_t.total} ({100 * row['bytes_vs_dense']:.1f}% "
              f"of dense),n_hot={mem_t.n_hot},n_cold={mem_t.n_cold}",
              flush=True)
        del idx

    gate = next(r for r in sweep
                if abs(r["hot_frac"] - GATE_HOT_FRAC) < 1e-9)
    crit_bytes = gate["bytes_vs_dense"] <= 0.50
    crit_recall = gate["recall_vs_dense"] >= 0.95
    print(f"check,tiered_bytes_le_50pct_dense_at_hot25,"
          f"{'PASS' if crit_bytes else 'FAIL'}")
    print(f"check,recall_ge_95pct_dense_at_hot25,"
          f"{'PASS' if crit_recall else 'FAIL'}")

    return {
        "meta": {
            "mode": mode, "backend": jax.default_backend(),
            "n": n, "dim": dim, "n_queries": n_queries,
            "head_frac": head_frac, "hot_fracs": hot_fracs,
            "config": dict(cfg._asdict()),
        },
        "dense": {
            "recall": round(recall_dense, 4),
            "bytes": int(mem_dense.total),
            "breakdown": mem_dense.as_dict(),
        },
        "sweep": sweep,
        "criteria": {
            "tiered_bytes_le_50pct_dense_at_hot25": bool(crit_bytes),
            "recall_ge_95pct_dense_at_hot25": bool(crit_recall),
        },
    }


def full_args(seed: int) -> dict:
    return dict(n=4096, dim=384, n_queries=256, head_frac=0.2,
                hot_fracs=[0.5, 0.25, 0.1], warm_rounds=3, seed=seed,
                mode="full")


def smoke_args(seed: int) -> dict:
    return dict(n=768, dim=384, n_queries=64, head_frac=0.2,
                hot_fracs=[0.5, 0.25, 0.1], warm_rounds=2, seed=seed,
                mode="smoke")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small CI instance")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless both tier criteria pass")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="output JSON path (default BENCH_memory.json, "
                    "or ci-bench/... under --smoke)")
    args = ap.parse_args(argv)

    kw = smoke_args(args.seed) if args.smoke else full_args(args.seed)
    doc = run(**kw)
    validate_schema(doc)
    out = args.out or ("ci-bench/BENCH_memory.smoke.json" if args.smoke
                       else "BENCH_memory.json")
    write_bench_json(out, doc)
    if args.check and not all(doc["criteria"].values()):
        print("tier memory/recall gate FAILED")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
