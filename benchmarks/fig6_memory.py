"""Fig. 6 reproduction: resident memory over time per workload.

Paper claims validated (relative form, §5.2):
  - DiskANN memory grows with updates (delta graph + vectors in RAM);
  - LSM-VEC and SPFresh stay flat/bounded;
  - LSM-VEC's resident set is a small fraction of the full dataset
    (the paper's "66.2% lower than DiskANN" at 100M scale).
"""

from __future__ import annotations

from collections import defaultdict

from benchmarks.common import WORKLOADS, run_workloads


def main(**kw):
    rows = run_workloads(**kw)
    series = defaultdict(list)
    for r in rows:
        series[(r["workload"], r["system"])].append(
            (r["batch"], r["memory_mb"]))
    print("\nfig6,workload,system,mem_first_mb,mem_last_mb,growth_pct")
    summary = {}
    for (wl, system), pts in sorted(series.items()):
        pts.sort()
        first, last = pts[0][1], pts[-1][1]
        growth = 100.0 * (last - first) / max(first, 1e-9)
        summary[(wl, system)] = (first, last, growth)
        print(f"fig6,{wl},{system},{first:.3f},{last:.3f},{growth:.1f}")
    ok = True
    for wl in WORKLOADS:
        if (wl, "diskann") in summary and (wl, "lsmvec") in summary:
            dk = summary[(wl, "diskann")][2]
            lv = summary[(wl, "lsmvec")][2]
            passed = dk > lv        # DiskANN grows faster than LSM-VEC
            print(f"check,{wl}: diskann mem growth > lsmvec,"
                  f"{'PASS' if passed else 'FAIL'}")
            ok &= passed
            # LSM-VEC memory saving vs DiskANN at end of run
            dk_mb = summary[(wl, "diskann")][1]
            lv_mb = summary[(wl, "lsmvec")][1]
            saving = 100.0 * (1 - lv_mb / max(dk_mb, 1e-9))
            print(f"fig6,{wl},saving_vs_diskann_pct,{saving:.1f},,")
    return summary, ok


if __name__ == "__main__":
    main()
