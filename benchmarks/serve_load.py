"""Sustained mixed-workload serving benchmark (DESIGN.md §8, §10).

Drives the `repro.serve` engine with an interleaved 80/10/10
query/insert/delete stream in saturation (every request pre-enqueued,
relaxed coalescing) and records:

  - **serve_qps** — queries completed / total drain wall, i.e. query
    throughput *while also absorbing the write stream* and any
    threshold-triggered LSM compactions;
  - **fixed_batch_qps** — the PR-1 reference path measured in-run: direct
    fixed-shape `LSMVecIndex.search` batches (no scheduler, no writes) on
    the same machine and index;
  - **zero-retrace proof** — jit trace counts per entry point are
    snapshotted after warmup and must not grow during the load phase
    (fixed pad shapes mean ragged micro-batches reuse one traced shape);
  - **recall parity** — a held-out query set evaluated through the engine
    vs the same op stream applied per-item to a bare index (the
    sequential baseline), both against brute force over the final live
    set.

Results go to ``BENCH_serve.json``.  ``--smoke`` runs a tiny instance and
validates the schema only (the CI mode), like ``throughput.py``.

``--shards P`` serves the identical protocol through a
`ShardedBackend` of P hash-partitioned `LSMVecIndex` shards (DESIGN.md
§10) — the engine code path is unchanged, only the backend differs.
The smoke instance scales ``n_base`` by P so per-shard scale matches
the single-device smoke; run under
``XLA_FLAGS=--xla_force_host_platform_device_count=P`` to give each
shard its own device.  The recall criterion relaxes from the strict
±0.01 band to a 0.95× floor of the sequential single-device baseline
(cross-shard merge is a different, recall-guarded execution).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402

from _util import write_bench_json                             # noqa: E402
from repro.core import hnsw                                    # noqa: E402
from repro.core.backend import shard_of_seq                    # noqa: E402
from repro.core.distributed import ShardedBackend              # noqa: E402
from repro.core.index import (LSMVecIndex, brute_force_knn,    # noqa: E402
                              recall_at_k)
from repro.data.synth import make_clustered_vectors            # noqa: E402
from repro.serve import (MaintenancePolicy, Op, ServeConfig,   # noqa: E402
                         ServeEngine)

SCHEMA = {
    "meta": ("mode", "backend", "shards", "n_base", "n_ops", "mix", "dim",
             "batch", "n_expand", "serve_query_batch", "serve_n_expand",
             "config"),
    "serve": ("qps", "insert_ops_s", "delete_ops_s", "query_p50_ms",
              "query_p99_ms", "mean_query_batch", "snapshot_resolves",
              "compactions", "wall_s"),
    "baseline": ("fixed_batch_qps", "qps_ratio"),
    "recall": ("serve", "sequential", "delta"),
    "retraces": ("after_warmup", "after_load", "new_during_load"),
    "criteria": ("zero_retraces_after_warmup", "qps_within_10pct_of_fixed",
                 "recall_within_0p01"),
}


def validate_schema(doc: dict) -> None:
    """Raise ValueError unless `doc` matches the BENCH_serve schema."""
    for section, fields in SCHEMA.items():
        if section not in doc:
            raise ValueError(f"missing section {section!r}")
        for f in fields:
            if f not in doc[section]:
                raise ValueError(f"missing field {section}.{f}")
    for section in ("serve", "baseline", "recall"):
        for f, v in doc[section].items():
            if not isinstance(v, (int, float)) or not np.isfinite(v):
                raise ValueError(f"non-finite {section}.{f}: {v!r}")
    for f, v in doc["retraces"].items():
        if not isinstance(v, dict) and not isinstance(v, int):
            raise ValueError(f"retraces.{f} must be dict|int, got {v!r}")
    for f, v in doc["criteria"].items():
        if not isinstance(v, bool):
            raise ValueError(f"criteria.{f} must be bool, got {v!r}")


def _cfg(dim: int, cap: int) -> hnsw.HNSWConfig:
    # the BENCH_throughput instance shape, so qps numbers are comparable
    return hnsw.HNSWConfig(
        cap=cap, dim=dim, M=12, M_up=6, num_upper=2, ef_search=48,
        ef_construction=48, k=10, m_bits=64, rho=1.0, eps=0.1,
        use_filter=False, lsm_mem_cap=256, lsm_levels=2, lsm_fanout=8,
        n_expand=1, batch_expand=4)


def make_stream(rng, n_ops: int, n_base: int, fresh: np.ndarray,
                base: np.ndarray):
    """80/10/10 interleaved stream; deletes target distinct base ids."""
    stream = []
    victims = list(rng.permutation(n_base))
    fi = 0
    for _ in range(n_ops):
        r = rng.random()
        if r < 0.8 or (r >= 0.9 and not victims) or (r < 0.9 and
                                                     fi >= len(fresh)):
            stream.append(("q", base[rng.integers(0, n_base)]))
        elif r < 0.9:
            stream.append(("i", fresh[fi]))
            fi += 1
        else:
            stream.append(("d", int(victims.pop())))
    return stream


SERVE_TRIALS = 2  # best-of-N full load drains (fresh index copy each):
                  # the reference takes its best trial, so the serve side
                  # must get the same chance against container jitter


def run(*, n_base: int, n_ops: int, batch: int, dim: int, seed: int,
        n_expand: int, mode: str, shards: int = 1) -> dict:
    rng = np.random.default_rng(seed)
    n_fresh = max(n_ops // 8, 8)
    cap = n_base + n_fresh + 4 * batch + 64
    cfg = _cfg(dim, cap)
    # per-shard id space: the shard's slice of the corpus plus slack for
    # routed inserts and hash imbalance
    cfg_shard = _cfg(dim, -(-(n_base + n_fresh) // shards) + 4 * batch + 64)
    base = make_clustered_vectors(n_base, dim=dim, seed=seed)
    fresh = make_clustered_vectors(n_fresh, dim=dim, seed=seed + 1)
    stream = make_stream(rng, n_ops, n_base, fresh, base)
    mix = {op: round(sum(1 for o, _ in stream if o == op) / n_ops, 3)
           for op in ("q", "i", "d")}

    # Serving configuration: query micro-batches coalesce 4x wider than
    # the write pad width (at saturation the scheduler's advantage is
    # filling large fixed shapes from the backlog), and beams expand 2x
    # wider than the reference path — on a churn-damaged graph the
    # vmapped batch runs as long as its slowest lane, and wider expansion
    # halves the straggler trip count.  Recall is guarded by the
    # sequential-baseline criterion below.
    serve_cfg = ServeConfig(
        query_batch=4 * batch, insert_batch=batch, delete_batch=batch,
        query_window=0.0, insert_window=0.0, delete_window=0.0,
        strict_order=False, n_expand=2 * n_expand,
        maintenance=MaintenancePolicy(tombstone_ratio=0.25, heat_budget=None,
                                      check_every=8))
    if shards > 1:
        backend0 = ShardedBackend(cfg_shard, shards).build(base, seed=seed)
    else:
        backend0 = LSMVecIndex.build(cfg, base)
    # warmup must compile every serving shape on every shard: extend the
    # warm insert run until the deterministic hash router has touched
    # each shard at least once (their deletes then cover the delete path
    # on the same shards; queries fan out to all shards regardless)
    n_warm = 3
    while shards > 1 and len(set(np.asarray(shard_of_seq(
            np.arange(n_base, n_base + n_warm), shards)))) < shards:
        n_warm += 1
    warm_vecs = make_clustered_vectors(n_warm, dim=dim, seed=seed + 9)

    wall = float("inf")
    idx = eng = warm_traces = load_traces = None
    for _ in range(SERVE_TRIALS):
        # fresh copy: the previous trial's donated jits consumed its state
        idx_t = backend0.clone()
        eng_t = ServeEngine(idx_t, serve_cfg)

        # warmup: compile every serving shape outside the timed region.
        # The warmup inserts are deleted again right away, so the index
        # content entering the load phase is exactly `base` (only the id
        # space advanced by n_warm) — the recall accounting relies on it.
        warm_ids = [eng_t.submit_insert(v) for v in warm_vecs]
        for i in range(5):
            eng_t.submit_query(base[i])
        eng_t.drain()
        for t in warm_ids:
            eng_t.submit_delete(t.result())
        eng_t.drain()
        idx_t.sync()
        warm_t = dict(idx_t.trace_counts())

        # the load phase: saturation drain of the interleaved stream
        for op, payload in stream:
            if op == "q":
                eng_t.submit_query(payload)
            elif op == "i":
                eng_t.submit_insert(payload)
            else:
                eng_t.submit_delete(payload)
        t0 = time.monotonic()
        eng_t.drain()
        idx_t.sync()
        wall_t = time.monotonic() - t0
        if wall_t < wall:
            wall = wall_t
        # keep the last trial's artifacts for the recall/reference phases
        idx, eng = idx_t, eng_t
        warm_traces, load_traces = warm_t, dict(idx_t.trace_counts())

    new_traces = {k: load_traces[k] - warm_traces.get(k, 0)
                  for k in load_traces if load_traces[k]
                  != warm_traces.get(k, 0)}

    # ---- fixed-batch reference QPS (the PR-1 path): measured on the SAME
    # post-churn index, same query distribution and same statistical
    # footing as the serve drain — one pass over as many distinct queries
    # as the stream carried, best of SERVE_TRIALS passes.  The ratio then
    # isolates the serving layer (scheduling + padding + snapshot reads +
    # absorbed writes) from workload-inherent graph damage and container
    # jitter alike.
    n_stream_q = sum(1 for o, _ in stream if o == "q")
    n_fixed_batches = max(n_stream_q // batch, 1)
    fixed_pool = base[rng.integers(0, n_base,
                                   size=n_fixed_batches * batch)]
    idx.search(fixed_pool[:batch], k=cfg.k, n_expand=n_expand)  # compile
    dt_fixed = float("inf")
    for _ in range(SERVE_TRIALS):
        t0 = time.monotonic()
        for b in range(n_fixed_batches):
            idx.search(fixed_pool[b * batch:(b + 1) * batch], k=cfg.k,
                       n_expand=n_expand, record_heat=False)
        idx.sync()
        dt_fixed = min(dt_fixed, time.monotonic() - t0)
    fixed_qps = n_fixed_batches * batch / dt_fixed

    m = eng.metrics.snapshot()
    serve_qps = n_stream_q / wall

    # ---- recall: engine vs the sequential per-item baseline --------------
    # Same op stream applied one-by-one to a bare index (the sequential
    # reference), then one shared eval query set through both.  The serve
    # index's id space carries the 3 (deleted) warmup inserts, so its
    # ground truth is built in its own id space.
    idx_seq = LSMVecIndex.build(cfg, base)
    live = np.ones(n_base + n_fresh, bool)
    n_ins = 0
    for op, payload in stream:
        if op == "i":
            idx_seq.insert(payload)
            n_ins += 1
        elif op == "d":
            idx_seq.delete(payload)
            live[payload] = False
    live_all = live[:n_base + n_ins].copy()
    eval_q = make_clustered_vectors(64, dim=dim, seed=seed + 3)
    allv_seq = np.concatenate([base, fresh[:n_ins]])
    truth_seq = brute_force_knn(allv_seq, eval_q, cfg.k, live=live_all)
    recall_seq = recall_at_k(idx_seq.search(eval_q, k=cfg.k).ids,
                             truth_seq)

    serve_tickets = [eng.submit_query(q) for q in eval_q]
    eng.drain()
    ids_serve = np.stack([t.result().ids for t in serve_tickets])
    allv_serve = np.concatenate([base, warm_vecs, fresh[:n_ins]])
    live_serve = np.concatenate(
        [live_all[:n_base], np.zeros(n_warm, bool), live_all[n_base:]])
    truth_serve = brute_force_knn(allv_serve, eval_q, cfg.k,
                                  live=live_serve)
    recall_serve = recall_at_k(ids_serve, truth_serve)

    doc = {
        "meta": {
            "mode": mode, "backend": jax.default_backend(),
            "shards": shards,
            "n_base": n_base, "n_ops": n_ops, "mix": mix, "dim": dim,
            "batch": batch, "n_expand": n_expand,
            # the serving layer's own knobs (the reference path runs the
            # PR-1 shape `batch`/`n_expand` above; wider coalescing and
            # beams are the scheduler's prerogative, recall-guarded)
            "serve_query_batch": serve_cfg.query_batch,
            "serve_n_expand": serve_cfg.n_expand,
            "config": {k: v for k, v in
                       (cfg_shard if shards > 1 else cfg)
                       ._asdict().items()},
        },
        "serve": {
            "qps": round(serve_qps, 1),
            "insert_ops_s": m["insert"]["ops_per_s"],
            "delete_ops_s": m["delete"]["ops_per_s"],
            "query_p50_ms": m["query"]["p50_ms"],
            "query_p99_ms": m["query"]["p99_ms"],
            "mean_query_batch": m["query"]["mean_batch"],
            "snapshot_resolves": m["snapshot_resolves"],
            "compactions": eng.maintenance.compactions,
            "wall_s": round(wall, 3),
        },
        "baseline": {
            "fixed_batch_qps": round(fixed_qps, 1),
            "qps_ratio": round(serve_qps / fixed_qps, 3),
        },
        "recall": {
            "serve": round(recall_serve, 4),
            "sequential": round(recall_seq, 4),
            "delta": round(recall_serve - recall_seq, 4),
        },
        "retraces": {
            "after_warmup": warm_traces,
            "after_load": load_traces,
            "new_during_load": new_traces,
        },
        "criteria": {
            "zero_retraces_after_warmup": not new_traces,
            "qps_within_10pct_of_fixed": bool(
                serve_qps >= 0.9 * fixed_qps),
            # one-sided: serving must not LOSE recall vs the sequential
            # per-item reference; exceeding it (batched inserts with
            # multi-expansion candidate search + intra-batch links build a
            # better-connected graph) is a win, not a violation.  Under
            # sharding the execution differs structurally (cross-shard
            # merge over hash partitions), so the gate is the 0.95x
            # floor of the single-device sequential baseline instead of
            # the ±0.01 band (DESIGN.md §10)
            "recall_within_0p01": bool(
                recall_serve >= recall_seq - 0.01 if shards == 1
                else recall_serve >= 0.95 * recall_seq),
        },
    }
    return doc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run; validate the JSON schema only")
    ap.add_argument("--out", default=None,
                    help="output path (default: <repo>/BENCH_serve.json)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shards", type=int, default=1,
                    help="serve through a ShardedBackend of P shards "
                         "(1 = single-device LSMVecIndex)")
    args = ap.parse_args(argv)

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = args.out or os.path.join(root, "BENCH_serve.json")

    if args.smoke:
        # scale the corpus with the shard count so per-shard scale (and
        # per-shard graph navigability) matches the single-device smoke
        doc = run(n_base=256 * args.shards, n_ops=96, batch=16, dim=16,
                  seed=args.seed, n_expand=4, mode="smoke",
                  shards=args.shards)
    else:
        doc = run(n_base=4096, n_ops=4096, batch=64, dim=64, seed=args.seed,
                  n_expand=4, mode="full", shards=args.shards)

    validate_schema(doc)
    print(json.dumps(doc, indent=1))
    if args.smoke:
        print("smoke: schema OK (perf criteria not enforced)")
        if args.out:
            # an explicit --out in smoke mode gets the smoke doc (CI
            # uploads the measurement it produced); the committed full-
            # run JSON is only written by full runs
            write_bench_json(args.out, doc)
        return 0

    write_bench_json(out, doc)
    for name, ok in doc["criteria"].items():
        print(f"  {'PASS' if ok else 'FAIL'} {name}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
