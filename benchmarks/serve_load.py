"""Sustained mixed-workload serving benchmark (DESIGN.md §8, §10).

Drives the `repro.serve` engine with an interleaved 80/10/10
query/insert/delete stream in saturation (every request pre-enqueued,
relaxed coalescing) and records:

  - **serve_qps** — queries completed / total drain wall, i.e. query
    throughput *while also absorbing the write stream* and any
    threshold-triggered LSM compactions;
  - **fixed_batch_qps** — the PR-1 reference path measured in-run: the
    SAME op stream dispatched directly as fixed-shape batches (no
    scheduler; arrival-order runs of `batch` per op) on the same machine
    from the same starting index, so both sides pay the identical write
    stream and the ratio isolates the serving layer rather than the
    box's read/write cost balance;
  - **zero-retrace proof** — jit trace counts per entry point are
    snapshotted after warmup and must not grow during the load phase
    (fixed pad shapes mean ragged micro-batches reuse one traced shape);
  - **recall parity** — a held-out query set evaluated through the engine
    vs the same op stream applied per-item to a bare index (the
    sequential baseline), both against brute force over the final live
    set.

Results go to ``BENCH_serve.json``.  ``--smoke`` runs a tiny instance and
validates the schema only (the CI mode), like ``throughput.py``.

``--shards P`` serves the identical protocol through a
`ShardedBackend` of P hash-partitioned `LSMVecIndex` shards (DESIGN.md
§10) — the engine code path is unchanged, only the backend differs.
The smoke instance scales ``n_base`` by P so per-shard scale matches
the single-device smoke; run under
``XLA_FLAGS=--xla_force_host_platform_device_count=P`` to give each
shard its own device.  The recall criterion relaxes from the strict
±0.01 band to a 0.95× floor of the sequential single-device baseline
(cross-shard merge is a different, recall-guarded execution).

**Durability** (DESIGN.md §11): every run also reports a ``durability``
section.  An A/B probe drives an identical closed-loop insert stream
with and without a group-committed WAL and reports acked-insert p50/p99
for both arms — the criterion ``wal_overhead_within_15pct`` gates the
fsync tax at ≤15% on p50.  ``--wal`` additionally runs the *main* serve
drain with the WAL on (acks then imply durability and the headline
qps absorbs the commit cost); ``--ckpt-every N`` layers covering
checkpoints every N write batches on top.  ``--crash-recovery`` runs
the failure-injection matrix instead of the load benchmark: kill at
each injection point, restart via `ServeEngine.recover`, and gate on
zero acknowledged-write loss plus a recall floor against an
uninterrupted run of the same op stream (the CI job's mode).

**Fused beam search** (DESIGN.md §15): every run also reports a
``fused`` section — an A/B probe of the beam-search megakernel path
(``HNSWConfig.fused_beam``) against the `while_loop` path: query-batch
p50 per arm, id bit-parity, recall ratio, and a zero-retrace check.
``--fused-beam`` additionally serves the *main* drain through the
fused path and binds the full criterion (p50 at or below the while
arm, within a 1.05x noise band on CPU hosts where both arms lower to
the same HLO).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from _util import write_bench_json
from repro.core import hnsw
from repro.core.backend import SearchParams, shard_of_seq
from repro.core.distributed import ShardedBackend, ShardedDispatch
from repro.core.index import LSMVecIndex, brute_force_knn, recall_at_k
from repro.data.synth import make_clustered_vectors
from repro.ft import FailureInjector, RestartPolicy, run_with_recovery, verify_acked_writes
from repro.serve import MaintenancePolicy, ServeConfig, ServeEngine, WalConfig
from repro.tier import TierPolicy

SCHEMA = {
    "meta": ("mode", "backend", "shards", "tier", "n_base", "n_ops", "mix",
             "dim", "batch", "n_expand", "serve_query_batch",
             "serve_n_expand", "config"),
    "serve": ("qps", "insert_ops_s", "delete_ops_s", "query_p50_ms",
              "query_p99_ms", "mean_query_batch", "snapshot_resolves",
              "compactions", "tier_passes", "tier_demoted", "tier_promoted",
              "wall_s"),
    "baseline": ("fixed_batch_qps", "qps_ratio"),
    "recall": ("serve", "sequential", "delta"),
    "retraces": ("after_warmup", "after_load", "new_during_load"),
    "durability": ("wal_enabled", "ckpt_every", "wal_records", "wal_commits",
                   "checkpoints", "probe_n", "acked_insert_p50_ms",
                   "acked_insert_p99_ms", "nowal_insert_p50_ms",
                   "nowal_insert_p99_ms", "overhead_p50_pct"),
    "fanout": ("shards", "batch", "seq_ms", "async_ms", "ratio", "parity",
               "host_cores"),
    "overlap": ("p99_nomaint_ms", "p99_overlap_ms", "ratio",
                "consolidations", "write_holds", "host_cores"),
    "fused": ("enabled", "while_p50_ms", "fused_p50_ms", "p50_ratio",
              "parity", "recall_ratio", "zero_retraces", "host_cores"),
    "criteria": ("zero_retraces_after_warmup", "qps_within_10pct_of_fixed",
                 "recall_within_0p01", "wal_overhead_within_15pct",
                 "fanout_dispatch_leq_0p7x", "overlap_p99_leq_1p3x",
                 "fused_parity_p50_leq_while"),
}


def validate_schema(doc: dict) -> None:
    """Raise ValueError unless `doc` matches the BENCH_serve schema."""
    for section, fields in SCHEMA.items():
        if section not in doc:
            raise ValueError(f"missing section {section!r}")
        for f in fields:
            if f not in doc[section]:
                raise ValueError(f"missing field {section}.{f}")
    for section in ("serve", "baseline", "recall", "fanout", "overlap",
                    "fused"):
        for f, v in doc[section].items():
            if isinstance(v, bool):
                continue
            if not isinstance(v, (int, float)) or not np.isfinite(v):
                raise ValueError(f"non-finite {section}.{f}: {v!r}")
    for f, v in doc["retraces"].items():
        if not isinstance(v, dict) and not isinstance(v, int):
            raise ValueError(f"retraces.{f} must be dict|int, got {v!r}")
    dur = doc["durability"]
    if not isinstance(dur["wal_enabled"], bool):
        raise ValueError(f"durability.wal_enabled must be bool, "
                         f"got {dur['wal_enabled']!r}")
    if dur["ckpt_every"] is not None \
            and not isinstance(dur["ckpt_every"], int):
        raise ValueError(f"durability.ckpt_every must be int|None, "
                         f"got {dur['ckpt_every']!r}")
    for f, v in dur.items():
        if f in ("wal_enabled", "ckpt_every"):
            continue
        if not isinstance(v, (int, float)) or not np.isfinite(v):
            raise ValueError(f"non-finite durability.{f}: {v!r}")
    for f, v in doc["criteria"].items():
        if not isinstance(v, bool):
            raise ValueError(f"criteria.{f} must be bool, got {v!r}")


def _cfg(dim: int, cap: int) -> hnsw.HNSWConfig:
    # the BENCH_throughput instance shape, so qps numbers are comparable
    return hnsw.HNSWConfig(
        cap=cap, dim=dim, M=12, M_up=6, num_upper=2, ef_search=48,
        ef_construction=48, k=10, m_bits=64, rho=1.0, eps=0.1,
        use_filter=False, lsm_mem_cap=256, lsm_levels=2, lsm_fanout=8,
        n_expand=1, batch_expand=4)


def make_stream(rng, n_ops: int, n_base: int, fresh: np.ndarray,
                base: np.ndarray):
    """80/10/10 interleaved stream; deletes target distinct base ids."""
    stream = []
    victims = list(rng.permutation(n_base))
    fi = 0
    for _ in range(n_ops):
        r = rng.random()
        if r < 0.8 or (r >= 0.9 and not victims) or (r < 0.9 and
                                                     fi >= len(fresh)):
            stream.append(("q", base[rng.integers(0, n_base)]))
        elif r < 0.9:
            stream.append(("i", fresh[fi]))
            fi += 1
        else:
            stream.append(("d", int(victims.pop())))
    return stream


SERVE_TRIALS = 2  # best-of-N full load drains (fresh index copy each):
                  # the reference takes its best trial, so the serve side
                  # must get the same chance against container jitter


def durability_probe(*, n: int, batch: int, dim: int, seed: int,
                     work_dir: str) -> dict:
    """A/B-measure the group-commit tax on acked-insert latency.

    Both arms drive the identical closed-loop insert stream (submit one
    batch, drain, repeat — so each latency sample is one micro-batch's
    execution, not queue depth) through identically configured engines;
    the only difference is ``ServeConfig.wal``.  With the WAL on, every
    batch's record is fsync'd before its tickets resolve (the default
    ``group_commit_n=1``), so the p50 delta *is* the durability cost an
    acked insert pays.  Best-of-``SERVE_TRIALS`` per arm: trial 0
    absorbs compilation.
    """
    cfg = _cfg(dim, n + 4 * batch + 64)
    base = make_clustered_vectors(batch, dim=dim, seed=seed + 21)
    vecs = make_clustered_vectors(n, dim=dim, seed=seed + 22)
    idx0 = LSMVecIndex.build(cfg, base)
    arms = {}
    for arm in ("nowal", "wal"):
        best = None
        for trial in range(SERVE_TRIALS):
            wal_cfg = None
            if arm == "wal":
                wal_cfg = WalConfig(dir=os.path.join(
                    work_dir, f"probe_{arm}_t{trial}"))
            eng = ServeEngine(idx0.clone(), ServeConfig(
                query_batch=batch, insert_batch=batch, delete_batch=batch,
                adaptive_windows=False, query_window=0.0, insert_window=0.0,
                delete_window=0.0, strict_order=False, wal=wal_cfg))
            for b in range(0, n, batch):
                for v in vecs[b:b + batch]:
                    eng.submit_insert(v)
                eng.drain()
            m = eng.metrics.snapshot()
            eng.close()
            cur = {"p50": m["insert"]["p50_ms"], "p99": m["insert"]["p99_ms"]}
            if best is None or cur["p50"] < best["p50"]:
                best = cur
        arms[arm] = best
    p50_wal, p50_raw = arms["wal"]["p50"], arms["nowal"]["p50"]
    return {
        "probe_n": n,
        "acked_insert_p50_ms": round(p50_wal, 3),
        "acked_insert_p99_ms": round(arms["wal"]["p99"], 3),
        "nowal_insert_p50_ms": round(p50_raw, 3),
        "nowal_insert_p99_ms": round(arms["nowal"]["p99"], 3),
        "overhead_p50_pct": round(
            (p50_wal - p50_raw) / max(p50_raw, 1e-9) * 100.0, 1),
    }



def _host_cores() -> int:
    """CPU cores actually available to this process.

    The wall-clock halves of the §13 gates (fanout speedup, overlapped
    p99) measure *parallelism*: on a single-core host every device
    stream timeslices one core and no dispatch order can beat the sum
    of the work, so the probes record the measured ratio alongside
    this count and the boolean gates only bind where >=2 cores can
    express the overlap (CI pins 4-core runners)."""
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:          # pragma: no cover - non-linux
        return os.cpu_count() or 1


class _Collected:
    """A pre-collected per-shard result wrapped as a `SearchHandle` —
    the sequential arm of the fanout probe reuses the exact production
    merge (`ShardedDispatch.collect`) over results it already blocked
    for one at a time."""

    def __init__(self, res):
        self._res = res

    def is_ready(self) -> bool:
        return True

    def collect(self):
        return self._res


def fanout_probe(*, n_base: int, dim: int, batch: int, seed: int,
                 shards: int = 4, reps: int = 8) -> dict:
    """Sequential vs two-phase shard fan-out on one P-shard backend.

    Both arms run the identical stable host merge; the sequential arm
    blocks on each shard before dispatching the next (the pre-§13
    fan-out), the async arm enqueues every shard's device work first
    and collects once, paying max-shard instead of sum-of-shard
    latency.  Results must be bit-identical between the arms on every
    trial.  Meaningful speedups need one device per shard (CI forces
    ``--xla_force_host_platform_device_count``); on fewer devices the
    device stream serializes and the ratio approaches 1.
    """
    cfg = _cfg(dim, -(-n_base // shards) + 64)
    base = make_clustered_vectors(n_base, dim=dim, seed=seed + 31)
    be = ShardedBackend(cfg, shards).build(base, seed=seed)
    queries = make_clustered_vectors(batch, dim=dim, seed=seed + 32)

    def seq_search():
        done = []
        for sh in be.shards:
            # dispatch + immediate collect: shard s+1's device work only
            # starts after shard s's results reach the host
            done.append(_Collected(sh.dispatch_search(queries,
                                                      k=cfg.k).collect()))
        return ShardedDispatch(done, cfg.cap, cfg.k).collect()

    be.search(queries, k=cfg.k)     # compile both arms' shapes
    seq_search()
    t_seq = t_async = float("inf")
    parity = True
    for _ in range(SERVE_TRIALS):
        t0 = time.monotonic()
        for _ in range(reps):
            r_seq = seq_search()
        t_seq = min(t_seq, time.monotonic() - t0)
        t0 = time.monotonic()
        for _ in range(reps):
            r_async = be.search(queries, k=cfg.k)
        t_async = min(t_async, time.monotonic() - t0)
        parity = parity and bool(
            np.array_equal(r_seq.ids, r_async.ids)
            and np.allclose(r_seq.dists, r_async.dists,
                            rtol=1e-6, atol=1e-6))
    seq_ms = t_seq / reps * 1e3
    async_ms = t_async / reps * 1e3
    return {"shards": shards, "batch": batch,
            "seq_ms": round(seq_ms, 3), "async_ms": round(async_ms, 3),
            "ratio": round(async_ms / max(seq_ms, 1e-9), 3),
            "parity": parity, "host_cores": _host_cores()}


def fused_probe(*, n_base: int, dim: int, batch: int, seed: int,
                reps: int = 16, enabled: bool = False) -> dict:
    """Fused megakernel vs `while_loop` beam search, A/B on one corpus.

    Two identically seeded builds — one with ``fused_beam`` on — serve
    the same snapshot query batch after the same tombstone churn, with
    ``record_heat=False`` on both arms (a capability the fused path
    introduced; the while path ignores the flag, DESIGN.md §15).  The
    probe reports query-batch p50 per arm (best of ``SERVE_TRIALS``
    passes of ``reps`` timed calls), bit-parity of the returned ids,
    the brute-force recall ratio, and a zero-retrace check on the fused
    arm.  The p50 half of the criterion binds only under
    ``--fused-beam`` (the 1.05x band absorbs CPU-oracle-route noise —
    on a CPU host both arms lower to `while_loop` HLO, so the ratio
    hovers at 1.0; on TPU the megakernel's single launch must win).
    """
    cfg_w = _cfg(dim, n_base + 64)
    cfg_f = cfg_w._replace(fused_beam=True)
    base = make_clustered_vectors(n_base, dim=dim, seed=seed + 51)
    queries = make_clustered_vectors(batch, dim=dim, seed=seed + 52)
    dels = np.arange(0, n_base // 8, dtype=np.int64)
    ix_w = LSMVecIndex.build(cfg_w, base, seed=seed)
    ix_f = LSMVecIndex.build(cfg_f, base, seed=seed)
    for ix in (ix_w, ix_f):
        ix.delete(dels)
    p = SearchParams(use_snapshot=True, pad_to=batch, record_heat=False)
    r_w = ix_w.search(queries, k=cfg_w.k, params=p)       # also warmup
    r_f = ix_f.search(queries, k=cfg_w.k, params=p)
    parity = bool(np.array_equal(np.asarray(r_w.ids), np.asarray(r_f.ids)))
    warm = dict(ix_f.trace_counts())
    live = np.ones(n_base, bool)
    live[dels] = False
    truth = brute_force_knn(base, queries, cfg_w.k, live=live)
    rec_w = recall_at_k(np.asarray(r_w.ids), truth)
    rec_f = recall_at_k(np.asarray(r_f.ids), truth)

    def measure(ix):
        best = None
        for _ in range(SERVE_TRIALS):
            lat = []
            for _ in range(reps):
                t0 = time.monotonic()
                res = ix.search(queries, k=cfg_w.k, params=p)
                np.asarray(res.ids)                       # force host sync
                lat.append((time.monotonic() - t0) * 1e3)
            p50 = float(np.percentile(lat, 50))
            best = p50 if best is None else min(best, p50)
        return best

    while_p50 = measure(ix_w)
    fused_p50 = measure(ix_f)
    return {"enabled": bool(enabled),
            "while_p50_ms": round(while_p50, 3),
            "fused_p50_ms": round(fused_p50, 3),
            "p50_ratio": round(fused_p50 / max(while_p50, 1e-9), 3),
            "parity": parity,
            "recall_ratio": round(rec_f / max(rec_w, 1e-9), 4),
            "zero_retraces": dict(ix_f.trace_counts()) == warm,
            "host_cores": _host_cores()}


def overlap_probe(*, n_base: int, n_ops: int, batch: int, dim: int,
                  seed: int) -> dict:
    """Query p99 while consolidating (overlapped) vs no maintenance.

    A 30%-churn stream (70/15/15 query/insert/delete) over a
    lazy-delete index.  The ``nomaint`` arm never consolidates — the
    tail an undisturbed server shows; the ``overlap`` arm triggers the
    double-buffered repair aggressively (low ratio, tight cadence).
    The §13 claim under test: because the repair's device work runs
    while queries keep serving from the live state — the cutover is a
    pointer swap at a poll or write barrier — the query tail must not
    stretch beyond 1.3x the undisturbed arm's p99.  Both arms replay
    the identical stream from clones of one built index, including the
    same warmup (which pre-traces the repair in the overlap arm so
    compilation never lands in the timed region).
    """
    cap = n_base + max(n_ops // 4, 8) + 4 * batch + 64
    cfg = _cfg(dim, cap)._replace(lazy_delete=True)
    rng = np.random.default_rng(seed + 41)
    base = make_clustered_vectors(n_base, dim=dim, seed=seed + 42)
    fresh = make_clustered_vectors(max(n_ops // 4, 8), dim=dim,
                                   seed=seed + 43)
    stream, victims, fi = [], list(rng.permutation(n_base // 2)), 0
    for _ in range(n_ops):
        r = rng.random()
        if r < 0.7 or (r >= 0.85 and not victims) or (r < 0.85 and
                                                      fi >= len(fresh)):
            stream.append(("q", base[rng.integers(0, n_base)]))
        elif r < 0.85:
            stream.append(("i", fresh[fi]))
            fi += 1
        else:
            stream.append(("d", int(victims.pop())))
    idx0 = LSMVecIndex.build(cfg, base)
    warm_del = [int(v) for v in
                rng.permutation(np.arange(n_base // 2, n_base))[:n_base // 8]]
    pols = {
        "nomaint": MaintenancePolicy(tombstone_ratio=None,
                                     consolidate_ratio=None,
                                     heat_budget=None),
        "overlap": MaintenancePolicy(tombstone_ratio=None,
                                     consolidate_ratio=0.05,
                                     heat_budget=None, check_every=2,
                                     overlap=True),
    }
    arms = {}
    for arm, pol in pols.items():
        best = None
        for _ in range(SERVE_TRIALS):
            eng = ServeEngine(idx0.clone(), ServeConfig(
                query_batch=batch, insert_batch=batch, delete_batch=batch,
                adaptive_windows=False, query_window=0.0,
                insert_window=0.0, delete_window=0.0, strict_order=False,
                maintenance=pol))
            # warmup: compile every serving shape AND (overlap arm) the
            # background repair — enough deletes to cross the trigger,
            # then a forced maintenance pass claimed to completion
            for i in range(4):
                eng.submit_query(base[i])
            for v in fresh[:4]:
                eng.submit_insert(v)
            eng.drain()
            for v in warm_del:
                eng.submit_delete(v)
            eng.drain()
            eng.maintenance.run_if_due(force=True)
            eng.maintenance.barrier()
            # the cutover left the search snapshot stale: insert now to
            # compile the *plain* insert path (no snapshot to patch),
            # then query to re-resolve — in the timed region a
            # consolidation-then-insert sequence replays exactly this
            for v in fresh[4:8]:
                eng.submit_insert(v)
            eng.drain()
            eng.submit_query(base[0])
            eng.drain()
            eng.backend.sync()
            eng.metrics = type(eng.metrics)()   # timed region starts clean
            for op, payload in stream:
                if op == "q":
                    eng.submit_query(payload)
                elif op == "i":
                    eng.submit_insert(payload)
                else:
                    eng.submit_delete(payload)
            eng.drain()
            eng.backend.sync()
            m = eng.metrics.snapshot()
            cur = {"p99": m["query"]["p99_ms"],
                   "cons": eng.maintenance.consolidations,
                   "holds": m["write_holds"]}
            eng.close()
            if best is None or cur["p99"] < best["p99"]:
                best = cur
        arms[arm] = best
    p99_no, p99_ov = arms["nomaint"]["p99"], arms["overlap"]["p99"]
    return {"p99_nomaint_ms": round(p99_no, 3),
            "p99_overlap_ms": round(p99_ov, 3),
            "ratio": round(p99_ov / max(p99_no, 1e-9), 3),
            "consolidations": arms["overlap"]["cons"],
            "write_holds": arms["overlap"]["holds"],
            "host_cores": _host_cores()}


def run(*, n_base: int, n_ops: int, batch: int, dim: int, seed: int,
        n_expand: int, mode: str, shards: int = 1, wal: bool = False,
        ckpt_every: int | None = None, tier: bool = False,
        fused: bool = False, work_dir: str | None = None) -> dict:
    rng = np.random.default_rng(seed)
    n_fresh = max(n_ops // 8, 8)
    cap = n_base + n_fresh + 4 * batch + 64
    cfg = _cfg(dim, cap)
    # per-shard id space: the shard's slice of the corpus plus slack for
    # routed inserts and hash imbalance
    cfg_shard = _cfg(dim, -(-(n_base + n_fresh) // shards) + 4 * batch + 64)
    if tier:
        # --tier: two-lane store under live churn (DESIGN.md §12); the
        # sequential recall baseline below shares the config but never
        # runs maintenance, so it stays all-hot (≡ dense)
        cfg = cfg._replace(tier=True, level_scale=0.25)
        cfg_shard = cfg_shard._replace(tier=True, level_scale=0.25)
    if fused:
        # --fused-beam: the main drain serves snapshot queries through
        # the megakernel path (DESIGN.md §15); the sequential recall
        # baseline keeps the while_loop path, so the recall criterion
        # doubles as a cross-path guard
        cfg = cfg._replace(fused_beam=True)
        cfg_shard = cfg_shard._replace(fused_beam=True)
    base = make_clustered_vectors(n_base, dim=dim, seed=seed)
    fresh = make_clustered_vectors(n_fresh, dim=dim, seed=seed + 1)
    stream = make_stream(rng, n_ops, n_base, fresh, base)
    mix = {op: round(sum(1 for o, _ in stream if o == op) / n_ops, 3)
           for op in ("q", "i", "d")}

    # Serving configuration: query micro-batches coalesce 2x wider than
    # the write pad width (at saturation the scheduler's advantage is
    # filling large fixed shapes from the backlog — but going wider still
    # loses more to pad-lane waste on partial batches than it gains in
    # dispatch amortization), and beams expand 2x
    # wider than the reference path — on a churn-damaged graph the
    # vmapped batch runs as long as its slowest lane, and wider expansion
    # halves the straggler trip count.  Recall is guarded by the
    # sequential-baseline criterion below.
    serve_cfg = ServeConfig(
        query_batch=2 * batch, insert_batch=batch, delete_batch=batch,
        query_window=0.0, insert_window=0.0, delete_window=0.0,
        strict_order=False,
        search=SearchParams(n_expand=2 * n_expand),
        maintenance=MaintenancePolicy(
            tombstone_ratio=0.25, heat_budget=None,
            # tier mode checks more often so demotion actually engages
            # within the smoke's short write stream
            check_every=2 if tier else 8,
            tier_policy=TierPolicy(hot_frac=0.25, max_demote=cap,
                                   max_promote=64) if tier else None))
    if work_dir is None:
        work_dir = tempfile.mkdtemp(prefix="serve_durability_")
    if shards > 1:
        backend0 = ShardedBackend(cfg_shard, shards).build(base, seed=seed)
    else:
        backend0 = LSMVecIndex.build(cfg, base)
    # warmup must compile every serving shape on every shard: extend the
    # warm insert run until the deterministic hash router has touched
    # each shard at least once (their deletes then cover the delete path
    # on the same shards; queries fan out to all shards regardless)
    n_warm = 3
    while shards > 1 and len(set(np.asarray(shard_of_seq(
            np.arange(n_base, n_base + n_warm), shards)))) < shards:
        n_warm += 1
    warm_vecs = make_clustered_vectors(n_warm, dim=dim, seed=seed + 9)
    # a second shard-covering wave, inserted while every shard's query
    # snapshot is current, compiles the incremental snapshot-patch path
    # (DESIGN.md §13) — its start seq is n_base + n_warm, so the cover
    # must be recomputed from there
    n_warm2 = 3
    while shards > 1 and len(set(np.asarray(shard_of_seq(
            np.arange(n_base + n_warm, n_base + n_warm + n_warm2),
            shards)))) < shards:
        n_warm2 += 1
    warm_vecs2 = make_clustered_vectors(n_warm2, dim=dim, seed=seed + 10)

    wall = float("inf")
    eng = warm_traces = load_traces = None
    for trial in range(SERVE_TRIALS):
        # fresh copy: the previous trial's donated jits consumed its state
        idx_t = backend0.clone()
        serve_cfg_t = serve_cfg
        if wal:
            # --wal: the headline drain runs durable — per-trial WAL (and
            # checkpoint, under --ckpt-every) directories, so trials never
            # replay each other's records
            serve_cfg_t = dataclasses.replace(
                serve_cfg,
                wal=WalConfig(dir=os.path.join(work_dir,
                                               f"serve_wal_t{trial}")),
                ckpt_dir=(os.path.join(work_dir, f"serve_ckpt_t{trial}")
                          if ckpt_every else None),
                maintenance=dataclasses.replace(
                    serve_cfg.maintenance, checkpoint_every=ckpt_every))
        eng_t = ServeEngine(idx_t, serve_cfg_t)

        # warmup: compile every serving shape outside the timed region.
        # The warmup inserts are deleted again right away, so the index
        # content entering the load phase is exactly `base` (only the id
        # space advanced by n_warm + n_warm2) — recall accounting relies
        # on it.
        warm_ids = [eng_t.submit_insert(v) for v in warm_vecs]
        for i in range(5):
            eng_t.submit_query(base[i])
        eng_t.drain()
        for t in warm_ids:
            eng_t.submit_delete(t.result())
        eng_t.drain()
        # patch wave: queries resolve every shard's snapshot, then a
        # covering insert run compiles the per-shard snapshot-patch jit
        for i in range(5):
            eng_t.submit_query(base[i])
        eng_t.drain()
        warm_ids2 = [eng_t.submit_insert(v) for v in warm_vecs2]
        eng_t.drain()
        for t in warm_ids2:
            eng_t.submit_delete(t.result())
        eng_t.drain()
        idx_t.sync()
        warm_t = dict(idx_t.trace_counts())

        # the load phase: saturation drain of the interleaved stream
        for op, payload in stream:
            if op == "q":
                eng_t.submit_query(payload)
            elif op == "i":
                eng_t.submit_insert(payload)
            else:
                eng_t.submit_delete(payload)
        t0 = time.monotonic()
        eng_t.drain()
        idx_t.sync()
        wall_t = time.monotonic() - t0
        if wall_t < wall:
            wall = wall_t
        # keep the last trial's artifacts for the recall/reference phases
        eng = eng_t
        warm_traces, load_traces = warm_t, dict(idx_t.trace_counts())

    new_traces = {k: load_traces[k] - warm_traces.get(k, 0)
                  for k in load_traces if load_traces[k]
                  != warm_traces.get(k, 0)}

    # ---- fixed-batch reference QPS (the PR-1 path): the SAME op stream
    # dispatched directly as fixed-shape batches — arrival-order runs of
    # `batch` per op, no scheduler, reference beam shape — from the same
    # starting index, best of SERVE_TRIALS passes.  Both sides pay the
    # identical write stream, so the ratio isolates the serving layer
    # (coalescing + padding + snapshot reads + scheduling) from the
    # box's read/write cost balance: a read-only reference flips the
    # criterion with the hardware — on a box with cheap batched reads
    # it penalizes the serve drain for write time no scheduler can
    # avoid, on one with dear reads it flatters it.
    n_stream_q = sum(1 for o, _ in stream if o == "q")
    gids0 = np.asarray(backend0.initial_ids(), np.int64)
    dt_fixed = float("inf")
    for _ in range(SERVE_TRIALS):
        idx_f = backend0.clone()
        # compile this clone's shapes outside the timed region (clone()
        # gives fresh jit caches), mirroring the serve trials' warmup:
        # the same warm inserts (shard-covering under --shards) are
        # deleted again, so only the id space advances before timing
        wid = np.asarray(idx_f.insert_batch(warm_vecs, pad_to=batch).ids,
                         np.int64)
        idx_f.delete_batch(wid, pad_to=batch)
        ref_params = SearchParams(n_expand=n_expand, record_heat=False,
                                  pad_to=batch)
        idx_f.search(base[:batch], k=cfg.k, params=ref_params)
        # insert against the current snapshot: compile the patch path
        # outside the timed region, mirroring the serve warmup
        wid2 = np.asarray(idx_f.insert_batch(warm_vecs2, pad_to=batch).ids,
                          np.int64)
        idx_f.delete_batch(wid2, pad_to=batch)
        idx_f.sync()
        bufs = {"q": [], "i": [], "d": []}

        def _flush(op, idx_f=idx_f, bufs=bufs):
            items = bufs[op]
            if not items:
                return
            if op == "q":
                idx_f.search(np.stack(items), k=cfg.k, params=ref_params)
            elif op == "i":
                idx_f.insert_batch(np.stack(items), pad_to=batch)
            else:
                idx_f.delete_batch(gids0[np.asarray(items, np.int64)],
                                   pad_to=batch)
            items.clear()

        t0 = time.monotonic()
        for op, payload in stream:
            bufs[op].append(payload)
            if len(bufs[op]) == batch:
                _flush(op)
        for op in ("q", "i", "d"):
            _flush(op)
        idx_f.sync()
        dt_fixed = min(dt_fixed, time.monotonic() - t0)
    fixed_qps = n_stream_q / dt_fixed

    m = eng.metrics.snapshot()
    serve_qps = n_stream_q / wall

    # ---- recall: engine vs the sequential per-item baseline --------------
    # Same op stream applied one-by-one to a bare index (the sequential
    # reference), then one shared eval query set through both.  The serve
    # index's id space carries the 3 (deleted) warmup inserts, so its
    # ground truth is built in its own id space.
    idx_seq = LSMVecIndex.build(cfg, base)
    live = np.ones(n_base + n_fresh, bool)
    n_ins = 0
    for op, payload in stream:
        if op == "i":
            idx_seq.insert(payload)
            n_ins += 1
        elif op == "d":
            idx_seq.delete(payload)
            live[payload] = False
    live_all = live[:n_base + n_ins].copy()
    eval_q = make_clustered_vectors(64, dim=dim, seed=seed + 3)
    allv_seq = np.concatenate([base, fresh[:n_ins]])
    truth_seq = brute_force_knn(allv_seq, eval_q, cfg.k, live=live_all)
    recall_seq = recall_at_k(idx_seq.search(eval_q, k=cfg.k).ids,
                             truth_seq)

    serve_tickets = [eng.submit_query(q) for q in eval_q]
    eng.drain()
    ids_serve = np.stack([t.result().ids for t in serve_tickets])
    allv_serve = np.concatenate([base, warm_vecs, warm_vecs2,
                                 fresh[:n_ins]])
    live_serve = np.concatenate(
        [live_all[:n_base], np.zeros(n_warm + n_warm2, bool),
         live_all[n_base:]])
    truth_serve = brute_force_knn(allv_serve, eval_q, cfg.k,
                                  live=live_serve)
    recall_serve = recall_at_k(ids_serve, truth_serve)

    # ---- durability: group-commit overhead A/B probe (DESIGN.md §11) -----
    probe = durability_probe(n=64 if mode == "smoke" else 512, batch=batch,
                             dim=dim, seed=seed, work_dir=work_dir)

    # ---- async serving spine probes (DESIGN.md §13) ----------------------
    fanout = fanout_probe(
        n_base=256 if mode == "smoke" else 2048, dim=dim, batch=2 * batch,
        seed=seed, shards=4, reps=8 if mode == "smoke" else 32)
    overlap = overlap_probe(
        n_base=256 if mode == "smoke" else 1024,
        n_ops=192 if mode == "smoke" else 1024,
        batch=batch, dim=dim, seed=seed)

    # ---- fused megakernel A/B probe (DESIGN.md §15) ----------------------
    fusedp = fused_probe(
        n_base=256 if mode == "smoke" else 2048, dim=dim, batch=batch,
        seed=seed, reps=8 if mode == "smoke" else 24, enabled=fused)

    doc = {
        "meta": {
            "mode": mode, "backend": jax.default_backend(),
            "shards": shards, "tier": bool(tier),
            "n_base": n_base, "n_ops": n_ops, "mix": mix, "dim": dim,
            "batch": batch, "n_expand": n_expand,
            # the serving layer's own knobs (the reference path runs the
            # PR-1 shape `batch`/`n_expand` above; wider coalescing and
            # beams are the scheduler's prerogative, recall-guarded)
            "serve_query_batch": serve_cfg.query_batch,
            "serve_n_expand": serve_cfg.search.n_expand,
            "config": {k: v for k, v in
                       (cfg_shard if shards > 1 else cfg)
                       ._asdict().items()},
        },
        "serve": {
            "qps": round(serve_qps, 1),
            "insert_ops_s": m["insert"]["ops_per_s"],
            "delete_ops_s": m["delete"]["ops_per_s"],
            "query_p50_ms": m["query"]["p50_ms"],
            "query_p99_ms": m["query"]["p99_ms"],
            "mean_query_batch": m["query"]["mean_batch"],
            "snapshot_resolves": m["snapshot_resolves"],
            "compactions": eng.maintenance.compactions,
            "tier_passes": eng.maintenance.tier_passes,
            "tier_demoted": eng.maintenance.tier_demoted,
            "tier_promoted": eng.maintenance.tier_promoted,
            "wall_s": round(wall, 3),
        },
        "baseline": {
            "fixed_batch_qps": round(fixed_qps, 1),
            "qps_ratio": round(serve_qps / fixed_qps, 3),
        },
        "recall": {
            "serve": round(recall_serve, 4),
            "sequential": round(recall_seq, 4),
            "delta": round(recall_serve - recall_seq, 4),
        },
        "retraces": {
            "after_warmup": warm_traces,
            "after_load": load_traces,
            "new_during_load": new_traces,
        },
        "fanout": fanout,
        "overlap": overlap,
        "fused": fusedp,
        "durability": {
            # main-drain accounting (zeros unless --wal): records appended
            # vs group commits fsync'd, and covering checkpoints written
            "wal_enabled": bool(wal),
            "ckpt_every": ckpt_every,
            "wal_records": m["wal"]["records"],
            "wal_commits": m["wal"]["commits"],
            "checkpoints": m["maintenance"]["checkpoint"],
            **probe,
        },
        "criteria": {
            "zero_retraces_after_warmup": not new_traces,
            "qps_within_10pct_of_fixed": bool(
                serve_qps >= 0.9 * fixed_qps),
            # one-sided: serving must not LOSE recall vs the sequential
            # per-item reference; exceeding it (batched inserts with
            # multi-expansion candidate search + intra-batch links build a
            # better-connected graph) is a win, not a violation.  Under
            # sharding the execution differs structurally (cross-shard
            # merge over hash partitions), so the gate is the 0.95x
            # floor of the single-device sequential baseline instead of
            # the ±0.01 band (DESIGN.md §10); same under --tier, where
            # cold candidates route through the quantized lane + exact
            # rerank while the sequential baseline stays all-hot
            "recall_within_0p01": bool(
                recall_serve >= recall_seq - 0.01
                if shards == 1 and not tier
                else recall_serve >= 0.95 * recall_seq),
            "wal_overhead_within_15pct": bool(
                probe["overhead_p50_pct"] <= 15.0),
            # the §13 gates: two-phase fan-out must beat blocking
            # per-shard dispatch by >=30% (needs one device per shard —
            # CI forces 4 host devices), and overlapped consolidation
            # must hold the query tail within 1.3x of an undisturbed
            # server's.  Bit-parity between the arms is folded into the
            # fanout gate: a fast merge that changes results is a fail.
            # The wall-clock halves bind only on hosts with >=2 cores
            # (see `_host_cores`): a single core serializes every
            # device stream, so no dispatch order can show the overlap
            # — the measured ratios are still recorded above.
            "fanout_dispatch_leq_0p7x": bool(
                fanout["parity"] and (fanout["ratio"] <= 0.7
                                      or fanout["host_cores"] < 2)),
            "overlap_p99_leq_1p3x": bool(
                overlap["consolidations"] >= 1
                and (overlap["ratio"] <= 1.3
                     or overlap["host_cores"] < 2)),
            # the §15 gate: the fused path must return bit-identical
            # ids, hold the recall ratio, and never retrace — always;
            # the p50 half (fused at or below while_loop, with a 1.05x
            # noise band for the CPU oracle route where both arms lower
            # to the same while_loop HLO) binds only when the drain
            # actually served fused (--fused-beam)
            "fused_parity_p50_leq_while": bool(
                fusedp["parity"] and fusedp["zero_retraces"]
                and fusedp["recall_ratio"] >= 0.999
                and (fusedp["p50_ratio"] <= 1.05 or not fused)),
        },
    }
    return doc


# ---------------------------------------------------------------------------
# crash-recovery mode (the CI `crash-recovery-smoke` job, DESIGN.md §11)
# ---------------------------------------------------------------------------

CRASH_MATRIX = (("pre_commit", 3), ("post_commit_pre_apply", 3),
                ("mid_checkpoint", 2), ("mid_consolidation", 1))


def _crash_ops(rng, n_ops: int, dim: int):
    """70/15/15 insert/delete/query client stream for the harness."""
    ops, n_ins = [], 0
    for _ in range(n_ops):
        r = rng.random()
        if r < 0.7 or n_ins < 5:
            ops.append(("insert",
                        rng.standard_normal(dim).astype(np.float32)))
            n_ins += 1
        elif r < 0.85:
            ops.append(("delete", int(rng.integers(0, n_ins))))
        else:
            ops.append(("query",
                        rng.standard_normal(dim).astype(np.float32)))
    return ops


def _expected_live(ops, acked):
    """Replay the acked subset into {ext_id: vector} (the survivor set)."""
    live = {}
    for i, (kind, payload) in enumerate(ops):
        if i not in acked:
            continue
        if kind == "insert":
            live[int(acked[i])] = np.asarray(payload, np.float32)
        elif kind == "delete":
            live.pop(int(payload), None)
    return live


def _recovered_recall(engine, live: dict, k: int, eval_q) -> float:
    """Recall of the recovered engine against brute force over its own
    acked live set.  Duplicate-tolerant: a client retry whose original
    record was durable-but-unacked leaves two copies of one vector under
    two external ids (at-least-once delivery), so a truth slot counts as
    hit when *any* external id carrying the same vector is returned."""
    exts = list(live.keys())
    allv = np.stack([live[e] for e in exts])
    gkey = [v.tobytes() for v in allv]
    ext2g = {e: gkey[i] for i, e in enumerate(exts)}
    truth = brute_force_knn(allv, eval_q, k)
    tickets = [engine.submit_query(q) for q in eval_q]
    engine.drain()
    hits = 0
    for row, t in zip(truth, tickets):
        got = {ext2g.get(int(e)) for e in np.asarray(t.result().ids)}
        got.discard(None)
        hits += sum(1 for j in row if gkey[int(j)] in got)
    return hits / (k * len(eval_q))


def run_crash_recovery(*, n_ops: int, dim: int, seed: int,
                       work_dir: str) -> dict:
    """Kill at every injection point, restart, prove nothing acked was
    lost and recall holds a floor against an uninterrupted run."""
    cfg = _cfg(dim, n_ops + 128)
    ops = _crash_ops(np.random.default_rng(seed), n_ops, dim)
    eval_q = np.random.default_rng(seed + 5).standard_normal(
        (32, dim)).astype(np.float32)
    maint_default = MaintenancePolicy(checkpoint_every=4)

    def recover(root, maint, injector=None):
        scfg = ServeConfig(
            query_batch=8, insert_batch=8, delete_batch=8,
            adaptive_windows=False, query_window=0.0, insert_window=0.0,
            delete_window=0.0,
            wal=WalConfig(dir=os.path.join(root, "wal")),
            ckpt_dir=os.path.join(root, "ckpt"), maintenance=maint)
        return ServeEngine.recover(
            scfg, fresh_backend=lambda: LSMVecIndex(cfg, seed=1),
            restore_backend=lambda d: LSMVecIndex.restore(cfg, d),
            injector=injector)

    # uninterrupted reference: same stream, no injector — its recall is
    # the floor every crashed-and-recovered run must hold
    ref_root = os.path.join(work_dir, "reference")
    ref = run_with_recovery(
        policy=RestartPolicy(ckpt_dir=os.path.join(ref_root, "ckpt")),
        make_engine=lambda inj: recover(ref_root, maint_default),
        ops=ops, chunk=10)
    ref_recall = _recovered_recall(
        ref["engine"], _expected_live(ops, ref["acked"]), cfg.k, eval_q)

    points, ok = {}, True
    for point, hit in CRASH_MATRIX:
        maint = maint_default
        if point == "mid_consolidation":
            # consolidation must actually trigger for the hook to fire
            maint = MaintenancePolicy(checkpoint_every=4, check_every=2,
                                      consolidate_ratio=0.05)
        root = os.path.join(work_dir, point)
        injector = FailureInjector(fail_points={point: hit})
        out = run_with_recovery(
            policy=RestartPolicy(ckpt_dir=os.path.join(root, "ckpt"),
                                 wal_dir=os.path.join(root, "wal")),
            make_engine=lambda inj, r=root, m=maint: recover(r, m, inj),
            ops=ops, injector=injector, chunk=10)
        try:
            summary = verify_acked_writes(out["engine"], ops, out["acked"])
            zero_loss = True
        except AssertionError as e:
            summary = {"live": 0, "deleted": 0, "searched": 0,
                       "lost": str(e)}
            zero_loss = False
        recall = _recovered_recall(
            out["engine"], _expected_live(ops, out["acked"]), cfg.k, eval_q)
        fired = out["restarts"] >= 1
        recall_ok = recall >= ref_recall - 0.05
        p_ok = fired and zero_loss and recall_ok
        ok = ok and p_ok
        points[point] = {
            "fired": fired, "restarts": out["restarts"],
            "retried": out["retried"], "zero_acked_loss": zero_loss,
            "recall": round(recall, 4), "recall_ok": recall_ok,
            "ok": p_ok, **summary,
        }
    return {"mode": "crash-recovery", "n_ops": n_ops, "dim": dim,
            "seed": seed, "reference_recall": round(ref_recall, 4),
            "points": points, "ok": ok}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run; validate the JSON schema only")
    ap.add_argument("--out", default=None,
                    help="output path (default: <repo>/BENCH_serve.json)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shards", type=int, default=1,
                    help="serve through a ShardedBackend of P shards "
                         "(1 = single-device LSMVecIndex)")
    ap.add_argument("--wal", action="store_true",
                    help="run the main serve drain with the group-"
                         "committed WAL on (acks imply durability)")
    ap.add_argument("--tier", action="store_true",
                    help="serve a two-lane tiered store: background "
                         "maintenance demotes cold nodes to the int8 "
                         "lane while the drain runs (DESIGN.md §12)")
    ap.add_argument("--fused-beam", action="store_true",
                    help="serve the main drain through the fused beam-"
                         "search megakernel path (DESIGN.md §15) and "
                         "bind the fused A/B criterion, p50 half "
                         "included, even under --smoke")
    ap.add_argument("--ckpt-every", type=int, default=None,
                    help="with --wal: write a covering checkpoint every "
                         "N write batches during the main drain")
    ap.add_argument("--crash-recovery", action="store_true",
                    help="run the failure-injection matrix instead of "
                         "the load benchmark; exit nonzero on any "
                         "acked-write loss or recall-floor breach")
    ap.add_argument("--gate-async", action="store_true",
                    help="enforce the DESIGN.md \u00a713 criteria (fanout "
                         "dispatch <=0.7x, overlapped-consolidation p99 "
                         "<=1.3x) even under --smoke; exit nonzero on "
                         "breach")
    ap.add_argument("--workdir", default=None,
                    help="directory for WAL/checkpoint artifacts "
                         "(default: a fresh temp dir); CI uploads it on "
                         "failure")
    args = ap.parse_args(argv)

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = args.out or os.path.join(root, "BENCH_serve.json")
    work_dir = args.workdir or tempfile.mkdtemp(prefix="serve_durability_")
    os.makedirs(work_dir, exist_ok=True)

    if args.crash_recovery:
        if args.smoke:
            doc = run_crash_recovery(n_ops=96, dim=16, seed=args.seed,
                                     work_dir=work_dir)
        else:
            doc = run_crash_recovery(n_ops=192, dim=32, seed=args.seed,
                                     work_dir=work_dir)
        print(json.dumps(doc, indent=1))
        for point, res in doc["points"].items():
            print(f"  {'PASS' if res['ok'] else 'FAIL'} {point} "
                  f"(restarts={res['restarts']} live={res['live']} "
                  f"searched={res['searched']} recall={res['recall']})")
        if args.out:
            write_bench_json(args.out, doc)
        return 0 if doc["ok"] else 1

    if args.smoke:
        # scale the corpus with the shard count so per-shard scale (and
        # per-shard graph navigability) matches the single-device smoke
        doc = run(n_base=256 * args.shards, n_ops=96, batch=16, dim=16,
                  seed=args.seed, n_expand=4, mode="smoke",
                  shards=args.shards, wal=args.wal, tier=args.tier,
                  fused=args.fused_beam, ckpt_every=args.ckpt_every,
                  work_dir=work_dir)
    else:
        doc = run(n_base=4096, n_ops=4096, batch=64, dim=64, seed=args.seed,
                  n_expand=4, mode="full", shards=args.shards, wal=args.wal,
                  tier=args.tier, fused=args.fused_beam,
                  ckpt_every=args.ckpt_every, work_dir=work_dir)

    validate_schema(doc)
    print(json.dumps(doc, indent=1))
    if args.smoke:
        if args.out:
            # an explicit --out in smoke mode gets the smoke doc (CI
            # uploads the measurement it produced); the committed full-
            # run JSON is only written by full runs
            write_bench_json(args.out, doc)
        gates = ()
        if args.gate_async:
            gates += ("fanout_dispatch_leq_0p7x", "overlap_p99_leq_1p3x")
        if args.fused_beam:
            gates += ("fused_parity_p50_leq_while",)
        if gates:
            for name in gates:
                print(f"  {'PASS' if doc['criteria'][name] else 'FAIL'} "
                      f"{name}")
            if not all(doc["criteria"][g] for g in gates):
                return 1
        print("smoke: schema OK (perf criteria not enforced)")
        return 0

    write_bench_json(out, doc)
    for name, ok in doc["criteria"].items():
        print(f"  {'PASS' if ok else 'FAIL'} {name}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
