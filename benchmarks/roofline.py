"""Roofline report: reads results/dryrun.json, prints the per-cell table.

    compute term    = per-device HLO FLOPs / 197 TFLOP/s (bf16)
    memory term     = per-device HLO bytes / 819 GB/s HBM
    collective term = per-device collective bytes / 50 GB/s ICI
                      (all-reduce counted 2x for the ring)

Plus MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (serve) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs.
"""

from __future__ import annotations

import json
import sys


def load(path: str = "results/dryrun.json"):
    with open(path) as f:
        return json.load(f)


def fmt_table(records, mesh_filter: str = "16x16"):
    rows = []
    header = ("arch", "shape", "t_compute_s", "t_memory_s",
              "t_collective_s", "dominant", "useful_ratio",
              "roofline_frac")
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"])):
        if r.get("status") != "ok" or r["mesh"] != mesh_filter:
            continue
        t = r["roofline"]
        bound = max(t["t_compute"], t["t_memory"], t["t_collective"])
        # roofline fraction: useful model FLOP time over the binding term
        useful_t = (r["model_flops_per_device"] / 197e12) if \
            r.get("model_flops_per_device") else 0.0
        frac = useful_t / bound if bound else 0.0
        rows.append((r["arch"], r["shape"],
                     f"{t['t_compute']:.4f}", f"{t['t_memory']:.4f}",
                     f"{t['t_collective']:.4f}", r["dominant"],
                     f"{r['useful_flops_ratio']:.3f}"
                     if r.get("useful_flops_ratio") else "-",
                     f"{frac:.3f}"))
    return header, rows


def main(path: str = "results/dryrun.json"):
    records = load(path)
    for mesh in ("16x16", "2x16x16"):
        header, rows = fmt_table(records, mesh)
        if not rows:
            continue
        print(f"\n=== roofline @ {mesh} ===")
        print(",".join(header))
        for row in rows:
            print(",".join(row))
    errs = [r for r in records if r.get("status") != "ok"]
    if errs:
        print("\nerrors:")
        for r in errs:
            print(f"  {r['arch']} x {r['shape']} @ {r['mesh']}: "
                  f"{r.get('error', '?')[:120]}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.json")
