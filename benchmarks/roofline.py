"""Roofline reports: the model-dryrun table and the beam-megakernel
bytes-moved model (DESIGN.md §15).

Legacy mode (default) reads results/dryrun.json and prints the per-cell
table:

    compute term    = per-device HLO FLOPs / 197 TFLOP/s (bf16)
    memory term     = per-device HLO bytes / 819 GB/s HBM
    collective term = per-device collective bytes / 50 GB/s ICI
                      (all-reduce counted 2x for the ring)

Plus MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (serve) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs.

``roofline.py beam`` instead models and measures the fused beam-search
megakernel against the per-hop-launch `while_loop` path and emits
``BENCH_roofline.json``:

  - **iostats** — measured per-query hop/row counts from a real beam
    search over a built index (the traffic terms below scale by these,
    not by worst-case loop caps);
  - **model** — bytes moved per query under both execution models at
    the TPU HBM ceiling (819 GB/s) plus a per-launch overhead term.
    Both paths stream the same adjacency/vector/code rows; the per-hop
    model additionally spills the beam heap and visited bitmap to HBM
    between launches and pays ~4 launches per hop (pop, adjacency
    gather, fused distance, merge), while the megakernel keeps heap and
    visited VMEM-resident across the whole loop and pays one launch per
    query block (DESIGN.md §15 derives both);
  - **measured** — wall-clock A/B of the two paths on this host.  On a
    CPU host both arms lower to `while_loop` HLO (the oracle route), so
    the measured ratio hovers near 1.0 and only the model halves carry
    the TPU claim; the backend is recorded so readers can tell.

``--smoke`` shrinks the instance; ``--check`` validates the schema and
gates on the model invariant (megakernel strictly fewer bytes and
launches than per-hop) plus measured id parity — the CI mode.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# hardware ceilings shared with the dryrun table below
TFLOPS_BF16 = 197e12
HBM_GBS = 819e9
ICI_GBS = 50e9
LAUNCH_US = 3.0          # conservative per-kernel-launch overhead
LAUNCHES_PER_HOP = 4     # pop/top_k, adjacency gather, gather_l2, merge

BEAM_SCHEMA = {
    "meta": ("mode", "backend", "n_base", "dim", "dpad", "batch", "ef",
             "M", "m_bits", "n_expand", "config"),
    "iostats": ("hops_per_query", "adj_rows_per_query",
                "vec_rows_per_query", "filtered_per_query"),
    "model": ("hbm_bw_gbs", "launch_overhead_us", "launches_per_hop",
              "per_hop", "megakernel", "bytes_ratio", "t_ratio"),
    "measured": ("while_p50_us_per_query", "fused_p50_us_per_query",
                 "ratio", "parity"),
}


def validate_beam_schema(doc: dict) -> None:
    for section, fields in BEAM_SCHEMA.items():
        if section not in doc:
            raise ValueError(f"missing section {section!r}")
        for f in fields:
            if f not in doc[section]:
                raise ValueError(f"missing field {section}.{f}")
    for arm in ("per_hop", "megakernel"):
        for f in ("bytes_per_query", "launches_per_query", "t_model_us"):
            v = doc["model"][arm][f]
            if not isinstance(v, (int, float)) or not np.isfinite(v):
                raise ValueError(f"non-finite model.{arm}.{f}: {v!r}")
    if not isinstance(doc["measured"]["parity"], bool):
        raise ValueError("measured.parity must be bool")


def beam_bytes_model(*, hops: float, adj_rows: float, vec_rows: float,
                     ef: int, M: int, cap: int, dpad: int, m_bits: int,
                     n_expand: int) -> dict:
    """Bytes moved per query under each execution model.

    Shared streaming traffic (both models; measured row counts):
      adjacency  adj_rows x M x 4 B
      vectors    vec_rows x dpad x 4 B   (hot f32 lane; the q8 cold
                                          lane would be dpad + 4 B/row)
      codes      per hop, B*M candidate code rows x m_bits/8 B

    Per-hop-launch extra, per hop: the beam heap (ids+dists+expanded,
    ef x 9 B) and visited bitmap (cap+1 B, bool) spill to HBM on every
    launch boundary (read + write), and the query row (dpad x 4 B) is
    re-read by each distance launch.  The megakernel reads the query
    row once and keeps heap + visited in VMEM scratch for the whole
    loop (DESIGN.md §15 lays out the residency plan).
    """
    B = max(1, min(n_expand, ef))
    code_bytes = hops * B * M * (m_bits // 8)
    shared = adj_rows * M * 4 + vec_rows * dpad * 4 + code_bytes
    spill = hops * (2 * ef * 9 + 2 * (cap + 1) + dpad * 4)
    per_hop = {
        "bytes_per_query": round(shared + spill, 1),
        "launches_per_query": round(hops * LAUNCHES_PER_HOP, 2),
    }
    mega = {
        "bytes_per_query": round(shared + dpad * 4, 1),
        "launches_per_query": 1.0,
    }
    for arm in (per_hop, mega):
        arm["t_model_us"] = round(
            arm["bytes_per_query"] / HBM_GBS * 1e6
            + arm["launches_per_query"] * LAUNCH_US, 3)
    return {
        "hbm_bw_gbs": HBM_GBS / 1e9,
        "launch_overhead_us": LAUNCH_US,
        "launches_per_hop": LAUNCHES_PER_HOP,
        "per_hop": per_hop,
        "megakernel": mega,
        "bytes_ratio": round(mega["bytes_per_query"]
                             / max(per_hop["bytes_per_query"], 1e-9), 4),
        "t_ratio": round(mega["t_model_us"]
                         / max(per_hop["t_model_us"], 1e-9), 4),
    }


def run_beam(*, n_base: int, dim: int, batch: int, seed: int,
             mode: str, reps: int, trials: int = 2) -> dict:
    import jax

    from repro.core import hnsw
    from repro.core.index import LSMVecIndex
    from repro.data.synth import make_clustered_vectors

    cfg = hnsw.HNSWConfig(
        cap=n_base + 64, dim=dim, M=12, M_up=6, num_upper=2,
        ef_search=48, ef_construction=48, k=10, m_bits=64, rho=1.0,
        eps=0.1, use_filter=False, lsm_mem_cap=256, lsm_levels=2,
        lsm_fanout=8, n_expand=1, batch_expand=4)
    base = make_clustered_vectors(n_base, dim=dim, seed=seed)
    queries = make_clustered_vectors(batch, dim=dim, seed=seed + 1)
    ix = LSMVecIndex.build(cfg, base, seed=seed)
    snap = ix.snapshot()

    def arm(fused):
        c = cfg._replace(fused_beam=fused)
        return lambda: hnsw.search_batch(c, ix.state, queries,
                                         snapshot=snap)

    run_w, run_f = arm(False), arm(True)
    res_w, res_f = run_w(), run_f()                 # compile + parity
    parity = bool(np.array_equal(np.asarray(res_w.ids),
                                 np.asarray(res_f.ids)))
    st = res_f.stats
    hops = float(np.mean(np.asarray(st.n_hops)))
    adj_rows = float(np.mean(np.asarray(st.n_adj)))
    vec_rows = float(np.mean(np.asarray(st.n_vec)))
    filtered = float(np.mean(np.asarray(st.n_filtered)))
    dpad = dim + ((-dim) % 128)
    model = beam_bytes_model(
        hops=hops, adj_rows=adj_rows, vec_rows=vec_rows,
        ef=cfg.ef_search, M=cfg.M, cap=cfg.cap, dpad=dpad,
        m_bits=cfg.m_bits, n_expand=cfg.n_expand)

    def measure(fn):
        best = None
        for _ in range(trials):
            lat = []
            for _ in range(reps):
                t0 = time.monotonic()
                r = fn()
                jax.block_until_ready(r.ids)
                lat.append((time.monotonic() - t0) * 1e6 / batch)
            p50 = float(np.percentile(lat, 50))
            best = p50 if best is None else min(best, p50)
        return best

    while_us = measure(run_w)
    fused_us = measure(run_f)
    return {
        "meta": {
            "mode": mode, "backend": jax.default_backend(),
            "n_base": n_base, "dim": dim, "dpad": dpad, "batch": batch,
            "ef": cfg.ef_search, "M": cfg.M, "m_bits": cfg.m_bits,
            "n_expand": cfg.n_expand,
            "config": dict(cfg._asdict()),
        },
        "iostats": {
            "hops_per_query": round(hops, 2),
            "adj_rows_per_query": round(adj_rows, 2),
            "vec_rows_per_query": round(vec_rows, 2),
            "filtered_per_query": round(filtered, 2),
        },
        "model": model,
        "measured": {
            "while_p50_us_per_query": round(while_us, 2),
            "fused_p50_us_per_query": round(fused_us, 2),
            "ratio": round(fused_us / max(while_us, 1e-9), 3),
            "parity": parity,
        },
    }


def beam_main(argv) -> int:
    ap = argparse.ArgumentParser(
        prog="roofline.py beam",
        description="beam megakernel bytes-moved model + measurement")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny instance (the CI mode)")
    ap.add_argument("--check", action="store_true",
                    help="validate the schema and gate on the model "
                         "invariant + measured parity; exit nonzero on "
                         "breach")
    ap.add_argument("--out", default=None,
                    help="output path (default: <repo>/"
                         "BENCH_roofline.json)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.smoke:
        doc = run_beam(n_base=512, dim=64, batch=32, seed=args.seed,
                       mode="smoke", reps=8)
    else:
        doc = run_beam(n_base=4096, dim=64, batch=64, seed=args.seed,
                       mode="full", reps=24)
    print(json.dumps(doc, indent=1))
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = args.out or os.path.join(root, "BENCH_roofline.json")
    # smoke writes only to an explicit --out (CI uploads its own
    # artifact); the committed JSON comes from full runs
    if not args.smoke or args.out:
        from _util import write_bench_json
        write_bench_json(out, doc)
    if args.check:
        validate_beam_schema(doc)
        m = doc["model"]
        gates = {
            "megakernel_fewer_bytes": m["bytes_ratio"] < 1.0,
            "megakernel_fewer_launches": (
                m["megakernel"]["launches_per_query"]
                < m["per_hop"]["launches_per_query"]),
            "model_time_at_or_below": m["t_ratio"] <= 1.0,
            "measured_parity": doc["measured"]["parity"],
        }
        for name, ok in gates.items():
            print(f"  {'PASS' if ok else 'FAIL'} {name}")
        if not all(gates.values()):
            return 1
        print("beam roofline: schema + gates OK")
    return 0


# ---------------------------------------------------------------------------
# legacy dryrun-table mode
# ---------------------------------------------------------------------------

def load(path: str = "results/dryrun.json"):
    with open(path) as f:
        return json.load(f)


def fmt_table(records, mesh_filter: str = "16x16"):
    rows = []
    header = ("arch", "shape", "t_compute_s", "t_memory_s",
              "t_collective_s", "dominant", "useful_ratio",
              "roofline_frac")
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"])):
        if r.get("status") != "ok" or r["mesh"] != mesh_filter:
            continue
        t = r["roofline"]
        bound = max(t["t_compute"], t["t_memory"], t["t_collective"])
        # roofline fraction: useful model FLOP time over the binding term
        useful_t = (r["model_flops_per_device"] / TFLOPS_BF16) if \
            r.get("model_flops_per_device") else 0.0
        frac = useful_t / bound if bound else 0.0
        rows.append((r["arch"], r["shape"],
                     f"{t['t_compute']:.4f}", f"{t['t_memory']:.4f}",
                     f"{t['t_collective']:.4f}", r["dominant"],
                     f"{r['useful_flops_ratio']:.3f}"
                     if r.get("useful_flops_ratio") else "-",
                     f"{frac:.3f}"))
    return header, rows


def main(path: str = "results/dryrun.json"):
    records = load(path)
    for mesh in ("16x16", "2x16x16"):
        header, rows = fmt_table(records, mesh)
        if not rows:
            continue
        print(f"\n=== roofline @ {mesh} ===")
        print(",".join(header))
        for row in rows:
            print(",".join(row))
    errs = [r for r in records if r.get("status") != "ok"]
    if errs:
        print("\nerrors:")
        for r in errs:
            print(f"  {r['arch']} x {r['shape']} @ {r['mesh']}: "
                  f"{r.get('error', '?')[:120]}")


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "beam":
        raise SystemExit(beam_main(sys.argv[2:]))
    main(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.json")
