"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from
results/dryrun.json (markdown to stdout; paste/managed by the author)."""

from __future__ import annotations

import json
import sys


def gb(x):
    return f"{x/1e9:.2f}"


def main(path="results/dryrun.json"):
    with open(path) as f:
        records = json.load(f)
    ok = [r for r in records if r.get("status") == "ok"]
    err = [r for r in records if r.get("status") != "ok"]

    print("### Dry-run summary\n")
    print("| arch | shape | mesh | lower s | compile s | args GB/dev |"
          " temp GB/dev | collective ops |")
    print("|---|---|---|---|---|---|---|---|")
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        m = r.get("memory", {})
        coll = r.get("collectives", {})
        coll_s = " ".join(f"{k.split('-')[-1][:4]}:{int(v['count'])}"
                          for k, v in sorted(coll.items()))
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {r.get('lower_s','-')} | {r.get('compile_s','-')} "
              f"| {gb(m.get('argument_bytes', 0))} "
              f"| {gb(m.get('temp_bytes', 0))} | {coll_s} |")
    if err:
        print("\nFailed cells:")
        for r in err:
            print(f"- {r['arch']} x {r['shape']} @ {r['mesh']}: "
                  f"{r.get('error','')[:140]}")

    print("\n### Roofline (single pod, 16x16 = 256 chips)\n")
    print("| arch | shape | t_comp s | t_mem s | t_coll s | dominant |"
          " MODEL_FLOPS/HLO | roofline frac | next lever |")
    print("|---|---|---|---|---|---|---|---|---|")
    LEVER = {
        "collective": "overlap/reshard the dominant collective "
                      "(FSDP all-gather or EP all-to-all)",
        "memory": "cut activation/optimizer traffic (dtype, remat policy)",
        "compute": "MXU-align tiles / raise arithmetic intensity",
    }
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != "16x16":
            continue
        t = r["roofline"]
        bound = max(t["t_compute"], t["t_memory"], t["t_collective"])
        useful_t = r.get("model_flops_per_device", 0) / 197e12
        frac = useful_t / bound if bound else 0
        ratio = r.get("useful_flops_ratio")
        print(f"| {r['arch']} | {r['shape']} | {t['t_compute']:.4f} "
              f"| {t['t_memory']:.4f} | {t['t_collective']:.4f} "
              f"| {r['dominant']} | {ratio:.3f} | {frac:.3f} "
              f"| {LEVER[r['dominant']]} |" if ratio else
              f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - | - |")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.json")
