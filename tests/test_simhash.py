"""Tests for SimHash codes + Hoeffding filter (core/simhash.py)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # skips gracefully when absent

from repro.core import simhash


def test_encode_shape_and_dtype():
    p = simhash.init(jax.random.key(0), dim=32, m_bits=64)
    x = jax.random.normal(jax.random.key(1), (5, 32))
    codes = simhash.encode(p, x)
    assert codes.shape == (5, 2)
    assert codes.dtype == jnp.uint32


def test_self_collisions_are_m():
    p = simhash.init(jax.random.key(0), dim=16, m_bits=64)
    x = jax.random.normal(jax.random.key(1), (3, 16))
    codes = simhash.encode(p, x)
    cols = simhash.collisions(codes, codes, 64)
    np.testing.assert_array_equal(np.asarray(cols), [64, 64, 64])


def test_opposite_vectors_zero_collisions():
    p = simhash.init(jax.random.key(0), dim=16, m_bits=64)
    x = jax.random.normal(jax.random.key(1), (1, 16))
    ca = simhash.encode(p, x)
    cb = simhash.encode(p, -x)
    cols = simhash.collisions(ca, cb, 64)
    # sgn flips for every projection except exact zeros (prob ~0)
    assert int(cols[0]) == 0


def test_collision_count_matches_unpacked_bits():
    """Packed popcount arithmetic == direct bit comparison (Eq. 5)."""
    p = simhash.init(jax.random.key(0), dim=24, m_bits=96)
    x = jax.random.normal(jax.random.key(1), (4, 24))
    y = jax.random.normal(jax.random.key(2), (4, 24))
    bits_x = np.asarray((x @ p.proj.T) >= 0)
    bits_y = np.asarray((y @ p.proj.T) >= 0)
    expected = (bits_x == bits_y).sum(axis=1)
    got = simhash.collisions(simhash.encode(p, x), simhash.encode(p, y), 96)
    np.testing.assert_array_equal(np.asarray(got), expected)


def test_collision_probability_endpoints():
    assert float(simhash.collision_probability(jnp.array(1.0))) == pytest.approx(1.0)
    assert float(simhash.collision_probability(jnp.array(-1.0))) == pytest.approx(0.0)
    assert float(simhash.collision_probability(jnp.array(0.0))) == pytest.approx(0.5)


def test_collisions_monotone_in_angle():
    """Closer vectors (higher cos) collide more, statistically."""
    dim, m = 64, 256
    p = simhash.init(jax.random.key(0), dim, m)
    key = jax.random.key(1)
    base = jax.random.normal(key, (200, dim))
    near = base + 0.1 * jax.random.normal(jax.random.key(2), base.shape)
    far = jax.random.normal(jax.random.key(3), base.shape)
    cb = simhash.encode(p, base)
    cn = simhash.encode(p, near)
    cf = simhash.encode(p, far)
    mean_near = float(jnp.mean(simhash.collisions(cb, cn, m)))
    mean_far = float(jnp.mean(simhash.collisions(cb, cf, m)))
    assert mean_near > mean_far + 20  # near ~ cos 0.99 -> ~0.97m; far ~ 0.5m


def test_hoeffding_guarantee_empirical():
    """Candidates within delta pass the threshold w.p. >= 1 - eps (Eq. 6)."""
    dim, m, eps = 32, 128, 0.05
    p = simhash.init(jax.random.key(0), dim, m)
    key = jax.random.key(1)
    q = jax.random.normal(key, (500, dim))
    q = q / jnp.linalg.norm(q, axis=1, keepdims=True)
    # construct candidates at a known angle (cos = 0.9)
    noise = jax.random.normal(jax.random.key(2), q.shape)
    noise = noise - jnp.sum(noise * q, axis=1, keepdims=True) * q
    noise = noise / jnp.linalg.norm(noise, axis=1, keepdims=True)
    cos_target = 0.9
    u = cos_target * q + math.sqrt(1 - cos_target**2) * noise
    cq, cu = simhash.encode(p, q), simhash.encode(p, u)
    cols = simhash.collisions(cq, cu, m)
    thr = simhash.hoeffding_threshold(m, eps, jnp.array(cos_target))
    pass_rate = float(jnp.mean(cols.astype(jnp.float32) >= thr))
    assert pass_rate >= 1 - eps - 0.02  # small empirical slack


def test_cos_from_l2_roundtrip():
    q = jnp.array([3.0, 4.0])          # norm 5
    u = jnp.array([4.0, 3.0])          # norm 5
    d2 = jnp.sum((q - u) ** 2)
    cos = simhash.cos_from_l2(d2, jnp.linalg.norm(q), jnp.linalg.norm(u))
    expected = float(q @ u / 25.0)
    assert float(cos) == pytest.approx(expected, abs=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_property_collisions_symmetric(seed):
    p = simhash.init(jax.random.key(0), dim=8, m_bits=32)
    x = jax.random.normal(jax.random.key(seed), (2, 8))
    c = simhash.encode(p, x)
    ab = simhash.collisions(c[0], c[1], 32)
    ba = simhash.collisions(c[1], c[0], 32)
    assert int(ab) == int(ba)
    assert 0 <= int(ab) <= 32


@settings(max_examples=10, deadline=None)
@given(st.floats(min_value=-0.99, max_value=0.99),
       st.sampled_from([0.01, 0.05, 0.1, 0.3]))
def test_property_threshold_monotone_in_eps(cos, eps):
    """Larger eps (more tolerance for misses) -> higher threshold."""
    lo = simhash.hoeffding_threshold(128, eps, jnp.array(cos))
    hi = simhash.hoeffding_threshold(128, eps * 0.5, jnp.array(cos))
    assert float(hi) <= float(lo)
