"""Integration tests: LSMVecIndex recall, dynamic updates, sampling, reorder."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hnsw
from repro.core.backend import SearchParams
from repro.core.index import LSMVecIndex, brute_force_knn, recall_at_k
from repro.data.synth import make_clustered_vectors


def make_data(n, dim=32, seed=0, clusters=16):
    """Synthetic SIFT-like clustered data (shared centers => queries are
    in-distribution, like the SIFT1B query set)."""
    return make_clustered_vectors(n, dim=dim, seed=seed, clusters=clusters)


CFG = hnsw.HNSWConfig(cap=2048, dim=32, M=12, M_up=6, num_upper=2,
                      ef_search=48, ef_construction=48, k=10,
                      rho=1.0, use_filter=False, lsm_mem_cap=128,
                      lsm_levels=2, lsm_fanout=8)


@pytest.fixture(scope="module")
def built_index():
    data = make_data(1024)
    idx = LSMVecIndex.build(CFG, data)
    return idx, data


def test_bulk_build_recall(built_index):
    idx, data = built_index
    queries = make_data(32, seed=7)
    res = idx.search(queries, k=10)
    truth = brute_force_knn(jnp.asarray(data), jnp.asarray(queries), 10)
    r = recall_at_k(res.ids, truth)
    assert r >= 0.85, f"bulk-build recall {r:.3f} too low"


def test_search_returns_sorted_distances(built_index):
    idx, _ = built_index
    queries = make_data(8, seed=9)
    dists = idx.search(queries, k=10).dists
    for row in dists:
        assert np.all(np.diff(row) >= -1e-5)


def test_insert_then_find_self():
    data = make_data(256, seed=1)
    idx = LSMVecIndex.build(CFG, data)
    new = make_data(8, seed=42) + 100.0  # far-away cluster
    ids = [idx.insert(x) for x in new]
    found = idx.search(new, k=1).ids
    assert set(found[:, 0].tolist()) == set(ids)


def test_incremental_insert_recall():
    """Start from a seed index, insert a batch, verify combined recall."""
    base = make_data(512, seed=2)
    extra = make_data(128, seed=3)
    idx = LSMVecIndex.build(CFG, base)
    for x in extra:
        idx.insert(x)
    assert idx.size == 640
    allv = np.concatenate([base, extra])
    queries = make_data(24, seed=8)
    ids = idx.search(queries, k=10).ids
    truth = brute_force_knn(jnp.asarray(allv), jnp.asarray(queries), 10)
    r = recall_at_k(ids, truth)
    assert r >= 0.75, f"post-insert recall {r:.3f}"


def test_delete_removes_from_results():
    data = make_data(256, seed=4)
    idx = LSMVecIndex.build(CFG, data)
    queries = data[:8]
    ids = idx.search(queries, k=1).ids
    victims = ids[:, 0].tolist()
    for v in set(victims):
        idx.delete(v)
    ids2 = idx.search(queries, k=10).ids
    for row in ids2:
        assert not (set(row.tolist()) & set(victims)), "deleted id returned"


def test_delete_preserves_recall_on_rest():
    data = make_data(512, seed=5)
    idx = LSMVecIndex.build(CFG, data)
    rng = np.random.default_rng(0)
    victims = rng.choice(512, 64, replace=False)
    for v in victims:
        idx.delete(int(v))
    assert idx.size == 448
    live = np.ones(512, bool)
    live[victims] = False
    queries = make_data(24, seed=6)
    truth = brute_force_knn(jnp.asarray(data), jnp.asarray(queries), 10,
                            live=jnp.asarray(live))
    ids = idx.search(queries, k=10).ids
    r = recall_at_k(ids, truth)
    assert r >= 0.7, f"post-delete recall {r:.3f}"


def test_sampling_reduces_vector_fetches():
    """Eq. 8-9: rho < 1 must fetch fewer vectors, recall degrades gently."""
    data = make_data(1024, seed=10)
    cfg = CFG._replace(rho=1.0, use_filter=False)
    idx = LSMVecIndex.build(cfg, data)
    queries = make_data(32, seed=11)

    idx.reset_stats()
    ids_full = idx.search(queries, k=10, params=SearchParams(rho=1.0)).ids
    full_fetches = int(idx.io_stats.n_vec)

    idx.reset_stats()
    ids_samp = idx.search(queries, k=10, params=SearchParams(rho=0.7)).ids
    samp_fetches = int(idx.io_stats.n_vec)

    assert samp_fetches < full_fetches
    truth = brute_force_knn(jnp.asarray(data), jnp.asarray(queries), 10)
    r_full = recall_at_k(ids_full, truth)
    r_samp = recall_at_k(ids_samp, truth)
    assert r_samp >= r_full - 0.15, (r_full, r_samp)


def test_hash_filter_counts_skips():
    data = make_data(1024, seed=12)
    cfg = CFG._replace(use_filter=True, eps=0.1)
    idx = LSMVecIndex.build(cfg, data)
    queries = make_data(16, seed=13)
    idx.reset_stats()
    idx.search(queries, k=10, params=SearchParams(use_filter=True))
    assert int(idx.io_stats.n_filtered) >= 0
    assert int(idx.io_stats.n_vec) > 0


def test_memory_accounting_grows_with_inserts():
    data = make_data(256, seed=14)
    idx = LSMVecIndex.build(CFG, data)
    m0 = idx.memory_bytes()
    for x in make_data(64, seed=15):
        idx.insert(x)
    m1 = idx.memory_bytes()
    assert m1 >= m0
    # the vector lanes only hold the live rows, far below the full
    # cap-sized dense array; the total also stays under it even though
    # memory_bytes() now counts all serving state (tombstone lane,
    # insert overlay, ext<->int id maps)
    bd = idx.memory_breakdown()
    assert bd.hot_vectors + bd.cold_codes < 0.5 * idx.state.vectors.nbytes
    assert m1 < idx.state.vectors.nbytes


def test_reorder_preserves_results_and_improves_layout():
    data = make_data(512, seed=16)
    idx = LSMVecIndex.build(CFG, data)
    queries = make_data(16, seed=17)
    d_before = idx.search(queries, k=5).dists
    idx.search(queries, k=5)  # accumulate heat
    perm = idx.reorder(window=8, lam=1.0)
    assert sorted(perm.tolist()) == list(range(512))  # valid permutation
    d_after = idx.search(queries, k=5).dists
    # distances identical (same vectors, relabeled ids)
    np.testing.assert_allclose(np.sort(d_after, axis=1),
                               np.sort(d_before, axis=1), rtol=1e-4,
                               atol=1e-4)


def test_update_after_reorder():
    data = make_data(256, seed=18)
    idx = LSMVecIndex.build(CFG, data)
    idx.search(make_data(8, seed=19), k=5)
    idx.reorder()
    new_vec = make_data(1, seed=20)[0] + 50.0
    nid = idx.insert(new_vec)
    found = idx.search(new_vec[None, :], k=1).ids
    assert int(found[0, 0]) == nid
