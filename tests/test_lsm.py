"""Unit + property tests for the functional LSM-tree (core/lsm.py)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st  # skips gracefully when absent

from repro.core import lsm

CFG = lsm.LSMConfig(mem_cap=8, num_levels=3, fanout=4, row_width=4)


def row(*xs):
    out = np.full((CFG.row_width,), lsm.EMPTY, np.int32)
    out[: len(xs)] = xs
    return jnp.asarray(out)


def test_put_get_roundtrip():
    s = lsm.init(CFG)
    s = lsm.put(CFG, s, 7, row(1, 2, 3))
    found, val, _ = lsm.get(CFG, s, 7)
    assert bool(found)
    np.testing.assert_array_equal(np.asarray(val)[:3], [1, 2, 3])
    found, _, _ = lsm.get(CFG, s, 8)
    assert not bool(found)


def test_overwrite_newest_wins():
    s = lsm.init(CFG)
    s = lsm.put(CFG, s, 5, row(1))
    s = lsm.put(CFG, s, 5, row(2))
    _, val, _ = lsm.get(CFG, s, 5)
    assert int(val[0]) == 2


def test_overwrite_survives_flush():
    s = lsm.init(CFG)
    s = lsm.put(CFG, s, 5, row(1))
    s = lsm.flush(CFG, s)
    s = lsm.put(CFG, s, 5, row(2))
    _, val, _ = lsm.get(CFG, s, 5)
    assert int(val[0]) == 2
    s = lsm.flush(CFG, s)
    _, val, _ = lsm.get(CFG, s, 5)
    assert int(val[0]) == 2


def test_delete_tombstone():
    s = lsm.init(CFG)
    s = lsm.put(CFG, s, 3, row(9))
    s = lsm.delete(CFG, s, 3)
    found, _, _ = lsm.get(CFG, s, 3)
    assert not bool(found)
    # tombstone persists across flush
    s = lsm.flush(CFG, s)
    found, _, _ = lsm.get(CFG, s, 3)
    assert not bool(found)


def test_reinsert_after_delete():
    s = lsm.init(CFG)
    s = lsm.put(CFG, s, 3, row(9))
    s = lsm.delete(CFG, s, 3)
    s = lsm.put(CFG, s, 3, row(4))
    found, val, _ = lsm.get(CFG, s, 3)
    assert bool(found) and int(val[0]) == 4


def test_auto_flush_on_full_memtable():
    s = lsm.init(CFG)
    for k in range(CFG.mem_cap + 3):
        s = lsm.put(CFG, s, k, row(k))
    assert int(s.n_flushes) >= 1
    for k in range(CFG.mem_cap + 3):
        found, val, _ = lsm.get(CFG, s, k)
        assert bool(found), f"missing key {k}"
        assert int(val[0]) == k


def test_cascading_compaction_many_keys():
    s = lsm.init(CFG)
    n = CFG.level_caps[0] * 2  # force L0 -> L1 merges
    put = jax.jit(lambda st, k, v: lsm.put(CFG, st, k, v))
    for k in range(n):
        s = put(s, k, row(k % 100))
    assert int(s.n_compactions) >= 1
    for k in range(0, n, 7):
        found, val, _ = lsm.get(CFG, s, k)
        assert bool(found)
        assert int(val[0]) == k % 100


def test_bulk_load_then_get():
    keys = jnp.array([9, 4, 6, 1], jnp.int32)
    vals = jnp.stack([row(90), row(40), row(60), row(10)])
    s = lsm.bulk_load(CFG, keys, vals)
    for k, v in [(9, 90), (4, 40), (6, 60), (1, 10)]:
        found, val, _ = lsm.get(CFG, s, k)
        assert bool(found) and int(val[0]) == v


def test_bulk_load_then_update():
    keys = jnp.arange(10, dtype=jnp.int32)
    vals = jnp.stack([row(i) for i in range(10)])
    s = lsm.bulk_load(CFG, keys, vals)
    s = lsm.put(CFG, s, 4, row(444))
    s = lsm.delete(CFG, s, 5)
    _, val, _ = lsm.get(CFG, s, 4)
    assert int(val[0]) == 444
    found, _, _ = lsm.get(CFG, s, 5)
    assert not bool(found)


def test_compact_all_drops_tombstones():
    s = lsm.init(CFG)
    for k in range(6):
        s = lsm.put(CFG, s, k, row(k))
    for k in range(3):
        s = lsm.delete(CFG, s, k)
    s = lsm.compact_all(CFG, s)
    # everything lives in the last level now; tombstones dropped
    assert int(s.level_counts[-1]) == 3
    for lvl in range(CFG.num_levels - 1):
        assert int(s.level_counts[lvl]) == 0
    for k in range(3):
        assert not bool(lsm.get(CFG, s, k)[0])
    for k in range(3, 6):
        assert bool(lsm.get(CFG, s, k)[0])


def test_remap_ids():
    s = lsm.init(CFG)
    s = lsm.put(CFG, s, 0, row(1, 2))
    s = lsm.put(CFG, s, 1, row(0, 2))
    s = lsm.put(CFG, s, 2, row(0, 1))
    perm = jnp.array([2, 0, 1], jnp.int32)  # 0->2, 1->0, 2->1
    s = lsm.remap_ids(CFG, s, perm)
    found, val, _ = lsm.get(CFG, s, 2)  # was node 0
    assert bool(found)
    np.testing.assert_array_equal(sorted(np.asarray(val)[:2]), [0, 1])


def test_get_batch_matches_get():
    s = lsm.init(CFG)
    for k in range(20):
        s = lsm.put(CFG, s, k * 3, row(k))
    keys = jnp.array([0, 3, 4, 57, 30], jnp.int32)
    f_b, v_b, _ = lsm.get_batch(CFG, s, keys)
    for i, k in enumerate(np.asarray(keys)):
        f, v, _ = lsm.get(CFG, s, int(k))
        assert bool(f_b[i]) == bool(f)
        np.testing.assert_array_equal(np.asarray(v_b[i]), np.asarray(v))


# ---------------------------------------------------------------------------
# property tests: the LSM tree behaves exactly like a python dict
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.lists(
    st.tuples(st.sampled_from(["put", "del"]),
              st.integers(min_value=0, max_value=30),
              st.integers(min_value=0, max_value=1000)),
    min_size=1, max_size=60))
def test_property_dict_equivalence(ops):
    cfg = lsm.LSMConfig(mem_cap=4, num_levels=3, fanout=3, row_width=2)
    s = lsm.init(cfg)
    model = {}
    for op, k, v in ops:
        if op == "put":
            s = lsm.put(cfg, s, k, jnp.array([v, v + 1], jnp.int32))
            model[k] = v
        else:
            s = lsm.delete(cfg, s, k)
            model.pop(k, None)
    for k in range(31):
        found, val, _ = lsm.get(cfg, s, k)
        if k in model:
            assert bool(found), f"key {k} should exist"
            assert int(val[0]) == model[k]
        else:
            assert not bool(found), f"key {k} should not exist"


@settings(max_examples=10, deadline=None)
@given(st.lists(
    st.tuples(st.integers(min_value=0, max_value=40),
              st.integers(min_value=0, max_value=99)),
    min_size=1, max_size=50))
def test_property_compaction_preserves_view(puts_list):
    cfg = lsm.LSMConfig(mem_cap=4, num_levels=3, fanout=3, row_width=2)
    s = lsm.init(cfg)
    model = {}
    for k, v in puts_list:
        s = lsm.put(cfg, s, k, jnp.array([v, 0], jnp.int32))
        model[k] = v
    s2 = lsm.compact_all(cfg, s)
    for k, v in model.items():
        found, val, _ = lsm.get(cfg, s2, k)
        assert bool(found) and int(val[0]) == v


def test_puts_bulk_newest_wins_and_overflow():
    """Bulk append: later entries win within a batch; chunks > mem_cap
    flush in between; point `get` sees the merged view."""
    s = lsm.init(CFG)
    n = CFG.mem_cap * 3 + 5          # forces several in-call flushes
    keys = jnp.asarray(np.arange(n) % 10, jnp.int32)
    vals = jnp.stack([row(i) for i in range(n)])
    s = lsm.puts(CFG, s, keys, vals)
    assert int(s.n_flushes) >= 2
    for k in range(10):
        last = max(i for i in range(n) if i % 10 == k)
        found, val, _ = lsm.get(CFG, s, k)
        assert bool(found) and int(val[0]) == last


def test_puts_lives_writes_tombstones():
    s = lsm.init(CFG)
    s = lsm.puts(CFG, s, jnp.array([1, 2], jnp.int32),
                 jnp.stack([row(10), row(20)]))
    s = lsm.puts(CFG, s, jnp.array([1], jnp.int32), jnp.stack([row(0)]),
                 lives=jnp.array([0], jnp.int8))
    assert not bool(lsm.get(CFG, s, 1)[0])
    assert bool(lsm.get(CFG, s, 2)[0])


@settings(max_examples=20, deadline=None)
@given(st.lists(
    st.tuples(st.integers(min_value=0, max_value=30),
              st.integers(min_value=0, max_value=999)),
    min_size=1, max_size=80),
    st.integers(min_value=1, max_value=9))
def test_property_puts_cascade_dict_equivalence(kvs, chunk):
    """Bulk `puts` in arbitrary chunk sizes (including > mem_cap, which
    triggers overflow flush/compaction mid-call) preserves newest-wins
    against a dict oracle."""
    cfg = lsm.LSMConfig(mem_cap=4, num_levels=3, fanout=3, row_width=2)
    s = lsm.init(cfg)
    model = {}
    for i in range(0, len(kvs), chunk):
        part = kvs[i:i + chunk]
        keys = jnp.asarray([k for k, _ in part], jnp.int32)
        vals = jnp.asarray([[v, v + 1] for _, v in part], jnp.int32)
        s = lsm.puts(cfg, s, keys, vals)
        model.update(part)
    for k in range(31):
        found, val, _ = lsm.get(cfg, s, k)
        if k in model:
            assert bool(found) and int(val[0]) == model[k]
        else:
            assert not bool(found)


@settings(max_examples=20, deadline=None)
@given(st.lists(
    st.tuples(st.sampled_from(["put", "del"]),
              st.integers(min_value=0, max_value=25),
              st.integers(min_value=0, max_value=999)),
    min_size=1, max_size=60))
def test_property_get_batch_matches_get_mixed_trace(ops):
    """`get_batch` agrees with per-key `get` (and the dict oracle) after
    an arbitrary interleaving of bulk puts and deletes."""
    cfg = lsm.LSMConfig(mem_cap=4, num_levels=3, fanout=3, row_width=2)
    s = lsm.init(cfg)
    model = {}
    for i in range(0, len(ops), 5):
        part = ops[i:i + 5]
        keys = jnp.asarray([k for _, k, _ in part], jnp.int32)
        vals = jnp.asarray([[v, v] for _, _, v in part], jnp.int32)
        lives = jnp.asarray([1 if op == "put" else 0 for op, _, _ in part],
                            jnp.int8)
        s = lsm.puts(cfg, s, keys, vals, lives=lives)
        for op, k, v in part:
            if op == "put":
                model[k] = v
            else:
                model.pop(k, None)
    probe = jnp.arange(26, dtype=jnp.int32)
    f_b, v_b, _ = lsm.get_batch(cfg, s, probe)
    for k in range(26):
        f, v, _ = lsm.get(cfg, s, k)
        assert bool(f_b[k]) == bool(f) == (k in model)
        np.testing.assert_array_equal(np.asarray(v_b[k]), np.asarray(v))
        if k in model:
            assert int(v_b[k][0]) == model[k]


def test_resolve_all_dense_view():
    cfg = lsm.LSMConfig(mem_cap=4, num_levels=2, fanout=4, row_width=2)
    s = lsm.init(cfg)
    s = lsm.put(cfg, s, 2, jnp.array([5, 6], jnp.int32))
    s = lsm.put(cfg, s, 0, jnp.array([1, 2], jnp.int32))
    s = lsm.put(cfg, s, 2, jnp.array([7, 8], jnp.int32))  # overwrite
    s = lsm.put(cfg, s, 3, jnp.array([9, 9], jnp.int32))
    s = lsm.delete(cfg, s, 0)
    live, rows = lsm.resolve_all(cfg, s, id_space=5)
    live = np.asarray(live)
    rows = np.asarray(rows)
    assert live[0] == 0 and live[2] == 1 and live[3] == 1 and live[1] == 0
    np.testing.assert_array_equal(rows[2], [7, 8])
