"""Tests: optimizer, schedules, compression, checkpointing, fault tolerance,
data pipeline."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # skips gracefully when absent

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data.synth import token_pipeline
from repro.ft import FailureInjector, RestartPolicy, run_with_restarts
from repro.optim import (
    adamw_init,
    adamw_update,
    compress_bf16,
    cosine_schedule,
    ef_int8_compress,
    ef_int8_decompress,
)
from repro.optim.compression import ef_init


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_decreases_quadratic_loss():
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    state = adamw_init(params)
    def loss(p):
        return jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(100):
        grads = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, grads, state, lr=0.05,
                                        weight_decay=0.0)
    assert float(loss(params)) < 0.1 * l0


def test_adamw_bf16_params_use_master():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = adamw_init(params)
    assert state.master is not None
    grads = {"w": jnp.full((4,), 1e-3, jnp.bfloat16)}
    p1, s1, _ = adamw_update(params, grads, state, lr=1e-4,
                             weight_decay=0.0)
    # master accumulates sub-bf16 updates
    assert not np.allclose(np.asarray(s1.master["w"]), 1.0)
    assert p1["w"].dtype == jnp.bfloat16


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros((3,))}
    state = adamw_init(params)
    grads = {"w": jnp.array([1e6, -1e6, 1e6])}
    _, _, gnorm = adamw_update(params, grads, state, lr=1e-3, clip_norm=1.0)
    assert float(gnorm) > 1e5  # reported pre-clip


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(s, peak_lr=1e-3, warmup=10, total=100))
           for s in range(100)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9
    assert abs(lrs[10] - 1e-3) < 1e-4
    assert lrs[-1] < 0.3 * 1e-3


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_bf16_compression_roundtrip_error_small():
    g = {"a": jnp.asarray(np.random.default_rng(0).normal(0, 1, (256,)),
                          jnp.float32)}
    c = compress_bf16(g)
    assert c["a"].dtype == jnp.bfloat16
    err = float(jnp.max(jnp.abs(c["a"].astype(jnp.float32) - g["a"])))
    assert err < 0.01


def test_ef_int8_error_feedback_converges():
    """Error feedback: accumulated compressed grads track the true sum."""
    rng = np.random.default_rng(1)
    g_true = {"w": jnp.asarray(rng.normal(0, 1, (128,)), jnp.float32)}
    ef = ef_init(g_true)
    total = np.zeros(128, np.float32)
    for _ in range(50):
        q, s, ef = ef_int8_compress(g_true, ef)
        total += np.asarray(ef_int8_decompress(q, s)["w"])
    expected = 50 * np.asarray(g_true["w"])
    rel = np.abs(total - expected) / (np.abs(expected) + 1e-3)
    assert float(rel.mean()) < 0.02


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    save_checkpoint(str(tmp_path), 7, tree, metadata={"cursor": 123})
    target = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    out, meta, step = restore_checkpoint(str(tmp_path), target)
    assert step == 7 and meta["cursor"] == 123
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))
    assert out["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_retention_and_latest(tmp_path):
    tree = {"a": jnp.zeros((2,))}
    for s in [1, 2, 3, 4, 5]:
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    assert latest_step(str(tmp_path)) == 5
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_00000004", "step_00000005"]


def test_checkpoint_atomicity_no_tmp_left(tmp_path):
    tree = {"a": jnp.zeros((2,))}
    save_checkpoint(str(tmp_path), 1, tree)
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"a": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"a": jnp.zeros((3,))})


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_restart_recovers_and_completes(tmp_path):
    """Training survives two injected node failures and reaches the exact
    same final state as an uninterrupted run (determinism after restart)."""
    policy = RestartPolicy(ckpt_dir=str(tmp_path / "ck"), ckpt_every=5,
                           max_restarts=5)

    def init_state():
        return {"x": jnp.zeros((), jnp.float32)}

    def step_fn(state, step):
        return {"x": state["x"] + float(step)}

    out = run_with_restarts(
        policy=policy, init_state=init_state, step_fn=step_fn,
        num_steps=23, injector=FailureInjector(fail_at=[7, 17]))
    assert out["restarts"] == 2
    assert out["resumed_from"] == [5, 15]
    assert float(out["state"]["x"]) == sum(range(23))


def test_restart_gives_up_after_max(tmp_path):
    from repro.ft import SimulatedFailure

    class AlwaysFail(FailureInjector):
        def check(self, step):
            if step in self.fail_at:       # permanent fault, never clears
                raise SimulatedFailure(f"hard failure at {step}")

    policy = RestartPolicy(ckpt_dir=str(tmp_path / "ck"), ckpt_every=100,
                           max_restarts=1)
    with pytest.raises(SimulatedFailure):
        run_with_restarts(policy=policy,
                          init_state=lambda: {"x": jnp.zeros(())},
                          step_fn=lambda s, t: s, num_steps=10,
                          injector=AlwaysFail(fail_at=[1]))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    a = list(zip(range(3), token_pipeline(2, 8, 100, seed=5)))
    b = token_pipeline(2, 8, 100, seed=5, start_step=2)
    t2a = a[2][1]
    t2b = next(b)
    np.testing.assert_array_equal(t2a[0], t2b[0])


def test_pipeline_hosts_disjoint():
    h0 = next(token_pipeline(4, 16, 1000, seed=1, host_id=0, num_hosts=2))
    h1 = next(token_pipeline(4, 16, 1000, seed=1, host_id=1, num_hosts=2))
    assert not np.array_equal(h0[0], h1[0])


def test_pipeline_labels_are_shifted_tokens():
    toks, labels = next(token_pipeline(2, 16, 50, seed=3))
    assert toks.shape == labels.shape == (2, 16)
    assert toks.min() >= 0 and toks.max() < 50


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=1000))
def test_property_pipeline_step_independent_of_history(step):
    """Batch at step t is a pure function of (seed, host, t)."""
    direct = next(token_pipeline(2, 8, 64, seed=9, start_step=step))
    it = token_pipeline(2, 8, 64, seed=9)
    for _ in range(step):
        next(it)
    walked = next(it)
    np.testing.assert_array_equal(direct[0], walked[0])
