"""Shared test config.

jax jit caches accumulate across the full suite (dozens of compiled model
graphs) and can exhaust the XLA CPU JIT's resources mid-run ("Failed to
materialize symbols" INTERNAL errors poisoning later tests).  Clearing
caches per test module keeps the single-process suite within budget.
"""

import gc

import jax
import pytest

from repro.core.sentinel import forbid_undeclared_sync


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    jax.clear_caches()
    gc.collect()


@pytest.fixture
def no_host_sync():
    """Runtime half of the repro-lint host-sync rule (DESIGN.md §14).

    Everything executed under this fixture runs with device→host
    syncs disallowed — including explicit `jax.device_get` — so the
    only way to materialize a device value is through one of the
    `repro.core.sentinel.declared_sync` scopes, which re-allow syncs
    for the handful of statically `# sync-ok`-annotated points.  A
    stray sync anywhere else raises `UndeclaredHostSyncError` with a
    traceback pointing at the offending call.

    Host→device is left unguarded: uploading query/insert payloads is
    inherent to serving, not a regression signal.
    """
    with forbid_undeclared_sync():
        yield
