"""Shared test config.

jax jit caches accumulate across the full suite (dozens of compiled model
graphs) and can exhaust the XLA CPU JIT's resources mid-run ("Failed to
materialize symbols" INTERNAL errors poisoning later tests).  Clearing
caches per test module keeps the single-process suite within budget.
"""

import gc

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    jax.clear_caches()
    gc.collect()
