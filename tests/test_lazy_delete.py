"""Tests for two-phase lazy deletion (DESIGN.md §9): tombstone
routability, background consolidation, no-op delete accounting, and
id-stability through the serving layer's reorder/consolidate cycle."""

import jax.numpy as jnp
import numpy as np

from repro.core import hnsw
from repro.core.index import LSMVecIndex, brute_force_knn, recall_at_k
from repro.data.synth import make_clustered_vectors
from repro.serve import MaintenancePolicy, ServeConfig, ServeEngine

CFG = hnsw.HNSWConfig(cap=2048, dim=32, M=12, M_up=6, num_upper=2,
                      ef_search=48, ef_construction=48, k=10,
                      rho=1.0, use_filter=False, lsm_mem_cap=128,
                      lsm_levels=2, lsm_fanout=8)
CFG_EAGER = CFG._replace(lazy_delete=False)


def make_data(n, seed=0):
    return make_clustered_vectors(n, dim=32, seed=seed, clusters=16)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# ---------------------------------------------------------------------------
# phase 1: tombstones are routable but never returnable
# ---------------------------------------------------------------------------

def test_lazy_delete_masks_results_without_graph_writes():
    data = make_data(512, seed=0)
    idx = LSMVecIndex.build(CFG, data)
    seq_before = int(idx.state.store.write_seq)
    victims = [3, 77, 200, 201, 499]
    idx.delete_batch(np.asarray(victims))
    # phase 1 is a pure tombstone-bit write: the LSM saw nothing
    assert int(idx.state.store.write_seq) == seq_before
    assert idx.size == 512 - len(victims)
    assert idx.n_tombstones == len(victims)
    ids = idx.search(data[victims], k=10).ids
    assert not (set(ids.flatten().tolist()) & set(victims)), \
        "tombstoned id returned"


def test_bridge_delete_keeps_graph_connected_before_consolidation():
    """Deleting the upper-layer skeleton (the graph's bridge/hub nodes)
    must not disconnect the bottom layer: tombstones stay routable, so
    recall over the remaining nodes is preserved pre-consolidation."""
    data = make_data(512, seed=1)
    idx = LSMVecIndex.build(CFG, data)
    # every node on layer >= 1 is a long-range bridge by construction
    bridges = np.flatnonzero(np.asarray(idx.state.levels) > 0).tolist()
    assert len(bridges) >= 20          # the instance has a real skeleton
    idx.delete_batch(np.asarray(bridges, np.int32))
    live = np.ones(512, bool)
    live[bridges] = False
    queries = make_data(32, seed=2)
    truth = brute_force_knn(jnp.asarray(data), jnp.asarray(queries), 10,
                            live=jnp.asarray(live))
    ids = idx.search(queries, k=10).ids
    assert not (set(ids.flatten().tolist()) & set(bridges))
    r = recall_at_k(ids, truth)
    assert r >= 0.75, f"bridge deletes disconnected the graph: {r:.3f}"


def test_lazy_recall_beats_eager_under_heavy_churn():
    data = make_data(512, seed=3)
    rng = np.random.default_rng(0)
    victims = rng.choice(512, 170, replace=False).astype(np.int32)
    live = np.ones(512, bool)
    live[victims] = False
    queries = make_data(24, seed=4)
    truth = brute_force_knn(jnp.asarray(data), jnp.asarray(queries), 10,
                            live=jnp.asarray(live))

    idx_l = LSMVecIndex.build(CFG, data)
    idx_l.delete_batch(victims)
    r_lazy = recall_at_k(idx_l.search(queries, k=10).ids, truth)

    idx_e = LSMVecIndex.build(CFG_EAGER, data)
    idx_e.delete_batch(victims)
    r_eager = recall_at_k(idx_e.search(queries, k=10).ids, truth)
    assert r_lazy >= r_eager, (r_lazy, r_eager)


# ---------------------------------------------------------------------------
# phase 2: consolidation reclaims slots and leaves a clean graph
# ---------------------------------------------------------------------------

def test_consolidate_reclaims_and_search_is_tombstone_free():
    data = make_data(512, seed=5)
    idx = LSMVecIndex.build(CFG, data)
    rng = np.random.default_rng(1)
    victims = rng.choice(512, 150, replace=False).astype(np.int32)
    idx.delete_batch(victims)
    assert idx.consolidate() == 150
    # clean state: no tombstones, levels retired, store holds live rows only
    assert idx.n_tombstones == 0
    assert not bool(jnp.any(idx.state.tombstone))
    lv = np.asarray(idx.state.levels)
    assert (lv[victims] == -1).all()
    assert idx.size == 362 and int((lv >= 0).sum()) == 362
    # no surviving row routes through a reclaimed id
    snap = np.asarray(idx.snapshot())
    assert not (set(snap[snap >= 0].tolist()) & set(victims.tolist()))
    live = np.ones(512, bool)
    live[victims] = False
    queries = make_data(24, seed=6)
    truth = brute_force_knn(jnp.asarray(data), jnp.asarray(queries), 10,
                            live=jnp.asarray(live))
    ids = idx.search(queries, k=10).ids
    assert not (set(ids.flatten().tolist()) & set(victims.tolist()))
    assert recall_at_k(ids, truth) >= 0.7


def test_consolidate_entry_repair_and_updates_after():
    data = make_data(256, seed=7)
    idx = LSMVecIndex.build(CFG, data)
    entry = int(idx.state.entry)
    idx.delete(entry)                   # tombstone the entry node itself
    ids = idx.search(data[entry][None, :], k=1).ids
    assert int(ids[0, 0]) != entry      # routable but not returnable
    idx.consolidate()
    assert int(idx.state.entry) != entry
    assert int(idx.state.levels[int(idx.state.entry)]) >= 0
    # the index keeps working: insert + exact self-search
    x = make_data(1, seed=8)[0] + 60.0
    nid = idx.insert(x)
    found = idx.search(x[None, :], k=1).ids
    assert int(found[0, 0]) == nid


def test_consolidate_on_clean_index_is_noop():
    data = make_data(128, seed=9)
    idx = LSMVecIndex.build(CFG, data)
    before = np.asarray(idx.snapshot())
    assert idx.consolidate() == 0       # no tombstones: nothing to do
    np.testing.assert_array_equal(np.asarray(idx.snapshot()), before)


# ---------------------------------------------------------------------------
# no-op delete accounting (never a silent graph write)
# ---------------------------------------------------------------------------

def test_double_delete_and_absent_id_are_counted_noops():
    data = make_data(256, seed=10)
    idx = LSMVecIndex.build(CFG, data)
    idx.delete(7)
    seq = int(idx.state.store.write_seq)
    size = idx.size
    idx.delete(7)          # already tombstoned
    idx.delete(1900)       # never inserted (inside cap)
    idx.delete_batch(np.asarray([7, 7, 2000], np.int32))
    assert idx.stats().delete_noops == 5
    assert idx.size == size
    assert idx.n_tombstones == 1
    assert int(idx.state.store.write_seq) == seq


def test_eager_double_delete_is_counted_noop_without_store_write():
    data = make_data(256, seed=11)
    idx = LSMVecIndex.build(CFG_EAGER, data)
    idx.delete(5)
    size = idx.size
    lv = np.asarray(idx.state.levels).copy()
    snap_before = np.asarray(idx.snapshot())
    idx.delete(5)          # double delete through the eager path
    idx.delete_batch(np.asarray([5, 1800], np.int32))
    assert idx.stats().delete_noops == 3
    assert idx.size == size
    np.testing.assert_array_equal(np.asarray(idx.state.levels), lv)
    # graph content untouched (the old path re-tombstoned the key)
    np.testing.assert_array_equal(np.asarray(idx.snapshot()), snap_before)


# ---------------------------------------------------------------------------
# serving layer: trigger, id-map contract, double-delete under coalescing
# ---------------------------------------------------------------------------

def test_serve_consolidation_trigger_and_id_stability():
    """Threshold-triggered consolidation + heat-triggered reorder must
    keep client-visible external ids stable: probes keep answering to
    the ids their inserts returned, reclaimed ids never reappear."""
    data = make_data(400, seed=12)
    idx = LSMVecIndex.build(CFG, data)
    pol = MaintenancePolicy(tombstone_ratio=None, consolidate_ratio=0.20,
                            heat_budget=1, check_every=1)
    eng = ServeEngine(idx, ServeConfig(query_batch=16, insert_batch=16,
                                       delete_batch=16, maintenance=pol),
                      clock=FakeClock())
    probe = data[37]
    ins_vec = make_data(1, seed=13)[0] + 50.0
    t_ins = eng.submit_insert(ins_vec)
    eng.drain()
    victims = list(range(100, 200))     # 100 of 401 -> ratio 0.25 >= 0.20
    for v in victims:
        eng.submit_delete(v)
    eng.drain()
    assert eng.maintenance.consolidations >= 1
    # the trigger fires mid-stream at the 0.20 ratio; deletes arriving
    # after the last check stay tombstoned until the next one
    assert eng.maintenance.slots_reclaimed + idx.n_tombstones \
        == len(victims)
    assert eng.maintenance.slots_reclaimed >= 80
    # reorder also ran (heat_budget=1): both id-map mechanisms composed
    t1 = eng.submit_query(probe)
    t2 = eng.submit_query(ins_vec)
    eng.drain()
    assert int(t1.result().ids[0]) == 37
    assert int(t2.result().ids[0]) == int(t_ins.result())
    returned = set(t1.result().ids.tolist()) | set(t2.result().ids.tolist())
    assert not (returned & set(victims)), "reclaimed external id returned"


def test_lazy_deletes_never_trigger_lsm_compaction():
    """Lazy deletes stage nothing in the LSM: the tombstone_ratio
    compact trigger must stay silent (a compact would rewrite every
    level to drop zero entries); consolidation covers them instead."""
    data = make_data(400, seed=19)
    idx = LSMVecIndex.build(CFG, data)
    pol = MaintenancePolicy(tombstone_ratio=0.10, consolidate_ratio=0.30,
                            heat_budget=None, check_every=1)
    eng = ServeEngine(idx, ServeConfig(delete_batch=16, maintenance=pol),
                      clock=FakeClock())
    for v in range(80):                 # 20% churn: under consolidate, but
        eng.submit_delete(v)            # far over the 0.10 compact ratio
    eng.drain()
    assert eng.maintenance.compactions == 0
    assert eng.maintenance.deletes_since_compact == 0
    assert idx.n_tombstones == 80


def test_serve_double_delete_under_coalescing_is_counted_noop():
    data = make_data(256, seed=14)
    idx = LSMVecIndex.build(CFG, data)
    eng = ServeEngine(idx, ServeConfig(
        delete_batch=8, strict_order=False,
        maintenance=MaintenancePolicy(tombstone_ratio=None,
                                      consolidate_ratio=None,
                                      heat_budget=None)),
        clock=FakeClock())
    t1 = eng.submit_delete(9)
    t2 = eng.submit_delete(9)           # coalesces into the same batch
    eng.drain()
    t3 = eng.submit_delete(9)           # and a later batch
    eng.drain()
    assert t1.result() is True
    assert t2.result() is False and t3.result() is False
    assert eng.metrics.delete_noops == 2
    assert eng.delete_noops == 2
    assert idx.size == 255


def test_delete_of_unallocated_ext_id_does_not_poison_it():
    """A delete of an in-range but not-yet-allocated external id is a
    counted no-op (the engine owns the ext↔int map and drops it host-
    side, never dispatching an unmapped id) and must NOT block the
    future legitimate delete of that id once an insert allocates it."""
    data = make_data(256, seed=17)
    idx = LSMVecIndex.build(CFG, data)
    eng = ServeEngine(idx, ServeConfig(
        insert_batch=8, delete_batch=8,
        maintenance=MaintenancePolicy(tombstone_ratio=None,
                                      consolidate_ratio=None,
                                      heat_budget=None)),
        clock=FakeClock())
    t0 = eng.submit_delete(256)          # not allocated yet
    eng.drain()
    assert t0.result() is False          # dropped as a counted no-op
    assert eng.delete_noops == 1 and idx.size == 256
    assert idx.stats().delete_noops == 0   # nothing reached the device
    t_ins = eng.submit_insert(make_data(1, seed=18)[0] + 40.0)
    eng.drain()
    assert t_ins.result() == 256         # the id is now live
    t1 = eng.submit_delete(256)          # ... and must be deletable
    eng.drain()
    assert t1.result() is True
    assert idx.size == 256 and idx.n_tombstones == 1


def test_search_stays_exactly_k_deep_under_tombstones():
    """ef >> k: even with many tombstones in the beam the returnable
    re-pack must still fill all k result slots."""
    data = make_data(512, seed=15)
    idx = LSMVecIndex.build(CFG, data)
    rng = np.random.default_rng(2)
    idx.delete_batch(rng.choice(512, 200, replace=False).astype(np.int32))
    res = idx.search(make_data(16, seed=16), k=10)
    ids, dists = res.ids, res.dists
    assert (ids >= 0).all(), "returnable re-pack under-filled the top-k"
    assert np.isfinite(dists).all()
    for row in dists:
        assert np.all(np.diff(row) >= -1e-5)   # still distance-sorted
