"""Self-tests for `tools.repro_lint` (DESIGN.md §14): each rule family
fires on a minimal known-bad fixture, stays quiet on the known-good
twin, suppression comments behave per spec — and the live repo lints
clean (the meta-test CI's `static-analysis` job re-checks)."""

import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from tools.repro_lint import RULES, lint_paths, lint_sources
from tools.repro_lint.registry import rule_names


def codes(report):
    return sorted(f.code for f in report.findings)


def lint_one(src, rules=None):
    return lint_sources({"m.py": src}, rules=rules)


# ---------------------------------------------------------------------------
# registry / driver
# ---------------------------------------------------------------------------

def test_all_rule_families_registered():
    assert set(rule_names()) == {"host-sync", "jit-discipline",
                                 "lock-discipline", "protocol"}
    for name in rule_names():
        assert callable(RULES[name])


def test_unknown_rule_rejected():
    with pytest.raises(KeyError):
        lint_one("x = 1", rules=["no-such-rule"])


def test_parse_error_is_a_finding():
    rep = lint_one("def broken(:\n")
    assert codes(rep) == ["PARSE"]


# ---------------------------------------------------------------------------
# host-sync (HS001/HS002) — the §8 zero-sync hot path
# ---------------------------------------------------------------------------

HOT = """
import jax
import jax.numpy as jnp
import numpy as np

class Engine:
    def pump(self):
        self._exec()

    def _exec(self):
{body}
"""


def hot(body):
    indented = "\n".join("        " + ln if ln else ""
                         for ln in body.splitlines())
    return HOT.format(body=indented)


def test_hs001_int_on_device_array():
    rep = lint_one(hot("x = jnp.sum(self.state.heat)\nreturn int(x)"),
                   rules=["host-sync"])
    assert codes(rep) == ["HS001"]


def test_hs001_np_asarray_and_item_and_device_get():
    rep = lint_one(hot(
        "x = jnp.arange(4)\n"
        "a = np.asarray(x)\n"
        "b = x.tolist()\n"
        "c = jax.device_get(x)"), rules=["host-sync"])
    assert codes(rep) == ["HS001", "HS001", "HS001"]


def test_hs001_branching_and_iteration_on_device_array():
    rep = lint_one(hot(
        "x = jnp.arange(4)\n"
        "if x > 0:\n"
        "    pass\n"
        "for v in x:\n"
        "    pass"), rules=["host-sync"])
    assert codes(rep) == ["HS001", "HS001"]


def test_hs_clean_when_not_reachable_from_pump():
    src = """
import jax.numpy as jnp
def offline_eval():
    return int(jnp.sum(jnp.arange(4)))
"""
    assert not lint_one(src, rules=["host-sync"]).findings


def test_hs_cleansing_and_identity_checks_do_not_taint():
    rep = lint_one(hot(
        "x = jnp.arange(4)\n"
        "if self._snap is None:\n"
        "    pass\n"
        "n = x.shape[0]\n"
        "for i in range(n):\n"
        "    pass"), rules=["host-sync"])
    assert not rep.findings


def test_hs002_per_element_loop_and_comprehension():
    rep = lint_one(hot(
        "ids = np.arange(8)\n"
        "out = []\n"
        "for e in ids:\n"
        "    out.append(int(e))\n"
        "out2 = [int(g) for g in ids]"), rules=["host-sync"])
    assert codes(rep) == ["HS002", "HS002"]


def test_sync_ok_suppresses_with_reason():
    rep = lint_one(hot(
        "x = jnp.sum(jnp.arange(4))\n"
        "return int(x)  # sync-ok: declared scalar accessor"),
        rules=["host-sync"])
    assert not rep.findings
    assert len(rep.suppressed) == 1


def test_sync_ok_without_reason_is_fatal():
    rep = lint_one(hot(
        "x = jnp.sum(jnp.arange(4))\n"
        "return int(x)  # sync-ok"), rules=["host-sync"])
    assert "SUP001" in codes(rep)


def test_unused_suppression_warns_but_passes():
    rep = lint_one("x = 1  # sync-ok: nothing here syncs\n",
                   rules=["host-sync"])
    assert not rep.failed
    assert any("unused" in w for w in rep.warnings)


# ---------------------------------------------------------------------------
# jit discipline (JD101-104) — donation + trace-cache hygiene
# ---------------------------------------------------------------------------

def test_jd101_use_after_donate():
    src = """
import jax
class A:
    def __init__(self, f):
        self._step_fn = jax.jit(f, donate_argnums=0)
    def bad(self, state):
        out = self._step_fn(state)
        return state
    def good(self, state):
        state = self._step_fn(state)
        return state
"""
    rep = lint_one(src, rules=["jit-discipline"])
    assert codes(rep) == ["JD101"]
    assert rep.findings[0].line == 8        # the re-read, not the call


def test_jd101_partial_jit_form_and_self_attr_buffer():
    src = """
import functools
import jax
class A:
    def __init__(self, f):
        self._fn = functools.partial(jax.jit, donate_argnums=(0,))(f)
    def bad(self):
        out = self._fn(self.state)
        return self.state.count
    def good(self):
        self.state = self._fn(self.state)
        return self.state.count
"""
    rep = lint_one(src, rules=["jit-discipline"])
    assert codes(rep) == ["JD101"]


def test_jd102_dynamic_static_argnames():
    src = """
import jax
names = tuple(sorted(["a", "b"]))
f1 = jax.jit(lambda x: x, static_argnames=names)
f2 = jax.jit(lambda x: x, static_argnames=("rho", "ef"))
"""
    rep = lint_one(src, rules=["jit-discipline"])
    assert codes(rep) == ["JD102"]


def test_jd103_jit_built_in_loop():
    src = """
import jax
fns = []
for k in range(4):
    fns.append(jax.jit(lambda x: x + 1))
"""
    rep = lint_one(src, rules=["jit-discipline"])
    assert codes(rep) == ["JD103"]


def test_jd103_kernel_ops_entry_point_is_hot():
    """Top-level functions of kernels/*/ops.py are JD103 roots: a jit
    built inside a dispatch shim retraces under every serving call."""
    src = """
import jax

def dispatch(x, use_pallas=None):
    fn = jax.jit(lambda v: v * 2)
    return fn(x)
"""
    rep = lint_sources({"kernels/beam/ops.py": src},
                       rules=["jit-discipline"])
    assert codes(rep) == ["JD103"]
    # the identical body outside a kernel ops module is not hot
    rep = lint_sources({"helpers.py": src}, rules=["jit-discipline"])
    assert codes(rep) == []


def test_jd103_kernel_ops_module_scope_handle_clean():
    src = """
import functools

import jax


@functools.partial(jax.jit, static_argnames=("k",))
def dispatch(x, *, k):
    return x[:k]


def _on_tpu():
    return jax.default_backend() == "tpu"
"""
    rep = lint_sources({"kernels/gather_l2/ops.py": src},
                       rules=["jit-discipline"])
    assert codes(rep) == []


def test_jd104_aliased_donated_buffer():
    src = """
import jax
class A:
    def __init__(self, f):
        self._fn = jax.jit(f, donate_argnums=0)
    def bad(self, state):
        state = self._fn(state, state)
        return state
"""
    rep = lint_one(src, rules=["jit-discipline"])
    assert codes(rep) == ["JD104"]


def test_jd_clean_on_init_constructed_handles():
    src = """
import jax
class A:
    def __init__(self, f):
        self._fn = jax.jit(f, donate_argnums=0,
                           static_argnames=("ef",))
    def step(self, state, ef):
        state = self._fn(state, ef=ef)
        return state
"""
    assert not lint_one(src, rules=["jit-discipline"]).findings


# ---------------------------------------------------------------------------
# lock discipline (LK201/LK202) — the scheduler's guarded-by contract
# ---------------------------------------------------------------------------

LOCKED = """
import threading
_GUARDED_BY = {{"_lock": ("queue",), "_pump_lock": ("acks",)}}
_LOCK_ORDER = ("_pump_lock", "_lock")
class E:
    def __init__(self):
        self._lock = threading.RLock()
        self._pump_lock = threading.RLock()
        self.queue = []
        self.acks = []
{body}
"""


def test_lk201_unguarded_access_and_lk202_inversion():
    rep = lint_one(LOCKED.format(body="""
    def bad(self):
        self.queue.append(1)
        with self._lock:
            with self._pump_lock:
                self.acks.append(2)
"""), rules=["lock-discipline"])
    assert codes(rep) == ["LK201", "LK202"]


def test_lk_clean_with_correct_nesting():
    rep = lint_one(LOCKED.format(body="""
    def good(self):
        with self._pump_lock:
            with self._lock:
                self.queue.append(1)
            self.acks.append(2)
"""), rules=["lock-discipline"])
    assert not rep.findings


def test_lk_private_helper_inherits_callers_locks():
    rep = lint_one(LOCKED.format(body="""
    def _helper(self):
        self.acks.append(1)
    def entry(self):
        with self._pump_lock:
            self._helper()
"""), rules=["lock-discipline"])
    assert not rep.findings


def test_lk_private_helper_with_one_unlocked_caller_flagged():
    rep = lint_one(LOCKED.format(body="""
    def _helper(self):
        self.acks.append(1)
    def entry(self):
        with self._pump_lock:
            self._helper()
    def entry2(self):
        self._helper()
"""), rules=["lock-discipline"])
    assert codes(rep) == ["LK201"]


def test_lk_nested_function_body_runs_unlocked():
    rep = lint_one(LOCKED.format(body="""
    def entry(self):
        with self._pump_lock:
            def later():
                self.acks.append(1)
            return later
"""), rules=["lock-discipline"])
    assert codes(rep) == ["LK201"]


def test_lk_def_line_block_suppression():
    rep = lint_one(LOCKED.format(body="""
    def _replay(self):  # lint-ok[LK201]: single-threaded recovery
        self.acks.append(1)
        self.queue.append(2)
"""), rules=["lock-discipline"])
    assert not rep.findings
    assert len(rep.suppressed) == 2


# ---------------------------------------------------------------------------
# protocol conformance (PC001-003)
# ---------------------------------------------------------------------------

PROTO = """
from typing import Protocol
class VectorBackend(Protocol):
    def search(self): ...
    def dispatch_search(self): ...
    def insert_batch(self): ...
    def delete_batch(self): ...
    def maintain(self): ...
    def stats(self): ...
"""


def test_pc001_near_implementation_missing_methods():
    src = PROTO + """
class AlmostBackend:
    def search(self): ...
    def dispatch_search(self): ...
    def insert_batch(self): ...
    def delete_batch(self): ...
class TinyBaseline:
    def search(self): ...
"""
    rep = lint_one(src, rules=["protocol"])
    assert codes(rep) == ["PC001"]
    assert "AlmostBackend" in rep.findings[0].message
    assert "maintain" in rep.findings[0].message


def test_pc001_init_attributes_satisfy_contract():
    src = PROTO + """
class Full:
    def __init__(self):
        self.stats = None
    def search(self): ...
    def dispatch_search(self): ...
    def insert_batch(self): ...
    def delete_batch(self): ...
    def maintain(self): ...
"""
    assert not lint_one(src, rules=["protocol"]).findings


def test_pc002_double_collect():
    src = """
def f(backend, qs):
    h = backend.dispatch_search(qs)
    a = h.collect()
    b = h.collect()
    return a, b
"""
    rep = lint_one(src, rules=["protocol"])
    assert codes(rep) == ["PC002"]


def test_pc002_exclusive_branches_and_loops_ok():
    src = """
def f(backend, qs, flag, handles):
    h = backend.dispatch_search(qs)
    if flag:
        r = h.collect()
    else:
        r = h.collect()
    out = []
    for hh in handles:
        hh = backend.dispatch_search(qs)
        out.append(hh.collect())
    return r, out
"""
    assert not lint_one(src, rules=["protocol"]).findings


def test_pc002_collect_after_either_branch_flagged():
    src = """
def f(backend, qs, flag):
    h = backend.dispatch_search(qs)
    if flag:
        r = h.collect()
    return h.collect()
"""
    rep = lint_one(src, rules=["protocol"])
    assert codes(rep) == ["PC002"]


def test_pc003_unguarded_poll_maintain_result():
    src = """
def f(backend):
    rep = backend.poll_maintain()
    return rep.perm
"""
    rep = lint_one(src, rules=["protocol"])
    assert codes(rep) == ["PC003"]


def test_pc003_none_guard_forms_accepted():
    src = """
def early_return(backend):
    rep = backend.poll_maintain()
    if rep is None:
        return None
    return rep.perm

def truthy(backend):
    rep = backend.poll_maintain()
    if rep:
        return rep.perm

def short_circuit(backend):
    rep = backend.poll_maintain()
    return rep and rep.perm
"""
    assert not lint_one(src, rules=["protocol"]).findings


# ---------------------------------------------------------------------------
# CLI + meta
# ---------------------------------------------------------------------------

def test_cli_exit_codes_and_json(tmp_path):
    from tools.repro_lint.__main__ import main
    bad = tmp_path / "bad.py"
    bad.write_text("import jax\n"
                   "for k in range(2):\n"
                   "    f = jax.jit(lambda x: x)\n")
    good = tmp_path / "good.py"
    good.write_text("x = 1\n")
    out = tmp_path / "report.json"
    assert main([str(good)]) == 0
    assert main([str(bad), "--json", str(out)]) == 1
    import json
    data = json.loads(out.read_text())
    assert data["failed"] and data["findings"][0]["code"] == "JD103"
    assert main(["--rules", "bogus", str(good)]) == 2


def test_live_repo_lints_clean():
    report = lint_paths(["src", "tests", "benchmarks"], root=str(REPO))
    assert not report.failed, "\n" + report.render()
