"""Sharded-vs-single-device parity suite (DESIGN.md §10).

The serve engine programs against the `VectorBackend` protocol; these
tests pin the contract that makes that safe:

- strict-mode serving over `ShardedBackend(n_shards=1)` is bit-parity
  with serving over a bare `LSMVecIndex` on the same stream;
- at 4 shards the same stream holds a recall floor vs single-device;
- churn under sharding: tombstone counts and consolidation are per
  shard, external ids stay stable through reorder + consolidate;
- adaptive batch shaping derives coalescing windows from the arrival
  EMA and exposes them in `ServeMetrics`.

The CI `serve-shard-smoke` job runs this file standalone under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so every shard
gets its own device; the suite itself never touches XLA_FLAGS (a
module-level mutation would silently change the device topology for
every other test collected in the same pytest run) — the routing,
merge, and id-map logic under test is device-count-independent, so it
also passes on a single device in the tier-1 run.
"""

import numpy as np
import pytest

from repro.core import (
    HNSWConfig,
    LSMVecIndex,
    SearchResult,
    UpdateResult,
    VectorBackend,
    brute_force_knn,
    recall_at_k,
)
from repro.core.backend import shard_of_seq
from repro.core.distributed import ShardedBackend
from repro.data.synth import make_clustered_vectors
from repro.serve import MaintenancePolicy, Op, ServeConfig, ServeEngine

CFG = HNSWConfig(cap=1024, dim=32, M=12, M_up=6, num_upper=2,
                 ef_search=48, ef_construction=48, k=10,
                 rho=1.0, use_filter=False, lsm_mem_cap=128,
                 lsm_levels=2, lsm_fanout=8)

NO_MAINT = MaintenancePolicy(tombstone_ratio=None, consolidate_ratio=None,
                             heat_budget=None)


def make_data(n, seed=0):
    return make_clustered_vectors(n, dim=32, seed=seed, clusters=16)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _stream(rng, base, fresh, n_ops, ins_ids):
    """(op, payload) mixed stream; deletes target live external ids."""
    stream = []
    live = list(range(len(base)))
    fi = 0
    for _ in range(n_ops):
        r = rng.random()
        if r < 0.7 or (r >= 0.85 and len(live) < 32):
            stream.append(("q", base[rng.integers(0, len(base))]))
        elif r < 0.85 and fi < len(fresh):
            stream.append(("i", fresh[fi]))
            fi += 1
        else:
            stream.append(("d", live.pop(rng.integers(0, len(live)))))
    return stream


def _drive(backend, stream, *, strict, caps=16):
    eng = ServeEngine(
        backend,
        ServeConfig(query_batch=caps, insert_batch=caps, delete_batch=caps,
                    strict_order=strict, query_window=0.0, insert_window=0.0,
                    delete_window=0.0, maintenance=NO_MAINT),
        clock=FakeClock())
    tickets = [(op, eng.submit_query(p) if op == "q" else
                eng.submit_insert(p) if op == "i" else
                eng.submit_delete(p)) for op, p in stream]
    eng.drain()
    return eng, tickets


# ---------------------------------------------------------------------------
# protocol + typed results
# ---------------------------------------------------------------------------

def test_both_backends_satisfy_the_protocol():
    base = make_data(96, seed=0)
    single = LSMVecIndex.build(CFG, base)
    sharded = ShardedBackend(CFG, 4).build(base)
    assert isinstance(single, VectorBackend)
    assert isinstance(sharded, VectorBackend)
    for b in (single, sharded):
        res = b.search(base[:3], k=5)
        assert isinstance(res, SearchResult)
        assert res.ids.shape == res.dists.shape == (3, 5)
        with pytest.raises(TypeError):
            ids, dists = res             # sequence compat is gone
        up = b.insert_batch(make_data(4, seed=1))
        assert isinstance(up, UpdateResult) and up.n_applied == 4
        assert b.delete_batch([int(up.ids[0])]).n_applied == 1
        st = b.stats()
        assert st.n_tombstones == 1 and len(st.shards) >= 1
        assert st.n_tombstones == sum(s.n_tombstones for s in st.shards)


def test_routing_is_deterministic_and_balanced():
    asg = np.asarray(shard_of_seq(np.arange(4096), 4))
    counts = np.bincount(asg, minlength=4)
    assert (counts > 4096 // 4 - 200).all(), counts   # no starved shard
    np.testing.assert_array_equal(
        asg, np.asarray(shard_of_seq(np.arange(4096), 4)))
    assert (np.asarray(shard_of_seq(np.arange(64), 1)) == 0).all()


# ---------------------------------------------------------------------------
# strict-mode parity: sharded(P=1) == single-device, bit for bit
# ---------------------------------------------------------------------------

def test_sharded1_strict_serving_bit_parity_with_single_device():
    base = make_data(512, seed=2)
    fresh = make_data(64, seed=3)
    rng = np.random.default_rng(11)
    stream = _stream(rng, base, fresh, 300, [])

    eng_s, tk_s = _drive(LSMVecIndex.build(CFG, base), stream, strict=True)
    eng_p, tk_p = _drive(ShardedBackend(CFG, 1).build(base), stream,
                         strict=True)

    assert eng_s.batch_log == eng_p.batch_log
    for (op_a, a), (op_b, b) in zip(tk_s, tk_p):
        ra, rb = a.result(), b.result()
        if op_a == "q":
            np.testing.assert_array_equal(ra.ids, rb.ids)
            np.testing.assert_array_equal(ra.dists, rb.dists)
        else:
            assert ra == rb                 # ext ids / delete outcomes


def test_sharded4_same_stream_recall_floor():
    # 4 shards over 1024 rows = 256 nodes/shard: the per-shard scale the
    # serve_load sharded smoke also uses.  (Far smaller shards lose
    # navigability in the bulk-built graph itself — a bulk_build
    # property, not a sharding one.)
    base = make_data(1024, seed=4)
    fresh = make_data(64, seed=5)
    rng = np.random.default_rng(12)
    stream = _stream(rng, base, fresh, 300, [])
    queries = make_data(32, seed=6)

    results = {}
    for name, backend in (("single",
                           LSMVecIndex.build(CFG._replace(cap=2048), base)),
                          ("sharded", ShardedBackend(CFG, 4).build(base))):
        eng, tickets = _drive(backend, stream, strict=True)
        n_ins = sum(1 for op, _ in stream if op == "i")
        dels = [p for op, p in stream if op == "d"]
        tq = [eng.submit_query(q) for q in queries]
        eng.drain()
        found = np.stack([t.result().ids for t in tq])
        allv = np.concatenate([base, fresh[:n_ins]])
        live = np.ones(len(allv), bool)
        live[dels] = False
        truth = brute_force_knn(allv, queries, 10, live=live)
        results[name] = recall_at_k(found, truth)
    assert results["sharded"] >= 0.95 * results["single"], results
    assert results["sharded"] >= 0.7


# ---------------------------------------------------------------------------
# churn under sharding: per-shard tombstones + consolidation
# ---------------------------------------------------------------------------

def test_churn_under_sharding_tombstones_and_consolidation_per_shard():
    base = make_data(512, seed=7)
    backend = ShardedBackend(CFG, 4).build(base)
    pol = MaintenancePolicy(tombstone_ratio=None, consolidate_ratio=0.25,
                            heat_budget=None, check_every=4)
    eng = ServeEngine(backend, ServeConfig(delete_batch=16, maintenance=pol),
                      clock=FakeClock())
    rng = np.random.default_rng(13)
    victims = rng.choice(512, 220, replace=False)
    for v in victims:
        eng.submit_delete(int(v))
    eng.drain()
    st = backend.stats()
    # every tombstone is accounted per shard; consolidated slots +
    # still-pending tombstones cover the whole victim set
    assert st.n_tombstones == sum(s.n_tombstones for s in st.shards)
    assert eng.maintenance.slots_reclaimed + st.n_tombstones == len(victims)
    assert eng.maintenance.consolidations >= 1
    assert sum(backend.consolidations) >= 1     # per-shard log
    # per-shard trigger: no shard may sit far over the ratio post-drain
    # (deletes arriving after the last check stay tombstoned until the
    # next one — bounded by check_every * delete_batch per shard)
    for s in st.shards:
        assert s.n_tombstones <= pol.check_every * 16
    # deleted ext ids never return
    tq = [eng.submit_query(base[int(v)]) for v in victims[:16]]
    eng.drain()
    returned = set(int(i) for t in tq for i in t.result().ids)
    assert not (returned & set(int(v) for v in victims))


def test_sharded_reorder_keeps_external_ids_stable():
    base = make_data(400, seed=8)
    backend = ShardedBackend(CFG, 2).build(base)
    pol = MaintenancePolicy(tombstone_ratio=None, consolidate_ratio=None,
                            heat_budget=1, check_every=1)
    eng = ServeEngine(backend, ServeConfig(query_batch=16, insert_batch=16,
                                           delete_batch=16, maintenance=pol),
                      clock=FakeClock())
    probe = base[37]
    t0 = eng.submit_query(probe)
    eng.drain()
    assert int(t0.result().ids[0]) == 37
    x = make_data(1, seed=9)[0] + 50.0
    t_ins = eng.submit_insert(x)
    eng.drain()
    assert eng.maintenance.reorders >= 1
    perm = eng.maintenance.last_perm
    assert perm is not None and len(perm) == backend.cap
    assert not np.array_equal(perm, np.arange(len(perm)))
    t1 = eng.submit_query(probe)
    t2 = eng.submit_query(x)
    eng.drain()
    assert int(t1.result().ids[0]) == 37
    assert int(t2.result().ids[0]) == int(t_ins.result())
    eng.submit_delete(37)
    t3 = eng.submit_query(probe)
    eng.drain()
    assert int(t3.result().ids[0]) != 37


# ---------------------------------------------------------------------------
# adaptive batch shaping (Quake-style windows from the arrival EMA)
# ---------------------------------------------------------------------------

def test_adaptive_windows_track_arrival_rate():
    base = make_data(256, seed=10)
    idx = LSMVecIndex.build(CFG, base)
    clock = FakeClock()
    cfg = ServeConfig(query_batch=8, query_window=0.01,
                      adaptive_windows=True, window_min=0.0,
                      window_max=0.02, window_fill=0.5, window_alpha=0.2,
                      maintenance=NO_MAINT)
    eng = ServeEngine(idx, cfg, clock=clock)
    # steady 1 ms inter-arrival gap: EMA converges to the gap itself
    for i in range(12):
        eng.submit_query(base[i])
        clock.t += 0.001
    eng.drain()
    w_slow = eng.metrics.windows[Op.QUERY]
    # expected: fill * cap * gap = 0.5 * 8 * 0.001 = 4 ms (clamped at 20)
    assert w_slow == pytest.approx(0.004, rel=0.2)
    # 20x faster arrivals shrink the window toward zero
    for i in range(40):
        eng.submit_query(base[i % 200])
        clock.t += 0.00005
    eng.drain()
    w_fast = eng.metrics.windows[Op.QUERY]
    assert w_fast < w_slow / 4
    # chosen windows surface in the metrics snapshot
    snap = eng.metrics.snapshot()
    assert snap["query"]["window_ms"] == pytest.approx(w_fast * 1e3,
                                                       abs=1e-3)


def test_adaptive_window_actually_gates_release():
    base = make_data(128, seed=11)
    idx = LSMVecIndex.build(CFG, base)
    clock = FakeClock()
    eng = ServeEngine(idx,
                      ServeConfig(query_batch=8, query_window=0.5,
                                  adaptive_windows=True, window_min=0.002,
                                  window_max=0.02, maintenance=NO_MAINT),
                      clock=clock)
    # establish a 1 ms arrival EMA -> window 0.5*8*0.001 = 4 ms
    for i in range(10):
        eng.submit_query(base[i])
        clock.t += 0.001
    eng.drain()
    # one lone query: held while the adaptive window is open ...
    eng.submit_query(base[0])
    assert eng.pump() is None
    # ... and released once its age crosses the chosen window
    clock.t += eng.metrics.windows[Op.QUERY] + 1e-4
    assert eng.pump() is Op.QUERY


# ---------------------------------------------------------------------------
# delete_noops: one stats surface, no drift
# ---------------------------------------------------------------------------

def test_delete_noops_single_surface():
    base = make_data(256, seed=12)
    idx = LSMVecIndex.build(CFG, base)
    eng = ServeEngine(idx, ServeConfig(delete_batch=8, maintenance=NO_MAINT),
                      clock=FakeClock())
    # device-side no-op: tombstone id 5 behind the engine's back, then
    # delete it through the engine (engine map says allocated+fresh)
    idx.delete(5)
    eng.submit_delete(5)
    # host-side no-ops: a repeat and an unallocated ext id
    eng.submit_delete(5)
    eng.submit_delete(900)
    eng.drain()
    st = idx.stats()
    assert st.delete_noops == 1          # the device count
    assert eng.metrics.delete_noops == 2  # the host count
    assert eng.delete_noops == 3          # the one combined accessor
