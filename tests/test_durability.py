"""Durability-spine tests (DESIGN.md §11): checkpoint atomicity and
dtype round-trips, backend save/restore bit-exactness at shards=1 and
shards=4, engine WAL recovery, group-commit ack deferral, and the
crash-recovery matrix over every injection point."""

import os

import jax
import numpy as np
import pytest

from repro.checkpoint import (
    latest_step,
    load_arrays,
    restore_checkpoint,
    save_checkpoint,
    sweep_stale_tmp,
)
from repro.core import hnsw
from repro.core.distributed import ShardedBackend
from repro.core.index import LSMVecIndex
from repro.ft import (
    FailureInjector,
    RestartPolicy,
    SimulatedFailure,
    run_with_recovery,
    run_with_restarts,
    verify_acked_writes,
)
from repro.serve import MaintenancePolicy, ServeConfig, ServeEngine, WalConfig

CFG = hnsw.HNSWConfig(cap=2048, dim=16, M=8, M_up=4, num_upper=2,
                      ef_search=32, ef_construction=32, k=10,
                      rho=1.0, use_filter=False, lsm_mem_cap=64,
                      lsm_levels=2, lsm_fanout=8)


def _vecs(n, seed=0):
    return np.random.default_rng(seed).standard_normal(
        (n, CFG.dim)).astype(np.float32)


def _trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# ckpt.py: non-native dtypes, stale-tmp sweep, mid-save atomicity
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrips_bf16_via_stored_as(tmp_path):
    import ml_dtypes
    import jax.numpy as jnp
    tree = {"w": jnp.arange(8, dtype=jnp.bfloat16) / 3,
            "b": jnp.ones((3,), jnp.float32)}
    save_checkpoint(str(tmp_path), 1, tree)
    got, _, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 1
    assert got["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(got["w"]).view(np.uint16),
        np.asarray(tree["w"]).view(np.uint16))    # bit-exact, not approx
    # and the target-free loader sees the same bits
    arrays, _, _ = load_arrays(str(tmp_path))
    assert arrays["w"].dtype == ml_dtypes.bfloat16
    np.testing.assert_array_equal(arrays["w"].view(np.uint16),
                                  np.asarray(tree["w"]).view(np.uint16))


def test_stale_tmp_dirs_are_swept_and_never_shadow(tmp_path):
    d = str(tmp_path)
    os.makedirs(os.path.join(d, "step_00000007.tmp"))  # crashed save
    assert latest_step(d) is None                      # never shadows
    assert sweep_stale_tmp(d) == 1
    assert not os.path.exists(os.path.join(d, "step_00000007.tmp"))
    # a save at the same step as a leftover tmp does not trip over it
    os.makedirs(os.path.join(d, "step_00000003.tmp"))
    save_checkpoint(d, 3, {"x": np.arange(4)})
    assert latest_step(d) == 3


def test_crash_before_publish_leaves_previous_checkpoint(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, {"x": np.arange(4)})

    def boom():
        raise SimulatedFailure("mid_checkpoint")

    with pytest.raises(SimulatedFailure):
        save_checkpoint(d, 2, {"x": np.arange(4) + 1}, _pre_publish=boom)
    # the torn save is invisible: latest is still step 1, with its data
    assert latest_step(d) == 1
    arrays, _, _ = load_arrays(d)
    np.testing.assert_array_equal(arrays["x"], np.arange(4))
    # and the next save sweeps the leftover stage and publishes fine
    save_checkpoint(d, 2, {"x": np.arange(4) + 1})
    assert latest_step(d) == 2


def test_retention_keeps_newest_k(tmp_path):
    d = str(tmp_path)
    for s in range(5):
        save_checkpoint(d, s, {"x": np.array([s])}, keep=2)
    steps = sorted(int(n[5:]) for n in os.listdir(d)
                   if n.startswith("step_") and not n.endswith(".tmp"))
    assert steps == [3, 4]


# ---------------------------------------------------------------------------
# backend save/restore: bit-exact at shards=1 and shards=4
# ---------------------------------------------------------------------------

def test_index_save_restore_bit_exact_and_search_parity(tmp_path):
    idx = LSMVecIndex(CFG, seed=3)
    idx.insert_batch(_vecs(300))
    idx.delete_batch(np.arange(20))
    idx.save(str(tmp_path), lsn=5,
             extra={"m": np.arange(4, dtype=np.int64)}, meta={"next_ext": 300})

    idx2, md, extras = LSMVecIndex.restore(CFG, str(tmp_path))
    assert md["lsn"] == 5 and md["next_ext"] == 300
    np.testing.assert_array_equal(extras["m"], np.arange(4))
    assert _trees_equal(idx.state, idx2.state)

    # restored RNG stream: the next insert batch lands bit-identically
    xs = _vecs(40, seed=9)
    idx.insert_batch(xs)
    idx2.insert_batch(xs)
    assert _trees_equal(idx.state, idx2.state)

    q = _vecs(16, seed=11)
    np.testing.assert_array_equal(np.asarray(idx.search(q).ids),
                                  np.asarray(idx2.search(q).ids))


def test_index_restore_refuses_config_mismatch(tmp_path):
    idx = LSMVecIndex(CFG, seed=0)
    idx.insert_batch(_vecs(80))
    idx.save(str(tmp_path), lsn=1)
    with pytest.raises(ValueError, match="cap/dim"):
        LSMVecIndex.restore(CFG._replace(dim=32), str(tmp_path))
    with pytest.raises(ValueError):
        LSMVecIndex.restore(CFG._replace(M=CFG.M * 2), str(tmp_path))


def test_sharded_save_restore_bit_exact_and_layout_guard(tmp_path):
    cfg = CFG._replace(cap=512)
    be = ShardedBackend(cfg, 4, seed=7).build(_vecs(300), seed=7)
    be.insert_batch(_vecs(40, seed=5))
    be.delete_batch(np.asarray(be.initial_ids()[:25]))
    be.save(str(tmp_path), lsn=3, meta={"next_ext": 340})

    be2, md, _ = ShardedBackend.restore(cfg, str(tmp_path), n_shards=4)
    assert md["lsn"] == 3 and md["next_ext"] == 340
    assert be2._n_routed == be._n_routed and be2._alloc == be._alloc
    for a, b in zip(be.shards, be2.shards):
        assert _trees_equal(a.state, b.state)
    q = _vecs(16, seed=13)
    np.testing.assert_array_equal(np.asarray(be.search(q).ids),
                                  np.asarray(be2.search(q).ids))
    # routing state restored: the next insert routes identically
    xs = _vecs(16, seed=17)
    np.testing.assert_array_equal(np.asarray(be.insert_batch(xs).ids),
                                  np.asarray(be2.insert_batch(xs).ids))

    with pytest.raises(ValueError, match="shards"):
        ShardedBackend.restore(cfg, str(tmp_path), n_shards=2)
    with pytest.raises(ValueError, match="cap/dim"):
        ShardedBackend.restore(cfg._replace(dim=32), str(tmp_path))


# ---------------------------------------------------------------------------
# engine-level durability
# ---------------------------------------------------------------------------

def _serve_cfg(tmp_path, **kw):
    maint = kw.pop("maintenance", MaintenancePolicy(checkpoint_every=4))
    return ServeConfig(
        query_batch=8, insert_batch=8, delete_batch=8,
        adaptive_windows=False, query_window=0.0, insert_window=0.0,
        delete_window=0.0,
        wal=WalConfig(dir=str(tmp_path / "wal"), **kw),
        ckpt_dir=str(tmp_path / "ckpt"), maintenance=maint)


def _recover(tmp_path, injector=None, **kw):
    return ServeEngine.recover(
        _serve_cfg(tmp_path, **kw),
        fresh_backend=lambda: LSMVecIndex(CFG, seed=1),
        restore_backend=lambda d: LSMVecIndex.restore(CFG, d),
        injector=injector)


def _mixed_ops(n, seed=0):
    rng = np.random.default_rng(seed)
    ops, n_ins = [], 0
    for _ in range(n):
        r = rng.random()
        if r < 0.7 or n_ins < 5:
            ops.append(("insert", rng.standard_normal(CFG.dim)
                        .astype(np.float32)))
            n_ins += 1
        elif r < 0.85:
            ops.append(("delete", int(rng.integers(0, n_ins))))
        else:
            ops.append(("query", rng.standard_normal(CFG.dim)
                        .astype(np.float32)))
    return ops


def test_engine_recovery_is_bit_exact_without_crash(tmp_path):
    """Kill-free baseline: an engine rebuilt from its checkpoint + WAL
    tail must hold bit-identical backend state to the one it replaced —
    the checkpoint-covered prefix restores exactly and the replayed
    tail re-executes through the same padded batch path."""
    eng = _recover(tmp_path)
    ids = []
    for x in _vecs(60, seed=2):
        ids.append(eng.submit_insert(x))
    for e in range(0, 10):
        eng.submit_delete(e)
    eng.drain()
    assert all(t.done for t in ids)
    assert eng.metrics.maintenance_runs["checkpoint"] >= 1

    eng2 = _recover(tmp_path)       # simulated process restart
    assert _trees_equal(eng.backend.state, eng2.backend.state)
    np.testing.assert_array_equal(eng._int2ext, eng2._int2ext)
    np.testing.assert_array_equal(eng._ext2int, eng2._ext2int)
    assert eng._deleted_ext == eng2._deleted_ext
    assert eng._next_ext == eng2._next_ext


def test_ack_implies_durable_replay(tmp_path):
    """Every resolved write ticket must survive a crash with no
    checkpoint at all (pure WAL replay from LSN 0)."""
    cfg = _serve_cfg(tmp_path,
                     maintenance=MaintenancePolicy(checkpoint_every=None))
    eng = ServeEngine.recover(
        cfg, fresh_backend=lambda: LSMVecIndex(CFG, seed=1),
        restore_backend=lambda d: LSMVecIndex.restore(CFG, d))
    tickets = [eng.submit_insert(x) for x in _vecs(30, seed=4)]
    del_t = eng.submit_delete(3)
    eng.drain()
    exts = [t.result() for t in tickets]
    assert del_t.result() is True

    eng2 = ServeEngine.recover(
        cfg, fresh_backend=lambda: LSMVecIndex(CFG, seed=1),
        restore_backend=lambda d: LSMVecIndex.restore(CFG, d))
    for e in exts:
        if e == 3:
            continue
        assert eng2.resolve_ext(e) >= 0
    assert eng2.is_deleted(3)
    assert _trees_equal(eng.backend.state, eng2.backend.state)


def test_group_commit_defers_acks_until_sync(tmp_path):
    cfg = _serve_cfg(tmp_path, group_commit_n=100,
                     maintenance=MaintenancePolicy(checkpoint_every=None))
    eng = ServeEngine(LSMVecIndex(CFG, seed=1), cfg)
    tickets = [eng.submit_insert(x) for x in _vecs(8, seed=6)]
    eng.pump(force=True)
    # batch executed but the commit threshold (100 records) not reached:
    # tickets stay pending — an ack may never precede its fsync
    assert not any(t.done for t in tickets)
    assert eng.wal.n_unsynced == 1
    eng.drain()                      # drain forces the group commit
    assert all(t.done for t in tickets)
    assert eng.wal.n_unsynced == 0
    assert eng.metrics.wal_commits == 1
    assert eng.metrics.wal_records == 1
    eng.close()


def test_checkpoint_truncates_covered_wal(tmp_path):
    eng = _recover(tmp_path)
    for x in _vecs(40, seed=8):
        eng.submit_insert(x)
    eng.drain()
    path = eng.checkpoint()
    if path is not None:             # cadence ckpt may already cover all
        assert os.path.isdir(path)
    assert eng._covering_lsn == eng.wal.last_lsn
    # every surviving WAL record is past the covering checkpoint
    assert eng.wal.records(after=eng._covering_lsn) == eng.wal.records()
    eng.close()


def test_acked_writes_survive_double_restart_after_covering_ckpt(tmp_path):
    """REVIEW.md high-severity regression: once a checkpoint covers LSN
    N and truncation leaves only the empty tail segment, two successive
    restarts must not reset LSN allocation — writes acked after the
    second restart would then carry LSNs <= N and be invisible to
    replay's records(after=N) cut."""
    eng = _recover(tmp_path)
    for x in _vecs(16, seed=20):
        eng.submit_insert(x)
    eng.drain()
    assert eng.checkpoint() is not None or eng._has_ckpt
    covering = eng._covering_lsn
    eng.close()

    eng2 = _recover(tmp_path)            # restart 1: nothing to replay
    assert eng2.wal.last_lsn == covering
    eng2.close()

    eng3 = _recover(tmp_path)            # restart 2: mark must persist
    assert eng3.wal.last_lsn == covering
    tickets = [eng3.submit_insert(x) for x in _vecs(8, seed=21)]
    eng3.drain()
    exts = [t.result() for t in tickets]
    eng3.close()

    eng4 = _recover(tmp_path)
    for e in exts:
        assert eng4.resolve_ext(e) >= 0, \
            f"acked insert ext={e} lost after double restart"
    eng4.close()


class _FlakyBackend:
    """Delegating wrapper whose first `fail_n` insert dispatches raise
    AFTER the engine has already logged the batch's WAL record."""

    def __init__(self, inner, fail_n=1):
        self._inner = inner
        self._fail_n = fail_n

    def insert_batch(self, *a, **kw):
        if self._fail_n > 0:
            self._fail_n -= 1
            raise RuntimeError("injected dispatch failure")
        return self._inner.insert_batch(*a, **kw)

    def __getattr__(self, name):
        return getattr(self._inner, name)


def test_failed_insert_dispatch_burns_logged_ext_ids(tmp_path):
    """A batch whose WAL record was appended but whose dispatch failed
    must burn its ext ids: the next batch may not re-log them (replay
    would otherwise apply both records and rebind the acked batch's
    ids to different gids)."""
    cfg = _serve_cfg(tmp_path,
                     maintenance=MaintenancePolicy(checkpoint_every=None))
    eng = ServeEngine(_FlakyBackend(LSMVecIndex(CFG, seed=1)), cfg)
    bad = [eng.submit_insert(x) for x in _vecs(8, seed=30)]
    with pytest.raises(RuntimeError, match="injected"):
        eng.drain()
    assert all(t.done for t in bad)
    for t in bad:
        with pytest.raises(RuntimeError):
            t.result()

    good = [eng.submit_insert(x) for x in _vecs(8, seed=31)]
    eng.drain()
    exts = [t.result() for t in good]
    assert min(exts) >= 8            # ids 0..7 burned with the orphan
    eng.close()

    # recovery replays both records; the orphan lands on its own ids
    # and every acked id still resolves
    eng2 = _recover(tmp_path,
                    maintenance=MaintenancePolicy(checkpoint_every=None))
    for e in exts:
        assert eng2.resolve_ext(e) >= 0, \
            f"acked insert ext={e} rebound by orphaned-record replay"
    eng2.close()


def test_no_wal_checkpoint_seq_resumes_after_recovery(tmp_path):
    """REVIEW.md: without a WAL, `_ckpt_seq` must resume from the
    restored checkpoint's step — a post-recovery checkpoint publishing
    step_1 under an existing step_N is silently shadowed forever."""
    cfg = ServeConfig(
        query_batch=8, insert_batch=8, delete_batch=8,
        adaptive_windows=False, query_window=0.0, insert_window=0.0,
        delete_window=0.0, wal=None, ckpt_dir=str(tmp_path / "ckpt"),
        maintenance=MaintenancePolicy(checkpoint_every=None))
    eng = ServeEngine(LSMVecIndex(CFG, seed=1), cfg)
    for x in _vecs(8, seed=40):
        eng.submit_insert(x)
    eng.drain()
    eng.checkpoint()
    eng.checkpoint()
    assert latest_step(cfg.ckpt_dir) == 2

    eng2 = ServeEngine.recover(
        cfg, fresh_backend=lambda: LSMVecIndex(CFG, seed=1),
        restore_backend=lambda d: LSMVecIndex.restore(CFG, d))
    for x in _vecs(8, seed=41):
        eng2.submit_insert(x)
    eng2.drain()
    eng2.checkpoint()
    assert latest_step(cfg.ckpt_dir) == 3   # was step_1, shadowed by 2


@pytest.mark.parametrize("point,hit", [
    ("pre_commit", 3),
    ("post_commit_pre_apply", 3),
    ("mid_checkpoint", 2),
    ("mid_consolidation", 1),
])
def test_crash_recovery_matrix_zero_acked_loss(tmp_path, point, hit):
    """The acceptance gate: kill at each injection point, restart,
    prove every acknowledged ticket survives — by id map and by search
    reachability — via the shared ft harness."""
    maint = MaintenancePolicy(checkpoint_every=4)
    if point == "mid_consolidation":
        # consolidation must actually trigger for the hook to fire
        maint = MaintenancePolicy(checkpoint_every=4, check_every=2,
                                  consolidate_ratio=0.05)
    policy = RestartPolicy(ckpt_dir=str(tmp_path / "ckpt"),
                           wal_dir=str(tmp_path / "wal"), max_restarts=5)
    injector = FailureInjector(fail_points={point: hit})
    ops = _mixed_ops(90, seed=3)
    out = run_with_recovery(
        policy=policy,
        make_engine=lambda inj: _recover(tmp_path, injector=inj,
                                         maintenance=maint),
        ops=ops, injector=injector, chunk=10)
    assert out["restarts"] >= 1, f"{point} never fired"
    summary = verify_acked_writes(out["engine"], ops, out["acked"])
    assert summary["live"] == summary["searched"] > 0


def test_restart_policy_requires_ckpt_dir():
    with pytest.raises(ValueError, match="ckpt_dir"):
        run_with_restarts(policy=RestartPolicy(), init_state=lambda: 0,
                          step_fn=lambda s, i: s, num_steps=1)
