"""Parity suite for the fused beam-search megakernel (DESIGN.md §15).

Three implementations of the bottom-layer beam search must agree
bit-for-bit at every config point:

  1. `traversal.beam_search` — the per-query `while_loop` path the
     index has always served from (ground truth);
  2. `beam.ref.beam_search_ref` — the fused pure-JAX oracle;
  3. `beam.kernel.beam_search_fused_pallas` — the Pallas megakernel,
     run here in interpret mode (TPU is the compile target).

Bit-exactness (not allclose) is achievable because the fixtures use
integer-valued vectors: squared L2 sums stay below 2^24 so f32
accumulation is exact regardless of reduction order.  One float test
keeps an allclose guard on realistic data.  The matrix covers the
acceptance axes: tombstone churn (returnable), tier-mixed lanes
(resident / qvecs / qscale), ef/M sweep, all-filtered frontiers,
n_expand > 1, and masked pad lanes — plus index- and serve-level
fused-vs-while parity and zero-retrace checks.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HNSWConfig, LSMVecIndex, simhash, traversal
from repro.core.backend import SearchParams
from repro.core.hnsw import _snapshot_adj_fn
from repro.kernels.beam.kernel import beam_search_fused_pallas
from repro.kernels.beam.ref import beam_search_ref
from repro.kernels.gather_l2.ops import gather_l2, gather_l2_q8
from repro.tier.quant import quantize_rows

EPS = 0.1


def _world(seed=0, cap=64, dim=16, M=6, Bq=5, m_bits=64, dead=0.1,
           tomb=0.2):
    """Dense integer-valued operand set shared by the op-level tests."""
    rng = np.random.default_rng(seed)
    vectors = jnp.asarray(rng.integers(-8, 8, (cap, dim)).astype(np.float32))
    adjacency = jnp.asarray(rng.integers(-1, cap, (cap, M)).astype(np.int32))
    proj = jax.random.normal(jax.random.key(seed + 1), (m_bits, dim),
                             jnp.float32)
    params = simhash.SimHashParams(proj)
    codes = simhash.encode(params, vectors)
    live = jnp.asarray(rng.random(cap) >= dead)
    qs = jnp.asarray(rng.integers(-8, 8, (Bq, dim)).astype(np.float32))
    code_qs = jax.vmap(lambda q: simhash.encode(params, q[None, :])[0])(qs)
    q_norms = jax.vmap(lambda q: jnp.sqrt(jnp.sum(q * q)))(qs)
    mean_norm = jnp.float32(np.sqrt(dim) * 4.0)
    entries = jnp.asarray(rng.integers(0, cap, (Bq,)).astype(np.int32))
    entry_ds = jax.vmap(lambda q, e: jnp.sum((q - vectors[e]) ** 2))(
        qs, entries)
    returnable = live & jnp.asarray(rng.random(cap) >= tomb)
    return dict(cap=cap, dim=dim, M=M, m_bits=m_bits, vectors=vectors,
                adjacency=adjacency, codes=codes, live=live, qs=qs,
                code_qs=code_qs, q_norms=q_norms, mean_norm=mean_norm,
                entries=entries, entry_ds=entry_ds, returnable=returnable)


def _while_loop_path(w, *, ef, k, rho, use_filter, n_expand,
                     returnable=None, dist_fn=None):
    """vmapped `traversal.beam_search` over the dense world — the
    ground-truth serving semantics (snapshot adjacency, fused gather)."""
    def one(q, e, ed, cq, qn):
        df = (lambda ids: gather_l2(q[None, :], w["vectors"],
                                    ids[None, :])[0]) \
            if dist_fn is None else dist_fn(q)
        return traversal.beam_search(
            q, e, ed, _snapshot_adj_fn(w["adjacency"]), df,
            w["codes"], cq, w["live"], cap=w["cap"], ef=ef, k=k,
            m_bits=w["m_bits"], eps=EPS, rho=rho, max_iters=2 * ef,
            use_filter=use_filter, q_norm=qn, mean_norm=w["mean_norm"],
            n_expand=n_expand, returnable=returnable)
    return jax.vmap(one)(w["qs"], w["entries"], w["entry_ds"],
                         w["code_qs"], w["q_norms"])


def _fused(fn, w, *, ef, k, rho, use_filter, n_expand, pad=False, **opt):
    qs, vectors = w["qs"], w["vectors"]
    if pad:
        lanes = (-w["dim"]) % 128
        qs = jnp.pad(qs, ((0, 0), (0, lanes)))
        vectors = jnp.pad(vectors, ((0, 0), (0, lanes)))
        if opt.get("qvecs") is not None:
            opt["qvecs"] = jnp.pad(opt["qvecs"], ((0, 0), (0, lanes)))
    return fn(qs, w["entries"], w["entry_ds"], w["adjacency"], vectors,
              w["codes"], w["code_qs"], w["live"], w["q_norms"],
              w["mean_norm"], ef=ef, k=k, m_bits=w["m_bits"], eps=EPS,
              rho=rho, max_iters=2 * ef, use_filter=use_filter,
              n_expand=n_expand, **opt)


def _assert_matches_while(res, base):
    ids, dists, stats, hn, hm = res
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(base.ids))
    np.testing.assert_array_equal(np.asarray(dists), np.asarray(base.dists))
    for col, name in enumerate(("n_adj", "n_vec", "n_filtered", "n_hops")):
        np.testing.assert_array_equal(
            np.asarray(stats[:, col]), np.asarray(getattr(base.stats, name)))
    np.testing.assert_array_equal(np.asarray(hn),
                                  np.asarray(base.heat_nodes))
    np.testing.assert_array_equal(np.asarray(hm), np.asarray(base.heat_mask))


def _assert_bitwise(a, b):
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# op-level parity matrix: tombstones x filter x sampling x n_expand
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rho", [1.0, 0.5])
@pytest.mark.parametrize("use_filter", [False, True])
@pytest.mark.parametrize("n_expand", [1, 3])
def test_beam_parity_matrix(n_expand, use_filter, rho):
    """Oracle == while_loop == Pallas(interpret), bit for bit, with
    tombstone lanes (returnable) always present."""
    w = _world(seed=n_expand * 10 + use_filter)
    kw = dict(ef=12, k=4, rho=rho, use_filter=use_filter,
              n_expand=n_expand)
    base = _while_loop_path(w, returnable=w["returnable"], **kw)
    ref = _fused(beam_search_ref, w, returnable=w["returnable"], **kw)
    _assert_matches_while(ref, base)
    pal = _fused(beam_search_fused_pallas, w, returnable=w["returnable"],
                 interpret=True, pad=True, **kw)
    _assert_bitwise(pal, ref)


@pytest.mark.parametrize("ef,M", [(8, 4), (16, 8), (24, 6)])
def test_beam_parity_ef_m_sweep(ef, M):
    w = _world(seed=ef + M, M=M)
    kw = dict(ef=ef, k=4, rho=1.0, use_filter=False, n_expand=2)
    base = _while_loop_path(w, returnable=w["returnable"], **kw)
    ref = _fused(beam_search_ref, w, returnable=w["returnable"], **kw)
    _assert_matches_while(ref, base)
    pal = _fused(beam_search_fused_pallas, w, returnable=w["returnable"],
                 interpret=True, pad=True, **kw)
    _assert_bitwise(pal, ref)


def test_beam_all_filtered_frontier():
    """Every neighbor dead or padded: the loop must expand the entry,
    find nothing eligible, and terminate with just the entry."""
    w = _world(seed=3)
    # all adjacency pads -1 -> zero eligible candidates anywhere
    w["adjacency"] = jnp.full_like(w["adjacency"], -1)
    kw = dict(ef=12, k=4, rho=1.0, use_filter=False, n_expand=2)
    base = _while_loop_path(w, **kw)
    ref = _fused(beam_search_ref, w, **kw)
    _assert_matches_while(ref, base)
    pal = _fused(beam_search_fused_pallas, w, interpret=True, pad=True,
                 **kw)
    _assert_bitwise(pal, ref)
    ids = np.asarray(ref[0])
    np.testing.assert_array_equal(ids[:, 0], np.asarray(w["entries"]))
    assert (ids[:, 1:] == -1).all()

    # same but via tombstones: neighbors exist, none routable
    w2 = _world(seed=4, dead=1.0)
    w2["live"] = w2["live"].at[w2["entries"]].set(True)
    base = _while_loop_path(w2, **kw)
    ref = _fused(beam_search_ref, w2, **kw)
    _assert_matches_while(ref, base)
    pal = _fused(beam_search_fused_pallas, w2, interpret=True, pad=True,
                 **kw)
    _assert_bitwise(pal, ref)


def test_beam_tier_mixed_lanes():
    """Hot rows exact, cold rows through the fused q8 dequant lane;
    power-of-two scales keep the min-merge bit-exact on both paths."""
    w = _world(seed=5)
    rng = np.random.default_rng(5)
    resident = jnp.asarray(rng.random(w["cap"]) < 0.5)
    qvecs = jnp.asarray(
        rng.integers(-127, 128, (w["cap"], w["dim"])).astype(np.int8))
    qscale = 2.0 ** jnp.asarray(
        rng.integers(-2, 3, w["cap"]).astype(np.float32))

    def tier_dist(q):
        def df(ids):
            res = resident[jnp.maximum(ids, 0)]
            hot = jnp.where((ids >= 0) & res, ids, -1)
            cold = jnp.where((ids >= 0) & ~res, ids, -1)
            d_hot = gather_l2(q[None, :], w["vectors"], hot[None, :])[0]
            d_cold = gather_l2_q8(q[None, :], qvecs, qscale,
                                  cold[None, :])[0]
            return jnp.minimum(d_hot, d_cold)
        return df

    kw = dict(ef=12, k=4, rho=1.0, use_filter=False, n_expand=2)
    base = _while_loop_path(w, returnable=w["returnable"],
                            dist_fn=tier_dist, **kw)
    opt = dict(returnable=w["returnable"], resident=resident,
               qvecs=qvecs, qscale=qscale)
    ref = _fused(beam_search_ref, w, **kw, **opt)
    _assert_matches_while(ref, base)
    pal = _fused(beam_search_fused_pallas, w, interpret=True, pad=True,
                 **kw, **opt)
    _assert_bitwise(pal, ref)


def test_beam_masked_pad_lanes():
    """Inactive block-pad queries return empty results and contribute
    nothing to the stats, on every path."""
    w = _world(seed=6, Bq=6)
    active = jnp.asarray([True, True, False, True, False, True])
    kw = dict(ef=12, k=4, rho=1.0, use_filter=False, n_expand=1)
    ref = _fused(beam_search_ref, w, active=active, **kw)
    pal = _fused(beam_search_fused_pallas, w, active=active,
                 interpret=True, pad=True, **kw)
    _assert_bitwise(pal, ref)
    ids, dists, stats, _, _ = ref
    dead = ~np.asarray(active)
    assert (np.asarray(ids)[dead] == -1).all()
    assert np.isinf(np.asarray(dists)[dead]).all()
    assert (np.asarray(stats)[dead] == 0).all()
    # live lanes bit-match an unmasked run over the same operands
    full = _fused(beam_search_ref, w, **kw)
    ok = np.asarray(active)
    np.testing.assert_array_equal(np.asarray(ids)[ok],
                                  np.asarray(full[0])[ok])
    np.testing.assert_array_equal(np.asarray(dists)[ok],
                                  np.asarray(full[1])[ok])


def test_beam_float_data_close():
    """Realistic float vectors: ids identical, distances allclose (the
    reduction orders legitimately differ between paths)."""
    w = _world(seed=7)
    rng = np.random.default_rng(7)
    w["vectors"] = jnp.asarray(
        rng.normal(size=(w["cap"], w["dim"])).astype(np.float32))
    w["qs"] = jnp.asarray(rng.normal(size=(5, w["dim"])).astype(np.float32))
    w["entry_ds"] = jax.vmap(
        lambda q, e: jnp.sum((q - w["vectors"][e]) ** 2))(
            w["qs"], w["entries"])
    kw = dict(ef=12, k=4, rho=1.0, use_filter=False, n_expand=2)
    ref = _fused(beam_search_ref, w, returnable=w["returnable"], **kw)
    pal = _fused(beam_search_fused_pallas, w, returnable=w["returnable"],
                 interpret=True, pad=True, **kw)
    np.testing.assert_array_equal(np.asarray(pal[0]), np.asarray(ref[0]))
    np.testing.assert_allclose(np.asarray(pal[1]), np.asarray(ref[1]),
                               rtol=1e-5, atol=1e-5)


def test_beam_record_heat_false():
    """record_heat=False skips the heat scatters but must not perturb
    ids/dists/stats; heat outputs collapse to the canonical empties."""
    w = _world(seed=8)
    kw = dict(ef=12, k=4, rho=1.0, use_filter=False, n_expand=2)
    on = _fused(beam_search_ref, w, record_heat=True, **kw)
    off = _fused(beam_search_ref, w, record_heat=False, **kw)
    _assert_bitwise(on[:3], off[:3])
    assert (np.asarray(off[3]) == -1).all()
    assert not np.asarray(off[4]).any()
    pal = _fused(beam_search_fused_pallas, w, record_heat=False,
                 interpret=True, pad=True, **kw)
    _assert_bitwise(pal[:3], off[:3])
    assert (np.asarray(pal[3]) == -1).all()
    assert not np.asarray(pal[4]).any()


# ---------------------------------------------------------------------------
# index level: fused_beam config flag vs the plain path
# ---------------------------------------------------------------------------

_IDX_CFG = HNSWConfig(cap=512, dim=24, M=8, M_up=4, num_upper=2,
                      ef_search=24, ef_construction=24, k=5, rho=1.0,
                      use_filter=False, lsm_mem_cap=128, lsm_levels=2,
                      lsm_fanout=8, n_expand=2)
_P = SearchParams(pad_to=32, use_snapshot=True)


def _base_data(n=300, dim=24, seed=2):
    return np.random.default_rng(seed).normal(
        size=(n, dim)).astype(np.float32)


def test_index_fused_parity_and_heat():
    base = _base_data()
    ix = LSMVecIndex.build(_IDX_CFG, base, seed=0)
    ixf = LSMVecIndex.build(_IDX_CFG._replace(fused_beam=True), base,
                            seed=0)
    dels = np.arange(40, 80, dtype=np.int64)
    ix.delete(dels)
    ixf.delete(dels)
    qs = np.random.default_rng(3).normal(size=(17, 24)).astype(np.float32)
    r, rf = ix.search(qs, params=_P), ixf.search(qs, params=_P)
    np.testing.assert_array_equal(r.ids, rf.ids)
    np.testing.assert_array_equal(r.dists, rf.dists)
    # heat accumulation must agree too — the megakernel's heat lanes
    # feed the same tier promotions as the while path
    np.testing.assert_array_equal(np.asarray(ix.state.heat),
                                  np.asarray(ixf.state.heat))


def test_index_fused_parity_tier():
    base = _base_data()
    rng = np.random.default_rng(2)
    cold = jnp.asarray(rng.random(512) < 0.5)
    objs = []
    for fused in (False, True):
        cfg = _IDX_CFG._replace(tier=True, rerank=16, fused_beam=fused)
        o = LSMVecIndex.build(cfg, base, seed=0)
        st = o.state
        qv, qs_ = quantize_rows(st.vectors)
        o.state = st._replace(hot=~(cold & (st.levels == 0)),
                              qvecs=qv, qscale=qs_)
        objs.append(o)
    qs = rng.normal(size=(8, 24)).astype(np.float32)
    r, rf = objs[0].search(qs, params=_P), objs[1].search(qs, params=_P)
    np.testing.assert_array_equal(r.ids, rf.ids)
    np.testing.assert_array_equal(r.dists, rf.dists)


def test_index_fused_parity_rho_filter_churn():
    base = _base_data()
    cfg = _IDX_CFG._replace(rho=0.5, use_filter=True)
    a = LSMVecIndex.build(cfg, base, seed=0)
    b = LSMVecIndex.build(cfg._replace(fused_beam=True), base, seed=0)
    dels = np.arange(20, 120, dtype=np.int64)
    a.delete(dels)
    b.delete(dels)
    qs = np.random.default_rng(4).normal(size=(11, 24)).astype(np.float32)
    ra, rb = a.search(qs, params=_P), b.search(qs, params=_P)
    np.testing.assert_array_equal(ra.ids, rb.ids)
    np.testing.assert_array_equal(ra.dists, rb.dists)


def test_index_fused_zero_retrace():
    base = _base_data()
    ixf = LSMVecIndex.build(_IDX_CFG._replace(fused_beam=True), base,
                            seed=0)
    rng = np.random.default_rng(5)
    ixf.search(rng.normal(size=(9, 24)).astype(np.float32), params=_P)
    warm = dict(ixf.trace_counts())
    for _ in range(4):
        n = int(rng.integers(1, 32))
        ixf.search(rng.normal(size=(n, 24)).astype(np.float32), params=_P)
    assert dict(ixf.trace_counts()) == warm


# ---------------------------------------------------------------------------
# serve level: fused_beam on, zero retraces under ragged traffic
# ---------------------------------------------------------------------------

def test_serve_fused_zero_retraces():
    from repro.serve import MaintenancePolicy, ServeConfig, ServeEngine

    cfg = _IDX_CFG._replace(cap=1024, fused_beam=True, batch_expand=4)
    base = _base_data(256)
    idx = LSMVecIndex.build(cfg, base, seed=0)
    eng = ServeEngine(
        idx, ServeConfig(query_batch=8, insert_batch=8, delete_batch=8,
                         maintenance=MaintenancePolicy(
                             tombstone_ratio=None, heat_budget=None)))
    rng = np.random.default_rng(6)
    fresh = _base_data(32, seed=7)
    for i in range(3):
        eng.submit_insert(fresh[i])
    for i in range(5):
        eng.submit_query(base[i])
    eng.submit_delete(int(rng.integers(0, 256)))
    eng.drain()
    eng.submit_query(base[0])
    eng.drain()
    eng.submit_insert(fresh[30])
    eng.drain()
    warm = idx.trace_counts()
    for round_ in range(4):
        for _ in range(int(rng.integers(1, 8))):
            eng.submit_query(base[rng.integers(0, 250)])
        if round_ % 2 == 0:
            eng.submit_insert(fresh[3 + round_])
        else:
            eng.submit_delete(256 + round_)
        eng.drain()
    assert idx.trace_counts() == warm, "fused serving retraced after warmup"
