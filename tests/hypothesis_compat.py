"""Graceful degradation when `hypothesis` is not installed.

Property-test modules import `given`, `settings`, and `st` from here
instead of from `hypothesis` directly.  With hypothesis available this is
a pure re-export; without it the decorators turn each property test into
a pytest skip (and `st` becomes an inert stub so strategy expressions at
decoration time still evaluate), letting the plain unit tests in the same
module run.  Install the real package via the `test` extra:
`pip install -e .[test]`.
"""

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised only without extra
    import pytest

    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Accepts any attribute access / call chain and returns itself."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _StrategyStub()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -e .[test])"
            )(fn)
        return deco

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
