"""Unit + property tests for connectivity-aware reordering (§3.4)."""

import numpy as np
from hypothesis_compat import given, settings, st  # skips gracefully when absent

from repro.core import reorder


def ring_rows(n, m=2):
    rows = np.full((n, m), -1, np.int32)
    rows[:, 0] = (np.arange(n) + 1) % n
    rows[:, 1] = (np.arange(n) - 1) % n
    return rows


def test_permutation_validity_random_graph():
    rng = np.random.default_rng(0)
    rows = rng.integers(0, 64, (64, 4)).astype(np.int32)
    perm = reorder.gorder_permutation(rows, window=4)
    assert sorted(perm.tolist()) == list(range(64))


def test_reordering_improves_shuffled_ring():
    """A ring shuffled randomly must relayout to near-contiguous."""
    n = 48
    rng = np.random.default_rng(1)
    shuffle = rng.permutation(n)
    inv = np.argsort(shuffle)
    # ring in shuffled id space
    rows = ring_rows(n)
    rows = inv[rows[shuffle]]
    base = reorder.layout_score(rows, np.arange(n, dtype=np.int32),
                                window=4)
    perm = reorder.gorder_permutation(rows, window=4)
    improved = reorder.layout_score(rows, perm, window=4)
    assert improved > base * 1.5, (base, improved)


def test_heat_weighted_edges_prioritized():
    """Edges with traversal heat pull their endpoints together."""
    n = 32
    rng = np.random.default_rng(2)
    rows = rng.integers(0, n, (n, 3)).astype(np.int32)
    heat = np.zeros_like(rows)
    rows[0, 0] = n - 1          # one specific hot edge 0 -> n-1
    heat[0, 0] = 1000
    perm = reorder.gorder_permutation(rows, heat, window=4, lam=4.0)
    gap_hot = abs(int(perm[0]) - int(perm[n - 1]))
    gaps = []
    for u in range(1, n - 1):
        for v in rows[u]:
            if v >= 0 and v != u:
                gaps.append(abs(int(perm[u]) - int(perm[v])))
    assert gap_hot <= np.median(gaps), (gap_hot, np.median(gaps))


def test_dead_nodes_placed_last():
    rows = ring_rows(16)
    live = np.ones(16, bool)
    live[[3, 7]] = False
    perm = reorder.gorder_permutation(rows, window=4, live=live)
    assert perm[3] >= 14 and perm[7] >= 14


def test_block_io_count_drops_after_reorder():
    """Fig. 4's metric: co-fetched nodes land in fewer physical blocks."""
    n = 64
    rng = np.random.default_rng(3)
    shuffle = rng.permutation(n)
    rows = ring_rows(n)
    rows = np.argsort(shuffle)[rows[shuffle]]
    # traversal fetches each node's neighbor pair together
    fetches = [rows[u][rows[u] >= 0] for u in range(n)]
    ident = np.arange(n, dtype=np.int32)
    before = reorder.block_io_count(fetches, ident, block_rows=4)
    perm = reorder.gorder_permutation(rows, window=4)
    after = reorder.block_io_count(fetches, perm, block_rows=4)
    assert after < before, (before, after)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=8, max_value=40), st.integers(0, 1000))
def test_property_gorder_always_valid_permutation(n, seed):
    rng = np.random.default_rng(seed)
    rows = rng.integers(-1, n, (n, 3)).astype(np.int32)
    perm = reorder.gorder_permutation(rows, window=4)
    assert sorted(perm.tolist()) == list(range(n))
