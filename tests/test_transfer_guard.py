"""Runtime sentinels for the host-sync invariant (DESIGN.md §14).

Steady-state serving runs here under
`repro.core.sentinel.forbid_undeclared_sync` — the runtime cross-check
of the static `tools.repro_lint` host-sync rule: the only device→host
syncs the serve path may perform are the ones inside `declared_sync`
scopes, i.e. exactly the points the static allowlist annotates with
``# sync-ok``.  A stray sync anywhere on the pump/dispatch/collect
path raises `UndeclaredHostSyncError` immediately.

The same run asserts `trace_counts` stability: the guard must not cost
the §8 zero-retrace property (a retrace under guard would also be the
first symptom of a shape leak).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HNSWConfig, LSMVecIndex
from repro.core.distributed import ShardedBackend
from repro.core.sentinel import (
    UndeclaredHostSyncError,
    declared_sync,
    forbid_undeclared_sync,
    sync_counts,
)
from repro.data.synth import make_clustered_vectors
from repro.serve import MaintenancePolicy, ServeConfig, ServeEngine

CFG = HNSWConfig(cap=1024, dim=16, M=8, M_up=4, num_upper=2,
                 ef_search=32, ef_construction=32, k=5,
                 rho=1.0, use_filter=False, lsm_mem_cap=128,
                 lsm_levels=2, lsm_fanout=8, batch_expand=4)

#: an eager consolidate trigger so maintenance fires during the test
MAINT = MaintenancePolicy(tombstone_ratio=None, consolidate_ratio=0.02,
                          heat_budget=None, check_every=2)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _engine(backend):
    return ServeEngine(
        backend, ServeConfig(query_batch=8, insert_batch=8,
                             delete_batch=8, maintenance=MAINT),
        clock=FakeClock())


class _Stream:
    """Mixed query/insert/delete traffic with persistent cursors, so
    deletes always hit live allocated external ids and never repeat."""

    def __init__(self, eng, base, fresh):
        self.eng, self.base, self.fresh = eng, base, fresh
        self.rng = np.random.default_rng(9)
        self.fi = 0
        self.next_del = 0

    def rounds(self, n):
        for r in range(n):
            for _ in range(int(self.rng.integers(1, 6))):
                self.eng.submit_query(
                    self.base[int(self.rng.integers(0, len(self.base)))])
            if r % 2 == 0:
                self.eng.submit_insert(self.fresh[self.fi % len(self.fresh)])
                self.fi += 1
            else:
                self.eng.submit_delete(self.next_del)
                self.next_del += 1
            self.eng.drain()


def _run_guarded_steady_state(backend):
    eng = _engine(backend)
    base = make_clustered_vectors(192, dim=16, seed=0, clusters=8)
    fresh = make_clustered_vectors(64, dim=16, seed=1, clusters=8)
    stream = _Stream(eng, base, fresh)
    # unguarded warmup to trace-cache fixpoint: hash-partitioned routing
    # means a fixed round count can leave one shard's batch entry
    # uncompiled, so drive traffic until two sweeps stop adding variants
    prev = None
    for _ in range(16):
        stream.rounds(4)
        cur = backend.trace_counts()
        if cur == prev:
            break
        prev = cur
    else:
        pytest.fail("trace counts never stabilized during warmup")
    assert eng.metrics.maintenance_runs["consolidate"] > 0, \
        "warmup never consolidated — the guard phase would compile it"
    warm = backend.trace_counts()
    runs_before = eng.metrics.maintenance_runs["consolidate"]
    syncs_before = sum(sync_counts().values())
    # guarded steady state: every device→host sync must go through a
    # declared_sync scope, and nothing may retrace
    with forbid_undeclared_sync():
        stream.rounds(10)
        eng.drain()
    assert backend.trace_counts() == warm, \
        "serving retraced under the transfer guard"
    assert eng.metrics.maintenance_runs["consolidate"] > runs_before, \
        "guarded phase never exercised the maintenance sync points"
    assert sum(sync_counts().values()) > syncs_before, \
        "declared_sync scopes never fired under the guard"


def test_steady_state_serve_under_transfer_guard_single():
    idx = LSMVecIndex.build(
        CFG, make_clustered_vectors(128, dim=16, seed=7, clusters=8))
    _run_guarded_steady_state(idx)


def test_steady_state_serve_under_transfer_guard_sharded():
    base = make_clustered_vectors(128, dim=16, seed=8, clusters=8)
    backend = ShardedBackend(CFG._replace(cap=512), 4).build(base)
    _run_guarded_steady_state(backend)


def test_guard_blocks_stray_sync_and_declared_scope_allows(no_host_sync):
    """The conftest fixture really disallows syncs — and
    `declared_sync` really is the sanctioned escape.

    The blocked constructs below are exactly the static HS001 sink
    set.  (`np.asarray` on the CPU backend is a zero-copy
    buffer-protocol view that no guard can see — the XLA transfer
    guard catches it on accelerator backends.)
    """
    x = jnp.arange(8)
    jax.block_until_ready(x)
    for stray in (lambda: int(x[0]), lambda: float(x[1]),
                  lambda: bool(x[2] > 0), lambda: x.tolist(),
                  lambda: x[0].item(), lambda: jax.device_get(x)):
        with pytest.raises(UndeclaredHostSyncError):
            stray()
    with declared_sync("test escape"):
        assert int(jnp.sum(x)) == 28
        assert x.tolist() == list(range(8))
    assert sync_counts().get("test escape", 0) >= 1
    # guard scopes unwind cleanly: the declared escape is closed again
    with pytest.raises(UndeclaredHostSyncError):
        x.tolist()
