"""Tiered hot/cold vector store tests (DESIGN.md §12): quantizer
round-trip, the fused dequant+L2 kernel vs its oracle, policy
convergence under hysteresis, mixed-lane search parity and recall,
external-id stability across reorder/consolidate with a populated cold
lane, checkpoint bit-exactness at shards=1 and shards=4, per-lane
memory accounting, and the small-clustered-shard bulk_build
reachability regression."""

import collections

import jax
import numpy as np
import pytest

from repro.core import hnsw, lsm
from repro.core.backend import SearchParams
from repro.core.distributed import ShardedBackend
from repro.core.index import LSMVecIndex, brute_force_knn, recall_at_k
from repro.data.synth import make_clustered_vectors
from repro.kernels import gather_l2, gather_l2_q8
from repro.kernels.gather_l2.ref import gather_l2_q8_ref
from repro.serve import MaintenancePolicy, ServeConfig, ServeEngine
from repro.tier import TierPolicy, dequantize_rows, quantize_rows

CFG = hnsw.HNSWConfig(cap=1024, dim=32, M=8, M_up=4, num_upper=2,
                      ef_search=48, ef_construction=48, k=10, rho=1.0,
                      use_filter=False, lsm_mem_cap=128, lsm_levels=2,
                      lsm_fanout=8, tier=True, rerank=32)

POL = TierPolicy(hot_frac=0.25, ewma=0.5, hysteresis=0.05,
                 max_demote=CFG.cap, max_promote=CFG.cap)


def _vecs(n, seed=0, dim=None):
    return np.random.default_rng(seed).standard_normal(
        (n, dim or CFG.dim)).astype(np.float32)


def _warm(idx, queries, rounds=2):
    """Accumulate traversal heat so the policy has a signal to rank."""
    for _ in range(rounds):
        idx.search(queries, params=SearchParams(record_heat=True))


def _skew_queries(base, n_q, seed=1):
    """Perturbations of the head quarter of the corpus: a workload with
    an actual hot set, so demotion targets the tail."""
    rng = np.random.default_rng(seed)
    picks = base[rng.integers(0, max(len(base) // 4, 1), n_q)]
    return (picks + rng.normal(0, 0.1, picks.shape)).astype(np.float32)


# ---------------------------------------------------------------------------
# quantizer
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bounded_by_half_step():
    rows = _vecs(64, seed=3) * 7.0
    codes, scales = quantize_rows(rows)
    assert codes.dtype == np.int8 and scales.dtype == np.float32
    deq = np.asarray(dequantize_rows(codes, scales))
    err = np.abs(deq - rows)
    # absmax scalar quantization: error <= scale/2 per element
    assert np.all(err <= np.asarray(scales)[:, None] * 0.5 + 1e-6)


def test_quantize_zero_row_is_stable():
    rows = np.zeros((2, CFG.dim), np.float32)
    codes, scales = quantize_rows(rows)
    assert np.all(np.asarray(codes) == 0)
    assert np.all(np.asarray(dequantize_rows(codes, scales)) == 0.0)


# ---------------------------------------------------------------------------
# fused dequant+L2 kernel family
# ---------------------------------------------------------------------------

def test_gather_l2_q8_ref_equals_dequant_then_gather():
    rng = np.random.default_rng(5)
    table = _vecs(128, seed=6) * 3.0
    codes, scales = quantize_rows(table)
    q = _vecs(4, seed=7)
    ids = rng.integers(0, 128, (4, 16)).astype(np.int32)
    ids[0, 3] = -1                                   # masked lane
    d_fused = np.asarray(gather_l2_q8_ref(q, codes, scales, ids))
    d_two_step = np.asarray(gather_l2(q, dequantize_rows(codes, scales),
                                      ids))
    assert np.isinf(d_fused[0, 3])
    np.testing.assert_allclose(d_fused, d_two_step, rtol=1e-5, atol=1e-5)


def test_gather_l2_q8_op_dispatches_to_ref_on_cpu():
    table = _vecs(64, seed=8)
    codes, scales = quantize_rows(table)
    q = _vecs(3, seed=9)
    ids = np.random.default_rng(10).integers(0, 64, (3, 8)).astype(np.int32)
    np.testing.assert_allclose(
        np.asarray(gather_l2_q8(q, codes, scales, ids)),
        np.asarray(gather_l2_q8_ref(q, codes, scales, ids)),
        rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# policy: convergence + hysteresis
# ---------------------------------------------------------------------------

def test_policy_converges_to_budget_and_hysteresis_holds():
    base = _vecs(512, seed=11)
    idx = LSMVecIndex.build(CFG, base)
    _warm(idx, _skew_queries(base, 64))
    m1 = idx.tier_maintain(POL)
    assert m1["demoted"] > 0
    st = idx.stats()
    n_lane = st.memory.n_hot + st.memory.n_cold
    # the hot lane lands at the budget, within the hysteresis band
    assert st.memory.n_hot <= int(
        np.ceil(POL.hot_frac * n_lane * (1 + POL.hysteresis))) + 1
    # heat unchanged since -> ranks unchanged -> a second pass is a no-op
    m2 = idx.tier_maintain(POL)
    assert m2 == {"demoted": 0, "promoted": 0}


def test_promotion_rehydrates_reheated_nodes():
    base = _vecs(512, seed=12)
    idx = LSMVecIndex.build(CFG, base)
    _warm(idx, _skew_queries(base, 64, seed=13))
    idx.tier_maintain(POL)
    n_cold0 = idx.stats().memory.n_cold
    assert n_cold0 > 0
    # shift the workload to the previously-cold tail; its nodes reheat
    rng = np.random.default_rng(14)
    tail_q = (base[rng.integers(3 * len(base) // 4, len(base), 64)]
              + rng.normal(0, 0.1, (64, CFG.dim))).astype(np.float32)
    _warm(idx, tail_q, rounds=4)
    moved = idx.tier_maintain(POL)
    assert moved["promoted"] > 0


# ---------------------------------------------------------------------------
# search: all-hot parity, tiered recall, rerank IO accounting
# ---------------------------------------------------------------------------

def test_all_hot_tier_search_is_bit_parity_with_dense():
    base = _vecs(400, seed=15)
    q = _vecs(16, seed=16)
    res_t = LSMVecIndex.build(CFG, base).search(q)
    res_d = LSMVecIndex.build(CFG._replace(tier=False), base).search(q)
    np.testing.assert_array_equal(np.asarray(res_t.ids),
                                  np.asarray(res_d.ids))
    np.testing.assert_allclose(np.asarray(res_t.dists),
                               np.asarray(res_d.dists), rtol=1e-6)


def test_tiered_recall_holds_floor_and_rerank_fetches_cold_rows():
    base = make_clustered_vectors(512, dim=CFG.dim, seed=17)
    q = _skew_queries(base, 64, seed=18)
    truth = brute_force_knn(base, q, CFG.k)
    idx = LSMVecIndex.build(CFG, base)
    _warm(idx, q)
    recall_dense = recall_at_k(
        idx.search(q, params=SearchParams(record_heat=False)).ids, truth)
    idx.tier_maintain(POL)
    assert idx.stats().memory.n_cold > 0
    idx.reset_stats()
    recall_tier = recall_at_k(
        idx.search(q, params=SearchParams(record_heat=False)).ids, truth)
    assert recall_tier >= 0.95 * recall_dense
    # rerank's exact re-fetch of cold candidates is modeled disk IO
    assert int(idx.io_stats.n_vec) > 0


# ---------------------------------------------------------------------------
# external-id stability across reorder + consolidate with a cold lane
# ---------------------------------------------------------------------------

def test_external_ids_stable_across_reorder_and_consolidate():
    base = _vecs(400, seed=19)
    idx = LSMVecIndex.build(CFG, base)
    pol = MaintenancePolicy(tombstone_ratio=None, consolidate_ratio=0.2,
                            heat_budget=1, check_every=1,
                            tier_policy=POL)
    eng = ServeEngine(idx, ServeConfig(query_batch=16, insert_batch=16,
                                       delete_batch=16, maintenance=pol))
    probe = base[37]
    t0 = eng.submit_query(probe)
    eng.drain()
    assert int(t0.result().ids[0]) == 37

    # trigger maintenance: reorder (permutes internal ids) + tier pass
    eng.submit_insert(_vecs(1, seed=20)[0])
    eng.drain()
    assert eng.maintenance.reorders >= 1
    assert eng.maintenance.tier_passes >= 1
    assert eng.maintenance.tier_demoted > 0
    t1 = eng.submit_query(probe)
    eng.drain()
    assert int(t1.result().ids[0]) == 37

    # churn past the consolidate trigger; 37 stays live
    for v in range(100, 220):
        eng.submit_delete(v)
    eng.submit_insert(_vecs(1, seed=21)[0])
    eng.drain()
    assert eng.maintenance.consolidations >= 1
    t2 = eng.submit_query(probe)
    eng.drain()
    assert int(t2.result().ids[0]) == 37
    returned = set(int(i) for i in t2.result().ids)
    assert not (returned & set(range(100, 220)))
    eng.close()


# ---------------------------------------------------------------------------
# checkpoint round-trip with a populated cold lane
# ---------------------------------------------------------------------------

def _trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


def test_checkpoint_restore_bit_exact_with_cold_lane(tmp_path):
    base = _vecs(300, seed=22)
    idx = LSMVecIndex.build(CFG, base)
    _warm(idx, _skew_queries(base, 32, seed=23))
    assert idx.tier_maintain(POL)["demoted"] > 0
    idx.save(str(tmp_path), lsn=7)

    idx2, md, _ = LSMVecIndex.restore(CFG, str(tmp_path))
    assert md["lsn"] == 7
    assert _trees_equal(idx.state, idx2.state)
    st = idx2.stats()
    assert st.memory.n_cold > 0                      # cold lane survived
    q = _vecs(16, seed=24)
    np.testing.assert_array_equal(
        np.asarray(idx.search(q, params=SearchParams(record_heat=False)).ids),
        np.asarray(idx2.search(q, params=SearchParams(record_heat=False)).ids))


def test_sharded_checkpoint_restore_bit_exact_with_cold_lane(tmp_path):
    cfg = CFG._replace(cap=512)
    base = _vecs(600, seed=25)
    be = ShardedBackend(cfg, 4).build(base, seed=25)
    for _ in range(2):
        be.search(_skew_queries(base, 32, seed=26))
    moved = be.tier_maintain(POL)
    assert moved["demoted"] > 0
    assert be.stats().memory.n_cold > 0
    be.save(str(tmp_path), lsn=9)

    be2, md, _ = ShardedBackend.restore(cfg, str(tmp_path), n_shards=4)
    assert md["lsn"] == 9
    for a, b in zip(be.shards, be2.shards):
        assert _trees_equal(a.state, b.state)
    q = _vecs(16, seed=27)
    np.testing.assert_array_equal(np.asarray(be.search(q).ids),
                                  np.asarray(be2.search(q).ids))


# ---------------------------------------------------------------------------
# memory accounting (per-lane + the serving state satellite)
# ---------------------------------------------------------------------------

def test_memory_breakdown_components_and_tier_shrinks_footprint():
    base = _vecs(512, seed=28)
    idx = LSMVecIndex.build(CFG, base)
    st = idx.stats()
    mem0 = st.memory
    assert mem0 is not None
    # serving-state components the old accounting omitted are surfaced
    # and non-zero (tombstone lane, insert overlay, ext<->int id maps)
    d = mem0.as_dict()
    for comp in ("tombstones", "insert_overlay", "id_maps", "memtable",
                 "simhash_codes", "hot_vectors"):
        assert d[comp] > 0, comp
    assert d["total"] == sum(v for k, v in d.items()
                             if k not in ("total", "n_hot", "n_cold"))
    assert idx.memory_bytes() == mem0.total

    _warm(idx, _skew_queries(base, 64, seed=29))
    idx.tier_maintain(POL)
    mem1 = idx.stats().memory
    assert mem1.n_cold > 0
    assert mem1.total < mem0.total                   # demotion freed bytes
    assert mem1.cold_codes == mem1.n_cold * (CFG.dim + 4)
    # per-shard lane counts ride the stats surface
    sh = idx.stats().shards[0]
    assert (sh.n_hot, sh.n_cold) == (mem1.n_hot, mem1.n_cold)


def test_dense_config_reports_all_rows_hot():
    idx = LSMVecIndex.build(CFG._replace(tier=False), _vecs(200, seed=30))
    mem = idx.stats().memory
    assert mem.n_cold == 0 and mem.cold_codes == 0
    assert mem.n_hot >= 200


# ---------------------------------------------------------------------------
# bulk_build small-clustered-shard reachability regression
# ---------------------------------------------------------------------------

def _bottom_reachable(cfg, state, n):
    """BFS over the bottom layer from the entry's bottom anchor."""
    live, rows = lsm.resolve_all(cfg.lsm_cfg, state.store, n)
    rows = np.asarray(rows)
    live = np.asarray(live).astype(bool) & (
        np.asarray(state.levels[:n]) >= 0)
    seen = {0}
    frontier = collections.deque([0])
    while frontier:
        u = frontier.popleft()
        for v in rows[u]:
            v = int(v)
            if v >= 0 and live[v] and v not in seen:
                seen.add(v)
                frontier.append(v)
    return seen, set(np.flatnonzero(live))


@pytest.mark.parametrize("n", [64, 96, 128])
def test_bulk_build_tiny_clustered_shard_fully_reachable(n):
    # regression: bulk_build on very small clustered shards used to
    # truncate the candidate pool below the cluster count, stranding
    # whole clusters off the entry component (the sharded smoke's
    # per-shard scale).  Every live node must be reachable on the
    # bottom layer, and recall must not crater.
    cfg = CFG._replace(cap=max(2 * n, 256))
    base = make_clustered_vectors(n, dim=CFG.dim, seed=31)
    idx = LSMVecIndex.build(cfg, base)
    seen, want = _bottom_reachable(cfg, idx.state, n)
    assert seen >= want, f"unreachable: {sorted(want - seen)[:10]}"
    q = (base + np.random.default_rng(32).normal(
        0, 0.05, base.shape)).astype(np.float32)
    truth = brute_force_knn(base, q, cfg.k)
    assert recall_at_k(
        idx.search(q, params=SearchParams(record_heat=False)).ids,
        truth) >= 0.9


def test_bulk_build_tiny_shards_inside_sharded_backend():
    # 4 shards over 256 rows = 64 nodes/shard: the regime the carried
    # issue called out as losing navigability
    base = make_clustered_vectors(256, dim=CFG.dim, seed=33)
    be = ShardedBackend(CFG._replace(cap=256), 4).build(base, seed=33)
    q = _vecs(32, seed=34)
    # backend ids are block-encoded gids: map truth through the
    # allocation-order id table
    truth = np.asarray(be.initial_ids())[brute_force_knn(base, q, CFG.k)]
    assert recall_at_k(np.asarray(be.search(q).ids), truth) >= 0.85
