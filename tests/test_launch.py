"""Launch-layer tests: mini dry-run (8 fake devices), HLO analyzer, specs.

Keeps the multi-pod machinery under pytest without the 512-device cost:
a smoke config is lowered + compiled on a (2, 4) mesh through exactly the
same code path dryrun.py uses at production scale.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.launch import analysis, hlo_analyzer, steps
from repro.launch.mesh import make_test_mesh
from repro.launch.sharding import data_sharding, param_spec, state_spec, tree_shardings
from repro.optim import adamw_init


def _mini_cell(arch: str, kind: str):
    mesh = make_test_mesh((2, 4), ("data", "model"))
    cfg = configs.get_config(arch, "smoke")
    params_abs = steps.abstract_params(cfg)
    p_sh = tree_shardings(mesh, params_abs, param_spec)
    with jax.sharding.set_mesh(mesh):
        if kind == "train":
            specs = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
            if cfg.num_img_tokens:
                specs["img_embeds"] = jax.ShapeDtypeStruct(
                    (8, cfg.num_img_tokens, cfg.d_model), cfg.act_dtype)
            opt_abs = jax.eval_shape(adamw_init, params_abs)
            o_sh = tree_shardings(mesh, opt_abs, param_spec)
            b_sh = {k: data_sharding(mesh, len(v.shape), v.shape[0])
                    for k, v in specs.items()}
            step = steps.make_train_step(cfg)
            return jax.jit(step, in_shardings=(p_sh, o_sh, b_sh)).lower(
                params_abs, opt_abs, specs).compile()
        else:
            from repro.models import transformer as T
            state = jax.eval_shape(
                lambda: T.init_decode_state(cfg, 8, max_len=32))
            s_sh = tree_shardings(mesh, state, state_spec)
            specs = {"tokens": jax.ShapeDtypeStruct((8, 1), jnp.int32),
                     "state": state}
            b_sh = {"tokens": data_sharding(mesh, 2, 8), "state": s_sh}
            step = steps.make_serve_step(cfg)
            return jax.jit(step, in_shardings=(p_sh, b_sh)).lower(
                params_abs, specs).compile()


@pytest.mark.parametrize("arch", ["qwen3-8b", "deepseek-v2-236b",
                                  "zamba2-7b", "rwkv6-3b",
                                  "llava-next-34b"])
def test_mini_dryrun_train_compiles(arch):
    compiled = _mini_cell(arch, "train")
    assert compiled.memory_analysis() is not None
    res = hlo_analyzer.analyze(compiled.as_text())
    assert res["flops"] > 0
    assert res["bytes"] > 0


@pytest.mark.parametrize("arch", ["qwen3-8b", "zamba2-7b", "rwkv6-3b"])
def test_mini_dryrun_decode_compiles(arch):
    compiled = _mini_cell(arch, "decode")
    res = hlo_analyzer.analyze(compiled.as_text())
    assert res["bytes"] > 0


def test_hlo_analyzer_trip_count_weighting():
    """A scanned matmul must count ~trip_count x the body flops."""
    w = jnp.zeros((64, 64), jnp.float32)

    def f(x):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=10)
        return h

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((8, 64), jnp.float32)).compile()
    res = hlo_analyzer.analyze(compiled.as_text())
    one_matmul = 2 * 8 * 64 * 64
    assert res["flops"] >= 9 * one_matmul, res["flops"]
    assert res["flops"] <= 12 * one_matmul, res["flops"]


def test_hlo_analyzer_collectives_weighted():
    from jax.sharding import PartitionSpec as P, NamedSharding
    mesh = make_test_mesh((2, 4), ("data", "model"))
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(x, w):
        def body(h, _):
            return h @ w, None          # contraction over sharded dim
        h, _ = jax.lax.scan(body, x, None, length=5)
        return h

    with jax.sharding.set_mesh(mesh):
        compiled = jax.jit(
            f, in_shardings=(NamedSharding(mesh, P(None, "model")),
                             NamedSharding(mesh, P("model", None)))
        ).lower(jax.ShapeDtypeStruct((8, 64), jnp.float32), w).compile()
    res = hlo_analyzer.analyze(compiled.as_text())
    total = sum(v["count"] for v in res["collectives"].values())
    assert total >= 5, res["collectives"]     # one per scan iteration


def test_input_specs_match_shapes_table():
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch, "full")
        for shape_name, (seq, batch, kind) in configs.SHAPES.items():
            if not configs.runs_cell(cfg, shape_name):
                continue
            specs = steps.input_specs(cfg, shape_name)
            if kind == "train":
                assert specs["tokens"].shape[0] == batch
                total = specs["tokens"].shape[1] + cfg.num_img_tokens
                assert total == seq
            elif kind == "decode":
                assert specs["tokens"].shape == (batch, 1)
                assert specs["state"]["pos"].shape == (batch,)


def test_roofline_terms_math():
    terms = analysis.roofline_terms(
        {"flops": 197e12, "bytes accessed": 819e9},
        {"all-reduce": {"count": 1, "bytes": 25e9}})
    assert abs(terms["t_compute"] - 1.0) < 1e-6
    assert abs(terms["t_memory"] - 1.0) < 1e-6
    assert abs(terms["t_collective"] - 1.0) < 1e-6   # 2x ring factor
    assert analysis.dominant_term(terms) in ("compute", "memory",
                                             "collective")
