"""Shape/dtype sweeps: every Pallas kernel vs its pure-jnp oracle.

Kernels run in interpret mode on CPU (the kernel body executes in Python);
TPU is the compile target.  Tolerances follow FlashAttention-style practice:
rtol 1e-3 on f32, 2e-2 on bf16 inputs (f32 accumulation inside the kernel).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # skips gracefully when absent

from repro.kernels.gather_l2.kernel import gather_l2_pallas
from repro.kernels.gather_l2.ops import gather_l2, gather_l2_q8
from repro.kernels.gather_l2.ref import gather_l2_q8_ref, gather_l2_ref
from repro.kernels.l2_distance.kernel import l2_distance_pallas
from repro.kernels.l2_distance.ops import l2_distance
from repro.kernels.l2_distance.ref import l2_distance_ref
from repro.kernels.simhash.kernel import collision_count_pallas, simhash_encode_pallas
from repro.kernels.simhash.ops import collision_count, simhash_encode
from repro.kernels.simhash.ref import collision_count_ref, simhash_encode_ref

TOL = {jnp.float32: dict(rtol=1e-3, atol=1e-3),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-1)}


# ---------------------------------------------------------------------------
# l2_distance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("q,n,d", [(8, 128, 128), (128, 256, 128),
                                   (16, 128, 256), (8, 128, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l2_distance_kernel_sweep(q, n, d, dtype):
    kq, kc = jax.random.split(jax.random.key(q * n + d))
    queries = jax.random.normal(kq, (q, d), dtype)
    cands = jax.random.normal(kc, (n, d), dtype)
    out = l2_distance_pallas(queries, cands, block_q=8, block_n=128,
                             interpret=True)
    ref = l2_distance_ref(queries, cands)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL[dtype])


def test_l2_distance_ops_ragged_shapes():
    """The ops wrapper pads/unpads non-tile-aligned shapes."""
    kq, kc = jax.random.split(jax.random.key(0))
    queries = jax.random.normal(kq, (5, 100))
    cands = jax.random.normal(kc, (77, 100))
    out = l2_distance(queries, cands, use_pallas=True, interpret=True)
    ref = l2_distance_ref(queries, cands)
    assert out.shape == (5, 77)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-3,
                               atol=1e-3)


def test_l2_distance_zero_on_identical():
    x = jax.random.normal(jax.random.key(0), (128, 128))
    out = l2_distance_pallas(x, x, block_q=128, block_n=128, interpret=True)
    diag = np.asarray(out)[np.arange(128), np.arange(128)]
    np.testing.assert_allclose(diag, 0.0, atol=1e-3)


# ---------------------------------------------------------------------------
# gather_l2
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,k,n,d", [(4, 16, 64, 128), (2, 8, 256, 128),
                                     (8, 32, 128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_l2_kernel_sweep(b, k, n, d, dtype):
    kq, kt, ki = jax.random.split(jax.random.key(b * k + n + d), 3)
    queries = jax.random.normal(kq, (b, d), dtype)
    table = jax.random.normal(kt, (n, d), dtype)
    ids = jax.random.randint(ki, (b, k), 0, n, jnp.int32)
    out = gather_l2_pallas(queries, table, ids, interpret=True)
    ref = gather_l2_ref(queries, table, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL[dtype])


def test_gather_l2_negative_ids_are_inf():
    queries = jax.random.normal(jax.random.key(0), (2, 128))
    table = jax.random.normal(jax.random.key(1), (16, 128))
    ids = jnp.array([[0, -1, 3, -1], [2, 2, -1, 5]], jnp.int32)
    out = gather_l2_pallas(queries, table, ids, interpret=True)
    out = np.asarray(out)
    assert np.isinf(out[0, 1]) and np.isinf(out[0, 3]) and np.isinf(out[1, 2])
    assert np.isfinite(out[0, 0]) and np.isfinite(out[1, 0])


def test_gather_l2_ops_pads_dim():
    queries = jax.random.normal(jax.random.key(0), (3, 100))
    table = jax.random.normal(jax.random.key(1), (32, 100))
    ids = jax.random.randint(jax.random.key(2), (3, 7), 0, 32, jnp.int32)
    out = gather_l2(queries, table, ids, use_pallas=True, interpret=True)
    ref = gather_l2_ref(queries, table, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-3,
                               atol=1e-3)


def test_gather_l2_pad_lane_roundtrip_dim65_exact():
    """dim=65 (not a lane multiple) must round-trip bit-exactly.

    Pad lanes are zero in query and table, contributing +0.0 each, so
    with integer-valued inputs (sums exact in f32) the padded kernel
    reduction must equal the unpadded oracle bit-for-bit.
    """
    kq, kt, ki = jax.random.split(jax.random.key(65), 3)
    queries = jax.random.randint(kq, (4, 65), -8, 8).astype(jnp.float32)
    table = jax.random.randint(kt, (48, 65), -8, 8).astype(jnp.float32)
    ids = jax.random.randint(ki, (4, 9), -1, 48, jnp.int32)
    out = gather_l2(queries, table, ids, use_pallas=True, interpret=True)
    ref = gather_l2_ref(queries, table, ids)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_gather_l2_q8_pad_lane_roundtrip_dim65_exact():
    kq, kt, ks, ki = jax.random.split(jax.random.key(66), 4)
    queries = jax.random.randint(kq, (4, 65), -8, 8).astype(jnp.float32)
    qtable = jax.random.randint(kt, (48, 65), -127, 128).astype(jnp.int8)
    # power-of-two scales keep dequant products exact in f32
    scales = 2.0 ** jax.random.randint(ks, (48,), -3, 3).astype(jnp.float32)
    ids = jax.random.randint(ki, (4, 9), -1, 48, jnp.int32)
    out = gather_l2_q8(queries, qtable, scales, ids, use_pallas=True,
                       interpret=True)
    ref = gather_l2_q8_ref(queries, qtable, scales, ids)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_gather_l2_dim_mismatch_guard():
    queries = jnp.zeros((2, 65))
    table = jnp.zeros((8, 64))
    ids = jnp.zeros((2, 3), jnp.int32)
    with pytest.raises(ValueError, match="dim"):
        gather_l2(queries, table, ids, use_pallas=True, interpret=True)
    with pytest.raises(ValueError, match="dim"):
        gather_l2_q8(queries, table.astype(jnp.int8), jnp.ones(8), ids,
                     use_pallas=True, interpret=True)


# ---------------------------------------------------------------------------
# simhash
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,m", [(256, 128, 64), (512, 64, 128),
                                   (256, 256, 32)])
def test_simhash_encode_kernel_sweep(n, d, m):
    kx, kp = jax.random.split(jax.random.key(n + d + m))
    x = jax.random.normal(kx, (n, d))
    proj = jax.random.normal(kp, (m, d))
    out = simhash_encode_pallas(x, proj, block_n=256, interpret=True)
    ref = simhash_encode_ref(x, proj)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("q,n,m", [(8, 512, 64), (16, 1024, 128)])
def test_collision_count_kernel_sweep(q, n, m):
    kx, ky, kp = jax.random.split(jax.random.key(q + n + m), 3)
    proj = jax.random.normal(kp, (m, 32))
    cq = simhash_encode_ref(jax.random.normal(kx, (q, 32)), proj)
    cc = simhash_encode_ref(jax.random.normal(ky, (n, 32)), proj)
    out = collision_count_pallas(cq, cc, m, block_q=8, block_n=512,
                                 interpret=True)
    ref = collision_count_ref(cq, cc, m)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_simhash_ops_ragged():
    x = jax.random.normal(jax.random.key(0), (100, 48))
    proj = jax.random.normal(jax.random.key(1), (64, 48))
    out = simhash_encode(x, proj, use_pallas=True, interpret=True)
    ref = simhash_encode_ref(x, proj)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    cols = collision_count(out[:10], out, 64, use_pallas=True, interpret=True)
    refc = collision_count_ref(ref[:10], ref, 64)
    np.testing.assert_array_equal(np.asarray(cols), np.asarray(refc))


# ---------------------------------------------------------------------------
# property: kernel/oracle agreement on random shapes
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=6),
       st.integers(min_value=1, max_value=40),
       st.sampled_from([64, 128, 200]))
def test_property_l2_ops_any_shape(q, n, d):
    kq, kc = jax.random.split(jax.random.key(q * 1000 + n * 10 + d))
    queries = jax.random.normal(kq, (q, d))
    cands = jax.random.normal(kc, (n, d))
    out = l2_distance(queries, cands, use_pallas=True, interpret=True)
    ref = l2_distance_ref(queries, cands)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-3,
                               atol=1e-3)
