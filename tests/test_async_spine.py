"""Async serving spine tests (DESIGN.md §13): two-phase dispatch /
collect parity, merge tie-break stability, and non-blocking
(double-buffered) consolidation with atomic cutover.

Runs on however many devices the session exposes — the dispatch
contract is about *ordering* (enqueue everything, then block), which
holds on one device too; the wall-clock win needs one device per shard
and is measured by ``benchmarks/serve_load.py``'s fanout probe.
"""

import numpy as np
import pytest

from repro.core import hnsw
from repro.core.backend import (
    MaintenanceReport,
    SearchHandle,
    SearchParams,
    SearchResult,
    merge_topk,
)
from repro.core.distributed import ShardedBackend
from repro.core.index import LSMVecIndex, brute_force_knn, recall_at_k
from repro.data.synth import make_clustered_vectors


def make_data(n, dim=32, seed=0):
    return make_clustered_vectors(n, dim=dim, seed=seed, clusters=16)


CFG = hnsw.HNSWConfig(cap=1024, dim=32, M=12, M_up=6, num_upper=2,
                      ef_search=48, ef_construction=48, k=10,
                      rho=1.0, use_filter=False, lsm_mem_cap=128,
                      lsm_levels=2, lsm_fanout=8)
LAZY = CFG._replace(lazy_delete=True)


# ---------------------------------------------------------------------------
# merge_topk tie-break stability
# ---------------------------------------------------------------------------

def test_merge_topk_ties_resolve_to_lower_shard_index():
    """Equal distances across shards must resolve deterministically to
    the earlier shard's candidate — the stable P-way merge contract."""
    d = np.array([[1.0, 2.0, 3.0]], np.float32)
    s0 = (np.array([[10, 11, 12]], np.int64), d)
    s1 = (np.array([[20, 21, 22]], np.int64), d.copy())
    res = merge_topk([s0[0], s1[0]], [s0[1], s1[1]], k=4)
    # tie at 1.0: shard 0's id 10 precedes shard 1's id 20, and so on
    np.testing.assert_array_equal(res.ids, [[10, 20, 11, 21]])
    np.testing.assert_array_equal(res.dists, [[1.0, 1.0, 2.0, 2.0]])


def test_merge_topk_is_a_permutation_stable_merge():
    """Shuffling which shard holds which candidates changes only the
    tie order (by design), never the returned candidate *set* per row,
    and identical shard contents in a different shard order merge ties
    toward the new lower index."""
    rng = np.random.default_rng(0)
    d0 = np.sort(rng.random((4, 6)).astype(np.float32), axis=1)
    d1 = np.sort(rng.random((4, 6)).astype(np.float32), axis=1)
    i0 = rng.integers(0, 500, (4, 6)).astype(np.int64)
    i1 = rng.integers(500, 1000, (4, 6)).astype(np.int64)
    a = merge_topk([i0, i1], [d0, d1], k=8)
    b = merge_topk([i1, i0], [d1, d0], k=8)
    # distances agree exactly; candidate sets per row agree
    np.testing.assert_array_equal(a.dists, b.dists)
    for ra, rb in zip(a.ids, b.ids):
        assert set(ra.tolist()) == set(rb.tolist())


def test_merge_topk_single_shard_is_identity():
    ids = np.array([[3, 1, 9]], np.int64)
    dists = np.array([[0.1, 0.5, 0.9]], np.float32)
    res = merge_topk([ids], [dists], k=3)
    np.testing.assert_array_equal(res.ids, ids)
    np.testing.assert_array_equal(res.dists, dists)


def test_merge_topk_pads_stay_last():
    ids = np.array([[5, -1, -1]], np.int64)
    dists = np.array([[0.4, np.inf, np.inf]], np.float32)
    other = (np.array([[7, -1, -1]], np.int64),
             np.array([[0.2, np.inf, np.inf]], np.float32))
    res = merge_topk([ids, other[0]], [dists, other[1]], k=4)
    np.testing.assert_array_equal(res.ids[0][:2], [7, 5])
    assert (res.ids[0][2:] == -1).all()
    assert np.isinf(res.dists[0][2:]).all()


# ---------------------------------------------------------------------------
# two-phase dispatch / collect parity
# ---------------------------------------------------------------------------

def _churn(backend, seed):
    """Interleave deletes of served ids and fresh inserts (tombstone
    churn) so parity is checked against a live, damaged graph."""
    rng = np.random.default_rng(seed)
    born = np.asarray(backend.initial_ids(), np.int64)
    victims = rng.choice(born, 40, replace=False)
    backend.delete_batch(victims)
    backend.insert_batch(make_data(24, seed=seed + 1) + 50.0)
    return victims


@pytest.mark.parametrize("shards", [1, 4])
def test_dispatch_collect_matches_blocking_search(shards):
    """search() is defined as dispatch+collect; an explicit two-phase
    round trip must be bit-identical to the one-call path, before and
    after tombstone churn, for 1 and 4 shards."""
    base = make_data(512, seed=1)
    if shards == 1:
        be = LSMVecIndex.build(LAZY, base)
    else:
        be = ShardedBackend(LAZY._replace(cap=256), shards).build(base)
    queries = make_data(16, seed=2)
    for phase in range(2):
        h = be.dispatch_search(queries, k=10)
        assert isinstance(h, SearchHandle)
        sync = be.search(queries, k=10)
        res = h.collect()
        assert isinstance(res, SearchResult)
        np.testing.assert_array_equal(res.ids, sync.ids)
        np.testing.assert_array_equal(res.dists, sync.dists)
        if phase == 0:
            _churn(be, seed=3)


def test_shards1_matches_bare_index_bitwise():
    """The sharded fan-out at P=1 is the single-device search exactly:
    same ids, same distances, the §13 bit-parity anchor."""
    base = make_data(384, seed=4)
    single = LSMVecIndex.build(LAZY, base)
    sharded = ShardedBackend(LAZY, 1).build(base)
    queries = make_data(12, seed=5)
    for be in (single, sharded):
        _churn(be, seed=6)
    a = single.search(queries, k=10)
    b = sharded.search(queries, k=10)
    np.testing.assert_array_equal(a.ids, b.ids)
    np.testing.assert_array_equal(a.dists, b.dists)


def test_dispatch_interleaving_does_not_change_results():
    """Handles dispatched before other queries' device work still
    collect their own results (no cross-talk between in-flight
    dispatches)."""
    base = make_data(256, seed=7)
    be = LSMVecIndex.build(CFG, base)
    q1 = make_data(8, seed=8)
    q2 = make_data(8, seed=9)
    want1 = be.search(q1, k=5)
    want2 = be.search(q2, k=5)
    h1 = be.dispatch_search(q1, k=5)
    h2 = be.dispatch_search(q2, k=5)
    r2, r1 = h2.collect(), h1.collect()      # collect out of order
    np.testing.assert_array_equal(r1.ids, want1.ids)
    np.testing.assert_array_equal(r2.ids, want2.ids)


def test_search_params_resolution_single_site():
    """None fields resolve from the backend config exactly once, at the
    dispatch boundary; explicit fields win."""
    p = SearchParams().resolve(CFG)
    assert (p.rho, p.ef, p.use_filter, p.n_expand) == (
        CFG.rho, CFG.ef_search, CFG.use_filter, CFG.n_expand)
    assert p.record_heat is True             # index-level default
    q = SearchParams(rho=0.5, record_heat=False).resolve(CFG)
    assert q.rho == 0.5 and q.record_heat is False
    # params route: narrower ef returns at most the same recall work
    base = make_data(256, seed=10)
    idx = LSMVecIndex.build(CFG, base)
    r1 = idx.search(base[:4], k=5, params=SearchParams(ef=16))
    r2 = idx.search(base[:4], k=5)
    assert r1.ids.shape == r2.ids.shape


# ---------------------------------------------------------------------------
# non-blocking consolidation: begin / poll / write-barrier cutover
# ---------------------------------------------------------------------------

def _tombstoned_index(seed=11, n=512, n_del=120):
    data = make_data(n, seed=seed)
    idx = LSMVecIndex.build(LAZY, data)
    rng = np.random.default_rng(seed)
    victims = rng.choice(n, n_del, replace=False).astype(np.int64)
    idx.delete_batch(victims)
    return idx, data, victims


def test_overlapped_consolidate_matches_sync_consolidate():
    """begin+poll lands bit-identically where the stop-the-world
    consolidate lands: same reclaimed count, same final state arrays."""
    idx_a, _, _ = _tombstoned_index()
    idx_b = idx_a.clone()
    rep_sync = idx_a.maintain("consolidate")
    assert isinstance(rep_sync, MaintenanceReport) and rep_sync.applied

    assert idx_b.begin_maintain("consolidate")
    assert idx_b.maintenance_pending
    rep = idx_b.poll_maintain(block=True)
    assert rep is not None and rep.applied
    assert rep.detail.get("overlapped") is True
    assert rep.reclaimed == rep_sync.reclaimed
    assert not idx_b.maintenance_pending
    for name, a, b in zip(hnsw.HNSWState._fields,
                          idx_a.state, idx_b.state):
        if name == "store":
            continue           # LSM flush timing may differ, content not
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


def test_queries_during_inflight_repair_serve_live_state():
    """Between begin and cutover, searches still run against the
    pre-repair live state and never return tombstoned ids."""
    idx, data, victims = _tombstoned_index(seed=12)
    queries = data[victims[:8]]
    assert idx.begin_maintain("consolidate")
    res = idx.search(queries, k=10)          # repair still in flight
    assert not (set(res.ids.flatten().tolist()) & set(victims.tolist()))
    rep = idx.poll_maintain(block=True)
    assert rep is not None and rep.applied
    res2 = idx.search(queries, k=10)
    assert not (set(res2.ids.flatten().tolist()) & set(victims.tolist()))


def test_write_barrier_claims_inflight_repair():
    """A mutation arriving mid-repair forces the cutover first (the
    write barrier), and the finished report is still claimable —
    exactly once — afterwards."""
    idx, _, _ = _tombstoned_index(seed=13)
    pre_tomb = idx.n_tombstones
    assert pre_tomb > 0
    assert idx.begin_maintain("consolidate")
    idx.insert_batch(make_data(8, seed=14) + 80.0)   # barrier -> cutover
    assert idx.n_tombstones == 0             # repair landed before insert
    rep = idx.poll_maintain()
    assert rep is not None and rep.applied and rep.reclaimed == pre_tomb
    assert idx.poll_maintain(block=True) is None     # claimed exactly once


def test_begin_maintain_noop_without_pressure():
    data = make_data(128, seed=15)
    idx = LSMVecIndex.build(LAZY, data)
    assert not idx.begin_maintain("consolidate")
    assert not idx.maintenance_pending
    assert idx.poll_maintain(block=True) is None


def test_sharded_overlapped_consolidate_aggregates_shards():
    base = make_data(512, seed=16)
    be = ShardedBackend(LAZY._replace(cap=352), 2).build(base)
    rng = np.random.default_rng(17)
    born = np.asarray(be.initial_ids(), np.int64)
    victims = rng.choice(born, 160, replace=False)
    be.delete_batch(victims)
    assert be.begin_maintain("consolidate", ratio=0.1)
    rep = be.poll_maintain(block=True)
    assert rep is not None and rep.applied
    assert rep.reclaimed == 160
    assert rep.detail["shards"] == [0, 1]
    assert sum(be.consolidations) >= 2
    # post-cutover recall over the survivors holds
    inv = np.full(be.cap, -1, np.int64)
    inv[born] = np.arange(len(born))
    live = np.ones(512, bool)
    live[inv[victims]] = False
    queries = make_data(16, seed=18)
    res = be.search(queries, k=10)
    ids = np.where(res.ids >= 0, inv[np.maximum(res.ids, 0)], -1)
    import jax.numpy as jnp
    truth = brute_force_knn(jnp.asarray(base), jnp.asarray(queries), 10,
                            live=jnp.asarray(live))
    assert recall_at_k(ids, truth) >= 0.7


def test_maintain_uniform_reports():
    """compact / reorder / consolidate all answer through one
    MaintenanceReport shape."""
    idx, _, _ = _tombstoned_index(seed=19, n=256, n_del=60)
    rep_c = idx.maintain("consolidate")
    assert rep_c.op == "consolidate" and rep_c.applied
    rep_k = idx.maintain("compact")
    assert rep_k.op == "compact" and rep_k.applied
    idx.search(make_data(8, seed=20), k=5)   # heat for the reorder
    rep_r = idx.maintain("reorder", window=8, lam=1.0)
    assert rep_r.op == "reorder" and rep_r.perm is not None
    assert sorted(rep_r.perm.tolist()) == list(range(len(rep_r.perm)))
    with pytest.raises(ValueError):
        idx.maintain("no-such-op")
