"""Property tests for the chunked linear-recurrence core (Mamba2/RWKV6)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # skips gracefully when absent

from repro.models.linear_scan import chunked_linear_attention, recurrent_step, reference_scan


def _mk(seed, b, t, h, dk, dv, decay_scale, scalar):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0, 1, (b, t, h, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, t, h, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, t, h, dv)), jnp.float32)
    da = 1 if scalar else dk
    la = jnp.asarray(-np.abs(rng.normal(0, decay_scale, (b, t, h, da))),
                     jnp.float32)
    return q, k, v, la


@pytest.mark.parametrize("scalar,decay", [(True, 0.5), (True, 8.0),
                                          (False, 0.05), (False, 0.5)])
@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_chunked_matches_reference(scalar, decay, chunk):
    q, k, v, la = _mk(0, 2, 16, 3, 8, 4, decay, scalar)
    out_c, s_c = chunked_linear_attention(q, k, v, la, chunk=chunk)
    out_r, s_r = reference_scan(q, k, v, la)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_r),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("chunk", [4, 16])
def test_chunked_rwkv_bonus_matches_reference(chunk):
    q, k, v, la = _mk(1, 2, 16, 3, 8, 8, 0.05, scalar=False)
    u = jnp.asarray(np.random.default_rng(2).normal(0, 1, (3, 8)),
                    jnp.float32)
    out_c, s_c = chunked_linear_attention(q, k, v, la, chunk=chunk, bonus=u)
    out_r, s_r = reference_scan(q, k, v, la, bonus=u)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_r),
                               rtol=1e-4, atol=1e-4)


def test_ragged_t_padding():
    q, k, v, la = _mk(3, 1, 13, 2, 4, 4, 0.3, scalar=True)
    out_c, s_c = chunked_linear_attention(q, k, v, la, chunk=8)
    out_r, s_r = reference_scan(q, k, v, la)
    assert out_c.shape == (1, 13, 2, 4)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_r),
                               rtol=1e-4, atol=1e-4)


def test_initial_state_carries():
    """Splitting a sequence in half through the state == one pass."""
    q, k, v, la = _mk(4, 1, 16, 2, 4, 4, 0.3, scalar=True)
    out_full, s_full = chunked_linear_attention(q, k, v, la, chunk=4)
    out_a, s_a = chunked_linear_attention(q[:, :8], k[:, :8], v[:, :8],
                                          la[:, :8], chunk=4)
    out_b, s_b = chunked_linear_attention(q[:, 8:], k[:, 8:], v[:, 8:],
                                          la[:, 8:], chunk=4,
                                          initial_state=s_a)
    np.testing.assert_allclose(np.asarray(out_b),
                               np.asarray(out_full[:, 8:]), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_b), np.asarray(s_full),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 100), st.sampled_from([4, 8]),
       st.booleans())
def test_property_chunked_equals_scan(seed, chunk, scalar):
    q, k, v, la = _mk(seed, 1, 8, 2, 4, 4, 0.4, scalar)
    out_c, _ = chunked_linear_attention(q, k, v, la, chunk=chunk)
    out_r, _ = reference_scan(q, k, v, la)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_r),
                               rtol=1e-3, atol=1e-3)


def test_decode_step_chains_to_full():
    q, k, v, la = _mk(5, 2, 6, 2, 4, 4, 0.3, scalar=False)
    out_r, _ = reference_scan(q, k, v, la)
    s = jnp.zeros((2, 2, 4, 4), jnp.float32)
    for t in range(6):
        o, s = recurrent_step(s, q[:, t], k[:, t], v[:, t], la[:, t])
        np.testing.assert_allclose(np.asarray(o), np.asarray(out_r[:, t]),
                                   rtol=1e-4, atol=1e-4)
