"""Tests for the serve-path write-ahead log (repro.serve.wal,
DESIGN.md §11): record round-trips, torn-tail and corruption recovery,
segment rotation/retention, and group-commit accounting."""

import os

import numpy as np

from repro.serve.wal import KIND_DELETE, KIND_INSERT, NO_LSN, WalConfig, WriteAheadLog


def _wal(tmp_path, **kw):
    return WriteAheadLog(WalConfig(dir=str(tmp_path / "wal"), **kw))


def _segments(w):
    return sorted(n for n in os.listdir(w.cfg.dir) if n.endswith(".log"))


# ---------------------------------------------------------------------------
# append / reopen round-trip
# ---------------------------------------------------------------------------

def test_roundtrip_insert_and_delete_records(tmp_path):
    w = _wal(tmp_path)
    ids = np.arange(4, dtype=np.int64)
    vecs = np.arange(4 * 8, dtype=np.float32).reshape(4, 8)
    assert w.append_insert(ids, vecs) == 1
    assert w.append_delete(np.array([7, 3], np.int64)) == 2
    w.sync()
    w.close()

    w2 = _wal(tmp_path)
    recs = w2.records()
    assert [r.lsn for r in recs] == [1, 2]
    assert recs[0].kind == KIND_INSERT
    np.testing.assert_array_equal(recs[0].ext_ids, ids)
    np.testing.assert_array_equal(recs[0].vectors, vecs)
    assert recs[1].kind == KIND_DELETE
    np.testing.assert_array_equal(recs[1].ext_ids, [7, 3])
    assert recs[1].vectors is None
    assert w2.last_lsn == 2
    # the `after` cut is exclusive
    assert [r.lsn for r in w2.records(after=1)] == [2]
    assert w2.records(after=2) == []
    w2.close()


def test_lsns_are_monotonic_across_reopen(tmp_path):
    w = _wal(tmp_path)
    for _ in range(3):
        w.append_delete(np.array([0], np.int64))
    w.sync()
    w.close()
    w2 = _wal(tmp_path)
    assert w2.append_delete(np.array([1], np.int64)) == 4
    w2.close()


def test_unsynced_records_are_visible_after_reopen_if_flushed(tmp_path):
    # close() syncs; this asserts the append->close->reopen path only
    w = _wal(tmp_path)
    w.append_delete(np.array([5], np.int64))
    assert w.synced_lsn == NO_LSN and w.n_unsynced == 1
    w.close()
    w2 = _wal(tmp_path)
    assert w2.last_lsn == 1
    w2.close()


# ---------------------------------------------------------------------------
# crash recovery: torn tails, corruption, chain breaks
# ---------------------------------------------------------------------------

def test_torn_tail_is_truncated_to_last_valid_record(tmp_path):
    w = _wal(tmp_path)
    for i in range(4):
        w.append_insert(np.array([i], np.int64),
                        np.full((1, 8), i, np.float32))
    w.sync()
    w.close()
    seg = os.path.join(str(tmp_path / "wal"), _segments_path(tmp_path)[-1])
    size = os.path.getsize(seg)
    with open(seg, "r+b") as f:
        f.truncate(size - 5)           # partial final record

    w2 = _wal(tmp_path)
    assert w2.last_lsn == 3
    assert [r.lsn for r in w2.records()] == [1, 2, 3]
    # the chain continues cleanly after truncation
    assert w2.append_delete(np.array([0], np.int64)) == 4
    w2.sync()
    w2.close()
    w3 = _wal(tmp_path)
    assert [r.lsn for r in w3.records()] == [1, 2, 3, 4]
    w3.close()


def test_corrupt_record_drops_it_and_everything_after(tmp_path):
    w = _wal(tmp_path, segment_bytes=100)   # force several segments
    for i in range(10):
        w.append_delete(np.array([i], np.int64))
    w.sync()
    w.close()
    segs = _segments_path(tmp_path)
    assert len(segs) > 2
    # flip one payload byte mid-way through the second segment
    seg = os.path.join(str(tmp_path / "wal"), segs[1])
    with open(seg, "r+b") as f:
        f.seek(12)
        b = f.read(1)
        f.seek(12)
        f.write(bytes([b[0] ^ 0xFF]))

    w2 = _wal(tmp_path)
    recs = w2.records()
    # prefix before the corruption survives; suffix segments are gone
    assert recs and recs[-1].lsn < 10
    assert [r.lsn for r in recs] == list(range(1, recs[-1].lsn + 1))
    w2.close()


def _segments_path(tmp_path):
    d = str(tmp_path / "wal")
    return sorted(n for n in os.listdir(d) if n.endswith(".log"))


# ---------------------------------------------------------------------------
# rotation + checkpoint truncation
# ---------------------------------------------------------------------------

def test_segment_rotation_at_size_threshold(tmp_path):
    w = _wal(tmp_path, segment_bytes=256)
    for i in range(12):
        w.append_delete(np.array([i], np.int64))
    w.sync()
    assert len(_segments(w)) > 1
    # reopen sees one contiguous chain across segments
    w.close()
    w2 = _wal(tmp_path, segment_bytes=256)
    assert [r.lsn for r in w2.records()] == list(range(1, 13))
    w2.close()


def test_truncate_through_drops_covered_segments(tmp_path):
    w = _wal(tmp_path, segment_bytes=256)
    for i in range(20):
        w.append_delete(np.array([i], np.int64))
    w.sync()
    before = len(_segments(w))
    removed = w.truncate_through(10)
    assert removed > 0
    # covered closed segments gone; the active one may have rotated
    assert len(_segments(w)) in (before - removed, before - removed + 1)
    # appends continue, and a reopen rebuilds the chain from mid-stream
    assert w.append_delete(np.array([99], np.int64)) == 21
    w.sync()
    w.close()
    w2 = _wal(tmp_path, segment_bytes=256)
    lsns = [r.lsn for r in w2.records()]
    assert lsns[-1] == 21 and lsns == list(range(lsns[0], 22))
    assert w2.records(after=20)[0].lsn == 21
    w2.close()


def test_lsn_high_water_mark_survives_repeated_reopen(tmp_path):
    """After a covering checkpoint truncates every record-bearing
    segment, the rotated-out empty tail segment's filename is the only
    durable copy of the LSN high-water mark.  A scan must keep it:
    unlinking it meant the restart-after-next reseeded LSNs from 1,
    and recovery's records(after=covering) filtered out every new
    acked write (the REVIEW.md high-severity loss)."""
    w = _wal(tmp_path)
    for i in range(5):
        w.append_delete(np.array([i], np.int64))
    w.sync()
    assert w.truncate_through(5) > 0       # rotates to an empty tail
    w.close()

    w2 = _wal(tmp_path)                    # restart 1: dir has only the
    assert w2.last_lsn == 5                # empty tail
    assert w2.records() == []
    w2.close()

    w3 = _wal(tmp_path)                    # restart 2: mark must survive
    assert w3.last_lsn == 5
    assert w3.append_delete(np.array([9], np.int64)) == 6
    w3.sync()
    w3.close()

    w4 = _wal(tmp_path)
    assert [r.lsn for r in w4.records(after=5)] == [6]
    w4.close()


def test_truncate_through_empty_active_segment_is_stable(tmp_path):
    # repeated truncation at the same covered LSN must not rotate the
    # (already empty) active segment into duplicate entries
    w = _wal(tmp_path)
    for i in range(4):
        w.append_delete(np.array([i], np.int64))
    w.sync()
    w.truncate_through(4)
    n_segs = len(_segments(w))
    w.truncate_through(4)
    assert len(_segments(w)) == n_segs
    assert len(w._segments) == 1
    assert w.append_delete(np.array([8], np.int64)) == 5
    w.sync()
    w.close()
    w2 = _wal(tmp_path)
    assert [r.lsn for r in w2.records()] == [5]
    w2.close()


def test_abandon_drops_buffered_records_without_flush(tmp_path):
    """Simulated process death: abandon() must release the fd without
    flushing, so a dead engine's buffered (unsynced, possibly
    duplicate-LSN) bytes can never land in the segment a recovered
    log is appending to."""
    import gc

    w = _wal(tmp_path)
    w.append_delete(np.array([0], np.int64))
    w.sync()
    w.append_delete(np.array([1], np.int64))   # buffered only
    w.abandon()

    w2 = _wal(tmp_path)                        # recovered log, same dir
    assert [r.lsn for r in w2.records()] == [1]
    assert w2.append_delete(np.array([2], np.int64)) == 2
    w2.sync()
    del w                                      # GC of the dead writer
    gc.collect()                               # must not flush LSN-2 dup
    w2.close()

    w3 = _wal(tmp_path)
    assert [r.lsn for r in w3.records()] == [1, 2]
    w3.close()


def test_truncate_through_below_first_segment_is_noop(tmp_path):
    w = _wal(tmp_path)
    for i in range(3):
        w.append_delete(np.array([i], np.int64))
    w.sync()
    assert w.truncate_through(0) == 0
    w.close()
    # nothing was dropped: a reopen recovers the full chain
    w2 = _wal(tmp_path)
    assert [r.lsn for r in w2.records()] == [1, 2, 3]
    w2.close()


# ---------------------------------------------------------------------------
# group-commit accounting
# ---------------------------------------------------------------------------

def test_sync_covers_everything_appended(tmp_path):
    w = _wal(tmp_path)
    for i in range(5):
        w.append_delete(np.array([i], np.int64))
    assert w.n_unsynced == 5 and w.synced_lsn == NO_LSN
    covered = w.sync()
    assert covered == 5 == w.synced_lsn
    assert w.n_unsynced == 0
    assert w.n_syncs == 1
    # idle sync is free (no extra fsync)
    w.sync()
    assert w.n_syncs == 1
    w.close()


def test_flush_only_mode_skips_fsync(tmp_path):
    w = _wal(tmp_path, sync=False)
    w.append_delete(np.array([1], np.int64))
    assert w.sync() == 1          # still advances the covered LSN
    w.close()


def test_record_and_byte_counters(tmp_path):
    w = _wal(tmp_path)
    w.append_insert(np.array([0], np.int64), np.zeros((1, 4), np.float32))
    w.append_delete(np.array([0], np.int64))
    assert w.n_records == 2
    assert w.bytes_appended > 0
    w.close()
