"""Distributed-index + sharding-rule tests (8 fake host devices)."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hnsw
from repro.core.distributed import ShardedBackend, ShardedFlatIndex
from repro.core.index import brute_force_knn, recall_at_k
from repro.data.synth import make_clustered_vectors
from repro.launch.mesh import make_test_mesh
from repro.launch.sharding import data_sharding, param_spec, tree_shardings


def test_sharded_flat_exact():
    mesh = make_test_mesh((8,), ("data",))
    data = make_clustered_vectors(1000, dim=32, seed=0)
    queries = make_clustered_vectors(16, dim=32, seed=7)
    idx = ShardedFlatIndex(mesh).build(data)
    ids, dists = idx.search(queries, k=10)
    truth = brute_force_knn(jnp.asarray(data), jnp.asarray(queries), 10)
    assert recall_at_k(ids, truth) == 1.0  # exact partitioned search


def test_sharded_flat_2d_mesh():
    mesh = make_test_mesh((2, 4), ("data", "model"))
    data = make_clustered_vectors(512, dim=16, seed=1)
    queries = make_clustered_vectors(8, dim=16, seed=8)
    idx = ShardedFlatIndex(mesh).build(data)
    ids, _ = idx.search(queries, k=5)
    truth = brute_force_knn(jnp.asarray(data), jnp.asarray(queries), 5)
    assert recall_at_k(ids, truth) == 1.0


def test_sharded_backend_recall():
    cfg = hnsw.HNSWConfig(cap=512, dim=32, M=12, M_up=6, num_upper=2,
                          ef_search=48, ef_construction=48, k=10,
                          rho=1.0, use_filter=False, lsm_mem_cap=128,
                          lsm_levels=2, lsm_fanout=8)
    data = make_clustered_vectors(1024, dim=32, seed=2)
    queries = make_clustered_vectors(16, dim=32, seed=9)
    idx = ShardedBackend(cfg, n_shards=4).build(data)
    res = idx.search(queries, k=10)
    # global ids -> build-order positions (what the truth is keyed by)
    inv = np.full(idx.cap, -1, np.int64)
    born = idx.initial_ids()
    inv[born] = np.arange(len(born))
    ids = np.where(res.ids >= 0, inv[np.maximum(res.ids, 0)], -1)
    truth = brute_force_knn(jnp.asarray(data), jnp.asarray(queries), 10)
    r = recall_at_k(ids, truth)
    assert r >= 0.85, f"sharded recall {r:.3f}"


def test_param_shardings_cover_tree():
    """Every parameter leaf gets a valid NamedSharding on a small mesh."""
    from repro import configs
    from repro.launch import steps
    mesh = make_test_mesh((2, 4), ("data", "model"))
    for arch in ("qwen3-8b", "deepseek-v2-236b", "zamba2-7b", "rwkv6-3b"):
        cfg = configs.get_config(arch, "smoke")
        params = steps.abstract_params(cfg)
        shardings = tree_shardings(mesh, params, param_spec)
        for path, s in jax.tree_util.tree_flatten_with_path(shardings)[0]:
            assert s.mesh.devices.size == 8


def test_data_sharding_batch_divisibility():
    mesh = make_test_mesh((2, 4), ("data", "model"))
    s8 = data_sharding(mesh, nd=2, batch_size=8)
    s1 = data_sharding(mesh, nd=2, batch_size=1)
    assert s8.spec[0] is not None
    assert s1.spec[0] is None  # batch=1 cannot shard -> replicate


def test_small_mesh_train_step_runs():
    """End-to-end sharded train step actually executes on 8 CPU devices."""
    from repro import configs
    from repro.launch import steps
    from repro.optim import adamw_init
    mesh = make_test_mesh((2, 4), ("data", "model"))
    cfg = configs.get_config("qwen3-8b", "smoke")
    params = jax.jit(lambda: __import__(
        "repro.models.transformer", fromlist=["init_params"]
    ).init_params(cfg, jax.random.key(0)))()
    opt = adamw_init(params)
    tokens = jnp.zeros((8, 16), jnp.int32)
    labels = jnp.ones((8, 16), jnp.int32)
    p_sh = tree_shardings(mesh, params, param_spec)
    o_sh = tree_shardings(mesh, opt, param_spec)
    b_sh = {"tokens": data_sharding(mesh, 2, 8),
            "labels": data_sharding(mesh, 2, 8)}
    step = steps.make_train_step(cfg)
    with jax.sharding.set_mesh(mesh):
        jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None))
        params2, opt2, metrics = jitted(
            params, opt, {"tokens": tokens, "labels": labels})
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(opt2.step) == 1
