"""Per-arch smoke tests: reduced config, one forward + train-ish step on CPU,
asserting output shapes and no NaNs; plus a decode-step consistency check."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T

ARCH_NAMES = sorted(configs.ARCHS)


def _inputs(cfg, batch=2, seq=16, seed=0):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)),
                         jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)),
                         jnp.int32)
    img = None
    if cfg.num_img_tokens:
        img = jnp.asarray(rng.normal(0, 1, (batch, cfg.num_img_tokens,
                                            cfg.d_model)), jnp.float32)
    return tokens, labels, img


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = configs.get_config(arch, "smoke")
    params = T.init_params(cfg, jax.random.key(0))
    tokens, labels, img = _inputs(cfg)
    logits, aux = T.forward(params, cfg, tokens, img)
    exp_s = tokens.shape[1] + cfg.num_img_tokens
    assert logits.shape == (2, exp_s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_loss_and_grad_step(arch):
    """One forward/backward step: finite loss, finite non-zero grads."""
    cfg = configs.get_config(arch, "smoke")
    params = T.init_params(cfg, jax.random.key(0))
    tokens, labels, img = _inputs(cfg)

    loss, grads = jax.value_and_grad(
        lambda p: T.loss_fn(p, cfg, tokens, labels, img))(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    flat = jax.tree.leaves(grads)
    norms = [float(jnp.linalg.norm(g.astype(jnp.float32))) for g in flat]
    assert all(np.isfinite(n) for n in norms), f"{arch}: NaN grads"
    assert any(n > 0 for n in norms), f"{arch}: all-zero grads"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_decode_matches_forward(arch):
    """Greedy decode logits == teacher-forced forward logits, step by step.

    This is the KV-cache/recurrent-state correctness test: decoding token
    t with the cache must reproduce the full-sequence forward at position
    t (tolerances cover the chunked-vs-recurrent scan reorderings).
    """
    cfg = configs.get_config(arch, "smoke")
    if cfg.num_img_tokens:
        pytest.skip("vlm decode exercised via prefill test")
    params = T.init_params(cfg, jax.random.key(0))
    batch, seq = 2, 8
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)),
                         jnp.int32)
    full_logits, _ = T.forward(params, cfg, tokens)

    state = T.init_decode_state(cfg, batch, max_len=seq)
    outs = []
    for t in range(seq):
        logit, state = T.decode_step(params, cfg, state, tokens[:, t:t + 1])
        outs.append(logit[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["qwen3-8b", "zamba2-7b", "rwkv6-3b",
                                  "deepseek-v2-236b"])
def test_smoke_prefill_then_decode(arch):
    """prefill(S tokens) then decode continues identically to forward."""
    cfg = configs.get_config(arch, "smoke")
    params = T.init_params(cfg, jax.random.key(0))
    batch, seq = 2, 8
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq + 1)),
                         jnp.int32)
    full_logits, _ = T.forward(params, cfg, tokens)

    last, state = T.prefill(params, cfg, tokens[:, :seq],
                            max_len=seq + 4)
    np.testing.assert_allclose(np.asarray(last[:, 0], np.float32),
                               np.asarray(full_logits[:, seq - 1],
                                          np.float32),
                               rtol=2e-2, atol=2e-2)
    nxt, state = T.decode_step(params, cfg, state, tokens[:, seq:seq + 1])
    np.testing.assert_allclose(np.asarray(nxt[:, 0], np.float32),
                               np.asarray(full_logits[:, seq], np.float32),
                               rtol=2e-2, atol=2e-2)


def test_full_configs_construct():
    """The published (full) configs are well-formed (no allocation)."""
    for arch in ARCH_NAMES:
        cfg = configs.get_config(arch, "full")
        assert cfg.num_layers > 0 and cfg.d_model > 0
        assert cfg.head_dim * cfg.num_heads >= cfg.d_model // 2
    # brief-specified exact values spot-check
    ds = configs.get_config("deepseek-v3-671b", "full")
    assert (ds.num_layers, ds.d_model, ds.num_heads,
            ds.vocab_size) == (61, 7168, 128, 129280)
    assert ds.moe.num_experts == 256 and ds.moe.top_k == 8
    rw = configs.get_config("rwkv6-3b", "full")
    assert (rw.num_layers, rw.d_model, rw.vocab_size) == (32, 2560, 65536)
