"""Tests for the DiskANN-like and SPFresh-like baselines."""

import jax.numpy as jnp
import pytest

from repro.core.baselines import DiskANNIndex, SPFreshIndex
from repro.core.index import brute_force_knn, recall_at_k
from repro.data.synth import make_clustered_vectors


def make_data(n, dim=32, seed=0, clusters=16):
    return make_clustered_vectors(n, dim=dim, seed=seed, clusters=clusters)


@pytest.fixture(scope="module")
def data():
    return make_data(1024)


@pytest.fixture(scope="module")
def queries():
    return make_data(32, seed=7)


def test_diskann_static_recall(data, queries):
    idx = DiskANNIndex.build(data, M=16, ef=64)
    ids, _ = idx.search(queries, k=10)
    truth = brute_force_knn(jnp.asarray(data), jnp.asarray(queries), 10)
    r = recall_at_k(ids, truth)
    assert r >= 0.85, f"DiskANN static recall {r:.3f}"


def test_diskann_exhaustive_io(data, queries):
    """DiskANN evaluates every neighbor: n_vec ~= hops * degree (Eq. 7)."""
    idx = DiskANNIndex.build(data, M=16, ef=64)
    idx.reset_stats()
    idx.search(queries[:8], k=10)
    hops = int(idx.io_stats.n_hops)
    fetches = int(idx.io_stats.n_vec)
    # no sampling: every not-yet-visited neighbor is fetched each hop
    assert fetches > 2 * hops


def test_diskann_delete_degrades_but_filters(data, queries):
    idx = DiskANNIndex.build(data, M=16, ef=64)
    ids0, _ = idx.search(queries, k=1)
    for v in set(ids0[:, 0].tolist()):
        idx.delete(int(v))
    ids1, _ = idx.search(queries, k=10)
    dead = set(ids0[:, 0].tolist())
    for row in ids1:
        assert not (set(row.tolist()) & dead)


def test_spfresh_build_recall_is_moderate(data, queries):
    """Coarse partitions: decent but below graph-based recall (paper §2.3)."""
    idx = SPFreshIndex.build(data, posting_cap=128, n_probe=4)
    ids, _ = idx.search(queries, k=10)
    truth = brute_force_knn(jnp.asarray(data), jnp.asarray(queries), 10)
    r = recall_at_k(ids, truth)
    assert 0.4 <= r <= 1.0, f"SPFresh recall {r:.3f}"


def test_spfresh_insert_and_split(data):
    idx = SPFreshIndex.build(data[:512], posting_cap=64, n_probe=4)
    n_post_before = len(idx.postings)
    for x in data[512:768]:
        idx.insert(x)
    assert idx.size == 768
    assert len(idx.postings) >= n_post_before  # splits may have happened
    assert all(len(p) <= idx.posting_cap for p in idx.postings)
    found, _ = idx.search(data[600][None, :], k=1)
    assert found[0, 0] >= 0


def test_spfresh_delete(data):
    idx = SPFreshIndex.build(data[:256], posting_cap=64, n_probe=4)
    ids0, _ = idx.search(data[:8], k=1)
    for v in set(ids0[:, 0].tolist()):
        idx.delete(int(v))
    ids1, _ = idx.search(data[:8], k=10)
    dead = set(ids0[:, 0].tolist())
    for row in ids1:
        assert not (set(row.tolist()) & dead)


def test_spfresh_memory_flat_vs_diskann_growth(data):
    """Fig. 6's shape: DiskANN RAM grows with inserts, SPFresh stays flat."""
    dk = DiskANNIndex.build(data[:512], M=16, ef=48)
    sp = SPFreshIndex.build(data[:512], posting_cap=128, n_probe=4)
    dk0, sp0 = dk.memory_bytes(), sp.memory_bytes()
    for x in data[512:768]:
        dk.insert(x)
        sp.insert(x)
    dk1, sp1 = dk.memory_bytes(), sp.memory_bytes()
    dk_growth = (dk1 - dk0) / dk0
    sp_growth = (sp1 - sp0) / max(sp0, 1)
    assert dk_growth > sp_growth
    assert dk1 - dk0 >= 256 * 32 * 4  # at least the delta vectors
