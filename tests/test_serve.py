"""Tests for the online serving subsystem (repro.serve, DESIGN.md §8):
scheduler parity with bare-index execution, coalescing-window policy,
fixed-shape pad-and-mask dispatch, and maintenance triggers."""

import numpy as np
import pytest

from repro.core import HNSWConfig, LSMVecIndex
from repro.core.index import brute_force_knn, recall_at_k
from repro.data.synth import make_clustered_vectors
from repro.serve import CoalescingQueue, MaintenancePolicy, Op, Request, ServeConfig, ServeEngine

CFG = HNSWConfig(cap=2048, dim=32, M=12, M_up=6, num_upper=2,
                 ef_search=48, ef_construction=48, k=10,
                 rho=1.0, use_filter=False, lsm_mem_cap=128,
                 lsm_levels=2, lsm_fanout=8, batch_expand=4)


def make_data(n, seed=0):
    return make_clustered_vectors(n, dim=32, seed=seed, clusters=16)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _req(op, payload, seq, t=0.0):
    return Request(op=op, payload=payload, seq=seq, t_enqueue=t)


NO_MAINT = MaintenancePolicy(tombstone_ratio=None, heat_budget=None)


# ---------------------------------------------------------------------------
# coalescing queue
# ---------------------------------------------------------------------------

def _queue(strict, caps=8, window=0.005):
    return CoalescingQueue(
        batch_caps={op: caps for op in Op},
        windows={op: window for op in Op}, strict_order=strict)


def test_queue_holds_underfull_run_until_window():
    q = _queue(strict=True)
    for s in range(3):
        q.push(_req(Op.QUERY, None, s, t=0.0))
    assert q.next_batch(0.001) is None          # open run, window not up
    got = q.next_batch(0.006)                   # window expired -> release
    assert got is not None and got[0] is Op.QUERY and len(got[1]) == 3
    assert len(q) == 0


def test_queue_releases_full_run_immediately():
    q = _queue(strict=True, caps=4)
    for s in range(6):
        q.push(_req(Op.QUERY, None, s, t=0.0))
    op, run = q.next_batch(0.0)
    assert op is Op.QUERY and len(run) == 4     # cap reached, no wait
    assert len(q) == 2


def test_queue_strict_releases_at_op_boundary():
    q = _queue(strict=True)
    q.push(_req(Op.QUERY, None, 0, t=0.0))
    q.push(_req(Op.QUERY, None, 1, t=0.0))
    q.push(_req(Op.INSERT, None, 2, t=0.0))
    op, run = q.next_batch(0.0)                 # run can't grow: closed
    assert op is Op.QUERY and len(run) == 2
    assert q.next_batch(0.0) is None            # lone insert: window holds it
    op2, run2 = q.next_batch(0.006)             # ... until the window expires
    assert op2 is Op.INSERT and len(run2) == 1


def test_queue_strict_never_jumps_op_boundary():
    q = _queue(strict=True)
    q.push(_req(Op.QUERY, "a", 0, t=0.0))
    q.push(_req(Op.INSERT, None, 1, t=0.0))
    q.push(_req(Op.QUERY, "b", 2, t=0.0))
    op, run = q.next_batch(0.0)
    assert op is Op.QUERY and [r.payload for r in run] == ["a"]


def test_queue_relaxed_coalesces_across_boundary():
    q = _queue(strict=False)
    q.push(_req(Op.QUERY, "a", 0, t=0.0))
    q.push(_req(Op.INSERT, None, 1, t=0.0))
    q.push(_req(Op.QUERY, "b", 2, t=0.0))
    op, run = q.next_batch(1.0)                 # window long expired
    assert op is Op.QUERY and [r.payload for r in run] == ["a", "b"]
    op2, run2 = q.next_batch(1.0)
    assert op2 is Op.INSERT and len(run2) == 1
    assert len(q) == 0


def test_queue_force_releases_open_run():
    q = _queue(strict=False)
    q.push(_req(Op.DELETE, 3, 0, t=0.0))
    assert q.next_batch(0.0) is None
    got = q.next_batch(0.0, force=True)
    assert got is not None and got[0] is Op.DELETE


# ---------------------------------------------------------------------------
# scheduler parity: serve == the same ops applied on a bare index
# ---------------------------------------------------------------------------

def _interleaved_stream(rng, base, fresh, n_ops):
    """(op, payload) stream, ~70/15/15, deletes always of live ids."""
    stream = []
    live = list(range(len(base)))
    fi = 0
    for _ in range(n_ops):
        r = rng.random()
        if r < 0.7 or (r >= 0.85 and len(live) < 32):
            stream.append(("q", base[rng.integers(0, len(base))]))
        elif r < 0.85 and fi < len(fresh):
            stream.append(("i", fresh[fi]))
            fi += 1
        else:
            stream.append(("d", live.pop(rng.integers(0, len(live)))))
    return stream


def _expected_runs(stream, caps):
    """Strict-order coalescing: consecutive same-op runs capped per op."""
    runs = []
    for op, payload in stream:
        if runs and runs[-1][0] == op and len(runs[-1][1]) < caps[op]:
            runs[-1][1].append(payload)
        else:
            runs.append((op, [payload]))
    return runs


def test_strict_stream_parity_with_bare_index():
    """The tentpole contract: an interleaved stream through the engine
    (strict order, pad-and-mask dispatch, snapshot reads) returns ids
    identical to the same micro-batches applied directly to a bare
    LSMVecIndex, and recall matches the sequential baseline exactly."""
    base = make_data(512, seed=0)
    fresh = make_data(96, seed=1)
    idx_serve = LSMVecIndex.build(CFG, base)
    idx_bare = LSMVecIndex.build(CFG, base)
    W = 16
    eng = ServeEngine(
        idx_serve,
        ServeConfig(query_batch=W, insert_batch=W, delete_batch=W,
                    strict_order=True, query_window=0.0, insert_window=0.0,
                    delete_window=0.0, maintenance=NO_MAINT),
        clock=FakeClock())

    rng = np.random.default_rng(7)
    stream = _interleaved_stream(rng, base, fresh, 400)

    tickets = [(op, eng.submit_query(p) if op == "q" else
                eng.submit_insert(p) if op == "i" else
                eng.submit_delete(p)) for op, p in stream]
    eng.drain()

    # the engine executed exactly the strict coalescing schedule
    caps = {"q": W, "i": W, "d": W}
    expected = _expected_runs(stream, caps)
    got = [(op.value[0], n) for op, n in eng.batch_log]
    assert got == [(op, len(items)) for op, items in expected]

    # replay the same runs on the bare index through the plain (unpadded
    # search / padded update) entry points
    serve_q = iter([t.result() for op, t in tickets if op == "q"])
    for op, items in expected:
        if op == "q":
            bare = idx_bare.search(np.stack(items), k=CFG.k)
            for row_ids, row_d in zip(bare.ids, bare.dists):
                res = next(serve_q)
                np.testing.assert_array_equal(res.ids, row_ids)
                np.testing.assert_array_equal(res.dists, row_d)
        elif op == "i":
            idx_bare.insert_batch(np.stack(items), pad_to=W)
        else:
            idx_bare.delete_batch(np.asarray(items), pad_to=W)

    # insert tickets returned the bare-identical id sequence
    serve_ids = [t.result() for op, t in tickets if op == "i"]
    assert serve_ids == list(range(512, 512 + len(serve_ids)))
    assert idx_serve.size == idx_bare.size
    np.testing.assert_array_equal(np.asarray(idx_serve.state.levels),
                                  np.asarray(idx_bare.state.levels))


def test_serve_zero_retraces_after_warmup():
    base = make_data(256, seed=2)
    idx = LSMVecIndex.build(CFG, base)
    eng = ServeEngine(idx, ServeConfig(query_batch=8, insert_batch=8,
                                       delete_batch=8, maintenance=NO_MAINT),
                      clock=FakeClock())
    fresh = make_data(64, seed=3)
    rng = np.random.default_rng(4)
    # warmup: one batch of each op at ragged occupancies
    for i in range(3):
        eng.submit_insert(fresh[i])
    for i in range(5):
        eng.submit_query(base[i])
    eng.submit_delete(int(rng.integers(0, 256)))
    eng.drain()
    # second wave: an insert while the query snapshot is current compiles
    # the incremental patch path (full resolve was compiled above)
    eng.submit_query(base[0])
    eng.drain()
    eng.submit_insert(fresh[63])
    eng.drain()
    warm = idx.trace_counts()
    # sustained ragged traffic: occupancies vary, shapes must not
    fi = 3
    for round_ in range(6):
        for _ in range(int(rng.integers(1, 8))):
            eng.submit_query(base[rng.integers(0, 250)])
        if round_ % 2 == 0:
            eng.submit_insert(fresh[fi]); fi += 1
        else:
            eng.submit_delete(256 + round_)
        eng.drain()
    assert idx.trace_counts() == warm, "serving retraced after warmup"


def test_serve_recall_matches_sequential_baseline():
    """Mixed stream recall through the engine equals the recall of the
    same final index state queried directly (snapshot path is exact)."""
    base = make_data(512, seed=5)
    fresh = make_data(64, seed=6)
    idx = LSMVecIndex.build(CFG, base)
    eng = ServeEngine(idx, ServeConfig(query_batch=16, insert_batch=16,
                                       delete_batch=16, strict_order=True,
                                       maintenance=NO_MAINT),
                      clock=FakeClock())
    for x in fresh:
        eng.submit_insert(x)
    dels = list(range(0, 100, 7))
    for d in dels:
        eng.submit_delete(d)
    eng.drain()
    queries = make_data(32, seed=8)
    tickets = [eng.submit_query(q) for q in queries]
    eng.drain()
    allv = np.concatenate([base, fresh])
    live = np.ones(len(allv), bool)
    live[dels] = False
    truth = brute_force_knn(allv, queries, 10, live=live)
    found = np.stack([t.result().ids for t in tickets])
    r_serve = recall_at_k(found, truth)
    direct_ids = idx.search(queries, k=10).ids
    r_direct = recall_at_k(direct_ids, truth)
    assert r_serve == pytest.approx(r_direct, abs=1e-9)
    assert r_serve >= 0.7


# ---------------------------------------------------------------------------
# maintenance policy
# ---------------------------------------------------------------------------

def test_maintenance_compacts_on_tombstone_ratio():
    # the compact trigger counts LSM-staged deletes, which only the
    # eager delete path produces (lazy deletes are tombstone-bit-only
    # and consolidation is their compaction — see test_lazy_delete)
    base = make_data(400, seed=9)
    idx = LSMVecIndex.build(CFG._replace(lazy_delete=False), base)
    pol = MaintenancePolicy(tombstone_ratio=0.10, heat_budget=None,
                            check_every=1)
    eng = ServeEngine(idx, ServeConfig(delete_batch=16, maintenance=pol),
                      clock=FakeClock())
    before = int(idx.state.store.n_compactions)
    for v in range(50):
        eng.submit_delete(v)
    eng.drain()
    assert eng.maintenance.compactions >= 1
    assert int(idx.state.store.n_compactions) > before
    assert eng.maintenance.deletes_since_compact < 50   # counter reset


def test_maintenance_below_threshold_never_compacts():
    base = make_data(400, seed=10)
    idx = LSMVecIndex.build(CFG._replace(lazy_delete=False), base)
    pol = MaintenancePolicy(tombstone_ratio=0.50, heat_budget=None,
                            check_every=1)
    eng = ServeEngine(idx, ServeConfig(delete_batch=16, maintenance=pol),
                      clock=FakeClock())
    for v in range(20):
        eng.submit_delete(v)
    eng.drain()
    assert eng.maintenance.compactions == 0


def test_maintenance_reorder_keeps_external_ids_stable():
    """Heat-triggered reordering permutes internal ids; the engine's
    external id map must keep client-visible ids stable: a vector keeps
    answering to the id its insert returned, and deletes by old ids keep
    hitting the right vector."""
    base = make_data(400, seed=11)
    idx = LSMVecIndex.build(CFG, base)
    pol = MaintenancePolicy(tombstone_ratio=None, heat_budget=1,
                            check_every=1)
    eng = ServeEngine(idx, ServeConfig(query_batch=16, insert_batch=16,
                                       delete_batch=16, maintenance=pol),
                      clock=FakeClock())
    probe = base[37]
    t0 = eng.submit_query(probe)
    eng.drain()
    assert int(t0.result().ids[0]) == 37
    # a write batch + accumulated heat triggers the reorder at the check
    x = make_data(1, seed=12)[0] + 50.0
    t_ins = eng.submit_insert(x)
    eng.drain()
    assert eng.maintenance.reorders >= 1
    perm = eng.maintenance.last_perm
    assert perm is not None and not np.array_equal(
        perm, np.arange(len(perm)))          # the relayout actually moved ids
    # same probe still answers to its original external id
    t1 = eng.submit_query(probe)
    t2 = eng.submit_query(x)
    eng.drain()
    assert int(t1.result().ids[0]) == 37
    assert int(t2.result().ids[0]) == int(t_ins.result())
    # delete by external id removes that vector
    eng.submit_delete(37)
    t3 = eng.submit_query(probe)
    eng.drain()
    assert int(t3.result().ids[0]) != 37
    assert idx.size == 400   # 400 base + 1 insert - 1 delete


def test_background_thread_serving():
    base = make_data(256, seed=13)
    idx = LSMVecIndex.build(CFG, base)
    eng = ServeEngine(idx, ServeConfig(query_batch=8, query_window=0.001,
                                       maintenance=NO_MAINT))
    eng.start()
    try:
        tickets = [eng.submit_query(base[i]) for i in range(20)]
        results = [t.result(timeout=60.0) for t in tickets]
    finally:
        eng.stop()
    hits = [int(r.ids[0]) == i for i, r in enumerate(results)]
    assert np.mean(hits) >= 0.9
