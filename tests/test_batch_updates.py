"""Tests for the batched update pipeline (hnsw.insert_batch /
delete_batch) and multi-expansion beam search (DESIGN.md §3-§4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hnsw, lsm
from repro.core.backend import SearchParams
from repro.core.index import LSMVecIndex, brute_force_knn, recall_at_k
from repro.data.synth import make_clustered_vectors


def make_data(n, dim=32, seed=0):
    return make_clustered_vectors(n, dim=dim, seed=seed, clusters=16)


CFG = hnsw.HNSWConfig(cap=2048, dim=32, M=12, M_up=6, num_upper=2,
                      ef_search=48, ef_construction=48, k=10,
                      rho=1.0, use_filter=False, lsm_mem_cap=128,
                      lsm_levels=2, lsm_fanout=8, batch_expand=4)


@pytest.fixture(scope="module")
def built_index():
    data = make_data(768)
    return LSMVecIndex.build(CFG, data), data


def test_insert_batch_ids_size_and_count_mirror():
    data = make_data(256, seed=1)
    idx = LSMVecIndex.build(CFG, data)
    xs = make_data(96, seed=2)
    ids = idx.insert_batch(xs).ids.tolist()
    assert ids == list(range(256, 256 + 96))
    assert idx.size == 352
    assert idx._count == int(idx.state.count) == 352


def test_insert_batch_find_self(built_index):
    idx, data = built_index
    new = make_data(32, seed=42) + 100.0     # far-away cluster
    ids = idx.insert_batch(new).ids.tolist()
    found = idx.search(new, k=1).ids
    assert set(found[:, 0].tolist()) == set(ids)


def test_insert_batch_recall():
    base = make_data(512, seed=3)
    extra = make_data(128, seed=4)
    idx = LSMVecIndex.build(CFG, base)
    idx.insert_batch(extra)
    allv = np.concatenate([base, extra])
    queries = make_data(24, seed=8)
    ids = idx.search(queries, k=10).ids
    truth = brute_force_knn(jnp.asarray(allv), jnp.asarray(queries), 10)
    r = recall_at_k(ids, truth)
    assert r >= 0.75, f"post-batch-insert recall {r:.3f}"


def test_insert_batch_rows_written_to_lsm():
    base = make_data(256, seed=5)
    idx = LSMVecIndex.build(CFG, base)
    ids = idx.insert_batch(make_data(64, seed=6)).ids.tolist()
    live, rows = lsm.resolve_all(CFG.lsm_cfg, idx.state.store, idx._count)
    live = np.asarray(live)
    rows = np.asarray(rows)
    for i in ids:
        assert live[i] == 1, f"node {i} has no bottom row"
        assert (rows[i] >= 0).any(), f"node {i} row is empty"


def test_insert_batch_cold_start_seeds_per_item():
    cfg = CFG._replace(cap=512)
    idx = LSMVecIndex(cfg, seed=0)
    xs = make_data(96, seed=7)
    ids = idx.insert_batch(xs).ids.tolist()
    assert ids == list(range(96))
    assert idx.size == 96
    found = idx.search(xs[:8], k=1).ids
    assert (found[:, 0] == np.arange(8)).mean() >= 0.9


def test_delete_batch_matches_sequential_deletes():
    """delete_batch stages Algorithm 2 through an overlay + one bulk
    `lsm.puts`: every non-store field is bit-identical to the per-item
    loop over the same ids in the same order, and the LSM tree resolves
    to identical content (flush timing may differ, never what a lookup
    returns)."""
    data = make_data(256, seed=9)
    idx_a = LSMVecIndex.build(CFG, data)
    idx_b = LSMVecIndex.build(CFG, data)
    victims = [3, 77, 150, 9, 201, 42]
    for v in victims:
        idx_a.delete(v)
    idx_b.delete_batch(victims)
    for name, a, b in zip(hnsw.HNSWState._fields, idx_a.state, idx_b.state):
        if name == "store":
            la, ra = lsm.resolve_all(CFG.lsm_cfg, a, CFG.cap)
            lb, rb = lsm.resolve_all(CFG.lsm_cfg, b, CFG.cap)
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
            np.testing.assert_array_equal(np.asarray(ra), np.asarray(rb))
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)
    np.testing.assert_array_equal(
        np.asarray(jax.tree.map(np.asarray, idx_a.io_stats)),
        np.asarray(jax.tree.map(np.asarray, idx_b.io_stats)))


def test_delete_batch_removes_from_results(built_index):
    idx, _ = built_index
    queries = make_data(8, seed=10)
    ids = idx.search(queries, k=1).ids
    victims = sorted(set(ids[:, 0].tolist()))
    idx.delete_batch(victims)
    ids2 = idx.search(queries, k=10).ids
    for row in ids2:
        assert not (set(row.tolist()) & set(victims)), "deleted id returned"


def test_multi_expansion_recall_parity(built_index):
    """n_expand=4 must stay within 0.01 recall of the exact B=1 path and
    return sorted distances."""
    idx, data = built_index
    queries = make_data(32, seed=11)
    live = np.asarray(idx.state.levels[:len(data)]) >= 0
    truth = brute_force_knn(jnp.asarray(data), jnp.asarray(queries), 10,
                            live=jnp.asarray(live))
    ids1 = idx.search(queries, k=10, params=SearchParams(n_expand=1)).ids
    res4 = idx.search(queries, k=10, params=SearchParams(n_expand=4))
    ids4, d4 = res4.ids, res4.dists
    r1 = recall_at_k(ids1, truth)
    r4 = recall_at_k(ids4, truth)
    assert abs(r4 - r1) <= 0.01, (r1, r4)
    for row in d4:
        assert np.all(np.diff(row) >= -1e-5)


def test_multi_expansion_parity_on_damaged_graph():
    """The trip cap must not starve B>1 searches where the frontier stays
    thin — a heavily deleted graph is the worst case (searches there
    terminate by frontier exhaustion, which the cap must not preempt)."""
    data = make_data(512, seed=20)
    idx = LSMVecIndex.build(CFG, data)
    rng = np.random.default_rng(0)
    victims = rng.choice(512, 200, replace=False)
    idx.delete_batch(victims)
    live = np.ones(512, bool)
    live[victims] = False
    queries = make_data(24, seed=21)
    truth = brute_force_knn(jnp.asarray(data), jnp.asarray(queries), 10,
                            live=jnp.asarray(live))
    r1 = recall_at_k(
        idx.search(queries, k=10, params=SearchParams(n_expand=1)).ids, truth)
    r4 = recall_at_k(
        idx.search(queries, k=10, params=SearchParams(n_expand=4)).ids, truth)
    assert r4 >= r1 - 0.01, (r1, r4)


def test_multi_expansion_visits_no_fewer_nodes(built_index):
    """B=4 expands at least as many nodes as B=1 on the same queries
    (speculative expansions are a superset-ish frontier)."""
    idx, _ = built_index
    queries = make_data(16, seed=12)
    idx.reset_stats()
    idx.search(queries, k=10,
               params=SearchParams(n_expand=1, record_heat=False))
    hops1 = int(idx.io_stats.n_hops)
    idx.reset_stats()
    idx.search(queries, k=10,
               params=SearchParams(n_expand=4, record_heat=False))
    hops4 = int(idx.io_stats.n_hops)
    idx.reset_stats()
    assert hops4 >= hops1


def test_insert_batch_padded_matches_exact_shape():
    """pad-and-mask dispatch: a padded batch produces the same ids and
    graph as the exact-shape call never could prove alone — padding must
    not perturb which neighbors valid items link to."""
    data = make_data(256, seed=30)
    idx = LSMVecIndex.build(CFG, data)
    xs = make_data(20, seed=31)
    ids = idx.insert_batch(xs, pad_to=32).ids.tolist()
    assert ids == list(range(256, 276))
    assert idx.size == 276
    assert idx._count == int(idx.state.count) == 276
    found = idx.search(xs, k=1).ids
    assert (found[:, 0] == np.array(ids)).mean() >= 0.9
    # padding ids were never allocated: nothing lives past the last valid
    live, rows = lsm.resolve_all(CFG.lsm_cfg, idx.state.store, CFG.cap)
    assert not np.asarray(live)[276:].any()
    assert np.asarray(idx.state.levels)[276:].max() == -1


def test_insert_batch_padded_no_retrace_across_occupancy():
    """Different occupancies of the same pad width reuse one traced
    shape; so does the all-consumed-by-seeding edge (empty rest skips
    dispatch entirely)."""
    cfg = CFG._replace(cap=1024)
    idx = LSMVecIndex(cfg, seed=0)
    seed_gap = LSMVecIndex.BATCH_MIN_GRAPH - idx.size
    ids = idx.insert_batch(make_data(seed_gap, seed=32), pad_to=32)
    assert ids.ids.tolist() == list(range(seed_gap))
    assert idx.trace_counts()["insert_batch"] == 0   # all seeded per-item
    before = None
    for occupancy, seed in ((32, 33), (7, 34), (1, 35), (32, 36)):
        ids = idx.insert_batch(make_data(occupancy, seed=seed), pad_to=32)
        assert ids.n_applied == occupancy
        counts = idx.trace_counts()["insert_batch"]
        if before is not None:
            assert counts == before, "padded insert retraced"
        before = counts
    assert before == 1
    # ragged chunking: 70 items through width 32 = 3 calls, same trace
    ids = idx.insert_batch(make_data(70, seed=37), pad_to=32)
    assert ids.n_applied == 70 and idx.trace_counts()["insert_batch"] == 1


def test_delete_batch_padded_and_masked_ids():
    """-1 ids are exact no-ops; pad_to chunks and pads transparently."""
    data = make_data(256, seed=40)
    idx_a = LSMVecIndex.build(CFG, data)
    idx_b = LSMVecIndex.build(CFG, data)
    victims = [5, 99, 180]
    idx_a.delete_batch(victims, pad_to=8)
    idx_b.delete_batch(victims)
    assert idx_a.size == idx_b.size == 253
    for name, a, b in zip(hnsw.HNSWState._fields, idx_a.state, idx_b.state):
        if name == "store":
            la, ra = lsm.resolve_all(CFG.lsm_cfg, a, CFG.cap)
            lb, rb = lsm.resolve_all(CFG.lsm_cfg, b, CFG.cap)
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
            np.testing.assert_array_equal(np.asarray(ra), np.asarray(rb))
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)
    # same traced shape across occupancies
    n0 = idx_a.trace_counts()["delete_batch"]
    idx_a.delete_batch([7], pad_to=8)
    assert idx_a.trace_counts()["delete_batch"] == n0


def test_search_snapshot_bit_parity(built_index):
    """Snapshot-gather adjacency + pad-and-mask lanes return exactly what
    the per-hop LSM path returns, and padded lanes record no heat/stats."""
    idx, _ = built_index
    queries = make_data(24, seed=50)
    res_a = idx.search(queries, k=10, params=SearchParams(record_heat=False))
    res_b = idx.search(queries, k=10, params=SearchParams(
        record_heat=False, use_snapshot=True, pad_to=32))
    ids_a, d_a = res_a.ids, res_a.dists
    ids_b, d_b = res_b.ids, res_b.dists
    np.testing.assert_array_equal(ids_a, ids_b)
    np.testing.assert_array_equal(d_a, d_b)
    # stats parity between the two paths on identical queries
    idx.reset_stats()
    idx.search(queries, k=10, params=SearchParams(record_heat=False))
    direct = jax.tree.map(int, idx.io_stats)
    idx.reset_stats()
    idx.search(queries, k=10, params=SearchParams(
        record_heat=False, use_snapshot=True, pad_to=32))
    snap = jax.tree.map(int, idx.io_stats)
    idx.reset_stats()
    assert direct == snap


def test_snapshot_invalidated_on_writes(built_index):
    """The cached dense view re-resolves after any write: a fresh insert
    must be findable through the snapshot path immediately."""
    idx, _ = built_index
    new = make_data(4, seed=51) + 250.0
    ids = idx.insert_batch(new, pad_to=8).ids.tolist()
    found = idx.search(
        new, k=1, params=SearchParams(use_snapshot=True, pad_to=8)).ids
    assert set(found[:, 0].tolist()) == set(ids)
    victim = ids[0]
    idx.delete_batch([victim], pad_to=8)
    found2 = idx.search(
        new[:1], k=5, params=SearchParams(use_snapshot=True, pad_to=8)).ids
    assert victim not in found2[0].tolist()


def test_mixed_batch_and_single_updates():
    """Batched and per-item updates interleave cleanly."""
    base = make_data(300, seed=13)
    idx = LSMVecIndex.build(CFG, base)
    ids = idx.insert_batch(make_data(40, seed=14)).ids.tolist()
    one = idx.insert(make_data(1, seed=15)[0])
    assert one == ids[-1] + 1
    idx.delete_batch(ids[:10])
    idx.delete(ids[10])
    assert idx.size == 300 + 40 + 1 - 11
    q = make_data(4, seed=16)
    d = idx.search(q, k=5).dists
    assert np.isfinite(d).all()
