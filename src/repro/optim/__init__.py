"""Optimizer substrate: AdamW with ZeRO-shardable states, clipping,
schedules, and gradient compression."""

from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.compression import (
    ErrorFeedbackState,
    compress_bf16,
    decompress_bf16,
    ef_int8_compress,
    ef_int8_decompress,
)
from repro.optim.schedule import cosine_schedule

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_schedule",
           "compress_bf16", "decompress_bf16", "ErrorFeedbackState",
           "ef_int8_compress", "ef_int8_decompress"]
