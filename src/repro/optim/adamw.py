"""AdamW with fp32 master accumulators, global-norm clipping.

Moments and (optionally) fp32 master params are plain pytrees mirroring
the parameter tree, so the ZeRO/FSDP story is purely a sharding-spec
choice in launch/sharding.py — states inherit the param sharding (or a
data-sharded variant for ZeRO-1) with no optimizer-code changes.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any          # first moment  (fp32, param-tree shaped)
    nu: Any          # second moment (fp32)
    master: Any      # fp32 master copy of params (None if params are fp32)


def adamw_init(params) -> AdamWState:
    def f32(p):
        return jnp.zeros(p.shape, jnp.float32)
    mu = jax.tree.map(f32, params)
    nu = jax.tree.map(f32, params)
    needs_master = any(p.dtype != jnp.float32
                       for p in jax.tree.leaves(params))
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params) \
        if needs_master else None
    return AdamWState(jnp.zeros((), jnp.int32), mu, nu, master)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, state: AdamWState, *,
                 lr, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 clip_norm: Optional[float] = 1.0
                 ) -> Tuple[Any, AdamWState, jax.Array]:
    """Returns (new_params, new_state, pre-clip grad norm)."""
    gnorm = global_norm(grads)
    if clip_norm is not None:
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    step = state.step + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, master):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / b1c
        vh = v / b2c
        base = master if master is not None else p.astype(jnp.float32)
        new = base - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * base)
        return new.astype(p.dtype), m, v, new

    if state.master is not None:
        out = jax.tree.map(upd, params, grads, state.mu, state.nu,
                           state.master)
    else:
        out = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v, None),
                           params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    mu = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    nu = jax.tree.map(lambda t: t[2], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    master = jax.tree.map(lambda t: t[3], out,
                          is_leaf=lambda t: isinstance(t, tuple)) \
        if state.master is not None else None
    return new_params, AdamWState(step, mu, nu, master), gnorm
