"""Gradient compression for the cross-pod all-reduce.

Two schemes:
 - bf16 compression: cast grads to bf16 before the all-reduce, accumulate
   in fp32 after — halves collective bytes, standard at pod scale.
 - int8 error-feedback: per-tensor scale quantization with a residual
   carried between steps (1-bit-Adam-style EF), quartering bytes; the
   residual keeps the quantization error from biasing the update.

Both act on pytrees and are exercised in the train-step variants; the
roofline's collective term is what they buy down.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


def compress_bf16(grads):
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def decompress_bf16(grads):
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads)


class ErrorFeedbackState(NamedTuple):
    residual: Any


def ef_init(grads) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def ef_int8_compress(grads, ef: ErrorFeedbackState
                     ) -> Tuple[Any, Any, ErrorFeedbackState]:
    """Returns (int8 payload, scales, new residual-state)."""
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(corrected / scale), -127, 127
                     ).astype(jnp.int8)
        new_r = corrected - q.astype(jnp.float32) * scale
        return q, scale, new_r

    out = jax.tree.map(one, grads, ef.residual)
    q = jax.tree.map(lambda t: t[0], out,
                     is_leaf=lambda t: isinstance(t, tuple))
    s = jax.tree.map(lambda t: t[1], out,
                     is_leaf=lambda t: isinstance(t, tuple))
    r = jax.tree.map(lambda t: t[2], out,
                     is_leaf=lambda t: isinstance(t, tuple))
    return q, s, ErrorFeedbackState(r)


def ef_int8_decompress(q, scales):
    return jax.tree.map(lambda qq, ss: qq.astype(jnp.float32) * ss,
                        q, scales)
