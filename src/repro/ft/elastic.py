"""Fault-tolerance policy layer.

On a 1000+-node cluster the failure model is: a node (or pod) dies every
few hours; stragglers inflate step time; capacity changes mid-run.  The
policy here is the standard production one:

 1. *Checkpoint/restart* — atomic checkpoints every K steps (ckpt.py); on
    any failure the launcher re-enters `run_with_restarts`, which restores
    the latest checkpoint and resumes the data pipeline from its cursor
    (the pipeline is counter-addressed, so resume is exact).
 2. *Straggler mitigation* — step times are monitored; a step exceeding
    `straggler_factor` x the trailing median marks the step "slow".  On a
    real cluster the response is re-scheduling the slow host (backup
    workers / `--jax_coordination_timeout`); here the detector and its
    accounting are implemented and tested, and the response hook is
    pluggable.
 3. *Elastic re-mesh* — checkpoints store logical (global-shape) arrays,
    so a resume may build a different mesh (fewer/more pods) and reshard;
    `run_with_restarts` re-invokes the step-builder with the current mesh.

`FailureInjector` deterministically raises mid-run to exercise all paths
in tests.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Raises SimulatedFailure at the given global steps (once each)."""
    fail_at: List[int] = field(default_factory=list)
    seen: set = field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at and step not in self.seen:
            self.seen.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclass
class RestartPolicy:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 10
    max_restarts: int = 5
    straggler_factor: float = 3.0
    keep: int = 3


class StragglerDetector:
    def __init__(self, factor: float, window: int = 16):
        self.factor = factor
        self.window = window
        self.times: List[float] = []
        self.flagged: List[int] = []

    def observe(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        hist = sorted(self.times[-self.window:])
        median = hist[len(hist) // 2]
        slow = len(self.times) >= 4 and dt > self.factor * median
        if slow:
            self.flagged.append(step)
        return slow


def run_with_restarts(
    *,
    policy: RestartPolicy,
    init_state: Callable[[], Any],
    step_fn: Callable[[Any, int], Any],
    num_steps: int,
    injector: Optional[FailureInjector] = None,
    meta_fn: Callable[[int], Dict] = lambda step: {},
    on_straggler: Optional[Callable[[int], None]] = None,
) -> Dict[str, Any]:
    """Drive `step_fn` to `num_steps` surviving injected/real failures.

    Returns {"state": final, "restarts": n, "stragglers": [...],
    "resumed_from": [...]}.
    """
    restarts = 0
    resumed_from: List[int] = []
    detector = StragglerDetector(policy.straggler_factor)

    while True:
        try:
            start = latest_step(policy.ckpt_dir)
            if start is not None:
                state, meta, start = restore_checkpoint(
                    policy.ckpt_dir, init_state(), step=start)
                resumed_from.append(start)
                step = start
            else:
                state = init_state()
                step = 0
            while step < num_steps:
                t0 = time.monotonic()
                if injector is not None:
                    injector.check(step)
                state = step_fn(state, step)
                step += 1
                if detector.observe(step, time.monotonic() - t0) \
                        and on_straggler:
                    on_straggler(step)
                if step % policy.ckpt_every == 0 or step == num_steps:
                    save_checkpoint(policy.ckpt_dir, step, state,
                                    metadata=meta_fn(step),
                                    keep=policy.keep)
            return {"state": state, "restarts": restarts,
                    "stragglers": detector.flagged,
                    "resumed_from": resumed_from}
        except SimulatedFailure:
            restarts += 1
            if restarts > policy.max_restarts:
                raise
