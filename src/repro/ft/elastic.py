"""Fault-tolerance policy layer, shared by the training loop and the
serving path.

On a 1000+-node cluster the failure model is: a node (or pod) dies every
few hours; stragglers inflate step time; capacity changes mid-run.  The
policy here is the standard production one:

 1. *Checkpoint/restart* — atomic checkpoints every `ckpt_every` units
    (ckpt.py): training steps in `run_with_restarts`, serve write
    batches in `run_with_recovery`.  On any failure the launcher
    re-enters the driver, which restores the latest checkpoint and
    resumes exactly — the training pipeline is counter-addressed, the
    serving path replays its WAL tail (DESIGN.md §11).
 2. *Straggler mitigation* — step times are monitored; a step exceeding
    `straggler_factor` x the trailing median marks the step "slow".  On a
    real cluster the response is re-scheduling the slow host (backup
    workers / `--jax_coordination_timeout`); here the detector and its
    accounting are implemented and tested, and the response hook is
    pluggable.
 3. *Elastic re-mesh* — checkpoints store logical (global-shape) arrays,
    so a resume may build a different mesh (fewer/more pods) and reshard;
    `run_with_restarts` re-invokes the step-builder with the current mesh.

`FailureInjector` deterministically raises mid-run to exercise all
paths in tests: by global step (`fail_at`, the training form) or by
named injection point (`fail_points`, the serve form — pre_commit,
post_commit_pre_apply, mid_checkpoint, mid_consolidation).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Raises SimulatedFailure deterministically, once per trigger.

    Two trigger forms, freely mixed:
    - `fail_at`: global training steps (checked via `check(step)`);
    - `fail_points`: named serve-path injection points — the value is
      the 1-based hit index at which to fire, so ``{"pre_commit": 3}``
      crashes the third batch that reaches the pre-commit gate.  The
      engine passes each point via `at(point)`; `armed(point)` lets the
      call site prepare the crash (e.g. force a WAL sync so a
      post-commit crash leaves a durable record).
    """
    fail_at: List[int] = field(default_factory=list)
    fail_points: Dict[str, int] = field(default_factory=dict)
    seen: set = field(default_factory=set)
    hits: Dict[str, int] = field(default_factory=dict)

    def check(self, step: int):
        if step in self.fail_at and step not in self.seen:
            self.seen.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")

    def armed(self, point: str) -> bool:
        """True if the *next* `at(point)` will raise."""
        target = self.fail_points.get(point)
        return (target is not None and point not in self.seen
                and self.hits.get(point, 0) + 1 == target)

    def at(self, point: str):
        """Pass a named injection point; raises on the configured hit."""
        self.hits[point] = self.hits.get(point, 0) + 1
        target = self.fail_points.get(point)
        if target is not None and point not in self.seen \
                and self.hits[point] == target:
            self.seen.add(point)
            raise SimulatedFailure(
                f"injected failure at {point} (hit {target})")


@dataclass
class RestartPolicy:
    """One policy object for both drivers.  `ckpt_dir` has no default:
    train and serve runs must name their own directory (the old shared
    `/tmp/repro_ckpt` default let two suites resume from each other's
    checkpoints).  `ckpt_every` counts training steps under
    `run_with_restarts` and serve write batches under
    `run_with_recovery`; `wal_dir` is serve-only (None = run without a
    WAL, i.e. no durability for un-checkpointed writes)."""
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 10
    max_restarts: int = 5
    straggler_factor: float = 3.0
    keep: int = 3
    wal_dir: Optional[str] = None


class StragglerDetector:
    def __init__(self, factor: float, window: int = 16):
        self.factor = factor
        self.window = window
        self.times: List[float] = []
        self.flagged: List[int] = []

    def observe(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        hist = sorted(self.times[-self.window:])
        median = hist[len(hist) // 2]
        slow = len(self.times) >= 4 and dt > self.factor * median
        if slow:
            self.flagged.append(step)
        return slow


def run_with_restarts(
    *,
    policy: RestartPolicy,
    init_state: Callable[[], Any],
    step_fn: Callable[[Any, int], Any],
    num_steps: int,
    injector: Optional[FailureInjector] = None,
    meta_fn: Callable[[int], Dict] = lambda step: {},
    on_straggler: Optional[Callable[[int], None]] = None,
) -> Dict[str, Any]:
    """Drive `step_fn` to `num_steps` surviving injected/real failures.

    Returns {"state": final, "restarts": n, "stragglers": [...],
    "resumed_from": [...]}.
    """
    if policy.ckpt_dir is None:
        raise ValueError("RestartPolicy.ckpt_dir must be set (the old "
                         "/tmp/repro_ckpt default is gone)")
    restarts = 0
    resumed_from: List[int] = []
    detector = StragglerDetector(policy.straggler_factor)

    while True:
        try:
            start = latest_step(policy.ckpt_dir)
            if start is not None:
                state, meta, start = restore_checkpoint(
                    policy.ckpt_dir, init_state(), step=start)
                resumed_from.append(start)
                step = start
            else:
                state = init_state()
                step = 0
            while step < num_steps:
                t0 = time.monotonic()
                if injector is not None:
                    injector.check(step)
                state = step_fn(state, step)
                step += 1
                if detector.observe(step, time.monotonic() - t0) \
                        and on_straggler:
                    on_straggler(step)
                if step % policy.ckpt_every == 0 or step == num_steps:
                    save_checkpoint(policy.ckpt_dir, step, state,
                                    metadata=meta_fn(step),
                                    keep=policy.keep)
            return {"state": state, "restarts": restarts,
                    "stragglers": detector.flagged,
                    "resumed_from": resumed_from}
        except SimulatedFailure:
            restarts += 1
            if restarts > policy.max_restarts:
                raise


# ---------------------------------------------------------------------------
# serve-path crash recovery (DESIGN.md §11)
# ---------------------------------------------------------------------------

def run_with_recovery(
    *,
    policy: RestartPolicy,
    make_engine: Callable[[Optional[FailureInjector]], Any],
    ops: List[Tuple[str, Any]],
    injector: Optional[FailureInjector] = None,
    chunk: int = 8,
) -> Dict[str, Any]:
    """Drive a serve op stream to completion across injected crashes.

    `make_engine(injector)` must return a recovered engine — in
    practice a thin wrapper over ``ServeEngine.recover`` pointed at
    `policy.ckpt_dir`/`policy.wal_dir` — so calling it again after a
    SimulatedFailure restores the latest covering checkpoint and
    replays the WAL tail.  `ops` is the client stream:
    ``("insert", vector)`` / ``("delete", ext_id)`` / ``("query",
    vector)``.

    Delivery semantics are the WAL's: acknowledged writes are durable
    and survive every crash; unacknowledged writes are retried by this
    driver (at-least-once — a retried insert whose original record was
    already durable-but-unacked becomes a second copy under a fresh
    external id, exactly what a real client retry produces).

    Returns ``{"engine", "acked" (op index -> ticket value),
    "restarts", "retried"}``.
    """
    engine = make_engine(injector)
    remaining = list(enumerate(ops))     # (op index, (kind, payload))
    acked: Dict[int, Any] = {}
    restarts = 0
    retried = 0

    def _submit(eng, idx, kind, payload):
        if kind == "insert":
            return idx, eng.submit_insert(payload)
        if kind == "delete":
            return idx, eng.submit_delete(payload)
        if kind == "query":
            return idx, eng.submit_query(payload)
        raise ValueError(f"unknown op kind {kind!r}")

    while remaining:
        batch, remaining = remaining[:chunk], remaining[chunk:]
        tickets = []
        try:
            for idx, (kind, payload) in batch:
                tickets.append(_submit(engine, idx, kind, payload))
            engine.drain()
            for idx, t in tickets:
                acked[idx] = t.result()
        except SimulatedFailure:
            restarts += 1
            if restarts > policy.max_restarts:
                raise
            # harvest what resolved before the crash; everything else
            # goes back to the head of the stream in original order
            done = set()
            for idx, t in tickets:
                if t.done:
                    try:
                        acked[idx] = t.result()
                        done.add(idx)
                    except BaseException:
                        pass            # failed ticket: retry
            redo = [(idx, op) for idx, op in batch if idx not in done]
            retried += len(redo)
            remaining = redo + remaining
            # simulated process death: drop the dead engine's WAL fd
            # without flushing — a real kill never flushes, and a late
            # buffered flush would write a stale partial record into
            # the segment the restarted engine appends to
            wal = getattr(engine, "wal", None)
            if wal is not None:
                wal.abandon()
            engine = make_engine(injector)   # simulated process restart
    engine.drain()
    return {"engine": engine, "acked": acked, "restarts": restarts,
            "retried": retried}


def verify_acked_writes(engine, ops: List[Tuple[str, Any]],
                        acked: Dict[int, Any]) -> Dict[str, int]:
    """Prove zero acknowledged-write loss after recovery.

    Replays the acked subset of `ops` into an expected live-set, then
    checks every expected-live external id two ways: by id (the engine
    maps it to a live internal id) and by search reachability (querying
    its own vector returns it).  Acked deletes must read as deleted.
    Raises AssertionError naming the first lost write; returns counts
    ``{"live", "deleted", "searched"}``.
    """
    expect_live: Dict[int, Any] = {}
    expect_deleted: List[int] = []
    for idx, (kind, payload) in enumerate(ops):
        if idx not in acked:
            continue
        if kind == "insert":
            expect_live[int(acked[idx])] = np.asarray(payload, np.float32)
        elif kind == "delete":
            expect_live.pop(int(payload), None)
            expect_deleted.append(int(payload))

    for ext in expect_live:
        gid = engine.resolve_ext(ext)
        assert gid >= 0, f"acked insert ext={ext} lost: no internal id"
        assert not engine.is_deleted(ext), \
            f"acked insert ext={ext} reads as deleted"
    for ext in expect_deleted:
        assert engine.is_deleted(ext) or engine.resolve_ext(ext) < 0, \
            f"acked delete ext={ext} still live after recovery"

    searched = 0
    items = list(expect_live.items())
    tickets = [engine.submit_query(vec) for _, vec in items]
    engine.drain()
    for (ext, _), t in zip(items, tickets):
        res = t.result()
        assert ext in np.asarray(res.ids).tolist(), \
            f"acked insert ext={ext} not search-reachable after recovery"
        searched += 1
    return {"live": len(expect_live), "deleted": len(expect_deleted),
            "searched": searched}
