"""Fault tolerance: restartable training, failure injection, straggler and
elasticity policy."""

from repro.ft.elastic import (FailureInjector, RestartPolicy,
                              SimulatedFailure, run_with_restarts)

__all__ = ["FailureInjector", "RestartPolicy", "SimulatedFailure",
           "run_with_restarts"]
