"""Fault tolerance: restartable training, failure injection, straggler and
elasticity policy."""

from repro.ft.elastic import (
    FailureInjector,
    RestartPolicy,
    SimulatedFailure,
    run_with_recovery,
    run_with_restarts,
    verify_acked_writes,
)

__all__ = ["FailureInjector", "RestartPolicy", "SimulatedFailure",
           "run_with_restarts", "run_with_recovery",
           "verify_acked_writes"]
