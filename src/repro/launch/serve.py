"""Serving launcher: batched prefill + decode for any assigned arch.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b \
        --preset smoke --batch 4 --prompt-len 16 --gen 16

Drives the same prefill/serve steps the dry-run lowers at production
shapes; on CPU this exercises the smoke configs end to end.
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import configs
    from repro.models import transformer as T

    cfg = configs.get_config(args.arch, args.preset)
    params = T.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    img = None
    if cfg.num_img_tokens:
        img = jnp.asarray(rng.normal(
            0, 1, (args.batch, cfg.num_img_tokens, cfg.d_model)),
            cfg.act_dtype)

    max_len = args.prompt_len + cfg.num_img_tokens + args.gen
    decode = jax.jit(lambda p, s, t: T.decode_step(p, cfg, s, t))

    t0 = time.monotonic()
    last, state = T.prefill(params, cfg, jnp.asarray(prompts), img,
                            max_len=max_len)
    t_prefill = time.monotonic() - t0

    key = jax.random.key(1)
    tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
    toks = [np.asarray(tok)[:, 0]]
    t1 = time.monotonic()
    for i in range(args.gen - 1):
        logits, state = decode(params, state, tok)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, 0] / args.temperature)[:, None].astype(
                jnp.int32)
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks.append(np.asarray(tok)[:, 0])
    t_decode = time.monotonic() - t1

    gen = np.stack(toks, axis=1)
    print(f"arch={cfg.name} batch={args.batch} "
          f"prefill={t_prefill*1e3:.0f}ms "
          f"decode={t_decode/max(args.gen-1,1)*1e3:.1f}ms/tok (CPU wall)")
    for b in range(min(2, args.batch)):
        print(f"  seq {b}: {gen[b][:12].tolist()}")


if __name__ == "__main__":
    main()
