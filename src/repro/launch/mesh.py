"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets the host-device-count flag
before any jax initialization.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 chips per pod; the multi-pod mesh adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devices = jax.devices()[:need]
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(jax.devices())} "
            "(the dry-run entrypoint sets "
            "--xla_force_host_platform_device_count=512)")
    return jax.make_mesh(shape, axes, devices=devices,
                         axis_types=(jax.sharding.AxisType.Auto,)
                         * len(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh for CPU distribution tests (requires >= prod(shape)
    host devices, set via XLA_FLAGS in the test)."""
    need = 1
    for s in shape:
        need *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:need],
                         axis_types=(jax.sharding.AxisType.Auto,)
                         * len(axes))
