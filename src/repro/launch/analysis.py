"""Compiled-artifact analysis: collective parsing + roofline terms.

The container is CPU-only, so the "profile" is the compiled HLO:
 - `cost_analysis()` gives per-device FLOPs / bytes accessed;
 - collective bytes are parsed from the optimized HLO text (per-device
   operand shapes of all-reduce / all-gather / reduce-scatter / all-to-all
   / collective-permute, skipping *-done halves of async pairs);
 - `memory_analysis()` gives per-device argument/output/temp bytes.

Hardware constants (TPU v5e-class target): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<types>.*?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def _bytes_of_types(span: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(span):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-op {count, bytes} from optimized per-device HLO."""
    out: Dict[str, Dict[str, float]] = {}
    for m in _COLL_RE.finditer(hlo_text):
        if m.group("suffix") == "-done":
            continue           # async pair: count the -start half only
        op = m.group("op")
        b = _bytes_of_types(m.group("types"))
        rec = out.setdefault(op, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += b
    return out


def roofline_terms(cost: Dict[str, float], coll: Dict[str, Dict],
                   *, steps_amortized: int = 1) -> Dict[str, float]:
    """Three roofline terms (seconds, per device) + totals."""
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    # all-reduce moves ~2x payload through each link (ring); others ~1x
    coll_bytes = 0.0
    for op, rec in coll.items():
        factor = 2.0 if op == "all-reduce" else 1.0
        coll_bytes += factor * rec["bytes"]
    return {
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll_bytes,
        "t_compute": flops / PEAK_FLOPS,
        "t_memory": bytes_accessed / HBM_BW,
        "t_collective": coll_bytes / ICI_BW,
    }


def dominant_term(terms: Dict[str, float]) -> str:
    trio = {"compute": terms["t_compute"], "memory": terms["t_memory"],
            "collective": terms["t_collective"]}
    return max(trio, key=trio.get)


def model_flops(cfg, n_params: int, shape_name: str, *,
                embed_params: int = 0, routed_params: int = 0) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params.

    MoE: routed-expert params count at top_k/num_experts utilization.
    Embedding-lookup params are excluded (gather, not FLOPs); the unembed
    matmul is part of n_params when untied.
    """
    from repro import configs as _c
    seq, batch, kind = _c.SHAPES[shape_name]
    n_active = n_params - embed_params
    if cfg.moe is not None and routed_params:
        n_active -= routed_params * (1.0 - cfg.moe.top_k
                                     / cfg.moe.num_experts)
    tokens = batch * (1 if kind == "decode" else seq)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * tokens
