"""Trip-count-aware analysis of optimized (post-SPMD) HLO text.

XLA's `compiled.cost_analysis()` counts each while-loop body ONCE, which
under-counts scan-over-layers programs by ~num_layers x.  This analyzer
walks the computation call graph, multiplying contributions by each while
op's `known_trip_count`, and reports per-device:

 - flops              — 2 * prod(out) * contracted for every dot;
 - bytes              — operand + result bytes of memory-touching ops at
                        fusion granularity (fusion/copy/dot/scatter/...);
 - collectives        — {op: {count, bytes}} for all-reduce / all-gather /
                        reduce-scatter / all-to-all / collective-permute
                        (async -done halves skipped), trip-weighted.

The parse is intentionally text-based (no private XLA APIs): shapes come
from each computation's SSA symbol table.
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s*"
                     r"([\w\-]+)\(", re.M)
_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")
# memory-traffic ops at fusion granularity.  Standalone layout/elementwise
# ops (convert/broadcast/select/pad/...) are EXCLUDED: the CPU backend
# leaves them unfused but the TPU target fuses them, so counting them
# would overstate HBM traffic ~5-20x.
_MEM_OPS = {"fusion", "copy", "dot", "convolution", "scatter", "gather",
            "dynamic-slice", "dynamic-update-slice", "sort", "reduce",
            "custom-call"}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


class _Comp:
    def __init__(self, name: str):
        self.name = name
        self.flops = 0.0
        self.bytes = 0.0
        self.coll: Dict[str, Dict[str, float]] = {}
        # (callee, multiplier)
        self.calls: List[Tuple[str, float]] = []


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in text.splitlines():
        m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{", line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                comps["__entry__"] = comps[cur]
                comps["__entry_name__"] = cur  # type: ignore
            continue
        if cur is not None:
            if line.startswith("}"):
                cur = None
                continue
            comps[cur].append(line)
    return comps


def _parse_comp(name: str, lines: List[str]) -> _Comp:
    comp = _Comp(name)
    # symbol table: %ssa_name -> type string
    sym: Dict[str, str] = {}
    for line in lines:
        m = re.match(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+"
                     r"([\w\-]+)\(", line)
        if not m:
            continue
        ssa, type_str, op = m.groups()
        sym[ssa] = type_str

    for line in lines:
        m = re.match(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+"
                     r"([\w\-]+)\(", line)
        if not m:
            continue
        ssa, type_str, op = m.groups()

        # collectives (trip-weighted later); skip async completion halves
        base = op
        for c in _COLL:
            if op.startswith(c):
                base = c
                break
        if base in _COLL:
            if op.endswith("-done"):
                continue
            rec = comp.coll.setdefault(base, {"count": 0, "bytes": 0.0})
            rec["count"] += 1
            rec["bytes"] += _type_bytes(type_str)
            continue

        # call graph edges
        if op == "while":
            body = re.search(r"body=%?([\w.\-]+)", line)
            trip = re.search(r'known_trip_count[^\d]*(\d+)', line)
            n = float(trip.group(1)) if trip else 1.0
            if body:
                comp.calls.append((body.group(1), n))
            cond = re.search(r"condition=%?([\w.\-]+)", line)
            if cond:
                comp.calls.append((cond.group(1), n))
            continue
        if op == "conditional":
            for br in re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                 r"true_computation=%?([\w.\-]+)|"
                                 r"false_computation=%?([\w.\-]+))", line):
                for grp in br:
                    if not grp:
                        continue
                    for callee in re.findall(r"%?([\w.\-]+)", grp):
                        comp.calls.append((callee, 1.0))
            continue
        called = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", line)
        if called and op in ("call", "fusion", "custom-call", "map",
                             "reduce", "reduce-window", "scatter", "sort",
                             "all-reduce"):
            # descend for flops/collectives; fusion bytes counted here
            comp.calls.append((called.group(1), 1.0))

        # dot flops: 2 * prod(out) * contracted-dims product
        if op == "dot":
            out_elems = _type_elems(type_str)
            lhs = re.search(r"\(%([\w.\-]+)", line)
            cdim = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            k = 1
            if lhs and cdim and lhs.group(1) in sym:
                dims_m = _SHAPE_RE.search(sym[lhs.group(1)])
                if dims_m:
                    dims = [int(d) for d in dims_m.group(2).split(",") if d]
                    for ci in cdim.group(1).split(","):
                        if ci and int(ci) < len(dims):
                            k *= dims[int(ci)]
            comp.flops += 2.0 * out_elems * k

        # memory traffic at fusion/op granularity
        if op in _MEM_OPS:
            b = _type_bytes(type_str)
            for operand in re.findall(r"%([\w.\-]+)", line.split("(", 1)[1]):
                if operand in sym:
                    b += _type_bytes(sym[operand])
            comp.bytes += b
    return comp


def analyze(text: str) -> Dict:
    raw = _split_computations(text)
    entry_name = raw.pop("__entry_name__", None)  # type: ignore
    raw.pop("__entry__", None)
    comps = {name: _parse_comp(name, lines) for name, lines in raw.items()}
    if entry_name is None:   # fallback: last computation is entry
        entry_name = list(comps)[-1]

    memo: Dict[str, Dict] = {}

    def walk(name: str, depth: int = 0) -> Dict:
        if name in memo:
            return memo[name]
        if name not in comps or depth > 64:
            return {"flops": 0.0, "bytes": 0.0, "coll": {}}
        c = comps[name]
        out = {"flops": c.flops, "bytes": c.bytes,
               "coll": {k: dict(v) for k, v in c.coll.items()}}
        for callee, mult in c.calls:
            sub = walk(callee, depth + 1)
            out["flops"] += mult * sub["flops"]
            out["bytes"] += mult * sub["bytes"]
            for k, v in sub["coll"].items():
                rec = out["coll"].setdefault(k, {"count": 0, "bytes": 0.0})
                rec["count"] += mult * v["count"]
                rec["bytes"] += mult * v["bytes"]
        memo[name] = out
        return out

    result = walk(entry_name)
    return {"flops": result["flops"], "bytes": result["bytes"],
            "collectives": result["coll"]}
