"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b \
        --preset smoke --steps 100 [--mesh-devices 8] [--ckpt-dir DIR]

On the production cluster this process runs per host with jax.distributed
initialization; here it drives the same train step (optionally over a
fake-device mesh) with the full substrate: sharded params/optimizer,
deterministic resumable data pipeline, atomic checkpoints, restart policy.
"""

from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh-devices", type=int, default=0,
                    help="fake host devices for a (data,model) mesh; 0=off")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    if args.mesh_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.mesh_devices}")

    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.data.synth import token_pipeline
    from repro.ft import RestartPolicy, run_with_restarts
    from repro.launch import steps as step_lib
    from repro.launch.mesh import make_test_mesh
    from repro.launch.sharding import (data_sharding, param_spec,
                                       tree_shardings)
    from repro.models import transformer as T
    from repro.optim import adamw_init

    cfg = configs.get_config(args.arch, args.preset)
    step = step_lib.make_train_step(cfg, peak_lr=args.lr,
                                    warmup=max(args.steps // 10, 1),
                                    total=args.steps)

    mesh = None
    if args.mesh_devices:
        model_ax = 2 if args.mesh_devices % 2 == 0 else 1
        mesh = make_test_mesh((args.mesh_devices // model_ax, model_ax),
                              ("data", "model"))
        print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    def init_state():
        params = T.init_params(cfg, jax.random.key(0))
        return {"params": params, "opt": adamw_init(params)}

    if mesh is not None:
        proto = jax.eval_shape(init_state)
        sh = {"params": tree_shardings(mesh, proto["params"], param_spec),
              "opt": tree_shardings(mesh, proto["opt"], param_spec)}
        b_sh = {"tokens": data_sharding(mesh, 2, args.batch),
                "labels": data_sharding(mesh, 2, args.batch)}
        jitted = jax.jit(step,
                         in_shardings=(sh["params"], sh["opt"], b_sh),
                         out_shardings=(sh["params"], sh["opt"], None))
    else:
        jitted = jax.jit(step)

    def step_fn(state, t):
        tokens, labels = next(token_pipeline(args.batch, args.seq,
                                             cfg.vocab_size, seed=1,
                                             start_step=t))
        batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        ctx = jax.sharding.set_mesh(mesh) if mesh is not None else None
        if ctx:
            with ctx:
                params, opt, m = jitted(state["params"], state["opt"], batch)
        else:
            params, opt, m = jitted(state["params"], state["opt"], batch)
        if t % 10 == 0:
            print(f"step {t:5d} loss {float(m['loss']):.4f} "
                  f"lr {float(m['lr']):.2e}", flush=True)
        return {"params": params, "opt": opt}

    out = run_with_restarts(
        policy=RestartPolicy(ckpt_dir=args.ckpt_dir,
                             ckpt_every=args.ckpt_every),
        init_state=init_state, step_fn=step_fn, num_steps=args.steps,
        meta_fn=lambda t: {"data_cursor": t})
    print(f"finished {args.steps} steps; restarts={out['restarts']} "
          f"stragglers={out['stragglers']}")


if __name__ == "__main__":
    main()
