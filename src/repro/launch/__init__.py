"""Launcher: production meshes, sharding rules, train/serve steps, dry-run."""
