"""Step builders: train_step / prefill_step / serve_step + input_specs.

`input_specs(cfg, shape_name)` returns ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, no allocation) — the dry-run
lowers against these; examples/tests feed real arrays through the same
functions.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer as T
from repro.optim import adamw_init, adamw_update, cosine_schedule


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

def input_specs(cfg: T.ModelConfig, shape_name: str) -> Dict[str, Any]:
    """ShapeDtypeStructs for one (arch x shape) cell."""
    seq, batch, kind = configs.SHAPES[shape_name]
    sds = jax.ShapeDtypeStruct
    if kind == "train":
        text_seq = seq - cfg.num_img_tokens   # img prefix counts toward S
        out = {"tokens": sds((batch, text_seq), jnp.int32),
               "labels": sds((batch, text_seq), jnp.int32)}
        if cfg.num_img_tokens:
            out["img_embeds"] = sds((batch, cfg.num_img_tokens,
                                     cfg.d_model), cfg.act_dtype)
        return out
    if kind == "prefill":
        text_seq = seq - cfg.num_img_tokens
        out = {"tokens": sds((batch, text_seq), jnp.int32)}
        if cfg.num_img_tokens:
            out["img_embeds"] = sds((batch, cfg.num_img_tokens,
                                     cfg.d_model), cfg.act_dtype)
        return out
    if kind == "decode":
        state = jax.eval_shape(
            lambda: T.init_decode_state(cfg, batch, max_len=seq))
        return {"tokens": sds((batch, 1), jnp.int32), "state": state}
    raise ValueError(kind)


def abstract_params(cfg: T.ModelConfig):
    return jax.eval_shape(
        functools.partial(T.init_params, cfg), jax.random.key(0))


def abstract_opt_state(cfg: T.ModelConfig):
    return jax.eval_shape(lambda: adamw_init(abstract_params_concrete(cfg)))


def abstract_params_concrete(cfg):
    # eval_shape-compatible init for the optimizer tree
    return abstract_params(cfg)


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: T.ModelConfig, *, peak_lr: float = 3e-4,
                    warmup: int = 2000, total: int = 100_000,
                    grad_compression: Optional[str] = None):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    grad_compression="bf16" casts grads before the (pod,data) all-reduce —
    the cross-pod bandwidth saver toggled in the perf experiments.
    """

    def train_step(params, opt_state, batch):
        def lf(p):
            return T.loss_fn(p, cfg, batch["tokens"], batch["labels"],
                             batch.get("img_embeds"))

        loss, grads = jax.value_and_grad(lf)(params)
        if grad_compression == "bf16":
            grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        lr = cosine_schedule(opt_state.step, peak_lr=peak_lr,
                             warmup=warmup, total=total)
        params, opt_state, gnorm = adamw_update(params, grads, opt_state,
                                                lr=lr)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm,
                                   "lr": lr}

    return train_step


def make_prefill_step(cfg: T.ModelConfig, max_len: int):
    def prefill_step(params, batch):
        return T.prefill(params, cfg, batch["tokens"],
                         batch.get("img_embeds"), max_len=max_len)
    return prefill_step


def make_serve_step(cfg: T.ModelConfig):
    """One decode step: new token against the KV cache / recurrent state."""
    def serve_step(params, batch):
        return T.decode_step(params, cfg, batch["state"], batch["tokens"])
    return serve_step
