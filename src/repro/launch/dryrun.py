import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

For every (architecture x input shape) cell, lower + compile the relevant
step (train_step / prefill_step / serve_step) against ShapeDtypeStruct
inputs on the production mesh (16x16 single pod, and 2x16x16 multi-pod),
print memory_analysis / cost_analysis, parse collective bytes, and append
a JSON record per cell to the results file.  A failed cell records its
error instead of aborting the sweep — sharding failures are bugs to fix,
and the record shows where.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
  python -m repro.launch.dryrun --all --out results/dryrun.json --skip-done
"""

import argparse
import json
import time
import traceback

import jax

from repro import configs
from repro.launch import analysis, steps
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import data_sharding, param_spec, state_spec, tree_shardings
from repro.optim import adamw_init


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    cfg = configs.get_config(arch, "full")
    seq, batch, kind = configs.SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(map(str, mesh.devices.shape)),
           "kind": kind, "n_devices": int(n_dev)}

    specs = steps.input_specs(cfg, shape_name)
    params_abs = steps.abstract_params(cfg)
    p_shard = tree_shardings(mesh, params_abs, param_spec)

    t0 = time.monotonic()
    with jax.sharding.set_mesh(mesh):
        if kind == "train":
            opt_abs = jax.eval_shape(adamw_init, params_abs)
            o_shard = tree_shardings(mesh, opt_abs, param_spec)
            batch_shard = {
                k: data_sharding(mesh, nd=len(v.shape),
                                 batch_size=v.shape[0])
                for k, v in specs.items()}
            step = steps.make_train_step(cfg)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, o_shard, batch_shard),
                             out_shardings=(p_shard, o_shard, None))
            lowered = jitted.lower(params_abs, opt_abs, specs)
        elif kind == "prefill":
            batch_shard = {
                k: data_sharding(mesh, nd=len(v.shape),
                                 batch_size=v.shape[0])
                for k, v in specs.items()}
            step = steps.make_prefill_step(cfg, max_len=seq)
            jitted = jax.jit(step, in_shardings=(p_shard, batch_shard))
            lowered = jitted.lower(params_abs, specs)
        else:  # decode
            state_shard = tree_shardings(mesh, specs["state"], state_spec)
            batch_shard = {
                "tokens": data_sharding(mesh, nd=2, batch_size=batch),
                "state": state_shard}
            step = steps.make_serve_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_shard, batch_shard),
                             out_shardings=(None, state_shard))
            lowered = jitted.lower(params_abs, specs)
        rec["lower_s"] = round(time.monotonic() - t0, 2)

        t1 = time.monotonic()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.monotonic() - t1, 2)

    mem = compiled.memory_analysis()
    if mem is not None:
        rec["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
        }
        print(f"[{arch} x {shape_name} @ {rec['mesh']}] memory_analysis:",
              mem)
    cost = compiled.cost_analysis()
    rec["cost_raw"] = {k: float(v) for k, v in cost.items()
                       if k in ("flops", "bytes accessed",
                                "transcendentals")}
    # trip-count-aware analysis (cost_analysis counts loop bodies once)
    from repro.launch import hlo_analyzer
    hlo = hlo_analyzer.analyze(compiled.as_text())
    rec["cost"] = {"flops": hlo["flops"], "bytes accessed": hlo["bytes"]}
    print(f"[{arch} x {shape_name} @ {rec['mesh']}] per-device: "
          f"flops={hlo['flops']:.3e} bytes={hlo['bytes']:.3e} "
          f"(raw cost_analysis flops={cost.get('flops', 0):.3e})")

    coll = hlo["collectives"]
    rec["collectives"] = coll
    terms = analysis.roofline_terms(rec["cost"], coll)
    rec["roofline"] = terms

    n_params = sum(
        int(__import__("numpy").prod(leaf.shape))
        for leaf in jax.tree.leaves(params_abs))
    embed = int(__import__("numpy").prod(params_abs["embed"].shape))
    routed = sum(
        int(__import__("numpy").prod(leaf.shape))
        for p, leaf in jax.tree_util.tree_flatten_with_path(params_abs)[0]
        if any(str(getattr(k, "key", "")) in ("w_gate", "w_up", "w_down")
               for k in p))
    rec["n_params"] = n_params
    rec["model_flops_global"] = analysis.model_flops(
        cfg, n_params, shape_name, embed_params=embed,
        routed_params=routed)
    rec["model_flops_per_device"] = rec["model_flops_global"] / n_dev
    rec["useful_flops_ratio"] = (
        rec["model_flops_per_device"] / rec["cost"]["flops"]
        if rec["cost"].get("flops") else None)
    rec["dominant"] = analysis.dominant_term(terms)
    rec["status"] = "ok"
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            for r in json.load(f):
                done[(r["arch"], r["shape"], r["mesh"])] = r
    records = list(done.values())

    archs = sorted(configs.ARCHS) if args.all else [args.arch]
    shapes = list(configs.SHAPES) if args.all or not args.shape \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    for arch in archs:
        cfg = configs.get_config(arch, "full")
        for shape_name in shapes:
            if not configs.runs_cell(cfg, shape_name):
                print(f"SKIP {arch} x {shape_name}: needs sub-quadratic "
                      "attention (documented in DESIGN.md §7)")
                continue
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                key = (arch, shape_name, mesh_name)
                if args.skip_done and key in done and \
                        done[key].get("status") == "ok":
                    print(f"skip done: {key}")
                    continue
                print(f"=== {arch} x {shape_name} @ {mesh_name} ===",
                      flush=True)
                try:
                    rec = run_cell(arch, shape_name, multi)
                except Exception as e:  # record, keep sweeping
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "error",
                           "error": f"{type(e).__name__}: {e}"}
                records = [r for r in records
                           if (r["arch"], r["shape"], r["mesh"]) != key]
                records.append(rec)
                with open(args.out, "w") as f:
                    json.dump(records, f, indent=1)

    n_ok = sum(1 for r in records if r.get("status") == "ok")
    print(f"\n{n_ok}/{len(records)} cells ok -> {args.out}")


if __name__ == "__main__":
    main()
