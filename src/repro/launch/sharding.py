"""Parameter / state / input sharding rules (DP + FSDP + TP + SP + EP).

Strategy (DESIGN.md §6):
 - batch dims shard over ("pod", "data");
 - FSDP: every weight also shards one non-TP dim over "data" (ZeRO-3-style
   — AdamW moments and fp32 masters inherit the same specs, which is what
   makes the 236B/671B configs representable);
 - TP over "model": attention heads (falling back to head_dim when the
   head count does not divide the axis — qwen3-14b's 40 and llava's 56
   heads), FFN hidden, MoE expert dim (EP), vocab;
 - SP: decode KV caches shard their *sequence* dim over "model"
   (split-KV/flash-decoding style) so 32k-500k contexts fit per chip.

Rules match pytree-path suffixes; stacked layer axes (leading scan dims)
are padded with None.
"""

from __future__ import annotations

import re
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def _axis_size(mesh, name: str) -> int:
    shp = getattr(mesh, "shape", None)
    if shp is not None and hasattr(shp, "get"):   # Mesh or AbstractMesh
        return shp.get(name, 1)
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _batch_axes(mesh, batch_size: Optional[int] = None):
    got = tuple(n for n in ("pod", "data") if n in mesh.axis_names)
    if not got:
        return None
    if batch_size is not None:
        total = 1
        for n in got:
            total *= _axis_size(mesh, n)
        if batch_size % total != 0:
            # fall back to the largest prefix that divides (or replicate)
            got = tuple(n for n in got
                        if batch_size % _axis_size(mesh, n) == 0)[:1]
            if not got or batch_size % _axis_size(mesh, got[0]) != 0:
                return None
    return got


def _div(n: int, size: int) -> bool:
    return size > 1 and n % size == 0


def _leaf_path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_spec(mesh, path: str, shape: Tuple[int, ...]) -> P:
    """Trailing-dim sharding rule for one parameter leaf."""
    model = _axis_size(mesh, "model")
    data = _axis_size(mesh, "data")
    name = path.split("/")[-1]
    ctx = path

    def fsdp(dim: int):
        return "data" if _div(dim, data) else None

    def tp(dim: int):
        return "model" if _div(dim, model) else None

    nd = len(shape)

    def pad(spec):
        return P(*([None] * (nd - len(spec)) + list(spec)))

    # embeddings / head
    if name == "embed":
        return pad([tp(shape[-2]), fsdp(shape[-1])])
    if name == "lm_head":
        return pad([fsdp(shape[-2]), tp(shape[-1])])

    # attention (GQA): wq/wk/wv [.., d, H, hd]; wo [.., H, hd, d].
    # When H doesn't divide the model axis (qwen3-14b: 40, llava: 56),
    # attention weights REPLICATE over model (FFN keeps TP): sharding
    # head_dim instead puts a sharded dim inside the attention
    # contraction and all-reduces ~100 GB/layer of score gradients
    # (measured on llava train_4k).
    if name in ("wq", "wk", "wv") and nd >= 3 and "att/" not in ctx:
        d, h, hd = shape[-3], shape[-2], shape[-1]
        if _div(h, model):
            return pad([fsdp(d), "model", None])
        return pad([fsdp(d), None, None])
    if name == "wo" and nd >= 3:
        h, hd, d = shape[-3], shape[-2], shape[-1]
        if _div(h, model):
            return pad(["model", None, fsdp(d)])
        return pad([None, None, fsdp(d)])

    # MLA pieces
    if name in ("wq_a", "wkv_a"):
        return pad([fsdp(shape[-2]), None])
    if name in ("wq_b",):
        return pad([None, tp(shape[-2]), None])
    if name in ("w_uk", "w_uv"):
        return pad([None, tp(shape[-2]), None])

    # dense FFN
    if name in ("gate", "up", "shared_gate", "shared_up"):
        return pad([fsdp(shape[-2]), tp(shape[-1])])
    if name in ("down", "shared_down"):
        return pad([tp(shape[-2]), fsdp(shape[-1])])

    # MoE experts [.., E, d, ff] / [.., E, ff, d]  (EP over the expert dim)
    if name in ("w_gate", "w_up", "w_down"):
        e = shape[-3]
        return pad([tp(e) or None, fsdp(shape[-2]), None])
    if name == "router":
        return pad([fsdp(shape[-2]), None])

    # mamba
    if name == "in_proj":
        return pad([fsdp(shape[-2]), None])
    if name == "out_proj":
        return pad([tp(shape[-2]), fsdp(shape[-1])])

    # rwkv time-mix / channel-mix square + ffn mats
    if re.search(r"(att|ffn)/(wr|wk|wv|wg)$", ctx) and nd >= 2:
        return pad([fsdp(shape[-2]), tp(shape[-1])])
    if re.search(r"(att|ffn)/wo$", ctx) or \
            (name == "wv" and "ffn/" in ctx):
        return pad([tp(shape[-2]), fsdp(shape[-1])])

    # everything small (norms, biases, loras, dt, conv) replicates
    return P()


def state_spec(mesh, path: str, shape: Tuple[int, ...]) -> P:
    """Decode-state sharding: batch over (pod,data), seq over model (SP)."""
    name = path.split("/")[-1]
    nd = len(shape)

    def pad(spec):
        return P(*([None] * (nd - len(spec)) + list(spec)))

    model = _axis_size(mesh, "model")
    if name in ("k", "v"):        # [.., B, C, kv, hd]
        batch = _batch_axes(mesh, shape[-4])
        seq = "model" if _div(shape[-3], model) else None
        return pad([batch, seq, None, None])
    if name in ("ckv", "krope"):  # [.., B, C, r]
        batch = _batch_axes(mesh, shape[-3])
        seq = "model" if _div(shape[-2], model) else None
        return pad([batch, seq, None])
    if name == "pos":
        if nd == 1:
            return P(_batch_axes(mesh, shape[0]))
        batch = _batch_axes(mesh, shape[-2])
        seq = "model" if _div(shape[-1], model) else None
        return pad([batch, seq])
    if name == "conv":            # [.., B, K-1, ch]
        return pad([_batch_axes(mesh, shape[-3]), None, None])
    if name in ("ssd", "wkv"):    # [.., B, H, dk, dv]
        return pad([_batch_axes(mesh, shape[-4]), None, None, None])
    if name in ("shift_att", "shift_ffn"):
        return pad([_batch_axes(mesh, shape[-2]), None])
    return P()


def tree_shardings(mesh, tree, rule) -> object:
    """Map a rule (mesh, path, shape) -> P over a pytree of arrays or
    ShapeDtypeStructs, returning NamedShardings."""
    def one(path, leaf):
        spec = rule(mesh, _leaf_path_str(path), tuple(np.shape(leaf)))
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, tree)


def data_sharding(mesh, nd: int = 2,
                  batch_size: Optional[int] = None) -> NamedSharding:
    """tokens/labels [B, S] (or [B, S, ...]): batch over (pod, data)."""
    return NamedSharding(mesh, P(_batch_axes(mesh, batch_size),
                                 *([None] * (nd - 1))))
