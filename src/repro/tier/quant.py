"""Scalar quantizer for the cold lane.

Per-row absmax int8: ``scale = max|x| / 127``, ``q = round(x / scale)``.
One f32 scale per row, so a cold row costs ``dim + 4`` bytes against
``4 * dim`` dense — a 3.8x lane compression at dim=128 before the
simhash codes (which both lanes keep).  The quantizer is intentionally
symmetric and zero-preserving: an all-zero row round-trips exactly
(scale clamps to a tiny epsilon instead of dividing by zero), and
dequantization is a single fused multiply in the gather kernel.
"""

from __future__ import annotations

import jax.numpy as jnp

# Rows quantize to [-127, 127] (not -128) so the lane is symmetric and
# negation of a vector negates its codes exactly.
_QMAX = 127.0
_EPS = 1e-12


def quantize_rows(rows: jnp.ndarray):
    """f32 [n, d] -> (int8 codes [n, d], f32 scales [n])."""
    absmax = jnp.max(jnp.abs(rows), axis=-1)
    scale = jnp.maximum(absmax / _QMAX, _EPS).astype(jnp.float32)
    q = jnp.clip(jnp.round(rows / scale[..., None]), -_QMAX, _QMAX)
    return q.astype(jnp.int8), scale


def dequantize_rows(codes: jnp.ndarray, scales: jnp.ndarray):
    """(int8 [n, d], f32 [n]) -> f32 [n, d] reconstruction."""
    return codes.astype(jnp.float32) * scales[..., None]
