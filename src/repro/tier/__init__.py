"""Tiered hot/cold vector store (DESIGN.md §12).

Hot nodes keep dense f32 rows resident; cold nodes are demoted to an
int8 scalar-quantized lane (plus the existing simhash codes) logically
backed by the deeper LSM levels, with full-precision rerank of the
final candidates.  `TierPolicy` turns the per-node heat signal already
maintained for reordering into batched demote/promote decisions run
alongside `consolidate`/`reorder` in background maintenance.
"""

from repro.tier.policy import TierPolicy, tier_maintain
from repro.tier.quant import dequantize_rows, quantize_rows

__all__ = ["TierPolicy", "tier_maintain", "quantize_rows",
           "dequantize_rows"]
