"""Heat-driven demote/promote policy for the two-lane store.

`tier_maintain` is a single jitted transition (policy is a static,
hashable dataclass): it folds the traversal heat counters into a
per-node EWMA, ranks live nodes by that score, and moves at most
`max_demote` / `max_promote` nodes across the lane boundary per call.
Hysteresis keeps the boundary from thrashing: a hot node is demoted
only when its rank falls *below* the budget by the hysteresis margin,
and a cold node is promoted only when its rank climbs *above* the
budget by the same margin, so nodes oscillating around rank `k_hot`
stay where they are.

Nodes on the upper HNSW layers are not special-cased here: their f32
rows are part of the resident upper-layer routing cache regardless of
lane (see `hnsw.memory_breakdown`), so demoting one only drops its
*bottom-lane* dense copy — search keeps exact distances for it via
`hot | (levels > 0)` masking.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.iostats import IOStats
from repro.tier.quant import quantize_rows


@dataclasses.dataclass(frozen=True)
class TierPolicy:
    """Static (hashable) knobs for one `tier_maintain` transition.

    hot_frac    — resident dense-lane budget as a fraction of live nodes.
    ewma        — weight of the *new* heat observation in the EWMA.
    hysteresis  — dead band around the budget rank, as a fraction of
                  `k_hot`; larger = fewer lane flips under noisy heat.
    max_demote  — per-call cap on hot->cold moves (batched quantize).
    max_promote — per-call cap on cold->hot moves (each is one modeled
                  full-row fetch from the cold store, counted in n_vec).
    """

    hot_frac: float = 0.25
    ewma: float = 0.5
    hysteresis: float = 0.1
    max_demote: int = 256
    max_promote: int = 64


@functools.partial(jax.jit, static_argnames=("cfg", "policy"))
def tier_maintain(cfg, state, policy: TierPolicy):
    """One batched demote/promote pass.  Returns (state', io, moved).

    `moved` is a dict of scalar i32 counters {"demoted", "promoted"}.
    The traversal heat counters in `state.heat` are *read*, not reset —
    `reorder` owns the heat lifecycle; this pass only folds them into
    the longer-horizon `tier_heat` EWMA.
    """
    cap = cfg.cap
    live = (state.levels >= 0) & ~state.tombstone

    node_heat = jnp.sum(state.heat, axis=1).astype(jnp.float32)
    a = jnp.float32(policy.ewma)
    tier_heat = a * node_heat + (1.0 - a) * state.tier_heat

    # Rank live nodes by heat (0 = hottest).  Dead slots sort to the
    # end and can never cross the demote/promote thresholds.
    score = jnp.where(live, tier_heat, -jnp.inf)
    order = jnp.argsort(-score)
    rank = jnp.zeros((cap,), jnp.float32).at[order].set(
        jnp.arange(cap, dtype=jnp.float32))

    n_live = jnp.maximum(state.n_live, 1).astype(jnp.float32)
    k_hot = jnp.ceil(jnp.float32(policy.hot_frac) * n_live)
    demote_edge = k_hot * (1.0 + policy.hysteresis)
    promote_edge = jnp.maximum(k_hot * (1.0 - policy.hysteresis), 1.0)

    demote_mask = state.hot & live & (rank >= demote_edge)
    promote_mask = ~state.hot & live & (rank < promote_edge)

    # Batched selection: coldest demote candidates / hottest promote
    # candidates first, capped at the policy's static batch sizes.
    n_dem = min(int(policy.max_demote), cap)
    n_pro = min(int(policy.max_promote), cap)
    d_pri = jnp.where(demote_mask, -tier_heat, -jnp.inf)
    d_val, d_ids = jax.lax.top_k(d_pri, n_dem)
    d_ids = jnp.where(jnp.isfinite(d_val), d_ids, cap)   # cap => dropped
    p_pri = jnp.where(promote_mask, tier_heat, -jnp.inf)
    p_val, p_ids = jax.lax.top_k(p_pri, n_pro)
    p_ids = jnp.where(jnp.isfinite(p_val), p_ids, cap)

    # Demote: quantize the dense rows into the cold lane, clear hot.
    rows = state.vectors[jnp.minimum(d_ids, cap - 1)]
    q, scales = quantize_rows(rows)
    qvecs = state.qvecs.at[d_ids].set(q, mode="drop")
    qscale = state.qscale.at[d_ids].set(scales, mode="drop")
    hot = state.hot.at[d_ids].set(False, mode="drop")
    # Promote: flip the lane bit; the dense row is re-fetched from the
    # cold store (vectors array = modeled disk), one n_vec read each.
    hot = hot.at[p_ids].set(True, mode="drop")

    n_demoted = jnp.sum(d_ids < cap).astype(jnp.int32)
    n_promoted = jnp.sum(p_ids < cap).astype(jnp.int32)
    io = IOStats(jnp.int32(0), n_promoted, jnp.int32(0), jnp.int32(0))

    state = state._replace(hot=hot, qvecs=qvecs, qscale=qscale,
                           tier_heat=tier_heat)
    return state, io, {"demoted": n_demoted, "promoted": n_promoted}
