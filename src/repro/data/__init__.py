"""Deterministic synthetic data pipelines (tokens for LM training, vectors
for the ANN benchmarks)."""

from repro.data.synth import make_clustered_vectors, token_pipeline

__all__ = ["make_clustered_vectors", "token_pipeline"]
