"""Synthetic data generators.

`make_clustered_vectors` mimics SIFT's clustered structure (the paper's
SIFT1B substrate is not shippable offline): a Gaussian-mixture in d dims,
values roughly in SIFT's dynamic range.  Queries drawn with the same
`center_seed` are in-distribution (the SIFT query set is), while a
different `center_seed` produces out-of-distribution probes.

`token_pipeline` is the LM-side data substrate: an infinite deterministic
stream of (tokens, labels) batches, shardable by (host, step) so every data
-parallel worker sees a disjoint slice — the property a real multi-pod
input pipeline must have (resume-able by step, no host coordination).
"""

from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def make_clustered_vectors(n: int, dim: int = 128, seed: int = 0,
                           clusters: int = 64, center_seed: int = 123,
                           scale: float = 2.5,
                           noise: float = 1.0) -> np.ndarray:
    """SIFT-like clustered vectors, float32 [n, dim]."""
    crng = np.random.default_rng(center_seed)
    centers = crng.normal(0.0, scale, (clusters, dim))
    rng = np.random.default_rng(seed)
    asg = rng.integers(0, clusters, n)
    return (centers[asg] + rng.normal(0.0, noise, (n, dim))).astype(np.float32)


def token_pipeline(batch: int, seq_len: int, vocab: int, *, seed: int = 0,
                   host_id: int = 0, num_hosts: int = 1,
                   start_step: int = 0) -> Iterator[
                       Tuple[np.ndarray, np.ndarray]]:
    """Deterministic sharded token stream.

    Step t on host h derives its slice from counter (t * num_hosts + h), so
    (a) restarts resume exactly (pass start_step), (b) hosts never overlap,
    (c) elastics re-shard cleanly: changing num_hosts re-partitions the same
    underlying stream.  Yields (tokens[batch, seq_len], labels) int32 where
    labels are tokens shifted by one (next-token prediction).
    """
    t = start_step
    while True:
        counter = np.uint64(t) * np.uint64(num_hosts) + np.uint64(host_id)
        rng = np.random.default_rng(
            np.random.SeedSequence(entropy=seed, spawn_key=(int(counter),)))
        # zipfian-ish marginal to mimic natural token frequencies
        z = rng.zipf(1.3, size=(batch, seq_len + 1)).astype(np.int64)
        toks = (z % vocab).astype(np.int32)
        yield toks[:, :-1], toks[:, 1:]
        t += 1
