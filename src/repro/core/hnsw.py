"""Hybrid memory/disk hierarchical proximity graph (paper §3.2).

Upper HNSW layers (layers 2.. in the paper's numbering; <1% of nodes) are
memory-resident dense adjacency arrays.  The bottom layer — the bulk of the
graph — lives in the LSM tree, so every structural update is an
out-of-place LSM write.  Vectors are stored in one contiguous ID-sorted
array ("disk", i.e. HBM on the TPU mapping) fetched by offset; SimHash
codes are memory-resident.

Implements Algorithm 1 (insert) and Algorithm 2 (delete with local
neighbor relinking) plus a bulk construction path used for initial index
builds (an exact-kNN bottom graph, the offline analogue).
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import lsm, simhash
from repro.core.iostats import IOStats
from repro.core.traversal import BeamResult, beam_search, greedy_descent
from repro.kernels.gather_l2.ops import gather_l2
from repro.kernels.l2_distance.ops import l2_distance

INF = jnp.inf


class HNSWConfig(NamedTuple):
    cap: int                 # id-space size (max nodes ever allocated)
    dim: int
    M: int = 16              # bottom-layer degree (LSM row width)
    M_up: int = 8            # upper-layer degree
    num_upper: int = 3       # number of memory-resident upper layers
    ef_search: int = 48
    ef_construction: int = 48
    k: int = 10
    m_bits: int = 64         # SimHash code width
    rho: float = 1.0         # sampling ratio (Eq. 8); 1.0 = no sampling
    eps: float = 0.1         # Hoeffding miss probability (Eq. 6)
    use_filter: bool = True  # hash-threshold filtering on top of rho
    lsm_mem_cap: int = 256
    lsm_levels: int = 3
    lsm_fanout: int = 8

    @property
    def lsm_cfg(self) -> lsm.LSMConfig:
        # last level must hold every node's adjacency row
        need = self.cap
        base = max(self.lsm_mem_cap, 64)
        fan = self.lsm_fanout
        # grow fanout chain until the last level covers `need`
        lv = self.lsm_levels
        while base * fan ** lv < need:
            fan += 1
        return lsm.LSMConfig(mem_cap=base, num_levels=lv, fanout=fan,
                             row_width=self.M)


    @property
    def max_iters(self) -> int:
        return 2 * self.ef_search

    @property
    def words(self) -> int:
        return self.m_bits // 32


class HNSWState(NamedTuple):
    vectors: jax.Array      # f32[cap, dim] — "disk" array, ID-sorted
    norms: jax.Array        # f32[cap]
    codes: jax.Array        # uint32[cap, W] — memory-resident
    levels: jax.Array       # int32[cap]: -1 absent/deleted, else 0..num_upper
    upper_adj: jax.Array    # int32[num_upper, cap, M_up]
    store: lsm.LSMState     # bottom-layer adjacency
    proj: jax.Array         # f32[m_bits, dim] — SimHash projections
    count: jax.Array        # int32[] — ids allocated so far
    n_live: jax.Array       # int32[]
    entry: jax.Array        # int32[]
    max_level: jax.Array    # int32[]
    mean_norm: jax.Array    # f32[]
    heat: jax.Array         # int32[cap, M] — sampled edge heat (§3.4)


def init(cfg: HNSWConfig, key: jax.Array) -> HNSWState:
    return HNSWState(
        vectors=jnp.zeros((cfg.cap, cfg.dim), jnp.float32),
        norms=jnp.zeros((cfg.cap,), jnp.float32),
        codes=jnp.zeros((cfg.cap, cfg.words), jnp.uint32),
        levels=jnp.full((cfg.cap,), -1, jnp.int32),
        upper_adj=jnp.full((cfg.num_upper, cfg.cap, cfg.M_up), -1, jnp.int32),
        store=lsm.init(cfg.lsm_cfg),
        proj=jax.random.normal(key, (cfg.m_bits, cfg.dim), jnp.float32),
        count=jnp.zeros((), jnp.int32),
        n_live=jnp.zeros((), jnp.int32),
        entry=jnp.full((), -1, jnp.int32),
        max_level=jnp.zeros((), jnp.int32),
        mean_norm=jnp.ones((), jnp.float32),
        heat=jnp.zeros((cfg.cap, cfg.M), jnp.int32),
    )


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _dist_fn(state: HNSWState, q: jax.Array):
    """ids int32[n] -> squared L2 f32[n]; -1 ids cost nothing (+inf).

    On TPU this is the fused gather+distance Pallas kernel (the "disk
    fetch"); on CPU containers the jnp oracle with identical semantics.
    """
    def fn(ids):
        return gather_l2(q[None, :], state.vectors, ids[None, :])[0]
    return fn


def _bottom_adj_fn(cfg: HNSWConfig, state: HNSWState):
    def fn(node):
        found, row, probes = lsm.get(cfg.lsm_cfg, state.store, node)
        return jnp.where(found, row, -1), probes
    return fn


def _upper_adj_fn(state: HNSWState, u: int):
    def fn(node):
        return state.upper_adj[u, node], jnp.zeros((), jnp.int32)
    return fn


def _point_dist(state: HNSWState, q: jax.Array, node: jax.Array) -> jax.Array:
    v = state.vectors[jnp.maximum(node, 0)]
    return jnp.sum((q - v) ** 2)


def _descend_upper(cfg: HNSWConfig, state: HNSWState, q: jax.Array,
                   down_to: jax.Array):
    """Greedy-route through upper layers u = num_upper-1 .. down_to."""
    ep = jnp.maximum(state.entry, 0)
    d_ep = _point_dist(state, q, ep)
    for u in reversed(range(cfg.num_upper)):
        live_u = state.levels > u
        new_ep, new_d = greedy_descent(q, ep, d_ep, state.upper_adj[u],
                                       state.vectors, live_u)
        use = jnp.asarray(u, jnp.int32) >= down_to
        ep = jnp.where(use, new_ep, ep)
        d_ep = jnp.where(use, new_d, d_ep)
    return ep, d_ep


def _topm(ids: jax.Array, dists: jax.Array, m: int):
    """Best-m prefix of a distance-sorted candidate list (pad -1)."""
    order = jnp.argsort(dists, stable=True)[:m]
    out_ids = ids[order]
    out_d = dists[order]
    return jnp.where(jnp.isfinite(out_d), out_ids, -1), out_d


def _diversity_topm(ids: jax.Array, dists: jax.Array, vectors: jax.Array,
                    m: int, alpha: float = 1.0):
    """HNSW neighbor-selection heuristic (keepPruned variant).

    Greedily keeps candidate c only if it is closer to the base point than
    to every already-kept neighbor (`alpha` relaxes the test, Vamana
    style), then fills leftover slots with the nearest pruned candidates.
    Plain closest-M edges all point into the local cluster and strand the
    graph on clustered data; diverse edges are what keeps it navigable.
    """
    order = jnp.argsort(dists, stable=True)
    ids, dists = ids[order], dists[order]
    c = ids.shape[0]
    cv = vectors[jnp.maximum(ids, 0)]
    pair = jnp.sum((cv[:, None, :] - cv[None, :, :]) ** 2, axis=-1)
    valid = jnp.isfinite(dists) & (ids >= 0)

    def body(i, kept):
        dominated = jnp.any(kept & (alpha * pair[i] < dists[i]))
        space = jnp.sum(kept) < m
        return kept.at[i].set(valid[i] & (~dominated) & space)

    kept = jax.lax.fori_loop(0, c, body, jnp.zeros((c,), jnp.bool_))
    rank = jnp.argsort(~kept, stable=True)   # kept first, distance order
    ids2, valid2 = ids[rank], valid[rank]
    return jnp.where(valid2[:m], ids2[:m], -1), dists[rank][:m]


def _evict_slot(row: jax.Array, row_vecs_d_new: jax.Array) -> jax.Array:
    """Backlink slot choice: empty slot first, else evict the existing
    neighbor *closest to the incoming node* (most redundant direction) —
    never the farthest, which would strip the long-range portals."""
    score = jnp.where(row < 0, INF, -row_vecs_d_new)
    return jnp.argmax(score)


def _dedup_to_inf(ids: jax.Array, dists: jax.Array):
    """Mask duplicate ids (keep first by distance order) with +inf."""
    order = jnp.argsort(ids, stable=True)
    sid = ids[order]
    dup_sorted = jnp.concatenate([jnp.array([False]), sid[1:] == sid[:-1]])
    dup = jnp.zeros_like(dup_sorted).at[order].set(dup_sorted)
    return jnp.where(dup, INF, dists)


# ---------------------------------------------------------------------------
# search (paper §3.2 "Search in LSM-VEC")
# ---------------------------------------------------------------------------

def search(cfg: HNSWConfig, state: HNSWState, q: jax.Array,
           *, rho: float | None = None, ef: int | None = None,
           use_filter: bool | None = None) -> BeamResult:
    """Single-query search: upper greedy descent -> sampled bottom beam."""
    ef = ef or cfg.ef_search
    rho = cfg.rho if rho is None else rho
    use_filter = cfg.use_filter if use_filter is None else use_filter
    ep, d_ep = _descend_upper(cfg, state, q, jnp.zeros((), jnp.int32))
    code_q = simhash.encode(simhash.SimHashParams(state.proj), q[None, :])[0]
    return beam_search(
        q, ep, d_ep,
        _bottom_adj_fn(cfg, state), _dist_fn(state, q),
        state.codes, code_q, state.levels >= 0,
        cap=cfg.cap, ef=ef, k=cfg.k, m_bits=cfg.m_bits, eps=cfg.eps,
        rho=rho, max_iters=2 * ef, use_filter=use_filter,
        q_norm=jnp.sqrt(jnp.sum(q * q)), mean_norm=state.mean_norm)


def search_batch(cfg: HNSWConfig, state: HNSWState, qs: jax.Array,
                 **kw) -> BeamResult:
    return jax.vmap(lambda q: search(cfg, state, q, **kw))(qs)


# ---------------------------------------------------------------------------
# insert (Algorithm 1)
# ---------------------------------------------------------------------------

def _put_masked(cfg: HNSWConfig, store: lsm.LSMState, key, row, active):
    """LSM put that lands on a reserved dead key when inactive.

    Avoids lax.cond duplication of the flush machinery: id `cap` is outside
    the live id space and never looked up.
    """
    dead = jnp.asarray(cfg.cap, jnp.int32)
    return lsm.put(cfg.lsm_cfg, store,
                   jnp.where(active, key, dead), row)


def insert(cfg: HNSWConfig, state: HNSWState, x: jax.Array,
           key: jax.Array) -> Tuple[HNSWState, IOStats]:
    """Insert one vector (Algorithm 1).  Returns (state, construction IO)."""
    i = state.count
    # paper: Pr(L) ∝ e^{-L}  -> L = floor(Exp(1)), capped at num_upper
    u01 = jax.random.uniform(key, (), jnp.float32, 1e-7, 1.0)
    lvl = jnp.minimum(jnp.floor(-jnp.log(u01)).astype(jnp.int32),
                      cfg.num_upper)

    xnorm = jnp.sqrt(jnp.sum(x * x))
    code = simhash.encode(simhash.SimHashParams(state.proj), x[None, :])[0]
    state = state._replace(
        vectors=state.vectors.at[i].set(x),
        norms=state.norms.at[i].set(xnorm),
        codes=state.codes.at[i].set(code),
        levels=state.levels.at[i].set(lvl),
        mean_norm=(state.mean_norm * state.n_live + xnorm)
        / jnp.maximum(state.n_live + 1, 1),
    )

    first = state.n_live == 0

    # ---- phase 1+2: upper layers ------------------------------------------
    ep = jnp.maximum(state.entry, 0)
    d_ep = _point_dist(state, x, ep)
    upper_adj = state.upper_adj
    for u in reversed(range(cfg.num_upper)):
        live_u = (state.levels > u) & (jnp.arange(cfg.cap) != i)
        above = jnp.asarray(u, jnp.int32) >= lvl   # greedy-only zone
        # greedy step (used when u >= lvl)
        g_ep, g_d = greedy_descent(x, ep, d_ep, upper_adj[u],
                                   state.vectors, live_u)
        # connect zone (u < lvl): ef-search this layer, link bidirectionally
        res = beam_search(
            x, ep, d_ep, _upper_adj_fn(state._replace(upper_adj=upper_adj), u),
            _dist_fn(state, x), state.codes, code, live_u,
            cap=cfg.cap, ef=cfg.ef_construction, k=cfg.k, m_bits=cfg.m_bits,
            eps=cfg.eps, rho=1.0, max_iters=2 * cfg.ef_construction,
            use_filter=False, q_norm=xnorm, mean_norm=state.mean_norm)
        nbrs, _ = _diversity_topm(res.ids, res.dists, state.vectors,
                                  cfg.M_up)
        connect = (~above) & (~first)
        upper_adj = upper_adj.at[u, i].set(
            jnp.where(connect, nbrs, upper_adj[u, i]))
        # backlinks: always formed; evict the most redundant edge when full
        for j in range(cfg.M_up):
            n = nbrs[j]
            ok = connect & (n >= 0)
            n_safe = jnp.maximum(n, 0)
            row = upper_adj[u, n_safe]
            d_new = jnp.sum((state.vectors[jnp.maximum(row, 0)]
                             - x[None, :]) ** 2, axis=-1)
            slot = _evict_slot(row, d_new)
            new_row = row.at[slot].set(i)
            upper_adj = upper_adj.at[u, n_safe].set(
                jnp.where(ok, new_row, row))
        ep = jnp.where(above, g_ep, jnp.where(res.dists[0] < INF,
                                              res.ids[0], ep))
        d_ep = jnp.where(above, g_d, jnp.minimum(res.dists[0], d_ep))
    state = state._replace(upper_adj=upper_adj)

    # ---- phase 3: bottom layer (disk / LSM) ---------------------------------
    res = beam_search(
        x, ep, d_ep, _bottom_adj_fn(cfg, state), _dist_fn(state, x),
        state.codes, code, (state.levels >= 0) & (jnp.arange(cfg.cap) != i),
        cap=cfg.cap, ef=cfg.ef_construction, k=cfg.k, m_bits=cfg.m_bits,
        eps=cfg.eps, rho=cfg.rho, max_iters=2 * cfg.ef_construction,
        use_filter=cfg.use_filter, q_norm=xnorm, mean_norm=state.mean_norm)
    nbrs, _ = _diversity_topm(res.ids, res.dists, state.vectors, cfg.M)
    nbrs = jnp.where(first, -1, nbrs)

    store = _put_masked(cfg, state.store, i, nbrs, jnp.bool_(True))
    # bidirectional links (Fig. 3: links are always formed; when the row is
    # full the most redundant existing edge is evicted, keeping the new
    # node reachable without stripping long-range portals)
    for j in range(cfg.M):
        n = nbrs[j]
        ok = n >= 0
        n_safe = jnp.maximum(n, 0)
        found, row, _ = lsm.get(cfg.lsm_cfg, store, n_safe)
        row = jnp.where(found, row, -1)
        d_new = jnp.sum((state.vectors[jnp.maximum(row, 0)]
                         - x[None, :]) ** 2, axis=-1)
        slot = _evict_slot(row, d_new)
        new_row = row.at[slot].set(i)
        store = _put_masked(cfg, store, n_safe, new_row, ok)

    new_entry = jnp.where(first | (lvl > state.max_level), i, state.entry)
    state = state._replace(
        store=store,
        count=state.count + 1,
        n_live=state.n_live + 1,
        entry=new_entry,
        max_level=jnp.maximum(state.max_level, lvl))
    stats = res.stats._replace(
        n_vec=res.stats.n_vec + cfg.M)  # backlink row re-rankings
    return state, stats


# ---------------------------------------------------------------------------
# delete (Algorithm 2)
# ---------------------------------------------------------------------------

def delete(cfg: HNSWConfig, state: HNSWState, node) -> Tuple[HNSWState, IOStats]:
    """Delete a vector with local neighbor relinking (Algorithm 2)."""
    i = jnp.asarray(node, jnp.int32)
    upper_adj = state.upper_adj

    # ---- upper layers -------------------------------------------------------
    for u in range(cfg.num_upper):
        active = state.levels[i] > u
        nbr = upper_adj[u, i]                                   # [M_up]
        nbr_safe = jnp.maximum(nbr, 0)
        cand = jnp.concatenate(
            [upper_adj[u, nbr_safe].reshape(-1), nbr])          # 2-hop pool C
        for jj in range(cfg.M_up):
            p = nbr[jj]
            ok = active & (p >= 0)
            p_safe = jnp.maximum(p, 0)
            d = jnp.sum((state.vectors[jnp.maximum(cand, 0)]
                         - state.vectors[p_safe][None, :]) ** 2, axis=-1)
            bad = (cand < 0) | (cand == i) | (cand == p) \
                | (state.levels[jnp.maximum(cand, 0)] <= u)
            d = jnp.where(bad, INF, d)
            d = _dedup_to_inf(jnp.where(bad, -1, cand), d)
            new_row, _ = _topm(cand, d, cfg.M_up)
            upper_adj = upper_adj.at[u, p_safe].set(
                jnp.where(ok, new_row, upper_adj[u, p_safe]))
        upper_adj = upper_adj.at[u, i].set(
            jnp.where(active, -1, upper_adj[u, i]))
    state = state._replace(upper_adj=upper_adj)

    # ---- bottom layer (Algorithm 2 lines 13-22) -----------------------------
    found, n1, _ = lsm.get(cfg.lsm_cfg, state.store, i)
    n1 = jnp.where(found, n1, -1)                               # [M]
    n1_safe = jnp.maximum(n1, 0)
    _, rows, _ = lsm.get_batch(cfg.lsm_cfg, state.store, n1_safe)  # [M, M]
    cand = jnp.concatenate([rows.reshape(-1), n1])              # [M*M + M]
    store = state.store
    n_vec = jnp.zeros((), jnp.int32)
    for jj in range(cfg.M):
        p = n1[jj]
        ok = p >= 0
        p_safe = jnp.maximum(p, 0)
        d = jnp.sum((state.vectors[jnp.maximum(cand, 0)]
                     - state.vectors[p_safe][None, :]) ** 2, axis=-1)
        bad = (cand < 0) | (cand == i) | (cand == p) \
            | (state.levels[jnp.maximum(cand, 0)] < 0)
        d = jnp.where(bad, INF, d)
        d = _dedup_to_inf(jnp.where(bad, -1, cand), d)
        new_row, _ = _topm(cand, d, cfg.M)
        store = _put_masked(cfg, store, p_safe, new_row, ok)
        n_vec = n_vec + jnp.sum(jnp.isfinite(d)).astype(jnp.int32)
    store = lsm.delete(cfg.lsm_cfg, store, i)

    was_live = state.levels[i] >= 0
    levels = state.levels.at[i].set(-1)
    # entry repair: highest remaining level (argmax breaks ties by lowest id)
    need_new_entry = (state.entry == i)
    alt = jnp.argmax(jnp.where(jnp.arange(cfg.cap) == i, -1, levels))
    entry = jnp.where(need_new_entry, alt.astype(jnp.int32), state.entry)
    state = state._replace(
        store=store, levels=levels, entry=entry,
        max_level=jnp.maximum(levels[jnp.maximum(entry, 0)], 0),
        n_live=state.n_live - was_live.astype(jnp.int32))
    stats = IOStats(n_adj=jnp.asarray(1 + cfg.M, jnp.int32), n_vec=n_vec,
                    n_filtered=jnp.zeros((), jnp.int32),
                    n_hops=jnp.zeros((), jnp.int32))
    return state, stats


# ---------------------------------------------------------------------------
# bulk construction (initial index build)
# ---------------------------------------------------------------------------

def _np_diversity_select(cand: "np.ndarray", cand_d: "np.ndarray",
                         vecs_np, deg: int, alpha: float = 1.0):
    """Numpy twin of _diversity_topm (keepPruned heuristic)."""
    import numpy as np
    order = np.argsort(cand_d)
    cand, cand_d = cand[order], cand_d[order]
    cv = vecs_np[cand]
    diff = cv[:, None, :] - cv[None, :, :]
    pair = np.einsum("ijk,ijk->ij", diff, diff)
    kept: list[int] = []
    kept_idx: list[int] = []
    for ci in range(len(cand)):
        if len(kept) >= deg:
            break
        if all(alpha * pair[ci, kj] >= cand_d[ci] for kj in kept_idx):
            kept.append(int(cand[ci]))
            kept_idx.append(ci)
    for ci in range(len(cand)):            # keepPruned fill
        if len(kept) >= deg:
            break
        if int(cand[ci]) not in kept:
            kept.append(int(cand[ci]))
            kept_idx.append(ci)
    return kept, [float(cand_d[j]) for j in kept_idx]


def _incremental_graph(vecs_np, member_ids, deg: int, seed: int,
                       batch: int = 64):
    """Batched random-order incremental construction of one layer.

    Nodes arrive in random order and connect to a *diversity-selected* set
    among the already-placed nodes (HNSW's neighbor heuristic); back-edges
    evict the placed node's most redundant edge.  Early arrivals keep
    long-range links, which is exactly how incremental HNSW/NSW layers
    become navigable — an exact kNN graph would fall apart into per-cluster
    islands.  Host-side numpy; the per-batch distance block uses the shared
    kernel wrapper.
    """
    import numpy as np
    n_total = vecs_np.shape[0]
    rows = np.full((n_total, deg), -1, np.int32)
    rowd = np.full((n_total, deg), np.inf, np.float32)
    ids = np.asarray(member_ids)
    if ids.size == 0:
        return rows
    rng = np.random.default_rng(seed)
    order = ids[rng.permutation(ids.size)]
    placed = [int(order[0])]
    # geometric batch ramp: early nodes (the long-range hubs) must connect
    # densely to each other, not just to the seed
    bounds = [1]
    step = 1
    while bounds[-1] < order.size:
        bounds.append(min(bounds[-1] + step, order.size))
        step = min(batch, step * 2)
    for s, e in zip(bounds[:-1], bounds[1:]):
        chunk = order[s:e]
        pv = jnp.asarray(vecs_np[np.asarray(placed)])
        d_blk = np.asarray(l2_distance(jnp.asarray(vecs_np[chunk]), pv))
        kk = min(2 * deg, len(placed))     # candidate pool for diversity
        top = np.argpartition(d_blk, kk - 1, axis=1)[:, :kk] \
            if kk < len(placed) else \
            np.broadcast_to(np.arange(len(placed)), (len(chunk),
                                                     len(placed)))
        placed_arr = np.asarray(placed)
        for bi, i in enumerate(chunk):
            cand = placed_arr[top[bi]]
            nb, nd = _np_diversity_select(cand, d_blk[bi, top[bi]],
                                          vecs_np, deg)
            rows[i, : len(nb)] = nb
            rowd[i, : len(nd)] = nd
            for p_, d_ in zip(nb, nd):
                free = np.flatnonzero(rows[p_] < 0)
                if free.size:
                    j = int(free[0])
                else:
                    # evict the edge most redundant w.r.t. the newcomer
                    nbr_vecs = vecs_np[rows[p_]]
                    d_to_new = ((nbr_vecs - vecs_np[i]) ** 2).sum(1)
                    j = int(np.argmin(d_to_new))
                rows[p_, j] = i
                rowd[p_, j] = d_
            placed.append(int(i))
    return rows


def bulk_build(cfg: HNSWConfig, vectors: jax.Array, key: jax.Array,
               *, batch: int = 64) -> HNSWState:
    """Initial index build: batched incremental construction per layer.

    Semantically this is Algorithm 1 run over a random insertion order with
    exact (brute-force) neighbor search instead of beam search — the graph
    the paper's insert procedure converges to, built at matmul speed.  The
    bottom layer is written into the LSM tree as one sorted run (the
    offline "build one big level" path); dynamic updates afterwards always
    go through insert()/delete().
    """
    import numpy as np
    n, dim = vectors.shape
    assert n <= cfg.cap and dim == cfg.dim
    k_init, k_lvl = jax.random.split(key)
    state = init(cfg, k_init)

    vecs = jnp.asarray(vectors, jnp.float32)
    vecs_np = np.asarray(vecs)
    norms = jnp.linalg.norm(vecs, axis=1)
    codes = simhash.encode(simhash.SimHashParams(state.proj), vecs)
    lvls_np = np.minimum(
        np.floor(-np.log(np.asarray(jax.random.uniform(
            k_lvl, (n,), jnp.float32, 1e-7, 1.0)))).astype(np.int32),
        cfg.num_upper)
    lvls_np[0] = cfg.num_upper   # stable entry chain
    ids = jnp.arange(n, dtype=jnp.int32)

    bottom = _incremental_graph(vecs_np, np.arange(n), cfg.M, seed=0,
                                batch=batch)
    store = lsm.bulk_load(cfg.lsm_cfg, ids, jnp.asarray(bottom))

    upper = jnp.full((cfg.num_upper, cfg.cap, cfg.M_up), -1, jnp.int32)
    for u in range(cfg.num_upper):
        members = np.flatnonzero(lvls_np > u)
        rows_u = _incremental_graph(vecs_np, members, cfg.M_up, seed=u + 1,
                                    batch=batch)
        upper = upper.at[u, :n].set(jnp.asarray(rows_u))

    lvls = jnp.asarray(lvls_np)
    entry = jnp.argmax(lvls).astype(jnp.int32)
    return state._replace(
        vectors=state.vectors.at[:n].set(vecs),
        norms=state.norms.at[:n].set(norms),
        codes=state.codes.at[:n].set(codes),
        levels=state.levels.at[:n].set(lvls),
        upper_adj=upper,
        store=store,
        count=jnp.asarray(n, jnp.int32),
        n_live=jnp.asarray(n, jnp.int32),
        entry=entry,
        max_level=lvls[entry],
        mean_norm=jnp.mean(norms))


# ---------------------------------------------------------------------------
# memory accounting (paper Fig. 6 — what must stay RAM-resident)
# ---------------------------------------------------------------------------

def memory_resident_bytes(cfg: HNSWConfig, state: HNSWState) -> jax.Array:
    """Bytes of RAM the index needs: upper layers + codes + memtable.

    Vectors and the bottom-layer graph live on "disk"; DiskANN-style systems
    keep the full graph in memory during updates — that difference is the
    paper's 66.2% memory claim (Fig. 6).
    """
    n_upper = jnp.sum(state.levels > 0)
    upper_bytes = n_upper * cfg.M_up * 4 * cfg.num_upper
    code_bytes = jnp.sum(state.levels >= 0) * cfg.words * 4
    memtable_bytes = cfg.lsm_cfg.mem_cap * (4 + 4 * cfg.M + 1)
    vec_cache = n_upper * cfg.dim * 4     # upper-node vectors cached in RAM
    return upper_bytes + code_bytes + memtable_bytes + vec_cache + 4096
