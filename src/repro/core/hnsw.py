"""Hybrid memory/disk hierarchical proximity graph (paper §3.2).

Upper HNSW layers (layers 2.. in the paper's numbering; <1% of nodes) are
memory-resident dense adjacency arrays.  The bottom layer — the bulk of the
graph — lives in the LSM tree, so every structural update is an
out-of-place LSM write.  Vectors are stored in one contiguous ID-sorted
array ("disk", i.e. HBM on the TPU mapping) fetched by offset; SimHash
codes are memory-resident.

Implements Algorithm 1 (insert) and Algorithm 2 (delete with local
neighbor relinking) plus a bulk construction path used for initial index
builds (an exact-kNN bottom graph, the offline analogue).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import lsm, simhash
from repro.core.backend import MemoryBreakdown
from repro.core.iostats import IOStats
from repro.core.traversal import BeamResult, beam_search, greedy_descent
from repro.kernels.beam.ops import fused_beam_search
from repro.kernels.gather_l2.ops import gather_l2, gather_l2_q8
from repro.kernels.l2_distance.ops import l2_distance

INF = jnp.inf


class HNSWConfig(NamedTuple):
    cap: int                 # id-space size (max nodes ever allocated)
    dim: int
    M: int = 16              # bottom-layer degree (LSM row width)
    M_up: int = 8            # upper-layer degree
    num_upper: int = 3       # number of memory-resident upper layers
    ef_search: int = 48
    ef_construction: int = 48
    k: int = 10
    m_bits: int = 64         # SimHash code width
    rho: float = 1.0         # sampling ratio (Eq. 8); 1.0 = no sampling
    eps: float = 0.1         # Hoeffding miss probability (Eq. 6)
    use_filter: bool = True  # hash-threshold filtering on top of rho
    lsm_mem_cap: int = 256
    lsm_levels: int = 3
    lsm_fanout: int = 8
    n_expand: int = 1        # query-path multi-expansion width (B); 1 = classic
    batch_expand: int = 4    # multi-expansion width for insert_batch searches
    #: two-phase lazy deletion (DESIGN.md §9): delete/delete_batch only set
    #: a tombstone bit (routable-not-returnable) and `consolidate` splices
    #: tombstones out of the graph later.  False = the eager Algorithm-2
    #: relink-on-delete path (the paper baseline).
    lazy_delete: bool = True
    #: two-lane tiered store (DESIGN.md §12): cold nodes answer beam
    #: expansions from the int8 quantized lane and the final candidate
    #: window is reranked against full-precision rows from the cold store.
    tier: bool = False
    #: width of the exact-rerank window over the beam result (clamped to
    #: ef_search).  Recall loss from cold-lane quantization is bounded by
    #: this window: any true neighbor the approximate beam ranks within
    #: the top `rerank` gets its exact distance back before the final cut.
    rerank: int = 32
    #: fused beam-search megakernel (DESIGN.md §15): run the whole
    #: bottom-layer beam loop for a query block in one launch
    #: (`repro.kernels.beam`) instead of the XLA `while_loop`.  Only the
    #: snapshot serving path routes through it (plain LSM-probe searches
    #: keep the `while_loop`); results are bit-parity either way, so
    #: flipping this never changes answers — only the launch shape.
    fused_beam: bool = False
    #: scale on the Exp(1) level draw: P(level >= 1) = exp(-1/level_scale).
    #: 1.0 keeps the historical draw (~37% of nodes upper); the paper's
    #: "<1% of nodes in upper layers" regime is level_scale ~= 0.25
    #: (e^-4 ~= 1.8%), which the memory benchmarks use so the resident
    #: upper-layer vector cache doesn't dwarf the lane accounting.
    level_scale: float = 1.0

    @property
    def lsm_cfg(self) -> lsm.LSMConfig:
        # last level must hold every node's adjacency row
        need = self.cap
        base = max(self.lsm_mem_cap, 64)
        fan = self.lsm_fanout
        # grow fanout chain until the last level covers `need`
        lv = self.lsm_levels
        while base * fan ** lv < need:
            fan += 1
        return lsm.LSMConfig(mem_cap=base, num_levels=lv, fanout=fan,
                             row_width=self.M)


    @property
    def max_iters(self) -> int:
        return 2 * self.ef_search

    @property
    def words(self) -> int:
        return self.m_bits // 32


class HNSWState(NamedTuple):
    vectors: jax.Array      # f32[cap, dim] — "disk" array, ID-sorted
    norms: jax.Array        # f32[cap]
    codes: jax.Array        # uint32[cap, W] — memory-resident
    levels: jax.Array       # int32[cap]: -1 absent/deleted, else 0..num_upper
    upper_adj: jax.Array    # int32[num_upper, cap, M_up]
    store: lsm.LSMState     # bottom-layer adjacency
    proj: jax.Array         # f32[m_bits, dim] — SimHash projections
    count: jax.Array        # int32[] — ids allocated so far
    n_live: jax.Array       # int32[]
    entry: jax.Array        # int32[]
    max_level: jax.Array    # int32[]
    mean_norm: jax.Array    # f32[]
    heat: jax.Array         # int32[cap, M] — sampled edge heat (§3.4)
    # lazy-deletion lane (DESIGN.md §9): tombstoned nodes keep levels >= 0
    # (routable) but are masked out of result heaps (not returnable) until
    # `consolidate` splices them out and reclaims the slots
    tombstone: jax.Array    # bool[cap]
    n_tombstones: jax.Array  # int32[] — live tombstone count
    n_delete_noops: jax.Array  # int32[] — deletes of absent/dead ids
    # tiered hot/cold lanes (DESIGN.md §12): `hot` marks nodes whose dense
    # f32 row is RAM-resident; cold nodes are served from (qvecs, qscale)
    # — per-row absmax int8 — and only touch the full-precision row at
    # rerank.  `tier_heat` is the demotion policy's EWMA of per-node heat.
    hot: jax.Array          # bool[cap] — True = dense lane resident
    qvecs: jax.Array        # int8[cap, dim] — cold-lane codes
    qscale: jax.Array       # f32[cap] — cold-lane per-row scales
    tier_heat: jax.Array    # f32[cap] — heat EWMA (policy state)


def init(cfg: HNSWConfig, key: jax.Array) -> HNSWState:
    return HNSWState(
        vectors=jnp.zeros((cfg.cap, cfg.dim), jnp.float32),
        norms=jnp.zeros((cfg.cap,), jnp.float32),
        codes=jnp.zeros((cfg.cap, cfg.words), jnp.uint32),
        levels=jnp.full((cfg.cap,), -1, jnp.int32),
        upper_adj=jnp.full((cfg.num_upper, cfg.cap, cfg.M_up), -1, jnp.int32),
        store=lsm.init(cfg.lsm_cfg),
        proj=jax.random.normal(key, (cfg.m_bits, cfg.dim), jnp.float32),
        count=jnp.zeros((), jnp.int32),
        n_live=jnp.zeros((), jnp.int32),
        entry=jnp.full((), -1, jnp.int32),
        max_level=jnp.zeros((), jnp.int32),
        mean_norm=jnp.ones((), jnp.float32),
        heat=jnp.zeros((cfg.cap, cfg.M), jnp.int32),
        tombstone=jnp.zeros((cfg.cap,), jnp.bool_),
        n_tombstones=jnp.zeros((), jnp.int32),
        n_delete_noops=jnp.zeros((), jnp.int32),
        hot=jnp.ones((cfg.cap,), jnp.bool_),
        qvecs=jnp.zeros((cfg.cap, cfg.dim), jnp.int8),
        qscale=jnp.zeros((cfg.cap,), jnp.float32),
        tier_heat=jnp.zeros((cfg.cap,), jnp.float32),
    )


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _dist_fn(state: HNSWState, q: jax.Array):
    """ids int32[n] -> squared L2 f32[n]; -1 ids cost nothing (+inf).

    On TPU this is the fused gather+distance Pallas kernel (the "disk
    fetch"); on CPU containers the jnp oracle with identical semantics.
    """
    def fn(ids):
        return gather_l2(q[None, :], state.vectors, ids[None, :])[0]
    return fn


def _exact_resident(state: HNSWState) -> jax.Array:
    """bool[cap]: nodes whose f32 row is RAM-resident (DESIGN.md §12).

    Hot-lane nodes by definition; upper-layer nodes too, because their
    rows are already in the resident upper routing cache regardless of
    lane — demoting one only drops its bottom-lane dense copy.
    """
    return state.hot | (state.levels > 0)


def _tier_dist_fn(state: HNSWState, q: jax.Array):
    """Mixed-lane distance: exact for resident rows, dequant+L2 for cold.

    Each id hits exactly one lane (the other contributes +inf), so the
    lanes merge with an elementwise min.  Cold distances are approximate;
    `_tier_rerank` restores exactness for the final candidate window.
    """
    resident = _exact_resident(state)

    def fn(ids):
        res = resident[jnp.maximum(ids, 0)]
        hot_ids = jnp.where((ids >= 0) & res, ids, -1)
        cold_ids = jnp.where((ids >= 0) & ~res, ids, -1)
        d_hot = gather_l2(q[None, :], state.vectors, hot_ids[None, :])[0]
        d_cold = gather_l2_q8(q[None, :], state.qvecs, state.qscale,
                              cold_ids[None, :])[0]
        return jnp.minimum(d_hot, d_cold)
    return fn


def _tier_rerank(cfg: HNSWConfig, state: HNSWState, q: jax.Array,
                 res: BeamResult) -> BeamResult:
    """Exact rerank of the top-`cfg.rerank` beam window (the tier
    contract): cold candidates get their full-precision row fetched from
    the cold store (one modeled disk read each, counted in n_vec), the
    window re-sorts on exact distances, and everything past the window
    keeps its approximate ordering — recall loss is bounded by the
    window, not the quantizer.
    """
    r = max(1, min(cfg.rerank, int(res.ids.shape[0])))
    ids_r = res.ids[:r]
    cold = (ids_r >= 0) & ~_exact_resident(state)[jnp.maximum(ids_r, 0)]
    fetch = jnp.where(cold, ids_r, -1)
    d_exact = gather_l2(q[None, :], state.vectors, fetch[None, :])[0]
    d_new = jnp.where(cold, d_exact, res.dists[:r])
    neg, order = jax.lax.top_k(-d_new, r)
    stats = res.stats._replace(
        n_vec=res.stats.n_vec + jnp.sum(cold).astype(jnp.int32))
    return res._replace(ids=res.ids.at[:r].set(ids_r[order]),
                        dists=res.dists.at[:r].set(-neg),
                        stats=stats)


def _bottom_adj_fn(cfg: HNSWConfig, state: HNSWState):
    """Batched bottom-layer adjacency: B node ids -> one LSM batch lookup."""
    def fn(nodes):
        found, rows, probes = lsm.get_batch(cfg.lsm_cfg, state.store, nodes)
        return jnp.where(found[:, None], rows, -1), probes
    return fn


def _snapshot_adj_fn(snapshot: jax.Array):
    """Adjacency served from a resolved dense view (`lsm.snapshot_rows`).

    Row-for-row identical to `_bottom_adj_fn` against the frozen tree —
    absent/tombstoned rows are already -1 in the view — but each read is a
    single gather instead of a full LSM probe.  `n_probes` keeps the
    1-read-per-row cost model of `lsm.get`.
    """
    def fn(nodes):
        rows = snapshot[jnp.maximum(nodes, 0)]
        return jnp.where((nodes >= 0)[:, None], rows, -1), \
            jnp.ones_like(nodes)
    return fn


def _upper_adj_fn(state: HNSWState, u: int):
    """Batched upper-layer adjacency (memory-resident dense rows)."""
    def fn(nodes):
        rows = state.upper_adj[u, jnp.maximum(nodes, 0)]
        return jnp.where((nodes >= 0)[:, None], rows, -1), \
            jnp.zeros_like(nodes)
    return fn


def _point_dist(state: HNSWState, q: jax.Array, node: jax.Array) -> jax.Array:
    v = state.vectors[jnp.maximum(node, 0)]
    return jnp.sum((q - v) ** 2)


def _descend_upper(cfg: HNSWConfig, state: HNSWState, q: jax.Array,
                   down_to: jax.Array):
    """Greedy-route through upper layers u = num_upper-1 .. down_to."""
    ep = jnp.maximum(state.entry, 0)
    d_ep = _point_dist(state, q, ep)
    for u in reversed(range(cfg.num_upper)):
        live_u = state.levels > u
        new_ep, new_d = greedy_descent(q, ep, d_ep, state.upper_adj[u],
                                       state.vectors, live_u)
        use = jnp.asarray(u, jnp.int32) >= down_to
        ep = jnp.where(use, new_ep, ep)
        d_ep = jnp.where(use, new_d, d_ep)
    return ep, d_ep


def _topm(ids: jax.Array, dists: jax.Array, m: int):
    """Best-m prefix of a distance-sorted candidate list (pad -1).

    `lax.top_k` instead of a stable argsort: ties resolve to the lower
    index either way, but top_k is a selection, not a full sort — XLA
    CPU's stable sorts were the dominant cost of the delete relink scan.
    """
    neg_d, order = jax.lax.top_k(-dists, m)
    out_ids = ids[order]
    out_d = -neg_d
    return jnp.where(jnp.isfinite(out_d), out_ids, -1), out_d


def _diversity_topm(ids: jax.Array, dists: jax.Array, vectors: jax.Array,
                    m: int, alpha: float = 1.0):
    """HNSW neighbor-selection heuristic (keepPruned variant).

    Greedily keeps candidate c only if it is closer to the base point than
    to every already-kept neighbor (`alpha` relaxes the test, Vamana
    style), then fills leftover slots with the nearest pruned candidates.
    Plain closest-M edges all point into the local cluster and strand the
    graph on clustered data; diverse edges are what keeps it navigable.
    """
    order = jnp.argsort(dists, stable=True)
    ids, dists = ids[order], dists[order]
    c = ids.shape[0]
    cv = vectors[jnp.maximum(ids, 0)]
    pair = jnp.sum((cv[:, None, :] - cv[None, :, :]) ** 2, axis=-1)
    valid = jnp.isfinite(dists) & (ids >= 0)

    def body(i, kept):
        dominated = jnp.any(kept & (alpha * pair[i] < dists[i]))
        space = jnp.sum(kept) < m
        return kept.at[i].set(valid[i] & (~dominated) & space)

    kept = jax.lax.fori_loop(0, c, body, jnp.zeros((c,), jnp.bool_))
    rank = jnp.argsort(~kept, stable=True)   # kept first, distance order
    ids2, valid2 = ids[rank], valid[rank]
    return jnp.where(valid2[:m], ids2[:m], -1), dists[rank][:m]


def _evict_slot(row: jax.Array, row_vecs_d_new: jax.Array) -> jax.Array:
    """Backlink slot choice: empty slot first, else evict the existing
    neighbor *closest to the incoming node* (most redundant direction) —
    never the farthest, which would strip the long-range portals."""
    score = jnp.where(row < 0, INF, -row_vecs_d_new)
    return jnp.argmax(score)


def _dedup_to_inf(ids: jax.Array, dists: jax.Array):
    """Mask duplicate ids (keep the first occurrence) with +inf.

    O(C^2) comparison triangle instead of sort+scatter: identical result
    (the stable id-sort kept the lowest original index of each id group),
    and at relink pool sizes the triangle is far cheaper than an XLA CPU
    stable sort.
    """
    eq = ids[None, :] == ids[:, None]
    dup = jnp.any(jnp.tril(eq, k=-1), axis=1)
    return jnp.where(dup, INF, dists)


def _relink_upper_rows(cfg: HNSWConfig, state_vectors, state_levels,
                       state_tomb, upper_adj, u: int, i, nbr, active):
    """Vectorized Algorithm-2 relink of node i's layer-u neighbors.

    All M_up relink rows derive from the same up-front 2-hop candidate
    pool (`cand` is read once, before any write), so the per-neighbor
    loop vectorizes into one [M_up, C] distance block + one scatter —
    bit-identical to writing the rows one at a time, since no row's
    computation reads another's write.
    """
    nbr_safe = jnp.maximum(nbr, 0)
    cand = jnp.concatenate(
        [upper_adj[u, nbr_safe].reshape(-1), nbr])              # 2-hop pool C
    d = jnp.sum((state_vectors[jnp.maximum(cand, 0)][None, :, :]
                 - state_vectors[nbr_safe][:, None, :]) ** 2, axis=-1)
    bad = (cand[None, :] < 0) | (cand[None, :] == i) \
        | (cand[None, :] == nbr[:, None]) \
        | (state_levels[jnp.maximum(cand, 0)][None, :] <= u) \
        | state_tomb[jnp.maximum(cand, 0)][None, :]
    d = jnp.where(bad, INF, d)
    masked = jnp.where(bad, -1, jnp.broadcast_to(cand, bad.shape))
    d = jax.vmap(_dedup_to_inf)(masked, d)
    new_rows, _ = jax.vmap(lambda dd: _topm(cand, dd, cfg.M_up))(d)
    ok = active & (nbr >= 0)
    idx_w = jnp.where(ok, nbr_safe, cfg.cap)   # masked rows drop
    upper_adj = upper_adj.at[u, idx_w].set(new_rows, mode="drop")
    return upper_adj.at[u, jnp.where(active, jnp.maximum(i, 0),
                                     cfg.cap)].set(-1, mode="drop")


# ---------------------------------------------------------------------------
# search (paper §3.2 "Search in LSM-VEC")
# ---------------------------------------------------------------------------

def search(cfg: HNSWConfig, state: HNSWState, q: jax.Array,
           *, rho: float | None = None, ef: int | None = None,
           use_filter: bool | None = None,
           n_expand: int | None = None,
           snapshot: jax.Array | None = None,
           active: jax.Array | None = None) -> BeamResult:
    """Single-query search: upper greedy descent -> sampled bottom beam.

    `n_expand` > 1 turns on multi-expansion (DESIGN.md §3): that many
    frontier nodes are expanded per beam iteration through one batched
    adjacency read and one fused distance block.  The default (1) is the
    paper's classic one-node-per-hop traversal.

    `snapshot` (optional, from `lsm.snapshot_rows`) serves bottom-layer
    adjacency by row gather from a resolved dense view instead of per-hop
    LSM probes — bit-identical results against an unchanged tree; the
    caller owns invalidation (re-resolve after any write).  `active`
    supports pad-and-mask dispatch: a False lane returns all -1/inf,
    records nothing, and costs no IOStats (DESIGN.md §8).

    Under `cfg.lazy_delete` the traversal distinguishes *routable* from
    *returnable* (DESIGN.md §9): tombstoned nodes are expanded through at
    full cost — their edges keep delete-damaged regions reachable — but
    never appear in the returned top-k.
    """
    if cfg.fused_beam and snapshot is not None:
        res = _search_batch_fused(
            cfg, state, q[None, :], snapshot=snapshot,
            active=(None if active is None
                    else jnp.asarray(active).reshape(1)),
            rho=rho, ef=ef, use_filter=use_filter, n_expand=n_expand)
        return jax.tree.map(lambda a: a[0], res)
    ef = ef or cfg.ef_search
    rho = cfg.rho if rho is None else rho
    use_filter = cfg.use_filter if use_filter is None else use_filter
    n_expand = cfg.n_expand if n_expand is None else n_expand
    # clamp like beam_search does, so the max_iters budget below stays
    # B-invariant even for n_expand > ef
    n_expand = max(1, min(n_expand, ef))
    routable = state.levels >= 0
    # static dispatch: the eager config never pays the returnable re-pack
    returnable = (routable & ~state.tombstone) if cfg.lazy_delete else None
    ep, d_ep = _descend_upper(cfg, state, q, jnp.zeros((), jnp.int32))
    code_q = simhash.encode(simhash.SimHashParams(state.proj), q[None, :])[0]
    adj_fn = _bottom_adj_fn(cfg, state) if snapshot is None \
        else _snapshot_adj_fn(snapshot)
    dist_fn = _tier_dist_fn(state, q) if cfg.tier else _dist_fn(state, q)
    res = beam_search(
        q, ep, d_ep,
        adj_fn, dist_fn,
        state.codes, code_q, routable,
        cap=cfg.cap, ef=ef, k=cfg.k, m_bits=cfg.m_bits, eps=cfg.eps,
        rho=rho, max_iters=2 * ef, use_filter=use_filter,
        q_norm=jnp.sqrt(jnp.sum(q * q)), mean_norm=state.mean_norm,
        n_expand=n_expand, active=active, returnable=returnable)
    if cfg.tier:
        res = _tier_rerank(cfg, state, q, res)
    return res


def _search_batch_fused(cfg: HNSWConfig, state: HNSWState, qs: jax.Array,
                        *, snapshot: jax.Array,
                        active: jax.Array | None = None,
                        rho: float | None = None, ef: int | None = None,
                        use_filter: bool | None = None,
                        n_expand: int | None = None,
                        record_heat: bool = True) -> BeamResult:
    """Fused-megakernel route for the snapshot serving path: one
    `fused_beam_search` launch for the whole query block instead of a
    vmapped `while_loop` (DESIGN.md §15).

    The per-query prelude (upper greedy descent, SimHash query encode,
    norms) is vmapped exactly like `search`, and the dense operands
    (snapshot adjacency, routable/returnable lanes, tier split) carry
    the identical semantics — results are bit-parity with the
    `while_loop` path; `tests/test_beam_kernel.py` pins it.

    `record_heat=False` is a capability the `while_loop` path doesn't
    have: it statically drops the per-trip heat carries from the fused
    loop (result arrays come back as -1/False padding).
    """
    ef = ef or cfg.ef_search
    rho = cfg.rho if rho is None else rho
    use_filter = cfg.use_filter if use_filter is None else use_filter
    n_expand = cfg.n_expand if n_expand is None else n_expand
    n_expand = max(1, min(n_expand, ef))
    routable = state.levels >= 0
    returnable = (routable & ~state.tombstone) if cfg.lazy_delete else None
    params = simhash.SimHashParams(state.proj)
    ent, ent_d = jax.vmap(
        lambda q: _descend_upper(cfg, state, q,
                                 jnp.zeros((), jnp.int32)))(qs)
    code_qs = jax.vmap(lambda q: simhash.encode(params, q[None, :])[0])(qs)
    q_norms = jax.vmap(lambda q: jnp.sqrt(jnp.sum(q * q)))(qs)
    ids, dists, stats, heat_nodes, heat_mask = fused_beam_search(
        qs, ent, ent_d, snapshot, state.vectors, state.codes, code_qs,
        routable, q_norms, state.mean_norm, returnable=returnable,
        resident=_exact_resident(state) if cfg.tier else None,
        qvecs=state.qvecs if cfg.tier else None,
        qscale=state.qscale if cfg.tier else None, active=active,
        ef=ef, k=cfg.k, m_bits=cfg.m_bits, eps=cfg.eps, rho=rho,
        max_iters=2 * ef, use_filter=use_filter, n_expand=n_expand,
        record_heat=record_heat)
    res = BeamResult(
        ids, dists,
        IOStats(n_adj=stats[:, 0], n_vec=stats[:, 1],
                n_filtered=stats[:, 2], n_hops=stats[:, 3]),
        heat_nodes, heat_mask)
    if cfg.tier:
        res = jax.vmap(lambda q, r: _tier_rerank(cfg, state, q, r))(qs, res)
    return res


def search_batch(cfg: HNSWConfig, state: HNSWState, qs: jax.Array,
                 *, active: jax.Array | None = None,
                 record_heat: bool = True, **kw) -> BeamResult:
    """Batched search; `active` (bool[B]) masks padded query lanes.

    With `cfg.fused_beam` and a snapshot, the whole block routes
    through the one-launch megakernel path; otherwise the vmapped
    `while_loop` (which always records heat — `record_heat` is the
    fused path's static skip and is ignored here).
    """
    if cfg.fused_beam and kw.get("snapshot") is not None:
        return _search_batch_fused(cfg, state, qs, active=active,
                                   record_heat=record_heat, **kw)
    if active is None:
        return jax.vmap(lambda q: search(cfg, state, q, **kw))(qs)
    return jax.vmap(lambda q, a: search(cfg, state, q, active=a, **kw))(
        qs, active)


# ---------------------------------------------------------------------------
# insert (Algorithm 1)
# ---------------------------------------------------------------------------

def _backlink_rows(cfg: HNSWConfig, store: lsm.LSMState, vectors: jax.Array,
                   nbrs: jax.Array, x: jax.Array, i) -> lsm.LSMState:
    """Bulk bottom-layer backlink pass: read the M neighbor rows in one
    batched lookup, evict each row's most redundant slot, write everything
    back with a single `lsm.puts`.  Masked (-1) neighbors land on the
    reserved dead key, exactly like the per-edge `_put_masked` path did."""
    ok = nbrs >= 0
    nbrs_safe = jnp.maximum(nbrs, 0)
    found, rows, _ = lsm.get_batch(cfg.lsm_cfg, store, nbrs_safe)  # [M, M]
    rows = jnp.where(found[:, None], rows, -1)
    d_new = jnp.sum((vectors[jnp.maximum(rows, 0)]
                     - x[None, None, :]) ** 2, axis=-1)            # [M, M]
    slots = jax.vmap(_evict_slot)(rows, d_new)
    new_rows = rows.at[jnp.arange(nbrs.shape[0]), slots].set(i)
    dead = jnp.asarray(cfg.cap, jnp.int32)
    return lsm.puts(cfg.lsm_cfg, store,
                    jnp.where(ok, nbrs_safe, dead), new_rows)


def _put_masked(cfg: HNSWConfig, store: lsm.LSMState, key, row, active):
    """LSM put that lands on a reserved dead key when inactive.

    Avoids lax.cond duplication of the flush machinery: id `cap` is outside
    the live id space and never looked up.
    """
    dead = jnp.asarray(cfg.cap, jnp.int32)
    return lsm.put(cfg.lsm_cfg, store,
                   jnp.where(active, key, dead), row)


def insert(cfg: HNSWConfig, state: HNSWState, x: jax.Array,
           key: jax.Array) -> Tuple[HNSWState, IOStats]:
    """Insert one vector (Algorithm 1).  Returns (state, construction IO)."""
    i = state.count
    # paper: Pr(L) ∝ e^{-L/s}  -> L = floor(s * Exp(1)), capped at num_upper
    # (s = cfg.level_scale; 1.0 is the classic draw)
    u01 = jax.random.uniform(key, (), jnp.float32, 1e-7, 1.0)
    lvl = jnp.minimum(
        jnp.floor(-cfg.level_scale * jnp.log(u01)).astype(jnp.int32),
        cfg.num_upper)

    xnorm = jnp.sqrt(jnp.sum(x * x))
    code = simhash.encode(simhash.SimHashParams(state.proj), x[None, :])[0]
    state = state._replace(
        vectors=state.vectors.at[i].set(x),
        norms=state.norms.at[i].set(xnorm),
        codes=state.codes.at[i].set(code),
        levels=state.levels.at[i].set(lvl),
        mean_norm=(state.mean_norm * state.n_live + xnorm)
        / jnp.maximum(state.n_live + 1, 1),
    )

    first = state.n_live == 0

    # ---- phase 1+2: upper layers ------------------------------------------
    # This block intentionally stays the paper-exact, unconditional form of
    # Algorithm 1 (beam + where-selects on every layer, sequential
    # backlinks): it is the parity reference the tests pin.  The batched
    # pipeline's `_connect_upper` is the cond-gated, vectorized variant of
    # the same logic — a change to the linking rule must land in both.
    ep = jnp.maximum(state.entry, 0)
    d_ep = _point_dist(state, x, ep)
    upper_adj = state.upper_adj
    for u in reversed(range(cfg.num_upper)):
        live_u = (state.levels > u) & (jnp.arange(cfg.cap) != i)
        above = jnp.asarray(u, jnp.int32) >= lvl   # greedy-only zone
        # greedy step (used when u >= lvl)
        g_ep, g_d = greedy_descent(x, ep, d_ep, upper_adj[u],
                                   state.vectors, live_u)
        # connect zone (u < lvl): ef-search this layer, link bidirectionally
        res = beam_search(
            x, ep, d_ep, _upper_adj_fn(state._replace(upper_adj=upper_adj), u),
            _dist_fn(state, x), state.codes, code, live_u,
            cap=cfg.cap, ef=cfg.ef_construction, k=cfg.k, m_bits=cfg.m_bits,
            eps=cfg.eps, rho=1.0, max_iters=2 * cfg.ef_construction,
            use_filter=False, q_norm=xnorm, mean_norm=state.mean_norm)
        nbrs, _ = _diversity_topm(res.ids, res.dists, state.vectors,
                                  cfg.M_up)
        connect = (~above) & (~first)
        upper_adj = upper_adj.at[u, i].set(
            jnp.where(connect, nbrs, upper_adj[u, i]))
        # backlinks: always formed; evict the most redundant edge when full
        for j in range(cfg.M_up):
            n = nbrs[j]
            ok = connect & (n >= 0)
            n_safe = jnp.maximum(n, 0)
            row = upper_adj[u, n_safe]
            d_new = jnp.sum((state.vectors[jnp.maximum(row, 0)]
                             - x[None, :]) ** 2, axis=-1)
            slot = _evict_slot(row, d_new)
            new_row = row.at[slot].set(i)
            upper_adj = upper_adj.at[u, n_safe].set(
                jnp.where(ok, new_row, row))
        ep = jnp.where(above, g_ep, jnp.where(res.dists[0] < INF,
                                              res.ids[0], ep))
        d_ep = jnp.where(above, g_d, jnp.minimum(res.dists[0], d_ep))
    state = state._replace(upper_adj=upper_adj)

    # ---- phase 3: bottom layer (disk / LSM) ---------------------------------
    res = beam_search(
        x, ep, d_ep, _bottom_adj_fn(cfg, state), _dist_fn(state, x),
        state.codes, code, (state.levels >= 0) & (jnp.arange(cfg.cap) != i),
        cap=cfg.cap, ef=cfg.ef_construction, k=cfg.k, m_bits=cfg.m_bits,
        eps=cfg.eps, rho=cfg.rho, max_iters=2 * cfg.ef_construction,
        use_filter=cfg.use_filter, q_norm=xnorm, mean_norm=state.mean_norm)
    nbrs, _ = _diversity_topm(res.ids, res.dists, state.vectors, cfg.M)
    nbrs = jnp.where(first, -1, nbrs)

    store = _put_masked(cfg, state.store, i, nbrs, jnp.bool_(True))
    # bidirectional links (Fig. 3: links are always formed; when the row is
    # full the most redundant existing edge is evicted, keeping the new
    # node reachable without stripping long-range portals).  The whole
    # backlink pass is amortized: one batched row read over the M
    # neighbors and one bulk `puts` instead of M get+put round-trips —
    # exact because beam candidates (hence `nbrs`) are distinct ids, so
    # no backlink row feeds another's lookup.
    store = _backlink_rows(cfg, store, state.vectors, nbrs, x, i)

    new_entry = jnp.where(first | (lvl > state.max_level), i, state.entry)
    state = state._replace(
        store=store,
        count=state.count + 1,
        n_live=state.n_live + 1,
        entry=new_entry,
        max_level=jnp.maximum(state.max_level, lvl))
    stats = res.stats._replace(
        n_vec=res.stats.n_vec + cfg.M)  # backlink row re-rankings
    return state, stats


# ---------------------------------------------------------------------------
# batched updates (DESIGN.md §4) — the FreshDiskANN-style bulk pipeline
# ---------------------------------------------------------------------------

def _connect_upper(cfg: HNSWConfig, state: HNSWState, upper_adj: jax.Array,
                   u: int, x, code, xnorm, i, ep, d_ep, n_expand: int):
    """Connect node i on upper layer u: ef-search, diversity-select, and a
    vectorized backlink-eviction pass (exact because the selected neighbors
    are distinct beam candidates, so their row updates are independent).
    Returns the updated (upper_adj, ep, d_ep)."""
    n_expand = max(1, min(n_expand, cfg.ef_construction))
    live_u = (state.levels > u) & (jnp.arange(cfg.cap) != i)
    adj = _upper_adj_fn(state._replace(upper_adj=upper_adj), u)
    res = beam_search(
        x, ep, d_ep, adj, _dist_fn(state, x), state.codes, code, live_u,
        cap=cfg.cap, ef=cfg.ef_construction, k=cfg.k, m_bits=cfg.m_bits,
        eps=cfg.eps, rho=1.0, max_iters=2 * cfg.ef_construction,
        use_filter=False, q_norm=xnorm, mean_norm=state.mean_norm,
        n_expand=n_expand)
    nbrs, _ = _diversity_topm(res.ids[:max(2 * cfg.M_up, cfg.M_up + 4)],
                              res.dists[:max(2 * cfg.M_up, cfg.M_up + 4)],
                              state.vectors, cfg.M_up)
    upper_adj = upper_adj.at[u, i].set(nbrs)
    ok = nbrs >= 0
    ns = jnp.maximum(nbrs, 0)
    rows = upper_adj[u, ns]                                  # [M_up, M_up]
    d_new = jnp.sum((state.vectors[jnp.maximum(rows, 0)]
                     - x[None, None, :]) ** 2, axis=-1)
    slots = jax.vmap(_evict_slot)(rows, d_new)
    new_rows = rows.at[jnp.arange(cfg.M_up), slots].set(i)
    # masked entries scatter out of bounds and are dropped
    idx = jnp.where(ok, ns, cfg.cap)
    upper_adj = upper_adj.at[u, idx].set(new_rows, mode="drop")
    ep = jnp.where(res.dists[0] < INF, res.ids[0], ep)
    d_ep = jnp.minimum(res.dists[0], d_ep)
    return upper_adj, ep, d_ep


def insert_batch(cfg: HNSWConfig, state: HNSWState, xs: jax.Array,
                 keys: jax.Array, *,
                 valid: jax.Array | None = None,
                 n_expand: int | None = None,
                 return_overlay: bool = False):
    """Insert a batch of vectors in one jit — zero per-item host syncs.

    Two phases (DESIGN.md §4):
      A (vmapped): every vector's bottom-layer candidate search runs
        against the *pre-batch* graph snapshot with multi-expansion beams,
        so the whole batch is one embarrassingly parallel sweep — the
        FreshDiskANN streaming-update recipe.
      B (`lax.scan`): graph writes are sequential and ids are computed
        inside the scan from the carried `count`.  Upper-layer connects
        run under `lax.cond` (only ~e^-1 of inserts reach layer >= 1, so
        the expensive construction beams are skipped for the rest), and
        the bottom backlink pass is the bulk read + `lsm.puts` path.

    Items in the same batch do not see each other as bottom-layer
    neighbor *candidates* (they still become mutually reachable through
    base-graph backlinks, like sequential inserts).  Callers should seed
    a small graph per-item first; `LSMVecIndex.insert_batch` does.

    `valid` (bool[n], default all-True) is the pad-and-mask hook
    (DESIGN.md §8): masked items allocate no id and write nothing, so a
    serving layer can dispatch ragged micro-batches through one traced
    shape.  Valid items must form a *prefix* (padding at the tail) so the
    ids computed from the scanned `count` stay consecutive.

    `return_overlay=True` additionally returns the staged bottom-layer
    write set `(overlay_rows int32[cap+1, M], overlay_valid bool[cap+1])`
    — every key the batch touched with its *final* row.  A caller
    holding a pre-batch dense snapshot can patch it with one
    `jnp.where(overlay_valid, overlay_rows, snap)` instead of paying a
    full `lsm.resolve_all` re-resolve (DESIGN.md §13).
    """
    if n_expand is None:
        n_expand = cfg.batch_expand
    n_expand = max(1, min(n_expand, cfg.ef_construction))
    n = xs.shape[0]
    if valid is None:
        valid = jnp.ones((n,), jnp.bool_)
    base_id = state.count
    codes = simhash.encode(simhash.SimHashParams(state.proj), xs)
    xnorms = jnp.sqrt(jnp.sum(xs * xs, axis=1))
    u01 = jax.vmap(
        lambda kk: jax.random.uniform(kk, (), jnp.float32, 1e-7, 1.0))(keys)
    lvls = jnp.minimum(
        jnp.floor(-cfg.level_scale * jnp.log(u01)).astype(jnp.int32),
        cfg.num_upper)

    # Intra-batch neighbor candidates: the snapshot cannot see batch
    # siblings, and an out-of-distribution batch (say, a brand-new
    # cluster) would otherwise compete for the same few base-node
    # backlink slots and come out mostly unreachable.  One triangular
    # [n, n] distance block (RAM-resident, no t_v cost — the batch is in
    # memory) lets item i also link to its nearest *earlier* items j < i,
    # whose ids (base_id + j) are deterministic and whose rows are
    # already staged in the overlay when i's backlink pass reads them —
    # the same "link to already-placed nodes" rule sequential insert has.
    bb = (xnorms[:, None] ** 2 + xnorms[None, :] ** 2
          - 2.0 * (xs @ xs.T))
    # masked (padding) items are never candidates; valid items form a
    # prefix, so the j < i triangle only ever pairs valid with valid
    bb = jnp.where(jnp.tril(jnp.ones((n, n), jnp.bool_), k=-1)
                   & valid[None, :], bb, INF)
    m_in = max(1, min(cfg.M, n - 1))
    nb_negd, nb_j = jax.lax.top_k(-bb, m_in)
    in_d = -nb_negd                                            # [n, m_in]
    in_ids = jnp.where(jnp.isfinite(in_d), base_id + nb_j, -1)

    # phase-A view with the batch vectors materialized, so diversity
    # selection can measure candidate pairs that include batch siblings
    vectors_view = state.vectors.at[base_id + jnp.arange(n)].set(xs)

    # ---- phase A: batch-parallel candidate search on the snapshot ---------
    # The pre-batch bottom graph is frozen for the whole sweep, so resolve
    # the LSM tree into a dense newest-wins view once (FreshDiskANN
    # searches its frozen disk index the same way) and serve adjacency by
    # row gather instead of per-hop LSM probes.  Rows are identical to
    # what `get_batch` would return; `n_probes` keeps the 1-read-per-row
    # cost model of `lsm.get`.
    snap_live, snap_rows = lsm.resolve_all(cfg.lsm_cfg, state.store, cfg.cap)
    snapshot = jnp.where(snap_live[:, None] > 0, snap_rows, -1)
    snap_adj = _snapshot_adj_fn(snapshot)

    def cand_search(x, code, xnorm, ids_in, d_in, v):
        ep, d_ep = _descend_upper(cfg, state, x, jnp.zeros((), jnp.int32))
        res = beam_search(
            x, ep, d_ep, snap_adj, _dist_fn(state, x),
            state.codes, code, state.levels >= 0,
            cap=cfg.cap, ef=cfg.ef_construction, k=cfg.k, m_bits=cfg.m_bits,
            eps=cfg.eps, rho=cfg.rho,
            max_iters=2 * cfg.ef_construction,
            use_filter=cfg.use_filter, q_norm=xnorm,
            mean_norm=state.mean_norm, n_expand=n_expand, active=v)
        # diversity-select the bottom neighbors here: it only reads the
        # frozen snapshot + batch view, and vmapping it runs the
        # sequential dominance loop once for the whole batch instead of
        # once per scanned item.  The beam is distance-sorted and
        # keepPruned almost never reaches past ~2M candidates, so
        # truncate before merging the intra-batch pool (disjoint ids:
        # beam ids are pre-batch, ids_in are >= base_id).
        pool = min(2 * cfg.M, res.ids.shape[0])
        cand_ids = jnp.concatenate([res.ids[:pool], ids_in])
        cand_d = jnp.concatenate([res.dists[:pool], d_in])
        nbrs, _ = _diversity_topm(cand_ids, cand_d, vectors_view, cfg.M)
        return nbrs, res.stats

    cand_nbrs, stats_a = jax.vmap(cand_search)(xs, codes, xnorms,
                                               in_ids, in_d, valid)

    # ---- phase B: sequential graph writes ---------------------------------
    # Bottom-layer rows are staged in a dense overlay carried through the
    # scan instead of being put into the LSM per item: a flush `lax.cond`
    # inside a scan makes XLA copy the level arrays on every step
    # (measured ~20x the cost of the appends themselves).  Reads resolve
    # overlay-first, then the phase-A snapshot — exactly the newest-wins
    # view the in-scan puts would have produced — and the LSM absorbs all
    # staged rows in one bulk `puts` after the scan.
    overlay_rows = jnp.full((cfg.cap + 1, cfg.M), -1, jnp.int32)
    overlay_valid = jnp.zeros((cfg.cap + 1,), jnp.bool_)
    dead = jnp.asarray(cfg.cap, jnp.int32)

    def step(carry, inp):
        st, orows, ovalid = carry
        x, code, xnorm, lvl, nbrs, v = inp
        i = st.count
        # masked (padding) items scatter to the out-of-bounds id `cap`,
        # which mode="drop" discards — the step is then a pure no-op
        i_w = jnp.where(v, i, cfg.cap)
        st = st._replace(
            vectors=st.vectors.at[i_w].set(x, mode="drop"),
            norms=st.norms.at[i_w].set(xnorm, mode="drop"),
            codes=st.codes.at[i_w].set(code, mode="drop"),
            levels=st.levels.at[i_w].set(lvl, mode="drop"),
            mean_norm=jnp.where(
                v,
                (st.mean_norm * st.n_live + xnorm)
                / jnp.maximum(st.n_live + 1, 1),
                st.mean_norm))
        first = st.n_live == 0

        # Upper-layer work only matters for items that reach layer >= 1
        # (~1 - e^-1 of them): bottom-layer candidates are precomputed, so
        # for lvl == 0 the greedy descents and connects would all be dead
        # code.  One cond skips the whole loop for the common case.
        def upper_work(ua):
            ep = jnp.maximum(st.entry, 0)
            d_ep = _point_dist(st, x, ep)
            for u in reversed(range(cfg.num_upper)):
                live_u = (st.levels > u) & (jnp.arange(cfg.cap) != i)
                g_ep, g_d = greedy_descent(x, ep, d_ep, ua[u],
                                           st.vectors, live_u)
                above = jnp.asarray(u, jnp.int32) >= lvl

                def connect(op, u=u):
                    a, e, de = op
                    return _connect_upper(cfg, st, a, u, x, code, xnorm, i,
                                          e, de, n_expand)

                def skip(op, g_ep=g_ep, g_d=g_d):
                    a, e, de = op
                    return a, g_ep, g_d

                ua, ep, d_ep = jax.lax.cond(
                    ~above, connect, skip, (ua, ep, d_ep))
            return ua

        upper_adj = jax.lax.cond((lvl > 0) & (~first) & v, upper_work,
                                 lambda ua: ua, st.upper_adj)
        st = st._replace(upper_adj=upper_adj)

        nbrs = jnp.where(first | (~v), -1, nbrs)
        # backlink pass against overlay-else-snapshot rows (pure gathers)
        ok = nbrs >= 0
        nbrs_safe = jnp.maximum(nbrs, 0)
        rows = jnp.where(ovalid[nbrs_safe][:, None],
                         orows[nbrs_safe], snapshot[nbrs_safe])
        d_new = jnp.sum((st.vectors[jnp.maximum(rows, 0)]
                         - x[None, None, :]) ** 2, axis=-1)
        slots = jax.vmap(_evict_slot)(rows, d_new)
        new_rows = rows.at[jnp.arange(cfg.M), slots].set(i)
        w_keys = jnp.concatenate([jnp.where(v, i, dead)[None],
                                  jnp.where(ok, nbrs_safe, dead)])
        w_vals = jnp.concatenate([nbrs[None, :], new_rows])
        orows = orows.at[w_keys].set(w_vals)
        ovalid = ovalid.at[w_keys].set(True)

        vi = v.astype(jnp.int32)
        new_entry = jnp.where(v & (first | (lvl > st.max_level)),
                              i, st.entry)
        st = st._replace(
            count=st.count + vi, n_live=st.n_live + vi,
            entry=new_entry,
            max_level=jnp.where(v, jnp.maximum(st.max_level, lvl),
                                st.max_level))
        return (st, orows, ovalid), w_keys

    (state, overlay_rows, overlay_valid), w_keys = jax.lax.scan(
        step, (state, overlay_rows, overlay_valid),
        (xs, codes, xnorms, lvls, cand_nbrs, valid))
    # one bulk LSM apply: every staged key carries its *final* overlay row,
    # so duplicate keys across items all write the same (last) value and
    # newest-wins is preserved.  (Deduping here would not save memtable
    # slots — static shapes mean duplicates could only be renamed to the
    # dead key, which occupies a slot all the same.)  Dead-key rows pad
    # exactly like the per-item `_put_masked` path.
    w_keys = w_keys.reshape(-1)
    w_vals = overlay_rows[jnp.minimum(w_keys, cfg.cap)]
    state = state._replace(
        store=lsm.puts(cfg.lsm_cfg, state.store, w_keys, w_vals))
    # masked lanes already report zero beam stats (active-gated)
    stats = IOStats(*(jnp.sum(a).astype(jnp.int32) for a in stats_a))
    # backlink row re-rankings, as in the per-item path
    stats = stats._replace(
        n_vec=stats.n_vec
        + jnp.sum(valid).astype(jnp.int32) * cfg.M)
    if return_overlay:
        return state, stats, (overlay_rows, overlay_valid)
    return state, stats


def delete_batch(cfg: HNSWConfig, state: HNSWState,
                 ids: jax.Array) -> Tuple[HNSWState, IOStats]:
    """Delete a batch of node ids in one jit.

    Dispatches statically on `cfg.lazy_delete`: the lazy path
    (`tombstone_batch`) marks the ids routable-but-not-returnable with no
    graph writes; the eager path is the Algorithm-2 relink pipeline
    below.  Negative ids are masked no-ops either way (the pad-and-mask
    serving contract); non-negative ids that are absent or already
    deleted are *counted* no-ops (`state.n_delete_noops`), never silent
    graph writes.
    """
    if cfg.lazy_delete:
        return tombstone_batch(cfg, state, ids)
    return _delete_batch_eager(cfg, state, ids)


def _delete_batch_eager(cfg: HNSWConfig, state: HNSWState,
                        ids: jax.Array) -> Tuple[HNSWState, IOStats]:
    """Eager batched delete — Algorithm 2 through an overlay.

    Like `insert_batch`'s phase B, the scanned per-item relinks read and
    stage bottom-layer rows in a dense newest-wins overlay (seeded from
    one `lsm.resolve_all` of the pre-batch tree) instead of issuing LSM
    puts inside the scan — in-scan puts drag the flush `lax.cond` into
    the loop and XLA copies the level arrays every step (the cond-copy
    tax, DESIGN.md §4).  One bulk `lsm.puts` after the scan applies every
    staged key's final row and liveness, so the resulting tree *content*
    is identical to the sequential per-item loop (flush/compaction timing
    may differ, which only changes how entries are distributed across
    runs, never what a lookup resolves).

    Negative ids are masked no-ops (the pad-and-mask serving contract,
    DESIGN.md §8): they allocate no writes and leave every state field
    untouched.
    """
    M = cfg.M
    ids = jnp.asarray(ids, jnp.int32)
    snap_live, snap_rows = lsm.resolve_all(cfg.lsm_cfg, state.store, cfg.cap)
    # spare slot cfg.cap absorbs masked writes, exactly like insert_batch
    dlive = jnp.concatenate([snap_live, jnp.zeros((1,), jnp.int8)])
    drows = jnp.concatenate(
        [snap_rows, jnp.full((1, M), lsm.EMPTY, jnp.int32)])
    dead = jnp.asarray(cfg.cap, jnp.int32)
    tomb = jnp.full((M,), lsm.EMPTY, jnp.int32)

    def step(carry, node):
        st, dlive, drows = carry
        i = jnp.asarray(node, jnp.int32)
        v = i >= 0
        i_safe = jnp.maximum(i, 0)
        # absent / already-deleted ids are counted no-ops: every write
        # below is gated on `was_live`, so a double delete stages nothing
        # (previously it re-tombstoned the key — a silent graph write)
        was_live = v & (st.levels[i_safe] >= 0)

        # ---- upper layers (same relink rule as `delete`, v-gated) --------
        upper_adj = st.upper_adj
        for u in range(cfg.num_upper):
            active = was_live & (st.levels[i_safe] > u)
            nbr = upper_adj[u, i_safe]                           # [M_up]
            upper_adj = _relink_upper_rows(
                cfg, st.vectors, st.levels, st.tombstone, upper_adj, u, i,
                nbr, active)
        st = st._replace(upper_adj=upper_adj)

        # ---- bottom layer (Algorithm 2 lines 13-22) ----------------------
        # reads resolve from the carried dense view: identical content to
        # what per-item `lsm.get`/`get_batch` would return mid-sequence
        n1 = jnp.where(was_live & (dlive[i_safe] > 0),
                       drows[i_safe], -1)                       # [M]
        n1_safe = jnp.maximum(n1, 0)
        rows = drows[n1_safe]                                   # [M, M]
        cand = jnp.concatenate([rows.reshape(-1), n1])          # C = M*M + M
        d = jnp.sum((st.vectors[jnp.maximum(cand, 0)][None, :, :]
                     - st.vectors[n1_safe][:, None, :]) ** 2, axis=-1)
        bad = (cand[None, :] < 0) | (cand[None, :] == i) \
            | (cand[None, :] == n1[:, None]) \
            | (st.levels[jnp.maximum(cand, 0)][None, :] < 0) \
            | st.tombstone[jnp.maximum(cand, 0)][None, :]
        d = jnp.where(bad, INF, d)
        masked_ids = jnp.where(bad, -1, jnp.broadcast_to(cand, bad.shape))
        d = jax.vmap(_dedup_to_inf)(masked_ids, d)
        new_rows, _ = jax.vmap(lambda dd: _topm(cand, dd, cfg.M))(d)

        # stage: relinked neighbor rows (live), then i's tombstone —
        # same write order as the sequential puts + lsm.delete
        tgt = jnp.where(n1 >= 0, n1_safe, dead)
        drows = drows.at[tgt].set(new_rows)
        dlive = dlive.at[tgt].set(1)
        ti = jnp.where(was_live, i_safe, dead)
        drows = drows.at[ti].set(tomb)
        dlive = dlive.at[ti].set(0)
        w_keys = jnp.concatenate([tgt, ti[None]])               # [M + 1]

        levels = st.levels.at[i_safe].set(
            jnp.where(was_live, -1, st.levels[i_safe]))
        need_new_entry = was_live & (st.entry == i)
        # entry repair is a full-cap argmax, needed only when the entry
        # node itself dies — cond it out of the common per-item path
        entry = jax.lax.cond(
            need_new_entry,
            lambda lv: jnp.argmax(
                jnp.where(jnp.arange(cfg.cap) == i, -1, lv)
            ).astype(jnp.int32),
            lambda lv: st.entry, levels)
        st = st._replace(
            levels=levels, entry=entry,
            max_level=jnp.where(
                was_live, jnp.maximum(levels[jnp.maximum(entry, 0)], 0),
                st.max_level),
            n_live=st.n_live - was_live.astype(jnp.int32),
            n_delete_noops=st.n_delete_noops
            + (v & ~was_live).astype(jnp.int32))
        stats = IOStats(
            n_adj=jnp.where(was_live, 1 + cfg.M, 0).astype(jnp.int32),
            n_vec=jnp.where(
                was_live, jnp.sum(jnp.isfinite(d)), 0).astype(jnp.int32),
            n_filtered=jnp.zeros((), jnp.int32),
            n_hops=jnp.zeros((), jnp.int32))
        return (st, dlive, drows), (w_keys, stats)

    (state, dlive, drows), (w_keys, stats) = jax.lax.scan(
        step, (state, dlive, drows), ids)
    # one bulk LSM apply: duplicate keys all carry their *final* overlay
    # row + liveness, so newest-wins resolution matches the sequential loop
    w_keys = w_keys.reshape(-1)
    state = state._replace(
        store=lsm.puts(cfg.lsm_cfg, state.store, w_keys,
                       drows[w_keys], dlive[w_keys]))
    return state, IOStats(*(jnp.sum(a).astype(jnp.int32) for a in stats))


# ---------------------------------------------------------------------------
# delete (Algorithm 2)
# ---------------------------------------------------------------------------

def delete(cfg: HNSWConfig, state: HNSWState, node) -> Tuple[HNSWState, IOStats]:
    """Delete one node; dispatches statically on `cfg.lazy_delete`.

    Lazy (default): set the tombstone bit only — the node stays routable
    but is never returned; `consolidate` reclaims it later.  Eager: the
    paper's Algorithm-2 local relink.  Deleting an absent or
    already-deleted id is a counted no-op either way.
    """
    if cfg.lazy_delete:
        return tombstone_batch(cfg, state,
                               jnp.asarray(node, jnp.int32)[None])
    return _delete_eager(cfg, state, node)


def _delete_eager(cfg: HNSWConfig, state: HNSWState,
                  node) -> Tuple[HNSWState, IOStats]:
    """Delete a vector with local neighbor relinking (Algorithm 2)."""
    i = jnp.asarray(node, jnp.int32)
    was_live = state.levels[i] >= 0
    upper_adj = state.upper_adj

    # ---- upper layers (vectorized relink, see _relink_upper_rows) -----------
    for u in range(cfg.num_upper):
        active = state.levels[i] > u
        nbr = upper_adj[u, i]                                   # [M_up]
        upper_adj = _relink_upper_rows(
            cfg, state.vectors, state.levels, state.tombstone, upper_adj,
            u, i, nbr, active)
    state = state._replace(upper_adj=upper_adj)

    # ---- bottom layer (Algorithm 2 lines 13-22) -----------------------------
    # The per-neighbor relink rows all derive from the same up-front
    # 2-hop candidate pool (no read-after-write dependency), so the whole
    # pass vectorizes: one [M, C] distance block, vmapped dedup/top-M, and
    # one bulk `puts` for the M rewritten rows.
    found, n1, _ = lsm.get(cfg.lsm_cfg, state.store, i)
    n1 = jnp.where(found & was_live, n1, -1)                    # [M]
    n1_safe = jnp.maximum(n1, 0)
    _, rows, _ = lsm.get_batch(cfg.lsm_cfg, state.store, n1_safe)  # [M, M]
    cand = jnp.concatenate([rows.reshape(-1), n1])              # C = M*M + M
    d = jnp.sum((state.vectors[jnp.maximum(cand, 0)][None, :, :]
                 - state.vectors[n1_safe][:, None, :]) ** 2, axis=-1)
    bad = (cand[None, :] < 0) | (cand[None, :] == i) \
        | (cand[None, :] == n1[:, None]) \
        | (state.levels[jnp.maximum(cand, 0)][None, :] < 0) \
        | state.tombstone[jnp.maximum(cand, 0)][None, :]
    d = jnp.where(bad, INF, d)
    masked_ids = jnp.where(bad, -1, jnp.broadcast_to(cand, bad.shape))
    d = jax.vmap(_dedup_to_inf)(masked_ids, d)
    new_rows, _ = jax.vmap(lambda dd: _topm(cand, dd, cfg.M))(d)
    dead = jnp.asarray(cfg.cap, jnp.int32)
    store = lsm.puts(cfg.lsm_cfg, state.store,
                     jnp.where(n1 >= 0, n1_safe, dead), new_rows)
    n_vec = jnp.sum(jnp.isfinite(d)).astype(jnp.int32)
    # deleting an absent/dead id stages no tombstone (counted no-op)
    store = lsm.delete(cfg.lsm_cfg, store, jnp.where(was_live, i, dead))

    levels = state.levels.at[i].set(jnp.where(was_live, -1,
                                              state.levels[i]))
    # entry repair: highest remaining level (argmax breaks ties by lowest id)
    need_new_entry = was_live & (state.entry == i)
    alt = jnp.argmax(jnp.where(jnp.arange(cfg.cap) == i, -1, levels))
    entry = jnp.where(need_new_entry, alt.astype(jnp.int32), state.entry)
    state = state._replace(
        store=store, levels=levels, entry=entry,
        max_level=jnp.where(
            was_live, jnp.maximum(levels[jnp.maximum(entry, 0)], 0),
            state.max_level),
        n_live=state.n_live - was_live.astype(jnp.int32),
        n_delete_noops=state.n_delete_noops
        + (~was_live).astype(jnp.int32))
    stats = IOStats(
        n_adj=jnp.where(was_live, 1 + cfg.M, 0).astype(jnp.int32),
        n_vec=jnp.where(was_live, n_vec, 0),
        n_filtered=jnp.zeros((), jnp.int32),
        n_hops=jnp.zeros((), jnp.int32))
    return state, stats


# ---------------------------------------------------------------------------
# lazy deletion + background consolidation (DESIGN.md §9)
# ---------------------------------------------------------------------------

def tombstone_batch(cfg: HNSWConfig, state: HNSWState,
                    ids: jax.Array) -> Tuple[HNSWState, IOStats]:
    """Phase-1 lazy delete: mark `ids` tombstoned in one scatter.

    No graph or LSM writes at all — the nodes keep their adjacency rows
    and stay *routable* (traversal expands through them, so routes
    crossing deleted regions survive), but the returnable mask hides
    them from every result heap.  Slots are reclaimed later by
    `consolidate` (FreshDiskANN's delete-list recipe).

    Negative ids are masked no-ops (the pad-and-mask serving contract).
    Non-negative ids that are absent, already tombstoned, or duplicated
    within the batch are counted in `n_delete_noops` and change nothing.
    """
    ids = jnp.asarray(ids, jnp.int32)
    valid = (ids >= 0) & (ids < cfg.cap)
    safe = jnp.clip(ids, 0, cfg.cap - 1)
    # within-batch duplicates: only the first occurrence applies (the
    # tombstone lane is read once, before any write of this batch)
    eq = (safe[None, :] == safe[:, None]) & valid[None, :]
    first = ~jnp.any(jnp.tril(eq, k=-1), axis=1)
    applies = valid & first & (state.levels[safe] >= 0) \
        & ~state.tombstone[safe]
    n_new = jnp.sum(applies).astype(jnp.int32)
    # masked lanes scatter to the out-of-bounds id `cap` and are dropped,
    # the same idiom as insert_batch's masked writes
    idx_w = jnp.where(applies, safe, cfg.cap)
    tomb = state.tombstone.at[idx_w].set(True, mode="drop")
    state = state._replace(
        tombstone=tomb,
        n_tombstones=state.n_tombstones + n_new,
        n_live=state.n_live - n_new,
        n_delete_noops=state.n_delete_noops
        + jnp.sum((ids >= 0) & ~applies).astype(jnp.int32))
    return state, IOStats.zero()


def _diversity_block(vectors: jax.Array, cand: jax.Array, d: jax.Array,
                     m: int, alpha: float = 1.0) -> jax.Array:
    """Blocked keepPruned diversity selection: `_diversity_topm` over a
    [b, C] candidate block, with the pairwise matrix built by matmul
    (norms + cv@cv^T) instead of the [b, C, C, dim] difference broadcast,
    which would not fit at consolidation block sizes.  `d` must already
    be +inf for duplicate/invalid candidates."""
    b, C = cand.shape
    order = jnp.argsort(d, axis=1, stable=True)
    ids_s = jnp.take_along_axis(cand, order, axis=1)
    d_s = jnp.take_along_axis(d, order, axis=1)
    cv = vectors[jnp.maximum(ids_s, 0)]                   # [b, C, dim]
    n2 = jnp.sum(cv * cv, axis=-1)
    pair = n2[:, :, None] + n2[:, None, :] \
        - 2.0 * jnp.einsum("bcd,bed->bce", cv, cv)
    valid = jnp.isfinite(d_s) & (ids_s >= 0)

    def body(i, kept):
        dominated = jnp.any(
            kept & (alpha * pair[:, i, :] < d_s[:, i][:, None]), axis=1)
        space = jnp.sum(kept, axis=1) < m
        return kept.at[:, i].set(valid[:, i] & (~dominated) & space)

    kept = jax.lax.fori_loop(0, C, body, jnp.zeros((b, C), jnp.bool_))
    rank = jnp.argsort(~kept, axis=1, stable=True)  # kept first, dist order
    ids_r = jnp.take_along_axis(ids_s, rank, axis=1)[:, :m]
    valid_r = jnp.take_along_axis(valid, rank, axis=1)[:, :m]
    return jnp.where(valid_r, ids_r, -1)


def _consolidate_rows(vectors: jax.Array, adj: jax.Array, tomb: jax.Array,
                      owner: jax.Array, member: jax.Array, W: int,
                      block: int):
    """Graph-wide batched splice: for every `owner` node whose row holds
    tombstoned neighbors, rebuild the row from the row itself plus the
    tombstoned neighbors' out-neighbors (their 2-hop bridge), selecting
    `member` targets under the diversity rule — FreshDiskANN's
    RobustPrune step.  Plain closest-W splicing measurably halves
    post-consolidation QPS: it fills repaired rows with cluster-local
    edges and strips the long-range portals the beam navigates by.

    `adj` is a dense view int32[cap, W]; `owner` masks which rows may be
    rewritten, `member` which ids are valid targets.  Processed in
    `block`-node chunks under `lax.map` so the [block, W + W*W, dim]
    distance gather never materializes at full cap.  Returns
    (new_adj, changed, n_dist) where rows with no tombstoned neighbor
    come back untouched.
    """
    cap = adj.shape[0]
    nblk = -(-cap // block)
    ids = jnp.arange(nblk * block, dtype=jnp.int32).reshape(nblk, block)

    def repair(blk):
        in_range = blk < cap
        safe_blk = jnp.minimum(blk, cap - 1)
        r = adj[safe_blk]                                    # [b, W]
        rs = jnp.maximum(r, 0)
        parent_tomb = (r >= 0) & tomb[rs]                    # [b, W]
        # out-neighbors of tombstoned neighbors only: live neighbors'
        # rows are not part of the FreshDiskANN splice pool
        exp = adj[rs].reshape(block, W * W)
        exp_ok = jnp.repeat(parent_tomb, W, axis=1)
        cand = jnp.concatenate([r, jnp.where(exp_ok, exp, -1)], axis=1)
        cs = jnp.maximum(cand, 0)
        bad = (cand < 0) | (cand == blk[:, None]) | ~member[cs]
        d = jnp.sum((vectors[cs]
                     - vectors[safe_blk][:, None, :]) ** 2, axis=-1)
        d = jnp.where(bad, INF, d)
        masked = jnp.where(bad, -1, cand)
        d = jax.vmap(_dedup_to_inf)(masked, d)
        new_r = _diversity_block(vectors, cand, d, W)
        changed = in_range & owner[safe_blk] & jnp.any(parent_tomb, axis=1)
        n_dist = jnp.sum(
            jnp.where(changed[:, None], jnp.isfinite(d), False))
        return jnp.where(changed[:, None], new_r, r), changed, n_dist

    new_adj, changed, n_dist = jax.lax.map(repair, ids)
    return (new_adj.reshape(nblk * block, W)[:cap],
            changed.reshape(-1)[:cap],
            jnp.sum(n_dist).astype(jnp.int32))


def consolidate(cfg: HNSWConfig, state: HNSWState, *,
                block: int = 256) -> Tuple[HNSWState, IOStats]:
    """Phase-2 lazy delete: splice every tombstone out and reclaim slots.

    The StreamingMerge-style batched repair (FreshDiskANN §4): resolve
    the bottom layer into a dense view once, rewrite every live row that
    touches a tombstone (splicing in the tombstones' out-neighbors under
    the relink rule), do the same for the memory-resident upper layers,
    then emit the surviving rows as one fresh sorted LSM run
    (`lsm.rebuild_from_dense`) — tombstoned ids simply do not appear in
    the rebuilt store, which is the slot reclamation.  Internal ids are
    never reused (allocation stays monotonic), so a serving layer's
    external↔internal map needs no rewrite: entries of reclaimed ids
    become permanently inert (see `serve`, DESIGN.md §9).

    Safe to call with zero tombstones (no row changes, store rewrite
    only).  Entry repair runs when the entry node itself is reclaimed.
    """
    live8, rows = lsm.resolve_all(cfg.lsm_cfg, state.store, cfg.cap)
    tomb = state.tombstone
    routable = state.levels >= 0
    keep = routable & ~tomb
    rows = jnp.where((routable & (live8 > 0))[:, None], rows, -1)

    new_rows, changed, n_dist = _consolidate_rows(
        state.vectors, rows, tomb, keep, keep, cfg.M, block)
    store = lsm.rebuild_from_dense(cfg.lsm_cfg, state.store, keep, new_rows)

    uppers = []
    for u in range(cfg.num_upper):
        member_u = keep & (state.levels > u)
        new_u, _, n_dist_u = _consolidate_rows(
            state.vectors, state.upper_adj[u], tomb, member_u, member_u,
            cfg.M_up, block)
        # reclaimed nodes lose their upper rows outright
        uppers.append(jnp.where(tomb[:, None], -1, new_u))
        n_dist = n_dist + n_dist_u
    upper_adj = jnp.stack(uppers)

    n_reclaimed = state.n_tombstones
    levels = jnp.where(tomb, -1, state.levels)
    entry_dead = (state.entry >= 0) & tomb[jnp.maximum(state.entry, 0)]
    alt = jnp.argmax(levels).astype(jnp.int32)
    entry = jnp.where(entry_dead, alt, state.entry)
    state = state._replace(
        store=store,
        upper_adj=upper_adj,
        levels=levels,
        entry=entry,
        max_level=jnp.maximum(levels[jnp.maximum(entry, 0)], 0),
        # repaired rows changed slot alignment; their heat restarts
        heat=jnp.where((tomb | changed)[:, None], 0, state.heat),
        tombstone=jnp.zeros_like(tomb),
        n_tombstones=jnp.zeros((), jnp.int32),
        # reclaimed slots leave the tier: back to the (empty) hot lane so
        # per-lane byte accounting never counts dead ids as cold rows
        hot=jnp.where(tomb, True, state.hot),
        qscale=jnp.where(tomb, 0.0, state.qscale),
        tier_heat=jnp.where(tomb, 0.0, state.tier_heat))
    stats = IOStats(
        n_adj=((1 + cfg.M) * n_reclaimed
               + jnp.sum(changed).astype(jnp.int32)),
        n_vec=n_dist,
        n_filtered=jnp.zeros((), jnp.int32),
        n_hops=jnp.zeros((), jnp.int32))
    return state, stats


# ---------------------------------------------------------------------------
# bulk construction (initial index build)
# ---------------------------------------------------------------------------

def _np_diversity_select(cand: "np.ndarray", cand_d: "np.ndarray",
                         vecs_np, deg: int, alpha: float = 1.0):
    """Numpy twin of _diversity_topm (keepPruned heuristic)."""
    import numpy as np
    order = np.argsort(cand_d)
    cand, cand_d = cand[order], cand_d[order]
    cv = vecs_np[cand]
    diff = cv[:, None, :] - cv[None, :, :]
    pair = np.einsum("ijk,ijk->ij", diff, diff)
    kept: list[int] = []
    kept_idx: list[int] = []
    for ci in range(len(cand)):
        if len(kept) >= deg:
            break
        if all(alpha * pair[ci, kj] >= cand_d[ci] for kj in kept_idx):
            kept.append(int(cand[ci]))
            kept_idx.append(ci)
    for ci in range(len(cand)):            # keepPruned fill
        if len(kept) >= deg:
            break
        if int(cand[ci]) not in kept:
            kept.append(int(cand[ci]))
            kept_idx.append(ci)
    return kept, [float(cand_d[j]) for j in kept_idx]


def _incremental_graph(vecs_np, member_ids, deg: int, seed: int,
                       batch: int = 64):
    """Batched random-order incremental construction of one layer.

    Nodes arrive in random order and connect to a *diversity-selected* set
    among the already-placed nodes (HNSW's neighbor heuristic); back-edges
    evict the placed node's most redundant edge.  Early arrivals keep
    long-range links, which is exactly how incremental HNSW/NSW layers
    become navigable — an exact kNN graph would fall apart into per-cluster
    islands.  Host-side numpy; the per-batch distance block uses the shared
    kernel wrapper.
    """
    import numpy as np
    n_total = vecs_np.shape[0]
    rows = np.full((n_total, deg), -1, np.int32)
    rowd = np.full((n_total, deg), np.inf, np.float32)
    ids = np.asarray(member_ids)
    if ids.size == 0:
        return rows
    rng = np.random.default_rng(seed)
    order = ids[rng.permutation(ids.size)]
    placed = [int(order[0])]
    # geometric batch ramp: early nodes (the long-range hubs) must connect
    # densely to each other, not just to the seed
    bounds = [1]
    step = 1
    while bounds[-1] < order.size:
        bounds.append(min(bounds[-1] + step, order.size))
        step = min(batch, step * 2)
    for s, e in zip(bounds[:-1], bounds[1:]):
        chunk = order[s:e]
        pv = jnp.asarray(vecs_np[np.asarray(placed)])
        d_blk = np.asarray(l2_distance(jnp.asarray(vecs_np[chunk]), pv))
        # candidate pool for diversity.  Very small builds (tiny shards,
        # sparse upper layers) see the *complete* placed set: with only a
        # few dozen nodes the 2*deg nearest candidates all sit inside one
        # tight cluster and diversity selection can strand other clusters
        # entirely (the small-shard navigability loss).
        kk = len(placed) if ids.size <= max(128, 4 * deg) \
            else min(2 * deg, len(placed))
        top = np.argpartition(d_blk, kk - 1, axis=1)[:, :kk] \
            if kk < len(placed) else \
            np.broadcast_to(np.arange(len(placed)), (len(chunk),
                                                     len(placed)))
        placed_arr = np.asarray(placed)
        for bi, i in enumerate(chunk):
            cand = placed_arr[top[bi]]
            nb, nd = _np_diversity_select(cand, d_blk[bi, top[bi]],
                                          vecs_np, deg)
            rows[i, : len(nb)] = nb
            rowd[i, : len(nd)] = nd
            for p_, d_ in zip(nb, nd):
                free = np.flatnonzero(rows[p_] < 0)
                if free.size:
                    j = int(free[0])
                else:
                    # evict the edge most redundant w.r.t. the newcomer
                    nbr_vecs = vecs_np[rows[p_]]
                    d_to_new = ((nbr_vecs - vecs_np[i]) ** 2).sum(1)
                    j = int(np.argmin(d_to_new))
                rows[p_, j] = i
                rowd[p_, j] = d_
            placed.append(int(i))
    return rows


def _repair_reachability(rows, vecs_np, member_ids, entry: int, deg: int):
    """Guarantee every member is reachable from `entry` over `rows`.

    Diversity selection on clustered data can leave whole clusters as
    graph islands (no inbound path from the entry chain), which beam
    search then never finds no matter the ef.  Repair: BFS from the
    entry; while any member is unreachable, bridge the globally closest
    (reachable, unreachable) pair with a bidirectional edge — each
    bridge absorbs that island's entire component.  Bridge edges are
    *protected*: a full row evicts its unprotected slot most redundant
    w.r.t. the new neighbor (the `_backlink_rows` rule), never an
    earlier bridge — two islands sharing one anchor would otherwise
    evict each other's bridge forever.  Anchors with no evictable slot
    are skipped, and the loop is bounded by the member count, so repair
    always terminates.
    """
    import numpy as np
    members = np.asarray(member_ids)
    if members.size <= 1:
        return rows
    in_layer = np.zeros(rows.shape[0], bool)
    in_layer[members] = True
    protected = np.zeros(rows.shape, bool)

    def bfs():
        seen = np.zeros(rows.shape[0], bool)
        seen[entry] = True
        frontier = np.asarray([entry])
        while frontier.size:
            nxt = rows[frontier].ravel()
            nxt = np.unique(nxt[nxt >= 0])
            nxt = nxt[in_layer[nxt] & ~seen[nxt]]
            seen[nxt] = True
            frontier = nxt
        return seen

    def add_edge(src: int, dst: int):
        if dst in rows[src]:
            j = int(np.flatnonzero(rows[src] == dst)[0])
            protected[src, j] = True
            return
        free = np.flatnonzero(rows[src] < 0)
        if free.size:
            j = int(free[0])
        else:
            cand = np.flatnonzero(~protected[src])
            if cand.size == 0:
                return      # row is all bridges; caller skips such anchors
            nbr = vecs_np[rows[src, cand]]
            j = int(cand[np.argmin(((nbr - vecs_np[dst]) ** 2).sum(1))])
        rows[src, j] = dst
        protected[src, j] = True

    for _ in range(members.size):
        seen = bfs()
        un = members[~seen[members]]
        if un.size == 0:
            break
        reach = members[seen[members]]
        d = ((vecs_np[un][:, None, :]
              - vecs_np[reach][None, :, :]) ** 2).sum(-1)
        # only anchors that can still take a bridge edge
        evictable = ((rows[reach] < 0) | ~protected[reach]).any(axis=1)
        if not evictable.any():
            break
        d[:, ~evictable] = np.inf
        bi, bj = np.unravel_index(int(np.argmin(d)), d.shape)
        u_node, r_node = int(un[bi]), int(reach[bj])
        add_edge(r_node, u_node)
        add_edge(u_node, r_node)
    return rows


def bulk_build(cfg: HNSWConfig, vectors: jax.Array, key: jax.Array,
               *, batch: int = 64) -> HNSWState:
    """Initial index build: batched incremental construction per layer.

    Semantically this is Algorithm 1 run over a random insertion order with
    exact (brute-force) neighbor search instead of beam search — the graph
    the paper's insert procedure converges to, built at matmul speed.  The
    bottom layer is written into the LSM tree as one sorted run (the
    offline "build one big level" path); dynamic updates afterwards always
    go through insert()/delete().
    """
    import numpy as np
    n, dim = vectors.shape
    assert n <= cfg.cap and dim == cfg.dim
    k_init, k_lvl = jax.random.split(key)
    state = init(cfg, k_init)

    vecs = jnp.asarray(vectors, jnp.float32)
    vecs_np = np.asarray(vecs)
    norms = jnp.linalg.norm(vecs, axis=1)
    codes = simhash.encode(simhash.SimHashParams(state.proj), vecs)
    lvls_np = np.minimum(
        np.floor(-cfg.level_scale * np.log(np.asarray(jax.random.uniform(
            k_lvl, (n,), jnp.float32, 1e-7, 1.0)))).astype(np.int32),
        cfg.num_upper)
    lvls_np[0] = cfg.num_upper   # stable entry chain
    ids = jnp.arange(n, dtype=jnp.int32)

    # entry = node 0 (forced to the top level above); every layer repairs
    # reachability from it so no cluster is stranded as a graph island
    bottom = _incremental_graph(vecs_np, np.arange(n), cfg.M, seed=0,
                                batch=batch)
    bottom = _repair_reachability(bottom, vecs_np, np.arange(n), 0, cfg.M)
    store = lsm.bulk_load(cfg.lsm_cfg, ids, jnp.asarray(bottom))

    upper = jnp.full((cfg.num_upper, cfg.cap, cfg.M_up), -1, jnp.int32)
    for u in range(cfg.num_upper):
        members = np.flatnonzero(lvls_np > u)
        rows_u = _incremental_graph(vecs_np, members, cfg.M_up, seed=u + 1,
                                    batch=batch)
        rows_u = _repair_reachability(rows_u, vecs_np, members, 0, cfg.M_up)
        upper = upper.at[u, :n].set(jnp.asarray(rows_u))

    lvls = jnp.asarray(lvls_np)
    entry = jnp.argmax(lvls).astype(jnp.int32)
    return state._replace(
        vectors=state.vectors.at[:n].set(vecs),
        norms=state.norms.at[:n].set(norms),
        codes=state.codes.at[:n].set(codes),
        levels=state.levels.at[:n].set(lvls),
        upper_adj=upper,
        store=store,
        count=jnp.asarray(n, jnp.int32),
        n_live=jnp.asarray(n, jnp.int32),
        entry=entry,
        max_level=lvls[entry],
        mean_norm=jnp.mean(norms))


# ---------------------------------------------------------------------------
# memory accounting (paper Fig. 6 — what must stay RAM-resident)
# ---------------------------------------------------------------------------

def memory_counts(state: HNSWState) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Device-side (n_routable, n_hot, n_upper) for the byte model."""
    routable = state.levels >= 0
    n_routable = jnp.sum(routable)
    n_hot = jnp.sum(routable & state.hot)
    n_upper = jnp.sum(state.levels > 0)
    return n_routable, n_hot, n_upper


def memory_breakdown(cfg: HNSWConfig, state: HNSWState,
                     counts=None) -> MemoryBreakdown:
    """Per-component resident bytes (DESIGN.md §12).

    The serving-vector lanes: with tiering off every routable node keeps
    its dense f32 row resident (the dense baseline the paper's Fig. 6
    argues against); with tiering on only hot-lane nodes do, and cold
    nodes cost ``dim + 4`` bytes (int8 row + f32 scale).  The bottom
    adjacency graph stays on "disk" (the LSM tree) in both modes —
    DiskANN-style systems keeping the *graph* in RAM during updates is
    the other half of the paper's 66.2% claim.

    Components the pre-tier accounting omitted are now counted: the
    tombstone bitmap, the insert-overlay staging buffers, and the
    ext↔int id maps a serving layer holds 1:1 with backend capacity.
    `counts` lets a caller pass pre-fetched host values of
    `memory_counts` to avoid a device sync.
    """
    if counts is None:
        counts = memory_counts(state)
    # one fused fetch instead of three scalar unboxings; stats() passes
    # pre-fetched host counts so the serve path never reaches the device
    n_routable, n_hot, n_upper = map(
        int, jax.device_get(counts))  # sync-ok: fused accounting fetch
    n_cold = n_routable - n_hot
    if not cfg.tier:
        n_hot, n_cold = n_routable, 0
    return MemoryBreakdown(
        hot_vectors=n_hot * cfg.dim * 4,
        cold_codes=n_cold * (cfg.dim + 4),
        upper_graph=n_upper * cfg.M_up * 4 * cfg.num_upper,
        upper_vec_cache=n_upper * cfg.dim * 4,
        simhash_codes=n_routable * cfg.words * 4,
        memtable=cfg.lsm_cfg.mem_cap * (4 + 4 * cfg.M + 1),
        tombstones=cfg.cap,
        insert_overlay=(cfg.cap + 1) * (4 * cfg.M + 1),
        id_maps=2 * cfg.cap * 8,
        misc=4096,
        n_hot=n_hot,
        n_cold=n_cold)


def memory_resident_bytes(cfg: HNSWConfig, state: HNSWState) -> int:
    """Total resident bytes: `memory_breakdown(...).total` (host int)."""
    return memory_breakdown(cfg, state).total
