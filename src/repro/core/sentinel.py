"""Runtime sync sentinels (DESIGN.md §14).

`declared_sync` marks the handful of places where the serving stack is
*allowed* to materialize device values on the host — the same points
the static `tools.repro_lint` host-sync rule requires a
``# sync-ok: <reason>`` comment on.  Serve tests run steady-state
traffic inside `forbid_undeclared_sync()`, so any device→host sync
*outside* one of these scopes raises at test time: the static
allowlist is cross-checked by execution.

Two layers of enforcement compose inside `forbid_undeclared_sync`:

* ``jax.transfer_guard_device_to_host("disallow_explicit")`` — the
  XLA-level guard.  Authoritative on accelerator backends, but inert
  on the CPU backend, where device buffers live in host memory and
  "transfers" are zero-copy.
* a patch of ``ArrayImpl._value`` / ``ArrayImpl.item`` — the Python
  chokepoints behind ``int()``/``float()``/``bool()``/``.tolist()``/
  ``jax.device_get``/``.item()`` on a jax array.  This is exactly the
  sink set the static HS001 rule flags, and it works on CPU.

Known gap: buffer-protocol reads (``np.asarray(x)`` on CPU) bypass
both layers — numpy takes a zero-copy view without consulting Python.
On accelerator backends the XLA guard catches those too.

Every `declared_sync` entry bumps a per-reason counter so tests can
assert that the declared points (and only those) actually fired.
"""

from __future__ import annotations

import collections
import threading
from contextlib import contextmanager
from typing import Dict, Iterator

import jax
import jax.numpy as jnp

_counts: Dict[str, int] = collections.Counter()
_lock = threading.Lock()

# forbid_undeclared_sync() state: a global depth (guard active in any
# thread guards every thread — serve worker threads sync too) plus a
# thread-local allow depth (a declared_sync scope only blesses the
# thread that entered it).
_guard_depth = 0
_tls = threading.local()


class UndeclaredHostSyncError(RuntimeError):
    """A device→host sync outside any `declared_sync` scope."""


def _allowed() -> bool:
    return getattr(_tls, "allow_depth", 0) > 0


@contextmanager
def declared_sync(reason: str) -> Iterator[None]:
    """Scope in which device→host transfers are declared legitimate.

    `reason` is mandatory and should say *why* the sync is allowed
    ("result materialization", "maintenance cadence scalar", ...) —
    it keys the counter surfaced by `sync_counts()`.
    """
    if not reason:
        raise ValueError("declared_sync requires a non-empty reason")
    with _lock:
        _counts[reason] += 1
    _tls.allow_depth = getattr(_tls, "allow_depth", 0) + 1
    try:
        with jax.transfer_guard_device_to_host("allow"):
            yield
    finally:
        _tls.allow_depth -= 1


@contextmanager
def forbid_undeclared_sync() -> Iterator[None]:
    """Raise `UndeclaredHostSyncError` on any host sync outside a
    `declared_sync` scope, for the duration of the context.

    Re-entrant; patches are installed on first entry and removed when
    the last scope exits.
    """
    global _guard_depth
    array_t = type(jnp.zeros(()))
    with _lock:
        if _guard_depth == 0:
            _install(array_t)
        _guard_depth += 1
    try:
        with jax.transfer_guard_device_to_host("disallow_explicit"):
            yield
    finally:
        with _lock:
            _guard_depth -= 1
            if _guard_depth == 0:
                _remove(array_t)


_saved: Dict[str, object] = {}


def _install(array_t: type) -> None:
    _saved["_value"] = array_t.__dict__["_value"]
    _saved["item"] = array_t.__dict__["item"]
    orig_value = _saved["_value"]
    orig_item = _saved["item"]

    def guarded_value(self):
        if _guard_depth > 0 and not _allowed():
            raise UndeclaredHostSyncError(
                "device→host sync outside declared_sync "
                "(annotate the call site with `# sync-ok: <reason>` "
                "and wrap it in repro.core.sentinel.declared_sync)")
        return orig_value.fget(self)  # type: ignore[union-attr]

    def guarded_item(self, *args, **kwargs):
        if _guard_depth > 0 and not _allowed():
            raise UndeclaredHostSyncError(
                "`.item()` outside declared_sync "
                "(annotate the call site with `# sync-ok: <reason>` "
                "and wrap it in repro.core.sentinel.declared_sync)")
        return orig_item(self, *args, **kwargs)  # type: ignore[operator]

    array_t._value = property(guarded_value)
    array_t.item = guarded_item


def _remove(array_t: type) -> None:
    array_t._value = _saved.pop("_value")
    array_t.item = _saved.pop("item")


def sync_counts() -> Dict[str, int]:
    """Snapshot of {reason: times entered} since process start."""
    with _lock:
        return dict(_counts)


def reset_sync_counts() -> None:
    with _lock:
        _counts.clear()
