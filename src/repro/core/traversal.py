"""Sampling-guided beam search over the hybrid memory/disk graph (§3.3).

The bottom-layer traversal is the paper's hot loop: repeatedly pop the
closest unexpanded candidate, read its adjacency row (from the LSM tree —
pays `t_n`), *prefilter* its neighbors with in-memory SimHash collision
counts (Eq. 5-6), and fetch full vectors only for survivors (pays `t_v`
each — Eq. 8's `rho * d` term).

Implementation notes (TPU adaptation — DESIGN.md §2):
 - The frontier is a fixed-size sorted beam (candidate set C and result set
   W of classic HNSW merged into one ef-wide array with `expanded` flags),
   so the whole search is a `jax.lax.while_loop` over static shapes and
   vmaps over a query batch.
 - `visited` is a bool[cap+1] array; masked scatter-writes land in the
   spare slot.
 - Edge-heat is recorded per hop as (node, fetched-mask) pairs so the
   caller can build the reordering heatmap (§3.4) without carrying a
   [cap, M] array through the loop.
 - Multi-expansion (DESIGN.md §3): `n_expand` (B) frontier nodes are
   popped per iteration, their adjacency rows are read through one
   batched LSM lookup, and the SimHash prefilter plus the fused
   gather+distance kernel run over the whole B*M candidate block before a
   single merge.  This cuts the `while_loop` trip count ~B× and makes
   each distance call wide enough to feed the MXU.  B=1 reproduces the
   classic one-node-per-hop search exactly.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import simhash
from repro.core.iostats import IOStats

INF = jnp.inf


class BeamResult(NamedTuple):
    ids: jax.Array       # int32[ef] — best ids found, ascending distance
    dists: jax.Array     # f32[ef]
    stats: IOStats
    # heat arrays have length iter_cap * n_expand, where iter_cap =
    # min(max_iters, ceil(max_iters / n_expand) + 3); for n_expand=1 that
    # is max_iters.  Callers reshape with (-1, ...), never a fixed size.
    heat_nodes: jax.Array   # int32[iter_cap * n_expand] — expanded nodes (-1 pad)
    heat_mask: jax.Array    # bool[iter_cap * n_expand, M] — fetched slots per hop


def _rank_desc(score: jax.Array) -> jax.Array:
    """rank[i] = position of i when sorting score descending (stable)."""
    order = jnp.argsort(-score, stable=True)
    return jnp.argsort(order, stable=True)


def beam_search(
    q: jax.Array,                    # f32[dim]
    entry: jax.Array,                # int32[] — entry node id
    entry_dist: jax.Array,           # f32[] — distance(q, entry)
    adj_fn: Callable,                # ids int32[B] -> (rows int32[B, M], probes int32[B])
    dist_fn: Callable,               # ids int32[n] -> f32[n] (inf for id<0)
    codes: jax.Array,                # uint32[cap, W] in-memory hash codes
    code_q: jax.Array,               # uint32[W]
    live: jax.Array,                 # bool[cap] — node liveness
    *,
    cap: int,
    ef: int,
    k: int,
    m_bits: int,
    eps: float,
    rho: float,                      # sampling ratio: fetch ceil(rho * |eligible|)
    max_iters: int,
    use_filter: bool,
    q_norm: jax.Array,               # f32[]
    mean_norm: jax.Array,            # f32[]
    n_expand: int = 1,               # B: frontier nodes expanded per iteration
    active: jax.Array | None = None,  # bool[] — False: inert (padded) lane
    returnable: jax.Array | None = None,  # bool[cap] — None: all of `live`
) -> BeamResult:
    """Single-query sampling-guided beam search.  vmap over queries.

    `adj_fn` is the *batched* adjacency reader: it takes the B popped node
    ids at once (-1 for inactive expansion slots, which must yield all -1
    rows) so the storage layer can serve the whole frontier block in one
    lookup (`lsm.get_batch`) instead of B point reads.

    `live` is the *routable* mask: nodes the traversal may fetch and
    expand through.  `returnable` (optional) is the stricter mask of
    nodes allowed in the final result list — the lazy-deletion contract
    (DESIGN.md §9): tombstoned nodes stay routable (their edges keep the
    graph connected and the beam expands through them at full cost) but
    are masked out of the returned heap after the loop.  None means
    every routable node is returnable (the classic eager behavior).

    `max_iters` budgets *expansions*, not loop trips: with B > 1 an
    iteration can pop fewer than B nodes when the frontier is thin (the
    first hops always are), so trip-count budgeting would starve wide
    beams.  The loop runs until the expansion budget or the frontier is
    exhausted; for B=1 expansions == iterations, the seed semantics.

    `active` supports pad-and-mask batch dispatch: a False lane never
    enters the loop (its entry distance is masked to +inf), returns all
    -1/inf results, records no heat, and contributes zero IOStats — under
    vmap it costs nothing beyond the trips its live siblings need.
    """
    B = max(1, min(n_expand, ef))
    M = adj_fn(jnp.zeros((B,), jnp.int32))[0].shape[1]
    # trip cap: budget/B trips suffice once the frontier is B wide, plus
    # slack for the thin ramp-up hops (the frontier grows ~M-fold per
    # trip, so 3 trips reach any B <= M^3).  Without the cap a single
    # thin-but-alive straggler would drag a vmapped batch through up to
    # `max_iters` trips.  B=1 keeps the exact seed cap.  Heat storage is
    # sized to the cap, so every trip records.
    iter_cap = min(max_iters, -(-max_iters // B) + 3)
    heat_len = iter_cap

    if active is None:
        entry_n_vec = jnp.ones((), jnp.int32)
    else:
        entry_dist = jnp.where(active, entry_dist, INF)
        entry = jnp.where(active, entry, -1)
        entry_n_vec = jnp.asarray(active, jnp.int32)
    beam_ids = jnp.full((ef,), -1, jnp.int32).at[0].set(entry)
    beam_d = jnp.full((ef,), INF, jnp.float32).at[0].set(entry_dist)
    expanded = jnp.zeros((ef,), jnp.bool_)
    visited = jnp.zeros((cap + 1,), jnp.bool_).at[jnp.maximum(entry, 0)].set(
        entry >= 0)
    heat_nodes = jnp.full((heat_len, B), -1, jnp.int32)
    heat_mask = jnp.zeros((heat_len, B, M), jnp.bool_)
    stats = IOStats.zero()
    # entry vector was fetched to compute entry_dist (not on masked lanes)
    stats = stats._replace(n_vec=stats.n_vec + entry_n_vec)

    # frontier threshold: stop expanding once every candidate within the
    # 3k-th best has been visited.  k-exact termination prunes too hard on
    # delete-damaged graphs (measured: recall 0.96 -> 0.46 post-delete);
    # 3k keeps recall while cutting ~40% of the tail expansions.
    fidx = min(ef, 3 * k) - 1

    def cond(carry):
        it, beam_ids, beam_d, expanded, _, stats, *_ = carry
        thresh = beam_d[fidx]
        frontier = (~expanded) & jnp.isfinite(beam_d) & (beam_d <= thresh)
        return (it < iter_cap) & (stats.n_hops < max_iters) \
            & jnp.any(frontier)

    def body(carry):
        (it, beam_ids, beam_d, expanded, visited, stats,
         heat_nodes, heat_mask) = carry

        # -- pop the B closest unexpanded candidates -----------------------
        frontier_d = jnp.where(expanded, INF, beam_d)
        thresh = beam_d[fidx]
        if B == 1:
            slots = jnp.argmin(frontier_d)[None]
        else:
            # top_k, not a full sort: ties resolve to the lower slot, same
            # as the stable argmin pop
            _, slots = jax.lax.top_k(-frontier_d, B)
        sel_d = frontier_d[slots]
        # extras past the frontier threshold would never be expanded by the
        # B=1 loop (the threshold only tightens) — keep them inert
        active = jnp.isfinite(sel_d) & (sel_d <= thresh)
        expanded = expanded.at[slots].set(expanded[slots] | active)
        nodes = jnp.where(active, beam_ids[slots], -1)

        # -- batched adjacency read (t_n) ----------------------------------
        rows, n_probes = adj_fn(nodes)                  # [B, M], [B]
        row = rows.reshape(B * M)
        valid = (row >= 0) & (row <= cap - 1)
        safe = jnp.where(valid, row, cap)
        seen = visited[safe]
        alive = jnp.where(valid, live[jnp.minimum(safe, cap - 1)], False)
        eligible = valid & (~seen) & alive
        if B > 1:
            # duplicates across the B rows would enter the beam twice
            # (visited is only updated after the block): keep the first
            # occurrence of each id within the block.  An O((BM)^2)
            # comparison triangle beats sort+scatter at these widths.
            eq = safe[None, :] == safe[:, None]
            earlier = jnp.tril(eq, k=-1)
            eligible = eligible & ~jnp.any(earlier, axis=1)

        # -- SimHash prefilter (Eq. 5-6), in-memory, whole block -----------
        cand_codes = codes[jnp.minimum(safe, cap - 1)]
        cols = simhash.collisions(code_q[None, :], cand_codes, m_bits)
        delta_sq = beam_d[k - 1]
        if use_filter:
            cos = simhash.cos_from_l2(delta_sq, q_norm, mean_norm)
            thr = simhash.hoeffding_threshold(m_bits, eps, cos)
            pass_thr = (cols.astype(jnp.float32) >= thr) | ~jnp.isfinite(delta_sq)
        else:
            pass_thr = jnp.ones_like(eligible)
        pre_mask = eligible & pass_thr

        # -- sampling cap (Eq. 8): evaluate only rho of the survivors,
        #    keeping the most-colliding ones ------------------------------
        if isinstance(rho, (int, float)) and rho >= 1.0:
            # static fast path: everything eligible is fetched, so the two
            # ranking argsorts vanish from the loop body
            fetch_mask = pre_mask
        else:
            score = jnp.where(pre_mask, cols, -1)
            rank = _rank_desc(score)
            n_elig = jnp.sum(pre_mask)
            cap_dyn = jnp.ceil(rho * n_elig).astype(jnp.int32)
            fetch_mask = pre_mask & (rank < cap_dyn)
        fetch_ids = jnp.where(fetch_mask, row, -1)

        # -- one fused gather+distance call over the B*M block (t_v each) --
        dists = dist_fn(fetch_ids)

        # -- bookkeeping ----------------------------------------------------
        visited = visited.at[jnp.where(fetch_mask, safe, cap)].set(True)
        n_fetch = jnp.sum(fetch_mask).astype(jnp.int32)
        stats = IOStats(
            n_adj=stats.n_adj + jnp.sum(jnp.where(active, n_probes, 0)),
            n_vec=stats.n_vec + n_fetch,
            n_filtered=stats.n_filtered
            + jnp.sum(eligible).astype(jnp.int32) - n_fetch,
            n_hops=stats.n_hops + jnp.sum(active).astype(jnp.int32),
        )
        heat_nodes = heat_nodes.at[it].set(nodes)
        heat_mask = heat_mask.at[it].set(fetch_mask.reshape(B, M))

        # -- single merge of the whole block into the beam ------------------
        all_ids = jnp.concatenate([beam_ids, fetch_ids])
        all_d = jnp.concatenate([beam_d, dists])
        all_exp = jnp.concatenate([expanded, jnp.ones((B * M,), jnp.bool_)])
        # new candidates are unexpanded; mark masked ones expanded (inert)
        all_exp = all_exp.at[ef:].set(~fetch_mask)
        # top_k == stable argsort prefix here: ties prefer the lower index
        _, order = jax.lax.top_k(-all_d, ef)
        return (it + 1, all_ids[order], all_d[order], all_exp[order],
                visited, stats, heat_nodes, heat_mask)

    init = (jnp.int32(0), beam_ids, beam_d, expanded, visited, stats,
            heat_nodes, heat_mask)
    (_, beam_ids, beam_d, _, _, stats, heat_nodes, heat_mask) = \
        jax.lax.while_loop(cond, body, init)
    if returnable is not None:
        # routable-but-not-returnable entries (tombstones) are demoted to
        # +inf/-1 and the survivors re-packed to the front — one selection
        # outside the loop, so routing cost is identical with or without
        # tombstones in the beam
        ok = (beam_ids >= 0) & returnable[jnp.clip(beam_ids, 0, cap - 1)]
        beam_d = jnp.where(ok, beam_d, INF)
        neg_d, order = jax.lax.top_k(-beam_d, ef)
        beam_d = -neg_d
        beam_ids = jnp.where(jnp.isfinite(beam_d), beam_ids[order], -1)
    return BeamResult(beam_ids, beam_d, stats,
                      heat_nodes.reshape(heat_len * B),
                      heat_mask.reshape(heat_len * B, M))


def greedy_descent(
    q: jax.Array,
    entry: jax.Array,
    entry_dist: jax.Array,
    adj: jax.Array,                # int32[cap, M_up] — one upper layer
    vectors: jax.Array,            # f32[cap, dim]
    live: jax.Array,               # bool[cap]
    *,
    max_steps: int = 64,
) -> Tuple[jax.Array, jax.Array]:
    """Greedy routing in one memory-resident upper layer (Alg. 1 lines 6-8).

    Upper-layer nodes are <1% of the data and their vectors are cached in
    RAM (paper §3.2), so these reads cost no slow-tier I/O.
    """
    cap = adj.shape[0]

    def cond(c):
        step, _, _, moved = c
        return (step < max_steps) & moved

    def body(c):
        step, ep, d_ep, _ = c
        row = adj[ep]
        valid = (row >= 0) & live[jnp.clip(row, 0, cap - 1)]
        safe = jnp.clip(row, 0, cap - 1)
        diff = vectors[safe] - q[None, :]
        d = jnp.where(valid, jnp.sum(diff * diff, axis=-1), INF)
        j = jnp.argmin(d)
        better = d[j] < d_ep
        return (step + 1, jnp.where(better, row[j], ep),
                jnp.where(better, d[j], d_ep), better)

    _, ep, d_ep, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), entry, entry_dist, jnp.bool_(True)))
    return ep, d_ep
