"""Sampling-guided beam search over the hybrid memory/disk graph (§3.3).

The bottom-layer traversal is the paper's hot loop: repeatedly pop the
closest unexpanded candidate, read its adjacency row (from the LSM tree —
pays `t_n`), *prefilter* its neighbors with in-memory SimHash collision
counts (Eq. 5-6), and fetch full vectors only for survivors (pays `t_v`
each — Eq. 8's `rho * d` term).

Implementation notes (TPU adaptation — DESIGN.md §2):
 - The frontier is a fixed-size sorted beam (candidate set C and result set
   W of classic HNSW merged into one ef-wide array with `expanded` flags),
   so the whole search is a `jax.lax.while_loop` over static shapes and
   vmaps over a query batch.
 - `visited` is a bool[cap+1] array; masked scatter-writes land in the
   spare slot.
 - Edge-heat is recorded per hop as (node, fetched-mask) pairs so the
   caller can build the reordering heatmap (§3.4) without carrying a
   [cap, M] array through the loop.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import simhash
from repro.core.iostats import IOStats

INF = jnp.inf


class BeamResult(NamedTuple):
    ids: jax.Array       # int32[ef] — best ids found, ascending distance
    dists: jax.Array     # f32[ef]
    stats: IOStats
    heat_nodes: jax.Array   # int32[max_iters] — expanded node per hop (-1 pad)
    heat_mask: jax.Array    # bool[max_iters, M] — fetched slots per hop


def _rank_desc(score: jax.Array) -> jax.Array:
    """rank[i] = position of i when sorting score descending (stable)."""
    order = jnp.argsort(-score, stable=True)
    return jnp.argsort(order, stable=True)


def beam_search(
    q: jax.Array,                    # f32[dim]
    entry: jax.Array,                # int32[] — entry node id
    entry_dist: jax.Array,           # f32[] — distance(q, entry)
    adj_fn: Callable,                # id -> (row int32[M], n_probes int32)
    dist_fn: Callable,               # ids int32[M] -> f32[M] (inf for id<0)
    codes: jax.Array,                # uint32[cap, W] in-memory hash codes
    code_q: jax.Array,               # uint32[W]
    live: jax.Array,                 # bool[cap] — node liveness
    *,
    cap: int,
    ef: int,
    k: int,
    m_bits: int,
    eps: float,
    rho: float,                      # sampling ratio: fetch ceil(rho * |eligible|)
    max_iters: int,
    use_filter: bool,
    q_norm: jax.Array,               # f32[]
    mean_norm: jax.Array,            # f32[]
) -> BeamResult:
    """Single-query sampling-guided beam search.  vmap over queries."""
    M = adj_fn(jnp.int32(0))[0].shape[0]

    beam_ids = jnp.full((ef,), -1, jnp.int32).at[0].set(entry)
    beam_d = jnp.full((ef,), INF, jnp.float32).at[0].set(entry_dist)
    expanded = jnp.zeros((ef,), jnp.bool_)
    visited = jnp.zeros((cap + 1,), jnp.bool_).at[entry].set(True)
    heat_nodes = jnp.full((max_iters,), -1, jnp.int32)
    heat_mask = jnp.zeros((max_iters, M), jnp.bool_)
    stats = IOStats.zero()
    # entry vector was fetched to compute entry_dist
    stats = stats._replace(n_vec=stats.n_vec + 1)

    # frontier threshold: stop expanding once every candidate within the
    # 3k-th best has been visited.  k-exact termination prunes too hard on
    # delete-damaged graphs (measured: recall 0.96 -> 0.46 post-delete);
    # 3k keeps recall while cutting ~40% of the tail expansions.
    fidx = min(ef, 3 * k) - 1

    def cond(carry):
        it, beam_ids, beam_d, expanded, *_ = carry
        thresh = beam_d[fidx]
        frontier = (~expanded) & jnp.isfinite(beam_d) & (beam_d <= thresh)
        return (it < max_iters) & jnp.any(frontier)

    def body(carry):
        (it, beam_ids, beam_d, expanded, visited, stats,
         heat_nodes, heat_mask) = carry

        # -- pop the closest unexpanded candidate --------------------------
        frontier_d = jnp.where(expanded, INF, beam_d)
        slot = jnp.argmin(frontier_d)
        node = beam_ids[slot]
        expanded = expanded.at[slot].set(True)

        # -- adjacency read (t_n) ------------------------------------------
        row, n_probes = adj_fn(node)
        valid = (row >= 0) & (row <= cap - 1)
        safe = jnp.where(valid, row, cap)
        seen = visited[safe]
        alive = jnp.where(valid, live[jnp.minimum(safe, cap - 1)], False)
        eligible = valid & (~seen) & alive

        # -- SimHash prefilter (Eq. 5-6), in-memory ------------------------
        cand_codes = codes[jnp.minimum(safe, cap - 1)]
        cols = simhash.collisions(code_q[None, :], cand_codes, m_bits)
        delta_sq = beam_d[k - 1]
        if use_filter:
            cos = simhash.cos_from_l2(delta_sq, q_norm, mean_norm)
            thr = simhash.hoeffding_threshold(m_bits, eps, cos)
            pass_thr = (cols.astype(jnp.float32) >= thr) | ~jnp.isfinite(delta_sq)
        else:
            pass_thr = jnp.ones_like(eligible)
        pre_mask = eligible & pass_thr

        # -- sampling cap (Eq. 8): evaluate only rho of the survivors,
        #    keeping the most-colliding ones ------------------------------
        score = jnp.where(pre_mask, cols, -1)
        rank = _rank_desc(score)
        n_elig = jnp.sum(pre_mask)
        cap_dyn = jnp.ceil(rho * n_elig).astype(jnp.int32)
        fetch_mask = pre_mask & (rank < cap_dyn)
        fetch_ids = jnp.where(fetch_mask, row, -1)

        # -- vector fetches (t_v each) + distance --------------------------
        dists = dist_fn(fetch_ids)

        # -- bookkeeping ----------------------------------------------------
        visited = visited.at[jnp.where(fetch_mask, safe, cap)].set(True)
        n_fetch = jnp.sum(fetch_mask).astype(jnp.int32)
        stats = IOStats(
            n_adj=stats.n_adj + n_probes,
            n_vec=stats.n_vec + n_fetch,
            n_filtered=stats.n_filtered
            + jnp.sum(eligible).astype(jnp.int32) - n_fetch,
            n_hops=stats.n_hops + 1,
        )
        heat_nodes = heat_nodes.at[it].set(node)
        heat_mask = heat_mask.at[it].set(fetch_mask)

        # -- merge fetched neighbors into the beam --------------------------
        all_ids = jnp.concatenate([beam_ids, fetch_ids])
        all_d = jnp.concatenate([beam_d, dists])
        all_exp = jnp.concatenate([expanded, jnp.ones((M,), jnp.bool_)])
        # new candidates are unexpanded; mark masked ones expanded (inert)
        all_exp = all_exp.at[ef:].set(~fetch_mask)
        order = jnp.argsort(all_d, stable=True)[:ef]
        return (it + 1, all_ids[order], all_d[order], all_exp[order],
                visited, stats, heat_nodes, heat_mask)

    init = (jnp.int32(0), beam_ids, beam_d, expanded, visited, stats,
            heat_nodes, heat_mask)
    (_, beam_ids, beam_d, _, _, stats, heat_nodes, heat_mask) = \
        jax.lax.while_loop(cond, body, init)
    return BeamResult(beam_ids, beam_d, stats, heat_nodes, heat_mask)


def greedy_descent(
    q: jax.Array,
    entry: jax.Array,
    entry_dist: jax.Array,
    adj: jax.Array,                # int32[cap, M_up] — one upper layer
    vectors: jax.Array,            # f32[cap, dim]
    live: jax.Array,               # bool[cap]
    *,
    max_steps: int = 64,
) -> Tuple[jax.Array, jax.Array]:
    """Greedy routing in one memory-resident upper layer (Alg. 1 lines 6-8).

    Upper-layer nodes are <1% of the data and their vectors are cached in
    RAM (paper §3.2), so these reads cost no slow-tier I/O.
    """
    cap = adj.shape[0]

    def cond(c):
        step, _, _, moved = c
        return (step < max_steps) & moved

    def body(c):
        step, ep, d_ep, _ = c
        row = adj[ep]
        valid = (row >= 0) & live[jnp.clip(row, 0, cap - 1)]
        safe = jnp.clip(row, 0, cap - 1)
        diff = vectors[safe] - q[None, :]
        d = jnp.where(valid, jnp.sum(diff * diff, axis=-1), INF)
        j = jnp.argmin(d)
        better = d[j] < d_ep
        return (step + 1, jnp.where(better, row[j], ep),
                jnp.where(better, d[j], d_ep), better)

    _, ep, d_ep, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), entry, entry_dist, jnp.bool_(True)))
    return ep, d_ep
