"""SPFresh-like baseline: coarse clustering index with in-place updates.

Models the system the paper compares against (§2.3 / §5):
 - offline k-means partitions; centroids RAM-resident, posting lists on
   disk;
 - search probes the P closest centroids and scans *entire* postings —
   the coarse-partition recall ceiling the paper attributes to SPFresh
   (similar vectors split across cluster boundaries);
 - insert appends to the nearest posting *in place* (fast, one write);
   a posting that outgrows its page splits into two via 2-means (the
   LIRE-style local split), reassigning only that posting;
 - delete compacts the posting in place;
 - memory stays flat (centroids + page table only) — Fig. 6's stable
   curve.

Host-side implementation; distance blocks use the shared kernel wrapper.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.iostats import IOStats
from repro.kernels.l2_distance.ops import l2_distance


class SPFreshIndex:
    def __init__(self, dim: int, posting_cap: int = 128, n_probe: int = 8,
                 seed: int = 0):
        self.dim = dim
        self.posting_cap = posting_cap
        self.n_probe = n_probe
        self.rng = np.random.default_rng(seed)
        self.vectors = np.zeros((0, dim), np.float32)
        self.live = np.zeros((0,), bool)
        self.centroids = np.zeros((0, dim), np.float32)
        self.postings: list[list[int]] = []
        self.io_stats = IOStats.zero()
        self._zero()

    def _zero(self):
        self._n_adj = 0   # posting-list page reads/writes
        self._n_vec = 0   # vector fetches (posting scans)
        self._n_hops = 0

    def _flush(self):
        self.io_stats = self.io_stats + IOStats(
            jnp.asarray(self._n_adj, jnp.int32),
            jnp.asarray(self._n_vec, jnp.int32),
            jnp.asarray(0, jnp.int32),
            jnp.asarray(self._n_hops, jnp.int32))
        self._zero()

    # -- construction ---------------------------------------------------------

    @classmethod
    def build(cls, vectors, posting_cap: int = 128, n_probe: int = 8,
              seed: int = 0, kmeans_iters: int = 8) -> "SPFreshIndex":
        vectors = np.asarray(vectors, np.float32)
        n, dim = vectors.shape
        idx = cls(dim, posting_cap=posting_cap, n_probe=n_probe, seed=seed)
        idx.vectors = vectors.copy()
        idx.live = np.ones(n, bool)
        k = max(4, int(np.ceil(2 * n / posting_cap)))
        rng = np.random.default_rng(seed)
        cent = vectors[rng.choice(n, k, replace=False)].copy()
        for _ in range(kmeans_iters):
            d = np.asarray(l2_distance(jnp.asarray(vectors),
                                       jnp.asarray(cent)))
            asg = d.argmin(1)
            for c in range(k):
                sel = vectors[asg == c]
                if len(sel):
                    cent[c] = sel.mean(0)
        d = np.asarray(l2_distance(jnp.asarray(vectors), jnp.asarray(cent)))
        asg = d.argmin(1)
        idx.centroids = cent
        idx.postings = [list(np.flatnonzero(asg == c)) for c in range(k)]
        # enforce page capacity from the start
        for c in range(k):
            while len(idx.postings[c]) > idx.posting_cap:
                idx._split(c)
        return idx

    # -- search ---------------------------------------------------------------

    def search(self, queries, k: int = 10) -> Tuple[np.ndarray, np.ndarray]:
        queries = np.atleast_2d(np.asarray(queries, np.float32))
        ids = np.full((len(queries), k), -1, np.int64)
        dists = np.full((len(queries), k), np.inf, np.float32)
        cent = jnp.asarray(self.centroids)
        dc = np.asarray(l2_distance(jnp.asarray(queries), cent))
        for i, q in enumerate(queries):
            probe = np.argsort(dc[i])[: self.n_probe]
            cand: list[int] = []
            for c in probe:
                self._n_adj += 1            # posting page read
                cand.extend(self.postings[c])
            cand = [v for v in cand if self.live[v]]
            self._n_hops += 1
            if not cand:
                continue
            self._n_vec += len(cand)       # full posting scans
            dv = ((self.vectors[cand] - q) ** 2).sum(1)
            top = np.argsort(dv)[:k]
            ids[i, : len(top)] = np.asarray(cand)[top]
            dists[i, : len(top)] = dv[top]
        self._flush()
        return ids, dists

    # -- updates --------------------------------------------------------------

    def _nearest_centroid(self, x) -> int:
        d = ((self.centroids - x) ** 2).sum(1)
        return int(d.argmin())

    def _split(self, c: int) -> None:
        """LIRE-style local split: 2-means within one overflowing posting."""
        members = self.postings[c]
        pts = self.vectors[members]
        a, b = self.rng.choice(len(members), 2, replace=False)
        ca, cb = pts[a].copy(), pts[b].copy()
        for _ in range(4):
            da = ((pts - ca) ** 2).sum(1)
            db = ((pts - cb) ** 2).sum(1)
            to_a = da <= db
            if to_a.any():
                ca = pts[to_a].mean(0)
            if (~to_a).any():
                cb = pts[~to_a].mean(0)
        da = ((pts - ca) ** 2).sum(1)
        db = ((pts - cb) ** 2).sum(1)
        to_a = da <= db
        self.centroids[c] = ca
        self.centroids = np.vstack([self.centroids, cb[None]])
        self.postings[c] = [m for m, t in zip(members, to_a) if t]
        self.postings.append([m for m, t in zip(members, to_a) if not t])
        self._n_adj += 2                    # two page writes
        self._n_vec += len(members)         # reassignment scan

    def insert(self, x) -> int:
        x = np.asarray(x, np.float32)
        new_id = len(self.vectors)
        self.vectors = np.vstack([self.vectors, x[None]])
        self.live = np.append(self.live, True)
        c = self._nearest_centroid(x)
        self._n_vec += 1                    # centroid compare is in RAM;
        self._n_adj += 1                    # one in-place page append
        self.postings[c].append(new_id)
        if len(self.postings[c]) > self.posting_cap:
            self._split(c)
        self._flush()
        return new_id

    def delete(self, node_id: int) -> None:
        self.live[node_id] = False
        c = self._nearest_centroid(self.vectors[node_id])
        if node_id in self.postings[c]:
            self.postings[c].remove(node_id)
        else:                                # split may have moved it
            for p in self.postings:
                if node_id in p:
                    p.remove(node_id)
                    break
        self._n_adj += 1                    # in-place page rewrite
        self._flush()

    # -- accounting -----------------------------------------------------------

    def memory_bytes(self) -> int:
        """Centroids + page table are RAM-resident; postings are on disk."""
        page_table = len(self.postings) * 16
        return self.centroids.nbytes + page_table + self.live.nbytes

    @property
    def size(self) -> int:
        return int(self.live.sum())

    def reset_stats(self):
        self.io_stats = IOStats.zero()
