"""DiskANN-like baseline: static disk graph with degraded dynamic behavior.

Models the system the paper compares against (§2.2 / §5):
 - offline-built pruned proximity graph (alpha-pruned greedy graph a la
   Vamana), medoid entry point;
 - search = best-first beam with *exhaustive* neighbor evaluation — every
   neighbor of every visited node costs one slow-tier vector fetch (no
   sampling filter, Eq. 7's full cost);
 - inserts are appended: the new node gets out-edges from a search, but
   back-edges are written in-place into neighbors' fixed-size rows only
   when there is free room (no relayout; paper: "appended ... without being
   properly integrated"), and the delta graph + vectors stay RAM-resident
   until the next full rebuild (Fig. 6's memory growth);
 - deletes are tombstones only; the graph fragments over time (recall drop
   in the Delete-heavy workload, Fig. 5a).

Host-side implementation (numpy + the shared distance kernels): baselines
are benchmark substrates, not TPU targets.
"""

from __future__ import annotations

import heapq
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.iostats import IOStats
from repro.kernels.l2_distance.ops import l2_distance


class DiskANNIndex:
    def __init__(self, dim: int, M: int = 16, ef: int = 48,
                 alpha: float = 1.2, seed: int = 0):
        self.dim = dim
        self.M = M
        self.ef = ef
        self.alpha = alpha
        self.rng = np.random.default_rng(seed)
        self.vectors = np.zeros((0, dim), np.float32)
        self.adj: list[np.ndarray] = []
        self.live = np.zeros((0,), bool)
        self.entry = 0
        self.n_base = 0          # size at last full build (on-disk part)
        self.io_stats = IOStats.zero()
        self._zero_stats()

    def _zero_stats(self):
        self._n_adj = 0
        self._n_vec = 0
        self._n_hops = 0
        self._n_write = 0

    def _flush_stats(self):
        # in-place sector updates are read-modify-write: 2 I/Os per write
        # (the update-cost asymmetry the paper's LSM design removes)
        self.io_stats = self.io_stats + IOStats(
            jnp.asarray(self._n_adj + 2 * self._n_write, jnp.int32),
            jnp.asarray(self._n_vec, jnp.int32),
            jnp.asarray(0, jnp.int32),
            jnp.asarray(self._n_hops, jnp.int32))
        self._zero_stats()

    # -- construction ---------------------------------------------------------

    @classmethod
    def build(cls, vectors, M: int = 16, ef: int = 48, seed: int = 0,
              block: int = 1024) -> "DiskANNIndex":
        vectors = np.asarray(vectors, np.float32)
        n, dim = vectors.shape
        idx = cls(dim, M=M, ef=ef, seed=seed)
        idx.vectors = vectors.copy()
        idx.live = np.ones(n, bool)
        # alpha-pruned graph (offline, "free" — not counted as I/O).
        # Vamana starts from a random graph, so the candidate pool mixes
        # the 4M nearest with random long-range nodes — without the random
        # arm, well-separated clusters would disconnect.
        rng = np.random.default_rng(seed)
        rows = []
        for s in range(0, n, block):
            d = np.array(l2_distance(jnp.asarray(vectors[s:s + block]),
                                     jnp.asarray(vectors)))
            for r, row_d in enumerate(d):
                row_d[s + r] = np.inf
                near = np.argpartition(row_d, 4 * M)[: 4 * M]
                far = rng.integers(0, n, 2 * M)
                cand = np.unique(np.concatenate([near, far]))
                cand = cand[cand != s + r]
                cand = cand[np.argsort(row_d[cand])]
                rows.append(idx._alpha_prune(s + r, cand, row_d[cand]))
        idx.adj = rows
        idx.entry = int(np.argmin(
            ((vectors - vectors.mean(0)) ** 2).sum(1)))  # medoid
        idx.n_base = n
        return idx

    def _alpha_prune(self, node: int, cand: np.ndarray,
                     cand_d: np.ndarray) -> np.ndarray:
        """Vamana alpha-pruning: keep diverse close neighbors."""
        keep: list[int] = []
        for c, dc in zip(cand, cand_d):
            if len(keep) >= self.M:
                break
            ok = True
            for kpt in keep:
                d_ck = float(((self.vectors[c] - self.vectors[kpt]) ** 2).sum())
                if self.alpha * d_ck < dc:
                    ok = False
                    break
            if ok:
                keep.append(int(c))
        return np.asarray(keep, np.int64)

    # -- search ---------------------------------------------------------------

    def _beam(self, q: np.ndarray, ef: int) -> list[tuple[float, int]]:
        d0 = float(((q - self.vectors[self.entry]) ** 2).sum())
        self._n_vec += 1
        visited = {self.entry}
        cand = [(d0, self.entry)]
        result = [(-d0, self.entry)]
        while cand:
            d, u = heapq.heappop(cand)
            if result and d > -result[0][0] and len(result) >= ef:
                break
            self._n_adj += 1
            self._n_hops += 1
            nbrs = [v for v in self.adj[u] if v not in visited]
            visited.update(nbrs)
            if not nbrs:
                continue
            # exhaustive evaluation: every neighbor fetched (Eq. 7)
            dv = ((self.vectors[nbrs] - q) ** 2).sum(1)
            self._n_vec += len(nbrs)
            for v, dvv in zip(nbrs, dv):
                dvv = float(dvv)
                if len(result) < ef or dvv < -result[0][0]:
                    heapq.heappush(cand, (dvv, int(v)))
                    heapq.heappush(result, (-dvv, int(v)))
                    if len(result) > ef:
                        heapq.heappop(result)
        out = sorted((-nd, v) for nd, v in result)
        return out

    def search(self, queries, k: int = 10) -> Tuple[np.ndarray, np.ndarray]:
        queries = np.atleast_2d(np.asarray(queries, np.float32))
        ids = np.full((len(queries), k), -1, np.int64)
        dists = np.full((len(queries), k), np.inf, np.float32)
        for i, q in enumerate(queries):
            res = [(d, v) for d, v in self._beam(q, self.ef)
                   if self.live[v]][:k]
            for j, (d, v) in enumerate(res):
                ids[i, j] = v
                dists[i, j] = d
        self._flush_stats()
        return ids, dists

    # -- updates --------------------------------------------------------------

    def insert(self, x) -> int:
        x = np.asarray(x, np.float32)
        new_id = len(self.vectors)
        self.vectors = np.vstack([self.vectors, x[None]])
        self.live = np.append(self.live, True)
        res = self._beam(x, self.ef)
        nbrs = np.asarray([v for _, v in res[: 4 * self.M]], np.int64)
        nd = np.asarray([d for d, _ in res[: 4 * self.M]], np.float32)
        self.adj.append(self._alpha_prune(new_id, nbrs, nd))
        self._n_write += 1
        # back-edges only where a fixed-size row has room (in-place limit)
        for v in self.adj[new_id]:
            if len(self.adj[v]) < self.M:
                self.adj[v] = np.append(self.adj[v], new_id)
                self._n_write += 1
        self._flush_stats()
        return new_id

    def delete(self, node_id: int) -> None:
        # tombstone only — graph keeps routing through the corpse
        self.live[node_id] = False
        self._n_write += 1
        self._flush_stats()

    # -- accounting -----------------------------------------------------------

    def memory_bytes(self) -> int:
        """DiskANN keeps the full graph + update-delta vectors in RAM.

        The base vectors live on disk, but the graph rows and every vector
        inserted since the last rebuild are memory-resident (Fig. 6).
        """
        graph_bytes = sum(a.nbytes for a in self.adj)
        delta = len(self.vectors) - self.n_base
        delta_bytes = max(delta, 0) * self.dim * 4
        # in-memory quantized base vectors guide the search (PQ sketch ~ d bytes)
        pq_bytes = self.n_base * self.dim
        return graph_bytes + delta_bytes + pq_bytes + self.live.nbytes

    @property
    def size(self) -> int:
        return int(self.live.sum())

    def reset_stats(self):
        self.io_stats = IOStats.zero()
