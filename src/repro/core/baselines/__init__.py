"""Comparison systems from the paper's evaluation (§5.1).

- diskann.py — DiskANN-like static pruned-graph index: offline build, beam
  search with exhaustive neighbor evaluation, append-style inserts and
  tombstone deletes (the degradation modes §2.2 describes).
- spfresh.py — SPFresh-like clustering index: coarse IVF partitions,
  in-place posting updates with split maintenance (LIRE-style), probe-P
  search.

Both expose the same interface as LSMVecIndex (build/insert/delete/search
+ IOStats) so the Fig. 5-8 benchmarks drive all three identically.
"""

from repro.core.baselines.diskann import DiskANNIndex
from repro.core.baselines.spfresh import SPFreshIndex

__all__ = ["DiskANNIndex", "SPFreshIndex"]
