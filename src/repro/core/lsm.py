"""A functional, fixed-capacity LSM-tree for graph adjacency storage.

This is the JAX realization of the paper's storage engine (AsterDB role):
a log-structured merge tree whose *values* are fixed-degree adjacency rows
of the bottom HNSW layer.  All state lives in statically-shaped arrays so
every operation (put / get / delete / flush / compaction) is jit- and
vmap-compatible and *out-of-place by construction* — the paper's central
storage property (§3.2).

Layout
------
- memtable: unsorted (key, row, live) triples, newest at the highest slot.
  This is the "memory-resident buffer" that absorbs random updates.
- levels 0..L-1: sorted runs of exponentially growing capacity
  ("disk-resident" — on the TPU mapping this is HBM, see DESIGN.md §2).
  Padding keys are INT32_MAX so `searchsorted` lookups stay branch-free.
- tombstones: live == 0 rows; retained until they reach the last level,
  where compaction drops them (classic LSM semantics).

Newest-wins resolution order: memtable (highest slot first) > L0 > L1 > ...
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

PAD_KEY = jnp.iinfo(jnp.int32).max  # sorted-run padding; sorts after any real key
EMPTY = -1                          # padding inside adjacency rows


class LSMConfig(NamedTuple):
    """Static configuration of the tree. All fields are Python ints."""

    mem_cap: int = 256          # memtable capacity (entries)
    num_levels: int = 4         # number of sorted on-"disk" levels
    fanout: int = 8             # capacity ratio between adjacent levels
    row_width: int = 16         # fixed adjacency-row width (HNSW M)

    @property
    def level_caps(self) -> Tuple[int, ...]:
        return tuple(self.mem_cap * self.fanout ** (i + 1)
                     for i in range(self.num_levels))

    @property
    def total_cap(self) -> int:
        return self.mem_cap + sum(self.level_caps)


class LSMState(NamedTuple):
    """Pytree of arrays. `level_*` are tuples (one entry per level)."""

    mem_keys: jax.Array           # int32[mem_cap]
    mem_vals: jax.Array           # int32[mem_cap, row_width]
    mem_live: jax.Array           # int8[mem_cap]  1=value, 0=tombstone
    mem_count: jax.Array          # int32[]
    level_keys: Tuple[jax.Array, ...]   # int32[cap_l], sorted, PAD_KEY padded
    level_vals: Tuple[jax.Array, ...]   # int32[cap_l, row_width]
    level_live: Tuple[jax.Array, ...]   # int8[cap_l]
    level_counts: Tuple[jax.Array, ...]  # int32[]
    # monotone write counter; doubles as the compaction epoch for stats
    write_seq: jax.Array          # int32[]
    n_flushes: jax.Array          # int32[]
    n_compactions: jax.Array      # int32[]


def init(cfg: LSMConfig) -> LSMState:
    mk = jnp.full((cfg.mem_cap,), PAD_KEY, jnp.int32)
    mv = jnp.full((cfg.mem_cap, cfg.row_width), EMPTY, jnp.int32)
    ml = jnp.zeros((cfg.mem_cap,), jnp.int8)
    lk, lv, ll, lc = [], [], [], []
    for cap in cfg.level_caps:
        lk.append(jnp.full((cap,), PAD_KEY, jnp.int32))
        lv.append(jnp.full((cap, cfg.row_width), EMPTY, jnp.int32))
        ll.append(jnp.zeros((cap,), jnp.int8))
        lc.append(jnp.zeros((), jnp.int32))
    return LSMState(mk, mv, ml, jnp.zeros((), jnp.int32),
                    tuple(lk), tuple(lv), tuple(ll), tuple(lc),
                    jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
                    jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# merge machinery
# ---------------------------------------------------------------------------

def _merge_runs(keys_new, vals_new, live_new, count_new,
                keys_old, vals_old, live_old, count_old,
                out_cap: int, drop_tombstones: bool):
    """Merge two sorted-ish runs; `new` shadows `old` on key collisions.

    Both runs are PAD_KEY-padded.  Output is a PAD_KEY-padded sorted run of
    static size `out_cap`.  Returns (keys, vals, live, count, overflow).
    """
    keys = jnp.concatenate([keys_new, keys_old])
    vals = jnp.concatenate([vals_new, vals_old])
    live = jnp.concatenate([live_new, live_old])
    # priority: 0 for the newer run, 1 for the older — ties resolved newest-first
    prio = jnp.concatenate([
        jnp.zeros_like(keys_new), jnp.ones_like(keys_old)
    ])
    order = jnp.lexsort((prio, keys))
    keys, vals, live, prio = keys[order], vals[order], live[order], prio[order]

    dup = jnp.concatenate([jnp.array([False]), keys[1:] == keys[:-1]])
    drop = dup | (keys == PAD_KEY)
    if drop_tombstones:
        drop = drop | (live == 0)

    # stable compaction: keep-entries first, already key-sorted
    keep_order = jnp.argsort(drop.astype(jnp.int32), stable=True)
    keys, vals, live = keys[keep_order], vals[keep_order], live[keep_order]
    count = jnp.sum(~drop).astype(jnp.int32)

    n = keys.shape[0]
    idx = jnp.arange(n)
    keys = jnp.where(idx < count, keys, PAD_KEY)
    live = jnp.where(idx < count, live, 0).astype(jnp.int8)

    overflow = jnp.maximum(count - out_cap, 0)
    return keys[:out_cap], vals[:out_cap], live[:out_cap], \
        jnp.minimum(count, out_cap), overflow


def _sorted_memtable(cfg: LSMConfig, st: LSMState):
    """Sort the memtable into a run; duplicate keys resolved newest-wins."""
    idx = jnp.arange(cfg.mem_cap)
    keys = jnp.where(idx < st.mem_count, st.mem_keys, PAD_KEY)
    # newer writes sit at higher slots -> lower priority value must win;
    # use negative slot so lexsort puts the newest first within a key group
    prio = -idx
    order = jnp.lexsort((prio, keys))
    keys = keys[order]
    vals = st.mem_vals[order]
    live = st.mem_live[order]
    dup = jnp.concatenate([jnp.array([False]), keys[1:] == keys[:-1]])
    drop = dup | (keys == PAD_KEY)
    keep_order = jnp.argsort(drop.astype(jnp.int32), stable=True)
    keys, vals, live = keys[keep_order], vals[keep_order], live[keep_order]
    count = jnp.sum(~drop).astype(jnp.int32)
    keys = jnp.where(jnp.arange(cfg.mem_cap) < count, keys, PAD_KEY)
    return keys, vals, live.astype(jnp.int8), count


def flush(cfg: LSMConfig, st: LSMState) -> LSMState:
    """Flush memtable into L0, then cascade compactions down the levels."""
    run_k, run_v, run_l, _ = _sorted_memtable(cfg, st)

    lk = list(st.level_keys)
    lv = list(st.level_vals)
    ll = list(st.level_live)
    lc = list(st.level_counts)

    # memtable -> L0 (leveled compaction: merge directly)
    lk[0], lv[0], ll[0], lc[0], _ = _merge_runs(
        run_k, run_v, run_l, None,
        lk[0], lv[0], ll[0], lc[0],
        cfg.level_caps[0], drop_tombstones=(cfg.num_levels == 1))

    n_comp = st.n_compactions
    # cascade: if level i exceeds a fill threshold, merge it into i+1.
    # The merge runs under lax.cond, not a where-select: compactions are
    # rare (every ~fanout flushes) but the merge sorts the *target* level,
    # so computing it unconditionally would put an O(cap_{i+1} log) sort
    # on every flush — measured as the dominant cost of bulk update
    # batches before this gate.
    for i in range(cfg.num_levels - 1):
        thresh = int(cfg.level_caps[i] * 0.75)
        need = lc[i] > thresh
        last = (i + 1 == cfg.num_levels - 1)

        def do_merge(args, i=i, last=last):
            ki, vi, li, ci, kj, vj, lj, cj = args
            mk, mv_, ml_, mc, _ = _merge_runs(
                ki, vi, li, ci, kj, vj, lj, cj,
                cfg.level_caps[i + 1], drop_tombstones=last)
            return (jnp.full_like(ki, PAD_KEY), jnp.full_like(vi, EMPTY),
                    jnp.zeros_like(li), jnp.zeros_like(ci),
                    mk, mv_, ml_, mc)

        (lk[i], lv[i], ll[i], lc[i],
         lk[i + 1], lv[i + 1], ll[i + 1], lc[i + 1]) = jax.lax.cond(
            need, do_merge, lambda args: args,
            (lk[i], lv[i], ll[i], lc[i],
             lk[i + 1], lv[i + 1], ll[i + 1], lc[i + 1]))
        n_comp = n_comp + need.astype(jnp.int32)

    return st._replace(
        mem_keys=jnp.full_like(st.mem_keys, PAD_KEY),
        mem_vals=jnp.full_like(st.mem_vals, EMPTY),
        mem_live=jnp.zeros_like(st.mem_live),
        mem_count=jnp.zeros((), jnp.int32),
        level_keys=tuple(lk), level_vals=tuple(lv),
        level_live=tuple(ll), level_counts=tuple(lc),
        n_flushes=st.n_flushes + 1, n_compactions=n_comp)


# ---------------------------------------------------------------------------
# point operations
# ---------------------------------------------------------------------------

def _raw_put(cfg: LSMConfig, st: LSMState, key, val, live) -> LSMState:
    slot = st.mem_count
    st = st._replace(
        mem_keys=st.mem_keys.at[slot].set(key),
        mem_vals=st.mem_vals.at[slot].set(val),
        mem_live=st.mem_live.at[slot].set(live),
        mem_count=st.mem_count + 1,
        write_seq=st.write_seq + 1)
    return jax.lax.cond(st.mem_count >= cfg.mem_cap,
                        lambda s: flush(cfg, s), lambda s: s, st)


def put(cfg: LSMConfig, st: LSMState, key, val) -> LSMState:
    """Insert/overwrite `key` with adjacency row `val` (out-of-place)."""
    return _raw_put(cfg, st, jnp.asarray(key, jnp.int32),
                    jnp.asarray(val, jnp.int32), jnp.int8(1))


def delete(cfg: LSMConfig, st: LSMState, key) -> LSMState:
    """Write a tombstone for `key`."""
    tomb = jnp.full((cfg.row_width,), EMPTY, jnp.int32)
    return _raw_put(cfg, st, jnp.asarray(key, jnp.int32), tomb, jnp.int8(0))


def get(cfg: LSMConfig, st: LSMState, key):
    """Newest-wins point lookup.

    Returns (found: bool[], value: int32[row_width], n_probes: int32[]).
    `found` is False for missing keys *and* tombstoned keys.  `n_probes`
    models the paper's t_n unit: ONE disk read per lookup — production
    graph-LSMs (AsterDB) consult in-memory bloom filters/fences per run,
    so only the resolving tier touches disk.  (The raw tier count is the
    read amplification a filterless LSM would pay.)
    """
    key = jnp.asarray(key, jnp.int32)
    idx = jnp.arange(cfg.mem_cap)
    match = (st.mem_keys == key) & (idx < st.mem_count)
    any_mem = jnp.any(match)
    newest = jnp.argmax(jnp.where(match, idx, -1))
    mem_val = st.mem_vals[newest]
    mem_live = st.mem_live[newest] > 0

    found = any_mem
    alive = any_mem & mem_live
    val = jnp.where(any_mem, mem_val, EMPTY)

    for lvl in range(cfg.num_levels):
        keys = st.level_keys[lvl]
        pos = jnp.searchsorted(keys, key)
        pos_c = jnp.minimum(pos, keys.shape[0] - 1)
        hit = (keys[pos_c] == key)
        lvl_val = st.level_vals[lvl][pos_c]
        lvl_live = st.level_live[lvl][pos_c] > 0
        take = (~found) & hit
        val = jnp.where(take, lvl_val, val)
        alive = jnp.where(take, lvl_live, alive)
        found = found | hit

    # bloom-filter model: one resolving disk read per lookup
    probes = jnp.ones((), jnp.int32)
    return found & alive, val, probes


def get_batch(cfg: LSMConfig, st: LSMState, keys):
    """Vectorized `get` over a key vector."""
    return jax.vmap(lambda k: get(cfg, st, k))(keys)


def _append_run(cfg: LSMConfig, st: LSMState, keys, vals, lives) -> LSMState:
    """Append one batch (size <= mem_cap) to the memtable in a single
    vectorized scatter, flushing around it as needed."""
    b = keys.shape[0]
    # pre-flush so the whole batch fits ...
    st = jax.lax.cond(st.mem_count + b > cfg.mem_cap,
                      lambda s: flush(cfg, s), lambda s: s, st)
    slots = st.mem_count + jnp.arange(b)
    st = st._replace(
        mem_keys=st.mem_keys.at[slots].set(keys),
        mem_vals=st.mem_vals.at[slots].set(vals),
        mem_live=st.mem_live.at[slots].set(lives),
        mem_count=st.mem_count + b,
        write_seq=st.write_seq + b)
    # ... post-flush to restore the `mem_count < mem_cap` rest invariant
    # that point puts rely on for their append slot
    return jax.lax.cond(st.mem_count >= cfg.mem_cap,
                        lambda s: flush(cfg, s), lambda s: s, st)


def puts(cfg: LSMConfig, st: LSMState, keys, vals, lives=None) -> LSMState:
    """Bulk put: one vectorized memtable append per mem_cap-sized chunk.

    Semantically equivalent to sequential `put` calls — newest-wins is by
    slot order, so duplicate keys within the batch resolve to the later
    entry — but the flush check runs once per chunk instead of once per
    key: the tree flushes *before* a chunk that would overflow rather than
    exactly at the high-water mark.  `lives` (int8, default all-1) writes
    tombstones where 0, making this the bulk form of `delete` too.
    """
    keys = jnp.asarray(keys, jnp.int32)
    vals = jnp.asarray(vals, jnp.int32)
    if lives is None:
        lives = jnp.ones(keys.shape, jnp.int8)
    else:
        lives = jnp.asarray(lives, jnp.int8)
    for s in range(0, keys.shape[0], cfg.mem_cap):
        st = _append_run(cfg, st, keys[s:s + cfg.mem_cap],
                         vals[s:s + cfg.mem_cap], lives[s:s + cfg.mem_cap])
    return st


# ---------------------------------------------------------------------------
# maintenance / introspection
# ---------------------------------------------------------------------------

def bulk_load(cfg: LSMConfig, keys, vals) -> LSMState:
    """Build a tree whose last level holds `keys`/`vals` directly (sorted).

    Used by `bulk_build` index construction — the analogue of building the
    initial index offline and writing one big sorted run.
    """
    st = init(cfg)
    cap = cfg.level_caps[-1]
    n = keys.shape[0]
    if n > cap:
        raise ValueError(f"bulk_load of {n} rows exceeds last-level cap {cap}")
    order = jnp.argsort(keys)
    lk = jnp.full((cap,), PAD_KEY, jnp.int32).at[:n].set(keys[order])
    lv = jnp.full((cap, cfg.row_width), EMPTY, jnp.int32).at[:n].set(vals[order])
    ll = jnp.zeros((cap,), jnp.int8).at[:n].set(1)
    level_keys = st.level_keys[:-1] + (lk,)
    level_vals = st.level_vals[:-1] + (lv,)
    level_live = st.level_live[:-1] + (ll,)
    level_counts = st.level_counts[:-1] + (jnp.asarray(n, jnp.int32),)
    return st._replace(level_keys=level_keys, level_vals=level_vals,
                       level_live=level_live, level_counts=level_counts)


def rebuild_from_dense(cfg: LSMConfig, st: LSMState, keep: jax.Array,
                       rows: jax.Array) -> LSMState:
    """Rewrite the whole tree from a dense view in one pass (jit-friendly).

    `keep` (bool[id_space]) selects which ids survive; `rows` carries
    their final adjacency.  The result is a fresh tree whose last level
    holds exactly the kept rows (sorted, tombstone-free) — the
    StreamingMerge-style consolidation write path: instead of staging one
    put per repaired row plus one LSM tombstone per reclaimed id (and
    paying cascade merges for all of them), the consolidated graph is
    emitted as a single sorted run, like a major compaction that also
    drops the reclaimed ids.  Requires id_space <= last-level capacity
    (the HNSWConfig.lsm_cfg sizing invariant).  Write/flush counters are
    carried forward; the rewrite itself counts as one compaction.
    """
    id_space = keep.shape[0]
    cap = cfg.level_caps[-1]
    if id_space > cap:
        raise ValueError(
            f"rebuild_from_dense of {id_space} ids exceeds last-level "
            f"cap {cap}")
    keep = jnp.asarray(keep, jnp.bool_)
    ids = jnp.arange(id_space, dtype=jnp.int32)
    keys = jnp.where(keep, ids, PAD_KEY)
    order = jnp.argsort(keys)
    n_keep = jnp.sum(keep).astype(jnp.int32)
    lk = jnp.full((cap,), PAD_KEY, jnp.int32).at[:id_space].set(keys[order])
    lv = jnp.full((cap, cfg.row_width), EMPTY, jnp.int32).at[:id_space].set(
        jnp.asarray(rows, jnp.int32)[order])
    ll = jnp.zeros((cap,), jnp.int8).at[:id_space].set(
        keep[order].astype(jnp.int8))
    fresh = init(cfg)
    return fresh._replace(
        level_keys=fresh.level_keys[:-1] + (lk,),
        level_vals=fresh.level_vals[:-1] + (lv,),
        level_live=fresh.level_live[:-1] + (ll,),
        level_counts=fresh.level_counts[:-1] + (n_keep,),
        write_seq=st.write_seq + n_keep,
        n_flushes=st.n_flushes,
        n_compactions=st.n_compactions + 1)


def compact_all(cfg: LSMConfig, st: LSMState) -> LSMState:
    """Force-merge everything into the last level (major compaction)."""
    st = flush(cfg, st)
    lk = list(st.level_keys)
    lv = list(st.level_vals)
    ll = list(st.level_live)
    lc = list(st.level_counts)
    for i in range(cfg.num_levels - 1):
        last = (i + 1 == cfg.num_levels - 1)
        lk[i + 1], lv[i + 1], ll[i + 1], lc[i + 1], _ = _merge_runs(
            lk[i], lv[i], ll[i], lc[i],
            lk[i + 1], lv[i + 1], ll[i + 1], lc[i + 1],
            cfg.level_caps[i + 1], drop_tombstones=last)
        lk[i] = jnp.full_like(lk[i], PAD_KEY)
        lv[i] = jnp.full_like(lv[i], EMPTY)
        ll[i] = jnp.zeros_like(ll[i])
        lc[i] = jnp.zeros((), jnp.int32)
    return st._replace(level_keys=tuple(lk), level_vals=tuple(lv),
                       level_live=tuple(ll), level_counts=tuple(lc),
                       n_compactions=st.n_compactions + 1)


def remap_ids(cfg: LSMConfig, st: LSMState, perm_map) -> LSMState:
    """Rename node IDs everywhere: key k -> perm_map[k]; same for row entries.

    `perm_map` is int32[id_space]; EMPTY entries in rows are preserved.
    Used when connectivity-aware reordering relabels nodes at compaction
    (§3.4).  Runs a major compaction first so only one run needs remapping.
    """
    st = compact_all(cfg, st)
    perm_map = jnp.asarray(perm_map, jnp.int32)
    keys = st.level_keys[-1]
    vals = st.level_vals[-1]
    live = st.level_live[-1]
    count = st.level_counts[-1]

    is_real = keys != PAD_KEY
    safe_keys = jnp.where(is_real, keys, 0)
    new_keys = jnp.where(is_real, perm_map[safe_keys], PAD_KEY)
    safe_vals = jnp.where(vals >= 0, vals, 0)
    new_vals = jnp.where(vals >= 0, perm_map[safe_vals], vals)

    order = jnp.argsort(new_keys)
    level_keys = st.level_keys[:-1] + (new_keys[order],)
    level_vals = st.level_vals[:-1] + (new_vals[order],)
    level_live = st.level_live[:-1] + (live[order],)
    return st._replace(level_keys=level_keys, level_vals=level_vals,
                       level_live=level_live,
                       level_counts=st.level_counts[:-1] + (count,))


def resolve_all(cfg: LSMConfig, st: LSMState, id_space: int):
    """Dense newest-wins view: (live int8[id_space], rows int32[id_space, M]).

    The snapshot-resolve primitive: the serving read path and the batched
    update pipelines materialize the whole tree into this view once per
    write epoch, then serve adjacency by row gather.  Also used by
    compaction-time reordering and the property tests.  Cost
    O(id_space + total_cap), fully vectorized (the memtable is deduped
    newest-wins by `_sorted_memtable`, so one scatter applies it).
    """
    # spare slot at id_space absorbs padding/out-of-range writes
    live = jnp.zeros((id_space + 1,), jnp.int8)
    rows = jnp.full((id_space + 1, cfg.row_width), EMPTY, jnp.int32)
    # oldest level first, newest memtable last — later writes overwrite
    for lvl in range(cfg.num_levels - 1, -1, -1):
        keys = st.level_keys[lvl]
        ok = (keys != PAD_KEY) & (keys < id_space)
        safe = jnp.where(ok, keys, id_space)
        live = live.at[safe].set(st.level_live[lvl].astype(jnp.int8))
        rows = rows.at[safe].set(st.level_vals[lvl])
    run_k, run_v, run_l, _ = _sorted_memtable(cfg, st)
    ok = (run_k != PAD_KEY) & (run_k < id_space)
    safe = jnp.where(ok, run_k, id_space)
    live = live.at[safe].set(run_l)
    rows = rows.at[safe].set(run_v)
    return live[:id_space], rows[:id_space]


def snapshot_rows(cfg: LSMConfig, st: LSMState, id_space: int) -> jax.Array:
    """Resolve the tree into dense adjacency rows int32[id_space, M].

    Rows of absent/tombstoned keys come back all -1 — exactly the
    `found & alive`-masked contract of `get`, so a gather from this view
    is interchangeable with per-hop point lookups against a frozen tree.
    Consumers cache it per write epoch (`st.write_seq` is the version
    counter) and re-resolve after any put/delete/compaction.
    """
    live, rows = resolve_all(cfg, st, id_space)
    return jnp.where(live[:, None] > 0, rows, EMPTY)


def memory_bytes(cfg: LSMConfig) -> int:
    """Bytes the *memory-resident* part occupies (memtable only)."""
    return cfg.mem_cap * (4 + 4 * cfg.row_width + 1) + 64


def disk_bytes(cfg: LSMConfig) -> int:
    """Bytes the on-"disk" levels occupy at full capacity."""
    return sum(c * (4 + 4 * cfg.row_width + 1) for c in cfg.level_caps)


# ---------------------------------------------------------------------------
# durable state (de)hydration (DESIGN.md §11)
# ---------------------------------------------------------------------------

def dehydrate(state, prefix: str = ""):
    """Flatten a state NamedTuple into ``{path: array}`` with explicit,
    stable string keys ("mem_keys", "level_keys/0", ...).

    Works for any NamedTuple whose leaves are arrays, including nested
    NamedTuples and tuples-of-arrays — so `HNSWState` (which embeds an
    `LSMState` under `store`) flattens through the same walk.  The
    explicit keys are the checkpoint manifest's schema: they must stay
    byte-stable across releases for old checkpoints to restore.
    """
    out = {}

    def walk(node, path):
        if hasattr(node, "_fields"):
            for name in node._fields:
                walk(getattr(node, name), f"{path}/{name}" if path else name)
        elif isinstance(node, (tuple, list)):
            for i, item in enumerate(node):
                walk(item, f"{path}/{i}" if path else str(i))
        else:
            out[path] = node

    walk(state, prefix.rstrip("/"))
    return out


def hydrate(template, leaves, prefix: str = ""):
    """Inverse of :func:`dehydrate`: rebuild `template`'s structure from
    a flat ``{path: array}`` dict.  `template` supplies structure only
    (use ``init(cfg)``); every leaf value comes from `leaves`.  Raises
    KeyError if the dict is missing a path the structure requires —
    a truncated or mismatched checkpoint must not restore silently.
    """

    def walk(node, path):
        if hasattr(node, "_fields"):
            vals = (walk(getattr(node, n), f"{path}/{n}" if path else n)
                    for n in node._fields)
            return type(node)(*vals)
        if isinstance(node, (tuple, list)):
            return tuple(walk(item, f"{path}/{i}" if path else str(i))
                         for i, item in enumerate(node))
        return leaves[path]

    return walk(template, prefix.rstrip("/"))
