"""Connectivity-aware graph reordering (paper §3.4, Eq. 10-12).

Chooses a node permutation phi that maximizes the windowed edge score

    F(phi) = sum_{0 < phi(v) - phi(u) <= w} S(u, v)           (Eq. 12)

with the paper's sampling-driven score

    S(u, v) = S_s(u, v) + S_n(u, v) * (1 + lambda * heat(u, v))   (Eq. 11)

where S_s counts shared in-neighbors, S_n direct edges (Gorder, Eq. 10),
and `heat` is the traversal frequency of the edge collected by the
sampling-based query engine (the paper folds the query-hash Hamming
statistic into this runtime term; we use the accumulated per-edge fetch
counts the traversal records, which is the same query-driven signal).

The greedy window placement follows Gorder [Wei et al., SIGMOD'16]: place
the unplaced node with the largest score against the current w-window;
placing u credits +S to candidates sharing an in-neighbor with or adjacent
to u, and nodes sliding out of the window debit their contribution.

This is the *compaction-time* path (host-side, like the paper's offline
pass piggybacked on LSM compaction), so it is plain numpy rather than jit.
`apply_permutation` rewrites the index state arrays + LSM keys so that
physical id order matches the new layout.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import hnsw, lsm


def _csr_from_rows(rows: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """rows int32[n, M] (-1 padded) -> CSR (indptr, indices) of out-edges."""
    n = rows.shape[0]
    mask = rows >= 0
    deg = mask.sum(axis=1)
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(deg, out=indptr[1:])
    indices = rows[mask].astype(np.int64)
    return indptr, indices


def _reverse_csr(indptr, indices, n) -> Tuple[np.ndarray, np.ndarray]:
    rdeg = np.bincount(indices, minlength=n)
    rptr = np.zeros(n + 1, np.int64)
    np.cumsum(rdeg, out=rptr[1:])
    ridx = np.empty(indices.shape[0], np.int64)
    fill = rptr[:-1].copy()
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    for s, d in zip(src, indices):
        ridx[fill[d]] = s
        fill[d] += 1
    return rptr, ridx


def gorder_permutation(rows: np.ndarray, heat: np.ndarray | None = None,
                       *, window: int = 8, lam: float = 1.0,
                       live: np.ndarray | None = None) -> np.ndarray:
    """Greedy windowed placement maximizing Eq. 12.

    rows: int32[n, M] adjacency (-1 padded); heat: int32[n, M] edge fetch
    counts aligned with `rows`; returns perm int32[n] with perm[old] = new.
    Dead nodes (live == False) are placed last, preserving relative order.
    """
    n, m = rows.shape
    rows = np.asarray(rows)
    live = np.ones(n, bool) if live is None else np.asarray(live).astype(bool)
    heat = np.zeros_like(rows) if heat is None else np.asarray(heat)

    # per-edge weight for the S_n term: 1 + lam * normalized heat
    hmax = max(float(heat.max()), 1.0)
    w_edge = np.where(rows >= 0, 1.0 + lam * heat / hmax, 0.0)

    indptr, indices = _csr_from_rows(np.where(live[:, None], rows, -1))
    edge_w = w_edge[np.where(live[:, None], rows, -1) >= 0]
    rptr, ridx = _reverse_csr(indptr, indices, n)

    gain = np.zeros(n, np.float64)
    placed = np.zeros(n, bool)
    order: list[int] = []
    window_nodes: list[int] = []

    def neighbors(u):
        return indices[indptr[u]:indptr[u + 1]], edge_w[indptr[u]:indptr[u + 1]]

    def in_neighbors(u):
        return ridx[rptr[u]:rptr[u + 1]]

    def credit(u, sign):
        # S_n: direct out- and in-edges of u (weighted by heat)
        nbr, wts = neighbors(u)
        np.add.at(gain, nbr, sign * wts)
        inn = in_neighbors(u)
        np.add.at(gain, inn, sign * 1.0)
        # S_s: nodes sharing an in-neighbor with u
        for w_ in inn:
            sib, _ = neighbors(w_)
            np.add.at(gain, sib, sign * 1.0)

    live_ids = np.flatnonzero(live)
    dead_ids = np.flatnonzero(~live)
    if live_ids.size:
        # seed: highest-degree live node
        deg = np.diff(indptr)
        start = int(live_ids[np.argmax(deg[live_ids])])
        order.append(start)
        placed[start] = True
        window_nodes.append(start)
        credit(start, +1.0)
        for _ in range(live_ids.size - 1):
            masked = np.where(placed | ~live, -np.inf, gain)
            u = int(np.argmax(masked))
            if not np.isfinite(masked[u]):
                u = int(live_ids[~placed[live_ids]][0] if
                        (~placed[live_ids]).any() else -1)
            order.append(u)
            placed[u] = True
            window_nodes.append(u)
            credit(u, +1.0)
            if len(window_nodes) > window:
                old = window_nodes.pop(0)
                credit(old, -1.0)
    order.extend(dead_ids.tolist())   # one batched conversion, not per-id

    perm = np.empty(n, np.int32)
    perm[np.asarray(order, np.int64)] = np.arange(n, dtype=np.int32)
    return perm


def layout_score(rows: np.ndarray, perm: np.ndarray,
                 heat: np.ndarray | None = None, *, window: int = 8,
                 lam: float = 1.0) -> float:
    """Evaluate Eq. 12 for a layout: windowed sum of edge scores."""
    rows = np.asarray(rows)
    n, m = rows.shape
    heat = np.zeros_like(rows) if heat is None else np.asarray(heat)
    hmax = max(float(heat.max()), 1.0)
    src = np.repeat(np.arange(n), m)
    dst = rows.reshape(-1)
    wts = (1.0 + lam * heat.reshape(-1) / hmax)
    ok = dst >= 0
    src, dst, wts = src[ok], dst[ok], wts[ok]
    gap = np.abs(perm[dst].astype(np.int64) - perm[src].astype(np.int64))
    return float(np.sum(wts * ((gap > 0) & (gap <= window))))


def block_io_count(fetch_sequences: list[np.ndarray], perm: np.ndarray,
                   *, block_rows: int = 8) -> int:
    """I/O blocks touched if vectors are laid out by `perm` (Fig. 4 metric).

    Each element of `fetch_sequences` is the array of node ids fetched in
    one traversal hop; ids in the same physical block cost one read.
    """
    total = 0
    for ids in fetch_sequences:
        if ids.size == 0:
            continue
        blocks = np.unique(perm[ids] // block_rows)
        total += blocks.size
    return int(total)


def apply_permutation(cfg: hnsw.HNSWConfig, state: hnsw.HNSWState,
                      perm: np.ndarray) -> hnsw.HNSWState:
    """Physically relayout the index: node id k moves to perm[k].

    Applied during a major LSM compaction (the paper aligns reordering with
    compaction so the rewrite is piggybacked on work the LSM does anyway).
    """
    n = perm.shape[0]
    full = np.arange(cfg.cap, dtype=np.int32)
    full[:n] = perm
    perm_j = jnp.asarray(full)
    inv = jnp.argsort(perm_j).astype(jnp.int32)  # inv[new] = old

    def remap_rows(rows):
        safe = jnp.maximum(rows, 0)
        return jnp.where(rows >= 0, perm_j[safe], rows)

    store = lsm.remap_ids(cfg.lsm_cfg, state.store, perm_j)
    upper = remap_rows(state.upper_adj)[:, inv, :]
    return state._replace(
        vectors=state.vectors[inv],
        norms=state.norms[inv],
        codes=state.codes[inv],
        levels=state.levels[inv],
        upper_adj=upper,
        store=store,
        entry=jnp.where(state.entry >= 0,
                        perm_j[jnp.maximum(state.entry, 0)], state.entry),
        heat=state.heat[inv],
        tombstone=state.tombstone[inv],
        # tier lanes ride the same physical relayout (tier_heat is
        # per-node policy state; qvecs/qscale stay aligned with vectors)
        hot=state.hot[inv],
        qvecs=state.qvecs[inv],
        qscale=state.qscale[inv],
        tier_heat=state.tier_heat[inv],
    )
