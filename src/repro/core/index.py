"""LSMVecIndex — the public API of the paper's system.

Wraps the functional core (hnsw/lsm/traversal/simhash/reorder) behind the
interface a vector database exposes: build, insert, delete, search,
maintenance (reorder/compact), plus the I/O statistics and memory
accounting the paper's experiments report.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.core import hnsw, iostats, lsm, reorder
from repro.core.backend import (
    BackendStats,
    MaintenanceReport,
    MemoryBreakdown,
    SearchParams,
    SearchResult,
    ShardStats,
    UpdateResult,
)
from repro.core.iostats import CostModel, IOStats
from repro.core.sentinel import declared_sync
from repro.kernels.l2_distance.ops import l2_distance
from repro.tier import policy as tier_policy


def brute_force_knn(vectors: jax.Array, queries: jax.Array, k: int,
                    live: Optional[jax.Array] = None,
                    block: int = 1024) -> np.ndarray:
    """Exact ground-truth ids [Q, k] (for Recall K@K evaluation)."""
    outs = []
    q = jnp.asarray(queries)
    for s in range(0, q.shape[0], block):
        d = l2_distance(q[s:s + block], vectors)
        if live is not None:
            d = jnp.where(live[None, :], d, jnp.inf)
        _, idx = jax.lax.top_k(-d, k)
        outs.append(np.asarray(idx))
    return np.concatenate(outs, axis=0)


def recall_at_k(found_ids: np.ndarray, true_ids: np.ndarray,
                block: int = 4096) -> float:
    """Recall K@K (Eq. 3): |found ∩ truth| / K averaged over queries.

    One broadcast membership test per block of queries instead of a
    per-query Python set loop (O(Q·k) host work that dominated eval at
    large Q).  Counting from the truth side — a truth id is hit if it
    appears anywhere in the found row — matches set-intersection
    semantics exactly: truth ids are distinct, and -1 pads in `found`
    never match.
    """
    f = np.asarray(found_ids)
    t = np.asarray(true_ids)
    k = t.shape[1]
    f = f[:, :k]
    hits = 0
    for s in range(0, len(t), block):
        fb, tb = f[s:s + block], t[s:s + block]
        hits += int((fb[:, :, None] == tb[:, None, :]).any(axis=1).sum())
    return hits / (k * len(t))


class DispatchedSearch:
    """`SearchHandle` over raw device arrays (DESIGN.md §13).

    Holds the jit outputs without forcing a host sync; `collect()` is
    the one blocking point (`np.asarray`) and slices the padded batch
    back to `[nq, k]` host-side.
    """

    __slots__ = ("_ids", "_dists", "_nq", "_k")

    def __init__(self, ids, dists, nq: int, k: int):
        self._ids, self._dists = ids, dists
        self._nq, self._k = nq, k

    def is_ready(self) -> bool:
        try:
            return bool(self._ids.is_ready() and self._dists.is_ready())
        except AttributeError:      # already a host array
            return True

    def collect(self) -> SearchResult:
        with declared_sync("search result materialization"):
            # sync-ok: collect() is the protocol's declared result sync point
            return SearchResult(
                ids=np.asarray(self._ids)[:self._nq, :self._k],
                dists=np.asarray(self._dists)[:self._nq, :self._k])


class LSMVecIndex:
    """Dynamic disk-based vector index (LSM-VEC).

    The single-device `VectorBackend` implementation (DESIGN.md §10):
    everything above the functional core programs against the protocol
    in `core/backend.py`, for which this class is the reference.
    """

    #: below this many live nodes, insert_batch falls back to per-item
    #: inserts: the batched pipeline searches the pre-batch graph snapshot,
    #: which must exist for the new nodes to link into (DESIGN.md §4)
    BATCH_MIN_GRAPH = 64

    def __init__(self, cfg: hnsw.HNSWConfig, seed: int = 0,
                 state: Optional[hnsw.HNSWState] = None):
        self.cfg = cfg
        self._seed = seed
        self.state = state if state is not None else hnsw.init(
            cfg, jax.random.key(seed))
        # commit the state to its device: committedness is part of the
        # jit executable cache key, and the overlapped-repair cutover
        # hands back a committed state — pinning up front means the
        # first repair never invalidates warmed-up executables
        self.state = jax.device_put(self.state, self._home_device())
        self._rng = jax.random.key(seed + 1)
        self.io_stats = IOStats.zero()
        # host mirror of state.count: id allocation and maintenance never
        # pay a device sync on the hot path
        self._count = int(self.state.count)
        # write-epoch counter + cached dense read snapshot (DESIGN.md §8):
        # every mutation bumps _version; the snapshot is lazily re-resolved
        # when a snapshot read observes a version mismatch
        self._version = 0
        self._snap = None
        self._snap_version = -1
        #: incremental snapshot patches applied (vs full re-resolves)
        self.snap_patches = 0
        # overlapped consolidation (DESIGN.md §13): (new_state, io, n)
        # while a double-buffered repair is in flight, plus the report of
        # the last repair finished by a write barrier, awaiting claim
        self._pending_repair = None
        self._done_report: Optional[MaintenanceReport] = None

        cfg_ = self.cfg

        @functools.partial(jax.jit, donate_argnums=0)
        def _insert(state, x, key):
            return hnsw.insert(cfg_, state, x, key)

        @functools.partial(jax.jit, donate_argnums=0)
        def _insert_batch(state, xs, keys, valid):
            return hnsw.insert_batch(cfg_, state, xs, keys, valid=valid)

        @functools.partial(jax.jit, donate_argnums=0)
        def _delete(state, i):
            return hnsw.delete(cfg_, state, i)

        @functools.partial(jax.jit, donate_argnums=0)
        def _delete_batch(state, ids):
            return hnsw.delete_batch(cfg_, state, ids)

        @functools.partial(jax.jit, donate_argnums=(0, 4))
        def _insert_batch_snap(state, xs, keys, valid, snap):
            state, st, (orows, ovalid) = hnsw.insert_batch(
                cfg_, state, xs, keys, valid=valid, return_overlay=True)
            snap = jnp.where(ovalid[:cfg_.cap, None], orows[:cfg_.cap], snap)
            return state, st, snap

        @functools.partial(jax.jit, donate_argnums=0)
        def _consolidate(state):
            return hnsw.consolidate(cfg_, state)

        # non-donated: the live state keeps serving queries while the
        # repair computes against it (double-buffer, DESIGN.md §13)
        @jax.jit
        def _consolidate_bg(state):
            return hnsw.consolidate(cfg_, state)

        # `record_heat` is static: False drops the scatter-add (and, on
        # the fused path, the loop's heat carries) from the trace —
        # callers that never apply heat don't pay for recording it
        @functools.partial(jax.jit, static_argnames=("rho", "use_filter",
                                                     "ef", "n_expand",
                                                     "record_heat"))
        def _search(state, qs, rho, use_filter, ef, n_expand,
                    record_heat=True):
            res = hnsw.search_batch(cfg_, state, qs, rho=rho,
                                    use_filter=use_filter, ef=ef,
                                    n_expand=n_expand)
            heat_delta = _heat_delta(state, res) if record_heat \
                else jnp.zeros_like(state.heat)
            return res, heat_delta

        @functools.partial(jax.jit, static_argnames=("rho", "use_filter",
                                                     "ef", "n_expand",
                                                     "record_heat"))
        def _search_snap(state, qs, valid, snap, rho, use_filter, ef,
                         n_expand, record_heat=True):
            res = hnsw.search_batch(cfg_, state, qs, rho=rho,
                                    use_filter=use_filter, ef=ef,
                                    n_expand=n_expand, snapshot=snap,
                                    active=valid,
                                    record_heat=record_heat)
            heat_delta = _heat_delta(state, res) if record_heat \
                else jnp.zeros_like(state.heat)
            return res, heat_delta

        @jax.jit
        def _resolve(state):
            return lsm.snapshot_rows(cfg_.lsm_cfg, state.store, cfg_.cap)

        def _heat_delta(state, res):
            nodes = res.heat_nodes.reshape(-1)
            mask = res.heat_mask.reshape(-1, cfg_.M)
            safe = jnp.maximum(nodes, 0)
            contrib = jnp.where((nodes >= 0)[:, None], mask, False)
            return jnp.zeros_like(state.heat).at[safe].add(
                contrib.astype(jnp.int32))

        self._insert_fn = _insert
        self._insert_batch_fn = _insert_batch
        self._insert_batch_snap_fn = _insert_batch_snap
        self._delete_fn = _delete
        self._delete_batch_fn = _delete_batch
        self._consolidate_fn = _consolidate
        self._consolidate_bg_fn = _consolidate_bg
        self._search_fn = _search
        self._search_snap_fn = _search_snap
        self._resolve_fn = _resolve

    # -- construction ---------------------------------------------------------

    @classmethod
    def build(cls, cfg: hnsw.HNSWConfig, vectors: jax.Array,
              seed: int = 0) -> "LSMVecIndex":
        idx = cls(cfg, seed=seed, state=hnsw.bulk_build(
            cfg, jnp.asarray(vectors, jnp.float32), jax.random.key(seed)))
        return idx

    # -- updates --------------------------------------------------------------

    def _barrier_repair(self) -> None:
        """Write barrier: force-finish any in-flight overlapped repair.

        Every mutation calls this first, so a consolidation cutover
        always lands on a write-batch boundary — the invariant that
        makes WAL replay deterministic (DESIGN.md §13).  The finished
        report is stashed for the next `poll_maintain` to claim."""
        if self._pending_repair is not None:
            self._finish_repair()

    def _finish_repair(self) -> None:
        """Atomic cutover to the repaired state.  Edge heat recorded by
        queries that served *during* the repair is dropped with the old
        state: consolidate zeroes heat on every changed row anyway and
        heat is a purely advisory signal (tier/reorder triggers)."""
        new_state, st, n = self._pending_repair
        self._pending_repair = None
        self.state = new_state
        self.io_stats = self.io_stats + st
        self._version += 1
        self._done_report = MaintenanceReport(
            op="consolidate", applied=True, reclaimed=n,
            detail={"overlapped": True})

    def insert(self, x) -> int:
        """Insert one vector; returns its id."""
        self._barrier_repair()
        self._rng, sub = jax.random.split(self._rng)
        new_id = self._count
        self.state, st = self._insert_fn(
            self.state, jnp.asarray(x, jnp.float32), sub)
        self._count += 1
        self._version += 1
        self.io_stats = self.io_stats + st
        return new_id

    def insert_batch(self, xs, *,
                     pad_to: Optional[int] = None) -> UpdateResult:
        """Insert a batch in one jit'd device call; returns the new ids
        as an `UpdateResult`.

        The whole batch is dispatched as a single donated-buffer
        `hnsw.insert_batch` (vmapped candidate search + scanned writes)
        with zero per-item host syncs.  While the graph is smaller than
        BATCH_MIN_GRAPH the leading items fall back to per-item inserts so
        the batch pipeline always has a snapshot to search.

        `pad_to` is the fixed-shape dispatch hook (DESIGN.md §8): the
        batch is zero-padded to that width with a validity prefix mask, so
        every call reuses one traced shape regardless of how many items a
        serving micro-batch actually carries (batches larger than `pad_to`
        chunk).  Without it the jit specializes on the exact batch length.

        When the cached read snapshot is fresh, the batch routes through
        the overlay-returning variant and *patches* the snapshot in the
        same jit (one `jnp.where` over the staged write set) instead of
        invalidating it — the next query batch skips the full
        `lsm.resolve_all` re-resolve (DESIGN.md §13).
        """
        self._barrier_repair()
        xs = np.asarray(xs, np.float32)
        if xs.size == 0:
            return UpdateResult(ids=np.zeros((0,), np.int64), n_applied=0)
        xs = np.atleast_2d(xs)
        # guard on *live* size, not allocated ids: a graph emptied by
        # deletes must re-seed per-item too (one scalar sync per batch
        # call, never per item)
        n_seed = max(0, min(len(xs), self.BATCH_MIN_GRAPH - self.size))
        ids = [self.insert(x) for x in xs[:n_seed]]
        rest = xs[n_seed:]
        if len(rest) == 0:
            return UpdateResult(ids=np.asarray(ids, np.int64),
                                n_applied=len(ids))
        patch = self._snap is not None and self._snap_version == self._version
        width = pad_to if pad_to else len(rest)
        for s in range(0, len(rest), width):
            chunk = rest[s:s + width]
            n = len(chunk)
            padded = np.zeros((width, rest.shape[1]), np.float32)
            padded[:n] = chunk
            valid = np.arange(width) < n
            self._rng, sub = jax.random.split(self._rng)
            keys = jax.random.split(sub, width)
            ids.extend(range(self._count, self._count + n))
            if patch:
                self.state, st, self._snap = self._insert_batch_snap_fn(
                    self.state, jnp.asarray(padded), keys,
                    jnp.asarray(valid), self._snap)
                self.snap_patches += 1
            else:
                self.state, st = self._insert_batch_fn(
                    self.state, jnp.asarray(padded), keys, jnp.asarray(valid))
            self._count += n
            self._version += 1
            if patch:
                self._snap_version = self._version
            self.io_stats = self.io_stats + st
        return UpdateResult(ids=np.asarray(ids, np.int64),
                            n_applied=len(ids))

    def delete(self, node_id: int) -> None:
        """Delete one id.  Under `cfg.lazy_delete` (default) this only
        sets the tombstone bit — no LSM write, so the cached read
        snapshot stays valid (the returnable mask, not the snapshot,
        hides the node)."""
        self._barrier_repair()
        self.state, st = self._delete_fn(self.state, jnp.asarray(node_id))
        if not self.cfg.lazy_delete:
            self._version += 1
        self.io_stats = self.io_stats + st

    def delete_batch(self, ids, *,
                     pad_to: Optional[int] = None) -> UpdateResult:
        """Delete a batch of ids in one jit'd device call.

        `pad_to` pads the id vector with -1 (masked no-ops in
        `hnsw.delete_batch`) so serving micro-batches of any occupancy
        dispatch through one traced shape; larger batches chunk.  Lazy
        deletes leave the read snapshot valid (tombstone-bit only).
        """
        self._barrier_repair()
        ids = np.atleast_1d(np.asarray(ids, np.int32))
        if len(ids) == 0:
            return UpdateResult(ids=np.zeros((0,), np.int64), n_applied=0)
        width = pad_to or len(ids)
        for s in range(0, len(ids), width):
            chunk = ids[s:s + width]
            padded = np.full((width,), -1, np.int32)
            padded[:len(chunk)] = chunk
            self.state, st = self._delete_batch_fn(
                self.state, jnp.asarray(padded))
            if not self.cfg.lazy_delete:
                self._version += 1
            self.io_stats = self.io_stats + st
        return UpdateResult(ids=ids.astype(np.int64),
                            n_applied=int((ids >= 0).sum()))

    # -- search ---------------------------------------------------------------

    def dispatch_search(self, queries, k: Optional[int] = None, *,
                        params: Optional[SearchParams] = None
                        ) -> DispatchedSearch:
        """Enqueue a batched ANN search; no host sync (DESIGN.md §13).

        queries [B, dim] -> `DispatchedSearch` whose `collect()` blocks
        on the device arrays and returns the final `SearchResult`
        (ids [B, k], dists [B, k]).  All knobs ride in `params`
        (`SearchParams`); `None` fields resolve from the config here —
        the single defaults site.

        `params.n_expand` > 1 expands that many frontier nodes per beam
        iteration (multi-expansion); 1 is the classic exact-parity path.
        `params.use_snapshot` serves bottom-layer adjacency from the
        cached dense LSM view (`snapshot()`), re-resolved (or overlay-
        patched) only after writes — identical results, but each hop is
        a row gather instead of an LSM probe.  `params.pad_to` zero-pads
        the query batch to a fixed width with masked lanes so every call
        shares one traced shape (implies the snapshot path, which is
        where the mask-aware kernels live).
        """
        p = (params or SearchParams()).resolve(self.cfg)
        k = k or self.cfg.k
        qs_np = np.atleast_2d(np.asarray(queries, np.float32))
        nq = len(qs_np)
        if p.use_snapshot or p.pad_to is not None:
            width = p.pad_to if p.pad_to else nq
            if nq > width:
                raise ValueError(f"batch {nq} exceeds pad width {width}")
            padded = np.zeros((width, qs_np.shape[1]), np.float32)
            padded[:nq] = qs_np
            valid = np.arange(width) < nq
            res, heat_delta = self._search_snap_fn(
                self.state, jnp.asarray(padded), jnp.asarray(valid),
                self.snapshot(), p.rho, p.use_filter, p.ef, p.n_expand,
                p.record_heat)
        else:
            res, heat_delta = self._search_fn(
                self.state, jnp.asarray(qs_np), p.rho, p.use_filter,
                p.ef, p.n_expand, p.record_heat)
        if p.record_heat:
            self.state = self.state._replace(
                heat=self.state.heat + heat_delta)
        batch_stats = jax.tree.map(lambda a: jnp.sum(a), res.stats)
        self.io_stats = self.io_stats + IOStats(*batch_stats)
        # slicing happens host-side at collect(): device slicing would
        # re-specialize on every distinct residual batch length
        return DispatchedSearch(res.ids, res.dists, nq, k)

    def search(self, queries, k: Optional[int] = None, *,
               params: Optional[SearchParams] = None) -> SearchResult:
        """Batched ANN search: dispatch + collect in one call."""
        return self.dispatch_search(queries, k, params=params).collect()

    # -- maintenance ----------------------------------------------------------

    def maintain(self, op: str, **params) -> MaintenanceReport:
        """Uniform maintenance entry point (`VectorBackend` protocol).

        ops: "consolidate" (`ratio=`), "compact", "reorder"
        (`window=`, `lam=`), "tier" (`policy=`).  The legacy per-op
        methods remain as thin deprecated wrappers around the same
        implementations.
        """
        if op == "consolidate":
            # a repair finished by a write barrier (or still in flight)
            # IS this consolidation — claim it instead of re-running
            rep = self.poll_maintain(block=True)
            if rep is not None and rep.applied:
                return rep
            n = self.consolidate(ratio=params.get("ratio"))
            return MaintenanceReport(op=op, applied=n > 0, reclaimed=n)
        if op == "compact":
            self.compact()
            return MaintenanceReport(op=op, applied=True)
        if op == "reorder":
            perm = self.reorder(window=int(params.get("window", 8)),
                                lam=float(params.get("lam", 1.0)))
            return MaintenanceReport(op=op, applied=True, perm=perm)
        if op == "tier":
            moved = self.tier_maintain(params["policy"])
            return MaintenanceReport(
                op=op, applied=(moved["demoted"] + moved["promoted"]) > 0,
                demoted=moved["demoted"], promoted=moved["promoted"])
        raise ValueError(f"unknown maintenance op {op!r}")

    def begin_maintain(self, op: str, **params) -> bool:
        """Start an overlapped consolidation (DESIGN.md §13).

        Runs the `lax.map` splice repair against a *non-donated* clone
        of the live state: queries keep dispatching on `self.state`
        while the repair computes.  Returns True iff a repair was
        started (False: unsupported op, one already in flight, or the
        tombstone-ratio trigger declined).  Cutover happens in
        `poll_maintain` — or earlier, at the next mutation's write
        barrier.
        """
        if op != "consolidate" or self._pending_repair is not None:
            return False
        with declared_sync("maintenance cadence scalar"):
            # sync-ok: scalar sync up front — maintenance cadence, not hot path
            n = int(self.state.n_tombstones)
        if n == 0:
            return False
        ratio = params.get("ratio")
        if ratio is not None and n / max(self.size + n, 1) < ratio:
            return False
        spare = self._spare_device()
        if spare is not None:
            # run the repair on a spare device so it never serializes
            # the serving device's execution stream: queries dispatched
            # during the repair start immediately instead of queueing
            # behind a cap-sized rebuild.  The repaired state rides a
            # device-to-device transfer home, enqueued behind the
            # compute — cutover still just swaps the pointer.
            src = jax.device_put(self.state, spare)
            out = self._consolidate_bg_fn(src)
            new_state, st = jax.device_put(out, self._home_device())
        else:
            new_state, st = self._consolidate_bg_fn(self.state)
        self._pending_repair = (new_state, st, n)
        return True

    def _home_device(self):
        """The device the live state is committed to."""
        try:
            return next(iter(self.state.count.devices()))
        except AttributeError:      # pragma: no cover - old jax
            return jax.local_devices()[0]

    def _spare_device(self):
        """A local device other than the home device, if one exists —
        where overlapped repairs run (DESIGN.md §13).  Deterministic
        (next device in the local ring) so the repair executable
        compiles exactly once per index."""
        devs = jax.local_devices()
        if len(devs) < 2:
            return None
        home = self._home_device()
        try:
            i = devs.index(home)
        except ValueError:
            return None
        return devs[(i + 1) % len(devs)]

    def poll_maintain(self, *, block: bool = False
                      ) -> Optional[MaintenanceReport]:
        """Cut over to a finished repair and return its report.

        Non-blocking by default: returns None while the repair's device
        work is still running (polled via `jax.Array.is_ready`).  Also
        returns (and clears) the report of a repair that a write
        barrier already finished.  `block=True` forces the cutover.
        """
        if self._pending_repair is not None:
            new_state = self._pending_repair[0]
            ready = getattr(new_state.count, "is_ready", lambda: True)()
            if not (block or ready):
                return None
            self._finish_repair()
        rep, self._done_report = self._done_report, None
        return rep

    @property
    def maintenance_pending(self) -> bool:
        """A repair is in flight or a finished report awaits claim."""
        return (self._pending_repair is not None
                or self._done_report is not None)

    def reorder(self, *, window: int = 8, lam: float = 1.0) -> np.ndarray:
        """Connectivity-aware relayout (§3.4), applied at compaction.
        Deprecated entry point — prefer `maintain("reorder", ...)`."""
        self._barrier_repair()
        n = self._count
        live, rows = lsm.resolve_all(self.cfg.lsm_cfg, self.state.store, n)
        with declared_sync("reorder host relayout"):
            # sync-ok: gorder relayout is a host-side maintenance pass
            live_np = np.asarray(live).astype(bool) & (
                np.asarray(self.state.levels[:n]) >= 0)
            # sync-ok: gorder relayout is a host-side maintenance pass
            perm = reorder.gorder_permutation(
                np.asarray(rows), np.asarray(self.state.heat[:n]),
                window=window, lam=lam, live=live_np)
        self.state = reorder.apply_permutation(self.cfg, self.state, perm)
        self._version += 1
        return perm

    def compact(self) -> None:
        """Deprecated entry point — prefer `maintain("compact")`."""
        self._barrier_repair()
        self.state = self.state._replace(
            store=lsm.compact_all(self.cfg.lsm_cfg, self.state.store))
        self._version += 1

    def consolidate(self, *, ratio: Optional[float] = None) -> int:
        """Splice tombstoned nodes out of the graph and reclaim slots
        (lazy-deletion phase 2, DESIGN.md §9).  Returns the number of
        slots reclaimed.  `ratio` applies the per-shard trigger rule of
        the backend protocol: skip unless tombstones / (live +
        tombstones) has reached it (None = unconditional).  Internal ids
        are never reused, so external id maps stay valid with no
        rewrite.  One scalar sync up front — this is the rare
        maintenance path, not the serving hot path.  Deprecated entry
        point — prefer `maintain("consolidate", ratio=...)` or the
        overlapped `begin_maintain`/`poll_maintain` pair."""
        self._barrier_repair()
        with declared_sync("maintenance cadence scalar"):
            n = int(self.state.n_tombstones)  # sync-ok: maintenance cadence
        if n == 0:
            return 0
        if ratio is not None and n / max(self.size + n, 1) < ratio:
            return 0
        self.state, st = self._consolidate_fn(self.state)
        self.io_stats = self.io_stats + st
        self._version += 1
        return n

    def tier_maintain(self, policy: "tier_policy.TierPolicy") -> dict:
        """One batched demote/promote pass of the tier policy
        (DESIGN.md §12).  Returns {"demoted": n, "promoted": n}.  Jit
        caches key on (cfg, policy), both static — a serving layer using
        one policy compiles this exactly once.  No-op (zero moves) when
        the hot fraction already sits inside the hysteresis band.
        Deprecated entry point — prefer `maintain("tier", policy=...)`.
        """
        self._barrier_repair()
        self.state, st, moved = tier_policy.tier_maintain(
            self.cfg, self.state, policy)
        self.io_stats = self.io_stats + st
        return {k: int(v) for k, v in moved.items()}

    # -- read snapshot (DESIGN.md §8) -----------------------------------------

    def snapshot(self) -> jax.Array:
        """Dense bottom-layer adjacency view int32[cap, M], cached.

        Resolved lazily from the LSM tree and reused across consecutive
        query batches; any write (insert/delete/compact/reorder) bumps the
        index version and the next call re-resolves.
        """
        if self._snap is None or self._snap_version != self._version:
            self._snap = self._resolve_fn(self.state)
            self._snap_version = self._version
        return self._snap

    # -- backend protocol surface (DESIGN.md §10) -----------------------------

    @property
    def cap(self) -> int:
        """Total internal id space (the `VectorBackend` contract)."""
        return self.cfg.cap

    @property
    def lazy_delete(self) -> bool:
        return self.cfg.lazy_delete

    @property
    def snapshot_stale(self) -> bool:
        """True when the next snapshot read will re-resolve the tree."""
        return self._snap is None or self._snap_version != self._version

    def stats(self) -> BackendStats:
        """The backend stats surface — one fused device fetch.

        This is the single accessor for the device-side delete no-op
        count (the old `LSMVecIndex.delete_noops` / engine-property pair
        could drift); serving metrics must read it from here.
        """
        with declared_sync("stats surface fetch"):
            # sync-ok: the single fused device fetch of the stats surface
            live, nt, noops, counts = jax.device_get(
                (self.state.n_live, self.state.n_tombstones,
                 self.state.n_delete_noops, hnsw.memory_counts(self.state)))
        live, nt, noops = int(live), int(nt), int(noops)
        mem = hnsw.memory_breakdown(self.cfg, self.state, counts)
        shard = ShardStats(size=live, n_tombstones=nt, delete_noops=noops,
                           n_hot=mem.n_hot, n_cold=mem.n_cold)
        return BackendStats(size=live, n_tombstones=nt, delete_noops=noops,
                            max_tombstone_ratio=shard.tombstone_ratio,
                            shards=(shard,), memory=mem)

    def heat_total(self) -> int:
        """Accumulated edge-heat counts (one scalar sync)."""
        with declared_sync("heat trigger scalar"):
            return int(jnp.sum(self.state.heat))  # sync-ok: heat cadence

    def initial_ids(self) -> np.ndarray:
        """Internal ids in allocation order, for seeding an external-id
        map: the j-th vector ever allocated holds internal id j."""
        return np.arange(self._count, dtype=np.int64)

    def sync(self) -> None:
        with declared_sync("explicit barrier"):
            # sync-ok: sync() is the protocol's explicit barrier API
            jax.block_until_ready(self.state.count)

    def clone(self) -> "LSMVecIndex":
        """Deep-copy the device state into a fresh index (fresh jit
        caches too — benchmark trials use this to undo donation).  The
        RNG stream carries over, so a clone inserts with the same
        randomness the original would have."""
        self._barrier_repair()
        other = LSMVecIndex(self.cfg, seed=self._seed,
                            state=jax.tree.map(jnp.copy, self.state))
        other._rng = self._rng
        return other

    # -- durability (DESIGN.md §11) -------------------------------------------

    def save(self, ckpt_dir: str, *, lsn: int = 0,
             extra: Optional[dict] = None, meta: Optional[dict] = None,
             keep: int = 3, _pre_publish=None) -> str:
        """Atomic full-state checkpoint (`VectorBackend` protocol).

        Everything needed for bit-exact resume goes in: the complete
        `HNSWState` (vectors, codes, upper layers, LSM store, tombstone
        lane, heat), the insert RNG stream (so replayed inserts draw the
        same level/edge randomness), and caller `extra` arrays (the
        serve engine's ext↔int map and deleted mask).  `lsn` is the
        covering WAL position — recovery replays only records after it —
        and doubles as the checkpoint step, so steps are monotone as
        long as the caller only checkpoints after new writes.
        """
        self._barrier_repair()
        self.sync()
        tree = lsm.dehydrate(self.state, "state")
        tree["rng"] = jax.random.key_data(self._rng)
        for k, v in (extra or {}).items():
            tree[f"extra/{k}"] = np.asarray(v)
        metadata = {"lsn": int(lsn), "count": self._count,
                    "version": self._version, "seed": self._seed,
                    "cap": self.cfg.cap, "dim": self.cfg.dim,
                    **(meta or {})}
        return ckpt.save_checkpoint(ckpt_dir, step=int(lsn), tree=tree,
                                    metadata=metadata, keep=keep,
                                    _pre_publish=_pre_publish)

    @classmethod
    def restore(cls, cfg: hnsw.HNSWConfig, ckpt_dir: str, *,
                step: Optional[int] = None
                ) -> Tuple["LSMVecIndex", dict, dict]:
        """Rebuild an index from its latest (or `step`-th) checkpoint.

        Structure comes from `cfg` (shapes are config-derived), values
        from the manifest; every config-required leaf must be present
        with the exact shape or the restore refuses — a checkpoint from
        a different cap/dim/M must never load silently.  Returns
        (index, metadata, extras) where extras are the caller arrays
        passed to `save(extra=...)`, keys unprefixed.
        """
        arrays, metadata, _ = ckpt.load_arrays(ckpt_dir, step)
        if (int(metadata["cap"]) != cfg.cap
                or int(metadata["dim"]) != cfg.dim):
            raise ValueError(
                f"checkpoint cap/dim ({metadata['cap']}/{metadata['dim']}) "
                f"!= config ({cfg.cap}/{cfg.dim})")
        seed = int(metadata.get("seed", 0))
        template = hnsw.init(cfg, jax.random.key(seed))
        leaves = {}
        for k, tmpl in lsm.dehydrate(template, "state").items():
            if k not in arrays:
                raise KeyError(f"checkpoint missing state leaf {k!r}")
            arr = arrays[k]
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise ValueError(
                    f"{k}: checkpoint shape {tuple(arr.shape)} != "
                    f"config-derived {tuple(tmpl.shape)}")
            leaves[k] = jnp.asarray(arr, tmpl.dtype)
        state = lsm.hydrate(template, leaves, "state")
        idx = cls(cfg, seed=seed, state=state)
        idx._rng = jax.random.wrap_key_data(jnp.asarray(arrays["rng"]))
        idx._count = int(metadata["count"])
        idx._version = int(metadata["version"])
        extras = {k[len("extra/"):]: v for k, v in arrays.items()
                  if k.startswith("extra/")}
        return idx, metadata, extras

    # -- accounting -----------------------------------------------------------

    def reset_stats(self) -> None:
        self.io_stats = IOStats.zero()

    def reset_heat(self) -> None:
        """Zero the edge-heat accumulator (after a heat-driven relayout)."""
        self._barrier_repair()
        self.state = self.state._replace(heat=jnp.zeros_like(self.state.heat))

    def trace_counts(self) -> dict:
        """Compiled-variant counts per jitted entry point.

        The serving layer's zero-retrace guarantee is asserted against
        these: with fixed pad widths each op converges to a constant
        number of traced shapes after warmup.
        """
        return {
            "insert": self._insert_fn._cache_size(),
            "insert_batch": self._insert_batch_fn._cache_size(),
            "insert_batch_snapshot": self._insert_batch_snap_fn._cache_size(),
            "delete": self._delete_fn._cache_size(),
            "delete_batch": self._delete_batch_fn._cache_size(),
            "search": self._search_fn._cache_size(),
            "search_snapshot": self._search_snap_fn._cache_size(),
            "consolidate_bg": self._consolidate_bg_fn._cache_size(),
        }

    def io_cost(self, model: CostModel = iostats.DISK) -> float:
        return float(iostats.search_cost(self.io_stats, model))

    def memory_breakdown(self) -> MemoryBreakdown:
        """Per-component resident bytes (DESIGN.md §12)."""
        return hnsw.memory_breakdown(self.cfg, self.state)

    def memory_bytes(self) -> int:
        with declared_sync("memory accounting scalar"):
            return int(self.memory_breakdown().total)

    @property
    def size(self) -> int:
        with declared_sync("live-count scalar"):
            return int(self.state.n_live)  # sync-ok: declared accessor

    @property
    def n_tombstones(self) -> int:
        """Nodes lazily deleted but not yet consolidated (one sync)."""
        with declared_sync("tombstone-count scalar"):
            return int(self.state.n_tombstones)  # sync-ok: declared accessor
