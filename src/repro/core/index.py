"""LSMVecIndex — the public API of the paper's system.

Wraps the functional core (hnsw/lsm/traversal/simhash/reorder) behind the
interface a vector database exposes: build, insert, delete, search,
maintenance (reorder/compact), plus the I/O statistics and memory
accounting the paper's experiments report.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hnsw, iostats, lsm, reorder
from repro.core.iostats import CostModel, IOStats
from repro.kernels.l2_distance.ops import l2_distance


def brute_force_knn(vectors: jax.Array, queries: jax.Array, k: int,
                    live: Optional[jax.Array] = None,
                    block: int = 1024) -> np.ndarray:
    """Exact ground-truth ids [Q, k] (for Recall K@K evaluation)."""
    outs = []
    q = jnp.asarray(queries)
    for s in range(0, q.shape[0], block):
        d = l2_distance(q[s:s + block], vectors)
        if live is not None:
            d = jnp.where(live[None, :], d, jnp.inf)
        _, idx = jax.lax.top_k(-d, k)
        outs.append(np.asarray(idx))
    return np.concatenate(outs, axis=0)


def recall_at_k(found_ids: np.ndarray, true_ids: np.ndarray) -> float:
    """Recall K@K (Eq. 3): |found ∩ truth| / K averaged over queries."""
    k = true_ids.shape[1]
    hits = 0
    for f, t in zip(found_ids, true_ids):
        hits += len(set(f[:k].tolist()) & set(t.tolist()))
    return hits / (k * len(true_ids))


class LSMVecIndex:
    """Dynamic disk-based vector index (LSM-VEC)."""

    #: below this many live nodes, insert_batch falls back to per-item
    #: inserts: the batched pipeline searches the pre-batch graph snapshot,
    #: which must exist for the new nodes to link into (DESIGN.md §4)
    BATCH_MIN_GRAPH = 64

    def __init__(self, cfg: hnsw.HNSWConfig, seed: int = 0,
                 state: Optional[hnsw.HNSWState] = None):
        self.cfg = cfg
        self.state = state if state is not None else hnsw.init(
            cfg, jax.random.key(seed))
        self._rng = jax.random.key(seed + 1)
        self.stats = IOStats.zero()
        # host mirror of state.count: id allocation and maintenance never
        # pay a device sync on the hot path
        self._count = int(self.state.count)

        cfg_ = self.cfg

        @functools.partial(jax.jit, donate_argnums=0)
        def _insert(state, x, key):
            return hnsw.insert(cfg_, state, x, key)

        @functools.partial(jax.jit, donate_argnums=0)
        def _insert_batch(state, xs, keys):
            return hnsw.insert_batch(cfg_, state, xs, keys)

        @functools.partial(jax.jit, donate_argnums=0)
        def _delete(state, i):
            return hnsw.delete(cfg_, state, i)

        @functools.partial(jax.jit, donate_argnums=0)
        def _delete_batch(state, ids):
            return hnsw.delete_batch(cfg_, state, ids)

        @functools.partial(jax.jit, static_argnames=("rho", "use_filter",
                                                     "ef", "n_expand"))
        def _search(state, qs, rho, use_filter, ef, n_expand):
            res = hnsw.search_batch(cfg_, state, qs, rho=rho,
                                    use_filter=use_filter, ef=ef,
                                    n_expand=n_expand)
            heat_delta = _heat_delta(state, res)
            return res, heat_delta

        def _heat_delta(state, res):
            nodes = res.heat_nodes.reshape(-1)
            mask = res.heat_mask.reshape(-1, cfg_.M)
            safe = jnp.maximum(nodes, 0)
            contrib = jnp.where((nodes >= 0)[:, None], mask, False)
            return jnp.zeros_like(state.heat).at[safe].add(
                contrib.astype(jnp.int32))

        self._insert_fn = _insert
        self._insert_batch_fn = _insert_batch
        self._delete_fn = _delete
        self._delete_batch_fn = _delete_batch
        self._search_fn = _search

    # -- construction ---------------------------------------------------------

    @classmethod
    def build(cls, cfg: hnsw.HNSWConfig, vectors: jax.Array,
              seed: int = 0) -> "LSMVecIndex":
        idx = cls(cfg, seed=seed, state=hnsw.bulk_build(
            cfg, jnp.asarray(vectors, jnp.float32), jax.random.key(seed)))
        return idx

    # -- updates --------------------------------------------------------------

    def insert(self, x) -> int:
        """Insert one vector; returns its id."""
        self._rng, sub = jax.random.split(self._rng)
        new_id = self._count
        self.state, st = self._insert_fn(
            self.state, jnp.asarray(x, jnp.float32), sub)
        self._count += 1
        self.stats = self.stats + st
        return new_id

    def insert_batch(self, xs) -> list[int]:
        """Insert a batch in one jit'd device call; returns the new ids.

        The whole batch is dispatched as a single donated-buffer
        `hnsw.insert_batch` (vmapped candidate search + scanned writes)
        with zero per-item host syncs.  While the graph is smaller than
        BATCH_MIN_GRAPH the leading items fall back to per-item inserts so
        the batch pipeline always has a snapshot to search.  Note the jit
        specializes on batch length; feed fixed-size batches for best
        throughput.
        """
        xs = np.asarray(xs, np.float32)
        if xs.size == 0:
            return []
        xs = np.atleast_2d(xs)
        # guard on *live* size, not allocated ids: a graph emptied by
        # deletes must re-seed per-item too (one scalar sync per batch
        # call, never per item)
        n_seed = max(0, min(len(xs), self.BATCH_MIN_GRAPH - self.size))
        ids = [self.insert(x) for x in xs[:n_seed]]
        rest = xs[n_seed:]
        if len(rest) == 0:
            return ids
        self._rng, sub = jax.random.split(self._rng)
        keys = jax.random.split(sub, len(rest))
        ids.extend(range(self._count, self._count + len(rest)))
        self.state, st = self._insert_batch_fn(
            self.state, jnp.asarray(rest), keys)
        self._count += len(rest)
        self.stats = self.stats + st
        return ids

    def delete(self, node_id: int) -> None:
        self.state, st = self._delete_fn(self.state, jnp.asarray(node_id))
        self.stats = self.stats + st

    def delete_batch(self, ids) -> None:
        """Delete a batch of ids in one jit'd `lax.scan` device call."""
        ids = np.atleast_1d(np.asarray(ids, np.int32))
        if len(ids) == 0:
            return
        self.state, st = self._delete_batch_fn(self.state, jnp.asarray(ids))
        self.stats = self.stats + st

    # -- search ---------------------------------------------------------------

    def search(self, queries, k: Optional[int] = None, *,
               rho: Optional[float] = None, ef: Optional[int] = None,
               use_filter: Optional[bool] = None,
               n_expand: Optional[int] = None,
               record_heat: bool = True) -> Tuple[np.ndarray, np.ndarray]:
        """Batched ANN search.  queries [B, dim] -> (ids [B, k], dists).

        `n_expand` > 1 expands that many frontier nodes per beam iteration
        (multi-expansion); 1 is the classic exact-parity path.
        """
        cfg = self.cfg
        k = k or cfg.k
        rho = cfg.rho if rho is None else float(rho)
        use_filter = cfg.use_filter if use_filter is None else use_filter
        ef = ef or cfg.ef_search
        n_expand = cfg.n_expand if n_expand is None else int(n_expand)
        qs = jnp.atleast_2d(jnp.asarray(queries, jnp.float32))
        res, heat_delta = self._search_fn(self.state, qs, rho, use_filter,
                                          ef, n_expand)
        if record_heat:
            self.state = self.state._replace(
                heat=self.state.heat + heat_delta)
        batch_stats = jax.tree.map(lambda a: jnp.sum(a), res.stats)
        self.stats = self.stats + IOStats(*batch_stats)
        return np.asarray(res.ids[:, :k]), np.asarray(res.dists[:, :k])

    # -- maintenance ----------------------------------------------------------

    def reorder(self, *, window: int = 8, lam: float = 1.0) -> np.ndarray:
        """Connectivity-aware relayout (§3.4), applied at compaction."""
        n = self._count
        live, rows = lsm.resolve_all(self.cfg.lsm_cfg, self.state.store, n)
        live_np = np.asarray(live).astype(bool) & (
            np.asarray(self.state.levels[:n]) >= 0)
        perm = reorder.gorder_permutation(
            np.asarray(rows), np.asarray(self.state.heat[:n]),
            window=window, lam=lam, live=live_np)
        self.state = reorder.apply_permutation(self.cfg, self.state, perm)
        return perm

    def compact(self) -> None:
        self.state = self.state._replace(
            store=lsm.compact_all(self.cfg.lsm_cfg, self.state.store))

    # -- accounting -----------------------------------------------------------

    def reset_stats(self) -> None:
        self.stats = IOStats.zero()

    def io_cost(self, model: CostModel = iostats.DISK) -> float:
        return float(iostats.search_cost(self.stats, model))

    def memory_bytes(self) -> int:
        return int(hnsw.memory_resident_bytes(self.cfg, self.state))

    @property
    def size(self) -> int:
        return int(self.state.n_live)
