"""The index↔serve boundary: `VectorBackend` protocol + typed results.

Everything above the functional core (`repro.serve`, benchmarks,
examples) programs against this protocol instead of a concrete index
class (DESIGN.md §10).  Two implementations ship:

- `LSMVecIndex` (`core/index.py`) — the single-device index;
- `ShardedBackend` (`core/distributed.py`) — hash-partitioned shards,
  each a full `LSMVecIndex`, fan-out search with device-side local
  top-k and a host merge.

The id contract: a backend exposes one flat *internal* id space
`[0, cap)` (for shards, block-encoded `shard * shard_cap + local`).
Internal ids are retired, never reused (consolidation), and only ever
permuted by `reorder`, which returns the permutation so a serving layer
can fold it into its own external↔internal map.  External ids — the ids
clients hold — are owned entirely by the serving layer; the backend
never sees them.

Search is two-phase (DESIGN.md §13): `dispatch_search` enqueues the
device work and returns a `SearchHandle` without forcing a host sync;
`handle.collect()` blocks on the device arrays and produces the final
`SearchResult`.  `search` = dispatch + collect, so single-call sites
are unchanged and shards=1 stays bit-parity.  Maintenance is unified
behind `maintain(op, **params) -> MaintenanceReport`, with an optional
async pair `begin_maintain`/`poll_maintain` for overlapped
consolidation.

Typed results replace the ad-hoc tuple/list returns: `search` returns a
`SearchResult`, `insert_batch`/`delete_batch` return an `UpdateResult`.
Both are frozen value types — the PR-4 sequence-compat shims are gone;
use `.ids`/`.dists` explicitly.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Protocol, Sequence, runtime_checkable

import numpy as np


@dataclass(frozen=True)
class SearchResult:
    """Batched ANN search result in the backend's internal id space.

    `ids` int [B, k] (-1 pads under-full rows), `dists` f32 [B, k]
    (squared L2, +inf on pads).
    """

    ids: np.ndarray
    dists: np.ndarray


@dataclass(frozen=True)
class UpdateResult:
    """Result of a batched mutation.

    For inserts, `ids` holds the new internal ids in submission order;
    for deletes, the internal ids the batch targeted (−1 = masked pad).
    `n_applied` counts items the backend dispatched (inserts allocated;
    deletes with a routable non-negative id).  Dispatched deletes that
    turn out to be device-side no-ops (absent/already-dead ids) are NOT
    subtracted here — they are reported once, in
    `stats().delete_noops`, so the two counts never drift.
    """

    ids: np.ndarray
    n_applied: int


@dataclass(frozen=True)
class SearchParams:
    """Typed search knobs — the one place defaults are resolved.

    A `None` field means "use the backend config default" (resolved via
    `resolve(cfg)` at the dispatch boundary, nowhere else).
    `record_heat=None` defers to the caller's policy: `LSMVecIndex`
    resolves it to True, `ServeEngine` resolves it from its tier policy.
    `use_snapshot` selects the cached dense-read snapshot (serving
    path); `pad_to` pads the query batch to a fixed traced width.
    """

    rho: Optional[float] = None
    ef: Optional[int] = None
    use_filter: Optional[bool] = None
    n_expand: Optional[int] = None
    record_heat: Optional[bool] = None
    use_snapshot: bool = False
    pad_to: Optional[int] = None

    def resolve(self, cfg) -> "SearchParams":
        """Fill `None` knobs from an `HNSWConfig` — the single
        config-derived-defaults site for the whole stack."""
        return SearchParams(
            rho=float(cfg.rho if self.rho is None else self.rho),
            ef=int(cfg.ef_search if self.ef is None else self.ef),
            use_filter=bool(cfg.use_filter if self.use_filter is None
                            else self.use_filter),
            n_expand=int(cfg.n_expand if self.n_expand is None
                         else self.n_expand),
            record_heat=(True if self.record_heat is None
                         else bool(self.record_heat)),
            use_snapshot=bool(self.use_snapshot),
            pad_to=self.pad_to,
        )

    def replace(self, **kw) -> "SearchParams":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class MaintenanceReport:
    """Uniform result of one `maintain(op)` invocation.

    `applied` is False when the op's own trigger rule declined to run
    (e.g. consolidate below the tombstone-ratio threshold).
    `reclaimed` — tombstone slots spliced out (consolidate);
    `perm` — internal-id permutation applied (reorder), else None;
    `demoted`/`promoted` — tier lane moves (tier).  `detail` carries
    op-specific extras (per-shard counts etc.).
    """

    op: str
    applied: bool
    reclaimed: int = 0
    perm: Optional[np.ndarray] = None
    demoted: int = 0
    promoted: int = 0
    detail: dict = field(default_factory=dict)


@runtime_checkable
class SearchHandle(Protocol):
    """An in-flight search: device work dispatched, host sync deferred.

    `collect()` blocks on the device arrays and returns the final
    `SearchResult`; it is called exactly once.  `is_ready()` is a
    non-blocking poll (True once every underlying device array has
    resolved — advisory, collect() is always safe).
    """

    def collect(self) -> SearchResult: ...

    def is_ready(self) -> bool: ...


@dataclass(frozen=True)
class MemoryBreakdown:
    """Per-component resident-byte accounting (DESIGN.md §12).

    Every field is bytes except the trailing lane counts.  `hot_vectors`
    is the dense f32 lane (with tiering off, every routable node is in
    it — the dense baseline fig6 compares against); `cold_codes` is the
    int8 + per-row-scale lane.  The serving-state components the old
    accounting omitted — tombstone lane, insert-overlay staging buffers,
    and the ext↔int id maps a serving layer must hold 1:1 with backend
    capacity — are included so fig6 numbers are honest about the full
    stack, not just the index arrays.  Adding two breakdowns adds
    componentwise (shard aggregation).
    """

    hot_vectors: int = 0     # dense-lane f32 rows
    cold_codes: int = 0      # int8 rows + f32 per-row scales
    upper_graph: int = 0     # upper-layer adjacency arrays
    upper_vec_cache: int = 0  # upper-node f32 rows cached for descent
    simhash_codes: int = 0   # per-node simhash codes (both lanes)
    memtable: int = 0        # LSM memtable (keys + rows + valid lane)
    tombstones: int = 0      # lazy-delete bitmap (capacity-sized)
    insert_overlay: int = 0  # insert_batch staging overlay (rows + valid)
    id_maps: int = 0         # serving ext↔int int64 maps (2 x cap)
    misc: int = 0            # entry/counters/rng etc.
    n_hot: int = 0           # dense-lane row count (not bytes)
    n_cold: int = 0          # cold-lane row count (not bytes)

    _BYTE_FIELDS = ("hot_vectors", "cold_codes", "upper_graph",
                    "upper_vec_cache", "simhash_codes", "memtable",
                    "tombstones", "insert_overlay", "id_maps", "misc")

    @property
    def total(self) -> int:
        return sum(getattr(self, f) for f in self._BYTE_FIELDS)

    def __add__(self, other: "MemoryBreakdown") -> "MemoryBreakdown":
        kw = {f: getattr(self, f) + getattr(other, f)
              for f in self._BYTE_FIELDS + ("n_hot", "n_cold")}
        return MemoryBreakdown(**kw)

    def as_dict(self) -> dict:
        d = {f: int(getattr(self, f)) for f in
             self._BYTE_FIELDS + ("n_hot", "n_cold")}
        d["total"] = int(self.total)
        return d


@dataclass(frozen=True)
class ShardStats:
    """Per-shard slice of `BackendStats`."""

    size: int            # live (returnable) nodes
    n_tombstones: int    # lazily deleted, not yet consolidated
    delete_noops: int    # device-counted deletes of absent/dead ids
    n_hot: int = 0       # dense-lane rows (== size+tombstones, tier off)
    n_cold: int = 0      # quantized-lane rows

    @property
    def tombstone_ratio(self) -> float:
        return self.n_tombstones / max(self.size + self.n_tombstones, 1)


@dataclass(frozen=True)
class BackendStats:
    """The backend stats surface — the single source for serving
    metrics (`ServeEngine.delete_noops` reads the device-side no-op
    count from here, never from a parallel accessor, so the two counts
    cannot drift).  `max_tombstone_ratio` is the per-shard maximum: the
    maintenance trigger fires when *any* shard crosses the threshold,
    not only when the global average does.
    """

    size: int
    n_tombstones: int
    delete_noops: int
    max_tombstone_ratio: float
    shards: tuple = ()     # tuple[ShardStats, ...], one entry per shard
    # per-component resident bytes, aggregated across shards (None only
    # for legacy constructors that predate the tier accounting)
    memory: Optional[MemoryBreakdown] = None


@runtime_checkable
class VectorBackend(Protocol):
    """What the serving layer requires of an index.

    Reads: `dispatch_search(queries, k, params=...)` enqueues device
    work and returns a `SearchHandle`; `search` is the one-call
    dispatch+collect.  Mutations: `insert_batch` / `delete_batch` take
    `pad_to` so a fixed micro-batch width dispatches through one traced
    shape.  Maintenance: `maintain(op, **params)` covers
    consolidate/compact/reorder/tier uniformly and returns a
    `MaintenanceReport`; `begin_maintain`/`poll_maintain` run a
    consolidation overlapped with serving (double-buffered repair,
    atomic cutover — DESIGN.md §13).  `initial_ids` seeds an
    external-id map: internal ids in allocation order for every node
    allocated so far.
    """

    @property
    def cap(self) -> int: ...                 # total internal id space

    @property
    def lazy_delete(self) -> bool: ...

    @property
    def snapshot_stale(self) -> bool: ...     # next snapshot read re-resolves

    def search(self, queries, k: Optional[int] = None, *,
               params: Optional[SearchParams] = None) -> SearchResult: ...

    def dispatch_search(self, queries, k: Optional[int] = None, *,
                        params: Optional[SearchParams] = None
                        ) -> SearchHandle: ...

    def insert_batch(self, xs, *,
                     pad_to: Optional[int] = None) -> UpdateResult: ...

    def delete_batch(self, ids, *,
                     pad_to: Optional[int] = None) -> UpdateResult: ...

    def maintain(self, op: str, **params) -> MaintenanceReport: ...

    # -- overlapped consolidation (DESIGN.md §13) -----------------------------
    # `begin_maintain("consolidate", ...)` starts a double-buffered repair
    # against a clone of the live state and returns True iff one was
    # started (False: trigger declined, or a repair is already in
    # flight).  Queries keep serving from the live snapshot;
    # `poll_maintain()` cuts over atomically once the repair's device
    # work is done and returns its report (None while still running or
    # when nothing is in flight; `block=True` forces completion).
    # Mutations barrier on any in-flight repair, so the cutover always
    # lands on a write-batch boundary — the WAL replay invariant.
    def begin_maintain(self, op: str, **params) -> bool: ...

    def poll_maintain(self, *, block: bool = False
                      ) -> Optional[MaintenanceReport]: ...

    def stats(self) -> BackendStats: ...

    def memory_bytes(self) -> int: ...        # MemoryBreakdown total

    def heat_total(self) -> int: ...

    def reset_heat(self) -> None: ...

    def initial_ids(self) -> np.ndarray: ...

    def trace_counts(self) -> dict: ...

    def sync(self) -> None: ...               # block until device work done

    # -- durability (DESIGN.md §11) -------------------------------------------
    # `save` writes an atomic full-state checkpoint (staged dir + rename)
    # whose manifest records `lsn`, the WAL position it covers: recovery
    # restores the checkpoint and replays only records with LSN > lsn.
    # `extra` carries caller-owned arrays (the serve engine's ext↔int id
    # map and deleted mask) and `meta` caller scalars; both come back
    # verbatim from the implementation's matching classmethod
    #   restore(cfg, ckpt_dir, ...) -> (backend, metadata, extras)
    # (a constructor, so not part of the instance protocol).  A restore
    # must refuse layout mismatches — cap/dim/shard count — rather than
    # load silently into a backend that would route differently.
    def save(self, ckpt_dir: str, *, lsn: int = 0,
             extra: Optional[dict] = None, meta: Optional[dict] = None,
             keep: int = 3, _pre_publish=None) -> str: ...


def merge_topk(gids: Sequence[np.ndarray], dists: Sequence[np.ndarray],
               k: int) -> SearchResult:
    """Host-side top-k merge of per-shard results.

    Each shard contributes its device-side local top-k (`gids[s]`
    int [B, k_s] already in the global id space, -1 pads; `dists[s]`
    f32 with +inf on pads).  Rows are distance-sorted per shard, so the
    merged stable sort is a deterministic P-way merge: ties resolve to
    the lower shard index, and with one shard the merge is the
    identity — the bit-parity anchor for shards=1.
    """
    flat_i = np.concatenate(gids, axis=1)
    flat_d = np.concatenate(dists, axis=1)
    flat_d = np.where(flat_i >= 0, flat_d, np.inf)
    order = np.argsort(flat_d, axis=1, kind="stable")[:, :k]
    return SearchResult(
        ids=np.take_along_axis(flat_i, order, axis=1),
        dists=np.take_along_axis(flat_d, order, axis=1))


def shard_of_seq(seq, n_shards: int):
    """Hash-partitioned routing: allocation sequence number -> shard.

    Fibonacci (multiplicative) hashing of the global allocation counter:
    deterministic across runs, load-balanced for any arrival pattern,
    and independent of vector content (content-hash routing would
    correlate shard load with the data distribution).  `seq` may be an
    int or an int array; one shard always routes to 0.
    """
    if n_shards == 1:
        return np.zeros_like(np.asarray(seq)) if np.ndim(seq) else 0
    x = np.asarray(seq, np.uint64) * np.uint64(0x9E3779B97F4A7C15)
    return ((x >> np.uint64(33)) % np.uint64(n_shards)).astype(np.int64)
