"""Sign-random-projection (SimHash) codes and the Hoeffding filter (§3.3).

The sampling-guided traversal keeps one packed binary code per vector *in
memory* (on the TPU mapping: resident per-core, cheap to evaluate) and only
fetches a candidate's full vector from the slow tier when its hash-collision
count with the query clears a Hoeffding threshold — Eq. (4)–(6) of the
paper.

Encoding (Eq. 4):   Hash(x) = [sgn(x·a_1), ..., sgn(x·a_m)],  a_i ~ N(0, I)
Collisions (Eq. 5): #Col(q,u) = (m + Hash(q)·Hash(u)) / 2
                               = m - popcount(bits_q XOR bits_u)
Filter (Eq. 6):     evaluate u iff #Col(q,u) >= T_eps

For SimHash, P[bit collides] = 1 - theta/pi where theta = angle(q,u).
#Col ~ Binomial(m, p), so by Hoeffding the one-sided deviation below the
mean exceeds sqrt(m ln(1/eps) / 2) with probability <= eps.  A candidate
within distance delta therefore passes

    T_eps = m * (1 - theta_delta / pi) - sqrt(m ln(1/eps) / 2)

with probability >= 1 - eps, which is the paper's recall guarantee: skipping
candidates below T_eps loses a true <=delta neighbor with prob <= eps.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class SimHashParams(NamedTuple):
    proj: jax.Array   # float32[m_bits, dim] — random projection directions

    @property
    def m_bits(self) -> int:
        return self.proj.shape[0]

    @property
    def words(self) -> int:
        return self.proj.shape[0] // 32


def init(key: jax.Array, dim: int, m_bits: int = 64) -> SimHashParams:
    if m_bits % 32 != 0:
        raise ValueError("m_bits must be a multiple of 32 for uint32 packing")
    proj = jax.random.normal(key, (m_bits, dim), jnp.float32)
    return SimHashParams(proj)


def encode(params: SimHashParams, x: jax.Array) -> jax.Array:
    """Pack sgn(x @ a_i) into uint32 words.  x: [..., dim] -> [..., m/32]."""
    bits = (x @ params.proj.T) >= 0.0                      # [..., m]
    m = params.m_bits
    bits = bits.reshape(*bits.shape[:-1], m // 32, 32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return jnp.sum(bits.astype(jnp.uint32) * weights, axis=-1, dtype=jnp.uint32)


def collisions(code_q: jax.Array, code_u: jax.Array, m_bits: int) -> jax.Array:
    """#Col(q, u) per Eq. (5).  Broadcasts over leading dims.

    code_*: uint32[..., m/32] -> int32[...]
    """
    ham = jnp.sum(jax.lax.population_count(code_q ^ code_u), axis=-1)
    return (m_bits - ham).astype(jnp.int32)


def collision_probability(cos_sim: jax.Array) -> jax.Array:
    """P[one SimHash bit collides] = 1 - angle / pi."""
    theta = jnp.arccos(jnp.clip(cos_sim, -1.0, 1.0))
    return 1.0 - theta / jnp.pi


def hoeffding_threshold(m_bits: int, eps: float, cos_sim: jax.Array) -> jax.Array:
    """T_eps: minimum collisions a <=delta candidate clears w.p. >= 1-eps.

    `cos_sim` is the cosine similarity corresponding to the dynamic distance
    cutoff delta (the worst distance in the current top-k set — Eq. 6's
    dynamic delta).  Smaller eps -> lower threshold -> fewer false skips.
    """
    p = collision_probability(cos_sim)
    slack = math.sqrt(m_bits * math.log(1.0 / eps) / 2.0)
    return p * m_bits - slack


def cos_from_l2(delta_sq: jax.Array, q_norm: jax.Array, u_norm: jax.Array) -> jax.Array:
    """cos(q,u) implied by squared L2 distance delta^2 and the two norms.

    ||q - u||^2 = ||q||^2 + ||u||^2 - 2 ||q|| ||u|| cos  =>
    cos = (||q||^2 + ||u||^2 - delta^2) / (2 ||q|| ||u||).

    The traversal uses the dataset's mean norm for ||u|| (the true candidate
    norm is unknown before the fetch — that is the point of the filter).
    """
    denom = jnp.maximum(2.0 * q_norm * u_norm, 1e-12)
    return jnp.clip((q_norm ** 2 + u_norm ** 2 - delta_sq) / denom, -1.0, 1.0)


def filter_mask(params: SimHashParams, code_q: jax.Array, codes_u: jax.Array,
                eps: float, delta_sq: jax.Array, q_norm: jax.Array,
                mean_norm: jax.Array) -> jax.Array:
    """Eq. (6): True where the candidate must be evaluated (fetched).

    code_q: uint32[W]; codes_u: uint32[n, W] -> bool[n]
    """
    cols = collisions(code_q[None, :], codes_u, params.m_bits)
    cos = cos_from_l2(delta_sq, q_norm, mean_norm)
    thr = hoeffding_threshold(params.m_bits, eps, cos)
    return cols.astype(jnp.float32) >= thr
