"""LSM-VEC core: the paper's contribution as composable JAX modules.

- lsm        — functional LSM-tree storing bottom-layer adjacency
- simhash    — sign-random-projection codes + Hoeffding filter (Eq. 4-6)
- hnsw       — hybrid memory/disk hierarchical graph (Alg. 1-2)
- traversal  — sampling-guided beam search (§3.3)
- reorder    — connectivity-aware relayout (§3.4, Eq. 10-12)
- iostats    — the paper's I/O cost model (Eq. 7-9)
- backend    — the VectorBackend protocol + typed results (§10): the
  boundary everything above the core programs against
- index      — LSMVecIndex, the single-device backend
- distributed— ShardedBackend (hash-partitioned shard-per-device
  serving) + exact flat sharded search
- baselines  — DiskANN-like and SPFresh-like comparison systems
"""

from repro.core.backend import (
    BackendStats,
    MaintenanceReport,
    SearchHandle,
    SearchParams,
    SearchResult,
    ShardStats,
    UpdateResult,
    VectorBackend,
)
from repro.core.hnsw import HNSWConfig, HNSWState
from repro.core.index import LSMVecIndex, brute_force_knn, recall_at_k
from repro.core.iostats import DISK, CostModel, IOStats, tpu_hbm_model

__all__ = [
    "HNSWConfig", "HNSWState", "LSMVecIndex", "brute_force_knn",
    "recall_at_k", "IOStats", "CostModel", "DISK", "tpu_hbm_model",
    "VectorBackend", "BackendStats", "ShardStats", "SearchResult",
    "UpdateResult", "SearchParams", "SearchHandle", "MaintenanceReport",
]
