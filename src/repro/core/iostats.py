"""I/O accounting + the paper's cost model (Eq. 7-9).

The traversal engine counts *accesses*, not seconds: how many adjacency
rows were read from the LSM tree (`n_adj`, the paper's `T` pays `t_n`
each) and how many full vectors were fetched from the slow tier (`n_vec`,
pays `t_v` each).  `n_filtered` counts neighbors the SimHash filter
skipped — the saving Delta of Eq. 9.

Two cost models ship by default:
 - `DISK`   — the paper's hardware (NVMe 4 KB random reads).
 - `TPU_HBM`— the TPU mapping (row bytes / HBM bandwidth) used by the
   roofline analysis.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class IOStats(NamedTuple):
    n_adj: jnp.ndarray        # adjacency-row (neighbor list) reads
    n_vec: jnp.ndarray        # full-vector fetches from the slow tier
    n_filtered: jnp.ndarray   # neighbor evaluations skipped by sampling
    n_hops: jnp.ndarray       # beam expansions (visited nodes T)

    @staticmethod
    def zero() -> "IOStats":
        z = jnp.zeros((), jnp.int32)
        return IOStats(z, z, z, z)

    def __add__(self, other: "IOStats") -> "IOStats":
        return IOStats(*(a + b for a, b in zip(self, other)))


class CostModel(NamedTuple):
    t_n: float   # seconds per neighbor-list fetch
    t_v: float   # seconds per vector fetch


# NVMe random 4KB read ~= 100 us; neighbor lists are similar-size reads.
DISK = CostModel(t_n=100e-6, t_v=100e-6)


def tpu_hbm_model(dim: int, row_width: int, bw_bytes: float = 819e9) -> CostModel:
    """Cost model for the TPU mapping: bytes moved / HBM bandwidth."""
    return CostModel(t_n=row_width * 4 / bw_bytes, t_v=dim * 4 / bw_bytes)


def search_cost(stats: IOStats, model: CostModel) -> jnp.ndarray:
    """Eq. 7/8: T * t_n + (fetched vectors) * t_v.

    With sampling off, fetched = T * d and this reduces to Eq. 7; with
    sampling, fetched ~= rho * T * d (Eq. 8).
    """
    return stats.n_adj * model.t_n + stats.n_vec * model.t_v


def sampling_saving(stats: IOStats, model: CostModel) -> jnp.ndarray:
    """Eq. 9: Delta = (skipped vector fetches) * t_v."""
    return stats.n_filtered * model.t_v
