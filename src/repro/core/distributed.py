"""Mesh-sharded vector search: partition-per-device serving.

The paper's billion-scale deployment note (§5.1) — "billion-scale indices
are typically partitioned or sharded in real-world systems" — is realized
here: the corpus is split into P shards, each device owns one shard's
index state, a query fans out to every shard (`shard_map`), local top-k
results are all-gathered, and a global top-k merge produces the answer.
Recall of the merged result equals single-shard recall because every
shard is searched (SPANN-style partition serving).

Two shard-local engines:
 - "flat": exact blocked L2 scan (the memory-bandwidth-optimal TPU form);
 - "hnsw": the LSM-VEC graph state, vmapped over the shard axis.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import hnsw
from repro.kernels.l2_distance.ref import l2_distance_ref


class ShardedFlatIndex:
    """Exact partitioned search over a device mesh axis."""

    def __init__(self, mesh, axis: str = "data"):
        self.mesh = mesh
        self.axis = axis
        self.vectors = None           # [P, n_per, d] sharded on axis 0
        self.n_per = 0

    def build(self, vectors: np.ndarray) -> "ShardedFlatIndex":
        p = self.mesh.devices.size
        n, d = vectors.shape
        n_per = -(-n // p)
        pad = n_per * p - n
        vecs = np.pad(vectors, ((0, pad), (0, 0)),
                      constant_values=np.inf).astype(np.float32)
        # inf-padding keeps padded rows out of every top-k
        arr = jnp.asarray(vecs.reshape(p, n_per, d))
        sharding = jax.sharding.NamedSharding(
            self.mesh, P(tuple(self.mesh.axis_names)))
        self.vectors = jax.device_put(arr, sharding)
        self.n_per = n_per
        self._search = self._make_search()
        return self

    def _make_search(self):
        mesh = self.mesh
        axes = tuple(mesh.axis_names)
        n_per = self.n_per
        p = mesh.devices.size

        def local(shard_id, vecs, queries):
            # shard_id [1] (this shard's slot — order-correct by
            # construction), vecs [1, n_per, d], queries [Q, d] replicated
            d2 = l2_distance_ref(queries, vecs[0])          # [Q, n_per]
            d2 = jnp.where(jnp.isfinite(d2), d2, jnp.inf)
            k = min(16, n_per)
            neg, idx = jax.lax.top_k(-d2, k)
            gids = idx + shard_id[0] * n_per                # global ids
            # gather every shard's candidates, merge
            all_d = jax.lax.all_gather(-neg, axes, tiled=False)
            all_i = jax.lax.all_gather(gids, axes, tiled=False)
            all_d = all_d.reshape(-1, *neg.shape)
            all_i = all_i.reshape(-1, *gids.shape)
            all_d = jnp.swapaxes(all_d, 0, 1).reshape(queries.shape[0], -1)
            all_i = jnp.swapaxes(all_i, 0, 1).reshape(queries.shape[0], -1)
            negd, pos = jax.lax.top_k(-all_d, 10)
            return jnp.take_along_axis(all_i, pos, axis=1), -negd

        fn = jax.shard_map(
            local, mesh=mesh,
            in_specs=(P(axes), P(axes), P()), out_specs=(P(), P()),
            check_vma=False)
        self._shard_ids = jax.device_put(
            jnp.arange(p, dtype=jnp.int32),
            jax.sharding.NamedSharding(self.mesh, P(axes)))
        return jax.jit(fn)

    def search(self, queries, k: int = 10) -> Tuple[np.ndarray, np.ndarray]:
        ids, dists = self._search(self._shard_ids, self.vectors,
                                  jnp.asarray(queries, jnp.float32))
        return np.asarray(ids)[:, :k], np.asarray(dists)[:, :k]


class ShardedLSMVec:
    """P independent LSM-VEC shards searched in parallel + global merge.

    Shard states are built on host (bulk_build per shard) and stacked; the
    query path runs each shard's sampled beam search under vmap and merges
    top-k across shards — update paths route to the owning shard exactly
    like the single-shard index.
    """

    def __init__(self, cfg: hnsw.HNSWConfig, n_shards: int):
        self.cfg = cfg
        self.n_shards = n_shards
        self.states = None
        self.shard_of = None   # global id -> (shard, local id) bookkeeping
        self.local_of = None

    def build(self, vectors: np.ndarray, seed: int = 0) -> "ShardedLSMVec":
        n = len(vectors)
        rng = np.random.default_rng(seed)
        asg = rng.integers(0, self.n_shards, n)
        self.shard_of = asg
        self.local_of = np.zeros(n, np.int32)
        states = []
        for s in range(self.n_shards):
            ids = np.flatnonzero(asg == s)
            self.local_of[ids] = np.arange(len(ids))
            st = hnsw.bulk_build(self.cfg, jnp.asarray(vectors[ids]),
                                 jax.random.key(seed + s))
            states.append(st)
        self.states = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
        self._globals = []
        for s in range(self.n_shards):
            g = np.full(self.cfg.cap, -1, np.int64)
            ids = np.flatnonzero(asg == s)
            g[: len(ids)] = ids
            self._globals.append(g)
        self._globals = np.stack(self._globals)

        cfg = self.cfg

        @jax.jit
        def _search(states, qs):
            def per_shard(st):
                res = hnsw.search_batch(cfg, st, qs)
                return res.ids, res.dists
            ids, dists = jax.vmap(per_shard)(states)     # [P, Q, ef]
            return ids, dists

        self._search = _search
        return self

    def search(self, queries, k: int = 10) -> Tuple[np.ndarray, np.ndarray]:
        qs = jnp.atleast_2d(jnp.asarray(queries, jnp.float32))
        ids, dists = self._search(self.states, qs)
        ids = np.asarray(ids)          # [P, Q, ef] local ids
        dists = np.asarray(dists)
        p, q, ef = ids.shape
        gids = np.take_along_axis(
            self._globals[:, None, :].repeat(q, 1).reshape(p, q, -1),
            np.maximum(ids, 0), axis=2)
        gids = np.where(ids >= 0, gids, -1)
        # merge across shards
        flat_i = gids.transpose(1, 0, 2).reshape(q, -1)
        flat_d = dists.transpose(1, 0, 2).reshape(q, -1)
        order = np.argsort(flat_d, axis=1)[:, :k]
        return (np.take_along_axis(flat_i, order, axis=1),
                np.take_along_axis(flat_d, order, axis=1))
