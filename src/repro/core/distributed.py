"""Mesh-sharded vector search: partition-per-device serving.

The paper's billion-scale deployment note (§5.1) — "billion-scale indices
are typically partitioned or sharded in real-world systems" — is realized
here: the corpus is split into P shards, each device owns one shard's
index state, a query fans out to every shard, local top-k results come
back per shard, and a global top-k merge produces the answer.  Recall of
the merged result equals single-shard recall because every shard is
searched (SPANN-style partition serving).

Two shard-local engines:
 - "flat": exact blocked L2 scan (the memory-bandwidth-optimal TPU form);
 - `ShardedBackend`: P full `LSMVecIndex` shards behind the
   `VectorBackend` protocol (DESIGN.md §10) — hash-partitioned routing,
   per-shard updates/tombstones/consolidation, fan-out search.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.checkpoint import ckpt
from repro.core import hnsw
from repro.core.backend import (
    BackendStats,
    MaintenanceReport,
    SearchParams,
    SearchResult,
    UpdateResult,
    merge_topk,
    shard_of_seq,
)
from repro.core.index import LSMVecIndex
from repro.kernels.l2_distance.ref import l2_distance_ref


class ShardedFlatIndex:
    """Exact partitioned search over a device mesh axis."""

    def __init__(self, mesh, axis: str = "data"):
        self.mesh = mesh
        self.axis = axis
        self.vectors = None           # [P, n_per, d] sharded on axis 0
        self.n_per = 0

    def build(self, vectors: np.ndarray) -> "ShardedFlatIndex":
        p = self.mesh.devices.size
        n, d = vectors.shape
        n_per = -(-n // p)
        pad = n_per * p - n
        vecs = np.pad(vectors, ((0, pad), (0, 0)),
                      constant_values=np.inf).astype(np.float32)
        # inf-padding keeps padded rows out of every top-k
        arr = jnp.asarray(vecs.reshape(p, n_per, d))
        sharding = jax.sharding.NamedSharding(
            self.mesh, P(tuple(self.mesh.axis_names)))
        self.vectors = jax.device_put(arr, sharding)
        self.n_per = n_per
        self._search = self._make_search()
        return self

    def _make_search(self):
        mesh = self.mesh
        axes = tuple(mesh.axis_names)
        n_per = self.n_per
        p = mesh.devices.size

        def local(shard_id, vecs, queries):
            # shard_id [1] (this shard's slot — order-correct by
            # construction), vecs [1, n_per, d], queries [Q, d] replicated
            d2 = l2_distance_ref(queries, vecs[0])          # [Q, n_per]
            d2 = jnp.where(jnp.isfinite(d2), d2, jnp.inf)
            k = min(16, n_per)
            neg, idx = jax.lax.top_k(-d2, k)
            gids = idx + shard_id[0] * n_per                # global ids
            # gather every shard's candidates, merge
            all_d = jax.lax.all_gather(-neg, axes, tiled=False)
            all_i = jax.lax.all_gather(gids, axes, tiled=False)
            all_d = all_d.reshape(-1, *neg.shape)
            all_i = all_i.reshape(-1, *gids.shape)
            all_d = jnp.swapaxes(all_d, 0, 1).reshape(queries.shape[0], -1)
            all_i = jnp.swapaxes(all_i, 0, 1).reshape(queries.shape[0], -1)
            negd, pos = jax.lax.top_k(-all_d, 10)
            return jnp.take_along_axis(all_i, pos, axis=1), -negd

        fn = jax.shard_map(
            local, mesh=mesh,
            in_specs=(P(axes), P(axes), P()), out_specs=(P(), P()),
            check_vma=False)
        self._shard_ids = jax.device_put(
            jnp.arange(p, dtype=jnp.int32),
            jax.sharding.NamedSharding(self.mesh, P(axes)))
        return jax.jit(fn)

    def search(self, queries, k: int = 10) -> Tuple[np.ndarray, np.ndarray]:
        ids, dists = self._search(self._shard_ids, self.vectors,
                                  jnp.asarray(queries, jnp.float32))
        return np.asarray(ids)[:, :k], np.asarray(dists)[:, :k]


class ShardedDispatch:
    """`SearchHandle` over the per-shard in-flight handles.

    Dispatch already happened (all shards' device work is enqueued);
    `collect()` blocks shard by shard, maps local ids into the
    block-encoded global space, and runs the stable `merge_topk` host
    merge.  Total wait is the *max* shard latency, not the sum — the
    overlap the two-phase contract buys (DESIGN.md §13).
    """

    __slots__ = ("_handles", "_cap", "_k")

    def __init__(self, handles, cap: int, k: int):
        self._handles = handles
        self._cap = cap
        self._k = k

    def is_ready(self) -> bool:
        return all(h.is_ready() for h in self._handles)

    def collect(self) -> SearchResult:
        gids, dists = [], []
        for s, h in enumerate(self._handles):
            res = h.collect()
            base = np.int64(s) * self._cap
            gids.append(np.where(res.ids >= 0,
                                 res.ids.astype(np.int64) + base, -1))
            dists.append(res.dists)
        return merge_topk(gids, dists, self._k)


class ShardedBackend:
    """P independent LSM-VEC shards behind one `VectorBackend` surface.

    Promotes the old build+search-only `ShardedLSMVec` into a full
    backend (DESIGN.md §10): every shard is a complete `LSMVecIndex`
    (insert/delete/lazy-delete/consolidate/compact/reorder), committed
    round-robin to the available devices, and the class owns only
    routing and merging:

    - **id space** — block-encoded global ids: shard s's local id l is
      global id ``s * cfg.cap + l``.  With one shard the encoding is
      the identity, which is what makes shards=1 bit-parity with a bare
      `LSMVecIndex` (the acceptance anchor for the serve layer).
    - **routing** — a new vector goes to shard
      ``hash(allocation_seq) % P`` (`shard_of_seq`): deterministic,
      load-balanced, content-independent.  Deletes/reorders route by
      the shard block encoded in the id.
    - **search** — fan out the query batch to every shard; each shard
      computes its local top-k on device; the host merge
      (`merge_topk`) is a stable P-way merge of the distance-sorted
      rows.
    - **maintenance** — per-shard triggers: `consolidate(ratio=r)`
      consolidates exactly the shards whose own tombstone ratio
      reached r; `reorder` composes per-shard permutations into one
      global permutation for the serving layer's id map.
    """

    def __init__(self, cfg: hnsw.HNSWConfig, n_shards: int, *,
                 devices: Optional[Sequence] = None, seed: int = 0):
        self.cfg = cfg
        self.n_shards = n_shards
        self.seed = seed
        if devices is None:
            devices = jax.local_devices()
        self.devices = [devices[s % len(devices)] for s in range(n_shards)]
        # shard states are expensive (full cap-sized arrays per shard):
        # materialize lazily so build()/clone(), which install their own
        # shards, never pay for throwaway empties
        self._shards: Optional[list] = None
        self._n_routed = 0           # global allocation counter (routing)
        self._alloc: list[int] = []  # global ids in allocation order
        self.consolidations = [0] * n_shards   # per-shard maintenance log
        # overlapped consolidation: per-shard reports already claimed
        # while other shards' repairs are still in flight
        self._claimed: dict = {}

    def _empty_shard(self, s: int) -> LSMVecIndex:
        return LSMVecIndex(
            self.cfg, seed=self.seed + s,
            state=jax.device_put(
                hnsw.init(self.cfg, jax.random.key(self.seed + s)),
                self.devices[s]))

    @property
    def shards(self) -> list:
        if self._shards is None:
            self._shards = [self._empty_shard(s)
                            for s in range(self.n_shards)]
        return self._shards

    # -- construction ---------------------------------------------------------

    def build(self, vectors: np.ndarray, seed: int = 0) -> "ShardedBackend":
        """Bulk-build the shards from `vectors`, routed like a stream.

        Row j routes to `shard_of_seq(j)` — the same rule later inserts
        follow — so a build is indistinguishable from inserting the
        rows one by one.  `initial_ids()` returns the global id of each
        row in build order for seeding an external-id map.
        """
        n = len(vectors)
        vectors = np.asarray(vectors, np.float32)
        self.seed = seed
        asg = np.asarray(shard_of_seq(np.arange(n), self.n_shards))
        shards = []
        for s in range(self.n_shards):
            rows = np.flatnonzero(asg == s)
            if len(rows) == 0:
                shards.append(self._empty_shard(s))
                continue
            st = hnsw.bulk_build(self.cfg, jnp.asarray(vectors[rows]),
                                 jax.random.key(seed + s))
            shards.append(LSMVecIndex(
                self.cfg, seed=seed + s,
                state=jax.device_put(st, self.devices[s])))
        self._shards = shards
        local = np.zeros(n, np.int64)
        for s in range(self.n_shards):
            rows = np.flatnonzero(asg == s)
            local[rows] = np.arange(len(rows))
        self._alloc = (asg.astype(np.int64) * self.cfg.cap + local).tolist()
        self._n_routed = n
        return self

    # -- backend protocol -----------------------------------------------------

    @property
    def cap(self) -> int:
        return self.n_shards * self.cfg.cap

    @property
    def lazy_delete(self) -> bool:
        return self.cfg.lazy_delete

    @property
    def snapshot_stale(self) -> bool:
        return any(sh.snapshot_stale for sh in self.shards)

    def _split(self, gid: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Global id [N] -> (shard [N], local id [N]); -1 passes through."""
        gid = np.asarray(gid, np.int64)
        shard = np.where(gid >= 0, gid // self.cfg.cap, -1)
        local = np.where(gid >= 0, gid % self.cfg.cap, -1)
        return shard, local

    def dispatch_search(self, queries, k: Optional[int] = None, *,
                        params: Optional[SearchParams] = None
                        ) -> ShardedDispatch:
        """Two-phase fan-out (DESIGN.md §13): enqueue every shard's
        device-side local top-k *before* blocking on any result — the
        per-shard devices compute concurrently and `collect()` pays the
        max shard latency instead of the sum.  All per-query knobs
        forward to the shards unchanged, so the merged result at
        shards=1 is bit-identical to the single-device index."""
        k = k or self.cfg.k
        handles = [sh.dispatch_search(queries, k=k, params=params)
                   for sh in self.shards]
        return ShardedDispatch(handles, self.cfg.cap, k)

    def search(self, queries, k: Optional[int] = None, *,
               params: Optional[SearchParams] = None) -> SearchResult:
        """Fan-out search: dispatch to every shard, then the stable
        `merge_topk` host merge."""
        return self.dispatch_search(queries, k, params=params).collect()

    def insert_batch(self, xs, *,
                     pad_to: Optional[int] = None) -> UpdateResult:
        """Route each vector by its allocation sequence number, insert
        per shard in one padded device call each, and return the global
        ids in submission order."""
        xs = np.atleast_2d(np.asarray(xs, np.float32))
        if xs.size == 0:
            return UpdateResult(ids=np.zeros((0,), np.int64), n_applied=0)
        n = len(xs)
        asg = np.asarray(shard_of_seq(
            np.arange(self._n_routed, self._n_routed + n), self.n_shards))
        self._n_routed += n
        gids = np.full(n, -1, np.int64)
        for s in range(self.n_shards):
            rows = np.flatnonzero(asg == s)
            if len(rows) == 0:
                continue
            res = self.shards[s].insert_batch(xs[rows], pad_to=pad_to)
            gids[rows] = np.asarray(res.ids, np.int64) \
                + np.int64(s) * self.cfg.cap
        # allocation order = submission order (ids are assigned in the
        # order each shard's sub-batch preserves); one batched host
        # conversion, not one numpy-scalar unboxing per id
        self._alloc.extend(gids.tolist())
        return UpdateResult(ids=gids, n_applied=n)

    def delete_batch(self, ids, *,
                     pad_to: Optional[int] = None) -> UpdateResult:
        """Route global ids to their owning shard blocks; negative or
        out-of-range ids are masked no-ops (the pad-and-mask serving
        contract) and are excluded from `n_applied`."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        if len(ids) == 0:
            return UpdateResult(ids=ids, n_applied=0)
        shard, local = self._split(ids)
        routable = (shard >= 0) & (shard < self.n_shards)
        for s in range(self.n_shards):
            sub = local[shard == s]
            if len(sub):
                self.shards[s].delete_batch(sub.astype(np.int32),
                                            pad_to=pad_to)
        return UpdateResult(ids=ids, n_applied=int(routable.sum()))

    def maintain(self, op: str, **params) -> MaintenanceReport:
        """Uniform maintenance over all shards (`VectorBackend`
        protocol).  Per-shard reports aggregate componentwise; for
        "reorder" the per-shard permutations compose into one global
        permutation exactly as the legacy method did."""
        if op == "consolidate":
            # overlapped repairs still in flight ARE this consolidation:
            # claim them, then run the sync trigger on the rest
            pre = self.poll_maintain(block=True)
            total = pre.reclaimed if pre is not None else 0
            total += self.consolidate(ratio=params.get("ratio"))
            return MaintenanceReport(op=op, applied=total > 0,
                                     reclaimed=total)
        if op == "compact":
            self.compact()
            return MaintenanceReport(op=op, applied=True)
        if op == "reorder":
            perm = self.reorder(window=int(params.get("window", 8)),
                                lam=float(params.get("lam", 1.0)))
            return MaintenanceReport(op=op, applied=True, perm=perm)
        if op == "tier":
            moved = self.tier_maintain(params["policy"])
            return MaintenanceReport(
                op=op, applied=(moved["demoted"] + moved["promoted"]) > 0,
                demoted=moved["demoted"], promoted=moved["promoted"])
        raise ValueError(f"unknown maintenance op {op!r}")

    def begin_maintain(self, op: str, **params) -> bool:
        """Start overlapped consolidation on every shard whose own
        tombstone-ratio trigger passes (each repair runs on that
        shard's device, concurrent with fan-out queries).  True iff at
        least one shard started."""
        if op != "consolidate":
            return False
        started = False
        for sh in self.shards:
            started |= sh.begin_maintain(op, **params)
        return started

    def poll_maintain(self, *, block: bool = False
                      ) -> Optional[MaintenanceReport]:
        """Claim finished per-shard repairs; once no shard repair is
        left in flight, return the aggregated report (None while any is
        still running, or when nothing was pending at all)."""
        for s, sh in enumerate(self.shards):
            rep = sh.poll_maintain(block=block)
            if rep is not None and rep.applied:
                self.consolidations[s] += 1
                self._claimed[s] = rep
        if any(sh.maintenance_pending for sh in self.shards):
            return None
        if not self._claimed:
            return None
        claimed, self._claimed = self._claimed, {}
        return MaintenanceReport(
            op="consolidate", applied=True,
            reclaimed=sum(r.reclaimed for r in claimed.values()),
            detail={"overlapped": True, "shards": sorted(claimed)})

    @property
    def maintenance_pending(self) -> bool:
        """A repair is in flight or a finished report awaits claim."""
        return bool(self._claimed) or any(sh.maintenance_pending
                                          for sh in self.shards)

    def consolidate(self, *, ratio: Optional[float] = None) -> int:
        """Per-shard trigger rule: each shard consolidates iff its own
        tombstone ratio reached `ratio` (None = every shard with any
        tombstones).  Returns total slots reclaimed.  Deprecated entry
        point — prefer `maintain("consolidate", ratio=...)`."""
        total = 0
        for s, sh in enumerate(self.shards):
            got = sh.consolidate(ratio=ratio)
            if got:
                self.consolidations[s] += 1
            total += got
        return total

    def compact(self) -> None:
        for sh in self.shards:
            sh.compact()

    def reorder(self, *, window: int = 8, lam: float = 1.0) -> np.ndarray:
        """Per-shard relayout composed into one global permutation
        (identity outside the permuted per-shard prefixes), so the
        serving layer folds it into its id map exactly like the
        single-device case."""
        perm = np.arange(self.cap, dtype=np.int64)
        for s, sh in enumerate(self.shards):
            ps = np.asarray(sh.reorder(window=window, lam=lam), np.int64)
            base = np.int64(s) * self.cfg.cap
            perm[base:base + len(ps)] = base + ps
        return perm

    def stats(self) -> BackendStats:
        full = [sh.stats() for sh in self.shards]
        per = tuple(f.shards[0] for f in full)
        mem = full[0].memory
        for f in full[1:]:
            mem = mem + f.memory
        return BackendStats(
            size=sum(p.size for p in per),
            n_tombstones=sum(p.n_tombstones for p in per),
            delete_noops=sum(p.delete_noops for p in per),
            max_tombstone_ratio=max(p.tombstone_ratio for p in per),
            shards=per, memory=mem)

    def tier_maintain(self, policy) -> dict:
        """Run the tier policy on every shard (each shard holds its own
        hot budget — heat is shard-local, like the consolidate trigger).
        Returns total moves across shards."""
        moved = {"demoted": 0, "promoted": 0}
        for sh in self.shards:
            got = sh.tier_maintain(policy)
            for k in moved:
                moved[k] += got[k]
        return moved

    def heat_total(self) -> int:
        return sum(sh.heat_total() for sh in self.shards)

    def reset_heat(self) -> None:
        for sh in self.shards:
            sh.reset_heat()

    def initial_ids(self) -> np.ndarray:
        return np.asarray(self._alloc, np.int64)

    def trace_counts(self) -> dict:
        """Compiled-variant counts summed across shards (the serve
        zero-retrace proof compares totals before/after load)."""
        out: dict = {}
        for sh in self.shards:
            for key, v in sh.trace_counts().items():
                out[key] = out.get(key, 0) + v
        return out

    def sync(self) -> None:
        for sh in self.shards:
            sh.sync()

    def clone(self) -> "ShardedBackend":
        """Deep-copy shard states into a fresh backend (fresh jit
        caches; benchmark trials use this to undo donation).  Per-shard
        RNG seeds, routing state, and the maintenance log carry over."""
        other = ShardedBackend(self.cfg, self.n_shards,
                               devices=self.devices, seed=self.seed)
        other._shards = [sh.clone() for sh in self.shards]
        for s, sh in enumerate(other._shards):
            sh.state = jax.device_put(sh.state, self.devices[s])
        other._n_routed = self._n_routed
        other._alloc = list(self._alloc)
        other.consolidations = list(self.consolidations)
        return other

    # -- durability (DESIGN.md §11) -------------------------------------------

    def save(self, ckpt_dir: str, *, lsn: int = 0,
             extra: Optional[dict] = None, meta: Optional[dict] = None,
             keep: int = 3, _pre_publish=None) -> str:
        """Atomic whole-backend checkpoint: per-shard subdirs + a
        shard-layout manifest, staged and renamed as one unit.

        Layout under ``step_<lsn>/``: ``shard_XX/`` (each shard's own
        `LSMVecIndex.save`), ``engine/`` (caller `extra` arrays),
        ``alloc.npz`` (global ids in allocation order) and
        ``layout.json`` recording shard count, routing counter and the
        covering LSN.  A restore validates the layout against the
        target config/shard count, so a checkpoint can never be loaded
        into a mis-sharded backend (routing would silently diverge).
        """
        self.sync()
        os.makedirs(ckpt_dir, exist_ok=True)
        ckpt.sweep_stale_tmp(ckpt_dir)
        final = os.path.join(ckpt_dir, f"step_{int(lsn):08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp)
        for s, sh in enumerate(self.shards):
            sh.save(os.path.join(tmp, f"shard_{s:02d}"), lsn=lsn, keep=1)
        if extra:
            ckpt.save_checkpoint(
                os.path.join(tmp, "engine"), step=int(lsn),
                tree={k: np.asarray(v) for k, v in extra.items()},
                metadata={}, keep=1)
        layout = {"n_shards": self.n_shards, "cap": self.cfg.cap,
                  "dim": self.cfg.dim, "lsn": int(lsn), "seed": self.seed,
                  "n_routed": self._n_routed,
                  "consolidations": list(self.consolidations),
                  "metadata": meta or {}}
        with open(os.path.join(tmp, "alloc.npz"), "wb") as f:
            np.savez(f, alloc=np.asarray(self._alloc, np.int64))
            f.flush()
            os.fsync(f.fileno())
        with open(os.path.join(tmp, "layout.json"), "w") as f:
            json.dump(layout, f)
            f.flush()
            os.fsync(f.fileno())
        if _pre_publish is not None:
            _pre_publish()
        os.rename(tmp, final)   # atomic publish
        fd = os.open(ckpt_dir, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
        steps = sorted(ckpt._list_steps(ckpt_dir))
        for st in steps[:-keep]:
            shutil.rmtree(os.path.join(ckpt_dir, f"step_{st:08d}"),
                          ignore_errors=True)
        return final

    @classmethod
    def restore(cls, cfg: hnsw.HNSWConfig, ckpt_dir: str, *,
                n_shards: Optional[int] = None,
                devices: Optional[Sequence] = None,
                step: Optional[int] = None
                ) -> Tuple["ShardedBackend", dict, dict]:
        """Rebuild the backend from its latest (or `step`-th) checkpoint.

        Refuses a layout mismatch: shard count (if the caller states an
        expectation), cap/dim vs `cfg`, and each shard's covering LSN vs
        the layout's — a torn multi-shard state must never restore.
        Returns (backend, metadata, extras) like `LSMVecIndex.restore`.
        """
        if step is None:
            step = ckpt.latest_step(ckpt_dir)
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
        path = os.path.join(ckpt_dir, f"step_{step:08d}")
        with open(os.path.join(path, "layout.json")) as f:
            layout = json.load(f)
        if n_shards is not None and n_shards != layout["n_shards"]:
            raise ValueError(f"checkpoint has {layout['n_shards']} shards, "
                             f"caller expects {n_shards}")
        if layout["cap"] != cfg.cap or layout["dim"] != cfg.dim:
            raise ValueError(
                f"checkpoint cap/dim ({layout['cap']}/{layout['dim']}) "
                f"!= config ({cfg.cap}/{cfg.dim})")
        be = cls(cfg, layout["n_shards"], devices=devices,
                 seed=int(layout["seed"]))
        shards = []
        for s in range(be.n_shards):
            sh, smd, _ = LSMVecIndex.restore(
                cfg, os.path.join(path, f"shard_{s:02d}"))
            if int(smd["lsn"]) != int(layout["lsn"]):
                raise ValueError(f"shard {s} covering lsn {smd['lsn']} != "
                                 f"layout {layout['lsn']} (torn checkpoint)")
            sh.state = jax.device_put(sh.state, be.devices[s])
            shards.append(sh)
        be._shards = shards
        be._n_routed = int(layout["n_routed"])
        be._alloc = np.load(os.path.join(path, "alloc.npz"))["alloc"].tolist()
        be.consolidations = [int(c) for c in layout["consolidations"]]
        extras = {}
        eng_dir = os.path.join(path, "engine")
        if os.path.isdir(eng_dir):
            extras, _, _ = ckpt.load_arrays(eng_dir)
        metadata = {**layout["metadata"], "lsn": int(layout["lsn"])}
        return be, metadata, extras

    # -- aggregate accounting -------------------------------------------------

    def reset_stats(self) -> None:
        for sh in self.shards:
            sh.reset_stats()

    def io_cost(self, model=None) -> float:
        from repro.core import iostats
        model = model or iostats.DISK
        return sum(sh.io_cost(model) for sh in self.shards)

    def memory_breakdown(self):
        mem = self.shards[0].memory_breakdown()
        for sh in self.shards[1:]:
            mem = mem + sh.memory_breakdown()
        return mem

    def memory_bytes(self) -> int:
        return sum(sh.memory_bytes() for sh in self.shards)

    @property
    def size(self) -> int:
        return sum(sh.size for sh in self.shards)

    @property
    def n_tombstones(self) -> int:
        return sum(sh.n_tombstones for sh in self.shards)
