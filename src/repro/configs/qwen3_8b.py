"""qwen3-8b [dense] — qk_norm, GQA.

36L d_model=4096 32H (kv=8, head_dim=128) d_ff=12288 vocab=151936
[hf:Qwen/Qwen3-8B; hf].
"""

import jax.numpy as jnp

from repro.models.transformer import ModelConfig


def full(dtype=jnp.bfloat16) -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b", family="dense",
        num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8,
        head_dim_override=128, d_ff=12288, vocab_size=151936,
        qk_norm=True, rope_theta=1e6,
        param_dtype=dtype, act_dtype=dtype)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim_override=16, d_ff=128, vocab_size=128,
        qk_norm=True, scan_chunk=8, attn_chunk=64, remat=False)
