"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.

61L d_model=7168 128H d_ff(expert)=2048 vocab=129280 [arXiv:2412.19437; hf].
Dense prefix: first 3 layers use d_ff=18432 (the HF config's
intermediate_size); MoE layers use 2048-wide experts.  V3 routes with
sigmoid scores + normalized top-k and trains with an MTP head.
"""

import jax.numpy as jnp

from repro.models.transformer import ModelConfig, MoEConfig


def full(dtype=jnp.bfloat16) -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
        d_ff=18432, vocab_size=129280,
        attention="mla", q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
        rope_theta=1e4,
        moe=MoEConfig(num_experts=256, top_k=8, num_shared=1,
                      d_ff_expert=2048, first_dense=3,
                      router_score="sigmoid", norm_topk=True),
        mtp=True,
        param_dtype=dtype, act_dtype=dtype)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-smoke", family="moe",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=128,
        attention="mla", q_lora_rank=32, kv_lora_rank=16,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        moe=MoEConfig(num_experts=8, top_k=2, num_shared=1,
                      d_ff_expert=32, first_dense=1,
                      router_score="sigmoid", norm_topk=True,
                      capacity_factor=8.0),
        mtp=True, scan_chunk=8, attn_chunk=64, remat=False)
