"""musicgen-large [audio] — decoder-only over EnCodec tokens.

48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048 [arXiv:2306.05284; hf].
Backbone only per the brief: the EnCodec audio frontend is a stub —
`input_specs()` supplies the precomputed token stream (the delay-pattern
flattened codebook ids), and audio reconstruction is out of scope.
"""

import jax.numpy as jnp

from repro.models.transformer import ModelConfig


def full(dtype=jnp.bfloat16) -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="audio",
        num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=2048,
        rope_theta=1e4,
        param_dtype=dtype, act_dtype=dtype)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke", family="audio",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=64,
        scan_chunk=8, attn_chunk=64, remat=False)
