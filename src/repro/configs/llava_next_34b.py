"""llava-next-34b [vlm] — anyres tiling VLM backbone.

60L d_model=7168 56H (kv=8) d_ff=20480 vocab=64000
[hf:llava-hf/llava-v1.6 family; unverified].  Backbone only per the brief:
the vision tower is a stub — `input_specs()` supplies 576 precomputed
patch embeddings per request, prepended to the text sequence.
"""

import jax.numpy as jnp

from repro.models.transformer import ModelConfig


def full(dtype=jnp.bfloat16) -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b", family="vlm",
        num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
        head_dim_override=128, d_ff=20480, vocab_size=64000,
        num_img_tokens=576, rope_theta=5e6,
        param_dtype=dtype, act_dtype=dtype)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llava-next-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim_override=16, d_ff=128, vocab_size=128,
        num_img_tokens=8, scan_chunk=8, attn_chunk=64, remat=False)
