"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6.

60L d_model=5120 128H d_ff(expert)=1536 vocab=102400 [arXiv:2405.04434; hf].
Dense prefix: first layer d_ff=12288; softmax router with normalized top-k.
"""

import jax.numpy as jnp

from repro.models.transformer import ModelConfig, MoEConfig


def full(dtype=jnp.bfloat16) -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
        d_ff=12288, vocab_size=102400,
        attention="mla", q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
        rope_theta=1e4,
        moe=MoEConfig(num_experts=160, top_k=6, num_shared=2,
                      d_ff_expert=1536, first_dense=1,
                      router_score="softmax", norm_topk=True),
        param_dtype=dtype, act_dtype=dtype)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=128,
        attention="mla", q_lora_rank=32, kv_lora_rank=16,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        moe=MoEConfig(num_experts=4, top_k=2, num_shared=2,
                      d_ff_expert=32, first_dense=1,
                      router_score="softmax", norm_topk=True,
                      capacity_factor=8.0),
        scan_chunk=8, attn_chunk=64, remat=False)
