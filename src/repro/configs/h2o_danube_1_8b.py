"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attn.

24L d_model=2560 32H (kv=8) d_ff=6912 vocab=32000 [arXiv:2401.16818; hf].
SWA window 4096 bounds the KV cache, which is why this arch runs the
long_500k cell (DESIGN.md §7).
"""

import jax.numpy as jnp

from repro.models.transformer import ModelConfig


def full(dtype=jnp.bfloat16) -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b", family="dense",
        num_layers=24, d_model=2560, num_heads=32, num_kv_heads=8,
        d_ff=6912, vocab_size=32000,
        window=4096, rope_theta=1e4,
        param_dtype=dtype, act_dtype=dtype)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=128,
        window=16, scan_chunk=8, attn_chunk=32, remat=False)
