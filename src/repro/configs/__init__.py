"""Architecture registry: 10 assigned archs + the paper's own index config.

`get_config(name, preset)` returns a ModelConfig; preset "full" is the
published configuration (dry-run only — ShapeDtypeStructs, no allocation),
preset "smoke" is a reduced same-family config runnable on CPU.
"""

from __future__ import annotations

import importlib

from repro.models.transformer import ModelConfig

ARCHS = {
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "stablelm-3b": "repro.configs.stablelm_3b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "musicgen-large": "repro.configs.musicgen_large",
    "llava-next-34b": "repro.configs.llava_next_34b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
}

# (seq_len, global_batch, kind); kind selects which step gets lowered
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def get_config(name: str, preset: str = "full", **kw) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    mod = importlib.import_module(ARCHS[name])
    return getattr(mod, preset)(**kw)


def runs_cell(cfg: ModelConfig, shape_name: str) -> bool:
    """long_500k requires a sub-quadratic mechanism (DESIGN.md §7)."""
    if shape_name == "long_500k":
        return cfg.sub_quadratic
    return True
