"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention block.

81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000 ssm_state=64
[arXiv:2411.15242; unverified].  One shared attention+FFN block is applied
every 6 Mamba2 layers (the published model alternates two shared blocks;
we use one — noted in DESIGN.md).  Sub-quadratic: runs long_500k.
"""

import jax.numpy as jnp

from repro.models.transformer import ModelConfig, SSMConfig


def full(dtype=jnp.bfloat16) -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid",
        num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
        d_ff=14336, vocab_size=32000,
        block_pattern="zamba", shared_attn_every=6,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64),
        param_dtype=dtype, act_dtype=dtype)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=128,
        block_pattern="zamba", shared_attn_every=3,
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=16),
        scan_chunk=8, attn_chunk=64, remat=False)
