"""stablelm-3b [dense] — MHA with partial rotary (25%).

32L d_model=2560 32H (kv=32) d_ff=6912 vocab=50304
[hf:stabilityai/stablelm-2 family; unverified].
"""

import jax.numpy as jnp

from repro.models.transformer import ModelConfig


def full(dtype=jnp.bfloat16) -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b", family="dense",
        num_layers=32, d_model=2560, num_heads=32, num_kv_heads=32,
        d_ff=6912, vocab_size=50304,
        rotary_pct=0.25, rope_theta=1e4,
        param_dtype=dtype, act_dtype=dtype)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=128,
        rotary_pct=0.25, scan_chunk=8, attn_chunk=64, remat=False)
