"""rwkv6-3b [ssm] — Finch: attention-free, data-dependent decay.

32L d_model=2560 d_ff=8960 vocab=65536 [arXiv:2404.05892; hf].
head_dim 64 => 40 heads.  O(1)-state decode: runs long_500k.
"""

import jax.numpy as jnp

from repro.models.transformer import ModelConfig, SSMConfig


def full(dtype=jnp.bfloat16) -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", family="ssm",
        num_layers=32, d_model=2560, num_heads=40, num_kv_heads=40,
        d_ff=8960, vocab_size=65536,
        block_pattern="rwkv",
        ssm=SSMConfig(head_dim=64),
        param_dtype=dtype, act_dtype=dtype)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke", family="ssm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=128,
        block_pattern="rwkv",
        ssm=SSMConfig(head_dim=16),
        scan_chunk=8, attn_chunk=64, remat=False)
