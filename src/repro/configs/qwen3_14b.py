"""qwen3-14b [dense] — qk_norm, GQA.

40L d_model=5120 40H (kv=8, head_dim=128) d_ff=17408 vocab=151936
[hf:Qwen/Qwen3-8B family; hf].
"""

import jax.numpy as jnp

from repro.models.transformer import ModelConfig


def full(dtype=jnp.bfloat16) -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b", family="dense",
        num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8,
        head_dim_override=128, d_ff=17408, vocab_size=151936,
        qk_norm=True, rope_theta=1e6,
        param_dtype=dtype, act_dtype=dtype)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b-smoke", family="dense",
        num_layers=2, d_model=80, num_heads=5, num_kv_heads=1,
        head_dim_override=16, d_ff=160, vocab_size=128,
        qk_norm=True, scan_chunk=8, attn_chunk=64, remat=False)
