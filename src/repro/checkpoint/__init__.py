"""Atomic, shard-aware checkpointing."""

from repro.checkpoint.ckpt import (
    latest_step,
    load_arrays,
    restore_checkpoint,
    save_checkpoint,
    sweep_stale_tmp,
)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "load_arrays", "sweep_stale_tmp"]
