"""Atomic pytree checkpointing with a manifest, built for restart-ability.

Design points that matter at cluster scale (and are exercised in tests):
 - *atomicity*: a checkpoint directory is staged under `step_<N>.tmp` and
   os.rename'd into place only after every array and the manifest are
   fsync'd — a crash mid-save can never corrupt the latest checkpoint;
 - *logical layout*: arrays are saved by pytree path with their *global*
   shape, not their device layout, so a restart may use a different mesh
   or host count (elastic resume) — resharding happens at load;
 - *self-describing*: manifest.json records step, tree structure, dtypes
   and user metadata (data-pipeline cursor, RNG key, mesh shape at save);
 - retention: keep the last `keep` checkpoints, delete older ones.

Multi-host note: on a real cluster each host writes only the shards it
owns (jax.experimental.multihost_utils / array_serialization); this
container is single-host so process-0 writes everything, but the layout
and the restore path are the multi-host ones.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    metadata: Optional[Dict] = None, *,
                    keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, _ = _flatten_with_paths(tree)
    names = {}
    arrays = {}
    for i, (key, leaf) in enumerate(leaves):
        arr_name = f"arr_{i:05d}"
        arr = np.asarray(jax.device_get(leaf))
        entry = {"file": arr_name, "dtype": str(np.dtype(leaf.dtype)),
                 "shape": list(np.shape(leaf))}
        if arr.dtype.kind not in "biufc":     # ml_dtypes (bf16 etc.)
            store_as = np.dtype(f"u{arr.dtype.itemsize}")
            entry["stored_as"] = str(store_as)
            arr = arr.view(store_as)
        names[key] = entry
        arrays[arr_name] = arr
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": step, "entries": names,
                "metadata": metadata or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, final)   # atomic publish

    # retention
    steps = sorted(_list_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
    return final


def _list_steps(ckpt_dir: str):
    out = []
    if not os.path.isdir(ckpt_dir):
        return out
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _list_steps(ckpt_dir)
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, target: Any,
                       step: Optional[int] = None
                       ) -> Tuple[Any, Dict, int]:
    """Restore into the structure of `target` (a pytree of arrays or
    ShapeDtypeStructs).  Returns (tree, metadata, step)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    leaves, treedef = _flatten_with_paths(target)
    restored = []
    for key, leaf in leaves:
        if key not in manifest["entries"]:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        ent = manifest["entries"][key]
        arr = data[ent["file"]]
        if "stored_as" in ent:
            import ml_dtypes
            arr = arr.view(getattr(ml_dtypes, ent["dtype"]))
        if list(arr.shape) != list(np.shape(leaf)):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != target "
                f"{np.shape(leaf)}")
        restored.append(jax.numpy.asarray(arr).astype(ent["dtype"]))
    tree = jax.tree_util.tree_unflatten(treedef, restored)
    return tree, manifest["metadata"], step
