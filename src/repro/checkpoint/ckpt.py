"""Atomic pytree checkpointing with a manifest, built for restart-ability.

Design points that matter at cluster scale (and are exercised in tests):
 - *atomicity*: a checkpoint directory is staged under `step_<N>.tmp` and
   os.rename'd into place only after every array and the manifest are
   fsync'd — a crash mid-save can never corrupt the latest checkpoint;
 - *logical layout*: arrays are saved by pytree path with their *global*
   shape, not their device layout, so a restart may use a different mesh
   or host count (elastic resume) — resharding happens at load;
 - *self-describing*: manifest.json records step, tree structure, dtypes
   and user metadata (data-pipeline cursor, RNG key, mesh shape at save);
 - retention: keep the last `keep` checkpoints, delete older ones.

Multi-host note: on a real cluster each host writes only the shards it
owns (jax.experimental.multihost_utils / array_serialization); this
container is single-host so process-0 writes everything, but the layout
and the restore path are the multi-host ones.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def sweep_stale_tmp(ckpt_dir: str) -> int:
    """Remove leftover ``step_*.tmp`` staging dirs from crashed saves.

    A save that died mid-stage leaves its tmp dir behind; it can never
    shadow a published checkpoint (``_list_steps`` skips ``.tmp``), but
    it wastes space and a same-step retry should not trip over it.
    Called on every save and safe to call before any restore.  Returns
    the number of stale dirs removed.
    """
    n = 0
    if not os.path.isdir(ckpt_dir):
        return n
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and name.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)
            n += 1
    return n


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    metadata: Optional[Dict] = None, *,
                    keep: int = 3,
                    _pre_publish: Optional[Callable[[], None]] = None) -> str:
    """Stage under ``step_<N>.tmp``, fsync every file, rename into place.

    ``_pre_publish`` is a failure-injection hook invoked after the stage
    is complete (arrays + manifest fsync'd) but *before* the atomic
    rename — the crash-recovery harness uses it to prove a mid-checkpoint
    crash leaves the previous checkpoint untouched.
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    sweep_stale_tmp(ckpt_dir)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp)

    leaves, _ = _flatten_with_paths(tree)
    names = {}
    arrays = {}
    for i, (key, leaf) in enumerate(leaves):
        arr_name = f"arr_{i:05d}"
        arr = np.asarray(jax.device_get(leaf))
        entry = {"file": arr_name, "dtype": str(np.dtype(leaf.dtype)),
                 "shape": list(np.shape(leaf))}
        if arr.dtype.kind not in "biufc":     # ml_dtypes (bf16 etc.)
            store_as = np.dtype(f"u{arr.dtype.itemsize}")
            entry["stored_as"] = str(store_as)
            arr = arr.view(store_as)
        names[key] = entry
        arrays[arr_name] = arr
    with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    manifest = {"step": step, "entries": names,
                "metadata": metadata or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if _pre_publish is not None:
        _pre_publish()
    os.rename(tmp, final)   # atomic publish
    _fsync_dir(ckpt_dir)    # the rename itself must survive a crash

    # retention: keep the newest `keep` published checkpoints.  Stale
    # .tmp dirs were swept above; unknown names are skipped by
    # _list_steps and rmtree tolerates concurrent disappearance.
    steps = sorted(_list_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
    return final


def _list_steps(ckpt_dir: str):
    out = []
    if not os.path.isdir(ckpt_dir):
        return out
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _list_steps(ckpt_dir)
    return max(steps) if steps else None


def load_arrays(ckpt_dir: str, step: Optional[int] = None
                ) -> Tuple[Dict[str, np.ndarray], Dict, int]:
    """Target-free restore: read every leaf of a checkpoint as a flat
    ``{path: np.ndarray}`` dict straight from the manifest (dtype/shape
    come from the manifest entries, including the ml_dtypes stored-as
    path).  Returns (arrays, metadata, step).

    Backend `restore()` implementations use this because their target
    structure (HNSWState shapes) is derived from config, not from a
    live template tree.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    out: Dict[str, np.ndarray] = {}
    for key, ent in manifest["entries"].items():
        arr = data[ent["file"]]
        if "stored_as" in ent:
            import ml_dtypes
            arr = arr.view(getattr(ml_dtypes, ent["dtype"]))
        if list(arr.shape) != ent["shape"]:
            raise ValueError(f"{key}: array shape {list(arr.shape)} != "
                             f"manifest {ent['shape']}")
        out[key] = arr
    return out, manifest["metadata"], step


def restore_checkpoint(ckpt_dir: str, target: Any,
                       step: Optional[int] = None
                       ) -> Tuple[Any, Dict, int]:
    """Restore into the structure of `target` (a pytree of arrays or
    ShapeDtypeStructs).  Returns (tree, metadata, step)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    leaves, treedef = _flatten_with_paths(target)
    restored = []
    for key, leaf in leaves:
        if key not in manifest["entries"]:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        ent = manifest["entries"][key]
        arr = data[ent["file"]]
        if "stored_as" in ent:
            import ml_dtypes
            arr = arr.view(getattr(ml_dtypes, ent["dtype"]))
        if list(arr.shape) != list(np.shape(leaf)):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != target "
                f"{np.shape(leaf)}")
        restored.append(jax.numpy.asarray(arr).astype(ent["dtype"]))
    tree = jax.tree_util.tree_unflatten(treedef, restored)
    return tree, manifest["metadata"], step
