"""Composable decoder stack driving all 10 assigned architectures.

A ModelConfig describes the block pattern:
 - "uniform": [attention + FFN] x L, with a dense prefix and an MoE tail
   when cfg.moe is set (DeepSeek layouts);
 - "zamba":   Mamba2 backbone with one *shared* attention+FFN block applied
   every `shared_attn_every` layers (Zamba2);
 - "rwkv":    [time-mix + channel-mix] x L (RWKV6).

Layers of each group are stacked on a leading axis and driven by
`jax.lax.scan` (small HLO even at 61-81 layers), with optional per-layer
remat.  Three entry points per model:
 - `forward`    — full-sequence training pass -> logits (+ MoE aux loss)
 - `prefill`    — forward + decode-cache construction
 - `decode_step`— one token against the cache/state (serve_step)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod, layers, moe as moe_mod, rwkv as rwkv_mod, ssm
from repro.models.partition import constrain, gather_fsdp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared: int = 0
    d_ff_expert: int = 0
    first_dense: int = 0            # leading dense-FFN layers
    capacity_factor: float = 1.25
    router_score: str = "softmax"   # "softmax" (V2) | "sigmoid" (V3)
    norm_topk: bool = True


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense|moe|hybrid|ssm|audio|vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim_override: Optional[int] = None
    attention: str = "gqa"          # gqa | mla
    window: Optional[int] = None    # sliding-window width
    qk_norm: bool = False
    rotary_pct: float = 1.0
    rope_theta: float = 1e4
    # MLA
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # sub-structures
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    block_pattern: str = "uniform"  # uniform | zamba | rwkv
    shared_attn_every: int = 6
    # modality stubs
    num_img_tokens: int = 0         # vlm: precomputed patch-embedding prefix
    # numerics / impl
    norm_eps: float = 1e-6
    param_dtype: Any = jnp.float32
    act_dtype: Any = jnp.float32
    scan_chunk: int = 64
    attn_chunk: int = 1024
    remat: bool = True
    tie_embeddings: bool = False
    mtp: bool = False               # DeepSeek-V3 multi-token prediction
    mtp_weight: float = 0.3
    aux_loss_weight: float = 0.001

    @property
    def head_dim(self) -> int:
        return self.head_dim_override or self.d_model // self.num_heads

    @property
    def rotary_dim(self) -> int:
        rd = int(self.head_dim * self.rotary_pct)
        return rd - rd % 2

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve 500k+ contexts? (SSM/hybrid state or SWA)."""
        return self.block_pattern in ("zamba", "rwkv") or \
            self.window is not None


# ---------------------------------------------------------------------------
# block definitions
# ---------------------------------------------------------------------------

def _init_attn_block(key, cfg: ModelConfig, use_moe: bool) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    p: Dict[str, Any] = {"ln1": jnp.ones((cfg.d_model,), cfg.param_dtype),
                         "ln2": jnp.ones((cfg.d_model,), cfg.param_dtype)}
    p["attn"] = attn_mod.init_mla(k1, cfg) if cfg.attention == "mla" \
        else attn_mod.init_gqa(k1, cfg)
    if use_moe:
        p["moe"] = moe_mod.init_moe(k2, cfg)
    else:
        p["ffn"] = {
            "gate": layers.dense_init(k2, (cfg.d_model, cfg.d_ff), 0,
                                      cfg.param_dtype),
            "up": layers.dense_init(k3, (cfg.d_model, cfg.d_ff), 0,
                                    cfg.param_dtype),
            "down": layers.dense_init(
                jax.random.fold_in(k3, 1), (cfg.d_ff, cfg.d_model), 0,
                cfg.param_dtype),
        }
    return p


def _attn_block(p, cfg: ModelConfig, x, positions, cache, use_moe: bool):
    if cache is None:           # train/prefill: FSDP gather-at-use
        p = gather_fsdp(p)
    attn_fn = attn_mod.mla if cfg.attention == "mla" else attn_mod.gqa
    h, new_cache = attn_fn(p["attn"], cfg,
                           layers.rms_norm(x, p["ln1"], cfg.norm_eps),
                           positions, cache)
    x = x + h
    hn = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    if use_moe:
        f, aux = moe_mod.moe_ffn(p["moe"], cfg, hn)
    else:
        f = layers.swiglu(hn, p["ffn"]["gate"], p["ffn"]["up"],
                          p["ffn"]["down"])
        aux = jnp.zeros((), jnp.float32)
    return x + f, new_cache, aux


def _init_mamba_block(key, cfg) -> Dict[str, Any]:
    return {"ln": jnp.ones((cfg.d_model,), cfg.param_dtype),
            "mix": ssm.init_mamba2(key, cfg)}


def _mamba_block(p, cfg, x, state):
    if state is None:
        p = gather_fsdp(p)
    h, new_state = ssm.mamba2(p["mix"], cfg,
                              layers.rms_norm(x, p["ln"], cfg.norm_eps),
                              state)
    return x + h, new_state, jnp.zeros((), jnp.float32)


def _init_rwkv_block(key, cfg) -> Dict[str, Any]:
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.ones((cfg.d_model,), cfg.param_dtype),
            "ln2": jnp.ones((cfg.d_model,), cfg.param_dtype),
            "att": rwkv_mod.init_rwkv_time_mix(k1, cfg),
            "ffn": rwkv_mod.init_rwkv_channel_mix(k2, cfg)}


def _rwkv_block(p, cfg, x, state):
    if state is None:
        p = gather_fsdp(p)
    h, new_att = rwkv_mod.rwkv_time_mix(
        p["att"], cfg, layers.rms_norm(x, p["ln1"], cfg.norm_eps), state)
    x = x + h
    f, carry_ffn = rwkv_mod.rwkv_channel_mix(
        p["ffn"], cfg, layers.rms_norm(x, p["ln2"], cfg.norm_eps), state)
    new_state = {**new_att, "shift_ffn": carry_ffn}
    return x + f, new_state, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# group layout
# ---------------------------------------------------------------------------

def _groups(cfg: ModelConfig):
    """[(group_name, kind, n_layers)] driving init/forward/decode."""
    if cfg.block_pattern == "uniform":
        if cfg.moe and cfg.moe.first_dense < cfg.num_layers:
            g = []
            if cfg.moe.first_dense:
                g.append(("dense", "attn_dense", cfg.moe.first_dense))
            g.append(("moe", "attn_moe",
                      cfg.num_layers - cfg.moe.first_dense))
            return g
        return [("layers", "attn_dense", cfg.num_layers)]
    if cfg.block_pattern == "zamba":
        return [("mamba", "mamba", cfg.num_layers)]
    if cfg.block_pattern == "rwkv":
        return [("layers", "rwkv", cfg.num_layers)]
    raise ValueError(cfg.block_pattern)


_INIT = {"attn_dense": lambda k, c: _init_attn_block(k, c, False),
         "attn_moe": lambda k, c: _init_attn_block(k, c, True),
         "mamba": _init_mamba_block,
         "rwkv": _init_rwkv_block}

_APPLY = {"attn_dense": lambda p, c, x, pos, st: _attn_block(p, c, x, pos,
                                                             st, False),
          "attn_moe": lambda p, c, x, pos, st: _attn_block(p, c, x, pos, st,
                                                           True),
          "mamba": lambda p, c, x, pos, st: _mamba_block(p, c, x, st),
          "rwkv": lambda p, c, x, pos, st: _rwkv_block(p, c, x, st)}


def _n_shared_apps(cfg: ModelConfig) -> int:
    return cfg.num_layers // cfg.shared_attn_every \
        if cfg.block_pattern == "zamba" else 0


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": layers.dense_init(keys[0],
                                   (cfg.vocab_size, cfg.d_model), 1,
                                   cfg.param_dtype),
        "final_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(
            keys[1], (cfg.d_model, cfg.vocab_size), 0, cfg.param_dtype)
    for gi, (name, kind, count) in enumerate(_groups(cfg)):
        gkeys = jax.random.split(jax.random.fold_in(keys[2], gi), count)
        params[name] = jax.vmap(
            lambda k: _INIT[kind](k, cfg))(gkeys)
    if cfg.block_pattern == "zamba":
        params["shared_attn"] = _init_attn_block(keys[3], cfg, False)
    if cfg.mtp:
        params["mtp"] = {
            "norm_h": jnp.ones((cfg.d_model,), cfg.param_dtype),
            "norm_e": jnp.ones((cfg.d_model,), cfg.param_dtype),
            "proj": layers.dense_init(keys[4],
                                      (2 * cfg.d_model, cfg.d_model), 0,
                                      cfg.param_dtype),
            "block": _init_attn_block(keys[5], cfg, False),
        }
    return params


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _scan_group(cfg, stacked, kind, x, positions, *, remat: bool):
    fn = _APPLY[kind]
    if remat:
        fn = jax.checkpoint(fn, static_argnums=(1,))

    def body(carry, p):
        h, aux = carry
        h, _, aux_d = fn(p, cfg, h, positions, None)
        return (h, aux + aux_d), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               stacked)
    return x, aux


def _zamba_segments(cfg: ModelConfig):
    """[(start, end, apply_shared_after)] segments of the mamba stack."""
    per = cfg.shared_attn_every
    segs = []
    s = 0
    while s < cfg.num_layers:
        e = min(s + per, cfg.num_layers)
        segs.append((s, e, e - s == per))
        s = e
    return segs


def _backbone(params, cfg: ModelConfig, x, positions, *, remat: bool):
    """Runs all blocks (no caches); returns (hidden, aux_loss)."""
    aux_total = jnp.zeros((), jnp.float32)
    if cfg.block_pattern == "zamba":
        stacked = params["mamba"]
        for (s, e, shared_after) in _zamba_segments(cfg):
            seg = jax.tree.map(lambda a: a[s:e], stacked)
            x, aux = _scan_group(cfg, seg, "mamba", x, positions,
                                 remat=remat)
            aux_total += aux
            if shared_after:
                x, _, _ = _attn_block(params["shared_attn"], cfg, x,
                                      positions, None, False)
        return x, aux_total
    for (name, kind, _) in _groups(cfg):
        x, aux = _scan_group(cfg, params[name], kind, x, positions,
                             remat=remat)
        aux_total += aux
    return x, aux_total


def _embed_inputs(params, cfg, tokens, img_embeds):
    x = params["embed"][tokens].astype(cfg.act_dtype)
    if cfg.num_img_tokens and img_embeds is not None:
        x = jnp.concatenate([img_embeds.astype(cfg.act_dtype), x], axis=1)
    return constrain(x, "batch", None, None)


def _logits(params, cfg, x):
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return constrain(x @ head, "batch", None, "model")


def forward(params, cfg: ModelConfig, tokens: jax.Array,
            img_embeds: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """tokens [B, S] -> (logits [B, S(+img), V], moe aux loss)."""
    x = _embed_inputs(params, cfg, tokens, img_embeds)
    positions = jnp.arange(x.shape[1])[None, :]
    x, aux = _backbone(params, cfg, x, positions, remat=cfg.remat)
    return _logits(params, cfg, x), aux


def loss_fn(params, cfg: ModelConfig, tokens, labels,
            img_embeds: Optional[jax.Array] = None) -> jax.Array:
    """Next-token CE (+ MoE aux + MTP second-token head for DeepSeek-V3)."""
    x = _embed_inputs(params, cfg, tokens, img_embeds)
    positions = jnp.arange(x.shape[1])[None, :]
    h, aux = _backbone(params, cfg, x, positions, remat=cfg.remat)
    if cfg.num_img_tokens and img_embeds is not None:
        pad = jnp.full(
            (labels.shape[0], cfg.num_img_tokens), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    logits = _logits(params, cfg, h)
    loss = layers.cross_entropy_loss(logits, labels)
    if cfg.mtp:
        # second-token head: combine hidden with next-token embedding
        emb_next = jnp.roll(x, -1, axis=1)
        m = params["mtp"]
        comb = jnp.concatenate(
            [layers.rms_norm(h, m["norm_h"], cfg.norm_eps),
             layers.rms_norm(emb_next, m["norm_e"], cfg.norm_eps)],
            axis=-1) @ m["proj"]
        h2, _, _ = _attn_block(m["block"], cfg, comb, positions, None,
                               False)
        logits2 = _logits(params, cfg, h2)
        labels2 = jnp.roll(labels, -1, axis=1).at[:, -1].set(-1)
        loss = loss + cfg.mtp_weight * layers.cross_entropy_loss(
            logits2, labels2)
    if cfg.moe:
        loss = loss + cfg.aux_loss_weight * aux
    return loss


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, max_len: int
                      ) -> Dict[str, Any]:
    dt = cfg.act_dtype

    def stack(n, make):
        return jax.vmap(lambda _: make())(jnp.arange(n))

    state: Dict[str, Any] = {"pos": jnp.zeros((batch,), jnp.int32)}
    if cfg.block_pattern == "zamba":
        state["mamba"] = stack(
            cfg.num_layers, lambda: ssm.mamba2_state_init(cfg, batch, dt))
        n_app = _n_shared_apps(cfg)
        if cfg.attention == "mla":
            def mk():
                return attn_mod.mla_cache_init(cfg, batch, max_len, dt)
        else:
            def mk():
                return attn_mod.gqa_cache_init(cfg, batch, max_len, dt)
        state["shared_attn"] = stack(n_app, mk)
        return state
    if cfg.block_pattern == "rwkv":
        state["layers"] = stack(
            cfg.num_layers, lambda: rwkv_mod.rwkv_state_init(cfg, batch, dt))
        return state
    for (name, kind, count) in _groups(cfg):
        if cfg.attention == "mla":
            def mk():
                return attn_mod.mla_cache_init(cfg, batch, max_len, dt)
        else:
            def mk():
                return attn_mod.gqa_cache_init(cfg, batch, max_len, dt)
        state[name] = stack(count, mk)
    return state


def decode_step(params, cfg: ModelConfig, state: Dict[str, Any],
                tokens: jax.Array) -> Tuple[jax.Array, Dict[str, Any]]:
    """tokens [B, 1] + state -> (logits [B, 1, V], new state).

    This is `serve_step`: one new token against a cache of `pos` history.
    """
    x = params["embed"][tokens].astype(cfg.act_dtype)
    x = constrain(x, "batch", None, None)
    positions = state["pos"][:, None]
    new_state: Dict[str, Any] = {"pos": state["pos"] + 1}

    def scan_decode(stacked_p, caches, kind):
        fn = _APPLY[kind]

        def body(h, pc):
            p, cache = pc
            h, new_cache, _ = fn(p, cfg, h, positions, cache)
            return h, new_cache

        return jax.lax.scan(body, x, (stacked_p, caches))

    if cfg.block_pattern == "zamba":
        h = x
        app_i = 0
        for (s, e, shared_after) in _zamba_segments(cfg):
            seg_p = jax.tree.map(lambda a: a[s:e], params["mamba"])
            seg_c = jax.tree.map(lambda a: a[s:e], state["mamba"])

            def body(hh, pc):
                p, cache = pc
                hh, nc, _ = _mamba_block(p, cfg, hh, cache)
                return hh, nc

            h, seg_nc = jax.lax.scan(body, h, (seg_p, seg_c))
            new_state.setdefault("_mamba_parts", []).append(seg_nc)
            if shared_after:
                cache = jax.tree.map(lambda a: a[app_i],
                                     state["shared_attn"])
                h, nc, _ = _attn_block(params["shared_attn"], cfg, h,
                                       positions, cache, False)
                new_state.setdefault("_shared_parts", []).append(nc)
                app_i += 1
        parts = new_state.pop("_mamba_parts")
        new_state["mamba"] = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *parts)
        sparts = new_state.pop("_shared_parts")
        new_state["shared_attn"] = jax.tree.map(
            lambda *xs: jnp.stack(xs, axis=0), *sparts)
        return _logits(params, cfg, h), new_state

    h = x
    for (name, kind, _) in _groups(cfg):
        fn = _APPLY[kind]

        def body(hh, pc):
            p, cache = pc
            hh, new_cache, _ = fn(p, cfg, hh, positions, cache)
            return hh, new_cache

        h, new_caches = jax.lax.scan(body, h, (params[name], state[name]))
        new_state[name] = new_caches
    return _logits(params, cfg, h), new_state


def prefill(params, cfg: ModelConfig, tokens: jax.Array,
            img_embeds: Optional[jax.Array] = None, *,
            max_len: Optional[int] = None):
    """Full-context pass building the decode state; returns (last_logits,
    state).  Attention archs cache all S keys; recurrent archs run the
    chunked scan and keep only the final state (their long-context edge)."""
    x = _embed_inputs(params, cfg, tokens, img_embeds)
    b, s, _ = x.shape
    max_len = max_len or s
    positions = jnp.arange(s)[None, :]
    state: Dict[str, Any] = {"pos": jnp.full((b,), s, jnp.int32)}

    cache_fn = attn_mod.mla_prefill_cache if cfg.attention == "mla" \
        else attn_mod.gqa_prefill_cache

    if cfg.block_pattern == "uniform":
        h = x
        for (name, kind, _) in _groups(cfg):
            fn = _APPLY[kind]

            def body(hh, p):
                pre = layers.rms_norm(hh, p["ln1"], cfg.norm_eps)
                cache = cache_fn(p["attn"], cfg, pre, positions,
                                 cfg.act_dtype, max_len)
                hh, _, _ = fn(p, cfg, hh, positions, None)
                return hh, cache

            h, caches = jax.lax.scan(body, h, params[name])
            state[name] = caches
        return _logits(params, cfg, h[:, -1:]), state

    if cfg.block_pattern == "rwkv":
        def body(hh, p):
            hh, st, _ = _rwkv_block(p, cfg, hh, None)
            return hh, st

        h, states = jax.lax.scan(body, x, params["layers"])
        state["layers"] = states
        return _logits(params, cfg, h[:, -1:]), state

    # zamba: mamba states from the chunked scan; shared-attn KV caches per
    # application
    h = x
    mamba_states, shared_caches = [], []
    for (s0, e0, shared_after) in _zamba_segments(cfg):
        seg = jax.tree.map(lambda a: a[s0:e0], params["mamba"])

        def body(hh, p):
            hh, st, _ = _mamba_block(p, cfg, hh, None)
            return hh, st

        h, seg_states = jax.lax.scan(body, h, seg)
        mamba_states.append(seg_states)
        if shared_after:
            p_sh = params["shared_attn"]
            pre = layers.rms_norm(h, p_sh["ln1"], cfg.norm_eps)
            shared_caches.append(cache_fn(p_sh["attn"], cfg, pre, positions,
                                          cfg.act_dtype, max_len))
            h, _, _ = _attn_block(p_sh, cfg, h, positions, None, False)
    state["mamba"] = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                                  *mamba_states)
    state["shared_attn"] = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0),
                                        *shared_caches)
    return _logits(params, cfg, h[:, -1:]), state


class Model:
    """Convenience OO wrapper over the functional API."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key) -> Dict[str, Any]:
        return init_params(self.cfg, key)

    def __call__(self, params, tokens, img_embeds=None):
        return forward(params, self.cfg, tokens, img_embeds)

    def loss(self, params, tokens, labels, img_embeds=None):
        return loss_fn(params, self.cfg, tokens, labels, img_embeds)

    def decode_step(self, params, state, tokens):
        return decode_step(params, self.cfg, state, tokens)

    def param_count(self, params) -> int:
        return sum(int(p.size) for p in jax.tree.leaves(params))
