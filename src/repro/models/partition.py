"""Logical-axis sharding constraints for model activations.

Models call `constrain(x, ...)` with logical axis names; under a mesh
context (`jax.sharding.use_mesh`) this lowers to with_sharding_constraint
with the mesh's real axes, and on meshless CPU test runs it is a no-op.

Logical axes:
  "batch" -> ("pod", "data") (whichever exist in the mesh)
  "model" -> "model"
  "seq"   -> "model" when cfg uses sequence parallelism for that tensor
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _mesh_axes():
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None:
        return ()
    return tuple(mesh.axis_names)


def resolve_axis(logical, axis_names):
    if logical is None:
        return None
    if logical == "batch":
        got = tuple(n for n in ("pod", "data") if n in axis_names)
        return got if got else None
    if logical in ("model", "seq_model"):
        return "model" if "model" in axis_names else None
    raise ValueError(f"unknown logical axis {logical}")


def model_axis_size() -> int:
    mesh = jax.sharding.get_abstract_mesh()
    shp = getattr(mesh, "shape", {})
    return shp.get("model", 1) if hasattr(shp, "get") else 1


def _axis_total(mesh, entry) -> int:
    shp = getattr(mesh, "shape", {})
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    total = 1
    for n in names:
        total *= shp.get(n, 1) if hasattr(shp, "get") else 1
    return total


def constrain(x: jax.Array, *axes) -> jax.Array:
    mesh = jax.sharding.get_abstract_mesh()
    names = _mesh_axes()
    if not names:
        return x
    spec = []
    for dim, a in zip(x.shape, axes):
        entry = resolve_axis(a, names)
        # skip non-divisible dims: padding-induced reshards cost more
        # than the annotation buys
        if entry is not None and dim % _axis_total(mesh, entry) != 0:
            entry = None
        spec.append(entry)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _strip_axis(spec: P, axis: str) -> P:
    out = []
    for e in spec:
        if e == axis:
            out.append(None)
        elif isinstance(e, tuple):
            kept = tuple(a for a in e if a != axis)
            out.append(kept if kept else None)
        else:
            out.append(e)
    return P(*out)


def gather_fsdp(block_params):
    """Explicit FSDP gather-at-use for one layer's parameters.

    FSDP shards a weight dim over "data"; left implicit, GSPMD sometimes
    keeps the weight sharded through a contraction and ALL-REDUCES the
    (much larger, f32) activation gradients instead of all-gathering the
    (bf16) weight — measured at ~1 GB/layer of backward all-reduce on
    qwen3-8b train_4k.  Constraining each weight to its TP-only spec at
    the top of the scanned block forces the cheap gather; dL/dw is then
    reduce-scattered back to the sharded param by the output binding.
    """
    mesh = jax.sharding.get_abstract_mesh()
    names = tuple(getattr(mesh, "axis_names", ()) or ())
    if "data" not in names:
        return block_params
    from repro.launch.sharding import param_spec   # no import cycle

    def one(path, leaf):
        if getattr(leaf, "ndim", 0) < 2:
            return leaf
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        spec = param_spec(mesh, pstr, tuple(leaf.shape))
        return jax.lax.with_sharding_constraint(
            leaf, _strip_axis(spec, "data"))

    return jax.tree_util.tree_map_with_path(one, block_params)
