"""Mixture-of-Experts FFN with capacity-based sorted dispatch + EP sharding.

DeepSeek-style MoE: `num_shared` always-on shared experts plus
`num_experts` routed experts with top-k gating (softmax for V2, sigmoid
scores with normalized top-k for V3).

Dispatch is sort-based (MegaBlocks/MaxText style): (token, choice) pairs
are sorted by expert id, each expert takes up to C = ceil(T*k/E * cf)
tokens, the rest are dropped (capacity overflow — standard for static
shapes).  The [E, C, d] buffer is the tensor expert parallelism shards
over the `model` axis; token->buffer scatter and buffer->token gather are
where GSPMD inserts the all-to-all-equivalent collectives.
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.partition import constrain


def init_moe(key, cfg) -> Dict[str, Any]:
    m = cfg.moe
    d, dff = cfg.d_model, m.d_ff_expert
    ks = jax.random.split(key, 7)
    p = {
        "router": layers.dense_init(ks[0], (d, m.num_experts), 0,
                                    jnp.float32),
        "w_gate": layers.dense_init(ks[1], (m.num_experts, d, dff), 1,
                                    cfg.param_dtype),
        "w_up": layers.dense_init(ks[2], (m.num_experts, d, dff), 1,
                                  cfg.param_dtype),
        "w_down": layers.dense_init(ks[3], (m.num_experts, dff, d), 1,
                                    cfg.param_dtype),
    }
    if m.num_shared:
        sh = m.num_shared * dff
        p["shared_gate"] = layers.dense_init(ks[4], (d, sh), 0,
                                             cfg.param_dtype)
        p["shared_up"] = layers.dense_init(ks[5], (d, sh), 0,
                                           cfg.param_dtype)
        p["shared_down"] = layers.dense_init(ks[6], (sh, d), 0,
                                             cfg.param_dtype)
    return p


def _route(params, m, x_flat):
    """Returns (weights [T,k], experts int32 [T,k], aux_loss)."""
    logits = (x_flat.astype(jnp.float32) @ params["router"])
    if m.router_score == "sigmoid":          # DeepSeek-V3
        scores = jax.nn.sigmoid(logits)
    else:                                    # softmax (V2 and classic)
        scores = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(scores, m.top_k)
    if m.norm_topk:
        weights = weights / jnp.maximum(
            jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    probs = jax.nn.softmax(logits, axis=-1)
    counts = jnp.zeros((m.num_experts,), jnp.float32).at[
        experts.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(counts.sum(), 1.0)
    p_mean = probs.mean(axis=0)
    aux = m.num_experts * jnp.sum(f * p_mean)
    return weights.astype(x_flat.dtype), experts.astype(jnp.int32), aux


def moe_ffn(params, cfg, x: jax.Array):
    """x [B,S,d] -> ([B,S,d], aux_loss)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    x_flat = x.reshape(t, d)
    weights, experts, aux = _route(params, m, x_flat)
    k = m.top_k
    e = m.num_experts
    cap = max(1, int(math.ceil(t * k / e * m.capacity_factor)))

    # ---- sorted capacity dispatch -------------------------------------------
    flat_e = experts.reshape(-1)                         # [T*k]
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    # position of each entry within its expert group
    group_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos = jnp.arange(t * k, dtype=jnp.int32) - group_start
    keep = pos < cap
    # overflow entries scatter-add a zero into slot 0 (masked values), so
    # no spare slot is needed — keeping the buffer shape cleanly
    # reshape-able lets GSPMD shard the scatter instead of replicating it
    dest = jnp.where(keep, sorted_e * cap + pos, 0)
    token_of = (sort_idx // k).astype(jnp.int32)
    vals = jnp.where(keep[:, None], x_flat[token_of],
                     jnp.zeros((), x_flat.dtype))
    # the dispatched-activation tensor is [T*k, d] — by far the largest
    # intermediate; shard its row dim or it replicates per device
    vals = constrain(vals, "batch", None)

    # two-phase dispatch: scatter into a row-sharded buffer first (the
    # scatter stays aligned with `vals`' sharding), THEN reshard to the
    # expert-parallel layout — one explicit all-to-all-shaped move instead
    # of GSPMD all-reducing the full [E, C, d] buffer per layer
    buf = jnp.zeros((e * cap, d), x.dtype).at[dest].add(vals)
    buf = constrain(buf, "batch", None)
    buf = constrain(buf.reshape(e, cap, d), "model", "batch", None)

    # ---- expert FFN (grouped matmul over the expert-sharded buffer) ---------
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    out_buf = constrain(out_buf, "model", "batch", None)

    # ---- combine -------------------------------------------------------------
    out_flat = out_buf.reshape(e * cap, d)
    gathered = jnp.where(keep[:, None], out_flat[dest],
                         jnp.zeros((), out_flat.dtype))
    gathered = constrain(gathered, "batch", None)
    w_sorted = weights.reshape(-1)[sort_idx][:, None]
    y = jnp.zeros((t, d), x.dtype).at[token_of].add(gathered * w_sorted)

    # ---- shared experts (always-on dense path) -------------------------------
    if m.num_shared:
        y = y + layers.swiglu(x_flat, params["shared_gate"],
                              params["shared_up"], params["shared_down"])
    return constrain(y.reshape(b, s, d), "batch", None, None), aux
