"""Attention variants: GQA/MHA (+ qk-norm, partial rotary, sliding window)
and MLA (DeepSeek multi-head latent attention), each with a training path
(full-sequence, chunked/flash-style) and a decode path (single new token
against a KV cache).

Decode caches:
 - GQA: {k, v: [B, C, KV, hd], pos: [B, C] int32} — C = min(window, S_max)
   (sliding-window archs keep only a rolling window of slots).
 - MLA: {ckv: [B, C, kv_lora], krope: [B, C, rope], pos} — the compressed
   latent is cached, attention is evaluated in "absorbed" form, which is
   the memory/bandwidth point of MLA.

KV caches are annotated for *sequence-parallel* sharding over the model
axis (split-KV decode): each model shard holds a slice of the context and
softmax statistics reduce across shards — this is what makes 32k-500k
contexts fit per chip (DESIGN.md §6).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.partition import constrain, model_axis_size

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# shared scaled-dot-product cores
# ---------------------------------------------------------------------------

def causal_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     *, window: Optional[int] = None,
                     chunk_q: int = 1024) -> jax.Array:
    """Grouped-query causal attention, memory-bounded via query chunking.

    q [B,S,H,dk], k [B,S,KV,dk], v [B,S,KV,dv] -> [B,S,H,dv].
    Scores for a chunk are [B,KV,G,cq,S] — never the full S x S square, so
    32k-token prefill stays within HBM per layer (flash-style blocking; the
    Pallas kernel target shares this schedule).
    """
    b, s, h, dk = q.shape
    kv = k.shape[2]
    g = h // kv
    dv = v.shape[-1]
    scale = 1.0 / math.sqrt(dk)
    qg = q.reshape(b, s, kv, g, dk)

    def block(q_blk, off):
        # q_blk [B,cq,KV,G,dk]; full-k scores [B,KV,G,cq,S]
        scores = jnp.einsum("bqkgd,bskd->bkgqs", q_blk.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        qpos = off + jnp.arange(q_blk.shape[1])
        kpos = jnp.arange(s)
        mask = kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bkgqs,bskd->bqkgd", p,
                          v.astype(jnp.float32)).astype(q.dtype)

    if s <= chunk_q:
        out = block(qg, 0)
    else:
        assert s % chunk_q == 0
        nchunks = s // chunk_q
        qs = qg.reshape(b, nchunks, chunk_q, kv, g, dk)

        def body(carry, inp):
            i, q_blk = inp
            return carry, block(q_blk, i * chunk_q)

        _, outs = jax.lax.scan(body, None,
                               (jnp.arange(nchunks), qs.swapaxes(0, 1)))
        out = outs.swapaxes(0, 1).reshape(b, nchunks * chunk_q, kv, g, dk
                                          if dv == dk else dv)
    return out.reshape(b, s, h, dv)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     valid: jax.Array) -> jax.Array:
    """One-token attention against a cache.

    q [B,1,H,dk], k_cache [B,C,KV,dk], v_cache [B,C,KV,dv],
    valid bool[B,C] -> [B,1,H,dv].
    """
    b, _, h, dk = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(dk)
    qg = q.reshape(b, kv, g, dk)
    scores = jnp.einsum("bkgd,bckd->bkgc", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, -1).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA / MHA / SWA
# ---------------------------------------------------------------------------

def init_gqa(key, cfg) -> Dict[str, Any]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(ks[0], (d, h, hd), 0, cfg.param_dtype),
        "wk": layers.dense_init(ks[1], (d, kv, hd), 0, cfg.param_dtype),
        "wv": layers.dense_init(ks[2], (d, kv, hd), 0, cfg.param_dtype),
        "wo": layers.dense_init(ks[3], (h, hd, d), (0, 1), cfg.param_dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.param_dtype)
        p["k_norm"] = jnp.ones((hd,), cfg.param_dtype)
    return p


def gqa_cache_init(cfg, batch: int, max_len: int, dtype) -> Dict[str, Any]:
    c = min(max_len, cfg.window) if cfg.window else max_len
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, c, kv, hd), dtype),
        "v": jnp.zeros((batch, c, kv, hd), dtype),
        "pos": jnp.full((batch, c), -1, jnp.int32),
    }


def gqa(params, cfg, x: jax.Array, positions: jax.Array,
        cache: Optional[Dict[str, Any]] = None
        ) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
    """x [B,S,d].  Train/prefill when cache is None; else one-step decode
    (S == 1) updating the rolling cache."""
    rd = cfg.rotary_dim
    q = jnp.einsum("bsd,dhx->bshx", x, params["wq"])
    k = jnp.einsum("bsd,dkx->bskx", x, params["wk"])
    v = jnp.einsum("bsd,dkx->bskx", x, params["wv"])
    if cfg.qk_norm:
        q = layers.rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = layers.rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = layers.apply_rope(q, positions, rd, cfg.rope_theta)
    k = layers.apply_rope(k, positions, rd, cfg.rope_theta)
    tp = model_axis_size()
    heads_shardable = tp <= 1 or cfg.num_heads % tp == 0
    if heads_shardable:
        q = constrain(q, "batch", None, "model", None)
    else:
        # sequence-parallel attention: when the head count doesn't divide
        # the model axis (qwen3-14b: 40, llava: 56), shard the q sequence
        # over `model` and keep the (small) k/v replicated — full TP-speed
        # compute without head-padding or the 100 GB/layer score
        # all-reduces of a sharded-head_dim contraction
        q = constrain(q, "batch", "model", None, None)
        k = constrain(k, "batch", None, None, None)
        v = constrain(v, "batch", None, None, None)

    if cache is None:
        out = causal_attention(q, k, v, window=cfg.window,
                               chunk_q=cfg.attn_chunk)
        if not heads_shardable:
            out = constrain(out, "batch", "model", None, None)
    else:
        slot_count = cache["k"].shape[1]
        pos = positions[:, 0]                          # [B]
        slot = (pos % slot_count).astype(jnp.int32)
        bidx = jnp.arange(x.shape[0])
        k_c = cache["k"].at[bidx, slot].set(k[:, 0])
        v_c = cache["v"].at[bidx, slot].set(v[:, 0])
        pos_c = cache["pos"].at[bidx, slot].set(pos)
        k_c = constrain(k_c, "batch", "model", None, None)
        v_c = constrain(v_c, "batch", "model", None, None)
        valid = (pos_c >= 0) & (pos_c <= pos[:, None])
        if cfg.window:
            valid &= pos_c > (pos[:, None] - cfg.window)
        out = decode_attention(q, k_c, v_c, valid)
        cache = {"k": k_c, "v": v_c, "pos": pos_c}

    y = jnp.einsum("bshx,hxd->bsd", out, params["wo"])
    return constrain(y, "batch", None, None), cache


def gqa_prefill_cache(params, cfg, x, positions, dtype,
                      max_len: Optional[int] = None) -> Dict[str, Any]:
    """Build a decode cache from a prefill pass (keys/values for all S,
    padded to max_len so subsequent decode steps have free slots)."""
    k = jnp.einsum("bsd,dkx->bskx", x, params["wk"])
    v = jnp.einsum("bsd,dkx->bskx", x, params["wv"])
    if cfg.qk_norm:
        k = layers.rms_norm(k, params["k_norm"], cfg.norm_eps)
    k = layers.apply_rope(k, positions, cfg.rotary_dim, cfg.rope_theta)
    pos = jnp.broadcast_to(positions, x.shape[:2]).astype(jnp.int32)
    s = x.shape[1]
    c = min(max_len or s, cfg.window) if cfg.window else (max_len or s)
    if c > s:
        pad = c - s
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos = jnp.pad(pos, ((0, 0), (0, pad)), constant_values=-1)
    elif c < s:      # sliding window: keep the last `c` positions
        k, v, pos = k[:, -c:], v[:, -c:], pos[:, -c:]
        # ring layout: physical slot = pos % c must hold that position
        slot = pos[0] % c
        inv = jnp.argsort(slot)
        k, v, pos = k[:, inv], v[:, inv], pos[:, inv]
    return {"k": k.astype(dtype), "v": v.astype(dtype), "pos": pos}


# ---------------------------------------------------------------------------
# MLA (DeepSeek V2/V3)
# ---------------------------------------------------------------------------

def init_mla(key, cfg) -> Dict[str, Any]:
    d, h = cfg.d_model, cfg.num_heads
    nope, rope, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, \
        cfg.v_head_dim
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    return {
        "wq_a": layers.dense_init(ks[0], (d, qr), 0, cfg.param_dtype),
        "q_norm": jnp.ones((qr,), cfg.param_dtype),
        "wq_b": layers.dense_init(ks[1], (qr, h, nope + rope), 0,
                                  cfg.param_dtype),
        "wkv_a": layers.dense_init(ks[2], (d, kvr + rope), 0,
                                   cfg.param_dtype),
        "kv_norm": jnp.ones((kvr,), cfg.param_dtype),
        "w_uk": layers.dense_init(ks[3], (kvr, h, nope), 0, cfg.param_dtype),
        "w_uv": layers.dense_init(ks[4], (kvr, h, vdim), 0, cfg.param_dtype),
        "wo": layers.dense_init(ks[5], (h, vdim, d), (0, 1), cfg.param_dtype),
    }


def mla_cache_init(cfg, batch: int, max_len: int, dtype) -> Dict[str, Any]:
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
    }


def _mla_q(params, cfg, x, positions):
    cq = layers.rms_norm(x @ params["wq_a"], params["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhx->bshx", cq, params["wq_b"])
    q_nope = q[..., : cfg.qk_nope_head_dim]
    q_rope = layers.apply_rope(q[..., cfg.qk_nope_head_dim:], positions,
                               cfg.qk_rope_head_dim, cfg.rope_theta)
    return q_nope, q_rope


def _mla_ckv(params, cfg, x, positions):
    ckv_full = x @ params["wkv_a"]
    ckv = layers.rms_norm(ckv_full[..., : cfg.kv_lora_rank],
                          params["kv_norm"], cfg.norm_eps)
    krope = layers.apply_rope(
        ckv_full[..., cfg.kv_lora_rank:][:, :, None, :], positions,
        cfg.qk_rope_head_dim, cfg.rope_theta)[:, :, 0, :]
    return ckv, krope


def mla(params, cfg, x: jax.Array, positions: jax.Array,
        cache: Optional[Dict[str, Any]] = None
        ) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
    b, s, _ = x.shape
    q_nope, q_rope = _mla_q(params, cfg, x, positions)

    if cache is None:
        # training / prefill: expand K,V per head (compute-rich form)
        ckv, krope = _mla_ckv(params, cfg, x, positions)
        k_nope = jnp.einsum("bsr,rhx->bshx", ckv, params["w_uk"])
        v = jnp.einsum("bsr,rhx->bshx", ckv, params["w_uv"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope[:, :, None, :],
                                      (*k_nope.shape[:3],
                                       cfg.qk_rope_head_dim))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        q = constrain(q, "batch", None, "model", None)
        out = causal_attention(q, k, v, chunk_q=cfg.attn_chunk)
        new_cache = None
    else:
        # decode: absorbed form against the compressed latent cache
        ckv_t, krope_t = _mla_ckv(params, cfg, x, positions)
        slot_count = cache["ckv"].shape[1]
        pos = positions[:, 0]
        slot = (pos % slot_count).astype(jnp.int32)
        bidx = jnp.arange(b)
        ckv_c = cache["ckv"].at[bidx, slot].set(ckv_t[:, 0])
        kr_c = cache["krope"].at[bidx, slot].set(krope_t[:, 0])
        pos_c = cache["pos"].at[bidx, slot].set(pos)
        ckv_c = constrain(ckv_c, "batch", "model", None)
        valid = (pos_c >= 0) & (pos_c <= pos[:, None])
        scale = 1.0 / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
        q_lat = jnp.einsum("bshx,rhx->bshr", q_nope, params["w_uk"])
        scores = (jnp.einsum("bshr,bcr->bhc", q_lat.astype(jnp.float32),
                             ckv_c.astype(jnp.float32))
                  + jnp.einsum("bshx,bcx->bhc", q_rope.astype(jnp.float32),
                               kr_c.astype(jnp.float32))) * scale
        scores = jnp.where(valid[:, None, :], scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        out_lat = jnp.einsum("bhc,bcr->bhr", p, ckv_c.astype(jnp.float32))
        out = jnp.einsum("bhr,rhx->bhx", out_lat,
                         params["w_uv"].astype(jnp.float32))
        out = out[:, None].astype(x.dtype)
        new_cache = {"ckv": ckv_c, "krope": kr_c, "pos": pos_c}

    y = jnp.einsum("bshx,hxd->bsd", out, params["wo"])
    return constrain(y, "batch", None, None), new_cache


def mla_prefill_cache(params, cfg, x, positions, dtype,
                      max_len: Optional[int] = None) -> Dict[str, Any]:
    ckv, krope = _mla_ckv(params, cfg, x, positions)
    pos = jnp.broadcast_to(positions, x.shape[:2]).astype(jnp.int32)
    s = x.shape[1]
    c = max_len or s
    if c > s:
        pad = c - s
        ckv = jnp.pad(ckv, ((0, 0), (0, pad), (0, 0)))
        krope = jnp.pad(krope, ((0, 0), (0, pad), (0, 0)))
        pos = jnp.pad(pos, ((0, 0), (0, pad)), constant_values=-1)
    return {"ckv": ckv.astype(dtype), "krope": krope.astype(dtype),
            "pos": pos}
