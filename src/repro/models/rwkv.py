"""RWKV6 ("Finch") blocks: data-dependent-decay time mix + channel mix.

Time mix: token shift with data-dependent lerp (the low-rank ddlerp),
receptance/key/value/gate projections, per-channel decay
w_t = exp(-exp(w0 + lora_w(x~_t))) and the bonus `u` for the current
token; the WKV recurrence runs through the shared chunked linear scan.

Channel mix: token shift + squared-ReLU MLP gated by receptance.

Decode state per layer: {"shift_att": [B,d], "shift_ffn": [B,d],
"wkv": [B,H,hd,hd]}.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.linear_scan import chunked_linear_attention, recurrent_step
from repro.models.partition import constrain

LORA_R = 32


def _heads(cfg):
    hd = cfg.ssm.head_dim if cfg.ssm else 64
    return cfg.d_model // hd, hd


def init_rwkv_time_mix(key, cfg) -> Dict[str, Any]:
    d = cfg.d_model
    n_heads, hd = _heads(cfg)
    ks = jax.random.split(key, 12)
    p = {
        # ddlerp base mixes (5 interpolation targets: w,k,v,r,g)
        "mix_base": 0.5 * jnp.ones((5, d), cfg.param_dtype),
        "mix_lora_a": layers.dense_init(ks[0], (d, LORA_R), 0,
                                        cfg.param_dtype),
        "mix_lora_b": layers.dense_init(ks[1], (5, LORA_R, d), 1,
                                        cfg.param_dtype),
        "wr": layers.dense_init(ks[2], (d, d), 0, cfg.param_dtype),
        "wk": layers.dense_init(ks[3], (d, d), 0, cfg.param_dtype),
        "wv": layers.dense_init(ks[4], (d, d), 0, cfg.param_dtype),
        "wg": layers.dense_init(ks[5], (d, d), 0, cfg.param_dtype),
        "wo": layers.dense_init(ks[6], (d, d), 0, cfg.param_dtype),
        "w0": -6.0 * jnp.ones((d,), jnp.float32),   # decay bias (slow)
        "w_lora_a": layers.dense_init(ks[7], (d, LORA_R), 0,
                                      cfg.param_dtype),
        "w_lora_b": layers.dense_init(ks[8], (LORA_R, d), 0,
                                      cfg.param_dtype),
        "u": layers.dense_init(ks[9], (n_heads, hd), 1, jnp.float32),
        "ln_x": jnp.ones((d,), cfg.param_dtype),
    }
    return p


def init_rwkv_channel_mix(key, cfg) -> Dict[str, Any]:
    d, dff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mix_k": 0.5 * jnp.ones((d,), cfg.param_dtype),
        "mix_r": 0.5 * jnp.ones((d,), cfg.param_dtype),
        "wk": layers.dense_init(ks[0], (d, dff), 0, cfg.param_dtype),
        "wv": layers.dense_init(ks[1], (dff, d), 0, cfg.param_dtype),
        "wr": layers.dense_init(ks[2], (d, d), 0, cfg.param_dtype),
    }


def _token_shift(x: jax.Array, prev: Optional[jax.Array]):
    """Shifted sequence (previous token), and the new carry (last token)."""
    if prev is None:
        prev_tok = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev_tok = jnp.concatenate([prev[:, None, :], x[:, :-1]], axis=1)
    return prev_tok, x[:, -1, :]


def rwkv_time_mix(params, cfg, x: jax.Array,
                  state: Optional[Dict[str, Any]] = None
                  ) -> Tuple[jax.Array, Dict[str, Any]]:
    """x [B,S,d]; decode when state is not None (S == 1)."""
    b, s, d = x.shape
    n_heads, hd = _heads(cfg)
    prev = state["shift_att"] if state is not None else None
    x_prev, carry = _token_shift(x, prev)
    dx = x_prev - x

    # ddlerp: shared low-rank modulation of the 5 mix coefficients
    base = x + dx * params["mix_base"][0]
    mod = jnp.tanh(base @ params["mix_lora_a"])           # [B,S,R]
    mixes = params["mix_base"][:, None, None, :] + jnp.einsum(
        "bsr,mrd->mbsd", mod, params["mix_lora_b"])       # [5,B,S,d]
    xw, xk, xv, xr, xg = (x + dx * mixes[i] for i in range(5))

    r = (xr @ params["wr"]).reshape(b, s, n_heads, hd)
    k = (xk @ params["wk"]).reshape(b, s, n_heads, hd)
    v = (xv @ params["wv"]).reshape(b, s, n_heads, hd)
    g = jax.nn.silu(xg @ params["wg"])

    w_raw = params["w0"] + jnp.tanh(xw @ params["w_lora_a"]) \
        @ params["w_lora_b"]
    log_w = -jnp.exp(w_raw.astype(jnp.float32))           # <= 0
    log_w = log_w.reshape(b, s, n_heads, hd)

    if state is None:
        chunk = min(cfg.scan_chunk, s)
        y, wkv = chunked_linear_attention(r, k, v, log_w, chunk=chunk,
                                          bonus=params["u"])
    else:
        o, wkv = recurrent_step(state["wkv"], r[:, 0], k[:, 0], v[:, 0],
                                log_w[:, 0], bonus=params["u"])
        y = o[:, None]
    # final state is returned in both modes (prefill needs it)
    new_state = {"wkv": wkv, "shift_att": carry}

    y = y.reshape(b, s, d)
    y = layers.rms_norm(y, params["ln_x"], cfg.norm_eps) * g
    out = y @ params["wo"]
    return constrain(out, "batch", None, None), new_state


def rwkv_channel_mix(params, cfg, x: jax.Array,
                     state: Optional[Dict[str, Any]] = None
                     ) -> Tuple[jax.Array, Optional[jax.Array]]:
    prev = state["shift_ffn"] if state is not None else None
    x_prev, carry = _token_shift(x, prev)
    dx = x_prev - x
    xk = x + dx * params["mix_k"]
    xr = x + dx * params["mix_r"]
    h = jnp.square(jax.nn.relu(xk @ params["wk"]))
    out = jax.nn.sigmoid(xr @ params["wr"]) * (h @ params["wv"])
    return constrain(out, "batch", None, None), carry


def rwkv_state_init(cfg, batch: int, dtype) -> Dict[str, Any]:
    n_heads, hd = _heads(cfg)
    return {
        "shift_att": jnp.zeros((batch, cfg.d_model), dtype),
        "shift_ffn": jnp.zeros((batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
    }
