"""Shared primitive layers: norms, rotary embeddings, losses, init."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init (LeCun-ish, standard for LMs)."""
    fan_in = shape[in_axis] if isinstance(in_axis, int) else \
        math.prod(shape[a] for a in in_axis)
    std = 1.0 / math.sqrt(fan_in)
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)
            ).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)
            ).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)
            ).astype(dt)


def rope_freqs(head_dim: int, rotary_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for the rotated subspace (partial rotary OK)."""
    assert rotary_dim % 2 == 0 and rotary_dim <= head_dim
    return 1.0 / (theta ** (jnp.arange(0, rotary_dim, 2, dtype=jnp.float32)
                            / rotary_dim))


def apply_rope(x: jax.Array, positions: jax.Array, rotary_dim: int,
               theta: float) -> jax.Array:
    """x [..., S, H, head_dim]; positions [..., S] (broadcastable).

    Rotates the first `rotary_dim` channels (partial rotary a la GPT-NeoX /
    StableLM); the rest pass through.
    """
    head_dim = x.shape[-1]
    if rotary_dim == 0:
        return x
    inv = rope_freqs(head_dim, rotary_dim, theta)            # [rd/2]
    ang = positions[..., :, None].astype(jnp.float32) * inv  # [..., S, rd/2]
    cos = jnp.cos(ang)[..., None, :]                         # [..., S, 1, rd/2]
    sin = jnp.sin(ang)[..., None, :]
    rot, rest = x[..., :rotary_dim], x[..., rotary_dim:]
    r1, r2 = rot[..., : rotary_dim // 2], rot[..., rotary_dim // 2:]
    out = jnp.concatenate(
        [r1 * cos - r2 * sin, r2 * cos + r1 * sin], axis=-1).astype(x.dtype)
    return jnp.concatenate([out, rest], axis=-1)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = x @ w_gate
    u = x @ w_up
    return (jax.nn.silu(g) * u) @ w_down


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       z_loss: float = 1e-4) -> jax.Array:
    """Mean next-token CE with z-loss stabilizer.

    Labels < 0 are ignored (e.g. image-prefix positions).  The true-logit
    pick uses a one-hot einsum rather than take_along_axis so that a
    vocab-sharded logits tensor reduces with partial-sums + all-reduce
    instead of a cross-shard gather.
    """
    from repro.models.partition import constrain
    logits = logits.astype(jnp.float32)
    mask = labels >= 0
    safe = jnp.where(mask, labels, 0).astype(jnp.int32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(safe, logits.shape[-1], dtype=logits.dtype)
    onehot = constrain(onehot, "batch", None, "model")
    true_logit = jnp.einsum("...v,...v->...", logits, onehot)
    ce = jnp.where(mask, lse - true_logit + z_loss * lse ** 2, 0.0)
    return jnp.sum(ce) / jnp.maximum(jnp.sum(mask), 1)
