"""Unified LM stack: every assigned architecture is a composition of these
modules (attention variants, MoE, SSM, RWKV, norms) driven by ModelConfig."""

from repro.models.transformer import Model, ModelConfig, MoEConfig, SSMConfig

__all__ = ["Model", "ModelConfig", "MoEConfig", "SSMConfig"]
