"""Mamba2 block (SSD form) — used by zamba2's backbone.

Structure follows the Mamba2 paper: fused input projection producing
(z | xBC | dt), short causal conv over xBC, scalar-per-head decay
A exp(dt), SSD recurrence via the shared chunked linear scan, gated
RMSNorm and output projection.

Decode state: {"conv": [B, K-1, d_conv_ch], "ssd": [B, H, d_state, hd]}.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.linear_scan import chunked_linear_attention, recurrent_step
from repro.models.partition import constrain


def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.d_state      # xBC gets convolved jointly
    return d_inner, n_heads, conv_ch


def init_mamba2(key, cfg) -> Dict[str, Any]:
    s = cfg.ssm
    d_inner, n_heads, conv_ch = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    return {
        "in_proj": layers.dense_init(
            ks[0], (d, d_inner + conv_ch + n_heads), 0, cfg.param_dtype),
        "conv_w": layers.dense_init(ks[1], (s.d_conv, conv_ch), 0,
                                    cfg.param_dtype),
        "conv_b": jnp.zeros((conv_ch,), cfg.param_dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)
                         ).astype(jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), cfg.param_dtype),
        "out_norm": jnp.ones((d_inner,), cfg.param_dtype),
        "out_proj": layers.dense_init(ks[4], (d_inner, d), 0,
                                      cfg.param_dtype),
    }


def _split(cfg, zxbcdt):
    d_inner, n_heads, conv_ch = _dims(cfg)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + conv_ch]
    dt = zxbcdt[..., d_inner + conv_ch:]
    return z, xbc, dt


def _causal_conv(xbc, w, b, prev: Optional[jax.Array] = None):
    """Depthwise causal conv, width K.  xbc [B,S,C]; prev [B,K-1,C]."""
    k = w.shape[0]
    if prev is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = prev.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)
    out = sum(full[:, i:full.shape[1] - (k - 1 - i)] * w[i]
              for i in range(k))
    return jax.nn.silu(out + b), full[:, -(k - 1):]


def mamba2(params, cfg, x: jax.Array,
           state: Optional[Dict[str, Any]] = None,
           ) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
    """x [B,S,d].  Train when state is None; else S==1 decode step."""
    s = cfg.ssm
    d_inner, n_heads, _ = _dims(cfg)
    b_sz = x.shape[0]

    zxbcdt = x @ params["in_proj"]
    z, xbc, dt = _split(cfg, zxbcdt)

    conv_prev = state["conv"] if state is not None else None
    xbc, conv_tail = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                  conv_prev)
    xs = xbc[..., :d_inner]
    bmat = xbc[..., d_inner:d_inner + s.d_state]          # [B,S,N]
    cmat = xbc[..., d_inner + s.d_state:]                 # [B,S,N]

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"])             # [B,S,H]
    a = -jnp.exp(params["a_log"])                         # [H] (negative)
    log_decay = (dt * a)[..., None]                       # [B,S,H,1] <= 0

    # SSD as linear attention: q=C, k=B (shared across heads), v=dt*x
    seq = x.shape[1]
    q = jnp.broadcast_to(cmat[:, :, None, :],
                         (b_sz, seq, n_heads, s.d_state))
    kk = jnp.broadcast_to(bmat[:, :, None, :],
                          (b_sz, seq, n_heads, s.d_state))
    v = xs.reshape(b_sz, seq, n_heads, s.head_dim) * dt[..., None]
    log_a = log_decay                        # [B,S,H,1] — scalar per head

    if state is None:
        chunk = min(cfg.scan_chunk, seq)
        y, ssd = chunked_linear_attention(q, kk, v.astype(jnp.float32),
                                          log_a, chunk=chunk)
    else:
        o, ssd = recurrent_step(state["ssd"], q[:, 0], kk[:, 0],
                                v[:, 0].astype(jnp.float32), log_a[:, 0])
        y = o[:, None]
    # final state is returned in both modes (prefill needs it)
    new_state = {"conv": conv_tail.astype(x.dtype), "ssd": ssd}

    y = y.astype(x.dtype).reshape(b_sz, seq, d_inner) \
        + xs * jnp.repeat(params["d_skip"], s.head_dim)[None, None, :]
    y = layers.rms_norm(y * jax.nn.silu(z), params["out_norm"],
                        cfg.norm_eps)
    out = y @ params["out_proj"]
    return constrain(out, "batch", None, None), new_state


def mamba2_state_init(cfg, batch: int, dtype) -> Dict[str, Any]:
    s = cfg.ssm
    d_inner, n_heads, conv_ch = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_ch), dtype),
        "ssd": jnp.zeros((batch, n_heads, s.d_state, s.head_dim),
                         jnp.float32),
    }
