"""Chunked linear-attention recurrence shared by Mamba2 (SSD) and RWKV6.

State recurrence (per batch b, head h), state S in R^{dk x dv}:

    S_t = diag(a_t) S_{t-1} + k_t (x) v_t
    Mamba2 read:  o_t = q_t . S_t                       (current kv included)
    RWKV6 read:   o_t = q_t . (S_{t-1} + (u (x) k_t) v_t)   (bonus diagonal)

with decay a_t in (0,1]^dk — scalar-per-head for Mamba2 (broadcast over
dk), full per-channel vector for RWKV6 (data-dependent w_t).

The chunked form computes within-chunk interactions as masked matmuls
(MXU-friendly) and carries the state across chunks with a scan — the
standard SSD/GLA block decomposition.  The pairwise weight between query i
and key j is exp(cum_i - cum_j) (cum = within-chunk cumsum of log a),
realized as the product of a q-side factor exp(cum_i) (<= 1, safe) and a
k-side factor exp(-cum_j) (clamped at CLAMP; error affects only ~e^-CLAMP
contributions — the GLA paper's secondary chunking addresses the same
issue).  The RWKV read convention is folded in by shifting the q-side
exponent by -log a_i and masking strictly.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

CLAMP = 30.0


def chunked_linear_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             log_a: jax.Array, *, chunk: int = 64,
                             bonus: Optional[jax.Array] = None,
                             initial_state: Optional[jax.Array] = None
                             ) -> Tuple[jax.Array, jax.Array]:
    """q,k [B,T,H,dk], v [B,T,H,dv], log_a [B,T,H,dk] (<= 0).

    bonus: optional [H, dk] current-token boost (RWKV's u) — switches the
    read convention to RWKV's.  Returns (out [B,T,H,dv], state [B,H,dk,dv]).
    """
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    t_orig = t
    pad = (-t) % chunk
    if pad:
        # end-padding with k=v=0, log_a=0 is inert: contributes nothing to
        # outputs of real positions and leaves the carried state unchanged
        zw = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = jnp.pad(q, zw), jnp.pad(k, zw), jnp.pad(v, zw)
        log_a = jnp.pad(log_a, zw)
        t = t + pad
    nc = t // chunk
    f32 = jnp.float32

    qc = q.reshape(b, nc, chunk, h, dk).astype(f32)
    kc = k.reshape(b, nc, chunk, h, dk).astype(f32)
    vc = v.reshape(b, nc, chunk, h, dv).astype(f32)
    da = log_a.shape[-1]                 # dk, or 1 for scalar-per-head decay
    la = log_a.reshape(b, nc, chunk, h, da).astype(f32)

    scalar_decay = bool(log_a.shape[-1] == 1) and dk > 1
    cum = jnp.cumsum(la, axis=2)                     # [B,nc,c,H,dk|1]
    total = cum[:, :, -1]                            # [B,nc,H,dk|1]

    # q-side exponent: cum_i (Mamba read) or cum_i - la_i (RWKV reads S_{t-1})
    q_exp = cum if bonus is None else cum - la
    idx = jnp.arange(chunk)
    strict = bonus is not None
    mask = (idx[:, None] > idx[None, :]) if strict else \
        (idx[:, None] >= idx[None, :])

    if scalar_decay:
        # SSD "segsum" diagonal block: pairwise exponents directly —
        # exact for arbitrarily fast decay (no clamp), scalar per head
        cs_q = q_exp[..., 0]                         # [B,nc,c,H]
        cs_k = cum[..., 0]
        diff = cs_q.swapaxes(2, 3)[..., :, None] \
            - cs_k.swapaxes(2, 3)[..., None, :]      # [B,nc,H,c,c]
        w = jnp.exp(jnp.where(mask[None, None, None], diff, -jnp.inf))
        dots = jnp.einsum("bnchd,bnmhd->bnhcm", qc, kc)
        scores = dots * w
        q_in = qc * jnp.exp(q_exp)                   # inter-chunk (safe: <=1)
    else:
        # factored form (vector decay, e.g. RWKV6 where |cum| stays small)
        q_in = qc * jnp.exp(jnp.clip(q_exp, -CLAMP, 0.0))
        k_in = kc * jnp.exp(jnp.clip(-cum, None, CLAMP))
        scores = jnp.einsum("bnchd,bnmhd->bnhcm", q_in, k_in)
        scores = jnp.where(mask[None, None, None], scores, 0.0)

    # carry factor: prod_{l>j} a_l = exp(total - cum_j) <= 1
    k_carry = kc * jnp.exp(total[:, :, None] - cum)
    out = jnp.einsum("bnhcm,bnmhd->bnchd", scores, vc)

    if bonus is not None:
        diag = jnp.einsum("bnchd,hd,bnchd->bnch", qc, bonus.astype(f32), kc)
        out = out + diag[..., None] * vc

    if initial_state is None:
        s0 = jnp.zeros((b, h, dk, dv), f32)
    else:
        s0 = initial_state.astype(f32)

    def body(s_prev, inp):
        q_in_c, k_carry_c, v_c, tot_c = inp
        inter = jnp.einsum("bchd,bhdv->bchv", q_in_c, s_prev)
        s_new = s_prev * jnp.exp(tot_c)[..., None] + \
            jnp.einsum("bchd,bchv->bhdv", k_carry_c, v_c)
        return s_new, inter

    xs = (q_in.swapaxes(0, 1), k_carry.swapaxes(0, 1), vc.swapaxes(0, 1),
          total.swapaxes(0, 1))
    s_final, inters = jax.lax.scan(body, s0, xs)
    out = out + inters.swapaxes(0, 1)
    out = out.reshape(b, t, h, dv)[:, :t_orig]
    return out.astype(q.dtype), s_final


def recurrent_step(state: jax.Array, q_t: jax.Array, k_t: jax.Array,
                   v_t: jax.Array, log_a_t: jax.Array,
                   bonus: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """One decode step.  state [B,H,dk,dv]; q/k/log_a [B,H,dk]; v [B,H,dv].

    Returns (o_t [B,H,dv], new_state) using the matching read convention.
    """
    f32 = jnp.float32
    st = state.astype(f32)
    a = jnp.exp(log_a_t.astype(f32))[..., None]          # [B,H,dk,1]
    kv = k_t.astype(f32)[..., None] * v_t.astype(f32)[..., None, :]
    new_state = st * a + kv
    if bonus is None:                                    # Mamba read
        o = jnp.einsum("bhd,bhdv->bhv", q_t.astype(f32), new_state)
    else:                                                # RWKV read
        ukv = (bonus.astype(f32) * k_t.astype(f32))[..., None] \
            * v_t.astype(f32)[..., None, :]
        o = jnp.einsum("bhd,bhdv->bhv", q_t.astype(f32), st + ukv)
    return o.astype(q_t.dtype), new_state


def reference_scan(q, k, v, log_a, bonus=None, initial_state=None):
    """O(T) sequential oracle for property tests (same conventions)."""
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    s = jnp.zeros((b, h, dk, dv), jnp.float32) if initial_state is None \
        else initial_state.astype(jnp.float32)
    outs = []
    for i in range(t):
        o, s = recurrent_step(s, q[:, i], k[:, i], v[:, i], log_a[:, i],
                              bonus)
        outs.append(o)
    return jnp.stack(outs, axis=1).astype(q.dtype), s
