"""Pure-jnp oracle for the batched L2 distance kernel."""

import jax.numpy as jnp


def l2_distance_ref(queries: jnp.ndarray, candidates: jnp.ndarray) -> jnp.ndarray:
    """Squared L2 distances.  queries [Q, d], candidates [N, d] -> [Q, N].

    Computed in f32 regardless of input dtype (the kernel accumulates in f32
    on the MXU).
    """
    q = queries.astype(jnp.float32)
    c = candidates.astype(jnp.float32)
    q2 = jnp.sum(q * q, axis=-1, keepdims=True)        # [Q, 1]
    c2 = jnp.sum(c * c, axis=-1, keepdims=True).T      # [1, N]
    cross = q @ c.T                                    # [Q, N]
    return jnp.maximum(q2 + c2 - 2.0 * cross, 0.0)
