"""Public jit'd wrapper for the L2 distance kernel (pad/unpad + dispatch)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.l2_distance.kernel import l2_distance_pallas
from repro.kernels.l2_distance.ref import l2_distance_ref

# CPU containers validate the Pallas path in interpret mode; on TPU the
# compiled kernel runs.  Callers can force either path.
def _on_tpu() -> bool:
    # lazy: calling default_backend() at import time would lock
    # the device count before test/dry-run env flags apply
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def l2_distance(queries: jax.Array, candidates: jax.Array,
                *, use_pallas: bool | None = None,
                interpret: bool | None = None) -> jax.Array:
    """Squared L2 distance [Q, N]; pads to kernel tiles and slices back."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if interpret is None:
        interpret = not _on_tpu()
    if not use_pallas:
        return l2_distance_ref(queries, candidates)
    q_tot, n_tot = queries.shape[0], candidates.shape[0]
    bq = min(128, max(8, 1 << (q_tot - 1).bit_length())) if q_tot else 8
    qp = _pad_to(queries, 0, bq)
    cp = _pad_to(candidates, 0, 128)
    out = l2_distance_pallas(qp, cp, block_q=bq, block_n=128,
                             interpret=interpret)
    return out[:q_tot, :n_tot]
