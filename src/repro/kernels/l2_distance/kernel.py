"""Pallas TPU kernel: tiled batched squared-L2 distance.

The distance-computation phase of ANN search (Fig. 1 of the paper).  On TPU
the ||q||^2 + ||c||^2 - 2 q.c^T decomposition turns the bulk of the work
into an MXU matmul; the rank-1 norm corrections ride on the VPU.

Tiling: grid (Q/bq, N/bn).  Each program holds a (bq, d) query tile and a
(bn, d) candidate tile in VMEM and emits a (bq, bn) distance tile.  bq/bn
default to 128 (MXU-aligned); d is kept whole per tile — embedding dims in
this system are 128-1024 so a full row fits VMEM comfortably
(128 x 1024 x 4 B = 512 KB per operand tile).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _l2_kernel(q_ref, c_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    q2 = jnp.sum(q * q, axis=1, keepdims=True)              # [bq, 1]
    c2 = jnp.sum(c * c, axis=1, keepdims=True).T            # [1, bn]
    cross = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                 # [bq, bn] on MXU
    o_ref[...] = jnp.maximum(q2 + c2 - 2.0 * cross, 0.0)


@functools.partial(jax.jit, static_argnames=("block_q", "block_n", "interpret"))
def l2_distance_pallas(queries: jax.Array, candidates: jax.Array,
                       *, block_q: int = 128, block_n: int = 128,
                       interpret: bool = False) -> jax.Array:
    """queries [Q, d] x candidates [N, d] -> squared L2 [Q, N] (f32).

    Q and N must be multiples of the block sizes (callers pad; `ops.py`
    handles ragged shapes).
    """
    q_tot, d = queries.shape
    n_tot, _ = candidates.shape
    assert q_tot % block_q == 0 and n_tot % block_n == 0

    return pl.pallas_call(
        _l2_kernel,
        grid=(q_tot // block_q, n_tot // block_n),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q_tot, n_tot), jnp.float32),
        interpret=interpret,
    )(queries, candidates)
