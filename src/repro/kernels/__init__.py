"""Pallas TPU kernels for the performance hot-spots the paper optimizes.

Each kernel ships three files:
  kernel.py — pl.pallas_call with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (padding, backend dispatch)
  ref.py    — pure-jnp oracle used by the allclose test sweeps
"""

from repro.kernels.beam.ops import fused_beam_search
from repro.kernels.gather_l2.ops import gather_l2, gather_l2_q8
from repro.kernels.l2_distance.ops import l2_distance
from repro.kernels.simhash.ops import collision_count, simhash_encode

__all__ = ["l2_distance", "gather_l2", "gather_l2_q8", "simhash_encode",
           "collision_count", "fused_beam_search"]
