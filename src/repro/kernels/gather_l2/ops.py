"""Public jit'd wrapper for the fused gather+distance kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gather_l2.kernel import gather_l2_pallas, gather_l2_q8_pallas
from repro.kernels.gather_l2.ref import gather_l2_q8_ref, gather_l2_ref

def _on_tpu() -> bool:
    # lazy: calling default_backend() at import time would lock
    # the device count before test/dry-run env flags apply
    return jax.default_backend() == "tpu"


def _pad_lanes(queries: jax.Array, table: jax.Array):
    """Zero-pad the feature dim of (queries, table) to a 128-lane
    multiple for the Pallas kernels.

    The round-trip is exact, not approximate: pad lanes are zero in
    both operands, so each one contributes (0-0)^2 = +0.0 to the row's
    squared distance and the padded reduction equals the unpadded one
    bit-for-bit for any dim (the dim=65 regression in
    `tests/test_kernels.py` pins it).  Guarded here because a silent
    query/table width mismatch would otherwise "work" after padding
    and return distances against truncated rows.
    """
    d = queries.shape[-1]
    if table.shape[-1] != d:
        raise ValueError(
            f"queries dim {d} != table dim {table.shape[-1]}")
    pad = (-d) % 128
    if pad:
        queries = jnp.pad(queries, ((0, 0), (0, pad)))
        table = jnp.pad(table, ((0, 0), (0, pad)))
    assert queries.shape[-1] % 128 == 0 \
        and table.shape[-1] == queries.shape[-1]
    return queries, table


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def gather_l2(queries: jax.Array, table: jax.Array, ids: jax.Array,
              *, use_pallas: bool | None = None,
              interpret: bool | None = None) -> jax.Array:
    """Fetch `table[ids]` and return squared L2 to `queries`.

    queries [B, d], table [N, d], ids int32[B, K] -> f32[B, K];
    ids < 0 yield +inf (filtered candidates are never fetched — Eq. 8's
    rho * d factor comes from negative ids produced by the SimHash filter).
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if interpret is None:
        interpret = not _on_tpu()
    if not use_pallas:
        return gather_l2_ref(queries, table, ids)
    queries, table = _pad_lanes(queries, table)
    return gather_l2_pallas(queries, table, ids, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def gather_l2_q8(queries: jax.Array, qtable: jax.Array, scales: jax.Array,
                 ids: jax.Array, *, use_pallas: bool | None = None,
                 interpret: bool | None = None) -> jax.Array:
    """Cold-lane companion to `gather_l2`: fetch int8 rows, dequantize
    with their per-row scale, and return squared L2 to `queries`.

    queries [B, d], qtable int8[N, d], scales f32[N], ids int32[B, K]
    -> f32[B, K]; ids < 0 yield +inf.  Approximate by construction —
    final candidates must be reranked against full-precision rows
    (the tier rerank contract, DESIGN.md §12).
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if interpret is None:
        interpret = not _on_tpu()
    if not use_pallas:
        return gather_l2_q8_ref(queries, qtable, scales, ids)
    queries, qtable = _pad_lanes(queries, qtable)
    return gather_l2_q8_pallas(queries, qtable, scales, ids,
                               interpret=interpret)
