"""Pallas TPU kernel: scalar-prefetch gather + fused squared-L2 distance.

This is the TPU-native form of the paper's dominant cost: fetching candidate
vectors from the slow tier during graph traversal (`d * t_v` in Eq. 7).  On
the paper's hardware that is a random 4 KB SSD read per neighbor; here it is
a data-dependent HBM->VMEM DMA selected by a prefetched neighbor id, with
the distance computation fused into the same pass so each fetched row is
touched exactly once (fetch+compute fusion — the kernel-level analogue of
DiskANN's "load only the best candidates").

Grid: (B, K) — one program per (query, candidate) pair.  The candidate id
for block indexing comes from the scalar-prefetch operand, so the DMA engine
can issue the row fetch ahead of the compute.  Rows are padded to a multiple
of 128 lanes.  Filtered-out candidates (id < 0) are redirected to row 0 and
masked to +inf afterwards — the DMA still happens but its result is ignored
(on real hardware Mosaic elides the arithmetic; redirecting keeps the index
map total).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_l2_kernel(ids_ref, q_ref, row_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)          # [1, d]
    r = row_ref[...].astype(jnp.float32)        # [1, d]
    diff = q - r
    o_ref[...] = jnp.sum(diff * diff, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_l2_pallas(queries: jax.Array, table: jax.Array, ids: jax.Array,
                     *, interpret: bool = False) -> jax.Array:
    """queries [B, d], table [N, d], ids int32[B, K] -> f32[B, K].

    d must be a multiple of 128 (callers pad; `ops.py` handles it).
    """
    b, d = queries.shape
    _, k = ids.shape
    assert d % 128 == 0, "pad dim to a lane multiple"

    flat_ids = jnp.maximum(ids, 0).reshape(-1)   # redirect sentinels to row 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, k),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j, ids_ref: (i, 0)),
            pl.BlockSpec((1, d), lambda i, j, ids_ref: (ids_ref[i * k + j], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j, ids_ref: (i, j)),
    )
    out = pl.pallas_call(
        _gather_l2_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(flat_ids, queries, table)
    return jnp.where(ids >= 0, out, jnp.inf)


def _gather_l2_q8_kernel(ids_ref, q_ref, row_ref, scale_ref, o_ref):
    # Dequantize in-register: the int8 row and its f32 scale arrive in
    # the same block pipeline, so reconstruction fuses with the distance
    # pass — the cold lane never materializes an f32 row in HBM.
    q = q_ref[...].astype(jnp.float32)                      # [1, d]
    r = row_ref[...].astype(jnp.float32) * scale_ref[0, 0]  # [1, d]
    diff = q - r
    o_ref[...] = jnp.sum(diff * diff, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_l2_q8_pallas(queries: jax.Array, qtable: jax.Array,
                        scales: jax.Array, ids: jax.Array,
                        *, interpret: bool = False) -> jax.Array:
    """Cold-lane gather: queries [B, d], qtable int8[N, d], scales f32[N],
    ids int32[B, K] -> f32[B, K].  Same grid/prefetch structure as
    `gather_l2_pallas`; the per-row scale rides along as a (1, 1) block
    selected by the same prefetched id.
    """
    b, d = queries.shape
    _, k = ids.shape
    assert d % 128 == 0, "pad dim to a lane multiple"

    flat_ids = jnp.maximum(ids, 0).reshape(-1)   # redirect sentinels to row 0
    scales2d = scales.reshape(-1, 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, k),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j, ids_ref: (i, 0)),
            pl.BlockSpec((1, d), lambda i, j, ids_ref: (ids_ref[i * k + j], 0)),
            pl.BlockSpec((1, 1), lambda i, j, ids_ref: (ids_ref[i * k + j], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j, ids_ref: (i, j)),
    )
    out = pl.pallas_call(
        _gather_l2_q8_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(flat_ids, queries, qtable, scales2d)
    return jnp.where(ids >= 0, out, jnp.inf)
