"""Pure-jnp oracle for the fused gather+distance kernel."""

import jax.numpy as jnp


def gather_l2_ref(queries: jnp.ndarray, table: jnp.ndarray,
                  ids: jnp.ndarray) -> jnp.ndarray:
    """Fetch table rows by id and return squared L2 distance to each query.

    queries [B, d], table [N, d], ids int32[B, K] -> dists f32[B, K].
    Negative ids are "skip" sentinels (filtered-out neighbors); their
    distance is +inf.
    """
    q = queries.astype(jnp.float32)                   # [B, d]
    safe = jnp.maximum(ids, 0)
    rows = table[safe].astype(jnp.float32)            # [B, K, d]
    diff = rows - q[:, None, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    return jnp.where(ids >= 0, d2, jnp.inf)


def gather_l2_q8_ref(queries: jnp.ndarray, qtable: jnp.ndarray,
                     scales: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Cold-lane variant: fused dequantize + squared L2.

    queries [B, d], qtable int8[N, d], scales f32[N], ids int32[B, K]
    -> dists f32[B, K].  Row i reconstructs as ``qtable[i] * scales[i]``
    (per-row absmax scalar quantization, see `repro.tier.quant`).
    Negative ids yield +inf, same contract as `gather_l2_ref`.
    """
    q = queries.astype(jnp.float32)                   # [B, d]
    safe = jnp.maximum(ids, 0)
    rows = qtable[safe].astype(jnp.float32) * scales[safe][..., None]
    diff = rows - q[:, None, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    return jnp.where(ids >= 0, d2, jnp.inf)
