"""Pure-JAX reference oracle for the fused beam-search megakernel.

`beam_search_ref` runs the *entire* bottom-layer beam search for a block
of queries as one fused JAX computation over dense operands.  It is the
semantics contract for `kernel.py`'s Pallas megakernel and the CPU /
interpret-host fallback that `ops.fused_beam_search` dispatches to.

It mirrors `repro.core.traversal.beam_search` op for op (same loop trip
structure, same stable `top_k` merges and tie-breaks, same
SimHash/Hoeffding filter and sampling-rank math), specialized to the
serving path's dense operands:

 - adjacency comes from a resolved snapshot (`lsm.snapshot_rows` view),
   i.e. `_snapshot_adj_fn` semantics — one gather per popped row,
   ``n_probes = 1`` per active expansion;
 - distances come from the dense vector table through the fused
   `gather_l2` kernel family (hot lane) and, under ``tier``, the int8
   cold lane merged by elementwise min — `_dist_fn` / `_tier_dist_fn`
   semantics, including the exact +inf non-owning-lane masking;
 - the SimHash collision / Hoeffding-threshold math is inlined (the
   kernels package must not import `repro.core`; the parity suite in
   `tests/test_beam_kernel.py` pins this transcription against
   `repro.core.simhash`).

Bit-parity with the `while_loop` path at every config point the suite
exercises (lazy deletes, tier, ``n_expand`` > 1, masked pad lanes) is
the whole point of this module.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.gather_l2.ops import gather_l2, gather_l2_q8

INF = jnp.inf


def _rank_desc(score: jax.Array) -> jax.Array:
    """rank[i] = position of i when sorting score descending (stable).

    Same double-stable-argsort as `traversal._rank_desc` — the sampling
    cap must pick the identical rho-prefix.
    """
    order = jnp.argsort(-score, stable=True)
    return jnp.argsort(order, stable=True)


def _collisions(code_q: jax.Array, codes_u: jax.Array,
                m_bits: int) -> jax.Array:
    """#Col(q, u) per Eq. 5 — transcribed from `repro.core.simhash`."""
    ham = jnp.sum(jax.lax.population_count(code_q[None, :] ^ codes_u),
                  axis=-1)
    return (m_bits - ham).astype(jnp.int32)


def _hoeffding_threshold(m_bits: int, eps: float, delta_sq: jax.Array,
                         q_norm: jax.Array,
                         mean_norm: jax.Array) -> jax.Array:
    """T_eps for the dynamic delta (Eq. 6) — transcribed from
    `simhash.cos_from_l2` + `simhash.hoeffding_threshold`."""
    denom = jnp.maximum(2.0 * q_norm * mean_norm, 1e-12)
    cos = jnp.clip((q_norm ** 2 + mean_norm ** 2 - delta_sq) / denom,
                   -1.0, 1.0)
    theta = jnp.arccos(jnp.clip(cos, -1.0, 1.0))
    p = 1.0 - theta / jnp.pi
    slack = math.sqrt(m_bits * math.log(1.0 / eps) / 2.0)
    return p * m_bits - slack


def beam_iter_cap(max_iters: int, n_expand: int, ef: int) -> int:
    """Trip cap shared with `traversal.beam_search` (heat arrays are
    sized by it, so callers on either path see identical shapes)."""
    b = max(1, min(n_expand, ef))
    return min(max_iters, -(-max_iters // b) + 3)


def _beam_one(q, entry, entry_dist, code_q, q_norm, act,
              adjacency, vectors, codes, live, returnable, resident,
              qvecs, qscale, mean_norm, *, ef, k, m_bits, eps, rho,
              max_iters, use_filter, n_expand, has_active, record_heat):
    """Single-query transcription of `traversal.beam_search` over dense
    operands.  vmapped over the query block by `beam_search_ref`."""
    cap, M = adjacency.shape
    B = max(1, min(n_expand, ef))
    iter_cap = beam_iter_cap(max_iters, n_expand, ef)
    heat_len = iter_cap
    tier = resident is not None

    def dist_fn(ids):
        if not tier:
            return gather_l2(q[None, :], vectors, ids[None, :])[0]
        res = resident[jnp.maximum(ids, 0)]
        hot_ids = jnp.where((ids >= 0) & res, ids, -1)
        cold_ids = jnp.where((ids >= 0) & ~res, ids, -1)
        d_hot = gather_l2(q[None, :], vectors, hot_ids[None, :])[0]
        d_cold = gather_l2_q8(q[None, :], qvecs, qscale,
                              cold_ids[None, :])[0]
        return jnp.minimum(d_hot, d_cold)

    if not has_active:
        entry_n_vec = jnp.ones((), jnp.int32)
    else:
        entry_dist = jnp.where(act, entry_dist, INF)
        entry = jnp.where(act, entry, -1)
        entry_n_vec = jnp.asarray(act, jnp.int32)
    beam_ids = jnp.full((ef,), -1, jnp.int32).at[0].set(entry)
    beam_d = jnp.full((ef,), INF, jnp.float32).at[0].set(entry_dist)
    expanded = jnp.zeros((ef,), jnp.bool_)
    visited = jnp.zeros((cap + 1,), jnp.bool_).at[
        jnp.maximum(entry, 0)].set(entry >= 0)
    n_adj = jnp.zeros((), jnp.int32)
    n_vec = entry_n_vec
    n_filtered = jnp.zeros((), jnp.int32)
    n_hops = jnp.zeros((), jnp.int32)
    if record_heat:
        heat_nodes = jnp.full((heat_len, B), -1, jnp.int32)
        heat_mask = jnp.zeros((heat_len, B, M), jnp.bool_)
    else:
        heat_nodes = jnp.zeros((), jnp.int32)
        heat_mask = jnp.zeros((), jnp.bool_)

    fidx = min(ef, 3 * k) - 1

    def cond(carry):
        it, beam_ids, beam_d, expanded, _, _, _, _, hops, *_ = carry
        thresh = beam_d[fidx]
        frontier = (~expanded) & jnp.isfinite(beam_d) & (beam_d <= thresh)
        return (it < iter_cap) & (hops < max_iters) & jnp.any(frontier)

    def body(carry):
        (it, beam_ids, beam_d, expanded, visited,
         n_adj, n_vec, n_filtered, n_hops, heat_nodes, heat_mask) = carry

        frontier_d = jnp.where(expanded, INF, beam_d)
        thresh = beam_d[fidx]
        if B == 1:
            slots = jnp.argmin(frontier_d)[None]
        else:
            _, slots = jax.lax.top_k(-frontier_d, B)
        sel_d = frontier_d[slots]
        active = jnp.isfinite(sel_d) & (sel_d <= thresh)
        expanded = expanded.at[slots].set(expanded[slots] | active)
        nodes = jnp.where(active, beam_ids[slots], -1)

        # snapshot adjacency: one gather per row, n_probes = 1
        rows = adjacency[jnp.maximum(nodes, 0)]
        rows = jnp.where((nodes >= 0)[:, None], rows, -1)
        n_probes = jnp.ones_like(nodes)
        row = rows.reshape(B * M)
        valid = (row >= 0) & (row <= cap - 1)
        safe = jnp.where(valid, row, cap)
        seen = visited[safe]
        alive = jnp.where(valid, live[jnp.minimum(safe, cap - 1)], False)
        eligible = valid & (~seen) & alive
        if B > 1:
            eq = safe[None, :] == safe[:, None]
            earlier = jnp.tril(eq, k=-1)
            eligible = eligible & ~jnp.any(earlier, axis=1)

        cand_codes = codes[jnp.minimum(safe, cap - 1)]
        cols = _collisions(code_q, cand_codes, m_bits)
        delta_sq = beam_d[k - 1]
        if use_filter:
            thr = _hoeffding_threshold(m_bits, eps, delta_sq, q_norm,
                                       mean_norm)
            pass_thr = (cols.astype(jnp.float32) >= thr) \
                | ~jnp.isfinite(delta_sq)
        else:
            pass_thr = jnp.ones_like(eligible)
        pre_mask = eligible & pass_thr

        if isinstance(rho, (int, float)) and rho >= 1.0:
            fetch_mask = pre_mask
        else:
            score = jnp.where(pre_mask, cols, -1)
            rank = _rank_desc(score)
            n_elig = jnp.sum(pre_mask)
            cap_dyn = jnp.ceil(rho * n_elig).astype(jnp.int32)
            fetch_mask = pre_mask & (rank < cap_dyn)
        fetch_ids = jnp.where(fetch_mask, row, -1)

        dists = dist_fn(fetch_ids)

        visited = visited.at[jnp.where(fetch_mask, safe, cap)].set(True)
        n_fetch = jnp.sum(fetch_mask).astype(jnp.int32)
        n_adj = n_adj + jnp.sum(jnp.where(active, n_probes, 0))
        n_vec = n_vec + n_fetch
        n_filtered = n_filtered \
            + jnp.sum(eligible).astype(jnp.int32) - n_fetch
        n_hops = n_hops + jnp.sum(active).astype(jnp.int32)
        if record_heat:
            heat_nodes = heat_nodes.at[it].set(nodes)
            heat_mask = heat_mask.at[it].set(fetch_mask.reshape(B, M))

        all_ids = jnp.concatenate([beam_ids, fetch_ids])
        all_d = jnp.concatenate([beam_d, dists])
        all_exp = jnp.concatenate([expanded, jnp.ones((B * M,), jnp.bool_)])
        all_exp = all_exp.at[ef:].set(~fetch_mask)
        _, order = jax.lax.top_k(-all_d, ef)
        return (it + 1, all_ids[order], all_d[order], all_exp[order],
                visited, n_adj, n_vec, n_filtered, n_hops,
                heat_nodes, heat_mask)

    init = (jnp.int32(0), beam_ids, beam_d, expanded, visited,
            n_adj, n_vec, n_filtered, n_hops, heat_nodes, heat_mask)
    (_, beam_ids, beam_d, _, _, n_adj, n_vec, n_filtered, n_hops,
     heat_nodes, heat_mask) = jax.lax.while_loop(cond, body, init)
    if returnable is not None:
        ok = (beam_ids >= 0) & returnable[jnp.clip(beam_ids, 0, cap - 1)]
        beam_d = jnp.where(ok, beam_d, INF)
        neg_d, order = jax.lax.top_k(-beam_d, ef)
        beam_d = -neg_d
        beam_ids = jnp.where(jnp.isfinite(beam_d), beam_ids[order], -1)
    if record_heat:
        heat_nodes = heat_nodes.reshape(heat_len * B)
        heat_mask = heat_mask.reshape(heat_len * B, M)
    else:
        heat_nodes = jnp.full((heat_len * B,), -1, jnp.int32)
        heat_mask = jnp.zeros((heat_len * B, M), jnp.bool_)
    stats = jnp.stack([n_adj, n_vec, n_filtered, n_hops])
    return beam_ids, beam_d, stats, heat_nodes, heat_mask


def beam_search_ref(qs, entries, entry_dists, adjacency, vectors, codes,
                    code_qs, live, q_norms, mean_norm, *,
                    returnable=None, resident=None, qvecs=None,
                    qscale=None, active=None, ef, k, m_bits, eps, rho,
                    max_iters, use_filter, n_expand=1, record_heat=True):
    """Whole-block beam search over dense operands, one fused launch.

    qs f32[Bq, dim]; entries int32[Bq]; entry_dists f32[Bq];
    adjacency int32[cap, M] (resolved snapshot rows, -1 pads);
    vectors f32[cap, dim]; codes uint32[cap, W]; code_qs uint32[Bq, W];
    live bool[cap] (routable); q_norms f32[Bq]; mean_norm f32[].
    Optional lanes: `returnable` bool[cap] (lazy-delete repack),
    `resident`/`qvecs`/`qscale` (tier split), `active` bool[Bq]
    (pad-lane masking).  Returns
    ``(ids [Bq, ef], dists [Bq, ef], stats int32[Bq, 4],
    heat_nodes [Bq, heat_len*B], heat_mask [Bq, heat_len*B, M])``
    where the stats columns are (n_adj, n_vec, n_filtered, n_hops).
    """
    has_active = active is not None
    if active is None:
        active = jnp.ones(qs.shape[0], jnp.bool_)
    one = partial(
        _beam_one, adjacency=adjacency, vectors=vectors, codes=codes,
        live=live, returnable=returnable, resident=resident, qvecs=qvecs,
        qscale=qscale, mean_norm=mean_norm, ef=ef, k=k, m_bits=m_bits,
        eps=eps, rho=rho, max_iters=max_iters, use_filter=use_filter,
        n_expand=n_expand, has_active=has_active, record_heat=record_heat)
    return jax.vmap(one)(qs, entries, entry_dists, code_qs, q_norms,
                         active)
