"""Public jit'd dispatch for the fused beam-search megakernel.

Mirrors the `gather_l2` family contract: backend-selected dispatch
(`use_pallas=None` -> TPU check), interpret-mode fallback for CPU
hosts, and row padding to a lane multiple of 128 handled here so both
backends see identical operands.  On non-TPU hosts the default route is
the pure-JAX oracle (`ref.beam_search_ref`) — the megakernel's win is
launch fusion + VMEM residency, which interpret mode cannot deliver
(DESIGN.md §15); the Pallas path stays reachable via
``use_pallas=True`` for the interpret-parity suite.

Jit handles are built once at module scope — never construct jits
inside dispatch functions here (`tools/repro_lint` JD103 treats every
top-level function of a ``kernels/*/ops.py`` module as a hot root).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.beam.kernel import beam_search_fused_pallas
from repro.kernels.beam.ref import beam_iter_cap, beam_search_ref

__all__ = ["fused_beam_search", "beam_iter_cap"]


def _on_tpu() -> bool:
    # lazy: calling default_backend() at import time would lock
    # the device count before test/dry-run env flags apply
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=(
    "ef", "k", "m_bits", "eps", "rho", "max_iters", "use_filter",
    "n_expand", "record_heat", "use_pallas", "interpret"))
def fused_beam_search(qs, entries, entry_dists, adjacency, vectors,
                      codes, code_qs, live, q_norms, mean_norm,
                      returnable=None, resident=None, qvecs=None,
                      qscale=None, active=None, *, ef, k, m_bits, eps,
                      rho, max_iters, use_filter, n_expand=1,
                      record_heat=True, use_pallas=None,
                      interpret=None):
    """Run the whole bottom-layer beam search for a query block in one
    fused launch.

    qs [Bq, dim]; entries int32[Bq]; entry_dists f32[Bq]; adjacency
    int32[cap, M] (resolved snapshot rows); vectors f32[cap, dim];
    codes uint32[cap, W]; code_qs uint32[Bq, W]; live bool[cap]
    (routable mask); q_norms f32[Bq]; mean_norm f32[].  Optional lanes:
    `returnable` (lazy-delete repack), `resident`/`qvecs`/`qscale`
    (tier split), `active` (pad-lane masking).  Returns
    ``(ids, dists, stats, heat_nodes, heat_mask)`` with stats columns
    (n_adj, n_vec, n_filtered, n_hops) — bit-parity with a vmapped
    `traversal.beam_search` over `_snapshot_adj_fn`/`_dist_fn`.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if interpret is None:
        interpret = not _on_tpu()
    kw = dict(ef=ef, k=k, m_bits=m_bits, eps=eps, rho=rho,
              max_iters=max_iters, use_filter=use_filter,
              n_expand=n_expand, record_heat=record_heat)
    if not use_pallas:
        return beam_search_ref(
            qs, entries, entry_dists, adjacency, vectors, codes,
            code_qs, live, q_norms, mean_norm, returnable=returnable,
            resident=resident, qvecs=qvecs, qscale=qscale,
            active=active, **kw)
    d = qs.shape[-1]
    pad = (-d) % 128
    if pad:
        qs = jnp.pad(qs, ((0, 0), (0, pad)))
        vectors = jnp.pad(vectors, ((0, 0), (0, pad)))
        if qvecs is not None:
            qvecs = jnp.pad(qvecs, ((0, 0), (0, pad)))
    return beam_search_fused_pallas(
        qs, entries, entry_dists, adjacency, vectors, codes, code_qs,
        live, q_norms, mean_norm, returnable=returnable,
        resident=resident, qvecs=qvecs, qscale=qscale, active=active,
        interpret=interpret, **kw)
