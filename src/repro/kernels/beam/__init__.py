from repro.kernels.beam.ops import beam_iter_cap, fused_beam_search

__all__ = ["fused_beam_search", "beam_iter_cap"]
