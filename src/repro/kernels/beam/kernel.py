"""Pallas TPU megakernel: the whole beam-search loop in one launch.

The per-hop `gather_l2` kernel already fuses fetch+distance for one
expansion block; the loop *around* it still round-trips the frontier
pop, heap merge and visited filter through XLA-generated sort/top-k ops
every trip (`traversal.beam_search`'s `while_loop`).  This kernel runs
the entire bottom-layer search for a query block in one launch — one
grid program per query:

 - the candidate heap (``ef`` slots: ids / distances / expanded flags)
   and the visited filter (``bool[cap+1]``, same spare-slot contract as
   the host loop) live in VMEM-resident loop carries across every
   expansion — they never touch HBM until the final result write;
 - adjacency rows and candidate vector rows stay in HBM (`pl.ANY`) and
   are gathered per trip with explicit `make_async_copy` DMAs into VMEM
   scratch — issue-all-then-wait, so the row fetches overlap like the
   scalar-prefetch pipeline in `gather_l2` (the ids are data-dependent
   on the heap state, so they cannot come from a prefetch operand);
 - SimHash codes, liveness/returnable/resident lanes and per-row cold
   scales are VMEM-resident tables (they are the "in-memory" half of
   the paper's hybrid layout);
 - the tier split fetches the f32 lane for resident rows and the int8
   lane (fused dequant) for cold rows, merged by elementwise min with
   +inf in the non-owning lane — `_tier_dist_fn` semantics.

Selection ops: Mosaic has no `top_k`/`argsort`, so every pop / merge /
repack uses stable *rank-by-comparison*: ``rank[i] = #{j: d[j] < d[i]}
+ #{j < i: d[j] == d[i]}`` — exactly the position a stable ascending
sort assigns, which is also exactly `lax.top_k`'s tie-break on ``-d``
(ties prefer the lower index).  Rank-selection therefore reproduces the
host loop's tie behavior identically; see DESIGN.md §15.

The `while_loop` becomes a `fori_loop` over the same static trip cap
with a monotone-false ``go`` carry: a trip whose continuation predicate
fails is a provable no-op (all updates are gated by the empty ``active``
set), so the fori/while results are bit-identical.

Dimensions must be padded to a lane multiple of 128 (`ops.py` pads;
zero pad lanes add exactly +0.0 to every squared distance).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INF = jnp.inf


def _ranks_asc(d: jax.Array) -> jax.Array:
    """Stable ascending rank of each element (ties -> lower index first).

    Equivalent to ``argsort(argsort(d, stable), stable)`` and to the
    index positions `lax.top_k(-d, n)` would emit — without a sort op.
    """
    n = d.shape[0]
    less = d[None, :] < d[:, None]
    eq = d[None, :] == d[:, None]
    col = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    row = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    return jnp.sum((less | (eq & (col < row))).astype(jnp.int32), axis=1)


def _ranks_desc(s: jax.Array) -> jax.Array:
    """Stable descending rank — `traversal._rank_desc` without sorts."""
    n = s.shape[0]
    gt = s[None, :] > s[:, None]
    eq = s[None, :] == s[:, None]
    col = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    row = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    return jnp.sum((gt | (eq & (col < row))).astype(jnp.int32), axis=1)


def _sel_matrix(ranks: jax.Array, m: int) -> jax.Array:
    """sel[s, i] = (ranks[i] == s) for s < m.  Ranks are a permutation,
    so each row has exactly one True — gathers become one-hot reduces."""
    n = ranks.shape[0]
    s = jax.lax.broadcasted_iota(jnp.int32, (m, n), 0)
    return s == ranks[None, :]


def _take(sel: jax.Array, a: jax.Array) -> jax.Array:
    """out[s] = a[i] where sel[s, i] — exact for ints, floats (inc. inf)
    and bools because each row of `sel` selects exactly one element."""
    if a.dtype == jnp.bool_:
        return jnp.any(sel & a[None, :], axis=1)
    return jnp.sum(jnp.where(sel, a[None, :], jnp.zeros_like(a)[None, :]),
                   axis=1)


def _gather_dma(table_ref, idxs: jax.Array, scratch, sems, n: int,
                sem_base: int):
    """DMA `n` data-dependent rows of `table_ref` (HBM) into `scratch`
    (VMEM): issue every copy, then wait — the issue-all window is what
    lets the DMA engine overlap row fetches across the block."""
    copies = []
    for j in range(n):
        c = pltpu.make_async_copy(table_ref.at[pl.ds(idxs[j], 1)],
                                  scratch.at[pl.ds(j, 1)],
                                  sems.at[sem_base + j])
        c.start()
        copies.append(c)
    for c in copies:
        c.wait()


def _onehot_cols(idxs: jax.Array, n_rows: int) -> jax.Array:
    """oh[c, j] = (idxs[j] == c) — VMEM-table gather as a masked reduce."""
    m = idxs.shape[0]
    c = jax.lax.broadcasted_iota(jnp.int32, (n_rows, m), 0)
    return c == idxs[None, :]


def _make_beam_kernel(*, B, M, ef, k, cap, dpad, W, iter_cap, max_iters,
                      m_bits, eps, rho, use_filter, tier, lazy,
                      record_heat):
    BM = B * M
    fidx = min(ef, 3 * k) - 1
    import math
    if use_filter:
        slack = math.sqrt(m_bits * math.log(1.0 / eps) / 2.0)

    def kernel(q_ref, entry_ref, entryd_ref, codeq_ref, qn_ref, act_ref,
               mn_ref, adj_ref, vec_ref, codes_ref, live_ref, ret_ref,
               *rest):
        if tier:
            res_ref, qvec_ref, qscale_ref = rest[:3]
            rest = rest[3:]
        ids_out, d_out, stats_out, heatn_out, heatm_out = rest[:5]
        scratch = rest[5:]
        if tier:
            adj_s, vec_s, qvec_s, sems = scratch
        else:
            adj_s, vec_s, sems = scratch

        q = q_ref[0, :]                                  # [dpad]
        entry = entry_ref[0, 0]
        entry_d = entryd_ref[0, 0]
        code_q = codeq_ref[0, :]                         # [W]
        q_norm = qn_ref[0, 0]
        mean_norm = mn_ref[0, 0]
        lane = act_ref[0, 0] != 0
        codes = codes_ref[...]                           # [cap, W]
        live = live_ref[..., 0] != 0                     # [cap]
        iota_cap1 = jax.lax.broadcasted_iota(
            jnp.int32, (cap + 1, 1), 0)[:, 0]

        # -- init: entry seeds slot 0; masked lanes never enter --------
        entry_d = jnp.where(lane, entry_d, INF)
        entry = jnp.where(lane, entry, -1)
        beam_ids = jnp.where(
            jax.lax.broadcasted_iota(jnp.int32, (ef, 1), 0)[:, 0] == 0,
            entry, -1)
        beam_d = jnp.where(
            jax.lax.broadcasted_iota(jnp.int32, (ef, 1), 0)[:, 0] == 0,
            entry_d, INF)
        expanded = jnp.zeros((ef,), jnp.bool_)
        visited = (iota_cap1 == jnp.maximum(entry, 0)) & (entry >= 0)
        n_adj = jnp.zeros((), jnp.int32)
        n_vec = lane.astype(jnp.int32)
        n_filt = jnp.zeros((), jnp.int32)
        n_hops = jnp.zeros((), jnp.int32)

        def trip(it, carry):
            if record_heat:
                (beam_ids, beam_d, expanded, visited,
                 n_adj, n_vec, n_filt, n_hops, go,
                 heat_nodes, heat_mask) = carry
            else:
                (beam_ids, beam_d, expanded, visited,
                 n_adj, n_vec, n_filt, n_hops, go) = carry

            # continuation predicate of the host while_loop; a False
            # trip zeroes `act` below and the whole body is a no-op
            thresh = beam_d[fidx]
            frontier = (~expanded) & jnp.isfinite(beam_d) \
                & (beam_d <= thresh)
            go = go & (n_hops < max_iters) & jnp.any(frontier)

            # -- pop the B closest unexpanded (stable rank select) ----
            frontier_d = jnp.where(expanded, INF, beam_d)
            ranks = _ranks_asc(frontier_d)
            sel = _sel_matrix(ranks, B)                  # [B, ef]
            sel_d = _take(sel, frontier_d)
            act = go & jnp.isfinite(sel_d) & (sel_d <= thresh)
            expanded = expanded | jnp.any(sel & act[:, None], axis=0)
            nodes = jnp.where(act, _take(sel, beam_ids), -1)

            # -- adjacency rows: B data-dependent DMAs from HBM -------
            _gather_dma(adj_ref, jnp.maximum(nodes, 0), adj_s, sems,
                        B, 0)
            rows = jnp.where((nodes >= 0)[:, None], adj_s[...], -1)
            row = rows.reshape(BM)
            valid = (row >= 0) & (row <= cap - 1)
            safe = jnp.where(valid, row, cap)
            oh1 = _onehot_cols(safe, cap + 1)            # [cap+1, BM]
            seen = jnp.any(visited[:, None] & oh1, axis=0)
            ohc = oh1[:cap, :]                           # [cap, BM]
            alive = jnp.where(valid,
                              jnp.any(live[:, None] & ohc, axis=0),
                              False)
            eligible = valid & (~seen) & alive
            if B > 1:
                eq = safe[None, :] == safe[:, None]
                colj = jax.lax.broadcasted_iota(jnp.int32, (BM, BM), 1)
                rowi = jax.lax.broadcasted_iota(jnp.int32, (BM, BM), 0)
                earlier = eq & (colj < rowi)
                eligible = eligible & ~jnp.any(earlier, axis=1)

            # -- SimHash prefilter from the VMEM code table -----------
            cand_codes = jnp.stack(
                [jnp.sum(jnp.where(ohc, codes[:, w][:, None],
                                   jnp.uint32(0)), axis=0)
                 for w in range(W)], axis=1)             # [BM, W]
            ham = jnp.sum(jax.lax.population_count(
                code_q[None, :] ^ cand_codes), axis=-1)
            cols = (m_bits - ham).astype(jnp.int32)
            delta_sq = beam_d[k - 1]
            if use_filter:
                denom = jnp.maximum(2.0 * q_norm * mean_norm, 1e-12)
                cos = jnp.clip(
                    (q_norm ** 2 + mean_norm ** 2 - delta_sq) / denom,
                    -1.0, 1.0)
                theta = jnp.arccos(jnp.clip(cos, -1.0, 1.0))
                thr = (1.0 - theta / jnp.pi) * m_bits - slack
                pass_thr = (cols.astype(jnp.float32) >= thr) \
                    | ~jnp.isfinite(delta_sq)
            else:
                pass_thr = jnp.ones_like(eligible)
            pre_mask = eligible & pass_thr

            if isinstance(rho, (int, float)) and rho >= 1.0:
                fetch_mask = pre_mask
            else:
                score = jnp.where(pre_mask, cols, -1)
                rank_s = _ranks_desc(score)
                n_elig = jnp.sum(pre_mask)
                cap_dyn = jnp.ceil(rho * n_elig).astype(jnp.int32)
                fetch_mask = pre_mask & (rank_s < cap_dyn)
            fetch_ids = jnp.where(fetch_mask, row, -1)

            # -- candidate vectors: BM data-dependent DMAs, fused L2 --
            if not tier:
                _gather_dma(vec_ref, jnp.maximum(fetch_ids, 0), vec_s,
                            sems, BM, 0)
                diff = q[None, :] - vec_s[...]
                dists = jnp.where(fetch_ids >= 0,
                                  jnp.sum(diff * diff, axis=1), INF)
            else:
                res = jnp.any((res_ref[..., 0] != 0)[:, None]
                              & _onehot_cols(jnp.maximum(fetch_ids, 0),
                                             cap), axis=0)
                hot_ids = jnp.where((fetch_ids >= 0) & res,
                                    fetch_ids, -1)
                cold_ids = jnp.where((fetch_ids >= 0) & ~res,
                                     fetch_ids, -1)
                _gather_dma(vec_ref, jnp.maximum(hot_ids, 0), vec_s,
                            sems, BM, 0)
                _gather_dma(qvec_ref, jnp.maximum(cold_ids, 0), qvec_s,
                            sems, BM, BM)
                diff = q[None, :] - vec_s[...]
                d_hot = jnp.where(hot_ids >= 0,
                                  jnp.sum(diff * diff, axis=1), INF)
                ohq = _onehot_cols(jnp.maximum(cold_ids, 0), cap)
                scale = jnp.sum(jnp.where(ohq, qscale_ref[...],
                                          0.0), axis=0)        # [BM]
                deq = qvec_s[...].astype(jnp.float32) * scale[:, None]
                diff_c = q[None, :] - deq
                d_cold = jnp.where(cold_ids >= 0,
                                   jnp.sum(diff_c * diff_c, axis=1),
                                   INF)
                dists = jnp.minimum(d_hot, d_cold)

            # -- bookkeeping (visited scatter as a masked reduce) -----
            visited = visited | jnp.any(oh1 & fetch_mask[None, :],
                                        axis=1)
            n_fetch = jnp.sum(fetch_mask).astype(jnp.int32)
            n_adj = n_adj + jnp.sum(act.astype(jnp.int32))
            n_vec = n_vec + n_fetch
            n_filt = n_filt \
                + jnp.sum(eligible).astype(jnp.int32) - n_fetch
            n_hops = n_hops + jnp.sum(act).astype(jnp.int32)
            if record_heat:
                at_it = jax.lax.broadcasted_iota(
                    jnp.int32, (iter_cap, 1), 0)[:, 0] == it
                heat_nodes = jnp.where(at_it[:, None], nodes[None, :],
                                       heat_nodes)
                heat_mask = jnp.where(
                    at_it[:, None, None],
                    fetch_mask.reshape(1, B, M), heat_mask)

            # -- single stable-rank merge of the whole block ----------
            all_ids = jnp.concatenate([beam_ids, fetch_ids])
            all_d = jnp.concatenate([beam_d, dists])
            all_exp = jnp.concatenate(
                [expanded, ~fetch_mask])
            mranks = _ranks_asc(all_d)
            msel = _sel_matrix(mranks, ef)               # [ef, ef+BM]
            out = (_take(msel, all_ids), _take(msel, all_d),
                   _take(msel, all_exp), visited,
                   n_adj, n_vec, n_filt, n_hops, go)
            if record_heat:
                out = out + (heat_nodes, heat_mask)
            return out

        carry = (beam_ids, beam_d, expanded, visited,
                 n_adj, n_vec, n_filt, n_hops, jnp.bool_(True))
        if record_heat:
            carry = carry + (jnp.full((iter_cap, B), -1, jnp.int32),
                             jnp.zeros((iter_cap, B, M), jnp.bool_))
        carry = jax.lax.fori_loop(0, iter_cap, trip, carry)
        beam_ids, beam_d = carry[0], carry[1]
        n_adj, n_vec, n_filt, n_hops = carry[4:8]

        if lazy:
            ret = ret_ref[..., 0] != 0                   # [cap]
            ohb = _onehot_cols(jnp.clip(beam_ids, 0, cap - 1), cap)
            ok = (beam_ids >= 0) & jnp.any(ret[:, None] & ohb, axis=0)
            beam_d = jnp.where(ok, beam_d, INF)
            rranks = _ranks_asc(beam_d)
            rsel = _sel_matrix(rranks, ef)
            beam_d = _take(rsel, beam_d)
            beam_ids = jnp.where(jnp.isfinite(beam_d),
                                 _take(rsel, beam_ids), -1)

        ids_out[...] = beam_ids[None, :]
        d_out[...] = beam_d[None, :]
        stats_out[...] = jnp.stack([n_adj, n_vec, n_filt,
                                    n_hops])[None, :]
        if record_heat:
            heatn_out[...] = carry[9].reshape(1, iter_cap * B)
            heatm_out[...] = carry[10].reshape(1, iter_cap * B * M)
        else:
            heatn_out[...] = jnp.full((1, 1), -1, jnp.int32)
            heatm_out[...] = jnp.zeros((1, 1), jnp.bool_)

    return kernel


@functools.partial(jax.jit, static_argnames=(
    "ef", "k", "m_bits", "eps", "rho", "max_iters", "use_filter",
    "n_expand", "record_heat", "interpret"))
def beam_search_fused_pallas(qs, entries, entry_dists, adjacency,
                             vectors, codes, code_qs, live, q_norms,
                             mean_norm, returnable=None, resident=None,
                             qvecs=None, qscale=None, active=None, *,
                             ef, k, m_bits, eps, rho, max_iters,
                             use_filter, n_expand=1, record_heat=True,
                             interpret=False):
    """One-launch beam search for a query block.  Same operand contract
    and return tuple as `ref.beam_search_ref`; `dim` must already be
    padded to a multiple of 128 (`ops.py` pads)."""
    bq, dpad = qs.shape
    cap, M = adjacency.shape
    W = codes.shape[1]
    assert dpad % 128 == 0, "pad dim to a lane multiple"
    B = max(1, min(n_expand, ef))
    BM = B * M
    iter_cap = min(max_iters, -(-max_iters // B) + 3)
    tier = resident is not None
    lazy = returnable is not None
    heat_len = iter_cap * B

    def as_col(a, dt):
        return a.astype(dt).reshape(-1, 1)

    ops = [qs,
           as_col(entries, jnp.int32),
           as_col(entry_dists, jnp.float32),
           code_qs,
           as_col(q_norms, jnp.float32),
           (jnp.ones((bq, 1), jnp.int32) if active is None
            else as_col(active, jnp.int32)),
           mean_norm.astype(jnp.float32).reshape(1, 1),
           adjacency, vectors, codes,
           as_col(live, jnp.int32),
           (jnp.ones((cap, 1), jnp.int32) if returnable is None
            else as_col(returnable, jnp.int32))]
    def per_q(w):
        return pl.BlockSpec((1, w), lambda i: (i, 0))

    def shared(shp):
        return pl.BlockSpec(shp, lambda i: tuple(0 for _ in shp))

    in_specs = [per_q(dpad), per_q(1), per_q(1), per_q(W), per_q(1),
                per_q(1), shared((1, 1)),
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY),
                shared((cap, W)), shared((cap, 1)), shared((cap, 1))]
    scratch = [pltpu.VMEM((B, M), jnp.int32),
               pltpu.VMEM((BM, dpad), jnp.float32)]
    if tier:
        ops += [as_col(resident, jnp.int32), qvecs,
                as_col(qscale, jnp.float32)]
        in_specs += [shared((cap, 1)),
                     pl.BlockSpec(memory_space=pltpu.ANY),
                     shared((cap, 1))]
        scratch.append(pltpu.VMEM((BM, dpad), jnp.int8))
    scratch.append(pltpu.SemaphoreType.DMA((2 * BM,)))

    hn = heat_len if record_heat else 1
    hm = heat_len * M if record_heat else 1
    out_shape = [jax.ShapeDtypeStruct((bq, ef), jnp.int32),
                 jax.ShapeDtypeStruct((bq, ef), jnp.float32),
                 jax.ShapeDtypeStruct((bq, 4), jnp.int32),
                 jax.ShapeDtypeStruct((bq, hn), jnp.int32),
                 jax.ShapeDtypeStruct((bq, hm), jnp.bool_)]
    out_specs = [per_q(ef), per_q(ef), per_q(4), per_q(hn), per_q(hm)]

    kernel = _make_beam_kernel(
        B=B, M=M, ef=ef, k=k, cap=cap, dpad=dpad, W=W,
        iter_cap=iter_cap, max_iters=max_iters, m_bits=m_bits, eps=eps,
        rho=rho, use_filter=use_filter, tier=tier, lazy=lazy,
        record_heat=record_heat)
    ids, dists, stats, heatn, heatm = pl.pallas_call(
        kernel, grid=(bq,), in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, scratch_shapes=scratch,
        interpret=interpret)(*ops)
    if not record_heat:
        heatn = jnp.full((bq, heat_len), -1, jnp.int32)
        heatm = jnp.zeros((bq, heat_len, M), jnp.bool_)
    else:
        heatm = heatm.reshape(bq, heat_len, M)
    return ids, dists, stats, heatn, heatm
