"""Pure-jnp oracles for the SimHash encode / collision-count kernels."""

import jax
import jax.numpy as jnp


def simhash_encode_ref(x: jnp.ndarray, proj: jnp.ndarray) -> jnp.ndarray:
    """x [N, d], proj [m, d] -> packed codes uint32[N, m/32]."""
    bits = (x.astype(jnp.float32) @ proj.T.astype(jnp.float32)) >= 0.0
    n, m = bits.shape
    bits = bits.reshape(n, m // 32, 32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return jnp.sum(bits.astype(jnp.uint32) * weights, axis=-1, dtype=jnp.uint32)


def collision_count_ref(codes_q: jnp.ndarray, codes_c: jnp.ndarray,
                        m_bits: int) -> jnp.ndarray:
    """codes_q uint32[Q, W], codes_c uint32[N, W] -> collisions int32[Q, N]."""
    x = codes_q[:, None, :] ^ codes_c[None, :, :]
    ham = jnp.sum(jax.lax.population_count(x), axis=-1)
    return (m_bits - ham).astype(jnp.int32)
