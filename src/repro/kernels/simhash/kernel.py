"""Pallas TPU kernels for the in-memory SimHash filter (§3.3).

Two kernels:

1. `simhash_encode` — projection matmul (MXU) + sign + bit packing (VPU).
   Runs at insert time, one row per new vector.
2. `collision_count` — XOR + popcount between query codes and candidate
   codes.  This is the *prefilter* the traversal runs before any HBM vector
   fetch; it must be far cheaper than the fetch it saves, which is why it
   stays in the fast tier (VMEM-resident packed uint32 words).

Packing note: bits land in uint32 words via a small [32] weight dot — the
VPU-friendly form of a bit shift reduce.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _encode_kernel(x_ref, proj_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)            # [bn, d]
    p = proj_ref[...].astype(jnp.float32)         # [d, m]
    z = jax.lax.dot_general(x, p, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    bits = (z >= 0.0)                              # [bn, m]
    bn, m = bits.shape
    bits = bits.reshape(bn, m // 32, 32).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    o_ref[...] = jnp.sum(bits * weights, axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def simhash_encode_pallas(x: jax.Array, proj: jax.Array,
                          *, block_n: int = 256,
                          interpret: bool = False) -> jax.Array:
    """x [N, d], proj [m, d] -> uint32[N, m/32].  N % block_n == 0."""
    n, d = x.shape
    m = proj.shape[0]
    assert n % block_n == 0 and m % 32 == 0
    proj_t = proj.T  # [d, m] — feed the MXU contiguous lanes

    return pl.pallas_call(
        _encode_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, m // 32), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m // 32), jnp.uint32),
        interpret=interpret,
    )(x, proj_t)


def _collision_kernel(q_ref, c_ref, o_ref, *, m_bits: int):
    q = q_ref[...]                                  # [bq, W]
    c = c_ref[...]                                  # [bn, W]
    x = q[:, None, :] ^ c[None, :, :]               # [bq, bn, W]
    ham = jnp.sum(jax.lax.population_count(x), axis=-1)
    o_ref[...] = (m_bits - ham).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("m_bits", "block_q", "block_n",
                                             "interpret"))
def collision_count_pallas(codes_q: jax.Array, codes_c: jax.Array,
                           m_bits: int, *, block_q: int = 8,
                           block_n: int = 512,
                           interpret: bool = False) -> jax.Array:
    """codes_q uint32[Q, W] x codes_c uint32[N, W] -> int32[Q, N]."""
    q, w = codes_q.shape
    n, _ = codes_c.shape
    assert q % block_q == 0 and n % block_n == 0

    return pl.pallas_call(
        functools.partial(_collision_kernel, m_bits=m_bits),
        grid=(q // block_q, n // block_n),
        in_specs=[
            pl.BlockSpec((block_q, w), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, n), jnp.int32),
        interpret=interpret,
    )(codes_q, codes_c)
