"""Public jit'd wrappers for the SimHash kernels (pad/unpad + dispatch)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.simhash.kernel import collision_count_pallas, simhash_encode_pallas
from repro.kernels.simhash.ref import collision_count_ref, simhash_encode_ref

def _on_tpu() -> bool:
    # lazy: calling default_backend() at import time would lock
    # the device count before test/dry-run env flags apply
    return jax.default_backend() == "tpu"


def _pad_rows(x: jax.Array, multiple: int) -> jax.Array:
    pad = (-x.shape[0]) % multiple
    return jnp.pad(x, ((0, pad), (0, 0))) if pad else x


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def simhash_encode(x: jax.Array, proj: jax.Array, *,
                   use_pallas: bool | None = None,
                   interpret: bool | None = None) -> jax.Array:
    """x [N, d], proj [m, d] -> packed uint32[N, m/32]."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if interpret is None:
        interpret = not _on_tpu()
    if not use_pallas:
        return simhash_encode_ref(x, proj)
    n = x.shape[0]
    block = 256 if n >= 256 else 8
    xp = _pad_rows(x, block)
    return simhash_encode_pallas(xp, proj, block_n=block,
                                 interpret=interpret)[:n]


@functools.partial(jax.jit, static_argnames=("m_bits", "use_pallas",
                                             "interpret"))
def collision_count(codes_q: jax.Array, codes_c: jax.Array, m_bits: int, *,
                    use_pallas: bool | None = None,
                    interpret: bool | None = None) -> jax.Array:
    """Matching-bit counts (Eq. 5) between every query/candidate pair."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if interpret is None:
        interpret = not _on_tpu()
    if not use_pallas:
        return collision_count_ref(codes_q, codes_c, m_bits)
    q, n = codes_q.shape[0], codes_c.shape[0]
    bq = 8
    bn = 512 if n >= 512 else 8
    qp = _pad_rows(codes_q, bq)
    cp = _pad_rows(codes_c, bn)
    return collision_count_pallas(qp, cp, m_bits, block_q=bq, block_n=bn,
                                  interpret=interpret)[:q, :n]
