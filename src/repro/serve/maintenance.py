"""Background maintenance policy: threshold-triggered consolidation,
compaction, and connectivity-aware relayout (DESIGN.md §8-10, §13).

The paper runs graph reordering piggybacked on LSM compaction (§3.4);
the seed repo left both as manual calls.  Here they become policy,
applied to any `VectorBackend` through its uniform
`maintain(op, **params) -> MaintenanceReport` method: the engine tracks
tombstone pressure host-side (no device syncs) and samples the
accumulated edge heat at a fixed batch cadence, triggering

- `maintain("consolidate")` when lazily-deleted (routable-but-not-
  returnable) nodes exceed `consolidate_ratio` of the index — the
  Quake-style live-workload trigger for the FreshDiskANN-style graph
  repair that splices tombstones out and reclaims their slots
  (DESIGN.md §9).  The check is **per shard**: the trigger fires when
  any shard's own ratio crosses the threshold
  (`BackendStats.max_tombstone_ratio`), and the backend consolidates
  exactly the shards over it.  With `overlap` (default) the repair runs
  double-buffered via `begin_maintain`/`poll_maintain` — queries keep
  serving from the live state while the `lax.map` repair computes, and
  the cutover lands either at a poll or at the next write barrier
  (DESIGN.md §13),
- `maintain("compact")` when staged deletes since the last compaction
  exceed `tombstone_ratio` of the live set — bounding LSM read
  amplification and the dead-entry tax on resolve, and
- `maintain("reorder")` when total sampled edge heat exceeds
  `heat_budget` — enough fresh traversal signal that a relayout pays
  for itself.

Reordering permutes internal ids, so the engine owns an
external↔internal id mapping and folds each permutation (returned in
`MaintenanceReport.perm`, global across shards) into it; clients keep
their ids.  Consolidation retires internal ids without reusing them, so
the same map needs no rewrite — reclaimed entries simply become inert.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional

import numpy as np

from repro.tier import TierPolicy


@dataclass
class MaintenancePolicy:
    """Thresholds; None disables the corresponding trigger."""

    #: LSM-staged deletes / live size (eager mode; lazy deletes stage
    #: nothing — consolidation doubles as their major compaction)
    tombstone_ratio: Optional[float] = 0.25
    #: graph tombstones / (live + tombstones) before consolidation runs,
    #: evaluated per shard (only meaningful under lazy deletion)
    consolidate_ratio: Optional[float] = 0.25
    heat_budget: Optional[int] = None         # total edge-heat counts
    check_every: int = 16                     # write batches between checks
    reorder_window: int = 8
    reorder_lam: float = 1.0
    #: write batches between covering checkpoints (DESIGN.md §11); None
    #: disables the trigger.  Unlike the threshold triggers this is a
    #: plain host counter — no device sync to evaluate — and it is not
    #: gated on `check_every`: durability cadence must not stretch just
    #: because maintenance probes are sparse.
    checkpoint_every: Optional[int] = None
    #: tiered hot/cold lane policy (DESIGN.md §12); None disables.  A
    #: demote/promote pass runs on every due check (it is a cheap jitted
    #: no-op when the hot fraction already sits inside the hysteresis
    #: band), per shard — heat is shard-local, like the consolidate
    #: trigger.  Requires the backend's HNSWConfig to have `tier=True`.
    tier_policy: Optional[TierPolicy] = None
    #: overlapped consolidation (DESIGN.md §13): run the repair
    #: double-buffered against the live state instead of stop-the-world
    #: between micro-batches.  Cutover is atomic — at a poll once the
    #: repair's device work finishes, or at the next mutation's write
    #: barrier, whichever comes first — so correctness is unchanged;
    #: only query tail latency improves.
    overlap: bool = True


class MaintenanceManager:
    """Applies a MaintenancePolicy to one `VectorBackend`."""

    def __init__(self, backend, policy: MaintenancePolicy):
        self.backend = backend
        self.policy = policy
        self.deletes_since_compact = 0
        self.write_batches_since_check = 0
        self.write_batches_since_ckpt = 0
        self.compactions = 0
        self.reorders = 0
        self.consolidations = 0
        self.slots_reclaimed = 0
        self.checkpoints = 0
        self.tier_passes = 0
        self.tier_demoted = 0
        self.tier_promoted = 0
        #: an overlapped repair has begun and its report is unclaimed
        self.overlap_inflight = False
        self.last_perm: Optional[np.ndarray] = None
        #: the engine wires its `checkpoint()` here; the manager owns
        #: only the cadence (checkpoint_every write batches)
        self.checkpoint_fn: Optional[Callable[[], Optional[str]]] = None
        #: failure-injection gate (ServeEngine._crash); called at the
        #: mid-consolidation point of the crash-recovery matrix
        self.crash_hook: Optional[Callable[[str], None]] = None

    def note_deletes(self, n: int) -> None:
        """Count LSM-staged deletes toward the compact trigger.

        Lazy deletes are tombstone-bit-only — they stage nothing in the
        LSM, so they must not accrue compaction pressure (a compact
        would rewrite every level to drop zero dead entries and
        invalidate the read snapshot for nothing); consolidation is
        their compaction and resets the counter itself.
        """
        if not self.backend.lazy_delete:
            self.deletes_since_compact += n

    def note_write_batch(self) -> None:
        self.write_batches_since_check += 1
        self.write_batches_since_ckpt += 1

    def due(self) -> bool:
        return self.write_batches_since_check >= self.policy.check_every

    def maybe_checkpoint(self) -> bool:
        """Fire the covering-checkpoint callback when enough write
        batches have accumulated.  Returns True if a checkpoint ran.
        The counter resets before the callback: a crash mid-checkpoint
        must not re-arm the trigger on the very next batch of the dead
        process (the recovered engine starts its own cadence)."""
        pol = self.policy
        if pol.checkpoint_every is None or self.checkpoint_fn is None:
            return False
        if self.write_batches_since_ckpt < pol.checkpoint_every:
            return False
        self.write_batches_since_ckpt = 0
        if self.checkpoint_fn() is None:
            return False
        self.checkpoints += 1
        return True

    def _note_consolidation(self, reclaimed: int) -> None:
        """Book one finished consolidation: counters, the crash-matrix
        injection point, and the compact-counter reset (the rebuilt
        store is fully compacted and tombstone-free)."""
        if self.crash_hook is not None:
            # the consolidation mutated backend state that no WAL
            # record describes — the injection point proves recovery
            # does not depend on consolidation timing
            self.crash_hook("mid_consolidation")
        self.slots_reclaimed += reclaimed
        self.consolidations += 1
        self.deletes_since_compact = 0

    def poll_overlap(self, *, block: bool = False) -> bool:
        """Claim a finished overlapped consolidation (True iff one was
        claimed).  Cheap when nothing is in flight; a repair finished
        early by a mutation's write barrier is claimed here too."""
        if not self.overlap_inflight:
            return False
        rep = self.backend.poll_maintain(block=block)
        if rep is None:
            return False
        self.overlap_inflight = False
        if rep.applied:
            self._note_consolidation(rep.reclaimed)
            return True
        return False

    def barrier(self) -> bool:
        """Force any in-flight overlapped repair to completion and claim
        it (drain/checkpoint semantics).  True iff one was claimed."""
        return self.poll_overlap(block=True)

    def run_if_due(self, *, force: bool = False) -> List[str]:
        """Check thresholds and run triggered maintenance.

        Returns the actions taken (possibly empty).  Every op routes
        through the backend's uniform `maintain()` (or the async
        `begin_maintain`/`poll_maintain` pair when `policy.overlap`);
        the manager never string-dispatches over per-op return shapes —
        it reads one `MaintenanceReport`.  The stats and heat probes
        cost device->host scalar syncs, which is why they ride the
        `check_every` cadence; the overlap claim poll is host-only and
        runs on every call so a finished repair is booked promptly.
        The engine re-maps ids via the perm recorded in `last_perm`.
        """
        actions: List[str] = []
        # claim outside the due gate: a repair that finished between
        # checks must not wait out the cadence to be booked
        if self.poll_overlap():
            actions.append("consolidate")
        if not (force or self.due()):
            return actions
        self.write_batches_since_check = 0
        self.last_perm = None

        pol = self.policy
        st = None
        if (pol.consolidate_ratio is not None and self.backend.lazy_delete
                and not self.overlap_inflight):
            # one stats fetch per check: per-shard tombstone pressure is
            # the Quake-style live-workload signal
            st = self.backend.stats()
            if st.n_tombstones > 0 \
                    and st.max_tombstone_ratio >= pol.consolidate_ratio:
                if pol.overlap and hasattr(self.backend, "begin_maintain"):
                    if self.backend.begin_maintain(
                            "consolidate", ratio=pol.consolidate_ratio):
                        self.overlap_inflight = True
                        st = None   # stale once the repair cuts over
                else:
                    rep = self.backend.maintain(
                        "consolidate", ratio=pol.consolidate_ratio)
                    if rep.applied:
                        self._note_consolidation(rep.reclaimed)
                        actions.append("consolidate")
                        st = None   # stale after consolidation

        if pol.tombstone_ratio is not None and self.deletes_since_compact:
            if st is None:
                st = self.backend.stats()
            live = max(st.size, 1)
            if self.deletes_since_compact / live >= pol.tombstone_ratio:
                self.backend.maintain("compact")
                self.deletes_since_compact = 0
                self.compactions += 1
                actions.append("compact")

        if pol.heat_budget is not None:
            if self.backend.heat_total() >= pol.heat_budget:
                rep = self.backend.maintain(
                    "reorder", window=pol.reorder_window,
                    lam=pol.reorder_lam)
                self.last_perm = rep.perm
                self.backend.reset_heat()
                self.reorders += 1
                actions.append("reorder")

        if pol.tier_policy is not None:
            # after any reorder above: tier_maintain folds the heat the
            # reorder just consumed into its own EWMA, so running it
            # last keeps the two heat consumers in the same order every
            # check.  A pass that moves nothing still counts (the
            # trigger fired); the action is only recorded on real moves
            # so serve metrics show lane activity, not probe cadence.
            rep = self.backend.maintain("tier", policy=pol.tier_policy)
            self.tier_passes += 1
            self.tier_demoted += rep.demoted
            self.tier_promoted += rep.promoted
            if rep.applied:
                actions.append("tier")
        return actions
