"""Request/response plumbing for the online serving engine (DESIGN.md §8).

A request is one operation against the index — a single query vector, a
single insert vector, or a single external-id delete.  The engine owns
batching: callers submit individual requests and receive a `Ticket`, a
tiny future resolved when the micro-batch carrying the request completes.
"""

from __future__ import annotations

import enum
import threading
from dataclasses import dataclass, field
from typing import Any, Optional


class Op(enum.Enum):
    QUERY = "query"
    INSERT = "insert"
    DELETE = "delete"


class Ticket:
    """Completion handle for one submitted request.

    Thread-safe: `result()` blocks until the engine pumps the micro-batch
    that carries this request (with an optional timeout).  In
    single-threaded use, call `engine.drain()` first and `result()`
    returns immediately.
    """

    __slots__ = ("_event", "_value", "_error")

    def __init__(self):
        self._event = threading.Event()
        self._value: Any = None
        self._error: Optional[BaseException] = None

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def _complete(self, value: Any) -> None:
        self._value = value
        self._event.set()

    def _fail(self, err: BaseException) -> None:
        self._error = err
        self._event.set()

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._event.wait(timeout):
            raise TimeoutError("request not completed; pump the engine "
                               "(engine.drain()) or raise the timeout")
        if self._error is not None:
            raise self._error
        return self._value


@dataclass
class Request:
    """One enqueued operation. `seq` is the global arrival order."""

    op: Op
    payload: Any                      # query/insert: vector; delete: ext id
    seq: int
    t_enqueue: float
    ticket: Ticket = field(default_factory=Ticket)


@dataclass(frozen=True)
class QueryResult:
    """k nearest external ids + squared distances for one query."""

    ids: Any       # np.ndarray [k]
    dists: Any     # np.ndarray [k]
