"""Coalescing request queue: arrival-ordered FIFO with per-op batch caps
and coalescing windows (DESIGN.md §8).

Two gather modes decide which requests join a micro-batch:

- **strict** — the batch is the longest run of *consecutive* same-op
  requests at the head of the FIFO.  Queries never jump over a pending
  write and vice versa, so the executed schedule is serializable in
  arrival order: the stream produces exactly the results of applying
  every op one-by-one (the parity contract the tests pin).
- **relaxed** — the batch gathers same-op requests from anywhere in the
  queue (op chosen by the oldest pending request).  Queries may execute
  before an older write completes and writes of different ops may
  reorder around each other — the Quake-style throughput mode, where
  the workload mix shapes the batch instead of the arrival interleave.
  Same-op order is always preserved (insert ids stay deterministic,
  deletes stay FIFO), and cross-op write reordering cannot change the
  final live set: a delete can only name an id some already-completed
  insert returned, so no delete can jump ahead of "its" insert.  What
  may differ from arrival-order execution is which graph edges form
  around in-flight nodes — the usual relaxed-consistency ANN-serving
  trade, bounded by the recall guardrail in `benchmarks/serve_load.py`.

Release policy, shared by both modes: a gathered run is dispatched when
it reaches the op's batch cap, when its oldest member has waited at
least the op's coalescing window, or when the run cannot grow anymore
(strict mode: a different-op request is queued right behind it).
Otherwise the queue holds the run back, trading latency for occupancy.
"""

from __future__ import annotations

import collections
from typing import Deque, Dict, List, Optional, Tuple

from repro.serve.request import Op, Request


class CoalescingQueue:
    def __init__(self, *, batch_caps: Dict[Op, int],
                 windows: Dict[Op, float], strict_order: bool = False):
        self._fifo: Deque[Request] = collections.deque()
        self._caps = dict(batch_caps)
        self._windows = dict(windows)
        self.strict_order = strict_order

    def __len__(self) -> int:
        return len(self._fifo)

    def push(self, req: Request) -> None:
        self._fifo.append(req)

    def set_window(self, op: Op, window: float) -> None:
        """Retarget one op's coalescing window (the engine's adaptive
        batch shaping re-derives windows from the live arrival mix)."""
        self._windows[op] = window

    def windows(self) -> Dict[Op, float]:
        """Current per-op coalescing windows (a copy)."""
        return dict(self._windows)

    def has_pending(self, op: Op) -> bool:
        """True when at least one request of `op` is queued."""
        return any(r.op is op for r in self._fifo)

    def _gather(self, only_op: Optional[Op] = None
                ) -> Tuple[List[Request], bool]:
        """Candidate run for the next micro-batch (not yet removed).

        Returns (run, closed): `closed` means the run can never grow —
        it hit its cap, or (strict mode) a different-op request follows.
        Relaxed mode gathers the head op from anywhere in the queue:
        cross-op reordering is safe for liveness because a delete can
        only name an id some already-*completed* insert returned (the
        external-id contract), so only same-op arrival order — which
        every run preserves — is semantically load-bearing.
        `only_op` restricts the run to that op (the engine's write-hold
        during an overlapped repair, relaxed mode only); the run may be
        empty.
        """
        head_op = self._fifo[0].op if only_op is None else only_op
        cap = self._caps[head_op]
        run: List[Request] = []
        blocked = False
        for req in self._fifo:
            if req.op is head_op:
                run.append(req)
                if len(run) >= cap:
                    return run, True
            elif self.strict_order:
                blocked = True
                break
        if not self.strict_order:
            # an open run only stays open while it could still fill
            return run, False
        return run, blocked

    def next_batch(self, now: float, *, force: bool = False,
                   hold_writes: bool = False
                   ) -> Optional[Tuple[Op, List[Request]]]:
        """Pop the next micro-batch, or None if coalescing should wait.

        `now` comes from the engine's clock; `force` releases regardless
        of window state (used by drain()).  `hold_writes` (relaxed mode
        only — strict arrival order is the parity contract and is never
        reordered) restricts the batch to queries: the engine sets it
        while an overlapped repair is in flight so write batches — whose
        barrier would force the cutover early — defer until the repair
        lands, while queries keep flowing.  Returns None when only
        writes are pending under a hold.
        """
        if not self._fifo:
            return None
        only = Op.QUERY if (hold_writes and not self.strict_order) else None
        run, closed = self._gather(only)
        if not run:
            return None
        op = run[0].op
        expired = now - run[0].t_enqueue >= self._windows[op]
        if not (closed or expired or force):
            return None
        members = set(id(r) for r in run)
        self._fifo = collections.deque(
            r for r in self._fifo if id(r) not in members)
        return op, run

    def oldest_wait(self, now: float) -> float:
        """Age of the oldest pending request (0.0 when empty)."""
        return now - self._fifo[0].t_enqueue if self._fifo else 0.0
