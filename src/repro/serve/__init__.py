"""Online serving subsystem: continuous micro-batching over LSM-VEC.

The first layer of the repo that owns *time* (DESIGN.md §8): everything
under `repro.core` is pure functions over index state; this package
schedules an interleaved query/insert/delete stream onto them as
fixed-shape micro-batches with snapshot-cached reads and
threshold-driven maintenance.  The whole package programs against the
`VectorBackend` protocol (DESIGN.md §10) — single-device and sharded
backends serve through the identical code path.

- request    — Op/Request/Ticket plumbing
- queue      — arrival-ordered coalescing queue (strict/relaxed modes)
- scheduler  — ServeEngine: pad-and-mask dispatch, snapshot lifecycle,
  external-id ownership, adaptive batch shaping
- metrics    — p50/p99 latency, occupancy, QPS, chosen windows
- maintenance— tombstone/heat thresholds -> consolidate()/compact()/
  reorder(), applied per shard (lazy-delete consolidation: DESIGN.md §9)
- wal        — group-committed write-ahead log; with `ServeConfig.wal`
  set, acks imply durability and `ServeEngine.recover` restores the
  latest covering checkpoint + replays the tail (DESIGN.md §11)
"""

from repro.serve.maintenance import MaintenanceManager, MaintenancePolicy
from repro.serve.metrics import ServeMetrics
from repro.serve.queue import CoalescingQueue
from repro.serve.request import Op, QueryResult, Request, Ticket
from repro.serve.scheduler import ServeConfig, ServeEngine
from repro.serve.wal import WalConfig, WalRecord, WriteAheadLog

__all__ = [
    "Op", "QueryResult", "Request", "Ticket", "CoalescingQueue",
    "ServeMetrics", "MaintenancePolicy", "MaintenanceManager",
    "ServeConfig", "ServeEngine", "WalConfig", "WalRecord",
    "WriteAheadLog",
]
