"""Write-ahead log for the serve ingest path (DESIGN.md §11).

Durability contract: every insert/delete micro-batch is serialized as one
WAL record and **group-committed — fsync'd — before any of its tickets
resolve**.  A crash can lose un-acknowledged work (clients retry), but an
acknowledged write is always recoverable as

    restore latest checkpoint  +  replay WAL records with LSN > covering

where "covering" is the LSN the checkpoint manifest records
(`VectorBackend.save(lsn=...)`).  Replay re-dispatches each record
through the engine's normal batch path, so the recovered backend state is
bit-exact with the pre-crash state for the same record sequence.

Record format (little-endian), one record per micro-batch::

    [crc u32][len u32][lsn u64][kind u8][payload len-9 bytes]

`len` counts lsn+kind+payload; `crc` is zlib.crc32 over everything after
the crc field.  LSNs are assigned monotonically from 1 (0 = "none").
Payloads:

- ``KIND_INSERT``: ``n u32 | dim u32 | ext_ids int64[n] | vectors f32[n*dim]``
  — the engine-assigned external ids plus the raw vectors, exactly the
  batch that was dispatched (replay reproduces the identical internal-id
  allocation and graph edges);
- ``KIND_DELETE``: ``n u32 | ext_ids int64[n]`` — the batch **as
  submitted**, before host-side dedup: replay reruns the dedup against
  the restored deleted-set, so duplicated records are absorbed as
  counted no-ops (the existing delete-noop contract).

Segments: records append to ``wal_<first_lsn:016d>.log`` files under the
WAL directory; a segment exceeding ``segment_bytes`` is closed (fsync'd)
and a new one opened.  On open, segments are scanned in LSN order with
CRC verification; a torn tail (partial or corrupt record — the crash
landed mid-write) truncates the file at the last valid record, and any
segments after a truncation point are dropped.  ``truncate_through``
unlinks segments wholly covered by a checkpoint's LSN.

Group commit: ``append_*`` only buffers (OS page cache); ``sync()``
fsyncs everything appended so far.  The engine batches syncs across
micro-batches (``group_commit_n`` records / ``group_commit_ms`` oldest
pending age) and defers ticket resolution until the covering sync — see
``ServeEngine._commit_wal``.
"""

from __future__ import annotations

import os
import struct
import zlib
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

KIND_INSERT = 1
KIND_DELETE = 2

_HDR = struct.Struct("<IIQB")        # crc, len, lsn, kind
_CRC_OFF = 4                         # crc covers bytes [4:] of the record

NO_LSN = 0                           # "no records" / "nothing covered"


@dataclass(frozen=True)
class WalConfig:
    """Knobs for the serve-path write-ahead log.

    ``group_commit_n``/``group_commit_ms`` shape the engine's commit
    policy: fsync once ``n`` batch records are pending, or once the
    oldest pending record has waited ``ms`` milliseconds — whichever
    comes first.  The defaults (1 / 0.0) commit every micro-batch.
    ``sync=False`` skips fsync entirely (flush-only): the benchmark's
    "how much of the overhead is the fsync" probe, never a durability
    mode.
    """

    dir: str
    segment_bytes: int = 4 << 20
    group_commit_n: int = 1
    group_commit_ms: float = 0.0
    sync: bool = True


@dataclass(frozen=True)
class WalRecord:
    lsn: int
    kind: int
    ext_ids: np.ndarray                 # int64[n]
    vectors: Optional[np.ndarray] = None  # f32[n, dim] (inserts only)


def _encode_insert(ext_ids: np.ndarray, vectors: np.ndarray) -> bytes:
    n, dim = vectors.shape
    return (struct.pack("<II", n, dim)
            + np.ascontiguousarray(ext_ids, np.int64).tobytes()
            + np.ascontiguousarray(vectors, np.float32).tobytes())


def _encode_delete(ext_ids: np.ndarray) -> bytes:
    return (struct.pack("<II", len(ext_ids), 0)
            + np.ascontiguousarray(ext_ids, np.int64).tobytes())


def _decode(lsn: int, kind: int, payload: bytes) -> WalRecord:
    n, dim = struct.unpack_from("<II", payload)
    off = 8
    ext = np.frombuffer(payload, np.int64, count=n, offset=off).copy()
    off += 8 * n
    if kind == KIND_INSERT:
        vec = np.frombuffer(payload, np.float32, count=n * dim,
                            offset=off).reshape(n, dim).copy()
        return WalRecord(lsn, kind, ext, vec)
    return WalRecord(lsn, kind, ext)


class WriteAheadLog:
    """Segmented, CRC-checked, group-committed WAL (see module doc).

    Opening scans every segment, truncates any torn tail, and leaves the
    log positioned to append at ``last_lsn + 1``.  Records recovered by
    the scan are available through :meth:`records` until the log is
    closed (recovery replays them; appends go to the active segment).
    """

    def __init__(self, cfg: WalConfig):
        self.cfg = cfg
        os.makedirs(cfg.dir, exist_ok=True)
        self._recovered: List[WalRecord] = []
        #: per segment: [path, first_lsn, last_lsn]
        self._segments: List[list] = []
        self._file = None
        self.last_lsn = NO_LSN       # last appended (not necessarily synced)
        self.synced_lsn = NO_LSN
        self.n_unsynced = 0
        self.n_syncs = 0
        self.n_records = 0
        self.bytes_appended = 0
        self._open_scan()

    # -- open/recovery --------------------------------------------------------

    def _seg_path(self, first_lsn: int) -> str:
        return os.path.join(self.cfg.dir, f"wal_{first_lsn:016d}.log")

    def _scan_segment(self, path: str,
                      expect_lsn: int) -> Tuple[List[WalRecord], bool]:
        """Parse one segment; returns (records, clean).

        Records must extend the LSN chain exactly (first record carries
        `expect_lsn`, each next +1).  A torn/corrupt/discontinuous tail
        is truncated in place and reported as clean=False.
        """
        out: List[WalRecord] = []
        with open(path, "rb") as f:
            data = f.read()
        off = 0
        while off + _HDR.size <= len(data):
            crc, length, lsn, kind = _HDR.unpack_from(data, off)
            end = off + 8 + length           # crc(4)+len(4) then `length`
            if length < 9 or end > len(data):
                break                        # torn tail (partial write)
            if zlib.crc32(data[off + _CRC_OFF:end]) != crc:
                break                        # corrupt record
            if lsn != expect_lsn:
                break                        # chain discontinuity
            out.append(_decode(lsn, kind, data[off + _HDR.size:end]))
            expect_lsn += 1
            off = end
        clean = off == len(data)
        if not clean:
            with open(path, "r+b") as f:
                f.truncate(off)
        return out, clean

    def _open_scan(self) -> None:
        names = sorted(n for n in os.listdir(self.cfg.dir)
                       if n.startswith("wal_") and n.endswith(".log"))
        if names:
            # the log need not start at LSN 1: checkpoint truncation
            # unlinks covered segments, so the earliest surviving
            # segment's filename carries the first expected LSN
            self.last_lsn = int(names[0][4:-4]) - 1
        truncated = False
        for name in names:
            path = os.path.join(self.cfg.dir, name)
            if truncated:
                # a torn segment ends the log: later segments are an
                # unreachable suffix and must not resurrect mid-stream
                os.unlink(path)
                continue
            recs, clean = self._scan_segment(path, self.last_lsn + 1)
            if not recs and clean:
                if name == names[-1]:
                    # empty clean TAIL segment: keep it as the active
                    # segment.  Its filename is the only durable copy of
                    # the LSN high-water mark once a checkpoint has
                    # truncated every earlier segment — unlinking it
                    # would reset LSN allocation to 1 on the restart
                    # after next, making new records invisible to a
                    # recovery that replays past the covering LSN
                    self._segments.append(
                        [path, self.last_lsn + 1, self.last_lsn])
                else:
                    # empty non-tail segment (can only arise from an
                    # interrupted create): nothing durable to preserve
                    os.unlink(path)
                continue
            self._recovered.extend(recs)
            first = recs[0].lsn if recs else self.last_lsn + 1
            if recs:
                self.last_lsn = recs[-1].lsn
            self._segments.append([path, first, self.last_lsn])
            if not clean:
                truncated = True
        self.synced_lsn = self.last_lsn
        # position the active segment for appends
        if self._segments:
            self._file = open(self._segments[-1][0], "ab")
        self._fsync_dir()

    def _fsync_dir(self) -> None:
        fd = os.open(self.cfg.dir, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    # -- append path ----------------------------------------------------------

    def _rotate(self) -> None:
        if self._file is not None:
            self._file.flush()
            if self.cfg.sync:
                os.fsync(self._file.fileno())
            self._file.close()
        first = self.last_lsn + 1
        path = self._seg_path(first)
        self._file = open(path, "ab")
        self._segments.append([path, first, self.last_lsn])
        self._fsync_dir()

    def _append(self, kind: int, payload: bytes) -> int:
        if self._file is None or self._file.tell() >= self.cfg.segment_bytes:
            self._rotate()
        lsn = self.last_lsn + 1
        body = struct.pack("<IQB", len(payload) + 9, lsn, kind) + payload
        rec = struct.pack("<I", zlib.crc32(body)) + body
        self._file.write(rec)
        self.last_lsn = lsn
        self._segments[-1][2] = lsn
        self.n_unsynced += 1
        self.n_records += 1
        self.bytes_appended += len(rec)
        return lsn

    def append_insert(self, ext_ids: np.ndarray, vectors: np.ndarray) -> int:
        """Log one insert micro-batch; returns its LSN (not yet durable)."""
        return self._append(KIND_INSERT, _encode_insert(
            np.asarray(ext_ids, np.int64),
            np.atleast_2d(np.asarray(vectors, np.float32))))

    def append_delete(self, ext_ids: np.ndarray) -> int:
        """Log one delete micro-batch (as submitted, pre-dedup)."""
        return self._append(KIND_DELETE, _encode_delete(
            np.atleast_1d(np.asarray(ext_ids, np.int64))))

    def sync(self) -> int:
        """Make everything appended so far durable; returns the covered
        LSN.  The group-commit point: tickets staged behind this sync
        may resolve once it returns."""
        if self._file is not None and self.n_unsynced:
            self._file.flush()
            if self.cfg.sync:
                os.fsync(self._file.fileno())
            self.n_syncs += 1
        self.synced_lsn = self.last_lsn
        self.n_unsynced = 0
        return self.synced_lsn

    # -- recovery / retention -------------------------------------------------

    def records(self, after: int = NO_LSN) -> List[WalRecord]:
        """Recovered records with LSN > `after`, in LSN order.  Only
        records present at open time are returned (recovery reads the
        log before new appends)."""
        return [r for r in self._recovered if r.lsn > after]

    def truncate_through(self, lsn: int) -> int:
        """Drop whole segments whose records are all <= `lsn` (covered
        by a checkpoint).  The active segment is rotated out first if it
        is fully covered, so the file holding the next append is never
        unlinked.  Returns the number of segments removed."""
        if not self._segments or lsn < self._segments[0][2]:
            return 0
        last = self._segments[-1]
        if last[2] <= lsn and last[1] <= last[2] and self.n_unsynced == 0:
            # rotate only a non-empty active segment: an empty one
            # (first > last) is already the post-truncation state, and
            # re-rotating would re-open the same filename as a
            # duplicate segment entry
            self._rotate()
        removed = 0
        keep = []
        for seg in self._segments[:-1]:
            if seg[2] <= lsn and seg[1] <= seg[2]:
                os.unlink(seg[0])
                removed += 1
            else:
                keep.append(seg)
        self._segments = keep + self._segments[-1:]
        self._recovered = [r for r in self._recovered if r.lsn > lsn]
        if removed:
            self._fsync_dir()
        return removed

    def close(self) -> None:
        if self._file is not None:
            self.sync()
            self._file.close()
            self._file = None

    def abandon(self) -> None:
        """Simulate process death: release the active segment's fd
        WITHOUT flushing the userspace buffer.  A killed process never
        flushes; if the abandoned BufferedWriter were left to flush on
        close/GC it could interleave a stale (possibly duplicate-LSN,
        possibly partial) record into the very segment a recovered
        engine is now appending to, corrupting the chain so a later
        scan truncates at the stale record.  Closing the raw FileIO
        marks the buffered wrapper closed, so its pending bytes are
        dropped and never reach a (potentially recycled) fd."""
        f, self._file = self._file, None
        if f is None:
            return
        try:
            f.raw.close()
        except (OSError, ValueError):
            pass
