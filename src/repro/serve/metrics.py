"""Serving metrics: per-op latency quantiles, batch occupancy, QPS.

Latency is measured enqueue→completion (queueing + padding + device
time), which is what a client of the engine actually observes.  Samples
are kept in bounded reservoirs so a long-running engine never grows
unboundedly; p50/p99 come from the retained sample.
"""

from __future__ import annotations

import collections
from typing import Deque, Dict

import numpy as np

from repro.serve.request import Op


class ServeMetrics:
    def __init__(self, *, reservoir: int = 16384):
        self._lat: Dict[Op, Deque[float]] = {
            op: collections.deque(maxlen=reservoir) for op in Op}
        self._count: Dict[Op, int] = {op: 0 for op in Op}
        self._batches: Dict[Op, int] = {op: 0 for op in Op}
        self._occupancy: Dict[Op, int] = {op: 0 for op in Op}
        self._t_start: float | None = None
        self._t_last: float | None = None
        #: the coalescing window each op is currently running under —
        #: with adaptive batch shaping this tracks the arrival-rate EMA
        #: (DESIGN.md §10); static configs just echo their constants
        self.windows: Dict[Op, float] = {op: 0.0 for op in Op}
        self.snapshot_resolves = 0
        self.maintenance_runs: Dict[str, int] = {
            "compact": 0, "reorder": 0, "consolidate": 0, "checkpoint": 0,
            "tier": 0}
        #: WAL accounting (zero when the engine runs without a WAL):
        #: records appended vs group commits actually fsync'd — the
        #: ratio is the group-commit amortization the config bought
        self.wal_records = 0
        self.wal_commits = 0
        #: deletes the engine dropped host-side as duplicates of an
        #: already-deleted external id or as never-allocated ids
        #: (relaxed coalescing can double-submit); the device-side
        #: count of absent-id no-ops lives on the backend stats surface
        #: (`VectorBackend.stats().delete_noops`)
        self.delete_noops = 0
        #: pumps that withheld pending write batches because an
        #: overlapped repair was in flight (relaxed mode; DESIGN.md §13)
        self.write_holds = 0

    def record_batch(self, op: Op, n: int, latencies, now: float) -> None:
        self._count[op] += n
        self._batches[op] += 1
        self._occupancy[op] += n
        self._lat[op].extend(latencies)
        if self._t_start is None:
            self._t_start = now
        self._t_last = now

    def _quantiles(self, op: Op):
        lat = np.asarray(self._lat[op], np.float64)
        if lat.size == 0:
            return {"p50_ms": 0.0, "p99_ms": 0.0}
        return {"p50_ms": float(np.percentile(lat, 50) * 1e3),
                "p99_ms": float(np.percentile(lat, 99) * 1e3)}

    def snapshot(self) -> dict:
        wall = 0.0
        if self._t_start is not None and self._t_last is not None:
            wall = max(self._t_last - self._t_start, 1e-9)
        out: dict = {"wall_s": round(wall, 4),
                     "snapshot_resolves": self.snapshot_resolves,
                     "delete_noops": self.delete_noops,
                     "write_holds": self.write_holds,
                     "maintenance": dict(self.maintenance_runs),
                     "wal": {"records": self.wal_records,
                             "commits": self.wal_commits}}
        for op in Op:
            nb = self._batches[op]
            out[op.value] = {
                "count": self._count[op],
                "batches": nb,
                "mean_batch": round(self._occupancy[op] / nb, 2) if nb else 0.0,
                "ops_per_s": round(self._count[op] / wall, 1) if wall else 0.0,
                "window_ms": round(self.windows[op] * 1e3, 4),
                **{k: round(v, 3) for k, v in self._quantiles(op).items()},
            }
        return out
