"""The continuous micro-batching engine over a `VectorBackend`
(DESIGN.md §8, §10).

`ServeEngine` accepts an interleaved stream of query/insert/delete
requests and executes it as fixed-shape micro-batches:

  queue → coalesce (per-op caps + adaptive windows) → pad-and-mask
        dispatch → snapshot-cached reads → threshold-driven maintenance

The engine programs against the `VectorBackend` protocol only — the
single-device index and the hash-partitioned `ShardedBackend` serve
through the identical code path.  Every op dispatches through one traced
shape (`pad_to` on the backend's batch entry points), so steady-state
serving performs **zero jit retraces** regardless of how ragged the
arrival pattern is.  Query batches read bottom-layer adjacency from the
backend's cached dense snapshot, re-resolved lazily after each write
batch (lazy deletes are tombstone-bit-only and leave the snapshot
valid).  Maintenance (tombstone consolidation, LSM compaction,
heat-driven reordering) runs from thresholds between batches — sharded
backends apply them per shard.

**External ids** are owned here, uniformly for every backend: the engine
allocates them sequentially in insert order (build rows first), keeps an
external↔internal map over the backend's global id space, and folds
every reorder permutation into it.  Consolidation retires internal ids
without reuse, so the same map needs no rewrite (DESIGN.md §9).

**Adaptive coalescing windows** (Quake-style, DESIGN.md §10): instead of
static per-op windows, the engine keeps an EMA of each op's inter-
arrival gap and sizes the window to a fraction of the expected
batch-fill time — heavy arrival mixes shrink the wait toward zero
(batches fill anyway), sparse mixes stop burning latency waiting for
stragglers that aren't coming.  The chosen windows are visible in
`ServeMetrics`.

The engine is single-threaded at heart — `pump()` executes at most one
micro-batch and is the unit the tests drive deterministically (with an
injectable clock).  `start()`/`stop()` wrap it in a background thread
for live serving; `drain()` pumps until the queue is empty.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.serve.maintenance import MaintenanceManager, MaintenancePolicy
from repro.serve.metrics import ServeMetrics
from repro.serve.queue import CoalescingQueue
from repro.serve.request import Op, QueryResult, Request, Ticket


@dataclass
class ServeConfig:
    """Engine knobs. Batch caps are also the fixed pad widths."""

    query_batch: int = 32
    insert_batch: int = 32
    delete_batch: int = 32
    #: per-op coalescing windows (seconds).  With `adaptive_windows`
    #: these are only the starting values used until the arrival-rate
    #: EMA has a sample; without it they are the static windows.
    query_window: float = 0.002
    insert_window: float = 0.005
    delete_window: float = 0.005
    #: Quake-style arrival-shaped windows: EMA the per-op inter-arrival
    #: gap and wait `window_fill` of the expected time to fill the
    #: batch cap, clamped to [window_min, window_max]
    adaptive_windows: bool = True
    window_min: float = 0.0
    window_max: float = 0.02
    window_fill: float = 0.5
    window_alpha: float = 0.2         # EMA smoothing of arrival gaps
    #: strict = serializable in arrival order (parity mode); relaxed =
    #: same-op coalescing across op boundaries (throughput mode)
    strict_order: bool = False
    k: Optional[int] = None           # search params; None = backend config
    ef: Optional[int] = None
    rho: Optional[float] = None
    n_expand: Optional[int] = None
    #: None = record edge heat only when the maintenance policy consumes
    #: it (heat_budget set); the per-batch heat scatter is pure cost
    #: otherwise
    record_heat: Optional[bool] = None
    maintenance: MaintenancePolicy = field(default_factory=MaintenancePolicy)


class ServeEngine:
    def __init__(self, backend, cfg: Optional[ServeConfig] = None,
                 clock=time.monotonic):
        self.backend = backend
        self.cfg = cfg or ServeConfig()
        self.clock = clock
        self.metrics = ServeMetrics()
        self.maintenance = MaintenanceManager(backend, self.cfg.maintenance)
        self.queue = CoalescingQueue(
            batch_caps={Op.QUERY: self.cfg.query_batch,
                        Op.INSERT: self.cfg.insert_batch,
                        Op.DELETE: self.cfg.delete_batch},
            windows={Op.QUERY: self.cfg.query_window,
                     Op.INSERT: self.cfg.insert_window,
                     Op.DELETE: self.cfg.delete_window},
            strict_order=self.cfg.strict_order)
        self._seq = 0
        self._lock = threading.RLock()       # queue + id-map access
        self._pump_lock = threading.RLock()  # serializes batch execution
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        # stable external ids across reorder permutations and shards:
        # the engine allocates external ids sequentially in insert order
        # (build rows seed the map via backend.initial_ids()), every
        # relayout perm is folded into this pair of maps, and -1 marks
        # the unallocated region of either space
        cap = backend.cap
        self._int2ext = np.full(cap, -1, dtype=np.int64)
        self._ext2int = np.full(cap, -1, dtype=np.int64)
        born = np.asarray(backend.initial_ids(), np.int64)
        self._int2ext[born] = np.arange(len(born))
        self._ext2int[:len(born)] = born
        self._next_ext = len(born)
        # external ids already deleted through this engine: a repeat
        # delete (relaxed coalescing can double-submit one client retry)
        # is dropped host-side as a counted no-op instead of reaching the
        # device.  Internal ids are never reused (consolidation retires
        # them, DESIGN.md §9), so entries are never removed.
        self._deleted_ext: set = set()
        # adaptive-window state: per-op EMA of inter-arrival gaps
        self._gap_ema: Dict[Op, Optional[float]] = {op: None for op in Op}
        self._last_arrival: Dict[Op, Optional[float]] = {
            op: None for op in Op}
        self._caps = {Op.QUERY: self.cfg.query_batch,
                      Op.INSERT: self.cfg.insert_batch,
                      Op.DELETE: self.cfg.delete_batch}
        for op, w in self.queue.windows().items():
            self.metrics.windows[op] = w
        self.batch_log: List[tuple] = []   # (op, size) per executed batch

    # -- submission -----------------------------------------------------------

    def _submit(self, op: Op, payload) -> Ticket:
        with self._lock:
            now = self.clock()
            req = Request(op=op, payload=payload, seq=self._seq,
                          t_enqueue=now)
            self._seq += 1
            self.queue.push(req)
            if self.cfg.adaptive_windows:
                last = self._last_arrival[op]
                if last is not None:
                    gap = now - last
                    ema = self._gap_ema[op]
                    a = self.cfg.window_alpha
                    self._gap_ema[op] = gap if ema is None \
                        else a * gap + (1 - a) * ema
                self._last_arrival[op] = now
            return req.ticket

    def submit_query(self, q) -> Ticket:
        """Query one vector; ticket resolves to QueryResult."""
        return self._submit(Op.QUERY, np.asarray(q, np.float32))

    def submit_insert(self, x) -> Ticket:
        """Insert one vector; ticket resolves to its stable external id."""
        return self._submit(Op.INSERT, np.asarray(x, np.float32))

    def submit_delete(self, ext_id: int) -> Ticket:
        """Delete by external id; ticket resolves to True, or False when
        the delete is a counted no-op (`metrics.delete_noops`) — the id
        was already deleted through this engine, or was never allocated.

        Rejects ids outside [0, cap) up front: -1 (the search-result pad
        value) would otherwise wrap through the numpy id map and delete
        an unrelated node.
        """
        ext_id = int(ext_id)
        if not 0 <= ext_id < self.backend.cap:
            raise ValueError(f"external id {ext_id} outside [0, "
                             f"{self.backend.cap})")
        return self._submit(Op.DELETE, ext_id)

    # -- adaptive batch shaping (Quake-style) ---------------------------------

    def _shape_windows(self) -> None:
        """Re-derive each op's coalescing window from the arrival EMA:
        wait `window_fill` of the expected time for the batch cap to
        fill, clamped to [window_min, window_max].  Ops with no gap
        sample yet keep their configured starting window."""
        for op in Op:
            ema = self._gap_ema[op]
            if ema is None:
                continue
            w = self.cfg.window_fill * self._caps[op] * ema
            w = min(max(w, self.cfg.window_min), self.cfg.window_max)
            self.queue.set_window(op, w)
            self.metrics.windows[op] = w

    # -- execution ------------------------------------------------------------

    def _exec_query(self, reqs: List[Request]) -> None:
        qs = np.stack([r.payload for r in reqs])
        if self.backend.snapshot_stale:
            self.metrics.snapshot_resolves += 1
        record_heat = self.cfg.record_heat
        if record_heat is None:
            record_heat = self.cfg.maintenance.heat_budget is not None
        res = self.backend.search(
            qs, k=self.cfg.k, ef=self.cfg.ef, rho=self.cfg.rho,
            n_expand=self.cfg.n_expand, record_heat=record_heat,
            use_snapshot=True, pad_to=self.cfg.query_batch)
        ext = np.where(res.ids >= 0,
                       self._int2ext[np.maximum(res.ids, 0)], -1)
        for row_ids, row_d, req in zip(ext, res.dists, reqs):
            req.ticket._complete(QueryResult(ids=row_ids, dists=row_d))

    def _exec_insert(self, reqs: List[Request]) -> None:
        xs = np.stack([r.payload for r in reqs])
        res = self.backend.insert_batch(xs, pad_to=self.cfg.insert_batch)
        for gid, req in zip(np.asarray(res.ids, np.int64), reqs):
            ext = self._next_ext
            self._next_ext += 1
            self._ext2int[ext] = gid
            self._int2ext[gid] = ext
            req.ticket._complete(int(ext))

    def _exec_delete(self, reqs: List[Request]) -> None:
        ext = np.asarray([r.payload for r in reqs], np.int64)
        # drop repeats and never-allocated ids host-side: the ticket
        # still resolves (False), but nothing reaches the device for
        # them — a double delete must be a counted no-op, not a write,
        # and an unallocated ext id must not be poisoned against the
        # day an insert hands it out.
        internal = self._ext2int[ext]
        fresh = np.ones(len(ext), bool)
        batch_seen: set = set()
        for j, e in enumerate(ext):
            e = int(e)
            if e in self._deleted_ext or e in batch_seen \
                    or internal[j] < 0:
                fresh[j] = False
            else:
                batch_seen.add(e)
        n_noop = int((~fresh).sum())
        if n_noop:
            self.metrics.delete_noops += n_noop
        gids = np.where(fresh, internal, -1)
        if fresh.any():
            self.backend.delete_batch(gids, pad_to=self.cfg.delete_batch)
        # record only after the device call succeeded: a raised dispatch
        # must not poison the ids as 'already deleted' (the client will
        # retry the failed tickets)
        self._deleted_ext.update(batch_seen)
        self.maintenance.note_deletes(int(fresh.sum()))
        for req, f in zip(reqs, fresh):
            req.ticket._complete(bool(f))

    def _apply_perm(self, perm: np.ndarray) -> None:
        """Fold a reorder permutation (perm[old_int] = new_int, identity
        outside the permuted region) into the external id maps; internal
        ids allocated after the perm are untouched, unallocated entries
        stay -1."""
        perm = np.asarray(perm, np.int64)
        n = len(perm)
        old_ext = self._int2ext[:n].copy()
        self._int2ext[perm] = old_ext
        alloc = old_ext >= 0
        self._ext2int[old_ext[alloc]] = perm[alloc]

    @property
    def delete_noops(self) -> int:
        """Total no-op deletes: engine-level repeats/unallocated dropped
        host-side, plus the backend stats surface's device-side count of
        deletes that hit absent/dead internal ids."""
        return self.metrics.delete_noops + self.backend.stats().delete_noops

    def pump(self, *, force: bool = False) -> Optional[Op]:
        """Execute at most one micro-batch; returns its op, or None.

        `force` releases under-full runs immediately (drain semantics).
        Pumps are serialized against each other by `_pump_lock`, but the
        queue lock is held only to pop the batch — submit_* never waits
        behind a device dispatch.
        """
        with self._pump_lock:
            with self._lock:
                if self.cfg.adaptive_windows:
                    self._shape_windows()
                got = self.queue.next_batch(self.clock(), force=force)
            if got is None:
                return None
            op, reqs = got
            try:
                if op is Op.QUERY:
                    self._exec_query(reqs)
                else:
                    if op is Op.INSERT:
                        self._exec_insert(reqs)
                    else:
                        self._exec_delete(reqs)
                    self.maintenance.note_write_batch()
                    actions = self.maintenance.run_if_due()
                    if "reorder" in actions:
                        self._apply_perm(self.maintenance.last_perm)
                    for a in actions:
                        self.metrics.maintenance_runs[a] += 1
            except BaseException as e:
                for r in reqs:
                    if not r.ticket.done:
                        r.ticket._fail(e)
                raise
            now = self.clock()
            self.metrics.record_batch(
                op, len(reqs), [now - r.t_enqueue for r in reqs], now)
            self.batch_log.append((op, len(reqs)))
            return op

    def drain(self) -> int:
        """Pump until the queue is empty; returns batches executed."""
        n = 0
        while True:
            with self._lock:
                if len(self.queue) == 0:
                    return n
            if self.pump(force=True) is not None:
                n += 1

    # -- background serving ---------------------------------------------------

    def start(self) -> None:
        """Run the pump loop in a daemon thread (live serving mode)."""
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                if self.pump() is None:
                    # nothing released: sleep one coalescing quantum
                    time.sleep(min(self.cfg.query_window,
                                   self.cfg.insert_window, 0.001))

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="lsmvec-serve")
        self._thread.start()

    def stop(self, *, drain: bool = True) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None
        if drain:
            self.drain()
