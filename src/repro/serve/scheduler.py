"""The continuous micro-batching engine over LSMVecIndex (DESIGN.md §8).

`ServeEngine` accepts an interleaved stream of query/insert/delete
requests and executes it as fixed-shape micro-batches:

  queue → coalesce (per-op caps + windows) → pad-and-mask dispatch
        → snapshot-cached reads → threshold-driven maintenance

Every op dispatches through one traced shape (`pad_to` on the index's
batch entry points), so steady-state serving performs **zero jit
retraces** regardless of how ragged the arrival pattern is.  Query
batches read bottom-layer adjacency from the cached dense LSM snapshot,
re-resolved lazily after each write batch (lazy deletes are
tombstone-bit-only and leave the snapshot valid).  Maintenance
(tombstone consolidation, LSM compaction, heat-driven reordering) runs
from thresholds between batches; reordering permutes internal ids,
which the engine hides behind a stable external id map — consolidation
retires ids without reuse, so the same map needs no rewrite
(DESIGN.md §9).

The engine is single-threaded at heart — `pump()` executes at most one
micro-batch and is the unit the tests drive deterministically (with an
injectable clock).  `start()`/`stop()` wrap it in a background thread
for live serving; `drain()` pumps until the queue is empty.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.serve.maintenance import MaintenanceManager, MaintenancePolicy
from repro.serve.metrics import ServeMetrics
from repro.serve.queue import CoalescingQueue
from repro.serve.request import Op, QueryResult, Request, Ticket


@dataclass
class ServeConfig:
    """Engine knobs. Batch caps are also the fixed pad widths."""

    query_batch: int = 32
    insert_batch: int = 32
    delete_batch: int = 32
    query_window: float = 0.002       # seconds an under-full run may wait
    insert_window: float = 0.005
    delete_window: float = 0.005
    #: strict = serializable in arrival order (parity mode); relaxed =
    #: same-op coalescing across op boundaries (throughput mode)
    strict_order: bool = False
    k: Optional[int] = None           # search params; None = index config
    ef: Optional[int] = None
    rho: Optional[float] = None
    n_expand: Optional[int] = None
    #: None = record edge heat only when the maintenance policy consumes
    #: it (heat_budget set); the per-batch heat scatter is pure cost
    #: otherwise
    record_heat: Optional[bool] = None
    maintenance: MaintenancePolicy = field(default_factory=MaintenancePolicy)


class ServeEngine:
    def __init__(self, index, cfg: Optional[ServeConfig] = None,
                 clock=time.monotonic):
        self.index = index
        self.cfg = cfg or ServeConfig()
        self.clock = clock
        self.metrics = ServeMetrics()
        self.maintenance = MaintenanceManager(index, self.cfg.maintenance)
        self.queue = CoalescingQueue(
            batch_caps={Op.QUERY: self.cfg.query_batch,
                        Op.INSERT: self.cfg.insert_batch,
                        Op.DELETE: self.cfg.delete_batch},
            windows={Op.QUERY: self.cfg.query_window,
                     Op.INSERT: self.cfg.insert_window,
                     Op.DELETE: self.cfg.delete_window},
            strict_order=self.cfg.strict_order)
        self._seq = 0
        self._lock = threading.RLock()       # queue + id-map access
        self._pump_lock = threading.RLock()  # serializes batch execution
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        # stable external ids across reorder permutations: a fresh insert's
        # external id equals its internal id at birth; every relayout perm
        # is folded into this pair of maps
        cap = index.cfg.cap
        self._int2ext = np.arange(cap, dtype=np.int64)
        self._ext2int = np.arange(cap, dtype=np.int64)
        # external ids already deleted through this engine: a repeat
        # delete (relaxed coalescing can double-submit one client retry)
        # is dropped host-side as a counted no-op instead of reaching the
        # device.  Internal ids are never reused (consolidation retires
        # them, DESIGN.md §9), so entries are never removed.
        self._deleted_ext: set = set()
        self.batch_log: List[tuple] = []   # (op, size) per executed batch

    # -- submission -----------------------------------------------------------

    def _submit(self, op: Op, payload) -> Ticket:
        with self._lock:
            req = Request(op=op, payload=payload, seq=self._seq,
                          t_enqueue=self.clock())
            self._seq += 1
            self.queue.push(req)
            return req.ticket

    def submit_query(self, q) -> Ticket:
        """Query one vector; ticket resolves to QueryResult."""
        return self._submit(Op.QUERY, np.asarray(q, np.float32))

    def submit_insert(self, x) -> Ticket:
        """Insert one vector; ticket resolves to its stable external id."""
        return self._submit(Op.INSERT, np.asarray(x, np.float32))

    def submit_delete(self, ext_id: int) -> Ticket:
        """Delete by external id; ticket resolves to True, or False when
        the id was already deleted through this engine (the delete is
        then a counted no-op — `metrics.delete_noops` — not a write).

        Rejects ids outside [0, cap) up front: -1 (the search-result pad
        value) would otherwise wrap through the numpy id map and delete
        an unrelated node.
        """
        ext_id = int(ext_id)
        if not 0 <= ext_id < self.index.cfg.cap:
            raise ValueError(f"external id {ext_id} outside [0, "
                             f"{self.index.cfg.cap})")
        return self._submit(Op.DELETE, ext_id)

    # -- execution ------------------------------------------------------------

    def _exec_query(self, reqs: List[Request]) -> None:
        qs = np.stack([r.payload for r in reqs])
        idx = self.index
        if idx._snap_version != idx._version:
            self.metrics.snapshot_resolves += 1
        record_heat = self.cfg.record_heat
        if record_heat is None:
            record_heat = self.cfg.maintenance.heat_budget is not None
        ids, dists = idx.search(
            qs, k=self.cfg.k, ef=self.cfg.ef, rho=self.cfg.rho,
            n_expand=self.cfg.n_expand, record_heat=record_heat,
            use_snapshot=True, pad_to=self.cfg.query_batch)
        ext = np.where(ids >= 0, self._int2ext[np.maximum(ids, 0)], -1)
        for row_ids, row_d, req in zip(ext, dists, reqs):
            req.ticket._complete(QueryResult(ids=row_ids, dists=row_d))

    def _exec_insert(self, reqs: List[Request]) -> None:
        xs = np.stack([r.payload for r in reqs])
        new_ids = self.index.insert_batch(xs, pad_to=self.cfg.insert_batch)
        for i, req in zip(new_ids, reqs):
            req.ticket._complete(int(self._int2ext[i]))

    def _exec_delete(self, reqs: List[Request]) -> None:
        ext = np.asarray([r.payload for r in reqs], np.int64)
        # drop repeats (within the batch and against history) host-side:
        # the ticket still resolves, but nothing reaches the device for
        # them — a double delete must be a counted no-op, not a write.
        # Only *allocated* ids are recorded: a delete of a not-yet-
        # allocated ext id must not poison the id against the day an
        # insert hands it out (the device counts it as a no-op instead).
        allocated = self._ext2int[ext] < self.index._count
        fresh = np.ones(len(ext), bool)
        batch_seen: set = set()
        for j, e in enumerate(ext):
            if int(e) in self._deleted_ext or int(e) in batch_seen:
                fresh[j] = False
            elif allocated[j]:
                batch_seen.add(int(e))
        n_noop = int((~fresh).sum())
        if n_noop:
            self.metrics.delete_noops += n_noop
        internal = np.where(fresh, self._ext2int[ext], -1).astype(np.int32)
        if fresh.any():
            self.index.delete_batch(internal, pad_to=self.cfg.delete_batch)
        # record only after the device call succeeded: a raised dispatch
        # must not poison the ids as 'already deleted' (the client will
        # retry the failed tickets)
        self._deleted_ext.update(batch_seen)
        self.maintenance.note_deletes(int(fresh.sum()))
        for req, f in zip(reqs, fresh):
            req.ticket._complete(bool(f))

    def _apply_perm(self, perm: np.ndarray) -> None:
        """Fold a reorder permutation (perm[old_int] = new_int) into the
        external id maps; ids allocated after the perm are untouched."""
        n = len(perm)
        old_ext = self._int2ext[:n].copy()
        self._int2ext[perm] = old_ext
        self._ext2int[old_ext] = perm

    @property
    def delete_noops(self) -> int:
        """Total no-op deletes: engine-level repeats dropped host-side
        plus device-counted deletes of absent/dead internal ids."""
        return self.metrics.delete_noops + self.index.delete_noops

    def pump(self, *, force: bool = False) -> Optional[Op]:
        """Execute at most one micro-batch; returns its op, or None.

        `force` releases under-full runs immediately (drain semantics).
        Pumps are serialized against each other by `_pump_lock`, but the
        queue lock is held only to pop the batch — submit_* never waits
        behind a device dispatch.
        """
        with self._pump_lock:
            with self._lock:
                got = self.queue.next_batch(self.clock(), force=force)
            if got is None:
                return None
            op, reqs = got
            try:
                if op is Op.QUERY:
                    self._exec_query(reqs)
                else:
                    if op is Op.INSERT:
                        self._exec_insert(reqs)
                    else:
                        self._exec_delete(reqs)
                    self.maintenance.note_write_batch()
                    actions = self.maintenance.run_if_due()
                    if "reorder" in actions:
                        self._apply_perm(self.maintenance.last_perm)
                    for a in actions:
                        self.metrics.maintenance_runs[a] += 1
            except BaseException as e:
                for r in reqs:
                    if not r.ticket.done:
                        r.ticket._fail(e)
                raise
            now = self.clock()
            self.metrics.record_batch(
                op, len(reqs), [now - r.t_enqueue for r in reqs], now)
            self.batch_log.append((op, len(reqs)))
            return op

    def drain(self) -> int:
        """Pump until the queue is empty; returns batches executed."""
        n = 0
        while True:
            with self._lock:
                if len(self.queue) == 0:
                    return n
            if self.pump(force=True) is not None:
                n += 1

    # -- background serving ---------------------------------------------------

    def start(self) -> None:
        """Run the pump loop in a daemon thread (live serving mode)."""
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                if self.pump() is None:
                    # nothing released: sleep one coalescing quantum
                    time.sleep(min(self.cfg.query_window,
                                   self.cfg.insert_window, 0.001))

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="lsmvec-serve")
        self._thread.start()

    def stop(self, *, drain: bool = True) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None
        if drain:
            self.drain()
