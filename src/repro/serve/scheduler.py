"""The continuous micro-batching engine over a `VectorBackend`
(DESIGN.md §8, §10).

`ServeEngine` accepts an interleaved stream of query/insert/delete
requests and executes it as fixed-shape micro-batches:

  queue → coalesce (per-op caps + adaptive windows) → pad-and-mask
        dispatch → snapshot-cached reads → threshold-driven maintenance

The engine programs against the `VectorBackend` protocol only — the
single-device index and the hash-partitioned `ShardedBackend` serve
through the identical code path.  Every op dispatches through one traced
shape (`pad_to` on the backend's batch entry points), so steady-state
serving performs **zero jit retraces** regardless of how ragged the
arrival pattern is.  Query batches read bottom-layer adjacency from the
backend's cached dense snapshot, re-resolved lazily after each write
batch (lazy deletes are tombstone-bit-only and leave the snapshot
valid).  Maintenance (tombstone consolidation, LSM compaction,
heat-driven reordering) runs from thresholds between batches — sharded
backends apply them per shard.

**External ids** are owned here, uniformly for every backend: the engine
allocates them sequentially in insert order (build rows first), keeps an
external↔internal map over the backend's global id space, and folds
every reorder permutation into it.  Consolidation retires internal ids
without reuse, so the same map needs no rewrite (DESIGN.md §9).

**Adaptive coalescing windows** (Quake-style, DESIGN.md §10): instead of
static per-op windows, the engine keeps an EMA of each op's inter-
arrival gap and sizes the window to a fraction of the expected
batch-fill time — heavy arrival mixes shrink the wait toward zero
(batches fill anyway), sparse mixes stop burning latency waiting for
stragglers that aren't coming.  The chosen windows are visible in
`ServeMetrics`.

The engine is single-threaded at heart — `pump()` executes at most one
micro-batch and is the unit the tests drive deterministically (with an
injectable clock).  `start()`/`stop()` wrap it in a background thread
for live serving; `drain()` pumps until the queue is empty.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint import latest_step
from repro.core.backend import SearchParams
from repro.serve.maintenance import MaintenanceManager, MaintenancePolicy
from repro.serve.metrics import ServeMetrics
from repro.serve.queue import CoalescingQueue
from repro.serve.request import Op, QueryResult, Request, Ticket
from repro.serve.wal import KIND_INSERT, NO_LSN, WalConfig, WalRecord, WriteAheadLog

#: The engine's locking contract, machine-checked by the
#: `lock-discipline` rule of `tools.repro_lint`: every listed attribute
#: may only be touched with its lock held (`__init__` and the
#: single-threaded `recover` path excepted).  `_lock` is the cheap
#: submit-side lock — `submit_*` never waits behind a device dispatch;
#: `_pump_lock` serializes batch execution, the id maps it mutates, and
#: the deferred-ack/checkpoint bookkeeping.
_GUARDED_BY = {
    "_lock": ("queue", "_seq", "_gap_ema", "_last_arrival"),
    "_pump_lock": (
        "_int2ext", "_ext2int", "_next_ext", "_deleted_ext",
        "_pending_acks", "_oldest_pending_t", "_covering_lsn",
        "_has_ckpt", "_ckpt_seq", "batch_log",
    ),
}
#: permitted nesting order, outermost first: a pump takes `_pump_lock`
#: then briefly `_lock` to pop the batch; taking them the other way
#: round is the ABBA deadlock the LK202 rule rejects
_LOCK_ORDER = ("_pump_lock", "_lock")


@dataclass
class ServeConfig:
    """Engine knobs. Batch caps are also the fixed pad widths."""

    query_batch: int = 32
    insert_batch: int = 32
    delete_batch: int = 32
    #: per-op coalescing windows (seconds).  With `adaptive_windows`
    #: these are only the starting values used until the arrival-rate
    #: EMA has a sample; without it they are the static windows.
    query_window: float = 0.002
    insert_window: float = 0.005
    delete_window: float = 0.005
    #: Quake-style arrival-shaped windows: EMA the per-op inter-arrival
    #: gap and wait `window_fill` of the expected time to fill the
    #: batch cap, clamped to [window_min, window_max]
    adaptive_windows: bool = True
    window_min: float = 0.0
    window_max: float = 0.02
    window_fill: float = 0.5
    window_alpha: float = 0.2         # EMA smoothing of arrival gaps
    #: strict = serializable in arrival order (parity mode); relaxed =
    #: same-op coalescing across op boundaries (throughput mode)
    strict_order: bool = False
    k: Optional[int] = None           # result width; None = backend config
    #: typed per-query knobs (`SearchParams`): None fields resolve from
    #: the backend config at dispatch — the engine adds only its own
    #: serving-path fields (use_snapshot, pad_to = query_batch) and, when
    #: `record_heat` is left None, records edge heat only when the
    #: maintenance policy consumes it (heat_budget or tier_policy set);
    #: the per-batch heat scatter is pure cost otherwise
    search: SearchParams = field(default_factory=SearchParams)
    maintenance: MaintenancePolicy = field(default_factory=MaintenancePolicy)
    #: durability spine (DESIGN.md §11).  `wal` turns on write-ahead
    #: logging of every insert/delete micro-batch: tickets defer until
    #: the covering group commit, so an acknowledged write survives any
    #: crash.  `ckpt_dir` enables covering checkpoints (manual via
    #: `checkpoint()`, automatic via `maintenance.checkpoint_every`).
    wal: Optional[WalConfig] = None
    ckpt_dir: Optional[str] = None
    ckpt_keep: int = 3


class ServeEngine:
    def __init__(self, backend, cfg: Optional[ServeConfig] = None,
                 clock=time.monotonic):
        self.backend = backend
        self.cfg = cfg or ServeConfig()
        self.clock = clock
        self.metrics = ServeMetrics()
        self.maintenance = MaintenanceManager(backend, self.cfg.maintenance)
        self.queue = CoalescingQueue(
            batch_caps={Op.QUERY: self.cfg.query_batch,
                        Op.INSERT: self.cfg.insert_batch,
                        Op.DELETE: self.cfg.delete_batch},
            windows={Op.QUERY: self.cfg.query_window,
                     Op.INSERT: self.cfg.insert_window,
                     Op.DELETE: self.cfg.delete_window},
            strict_order=self.cfg.strict_order)
        self._seq = 0
        self._lock = threading.RLock()       # submit side; see _GUARDED_BY
        self._pump_lock = threading.RLock()  # execution side; see _GUARDED_BY
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        # stable external ids across reorder permutations and shards:
        # the engine allocates external ids sequentially in insert order
        # (build rows seed the map via backend.initial_ids()), every
        # relayout perm is folded into this pair of maps, and -1 marks
        # the unallocated region of either space
        cap = backend.cap
        self._int2ext = np.full(cap, -1, dtype=np.int64)
        self._ext2int = np.full(cap, -1, dtype=np.int64)
        born = np.asarray(backend.initial_ids(), np.int64)
        self._int2ext[born] = np.arange(len(born))
        self._ext2int[:len(born)] = born
        self._next_ext = len(born)
        # external ids already deleted through this engine: a repeat
        # delete (relaxed coalescing can double-submit one client retry)
        # is dropped host-side as a counted no-op instead of reaching the
        # device.  Internal ids are never reused (consolidation retires
        # them, DESIGN.md §9), so entries are never removed.
        self._deleted_ext: set = set()
        # adaptive-window state: per-op EMA of inter-arrival gaps
        self._gap_ema: Dict[Op, Optional[float]] = {op: None for op in Op}
        self._last_arrival: Dict[Op, Optional[float]] = {
            op: None for op in Op}
        self._caps = {Op.QUERY: self.cfg.query_batch,
                      Op.INSERT: self.cfg.insert_batch,
                      Op.DELETE: self.cfg.delete_batch}
        for op, w in self.queue.windows().items():
            self.metrics.windows[op] = w
        self.batch_log: List[tuple] = []   # (op, size) per executed batch
        # durability spine (DESIGN.md §11): opening the WAL scans its
        # segments and truncates any torn tail; write-op tickets are
        # staged in _pending_acks and resolve only once a group commit
        # covers their record (ack => record fsync'd)
        self.wal: Optional[WriteAheadLog] = \
            WriteAheadLog(self.cfg.wal) if self.cfg.wal is not None else None
        self._pending_acks: List[Tuple[Ticket, Any]] = []
        self._oldest_pending_t: Optional[float] = None
        self._covering_lsn = NO_LSN       # lsn of the last checkpoint
        self._has_ckpt = False
        self._ckpt_seq = 0                # checkpoint step when no WAL
        #: crash-recovery harness gate (ft/elastic.FailureInjector);
        #: None in production — every injection point is then free
        self.injector = None
        self.maintenance.checkpoint_fn = self.checkpoint
        self.maintenance.crash_hook = self._crash

    # -- submission -----------------------------------------------------------

    def _submit(self, op: Op, payload) -> Ticket:
        with self._lock:
            now = self.clock()
            req = Request(op=op, payload=payload, seq=self._seq,
                          t_enqueue=now)
            self._seq += 1
            self.queue.push(req)
            if self.cfg.adaptive_windows:
                last = self._last_arrival[op]
                if last is not None:
                    gap = now - last
                    ema = self._gap_ema[op]
                    a = self.cfg.window_alpha
                    self._gap_ema[op] = gap if ema is None \
                        else a * gap + (1 - a) * ema
                self._last_arrival[op] = now
            return req.ticket

    def submit_query(self, q) -> Ticket:
        """Query one vector; ticket resolves to QueryResult."""
        return self._submit(Op.QUERY, np.asarray(q, np.float32))

    def submit_insert(self, x) -> Ticket:
        """Insert one vector; ticket resolves to its stable external id."""
        return self._submit(Op.INSERT, np.asarray(x, np.float32))

    def submit_delete(self, ext_id: int) -> Ticket:
        """Delete by external id; ticket resolves to True, or False when
        the delete is a counted no-op (`metrics.delete_noops`) — the id
        was already deleted through this engine, or was never allocated.

        Rejects ids outside [0, cap) up front: -1 (the search-result pad
        value) would otherwise wrap through the numpy id map and delete
        an unrelated node.
        """
        ext_id = int(ext_id)
        if not 0 <= ext_id < self.backend.cap:
            raise ValueError(f"external id {ext_id} outside [0, "
                             f"{self.backend.cap})")
        return self._submit(Op.DELETE, ext_id)

    # -- adaptive batch shaping (Quake-style) ---------------------------------

    def _shape_windows(self) -> None:
        """Re-derive each op's coalescing window from the arrival EMA:
        wait `window_fill` of the expected time for the batch cap to
        fill, clamped to [window_min, window_max].  Ops with no gap
        sample yet keep their configured starting window."""
        for op in Op:
            ema = self._gap_ema[op]
            if ema is None:
                continue
            w = self.cfg.window_fill * self._caps[op] * ema
            w = min(max(w, self.cfg.window_min), self.cfg.window_max)
            self.queue.set_window(op, w)
            self.metrics.windows[op] = w

    # -- execution ------------------------------------------------------------

    def _exec_query(self, reqs: List[Request]) -> None:
        qs = np.stack([r.payload for r in reqs])
        if self.backend.snapshot_stale:
            self.metrics.snapshot_resolves += 1
        p = self.cfg.search
        if p.record_heat is None:
            # both heat consumers need the traversal signal: the reorder
            # trigger and the tier demotion policy (DESIGN.md §12)
            p = p.replace(record_heat=(
                self.cfg.maintenance.heat_budget is not None
                or self.cfg.maintenance.tier_policy is not None))
        res = self.backend.search(
            qs, k=self.cfg.k,
            params=p.replace(use_snapshot=True,
                             pad_to=self.cfg.query_batch))
        ext = np.where(res.ids >= 0,
                       self._int2ext[np.maximum(res.ids, 0)], -1)
        for row_ids, row_d, req in zip(ext, res.dists, reqs):
            req.ticket._complete(QueryResult(ids=row_ids, dists=row_d))

    def _exec_insert(self, reqs: List[Request]) -> None:
        xs = np.stack([r.payload for r in reqs])
        n = len(reqs)
        # external ids are pre-assigned (allocation is sequential and
        # deterministic) so the WAL record carries them *before* the
        # backend dispatch: replaying the record reproduces the same
        # ext->int binding the original acks promised
        ext_ids = np.arange(self._next_ext, self._next_ext + n,
                            dtype=np.int64)
        pre_lsn = self.wal.last_lsn if self.wal is not None else NO_LSN
        try:
            self._log_batch(
                lambda: self.wal.append_insert(ext_ids, xs))
            res = self.backend.insert_batch(xs, pad_to=self.cfg.insert_batch)
        except BaseException:
            if self.wal is not None and self.wal.last_lsn > pre_lsn:
                # the record is in the log but the batch failed: burn
                # its ext ids so the next batch can't log them again —
                # a replay of the orphaned record then lands on ids no
                # acked batch owns (an at-least-once ghost the client
                # retries), instead of rebinding ids a later acked
                # batch was granted
                self._next_ext += n
            raise
        gids = np.asarray(res.ids, np.int64)
        self._next_ext += n
        self._ext2int[ext_ids] = gids
        self._int2ext[gids] = ext_ids
        # one batched host conversion for the whole ack run, not one
        # numpy-scalar unboxing per request
        for ext, req in zip(ext_ids.tolist(), reqs):
            self._stage_ack(req.ticket, ext)

    def _apply_delete(self, ext: np.ndarray) -> np.ndarray:
        """Dedup + dispatch one delete batch; returns the fresh mask.

        Drops repeats and never-allocated ids host-side: the ticket
        still resolves (False), but nothing reaches the device for
        them — a double delete must be a counted no-op, not a write,
        and an unallocated ext id must not be poisoned against the
        day an insert hands it out.  WAL replay re-enters here with the
        *as-submitted* batch: the same dedup against the restored
        deleted-set absorbs duplicates, which is what makes replay
        idempotent.
        """
        internal = self._ext2int[ext]
        fresh = np.ones(len(ext), bool)
        batch_seen: set = set()
        # two batched host conversions up front instead of a
        # numpy-scalar unboxing per element
        dead = (internal < 0).tolist()
        for j, e in enumerate(ext.tolist()):
            if e in self._deleted_ext or e in batch_seen or dead[j]:
                fresh[j] = False
            else:
                batch_seen.add(e)
        n_noop = int((~fresh).sum())
        if n_noop:
            self.metrics.delete_noops += n_noop
        gids = np.where(fresh, internal, -1)
        if fresh.any():
            self.backend.delete_batch(gids, pad_to=self.cfg.delete_batch)
        # record only after the device call succeeded: a raised dispatch
        # must not poison the ids as 'already deleted' (the client will
        # retry the failed tickets)
        self._deleted_ext.update(batch_seen)
        self.maintenance.note_deletes(int(fresh.sum()))
        return fresh

    def _exec_delete(self, reqs: List[Request]) -> None:
        ext = np.asarray([r.payload for r in reqs], np.int64)
        self._log_batch(lambda: self.wal.append_delete(ext))
        fresh = self._apply_delete(ext)
        for req, f in zip(reqs, fresh.tolist()):
            self._stage_ack(req.ticket, f)

    # -- WAL group commit + failure injection (DESIGN.md §11) -----------------

    def _log_batch(self, append: Callable[[], int]) -> int:
        """Append one write batch's WAL record, then pass the two ingest
        injection points.  Returns the record's LSN (NO_LSN without a
        WAL).  `pre_commit` crashes lose the (unsynced) record along
        with its unacked tickets; `post_commit_pre_apply` first forces
        the record durable, modelling a crash after the group commit but
        before the in-memory apply — recovery must replay it."""
        if self.wal is None:
            return NO_LSN
        lsn = append()
        self.metrics.wal_records += 1
        if self._oldest_pending_t is None:
            self._oldest_pending_t = self.clock()
        self._crash("pre_commit")
        self._crash("post_commit_pre_apply")
        return lsn

    def _stage_ack(self, ticket: Ticket, value) -> None:
        """Resolve now (no WAL) or defer until the covering commit."""
        if self.wal is None:
            ticket._complete(value)
        else:
            self._pending_acks.append((ticket, value))

    def _commit_wal(self, *, force: bool = False) -> None:
        """Group commit: fsync once `group_commit_n` records are pending
        or the oldest has waited `group_commit_ms`, then resolve every
        staged ticket — the invariant is ack => record durable."""
        if self.wal is None or self.wal.n_unsynced == 0:
            if self.wal is not None and self._pending_acks:
                # records already durable (e.g. a forced sync at an
                # injection point); release the acks they cover
                self._release_acks()
            return
        wcfg = self.wal.cfg
        age_ms = 0.0
        if self._oldest_pending_t is not None:
            age_ms = (self.clock() - self._oldest_pending_t) * 1e3
        if not (force or self.wal.n_unsynced >= wcfg.group_commit_n
                or (wcfg.group_commit_ms > 0
                    and age_ms >= wcfg.group_commit_ms)):
            return
        self.wal.sync()
        self.metrics.wal_commits += 1
        self._release_acks()

    def _release_acks(self) -> None:
        acks, self._pending_acks = self._pending_acks, []
        self._oldest_pending_t = None
        for ticket, value in acks:
            ticket._complete(value)

    def _crash(self, point: str) -> None:
        """Failure-injection gate.  `point` is one of the matrix in
        DESIGN.md §11: pre_commit, post_commit_pre_apply,
        mid_checkpoint, mid_consolidation.  No-op without an injector.
        """
        inj = self.injector
        if inj is None:
            return
        if (point == "post_commit_pre_apply" and self.wal is not None
                and inj.armed(point)):
            self.wal.sync()   # the record must survive this crash
        inj.at(point)

    def _apply_perm(self, perm: np.ndarray) -> None:
        """Fold a reorder permutation (perm[old_int] = new_int, identity
        outside the permuted region) into the external id maps; internal
        ids allocated after the perm are untouched, unallocated entries
        stay -1."""
        perm = np.asarray(perm, np.int64)
        n = len(perm)
        old_ext = self._int2ext[:n].copy()
        self._int2ext[perm] = old_ext
        alloc = old_ext >= 0
        self._ext2int[old_ext[alloc]] = perm[alloc]

    @property
    def delete_noops(self) -> int:
        """Total no-op deletes: engine-level repeats/unallocated dropped
        host-side, plus the backend stats surface's device-side count of
        deletes that hit absent/dead internal ids."""
        return self.metrics.delete_noops + self.backend.stats().delete_noops

    def _claim_overlap(self, *, block: bool = False) -> None:
        """Book a finished overlapped consolidation (DESIGN.md §13)."""
        if self.maintenance.poll_overlap(block=block):
            self.metrics.maintenance_runs["consolidate"] += 1

    def pump(self, *, force: bool = False) -> Optional[Op]:
        """Execute at most one micro-batch; returns its op, or None.

        `force` releases under-full runs immediately (drain semantics).
        Pumps are serialized against each other by `_pump_lock`, but the
        queue lock is held only to pop the batch — submit_* never waits
        behind a device dispatch.

        While an overlapped repair is in flight (relaxed mode), write
        batches are held back — their write barrier would force the
        cutover early and stall on the repair — and queries keep
        flowing against the live state; the hold lifts as soon as the
        repair lands (polled here every pump).  Under `force` (drain
        semantics) a held write forces the cutover instead of waiting.
        """
        with self._pump_lock:
            self._claim_overlap()   # book a landed repair promptly
            hold = (self.maintenance.overlap_inflight
                    and not self.cfg.strict_order)
            with self._lock:
                if self.cfg.adaptive_windows:
                    self._shape_windows()
                got = self.queue.next_batch(self.clock(), force=force,
                                            hold_writes=hold)
                held_writes = hold and (
                    self.queue.has_pending(Op.INSERT)
                    or self.queue.has_pending(Op.DELETE))
            if held_writes:
                self.metrics.write_holds += 1
            if got is None and held_writes and force:
                # drain must make progress: force the cutover, then
                # release the held writes normally
                self._claim_overlap(block=True)
                with self._lock:
                    got = self.queue.next_batch(self.clock(), force=True)
            if got is None:
                # no batch released: still honor the group-commit clock
                # so deferred acks can't wait behind an idle queue
                self._commit_wal()
                return None
            op, reqs = got
            try:
                if op is Op.QUERY:
                    self._exec_query(reqs)
                else:
                    if op is Op.INSERT:
                        self._exec_insert(reqs)
                    else:
                        self._exec_delete(reqs)
                    self.maintenance.note_write_batch()
                    actions = self.maintenance.run_if_due()
                    if "reorder" in actions:
                        self._apply_perm(self.maintenance.last_perm)
                    for a in actions:
                        self.metrics.maintenance_runs[a] += 1
                    self._commit_wal()
                    self.maintenance.maybe_checkpoint()
            except BaseException as e:
                # un-stage this batch's deferred acks before failing its
                # tickets: a later group commit must not resolve a
                # ticket the client was already told failed
                dead = {r.ticket for r in reqs}
                self._pending_acks = [(t, v) for t, v in self._pending_acks
                                      if t not in dead]
                for r in reqs:
                    if not r.ticket.done:
                        r.ticket._fail(e)
                raise
            now = self.clock()
            self.metrics.record_batch(
                op, len(reqs), [now - r.t_enqueue for r in reqs], now)
            self.batch_log.append((op, len(reqs)))
            return op

    def drain(self) -> int:
        """Pump until the queue is empty (then force the group commit so
        every staged ack resolves); returns batches executed."""
        n = 0
        while True:
            with self._lock:
                empty = len(self.queue) == 0
            if empty:
                with self._pump_lock:
                    self._commit_wal(force=True)
                    # settle any in-flight overlapped repair: after a
                    # drain the maintenance counters must be final
                    self._claim_overlap(block=True)
                return n
            if self.pump(force=True) is not None:
                n += 1

    # -- durability: checkpoint / recover (DESIGN.md §11) ---------------------

    def resolve_ext(self, ext_id: int) -> int:
        """Internal id currently backing an external id (-1 = none) —
        the id-level survival probe the recovery harness verifies with."""
        with self._pump_lock:
            return int(self._ext2int[int(ext_id)])

    def is_deleted(self, ext_id: int) -> bool:
        """True if this engine has applied a delete of `ext_id`."""
        with self._pump_lock:
            return int(ext_id) in self._deleted_ext

    def checkpoint(self) -> Optional[str]:
        """Write a covering checkpoint: force the group commit, save the
        backend with the engine's id maps as extras, then drop WAL
        segments the checkpoint covers.  Returns the published path, or
        None when disabled / nothing new to cover.  The covering LSN in
        the manifest is the replay cut: recovery applies exactly the
        records after it."""
        if self.cfg.ckpt_dir is None:
            return None
        with self._pump_lock:
            # a checkpoint must capture a settled backend: force the
            # overlapped-repair cutover first so the saved state and the
            # maintenance counters agree
            self._claim_overlap(block=True)
            if self.wal is not None:
                self._commit_wal(force=True)
                lsn = self.wal.last_lsn
                if self._has_ckpt and lsn == self._covering_lsn:
                    return None          # nothing new since last cover
            else:
                self._ckpt_seq += 1
                lsn = self._ckpt_seq
            deleted = np.zeros(self.backend.cap, bool)
            if self._deleted_ext:
                deleted[np.fromiter(self._deleted_ext, np.int64)] = True
            # _seq belongs to the submit side: snapshot it under _lock
            # (reading it under _pump_lock alone races a live submit_*)
            with self._lock:
                seq = self._seq
            path = self.backend.save(
                self.cfg.ckpt_dir, lsn=lsn,
                extra={"int2ext": self._int2ext, "ext2int": self._ext2int,
                       "deleted": deleted},
                meta={"next_ext": self._next_ext, "seq": seq,
                      # maintenance trigger phase: replay must re-enter
                      # run_if_due with the same counters or its
                      # consolidate/compact timing drifts from the
                      # original timeline (breaking bit-exact replay)
                      "maint_since_check":
                          self.maintenance.write_batches_since_check,
                      "maint_deletes":
                          self.maintenance.deletes_since_compact},
                keep=self.cfg.ckpt_keep,
                _pre_publish=lambda: self._crash("mid_checkpoint"))
            self._covering_lsn = lsn
            self._has_ckpt = True
            self.metrics.maintenance_runs["checkpoint"] += 1
            if self.wal is not None:
                self.wal.truncate_through(lsn)
            return path

    @classmethod
    def recover(cls, cfg: ServeConfig, *,
                fresh_backend: Callable[[], Any],
                restore_backend: Optional[
                    Callable[[str], Tuple[Any, dict, dict]]] = None,
                clock=time.monotonic, injector=None) -> "ServeEngine":
        """Rebuild an engine after a crash (or cold-start it — with no
        checkpoint and an empty WAL this is a plain constructor).

        `restore_backend(ckpt_dir) -> (backend, metadata, extras)` is
        the implementation's restore classmethod (e.g.
        ``lambda d: LSMVecIndex.restore(hnsw_cfg, d)``); `fresh_backend`
        builds the empty backend when no checkpoint exists.  Opening the
        WAL truncates any torn tail; the tail records past the covering
        LSN then replay through the normal dispatch path.
        """
        backend, md, extras = None, {}, {}
        if (cfg.ckpt_dir is not None and restore_backend is not None
                and latest_step(cfg.ckpt_dir) is not None):
            backend, md, extras = restore_backend(cfg.ckpt_dir)
        restored = backend is not None
        if backend is None:
            backend = fresh_backend()
        eng = cls(backend, cfg, clock=clock)
        eng.injector = injector
        if restored:
            eng._int2ext = np.asarray(extras["int2ext"], np.int64).copy()
            eng._ext2int = np.asarray(extras["ext2int"], np.int64).copy()
            eng._deleted_ext = set(
                np.flatnonzero(np.asarray(extras["deleted"], bool)).tolist())
            eng._next_ext = int(md["next_ext"])
            eng._seq = int(md["seq"])
            eng._covering_lsn = int(md.get("lsn", NO_LSN))
            # without a WAL the checkpoint "lsn" is the engine's own
            # step counter: resume it, or the first post-recovery
            # checkpoint publishes step_1 below the restored step_N and
            # latest_step keeps resolving the stale checkpoint forever
            eng._ckpt_seq = eng._covering_lsn
            eng._has_ckpt = True
            eng.maintenance.write_batches_since_check = \
                int(md.get("maint_since_check", 0))
            eng.maintenance.deletes_since_compact = \
                int(md.get("maint_deletes", 0))
        if eng.wal is not None:
            eng._replay(eng.wal.records(after=eng._covering_lsn))
            # replay may have re-triggered an overlapped repair; settle
            # it so the recovered engine's state is deterministic
            eng._claim_overlap(block=True)
        return eng

    def _replay(self, records: List[WalRecord]) -> int:
        """Re-dispatch recovered WAL records through the identical batch
        path — same pad widths, same maintenance cadence — so for
        deterministic policies the recovered backend is bit-exact with
        an uninterrupted run of the same record sequence.  Exactly-once
        relative to the restored state: backend memory is volatile, so
        everything after the covering LSN is by definition unapplied.
        Returns the number of records applied."""
        n = 0
        # recovery is single-threaded, but holding the execution lock
        # keeps the _GUARDED_BY contract uniform (and is free: RLock,
        # no contention before serving starts)
        with self._pump_lock:
            for rec in records:
                if rec.kind == KIND_INSERT:
                    res = self.backend.insert_batch(
                        rec.vectors, pad_to=self.cfg.insert_batch)
                    gids = np.asarray(res.ids, np.int64)
                    self._ext2int[rec.ext_ids] = gids
                    self._int2ext[gids] = rec.ext_ids
                    self._next_ext = max(self._next_ext,
                                         int(rec.ext_ids.max()) + 1)
                else:
                    self._apply_delete(rec.ext_ids)
                self.maintenance.note_write_batch()
                actions = self.maintenance.run_if_due()
                if "reorder" in actions:
                    self._apply_perm(self.maintenance.last_perm)
                for a in actions:
                    self.metrics.maintenance_runs[a] += 1
                n += 1
        return n

    def close(self) -> None:
        """Graceful shutdown: stop serving, drain, close the WAL.  A
        crash-recovery test never calls this — simulated death abandons
        the files exactly as a killed process would."""
        self.stop()
        if self.wal is not None:
            self.wal.close()

    # -- background serving ---------------------------------------------------

    def start(self) -> None:
        """Run the pump loop in a daemon thread (live serving mode)."""
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                if self.pump() is None:
                    # nothing released: sleep one coalescing quantum
                    time.sleep(min(self.cfg.query_window,
                                   self.cfg.insert_window, 0.001))

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="lsmvec-serve")
        self._thread.start()

    def stop(self, *, drain: bool = True) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None
        if drain:
            self.drain()
