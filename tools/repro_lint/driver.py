"""Rule orchestration: run rules, apply suppressions, render reports."""

from __future__ import annotations

import ast
import json
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

from tools.repro_lint.project import Project, SourceFile
from tools.repro_lint.registry import RULES, rule_names
from tools.repro_lint.suppressions import Suppression


@dataclass
class Finding:
    code: str                   # e.g. "HS001"
    path: str
    line: int
    message: str
    rule: str = ""              # registry family name
    suppressed: bool = field(default=False, compare=False)

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"


@dataclass
class LintReport:
    findings: List[Finding]            # unsuppressed, fatal
    suppressed: List[Finding]          # matched by a reasoned comment
    warnings: List[str]                # unused suppressions etc.
    rules_run: List[str]

    @property
    def failed(self) -> bool:
        return bool(self.findings)

    def render(self) -> str:
        out: List[str] = []
        for f in sorted(self.findings, key=lambda f: (f.path, f.line, f.code)):
            out.append(f.render())
        for w in self.warnings:
            out.append(f"warning: {w}")
        out.append(
            f"repro-lint: {len(self.findings)} finding(s), "
            f"{len(self.suppressed)} suppressed, "
            f"rules: {', '.join(self.rules_run)}")
        return "\n".join(out)

    def to_json(self) -> str:
        def enc(f: Finding) -> Dict:
            return {"code": f.code, "path": f.path, "line": f.line,
                    "message": f.message, "rule": f.rule}

        return json.dumps({
            "failed": self.failed,
            "findings": [enc(f) for f in sorted(
                self.findings, key=lambda f: (f.path, f.line, f.code))],
            "suppressed": [enc(f) for f in sorted(
                self.suppressed, key=lambda f: (f.path, f.line, f.code))],
            "warnings": self.warnings,
            "rules_run": self.rules_run,
        }, indent=2)


def _statement_extents(sf: SourceFile) -> List[Tuple[int, int]]:
    spans: List[Tuple[int, int]] = []
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.stmt):
            spans.append((node.lineno, node.end_lineno or node.lineno))
    return spans


def _coverage(sup: Suppression, sf: SourceFile,
              spans: List[Tuple[int, int]]) -> Tuple[int, int]:
    """Inclusive line range a suppression comment covers."""
    if sup.on_def_line and sup.codes:
        # block scope: the whole def/class body
        cands = [s for s in spans if s[0] == sup.line]
        if cands:
            return (sup.line, max(e for _, e in cands))
    if sup.standalone:
        nxt = [s for s in spans if s[0] > sup.line]
        if not nxt:
            return (sup.line, sup.line)
        start = min(s[0] for s in nxt)
        ends = [e for b, e in nxt if b == start]
        # cover only the header line of compound statements so a
        # standalone comment above a `with`/`for` doesn't blanket the body
        first = min(ends)
        return (start, first if _is_simple(sf, start) else start)
    # trailing: innermost statement whose span includes the line
    cands = [s for s in spans if s[0] <= sup.line <= s[1]]
    if not cands:
        return (sup.line, sup.line)
    start = max(b for b, _ in cands)
    end = min(e for b, e in cands if b == start)
    if not _is_simple(sf, start):
        end = sup.line            # header-only for compound statements
    return (start, max(end, sup.line))


def _is_simple(sf: SourceFile, lineno: int) -> bool:
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.stmt) and node.lineno == lineno:
            if isinstance(node, (ast.If, ast.For, ast.While, ast.With,
                                 ast.Try, ast.FunctionDef,
                                 ast.AsyncFunctionDef, ast.ClassDef)):
                return False
    return True


def _apply_suppressions(project: Project,
                        findings: List[Finding]) -> LintReport:
    live: List[Finding] = []
    suppressed: List[Finding] = []
    warnings: List[str] = []

    cover: Dict[str, List[Tuple[Suppression, Tuple[int, int]]]] = {}
    for path, sf in project.files.items():
        spans = _statement_extents(sf)
        entries = []
        for sup in sf.suppressions:
            if not sup.reason:
                live.append(Finding(
                    code="SUP001", path=path, line=sup.line,
                    message=f"`# {sup.kind}` suppression without a "
                            "reason — write `# "
                            f"{sup.kind}: <why this is allowed>`",
                    rule="suppressions"))
                continue
            entries.append((sup, _coverage(sup, sf, spans)))
        cover[path] = entries

    for f in findings:
        hit = None
        for sup, (lo, hi) in cover.get(f.path, []):
            if lo <= f.line <= hi and sup.matches(f.code):
                hit = sup
                break
        if hit is not None:
            hit.used = True
            f.suppressed = True
            suppressed.append(f)
        else:
            live.append(f)

    for path, entries in cover.items():
        for sup, _ in entries:
            if not sup.used:
                warnings.append(
                    f"{path}:{sup.line}: unused `# {sup.kind}` "
                    f"suppression ({sup.reason})")
    return LintReport(live, suppressed, warnings, [])


def lint_project(project: Project,
                 rules: Optional[Iterable[str]] = None) -> LintReport:
    names = rule_names(rules)
    findings: List[Finding] = []
    for path, msg in project.errors:
        findings.append(Finding(
            code="PARSE", path=path, line=1,
            message=f"could not parse: {msg}", rule="driver"))
    for name in names:
        for f in RULES[name](project):
            f.rule = f.rule or name
            findings.append(f)
    report = _apply_suppressions(project, findings)
    report.rules_run = names
    return report


def lint_paths(paths: Iterable[str], root: str = ".",
               rules: Optional[Iterable[str]] = None) -> LintReport:
    return lint_project(Project.from_paths(paths, root=root), rules)


def lint_sources(sources: Dict[str, str],
                 rules: Optional[Iterable[str]] = None) -> LintReport:
    return lint_project(Project.from_sources(sources), rules)
