"""repro-lint: invariant-enforcing static analysis for this repo.

The rules encode the cross-cutting invariants the end-to-end benchmarks
gate only after the fact (DESIGN.md §14): no host syncs on the serve
hot path, jit donation/static-arg discipline, the scheduler's
guarded-by lock map, and `VectorBackend` protocol conformance.

Usage::

    python -m tools.repro_lint src tests benchmarks

Programmatic::

    from tools.repro_lint import lint_paths, lint_sources
    report = lint_paths(["src"])
    assert not report.failed, report.render()
"""

from tools.repro_lint.driver import Finding, LintReport, lint_paths, lint_sources
from tools.repro_lint.project import Project
from tools.repro_lint.registry import RULES, register

__all__ = [
    "RULES",
    "Finding",
    "LintReport",
    "Project",
    "lint_paths",
    "lint_sources",
    "register",
]
