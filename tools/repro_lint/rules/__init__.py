"""Rule modules; importing each registers it with the registry."""
