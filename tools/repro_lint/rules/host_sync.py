"""HS: host-sync-in-hot-path (DESIGN.md §8/§13).

The serve hot path — everything reachable from `ServeEngine.pump` and
from the `dispatch_search` → `collect` fan-out — must not force a
device→host synchronization.  A stray `int(x)` on a jax array blocks
the python thread on the device stream and re-serializes the async
spine.

Codes:

HS001  host sync applied to a jax-array-typed value in a hot-path
       function: ``int()/float()/bool()``, ``np.asarray/np.array``,
       ``.item()/.tolist()``, ``jax.device_get``,
       ``jax.block_until_ready``, iterating the array, or branching
       on it.  Every *legitimate* sync point carries a
       ``# sync-ok: <reason>`` comment.
HS002  per-element ``int()/float()`` conversion of the loop variable
       inside a hot-path loop — one host transfer per element even on
       numpy values; batch into a single
       ``np.asarray(...).tolist()`` transfer instead.

Taint is lexical and per-function: values produced by jax/jnp/lax
calls, jitted-handle calls (``self._*_fn``), and known device-resident
attributes are tainted; ``int()`` and friends cleanse (and are flagged
when their operand is tainted).  The call-graph hot set is a name-based
over-approximation — the answer to a false positive is a reasoned
suppression, never silence.
"""

from __future__ import annotations

import ast
import re
from typing import Iterable, List, Set

from tools.repro_lint.driver import Finding
from tools.repro_lint.project import FunctionInfo, Project
from tools.repro_lint.registry import register

#: module aliases whose call results are device arrays
ARRAY_MODULES = {"jnp", "jax", "lax", "lsm", "hnsw"}

#: jax.* / module attrs that do NOT return device arrays
_NON_ARRAY_CALLS = {"jit", "named_scope", "transfer_guard",
                    "transfer_guard_device_to_host", "checking_leaks",
                    "default_device", "PRNGKey"}

#: self-attributes that hold device-resident state
TAINTED_ATTRS = {"state", "_snap", "_pending_repair", "_ids", "_dists",
                 "_rng", "heat"}

#: self-attributes that are host (numpy) despite array-ish names
HOST_ATTRS = {"_int2ext", "_ext2int"}

_JIT_HANDLE = re.compile(r"^_\w+_fn$")

#: functions excluded from hot-path analysis even when name-reachable
EXCLUDED_PATH_PARTS = ("baselines", "tests/", "benchmarks/")


def _is_excluded(path: str) -> bool:
    norm = path.replace("\\", "/")
    return any(part in norm for part in EXCLUDED_PATH_PARTS)


class _Taint:
    """Per-function lexical taint state + sink detection."""

    def __init__(self, fn: FunctionInfo, findings: List[Finding]):
        self.fn = fn
        self.findings = findings
        self.tainted: Set[str] = set()
        self.loop_vars: List[Set[str]] = []   # stack of for-loop targets

    # -- expression taint ------------------------------------------------
    def is_tainted(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in ("shape", "ndim", "dtype"):
                return False          # host-side array metadata
            if isinstance(node.value, ast.Name) and \
                    node.value.id == "self":
                if node.attr in HOST_ATTRS:
                    return False
                return node.attr in TAINTED_ATTRS
            return self.is_tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.is_tainted(node.value)
        if isinstance(node, ast.Call):
            return self.call_taint(node)
        if isinstance(node, (ast.BinOp,)):
            return self.is_tainted(node.left) or self.is_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_tainted(node.operand)
        if isinstance(node, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot))
                   for op in node.ops):
                return False          # identity checks never sync
            return self.is_tainted(node.left) or any(
                self.is_tainted(c) for c in node.comparators)
        if isinstance(node, ast.BoolOp):
            return any(self.is_tainted(v) for v in node.values)
        if isinstance(node, ast.IfExp):
            return self.is_tainted(node.body) or self.is_tainted(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.is_tainted(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.is_tainted(node.value)
        if isinstance(node, ast.NamedExpr):
            return self.is_tainted(node.value)
        return False

    def call_taint(self, node: ast.Call) -> bool:
        """Taint of a call's *result* (sinks are reported separately)."""
        func = node.func
        if isinstance(func, ast.Attribute):
            base = func.value
            if isinstance(base, ast.Name) and base.id in ARRAY_MODULES:
                return func.attr not in _NON_ARRAY_CALLS and \
                    func.attr != "device_get"
            if isinstance(base, ast.Name) and base.id == "self" and \
                    _JIT_HANDLE.match(func.attr):
                return True
            if func.attr in ("item", "tolist", "is_ready"):
                return False          # host result (sink checked elsewhere)
            # method call on a tainted object: assume array-in-array-out
            # for jnp-style chaining (x.sum(), x.astype(...))
            if self.is_tainted(base):
                return True
            return False
        if isinstance(func, ast.Name):
            if func.id in ("int", "float", "bool", "str", "len"):
                return False          # cleansing conversions
            # unknown helper (merge_topk, …): propagate through args
            return any(self.is_tainted(a) for a in node.args)
        return False

    # -- sinks -----------------------------------------------------------
    def check_call(self, node: ast.Call) -> None:
        func = node.func
        args = node.args
        if isinstance(func, ast.Name) and func.id in ("int", "float",
                                                      "bool"):
            if args and self.is_tainted(args[0]):
                self._emit("HS001", node,
                           f"`{func.id}()` on a device array forces a "
                           "host sync on the hot path")
            elif args and self._is_loop_var(args[0]) and func.id in (
                    "int", "float"):
                self._emit("HS002", node,
                           f"per-element `{func.id}()` of loop variable "
                           f"`{ast.unparse(args[0])}` — batch the "
                           "conversion with one `np.asarray(...).tolist()`"
                           " transfer before the loop")
        elif isinstance(func, ast.Attribute):
            base = func.value
            if isinstance(base, ast.Name) and base.id == "np" and \
                    func.attr in ("asarray", "array"):
                if args and self.is_tainted(args[0]):
                    self._emit("HS001", node,
                               f"`np.{func.attr}()` on a device array "
                               "copies device→host on the hot path")
            elif isinstance(base, ast.Name) and base.id == "jax" and \
                    func.attr in ("device_get", "block_until_ready"):
                self._emit("HS001", node,
                           f"`jax.{func.attr}()` synchronizes with the "
                           "device on the hot path")
            elif func.attr in ("item", "tolist") and self.is_tainted(base):
                self._emit("HS001", node,
                           f"`.{func.attr}()` on a device array forces "
                           "a host sync on the hot path")

    def _check_comprehension(self, comp: ast.AST) -> None:
        """Per-element `int()/float()` of a comprehension variable is
        the generator spelling of the HS002 loop pattern."""
        targets: Set[str] = set()
        for gen in comp.generators:
            for n in ast.walk(gen.target):
                if isinstance(n, ast.Name):
                    targets.add(n.id)
        if not targets:
            return
        for node in ast.walk(comp.elt):
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Name) and \
                    node.func.id in ("int", "float") and node.args and \
                    isinstance(node.args[0], ast.Name) and \
                    node.args[0].id in targets:
                self._emit("HS002", node,
                           f"per-element `{node.func.id}()` of "
                           f"comprehension variable "
                           f"`{node.args[0].id}` — batch the "
                           "conversion with one "
                           "`np.asarray(...).tolist()` transfer")

    def _is_loop_var(self, node: ast.AST) -> bool:
        names = {v for frame in self.loop_vars for v in frame}
        if isinstance(node, ast.Name):
            return node.id in names
        if isinstance(node, ast.Subscript) and isinstance(
                node.value, ast.Name):
            return node.value.id in names
        return False

    def _emit(self, code: str, node: ast.AST, msg: str) -> None:
        self.findings.append(Finding(
            code=code, path=self.fn.module, line=node.lineno,
            message=f"{msg} (in `{self.fn.qualname.split('::')[1]}`)"))

    # -- statement walk --------------------------------------------------
    def run(self) -> None:
        body = getattr(self.fn.node, "body", [])
        self._walk(body)

    def _walk(self, stmts: List[ast.stmt]) -> None:
        for stmt in stmts:
            self._stmt(stmt)

    def _stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return                     # nested defs analyzed separately
        # sinks anywhere in the statement's expressions
        for node in ast.walk(stmt):
            if isinstance(node, ast.Call):
                self.check_call(node)
            elif isinstance(node, (ast.GeneratorExp, ast.ListComp,
                                   ast.SetComp)):
                self._check_comprehension(node)
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = stmt.value
            if value is None:
                return
            tainted = self.is_tainted(value)
            targets = stmt.targets if isinstance(stmt, ast.Assign) \
                else [stmt.target]
            for t in targets:
                self._bind(t, tainted)
        elif isinstance(stmt, ast.For):
            if self.is_tainted(stmt.iter):
                self._emit("HS001", stmt.iter,
                           "iterating a device array forces one host "
                           "sync per element")
            frame: Set[str] = set()
            for n in ast.walk(stmt.target):
                if isinstance(n, ast.Name):
                    frame.add(n.id)
            self._bind(stmt.target, self.is_tainted(stmt.iter))
            self.loop_vars.append(frame)
            self._walk(stmt.body)
            self.loop_vars.pop()
            self._walk(stmt.orelse)
        elif isinstance(stmt, (ast.If, ast.While)):
            if self.is_tainted(stmt.test):
                self._emit("HS001", stmt.test,
                           "branching on a device array implicitly "
                           "calls `bool()` — a host sync")
            self._walk(stmt.body)
            self._walk(stmt.orelse)
        elif isinstance(stmt, ast.With):
            for item in stmt.items:
                if item.optional_vars is not None:
                    self._bind(item.optional_vars,
                               self.is_tainted(item.context_expr))
            self._walk(stmt.body)
        elif isinstance(stmt, ast.Try):
            self._walk(stmt.body)
            for h in stmt.handlers:
                self._walk(h.body)
            self._walk(stmt.orelse)
            self._walk(stmt.finalbody)
        elif isinstance(stmt, ast.Assert):
            if self.is_tainted(stmt.test):
                self._emit("HS001", stmt.test,
                           "asserting on a device array implicitly "
                           "calls `bool()` — a host sync")

    def _bind(self, target: ast.AST, tainted: bool) -> None:
        if isinstance(target, ast.Name):
            if tainted:
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._bind(e, tainted)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, tainted)
        # attribute/subscript stores don't change name taint


def hot_roots(project: Project) -> List[FunctionInfo]:
    cg = project.callgraph
    roots: List[FunctionInfo] = []
    for f in project.functions:
        if _is_excluded(f.module):
            continue
        if f.name == "pump" and f.cls is not None:
            roots.append(f)
        elif f.name in ("dispatch_search", "collect") and f.cls and \
                (f.module, f.cls) in cg._backend_classes:
            roots.append(f)
    return roots


@register("host-sync")
def check_host_sync(project: Project) -> Iterable[Finding]:
    findings: List[Finding] = []
    roots = hot_roots(project)
    if not roots:
        return findings
    hot = project.callgraph.reachable(roots)
    for f in project.functions:
        if f.qualname not in hot or _is_excluded(f.module):
            continue
        _Taint(f, findings).run()
    return findings
