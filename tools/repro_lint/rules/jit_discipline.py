"""JD: jit discipline (DESIGN.md §8 zero-retrace serving).

Codes:

JD101  donated-buffer use-after-donate: an argument passed at a
       ``donate_argnums`` position of a jitted handle is read again
       later in the same function without being rebound by the call
       statement.  Donated buffers are deallocated by XLA; the read
       returns garbage or raises.
JD102  ``static_argnames``/``static_argnums`` built from a dynamic
       expression — values must be constant strings/ints so the trace
       cache key is stable; dynamic values cause retrace storms.
JD103  ``jax.jit`` construction inside a loop body or inside a
       serve-hot-path function: each construction is a fresh trace
       cache, defeating the §8 zero-retrace guarantee.  Build handles
       once in ``__init__`` / module scope.  Hot-path roots are the
       serving entry points (`host_sync.hot_roots`) plus every kernel
       dispatch entry point — the top-level functions of
       ``kernels/*/ops.py`` modules (`kernel_roots`): those shims run
       under every serving jit, so a jit built in one retraces per
       call.
JD104  the same buffer passed to two positions of a donating call
       when one of them is donated — XLA may alias the donated input,
       corrupting the second read.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Optional, Set, Tuple

from tools.repro_lint.driver import Finding
from tools.repro_lint.project import FunctionInfo, Project, SourceFile
from tools.repro_lint.registry import register
from tools.repro_lint.rules.host_sync import hot_roots

_KERNEL_OPS_RE = re.compile(r"(^|/)kernels/[^/]+/ops\.py$")


def kernel_roots(project: Project) -> List[FunctionInfo]:
    """Kernel dispatch entry points: top-level functions of
    ``kernels/*/ops.py`` modules (`gather_l2`, `fused_beam_search`, ...).

    These shims execute under every serving jit, so a jit constructed
    anywhere reachable from them retraces on each call — they join the
    JD103 hot set.  They are deliberately NOT `host_sync` roots: the
    lazy backend probe (``jax.default_backend()``) every shim performs
    is a legitimate host call at dispatch time, not a device sync on a
    traced value."""
    return [f for f in project.functions
            if f.cls is None and _KERNEL_OPS_RE.search(f.module)]


def _is_jax_jit(node: ast.AST) -> bool:
    return (isinstance(node, ast.Attribute) and node.attr == "jit"
            and isinstance(node.value, ast.Name)
            and node.value.id == "jax") or (
        isinstance(node, ast.Name) and node.id == "jit")


def _donate_indices(call: ast.Call) -> Optional[Set[int]]:
    for kw in call.keywords:
        if kw.arg in ("donate_argnums", "donate_argnames"):
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return {v.value}
            if isinstance(v, (ast.Tuple, ast.List)):
                idx = set()
                for e in v.elts:
                    if isinstance(e, ast.Constant) and \
                            isinstance(e.value, int):
                        idx.add(e.value)
                return idx
    return None


def _jit_constructions(sf: SourceFile):
    """Yield (call_node, donate_indices|None) for every jit build."""
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        if _is_jax_jit(node.func):
            yield node, _donate_indices(node)
        elif isinstance(node.func, ast.Call) and \
                _is_jax_jit_partial(node.func):
            yield node, _donate_indices(node.func)


def _is_jax_jit_partial(call: ast.Call) -> bool:
    """``functools.partial(jax.jit, donate_argnums=...)`` pattern."""
    f = call.func
    if not (isinstance(f, ast.Attribute) and f.attr == "partial") and \
            not (isinstance(f, ast.Name) and f.id == "partial"):
        return False
    return bool(call.args) and _is_jax_jit(call.args[0])


def _expr_key(node: ast.AST) -> Optional[str]:
    """Stable key for a Name or self-attribute expression."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return f"self.{node.attr}"
    return None


class _DonatingHandles:
    """Map handle name → donated arg indices, per file."""

    def __init__(self, sf: SourceFile):
        self.handles: Dict[str, Set[int]] = {}
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Assign):
                continue
            donate = None
            for call, idx in _jit_constructions(sf):
                if call is node.value and idx:
                    donate = idx
                    break
            if donate is None:
                continue
            for t in node.targets:
                key = _expr_key(t)
                if key:
                    self.handles[key] = donate


def _check_donation(sf: SourceFile, findings: List[Finding]) -> None:
    handles = _DonatingHandles(sf).handles
    if not handles:
        return
    for fn in sf.iter_functions():
        stmts = list(ast.walk(fn.node))
        calls: List[Tuple[ast.Call, Set[int], ast.stmt]] = []
        stmt_of: Dict[int, ast.stmt] = {}
        # ast.walk is breadth-first, so later (deeper) statements
        # overwrite: each call maps to its innermost enclosing stmt
        for stmt in ast.walk(fn.node):
            if isinstance(stmt, ast.stmt):
                for sub in ast.walk(stmt):
                    if isinstance(sub, ast.Call):
                        stmt_of[id(sub)] = stmt
        for node in stmts:
            if isinstance(node, ast.Call):
                key = _expr_key(node.func)
                if key in handles:
                    calls.append((node, handles[key], stmt_of[id(node)]))
        for call, donated, stmt in calls:
            donated_keys: List[Tuple[str, int]] = []
            seen_args: Dict[str, int] = {}
            for i, arg in enumerate(call.args):
                k = _expr_key(arg)
                if k is None:
                    continue
                if k in seen_args and (i in donated or
                                       seen_args[k] in donated):
                    findings.append(Finding(
                        code="JD104", path=sf.path, line=call.lineno,
                        message=f"`{k}` passed twice to a donating "
                                "jit handle; the donated copy may "
                                "alias the other"))
                seen_args.setdefault(k, i)
                if i in donated:
                    donated_keys.append((k, i))
            if not donated_keys:
                continue
            rebound = _rebound_keys(stmt)
            for k, i in donated_keys:
                if k in rebound:
                    continue
                later = _later_load(fn.node, stmt, k)
                if later is not None:
                    findings.append(Finding(
                        code="JD101", path=sf.path, line=later,
                        message=f"`{k}` was donated at line "
                                f"{call.lineno} (donate position {i}) "
                                "and is read again — donated buffers "
                                "are deallocated by XLA"))


def _rebound_keys(stmt: ast.stmt) -> Set[str]:
    out: Set[str] = set()
    targets: List[ast.AST] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
        targets = [stmt.target]
    for t in targets:
        for node in ast.walk(t):
            k = _expr_key(node)
            if k:
                out.add(k)
    return out


def _later_load(fn_node: ast.AST, call_stmt: ast.stmt,
                key: str) -> Optional[int]:
    """Line of the first Load of `key` after the donating statement."""
    boundary = call_stmt.end_lineno or call_stmt.lineno
    for node in ast.walk(fn_node):
        if node is call_stmt:
            continue
        lineno = getattr(node, "lineno", None)
        if lineno is None or lineno <= boundary:
            continue
        if isinstance(node, (ast.Name, ast.Attribute)) and \
                isinstance(getattr(node, "ctx", None), ast.Load) and \
                _expr_key(node) == key:
            # a rebinding between boundary and this load clears it
            if _rebound_between(fn_node, key, boundary, lineno):
                return None
            return lineno
    return None


def _rebound_between(fn_node: ast.AST, key: str, lo: int,
                     hi: int) -> bool:
    for node in ast.walk(fn_node):
        if isinstance(node, (ast.Assign, ast.AnnAssign)):
            if node.lineno <= lo or node.lineno > hi:
                continue
            if key in _rebound_keys(node):
                return True
    return False


def _check_static_args(sf: SourceFile, findings: List[Finding]) -> None:
    for call, _ in _jit_constructions(sf):
        keywords = list(call.keywords)
        if isinstance(call.func, ast.Call):     # partial form
            keywords += list(call.func.keywords)
        for kw in keywords:
            if kw.arg not in ("static_argnames", "static_argnums"):
                continue
            if not _is_constant_static(kw.value):
                findings.append(Finding(
                    code="JD102", path=sf.path, line=kw.value.lineno,
                    message=f"`{kw.arg}` built from a dynamic "
                            "expression — must be constant "
                            "strings/ints for a stable trace cache "
                            "key"))


def _is_constant_static(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant):
        return isinstance(node.value, (str, int))
    if isinstance(node, (ast.Tuple, ast.List)):
        return all(_is_constant_static(e) for e in node.elts)
    return False


def _check_jit_in_loop(project: Project,
                       findings: List[Finding]) -> None:
    hot: Set[str] = set()
    roots = hot_roots(project) + kernel_roots(project)
    if roots:
        hot = project.callgraph.reachable(roots)
    hot_fn_nodes = {id(f.node) for f in project.functions
                    if f.qualname in hot}
    for sf in project.files.values():
        jit_calls = {id(c) for c, _ in _jit_constructions(sf)}
        if not jit_calls:
            continue
        for node in ast.walk(sf.tree):
            in_loop = isinstance(node, (ast.For, ast.While))
            in_hot = id(node) in hot_fn_nodes
            if not (in_loop or in_hot):
                continue
            body = node.body if in_loop else node.body
            for sub_stmt in body:
                for sub in ast.walk(sub_stmt):
                    if isinstance(sub, ast.Call) and id(sub) in jit_calls:
                        where = "a loop body" if in_loop else \
                            "a serve hot-path function"
                        findings.append(Finding(
                            code="JD103", path=sf.path,
                            line=sub.lineno,
                            message="`jax.jit` constructed inside "
                                    f"{where} — each construction is a "
                                    "fresh trace cache; build the "
                                    "handle once in `__init__`"))


@register("jit-discipline")
def check_jit_discipline(project: Project) -> Iterable[Finding]:
    findings: List[Finding] = []
    for sf in project.files.values():
        _check_donation(sf, findings)
        _check_static_args(sf, findings)
    _check_jit_in_loop(project, findings)
    # dedupe JD103 double-reported when a loop sits inside a hot fn
    seen: Set[Tuple[str, str, int]] = set()
    out: List[Finding] = []
    for f in findings:
        k = (f.code, f.path, f.line)
        if k in seen:
            continue
        seen.add(k)
        out.append(f)
    return out
