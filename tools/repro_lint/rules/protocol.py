"""PC: `VectorBackend` protocol conformance (core/backend.py contract).

Codes:

PC001  a class that implements most of the `VectorBackend` surface
       (≥ half of the protocol's methods) is missing part of the
       frozen contract.  Baselines that deliberately expose a small
       host-native API fall below the threshold and are skipped.
PC002  `collect()` called twice on one dispatch handle — `collect`
       consumes the handle (donated result buffers, §13 two-phase
       fan-out); the second call observes freed state.
PC003  the Optional result of `poll_maintain()` used without a
       None-guard — the report is only present once per maintenance
       round (claim-once), absent polls return None.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set

from tools.repro_lint.driver import Finding
from tools.repro_lint.project import Project, SourceFile
from tools.repro_lint.registry import register

#: dunders & helpers never part of the protocol surface
_IGNORED = {"__init__", "__len__", "__repr__", "__contains__"}


def _protocol_surface(project: Project) -> Set[str]:
    """Method names of the `VectorBackend` Protocol class."""
    for sf in project.files.values():
        for cls in sf.iter_classes():
            if cls.name != "VectorBackend":
                continue
            names = {n.name for n in cls.body
                     if isinstance(n, (ast.FunctionDef,
                                       ast.AsyncFunctionDef))
                     and n.name not in _IGNORED}
            names |= {n.target.id for n in cls.body
                      if isinstance(n, ast.AnnAssign)
                      and isinstance(n.target, ast.Name)}
            if names:
                return names
    return set()


def _class_surface(cls: ast.ClassDef) -> Set[str]:
    names = {n.name for n in cls.body
             if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    # attributes assigned in __init__ satisfy data members of the
    # contract (e.g. `self.cap = ...`)
    for n in cls.body:
        if isinstance(n, ast.FunctionDef) and n.name == "__init__":
            for sub in ast.walk(n):
                if isinstance(sub, ast.Attribute) and \
                        isinstance(sub.ctx, ast.Store) and \
                        isinstance(sub.value, ast.Name) and \
                        sub.value.id == "self":
                    names.add(sub.attr)
    return names


def _check_conformance(project: Project,
                       findings: List[Finding]) -> None:
    surface = _protocol_surface(project)
    if not surface:
        return
    for path, sf in project.files.items():
        for cls in sf.iter_classes():
            if cls.name == "VectorBackend":
                continue
            have = _class_surface(cls)
            overlap = have & surface
            if len(overlap) < (len(surface) + 1) // 2:
                continue                # not claiming the protocol
            missing = sorted(surface - have)
            if missing:
                findings.append(Finding(
                    code="PC001", path=path, line=cls.lineno,
                    message=f"`{cls.name}` implements "
                            f"{len(overlap)}/{len(surface)} of the "
                            "VectorBackend contract but is missing: "
                            f"{', '.join(missing)}"))


class _CollectSim:
    """Track per-name collect() consumption through branches."""

    def __init__(self, path: str, findings: List[Finding]):
        self.path = path
        self.findings = findings

    def run(self, fn: ast.FunctionDef) -> None:
        self._walk(fn.body, {})

    def _walk(self, stmts: List[ast.stmt],
              state: Dict[str, bool]) -> None:
        for stmt in stmts:
            self._stmt(stmt, state)

    def _stmt(self, stmt: ast.stmt, state: Dict[str, bool]) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return
        if isinstance(stmt, ast.If):
            s1, s2 = dict(state), dict(state)
            self._walk(stmt.body, s1)
            self._walk(stmt.orelse, s2)
            for k in set(s1) | set(s2):
                state[k] = s1.get(k, False) or s2.get(k, False)
            return
        if isinstance(stmt, (ast.For, ast.While)):
            # a loop body may rebind the handle each iteration; analyze
            # the body in isolation so one lexical collect() is legal
            self._walk(stmt.body, dict(state))
            self._walk(stmt.orelse, dict(state))
            return
        if isinstance(stmt, (ast.With, ast.Try)):
            for body in ([stmt.body] if isinstance(stmt, ast.With) else
                         [stmt.body, stmt.finalbody, stmt.orelse]
                         + [h.body for h in stmt.handlers]):
                self._walk(body, state)
            return
        # handle binding: x = <...>.dispatch_search(...)
        if isinstance(stmt, ast.Assign) and \
                isinstance(stmt.value, ast.Call) and \
                isinstance(stmt.value.func, ast.Attribute) and \
                stmt.value.func.attr == "dispatch_search":
            for t in stmt.targets:
                if isinstance(t, ast.Name):
                    state[t.id] = False
        for node in ast.walk(stmt):
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "collect" and \
                    isinstance(node.func.value, ast.Name):
                name = node.func.value.id
                if name in state:
                    if state[name]:
                        self.findings.append(Finding(
                            code="PC002", path=self.path,
                            line=node.lineno,
                            message=f"`{name}.collect()` called a "
                                    "second time — collect() consumes "
                                    "the dispatch handle"))
                    state[name] = True


def _check_poll_guard(sf: SourceFile, findings: List[Finding]) -> None:
    for fn_node in ast.walk(sf.tree):
        if not isinstance(fn_node, (ast.FunctionDef,
                                    ast.AsyncFunctionDef)):
            continue
        _poll_guard_in(fn_node, sf.path, findings)


def _poll_guard_in(fn_node: ast.AST, path: str,
                   findings: List[Finding]) -> None:
    # find `name = <x>.poll_maintain(...)` assignments
    assigns: Dict[str, int] = {}
    for node in ast.walk(fn_node):
        if isinstance(node, ast.Assign) and \
                isinstance(node.value, ast.Call) and \
                isinstance(node.value.func, ast.Attribute) and \
                node.value.func.attr == "poll_maintain":
            for t in node.targets:
                if isinstance(t, ast.Name):
                    assigns[t.id] = node.lineno
    if not assigns:
        return
    guarded: Set[str] = set()
    for node in ast.walk(fn_node):
        # any comparison/truth test mentioning the name counts as the
        # None-guard (if rep is None: return / if rep: / rep and rep.x
        # / rep.x if rep else …)
        test = None
        if isinstance(node, (ast.If, ast.While, ast.IfExp, ast.Assert)):
            test = node.test
        elif isinstance(node, ast.BoolOp):
            test = node.values[0]
        if test is None:
            continue
        for sub in ast.walk(test):
            if isinstance(sub, ast.Name) and sub.id in assigns:
                guarded.add(sub.id)
    for name, lineno in assigns.items():
        if name in guarded:
            continue
        for node in ast.walk(fn_node):
            if isinstance(node, ast.Attribute) and \
                    isinstance(node.value, ast.Name) and \
                    node.value.id == name and \
                    isinstance(node.ctx, ast.Load) and \
                    node.lineno > lineno:
                findings.append(Finding(
                    code="PC003", path=path, line=node.lineno,
                    message=f"`{name}` comes from `poll_maintain()` "
                            "(Optional, claim-once) and is used "
                            "without a None-guard"))
                break


@register("protocol")
def check_protocol(project: Project) -> Iterable[Finding]:
    findings: List[Finding] = []
    _check_conformance(project, findings)
    for path, sf in project.files.items():
        for fn_node in ast.walk(sf.tree):
            if isinstance(fn_node, (ast.FunctionDef,
                                    ast.AsyncFunctionDef)):
                _CollectSim(path, findings).run(fn_node)
        _check_poll_guard(sf, findings)
    return findings
